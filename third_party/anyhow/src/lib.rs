//! Offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io registry, so this path dependency
//! provides the subset of the `anyhow` API the workspace uses: [`Error`] as a
//! message-chain error, [`Result`] with a defaulted error type, the
//! [`anyhow!`] / [`bail!`] / [`ensure!`] macros, and the [`Context`]
//! extension trait for `Result` and `Option`.
//!
//! Semantics intentionally mirror `anyhow`:
//! * `{}` displays the outermost message, `{:#}` the whole chain joined with
//!   `": "`, and `{:?}` a readable multi-line report (what `unwrap()` prints);
//! * `?` converts any `std::error::Error + Send + Sync + 'static` into
//!   [`Error`], capturing its `source()` chain;
//! * like the real crate, [`Error`] deliberately does NOT implement
//!   `std::error::Error` (that is what keeps the blanket `From` coherent).

use std::fmt;

/// A message-chain error: `msgs[0]` is the outermost context, the last entry
/// is the root cause.
pub struct Error {
    msgs: Vec<String>,
}

/// `anyhow::Result<T>` — `std::result::Result` with [`Error`] as the default
/// error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Create an error from a printable message (the `anyhow!` entry point).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msgs: vec![message.to_string()] }
    }

    /// Wrap with an outer context message (used by the [`Context`] trait).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.msgs.insert(0, context.to_string());
        self
    }

    /// The error chain, outermost message first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.msgs.iter().map(String::as_str)
    }

    /// The innermost (root cause) message.
    pub fn root_cause(&self) -> &str {
        self.msgs.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.msgs.join(": "))
        } else {
            write!(f, "{}", self.msgs.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msgs.first().map(String::as_str).unwrap_or(""))?;
        if self.msgs.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, m) in self.msgs[1..].iter().enumerate() {
                if self.msgs.len() > 2 {
                    write!(f, "\n    {i}: {m}")?;
                } else {
                    write!(f, "\n    {m}")?;
                }
            }
        }
        Ok(())
    }
}

// NOTE: no `impl std::error::Error for Error` — its absence is what makes the
// blanket conversion below coherent with the reflexive `From<T> for T`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        Error { msgs }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(|| ..)` to
/// `Result` and `Option`.
pub trait Context<T>: Sized {
    fn context<C>(self, context: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C>(self, context: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(context)
        })
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(f())
        })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C>(self, context: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any printable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an error when a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            $crate::bail!($($t)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = Err::<(), _>(io_err())
            .context("reading manifest")
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: gone");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(e.root_cause(), "gone");
    }

    #[test]
    fn context_on_anyhow_result_and_option() {
        let e: Error = Err::<(), _>(anyhow!("inner {}", 7))
            .with_context(|| "outer")
            .unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner 7");
        let o: Result<i32> = None.context("missing value");
        assert_eq!(format!("{:#}", o.unwrap_err()), "missing value");
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative input {x}");
            if x > 10 {
                bail!("too big: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(-1).unwrap_err()), "negative input -1");
        assert_eq!(format!("{}", f(11).unwrap_err()), "too big: 11");
    }

    #[test]
    fn debug_report_includes_causes() {
        let e: Error = Err::<(), _>(io_err()).context("a").context("b").unwrap_err();
        let dbg = format!("{e:?}");
        assert!(dbg.contains('b') && dbg.contains("Caused by"));
    }
}
