//! Tables 9 & 12 — inference speed (tok/s) per task for all six methods on
//! ∞Bench and RULER at 128K, three model profiles (paper §4.2 speed runs).
//!
//! speed = (#input + #output) / (prefill + decode) per the paper's metric;
//! per-task #output comes from the task profiles, prefill/decode from the
//! calibrated wall-time model.

use apb::attnsim::{estimate, speed_tok_per_s, Hyper, Method, ModelProfile, A800,
                   LLAMA31_8B, QWEN25_14B, YI_34B};
use apb::bench_harness::Table;
use apb::report;
use apb::ruler::tasks::{infbench_tasks, ruler_tasks, TaskProfile};
use apb::util::json::{self, Json};

const N: f64 = 131072.0;
const HOSTS: f64 = 8.0;

fn speed_for(method: Method, model: &ModelProfile, task: &TaskProfile) -> Option<f64> {
    let h = if method.uses_sequence_parallelism() { HOSTS } else { 1.0 };
    let hy = Hyper::e2e_128k();
    let n_out = task.out_tokens as f64;
    // Yi-34B runs layer-split across two machines (§B.2.1): each stage
    // holds half the layers; pipeline prefill ~ sequential halves on the
    // critical path -> model full depth (already in the profile).
    let est = estimate(method, model, N, h, &hy, &A800, n_out);
    speed_tok_per_s(&est, N, n_out)
}

fn run(title: &str, experiment: &str, tasks: &[TaskProfile]) {
    let mut rows = Vec::new();
    for model in [&LLAMA31_8B, &QWEN25_14B, &YI_34B] {
        let mut headers: Vec<&str> = vec!["Method"];
        headers.extend(tasks.iter().map(|t| t.id));
        headers.push("Avg.");
        let mut table = Table::new(&format!("{title} — {}", model.name), &headers);
        for method in Method::ALL {
            let mut cells = vec![method.name().to_string()];
            let mut sum = 0.0;
            let mut cnt = 0.0;
            for t in tasks {
                match speed_for(method, model, t) {
                    Some(s) => {
                        cells.push(format!("{s:.0}"));
                        sum += s;
                        cnt += 1.0;
                        rows.push(report::row(vec![
                            ("model", json::s(model.name)),
                            ("method", json::s(method.name())),
                            ("task", json::s(t.id)),
                            ("tok_per_s", json::num(s)),
                        ]));
                    }
                    None => cells.push("OOM".into()),
                }
            }
            cells.push(if cnt > 0.0 { format!("{:.0}", sum / cnt) } else { "OOM".into() });
            table.row(cells);
        }
        table.print();
    }

    let path = report::write_report(experiment, vec![("n", json::num(N))],
                                    Json::Arr(rows)).expect("report");
    println!("[report] {}", path.display());
}

fn main() {
    run("Table 9: ∞Bench speed (tok/s)", "tab9_infbench_speed", &infbench_tasks());
    run("Table 12: RULER speed (tok/s)", "tab12_ruler_speed", &ruler_tasks());

    // Shape check vs paper headline speedup columns (Llama, RULER avg:
    // APB 37077 vs Flash 4156 = 8.9x; vs Ring 17876 = 2.07x; vs Star
    // 26675 = 1.39x).
    let t = &ruler_tasks()[0];
    let s = |m| speed_for(m, &LLAMA31_8B, t).unwrap();
    println!("\nSG1 Llama speedups — APB/Flash {:.1}x  APB/Ring {:.1}x  APB/Star {:.2}x",
             s(Method::Apb) / s(Method::FlashAttn),
             s(Method::Apb) / s(Method::RingAttn),
             s(Method::Apb) / s(Method::StarAttn));
    println!("(paper: 10.3x / 2.2x / 1.39x)");
}
