//! Table 4 — E.MC accuracy across distributed settings H ∈ {2,4,6,8} at
//! 32K and 128K: StarAttn degrades as hosts increase on short inputs; APB
//! stays stable thanks to passing blocks.

use apb::attnsim::Hyper;
use apb::bench_harness::Table;
use apb::oracle::{expected_score, AccMethod, ApbQuality, EvalCtx};
use apb::report;
use apb::ruler::tasks::{infbench_tasks, ModelCol};
use apb::util::json::{self, Json};

fn main() {
    let t = infbench_tasks().into_iter().find(|t| t.id == "E.MC").unwrap();
    let hosts = [2.0, 4.0, 6.0, 8.0];
    let mut rows = Vec::new();
    let mut table = Table::new(
        "Table 4: E.MC vs sequence-parallel size",
        &["Length", "Method", "H=2", "H=4", "H=6", "H=8"],
    );
    for n in [131072.0, 32768.0] {
        let label = if n > 100_000.0 { "128K" } else { "32K" };
        for (name, is_apb) in [("APB", true), ("StarAttn", false)] {
            let mut cells = vec![label.to_string(), name.to_string()];
            for &h in &hosts {
                let ctx = EvalCtx { n, hosts: h, model: ModelCol::Llama,
                                    samples: 50, seed: 4 };
                let m = if is_apb {
                    let hy = Hyper::paper_schedule(n, h);
                    AccMethod::Apb(ApbQuality::paper_default(hy.l_a, hy.l_p, n / h))
                } else {
                    AccMethod::StarAttn
                };
                let s = expected_score(&t, m, &ctx);
                cells.push(format!("{s:.2}"));
                rows.push(report::row(vec![
                    ("n", json::s(label)),
                    ("method", json::s(name)),
                    ("hosts", json::num(h)),
                    ("score", json::num(s)),
                ]));
            }
            table.row(cells);
        }
    }
    table.print();

    // Paper shape: at 32K StarAttn H=8 < H=2 by a clear margin; APB H=8
    // within a small band of H=2 and above StarAttn.
    let score = |is_apb: bool, n: f64, h: f64| {
        let ctx = EvalCtx { n, hosts: h, model: ModelCol::Llama, samples: 0, seed: 0 };
        let m = if is_apb {
            let hy = Hyper::paper_schedule(n, h);
            AccMethod::Apb(ApbQuality::paper_default(hy.l_a, hy.l_p, n / h))
        } else {
            AccMethod::StarAttn
        };
        expected_score(&t, m, &ctx)
    };
    let star_drop = score(false, 32768.0, 2.0) - score(false, 32768.0, 8.0);
    let apb_drop = score(true, 32768.0, 2.0) - score(true, 32768.0, 8.0);
    println!("\n32K degradation H=2→8: StarAttn {star_drop:.2}, APB {apb_drop:.2} \
              (paper: 10.0 vs ≤0 — APB even gains)");
    assert!(apb_drop < 0.75 * star_drop);
    assert!(score(true, 32768.0, 8.0) > score(false, 32768.0, 8.0));

    let path = report::write_report("tab4_hosts", vec![], Json::Arr(rows))
        .expect("report");
    println!("[report] {}", path.display());
}
