//! Figure 5 / Table 13 — prefill wall-time breakdown into the paper's 7
//! components (QKV, retaining head, communication, attention, O-proj, FFN,
//! others), per Transformer block at 128K.
//!
//! Two tables: (1) the analytical model on the paper's A800/Llama profile
//! (Table 13's twin), and (2) a REAL measured breakdown from the tiny PJRT
//! cluster (artifact granularity maps per coordinator::timing docs).

use apb::attnsim::{estimate, Hyper, Method, A800, LLAMA31_8B};
use apb::bench_harness::Table;
use apb::config::ApbOptions;
use apb::coordinator::{Cluster, PrefillTiming};
use apb::report;
use apb::util::json::{self, Json};

fn analytical() -> Vec<Json> {
    let n = 131072.0;
    let mut table = Table::new(
        "Figure 5 / Table 13: per-block prefill breakdown (ms), 128K, analytical",
        &["Method", "QKV", "RetainHead", "Comm", "Attention", "O Proj", "FFN",
          "Others", "Block total"],
    );
    let mut rows = Vec::new();
    for method in Method::ALL {
        let h = if method.uses_sequence_parallelism() { 8.0 } else { 1.0 };
        let est = estimate(method, &LLAMA31_8B, n, h, &Hyper::e2e_128k(), &A800, 64.0);
        let b = est.prefill;
        let l = LLAMA31_8B.layers;
        let ms = |x: f64| x / l * 1e3;
        table.row(vec![
            method.name().into(),
            format!("{:.2}", ms(b.qkv)),
            if b.retaining > 0.0 { format!("{:.2}", ms(b.retaining)) } else { "-".into() },
            if b.comm > 0.0 { format!("{:.2}", ms(b.comm)) } else { "-".into() },
            format!("{:.2}", ms(b.attention)),
            format!("{:.2}", ms(b.o_proj)),
            format!("{:.2}", ms(b.ffn)),
            format!("{:.2}", ms(b.others)),
            format!("{:.2}", ms(b.total())),
        ]);
        rows.push(report::row(vec![
            ("method", json::s(method.name())),
            ("qkv_ms", json::num(ms(b.qkv))),
            ("retaining_ms", json::num(ms(b.retaining))),
            ("comm_ms", json::num(ms(b.comm))),
            ("attention_ms", json::num(ms(b.attention))),
            ("o_proj_ms", json::num(ms(b.o_proj))),
            ("ffn_ms", json::num(ms(b.ffn))),
            ("others_ms", json::num(ms(b.others))),
        ]));
    }
    table.print();

    // Table 13 shape: APB block total < StarAttn < Ulysses < Ring << Flash.
    let total = |m| {
        let h = if m == Method::FlashAttn || m == Method::MInference { 1.0 } else { 8.0 };
        estimate(m, &LLAMA31_8B, n, h, &Hyper::e2e_128k(), &A800, 64.0).prefill.total()
    };
    assert!(total(Method::Apb) < total(Method::StarAttn));
    assert!(total(Method::StarAttn) < total(Method::Ulysses));
    assert!(total(Method::Ulysses) < total(Method::RingAttn));
    assert!(total(Method::RingAttn) < total(Method::FlashAttn));
    rows
}

fn measured() -> Vec<Json> {
    let cfg = apb::load_config_or_sim("tiny").expect("config");
    let cluster = Cluster::start(&cfg).expect("cluster");
    let mut rng = apb::util::rng::Rng::new(5);
    let doc: Vec<i32> = (0..cfg.apb.doc_len())
        .map(|_| rng.range(1, cfg.model.vocab_size as i64) as i32)
        .collect();
    let query: Vec<i32> = (0..cfg.apb.query_len)
        .map(|_| rng.range(1, cfg.model.vocab_size as i64) as i32)
        .collect();
    let opts = ApbOptions::default();
    // Warm up once (PJRT JIT caches; harmless on sim), then measure.
    cluster.prefill(&doc, &query, &opts).expect("warmup");
    cluster.clear().unwrap();
    let rep = cluster.prefill(&doc, &query, &opts).expect("prefill");

    let mut sum = PrefillTiming::default();
    for t in &rep.per_host {
        sum.add(t);
    }
    let nl = (cfg.model.n_layers * rep.per_host.len()) as f64;
    let ms = |x: f64| x / nl * 1e3;
    let title = format!(
        "Measured (tiny {} cluster): per-block per-host breakdown (ms)",
        cfg.backend.name()
    );
    let mut table = Table::new(
        &title,
        &["Component", "ms/block", "maps to (paper Fig.5)"],
    );
    table.row(vec!["layer_pre".into(), format!("{:.3}", ms(sum.layer_pre_s)),
                   "QKV proj + retaining head".into()]);
    table.row(vec!["topk".into(), format!("{:.3}", ms(sum.topk_s)),
                   "compressor select (others)".into()]);
    table.row(vec!["comm".into(), format!("{:.3}", ms(sum.comm_s)),
                   "communication".into()]);
    table.row(vec!["layer_post".into(), format!("{:.3}", ms(sum.layer_post_s)),
                   "attention + O proj + FFN".into()]);
    table.row(vec!["cache".into(), format!("{:.3}", ms(sum.cache_s)),
                   "others".into()]);
    table.print();
    println!("prefill wall: {:.1} ms, comm bytes: {}", rep.wall_seconds * 1e3,
             rep.comm_bytes);

    vec![report::row(vec![
        ("layer_pre_ms", json::num(ms(sum.layer_pre_s))),
        ("topk_ms", json::num(ms(sum.topk_s))),
        ("comm_ms", json::num(ms(sum.comm_s))),
        ("layer_post_ms", json::num(ms(sum.layer_post_s))),
        ("cache_ms", json::num(ms(sum.cache_s))),
        ("wall_ms", json::num(rep.wall_seconds * 1e3)),
        ("comm_bytes", json::num(rep.comm_bytes as f64)),
    ])]
}

fn main() {
    let mut rows = analytical();
    rows.extend(measured());
    let path = report::write_report("fig5_tab13_breakdown", vec![],
                                    Json::Arr(rows)).expect("report");
    println!("[report] {}", path.display());
}
