//! Figure 7 — hyperparameter stability: E.QA score over the l_a × l_p grid
//! {1K, 2K, 3K, 4K} at 128K. Both knobs saturate quickly — "it is not
//! necessary to tune l_a and l_p delicately".

use apb::bench_harness::Table;
use apb::oracle::{expected_score, AccMethod, ApbQuality, EvalCtx};
use apb::report;
use apb::ruler::tasks::{infbench_tasks, ModelCol};
use apb::util::json::{self, Json};

fn main() {
    let t = infbench_tasks().into_iter().find(|t| t.id == "E.QA").unwrap();
    let ctx = EvalCtx { n: 131072.0, hosts: 8.0, model: ModelCol::Llama,
                        samples: 50, seed: 6 };
    let grid = [1024.0, 2048.0, 3072.0, 4096.0];
    let l_b = 131072.0 / 8.0;

    let mut table = Table::new(
        "Figure 7: E.QA vs anchor length l_a (rows) × passing length l_p (cols)",
        &["l_a \\ l_p", "1K", "2K", "3K", "4K"],
    );
    let mut rows = Vec::new();
    let mut all = Vec::new();
    for &l_a in &grid {
        let mut cells = vec![format!("{}K", l_a as usize / 1024)];
        for &l_p in &grid {
            let q = ApbQuality::paper_default(l_a, l_p, l_b);
            let s = expected_score(&t, AccMethod::Apb(q), &ctx);
            all.push(s);
            cells.push(format!("{s:.2}"));
            rows.push(report::row(vec![
                ("l_a", json::num(l_a)),
                ("l_p", json::num(l_p)),
                ("score", json::num(s)),
            ]));
        }
        table.row(cells);
    }
    table.print();

    let min = all.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = all.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    println!("\nscore range over the grid: [{min:.2}, {max:.2}] — spread {:.2}",
             max - min);
    // Paper: "both l_a and l_p are stable ... variation remains
    // insignificant". Bound the spread to a few points.
    assert!(max - min < 6.0, "hyperparameters must be stable, spread {}", max - min);
    // Mild monotone trend with l_a (paper: slight improvement).
    let s_small = all[0];
    let s_big = all[all.len() - 1];
    assert!(s_big >= s_small - 0.5);

    let path = report::write_report("fig7_hparam_stability", vec![],
                                    Json::Arr(rows)).expect("report");
    println!("[report] {}", path.display());
}
