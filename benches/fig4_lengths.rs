//! Figure 4 / Tables 14, 15 — accuracy (a), speed (b) and compute (c)
//! across input lengths 32K..512K, Llama profile, RULER tasks, with the
//! Table 5 hyperparameter schedule.

use apb::attnsim::{apb_flops, estimate, fullattn_flops, speed_tok_per_s, starattn_flops,
                   Hyper, Method, A800, LLAMA31_8B};
use apb::bench_harness::{AsciiPlot, Table};
use apb::oracle::{expected_score, AccMethod, ApbQuality, EvalCtx};
use apb::report;
use apb::ruler::tasks::{ruler_tasks, ModelCol, LENGTHS};
use apb::util::json::{self, Json};

const HOSTS: f64 = 8.0;
const LABELS: [&str; 5] = ["32K", "64K", "128K", "256K", "512K"];

fn acc_method(m: Method, n: f64) -> AccMethod {
    let hy = Hyper::paper_schedule(n, HOSTS);
    match m {
        Method::FlashAttn | Method::Ulysses | Method::RingAttn => AccMethod::Full,
        Method::MInference => AccMethod::MInference,
        Method::StarAttn => AccMethod::StarAttn,
        Method::Apb => {
            AccMethod::Apb(ApbQuality::paper_default(hy.l_a, hy.l_p, n / HOSTS))
        }
    }
}

fn main() {
    let tasks = ruler_tasks();
    let mut rows = Vec::new();

    // (a) accuracy vs length — Table 14.
    let mut t_acc = Table::new("Figure 4(a) / Table 14: RULER avg score vs length",
                               &["Method", "32K", "64K", "128K", "256K", "512K"]);
    let mut p_acc = AsciiPlot::new("Figure 4(a): log2(n) vs avg score");
    for method in [Method::FlashAttn, Method::MInference, Method::StarAttn, Method::Apb] {
        let mut cells = vec![method.name().to_string()];
        let mut pts = Vec::new();
        for (i, &n) in LENGTHS.iter().enumerate() {
            let ctx = EvalCtx { n, hosts: HOSTS, model: ModelCol::Llama,
                                samples: 0, seed: 0 };
            let am = acc_method(method, n);
            let avg = tasks.iter().map(|t| expected_score(t, am, &ctx)).sum::<f64>()
                / tasks.len() as f64;
            cells.push(format!("{avg:.2}"));
            pts.push((n.log2(), avg));
            rows.push(report::row(vec![
                ("panel", json::s("accuracy")),
                ("method", json::s(method.name())),
                ("n", json::s(LABELS[i])),
                ("value", json::num(avg)),
            ]));
        }
        t_acc.row(cells);
        p_acc.series(method.name(), pts);
    }
    t_acc.print();
    p_acc.print();

    // (b) speed vs length — Table 15.
    let mut t_sp = Table::new("Figure 4(b) / Table 15: speed (tok/s) vs length",
                              &["Method", "32K", "64K", "128K", "256K", "512K"]);
    for method in Method::ALL {
        let h = if method.uses_sequence_parallelism() { HOSTS } else { 1.0 };
        let mut cells = vec![method.name().to_string()];
        for (i, &n) in LENGTHS.iter().enumerate() {
            let hy = Hyper::paper_schedule(n, HOSTS);
            let est = estimate(method, &LLAMA31_8B, n, h, &hy, &A800, 64.0);
            match speed_tok_per_s(&est, n, 64.0) {
                Some(s) => {
                    cells.push(format!("{s:.0}"));
                    rows.push(report::row(vec![
                        ("panel", json::s("speed")),
                        ("method", json::s(method.name())),
                        ("n", json::s(LABELS[i])),
                        ("value", json::num(s)),
                    ]));
                }
                None => cells.push("OOM".into()),
            }
        }
        t_sp.row(cells);
    }
    t_sp.print();

    // (c) compute vs length — Table 6 visualization.
    let mut t_fl = Table::new("Figure 4(c) / Table 6: FLOPs per forward (PFLOPs)",
                              &["Method", "32K", "64K", "128K", "256K", "512K"]);
    for (name, f) in [
        ("FullAttn", Box::new(|n: f64| fullattn_flops(&LLAMA31_8B, n))
            as Box<dyn Fn(f64) -> f64>),
        ("StarAttn", Box::new(|n: f64| starattn_flops(&LLAMA31_8B, n, HOSTS))),
        ("APB", Box::new(|n: f64| {
            apb_flops(&LLAMA31_8B, n, &Hyper::paper_schedule(n, HOSTS))
        })),
    ] {
        let mut cells = vec![name.to_string()];
        for (i, &n) in LENGTHS.iter().enumerate() {
            let v = f(n) / 1e15;
            cells.push(format!("{v:.1}"));
            rows.push(report::row(vec![
                ("panel", json::s("flops")),
                ("method", json::s(name)),
                ("n", json::s(LABELS[i])),
                ("value", json::num(v)),
            ]));
        }
        t_fl.row(cells);
    }
    t_fl.print();

    // Shape assertions from §4.3: APB best accuracy AND best speed at 512K;
    // Star/APB speed *rises* from 32K to 128K while exact methods fall.
    let speed = |m: Method, n: f64| {
        let h = if m.uses_sequence_parallelism() { HOSTS } else { 1.0 };
        let est = estimate(m, &LLAMA31_8B, n, h, &Hyper::paper_schedule(n, HOSTS),
                           &A800, 64.0);
        speed_tok_per_s(&est, n, 64.0).unwrap_or(0.0)
    };
    assert!(speed(Method::Apb, 524288.0) > speed(Method::StarAttn, 524288.0));
    assert!(speed(Method::Apb, 131072.0) > speed(Method::Apb, 32768.0),
            "APB speed should grow 32K->128K (compute not yet the bottleneck)");

    let path = report::write_report("fig4_lengths", vec![("hosts", json::num(HOSTS))],
                                    Json::Arr(rows)).expect("report");
    println!("[report] {}", path.display());
}
