//! Figure 1 / Table 11 — prefill time vs input length (32K..1M) for every
//! method, with OOM verdicts, on the Llama-3.1-8B / 8×A800 profile.

use apb::attnsim::{estimate, Hyper, Method, A800, LLAMA31_8B};
use apb::bench_harness::{AsciiPlot, Table};
use apb::report;
use apb::util::json::{self, Json};

fn main() {
    // `--smoke` (CI): a reduced sweep that still exercises every method and
    // the paper-anchored asserts below, so the perf harness cannot rot
    // silently without burning CI minutes on the full grid.
    let smoke = std::env::args().any(|a| a == "--smoke" || a == "--quick");
    let all_lengths: [f64; 6] =
        [32768.0, 65536.0, 131072.0, 262144.0, 524288.0, 1048576.0];
    let all_labels = ["32K", "64K", "128K", "256K", "512K", "1024K"];
    let take = if smoke { 3 } else { all_lengths.len() };
    let lengths = &all_lengths[..take];
    let labels = &all_labels[..take];
    if smoke {
        println!("[fig1_prefill] smoke mode: {take} lengths");
    }
    let hosts = 8.0;

    let mut headers = vec!["Method"];
    headers.extend(labels);
    let mut table = Table::new("Figure 1 / Table 11: prefill time (s), Llama-3.1-8B, H=8",
                               &headers);
    let mut plot = AsciiPlot::new("Figure 1: log2(n) vs prefill seconds");
    let mut rows = Vec::new();

    for method in Method::ALL {
        // FlashAttn / MInference run on a single device (§B.3).
        let h = if method.uses_sequence_parallelism() { hosts } else { 1.0 };
        let mut cells = vec![method.name().to_string()];
        let mut pts = Vec::new();
        for (&n, &lab) in lengths.iter().zip(labels.iter()) {
            let hy = Hyper::paper_schedule(n, hosts);
            let est = estimate(method, &LLAMA31_8B, n, h, &hy, &A800, 64.0);
            if est.oom {
                cells.push("OOM".into());
            } else {
                cells.push(format!("{:.2}", est.prefill_s));
                pts.push((n.log2(), est.prefill_s));
            }
            rows.push(report::row(vec![
                ("method", json::s(method.name())),
                ("n", json::s(lab)),
                ("prefill_s", if est.oom { Json::Null } else { json::num(est.prefill_s) }),
                ("oom", Json::Bool(est.oom)),
                ("mem_gb", json::num(est.mem_bytes_peak / 1e9)),
            ]));
        }
        table.row(cells);
        plot.series(method.name(), pts);
    }
    table.print();
    plot.print();

    // Paper-anchored checks (Table 11 pattern).
    let est_at = |m, n: f64, h| {
        estimate(m, &LLAMA31_8B, n, h, &Hyper::paper_schedule(n, hosts), &A800, 64.0)
    };
    assert!(est_at(Method::FlashAttn, 262144.0, 1.0).oom, "FlashAttn OOM @256K");
    assert!(!est_at(Method::Apb, 1048576.0, 8.0).oom, "APB survives 1M");
    let apb = est_at(Method::Apb, 131072.0, 8.0).prefill_s;
    let star = est_at(Method::StarAttn, 131072.0, 8.0).prefill_s;
    println!("\nAPB vs StarAttn @128K: {:.2}x (paper: 3.50/0.94 = 3.7x)", star / apb);

    // Mark smoke runs in the report metadata so a truncated CI sweep can
    // never be mistaken for (or silently overwrite the meaning of) the
    // full 32K–1M grid.
    let path = report::write_report(
        "fig1_tab11_prefill",
        vec![("hosts", json::num(hosts)), ("smoke", Json::Bool(smoke))],
        Json::Arr(rows),
    )
    .expect("report");
    println!("[report] {}", path.display());
}
