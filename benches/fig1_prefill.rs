//! Figure 1 / Table 11 — prefill time vs input length (32K..1M) for every
//! method, with OOM verdicts, on the Llama-3.1-8B / 8×A800 profile —
//! followed by the *measured* communication of the four executable cluster
//! modes (`AttnMethod`) on the sim-tiny cluster, so the modeled numbers
//! are always printed next to a real run of the same methods.

use apb::attnsim::{estimate, Hyper, Method, A800, LLAMA31_8B};
use apb::bench_harness::{AsciiPlot, Table};
use apb::cluster::{Interconnect, WireModel};
use apb::config::{ApbOptions, AttnMethod, Config};
use apb::coordinator::{Cluster, Driver};
use apb::report;
use apb::util::json::{self, Json};

fn main() {
    // `--smoke` (CI): a reduced sweep that still exercises every method and
    // the paper-anchored asserts below, so the perf harness cannot rot
    // silently without burning CI minutes on the full grid.
    let smoke = std::env::args().any(|a| a == "--smoke" || a == "--quick");
    let all_lengths: [f64; 6] =
        [32768.0, 65536.0, 131072.0, 262144.0, 524288.0, 1048576.0];
    let all_labels = ["32K", "64K", "128K", "256K", "512K", "1024K"];
    let take = if smoke { 3 } else { all_lengths.len() };
    let lengths = &all_lengths[..take];
    let labels = &all_labels[..take];
    if smoke {
        println!("[fig1_prefill] smoke mode: {take} lengths");
    }
    let hosts = 8.0;

    let mut headers = vec!["Method"];
    headers.extend(labels);
    let mut table = Table::new("Figure 1 / Table 11: prefill time (s), Llama-3.1-8B, H=8",
                               &headers);
    let mut plot = AsciiPlot::new("Figure 1: log2(n) vs prefill seconds");
    let mut rows = Vec::new();

    for method in Method::ALL {
        // FlashAttn / MInference run on a single device (§B.3).
        let h = if method.uses_sequence_parallelism() { hosts } else { 1.0 };
        let mut cells = vec![method.name().to_string()];
        let mut pts = Vec::new();
        for (&n, &lab) in lengths.iter().zip(labels.iter()) {
            let hy = Hyper::paper_schedule(n, hosts);
            let est = estimate(method, &LLAMA31_8B, n, h, &hy, &A800, 64.0);
            if est.oom {
                cells.push("OOM".into());
            } else {
                cells.push(format!("{:.2}", est.prefill_s));
                pts.push((n.log2(), est.prefill_s));
            }
            rows.push(report::row(vec![
                ("method", json::s(method.name())),
                ("n", json::s(lab)),
                ("prefill_s", if est.oom { Json::Null } else { json::num(est.prefill_s) }),
                ("oom", Json::Bool(est.oom)),
                ("mem_gb", json::num(est.mem_bytes_peak / 1e9)),
            ]));
        }
        table.row(cells);
        plot.series(method.name(), pts);
    }
    table.print();
    plot.print();

    // Paper-anchored checks (Table 11 pattern).
    let est_at = |m, n: f64, h| {
        estimate(m, &LLAMA31_8B, n, h, &Hyper::paper_schedule(n, hosts), &A800, 64.0)
    };
    assert!(est_at(Method::FlashAttn, 262144.0, 1.0).oom, "FlashAttn OOM @256K");
    assert!(!est_at(Method::Apb, 1048576.0, 8.0).oom, "APB survives 1M");
    let apb = est_at(Method::Apb, 131072.0, 8.0).prefill_s;
    let star = est_at(Method::StarAttn, 131072.0, 8.0).prefill_s;
    println!("\nAPB vs StarAttn @128K: {:.2}x (paper: 3.50/0.94 = 3.7x)", star / apb);

    // --- Measured executable modes (sim-tiny cluster) ----------------------
    // One real (chunked, resumable) prefill + query-chunk decode per
    // AttnMethod: comm bytes and rounds per meter label, measured — the
    // executable twin of the modeled table above, now paired with the
    // modeled comm/compute overlap win at 128K. Runs in smoke mode too (it
    // is milliseconds of work).
    let mut measured = Table::new(
        "Measured cluster comm per method (sim-tiny, one prefill + query chunk)",
        &["Method", "exact", "kv B/rnd", "ring B/rnd", "att B/rnd", "total B",
          "ovl frac (model)", "ovl frac (meas)"],
    );
    let mut measured_rows = Vec::new();
    let mut bench_rows = Vec::new();
    let mut comm_of = std::collections::BTreeMap::new();
    for method in AttnMethod::ALL {
        let cfg = Config::sim_tiny().with_method(method);
        let cluster = Cluster::start(&cfg).expect("sim cluster");
        let mut rng = apb::util::rng::Rng::new(42);
        let doc: Vec<i32> = (0..cfg.apb.doc_len())
            .map(|_| rng.range(1, cfg.model.vocab_size as i64) as i32)
            .collect();
        let query: Vec<i32> = (0..cfg.apb.query_len)
            .map(|_| rng.range(1, cfg.model.vocab_size as i64) as i32)
            .collect();
        let opts = ApbOptions { method, ..Default::default() };
        let rep = cluster.prefill(&doc, &query, &opts).expect("prefill");
        cluster.generate(&query, 2).expect("decode");
        // Warm-vs-cold on a prefix-cache-enabled twin cluster: the same
        // request prefilled twice — the first run freezes the document KV,
        // the second attaches to it (zero comm, positive bytes saved).
        let warm_cluster =
            Cluster::start(&Config::sim_tiny().with_method(method).with_prefix_cache(true))
                .expect("warm cluster");
        let rep_cold = warm_cluster.prefill_session(1, &doc, &query, &opts)
            .expect("cold prefill");
        warm_cluster.clear_session(1).expect("clear cold session");
        let rep_warm = warm_cluster.prefill_session(2, &doc, &query, &opts)
            .expect("warm prefill");
        assert!(!rep_cold.prefix_hit && rep_warm.prefix_hit,
                "{}: second identical request must hit the prefix store",
                method.name());
        assert_eq!(rep_warm.comm_bytes, 0,
                   "{}: a prefix hit must not communicate", method.name());
        assert!(rep_warm.prefix_bytes_saved > 0,
                "{}: a prefix hit must save KV bytes", method.name());
        // MEASURED overlap: a dedicated threaded-driver cluster (per-host
        // OS threads, real wall clocks) with a modeled wire, so every
        // collective round has a genuine post→delivery window. Each host's
        // timing splits that window into the part its own compute covered
        // (`comm_hidden_s` — for APB, the cache appends scheduled inside
        // the gather window) and the part it actually blocked on; the
        // measured overlap fraction is hidden / window, summed over hosts.
        // This is the measured counterpart of Figure 1's overlap claim —
        // next to (never replacing) the analytic model below.
        let ovl_cluster =
            Cluster::start_with(&cfg, Driver::Threaded).expect("overlap cluster");
        ovl_cluster.fabric.set_wire(WireModel::Modeled { gbps: 1.0, latency_us: 200.0 });
        let ovl_rep = ovl_cluster.prefill(&doc, &query, &opts).expect("overlap prefill");
        let window_s: f64 = ovl_rep.per_host.iter().map(|t| t.comm_window_s).sum();
        let hidden_s: f64 = ovl_rep.per_host.iter().map(|t| t.comm_hidden_s).sum();
        let ovl_measured = if window_s > 0.0 { hidden_s / window_s } else { 0.0 };
        assert!((0.0..=1.0).contains(&ovl_measured),
                "{}: measured overlap fraction {ovl_measured} outside [0, 1]",
                method.name());
        // Modeled overlap win for this method's analytic twin @128K: per
        // layer step the collective hides under the attention compute
        // (max(comm, compute) instead of sum).
        let est128 = estimate(Method::from(method), &LLAMA31_8B, 131072.0, hosts,
                              &Hyper::paper_schedule(131072.0, hosts), &A800, 64.0);
        let ovl = est128.overlap_fraction();
        let m = &cluster.fabric.meter;
        let cell = |label: &str| format!("{}/{}", m.bytes_for(label), m.rounds_for(label));
        measured.row(vec![
            method.name().into(),
            method.exact_attention().to_string(),
            cell(Interconnect::KV_LABEL),
            cell(Interconnect::RING_LABEL),
            cell(Interconnect::ATT_LABEL),
            m.bytes_total().to_string(),
            format!("{ovl:.2}"),
            format!("{ovl_measured:.2}"),
        ]);
        comm_of.insert(method.name(), rep.comm_bytes);
        let row = report::row(vec![
            ("method", json::s(method.name())),
            ("exact", Json::Bool(method.exact_attention())),
            ("walltime_s", json::num(rep.wall_seconds)),
            ("prefill_comm_bytes", json::num(rep.comm_bytes as f64)),
            ("kv_bytes", json::num(m.bytes_for(Interconnect::KV_LABEL) as f64)),
            ("ring_bytes", json::num(m.bytes_for(Interconnect::RING_LABEL) as f64)),
            ("att_bytes", json::num(m.bytes_for(Interconnect::ATT_LABEL) as f64)),
            ("overlap_fraction_model", json::num(ovl)),
            // Measured on the threaded-driver + modeled-wire run above.
            ("overlap_fraction_measured", json::num(ovl_measured)),
            ("comm_window_s_measured", json::num(window_s)),
            ("comm_hidden_s_measured", json::num(hidden_s)),
            ("overlap_driver", json::s(ovl_cluster.driver().name())),
            ("prefill_s_model_128k", json::num(est128.prefill_s)),
            ("prefill_overlapped_s_model_128k", json::num(est128.prefill_overlapped_s)),
            // Warm-prefill record (prefix cache): measured cold/warm wall
            // seconds of the same request on this tiny cluster, the KV
            // bytes the hit skipped, and the analytic twin @128K.
            ("prefill_cold_s_measured", json::num(rep_cold.wall_seconds)),
            ("prefill_warm_s_measured", json::num(rep_warm.wall_seconds)),
            ("prefix_bytes_saved", json::num(rep_warm.prefix_bytes_saved as f64)),
            ("prefill_warm_s_model_128k", json::num(est128.prefill_warm_s)),
            ("warm_speedup_model_128k", json::num(est128.warm_speedup())),
        ]);
        measured_rows.push(row.clone());
        bench_rows.push(row);
        if method == AttnMethod::Apb {
            assert!(ovl > 0.0,
                    "APB must show a nonzero modeled overlap fraction, got {ovl}");
            // APB schedules its per-layer cache appends inside the gather
            // window, so with a real wire some of that window MUST be
            // measured as hidden.
            assert!(ovl_measured > 0.0,
                    "APB must measure a nonzero overlap fraction, got {ovl_measured}");
            assert!(window_s > 0.0, "APB's kv gather must open a comm window");
        }
        assert!(est128.prefill_warm_s > 0.0 && est128.prefill_warm_s < est128.prefill_s,
                "{}: modeled warm prefill must sit inside (0, cold)", method.name());
    }
    measured.print();

    // Machine-readable perf record for CI (checked for well-formed JSON):
    // per-method measured walltime + comm bytes and the modeled overlap
    // fraction, written next to the bench invocation. `schema_version`
    // gates the CI field validator: bump it when fields change shape.
    let bench = json::obj(vec![
        ("bench", json::s("fig1_prefill")),
        ("schema_version", json::num(2.0)),
        ("config", json::s("sim-tiny")),
        ("smoke", Json::Bool(smoke)),
        ("driver", json::s(Driver::from_env().name())),
        ("rows", Json::Arr(bench_rows)),
    ]);
    std::fs::write("BENCH_prefill.json", bench.pretty()).expect("BENCH_prefill.json");
    println!("[bench json] BENCH_prefill.json");
    // The measured structure the paper's comparison rests on: APB passes a
    // compressed fraction of what Ring rotates; Star and Dense pass nothing.
    assert!(comm_of["RingAttn"] > comm_of["APB"],
            "ring must move more prefill bytes than APB's compressed blocks");
    assert!(comm_of["APB"] > 0, "APB prefill must communicate");
    assert_eq!(comm_of["StarAttn"], 0, "StarAttn prefill must not communicate");
    assert_eq!(comm_of["Dense"], 0, "Dense must not communicate");

    // Mark smoke runs in the report metadata so a truncated CI sweep can
    // never be mistaken for (or silently overwrite the meaning of) the
    // full 32K–1M grid.
    let path = report::write_report(
        "fig1_tab11_prefill",
        vec![("hosts", json::num(hosts)), ("smoke", Json::Bool(smoke))],
        Json::Arr(rows),
    )
    .expect("report");
    let path2 = report::write_report(
        "fig1_measured_cluster_comm",
        vec![("config", json::s("sim-tiny")), ("smoke", Json::Bool(smoke))],
        Json::Arr(measured_rows),
    )
    .expect("report");
    println!("[report] {}", path.display());
    println!("[report] {}", path2.display());
}
