//! Tables 1 & 2 — task accuracy of APB vs baselines on ∞Bench and RULER,
//! three model columns, n = 128K, H = 8 (paper §4.2 setting).
//!
//! FULLATTN cells are the paper's own measurements (calibration anchors);
//! MInference / StarAttn / APB cells are derived from the mechanism model
//! in `oracle` (see DESIGN.md §2). Claim: orderings + approximate deltas.

use apb::bench_harness::Table;
use apb::oracle::{expected_score, sampled_score, AccMethod, ApbQuality, EvalCtx};
use apb::report;
use apb::ruler::tasks::{infbench_tasks, ruler_tasks, ModelCol, TaskProfile};
use apb::util::json::{self, Json};

fn methods() -> Vec<(&'static str, AccMethod)> {
    // §B.2.1: l_a = 4K, l_p = 2K, H = 8 -> l_b = 16K.
    let q = ApbQuality::paper_default(4096.0, 2048.0, 16384.0);
    vec![
        ("FullAttn", AccMethod::Full),
        ("MInference", AccMethod::MInference),
        ("StarAttn", AccMethod::StarAttn),
        ("APB", AccMethod::Apb(q)),
    ]
}

fn run_suite(title: &str, experiment: &str, tasks: &[TaskProfile], samples: usize) {
    let mut report_rows = Vec::new();
    for model in ModelCol::ALL {
        let ctx = EvalCtx { n: 131072.0, hosts: 8.0, model, samples, seed: 20250710 };
        let mut headers: Vec<&str> = vec!["Method"];
        headers.extend(tasks.iter().map(|t| t.id));
        headers.push("Avg.");
        let mut table = Table::new(&format!("{title} — {}", model.name()), &headers);
        for (name, m) in methods() {
            let mut cells = vec![name.to_string()];
            let mut sum = 0.0;
            for t in tasks {
                let s = sampled_score(t, m, &ctx);
                sum += s;
                cells.push(format!("{s:.2}"));
                report_rows.push(report::row(vec![
                    ("model", json::s(model.name())),
                    ("method", json::s(name)),
                    ("task", json::s(t.id)),
                    ("score", json::num(s)),
                    ("expected", json::num(expected_score(t, m, &ctx))),
                ]));
            }
            cells.push(format!("{:.2}", sum / tasks.len() as f64));
            table.row(cells);
        }
        table.print();
    }
    let path = report::write_report(experiment, vec![("n", json::num(131072.0))],
                                    Json::Arr(report_rows))
        .expect("report");
    println!("[report] {}", path.display());
}

fn main() {
    // ∞Bench: the paper runs all data; we sample 200/task.
    run_suite("Table 1: ∞Bench accuracy (128K)", "tab1_infbench",
              &infbench_tasks(), 200);
    // RULER: 500 samples per task (§B.2.1).
    run_suite("Table 2: RULER accuracy (128K)", "tab2_ruler",
              &ruler_tasks(), 500);

    // Paper-shape sanity summary.
    let ctx = EvalCtx { n: 131072.0, hosts: 8.0, model: ModelCol::Llama,
                        samples: 100_000, seed: 1 };
    let q = ApbQuality::paper_default(4096.0, 2048.0, 16384.0);
    let tasks = ruler_tasks();
    let avg = |m: AccMethod| {
        tasks.iter().map(|t| expected_score(t, m, &ctx)).sum::<f64>() / tasks.len() as f64
    };
    println!("\nRULER Llama averages — Full {:.2}  MInf {:.2}  Star {:.2}  APB {:.2}",
             avg(AccMethod::Full), avg(AccMethod::MInference),
             avg(AccMethod::StarAttn), avg(AccMethod::Apb(q)));
    println!("(paper: 82.20 / 72.97 / 76.84 / 81.63)");
}
