//! Decode-scaling bench (`docs/ADR-007-adaptive-decode.md`) — the
//! executable + modeled record behind `BENCH_decode.json`.
//!
//! Measured half (sim-tiny cluster, real collectives): the same session
//! decoded under both fixed pass strategies while its resident context
//! grows turn by turn. The claim under test: the pass-Q `qring` bytes
//! per decode step are CONSTANT in context length, and the two
//! strategies' logits are bit-identical at every point.
//!
//! Modeled half (Llama-3.1-8B / 8×A800 analytic twin): the pass-KV cost
//! of re-gathering context KV grows linearly in `n_ctx` while the pass-Q
//! rotation stays flat, crossing over well below paper scale — swept to
//! beyond a million tokens, with the `Auto` chooser pinned to the
//! per-point winner.

use apb::attnsim::{decode_scaling_sweep, A800, DECODE_SWEEP_LENGTHS, LLAMA31_8B};
use apb::bench_harness::Table;
use apb::config::{ApbOptions, Config, PassStrategy};
use apb::coordinator::Cluster;
use apb::report;
use apb::util::json::{self, Json};
use apb::util::rng::Rng;
use apb::util::tensor::Tensor;

/// One measured context point: the per-label comm of a single-token
/// decode step, plus the pool occupancy it attended.
struct Point {
    pool_bytes: u64,
    att_bytes: u64,
    qring_bytes: u64,
    comm_bytes: u64,
    logits: Vec<f32>,
}

/// Prefill one session under a fixed strategy, then alternate
/// single-token decode steps (measured) with multi-token `append_turn`s
/// (context growth) so successive points attend strictly longer caches.
fn measure(strategy: PassStrategy, doc: &[i32], query: &[i32], turns: &[Vec<i32>]) -> Vec<Point> {
    let cfg = Config::sim_tiny().with_pass_strategy(strategy);
    let cluster = Cluster::start(&cfg).expect("sim cluster");
    cluster
        .prefill_session(1, doc, query, &ApbOptions::default())
        .expect("prefill");
    let chunk = cluster.decode_query_chunk(1, query).expect("query chunk");
    let vocab = cfg.model.vocab_size;
    let mut token = Tensor::argmax_row(&chunk.logits[chunk.logits.len() - vocab..]) as i32;
    let mut points = Vec::new();
    for (i, turn) in turns.iter().enumerate() {
        let rep = cluster.decode_step_batch(&[(1, token)]).expect("decode step");
        assert_eq!(rep.strategy, strategy, "fixed strategy must pass through");
        token = Tensor::argmax_row(&rep.logits[0].1) as i32;
        let pool_bytes = cluster
            .pool_stats()
            .expect("pool stats")
            .iter()
            .map(|s| s.bytes_used as u64)
            .sum();
        points.push(Point {
            pool_bytes,
            att_bytes: rep.att_bytes,
            qring_bytes: rep.qring_bytes,
            comm_bytes: rep.comm_bytes,
            logits: rep.logits[0].1.clone(),
        });
        // Grow the resident context before the next measured step. The
        // last turn is not consumed: points.len() == turns.len().
        if i + 1 < turns.len() {
            cluster.append_turn(1, turn).expect("append turn");
        }
    }
    points
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke" || a == "--quick");
    if smoke {
        println!("[fig_decode_scaling] smoke mode (sweep is already milliseconds)");
    }

    // --- Measured: per-step comm vs growing resident context -------------
    let cfg = Config::sim_tiny();
    let mut rng = Rng::new(0xDEC0);
    let doc: Vec<i32> = (0..cfg.apb.doc_len())
        .map(|_| rng.range(1, cfg.model.vocab_size as i64) as i32)
        .collect();
    let query: Vec<i32> = (0..cfg.apb.query_len)
        .map(|_| rng.range(1, cfg.model.vocab_size as i64) as i32)
        .collect();
    // Three measured points, two 2-token turns between them: within the
    // sim-tiny last-host KV budget (query_len + max_new rows).
    let turns: Vec<Vec<i32>> = (0..3)
        .map(|_| {
            (0..2)
                .map(|_| rng.range(1, cfg.model.vocab_size as i64) as i32)
                .collect()
        })
        .collect();
    let kv = measure(PassStrategy::PassKv, &doc, &query, &turns);
    let q = measure(PassStrategy::PassQ, &doc, &query, &turns);

    let mut measured =
        Table::new("Measured per-step decode comm vs resident context (sim-tiny)",
                   &["point", "pool B", "kv att B", "kv qring B", "q att B", "q qring B"]);
    let mut measured_rows = Vec::new();
    for (i, (k, p)) in kv.iter().zip(q.iter()).enumerate() {
        // The invariant the whole PR rests on: identical logits, and each
        // strategy charges exactly one merge label.
        assert_eq!(k.logits, p.logits, "point {i}: strategies must be bit-identical");
        assert_eq!(k.pool_bytes, p.pool_bytes, "point {i}: pool bytes");
        assert_eq!(k.qring_bytes, 0, "gather path must not touch qring");
        assert_eq!(p.att_bytes, 0, "rotation must not touch att");
        assert_eq!(k.att_bytes, k.comm_bytes, "point {i}: kv label split");
        assert_eq!(p.qring_bytes, p.comm_bytes, "point {i}: q label split");
        measured.row(vec![
            i.to_string(),
            k.pool_bytes.to_string(),
            k.att_bytes.to_string(),
            k.qring_bytes.to_string(),
            p.att_bytes.to_string(),
            p.qring_bytes.to_string(),
        ]);
        measured_rows.push(report::row(vec![
            ("point", json::num(i as f64)),
            ("pool_bytes", json::num(k.pool_bytes as f64)),
            ("pass_kv_att_bytes", json::num(k.att_bytes as f64)),
            ("pass_kv_qring_bytes", json::num(k.qring_bytes as f64)),
            ("pass_q_att_bytes", json::num(p.att_bytes as f64)),
            ("pass_q_qring_bytes", json::num(p.qring_bytes as f64)),
            ("logits_bit_identical", Json::Bool(true)),
        ]));
    }
    measured.print();
    // Context really grew between points, and the rotation didn't care.
    assert!(kv.windows(2).all(|w| w[1].pool_bytes > w[0].pool_bytes),
            "append_turn must grow the resident pool between points");
    assert!(q.iter().all(|p| p.qring_bytes == q[0].qring_bytes && p.qring_bytes > 0),
            "pass-Q qring bytes per step must be flat in context length");

    // --- Modeled: million-token crossover (Llama-3.1-8B, 8×A800) ---------
    let hosts = 8.0;
    let t_new = 1.0;
    let sweep = decode_scaling_sweep(&LLAMA31_8B, t_new, hosts, &A800, &DECODE_SWEEP_LENGTHS);
    let mut modeled = Table::new(
        "Modeled per-step decode comm, Llama-3.1-8B H=8 (bytes, seconds)",
        &["n_ctx", "pass-kv B", "pass-q B", "pass-kv s", "pass-q s", "auto"],
    );
    let mut modeled_rows = Vec::new();
    let mut crossover = Json::Null;
    for p in &sweep {
        if p.auto == PassStrategy::PassQ && matches!(crossover, Json::Null) {
            crossover = json::num(p.n_ctx);
        }
        modeled.row(vec![
            format!("{:.0}", p.n_ctx),
            format!("{:.3e}", p.pass_kv_bytes),
            format!("{:.3e}", p.pass_q_bytes),
            format!("{:.4}", p.pass_kv_s),
            format!("{:.4}", p.pass_q_s),
            p.auto.name().to_string(),
        ]);
        modeled_rows.push(report::row(vec![
            ("n_ctx", json::num(p.n_ctx)),
            ("pass_kv_bytes", json::num(p.pass_kv_bytes)),
            ("pass_q_bytes", json::num(p.pass_q_bytes)),
            ("pass_kv_s", json::num(p.pass_kv_s)),
            ("pass_q_s", json::num(p.pass_q_s)),
            ("auto", json::s(p.auto.name())),
            ("auto_s", json::num(p.auto_s)),
        ]));
    }
    modeled.print();
    // The modeled scaling claims CI field-validates from the JSON.
    assert!(sweep.last().unwrap().n_ctx >= 1_048_576.0, "sweep must reach 1M tokens");
    assert!(sweep.windows(2).all(|w| w[1].pass_kv_bytes > w[0].pass_kv_bytes),
            "modeled pass-KV re-gather must grow with context");
    assert!(sweep.iter().all(|p| (p.pass_q_bytes - sweep[0].pass_q_bytes).abs() < 1e-6),
            "modeled pass-Q rotation must be flat in context");
    // Auto is never slower than either fixed strategy at any point.
    assert!(sweep.iter().all(|p| p.auto_s == p.pass_kv_s.min(p.pass_q_s)),
            "Auto must match the per-point winner");

    let bench = json::obj(vec![
        ("bench", json::s("fig_decode_scaling")),
        ("schema_version", json::num(1.0)),
        ("config", json::s("sim-tiny")),
        ("smoke", Json::Bool(smoke)),
        ("driver", json::s(apb::coordinator::Driver::from_env().name())),
        ("measured_hosts", json::num(cfg.apb.n_hosts as f64)),
        ("measured", Json::Arr(measured_rows.clone())),
        ("measured_qring_flat", Json::Bool(true)),
        ("modeled_model", json::s("llama31-8b")),
        ("modeled_hosts", json::num(hosts)),
        ("modeled_t_new", json::num(t_new)),
        ("modeled", Json::Arr(modeled_rows.clone())),
        ("modeled_crossover_n_ctx", crossover),
    ]);
    std::fs::write("BENCH_decode.json", bench.pretty()).expect("BENCH_decode.json");
    println!("[bench json] BENCH_decode.json");

    let path = report::write_report(
        "fig_decode_scaling_measured",
        vec![("config", json::s("sim-tiny")), ("smoke", Json::Bool(smoke))],
        Json::Arr(measured_rows),
    )
    .expect("report");
    let path2 = report::write_report(
        "fig_decode_scaling_modeled",
        vec![("hosts", json::num(hosts)), ("smoke", Json::Bool(smoke))],
        Json::Arr(modeled_rows),
    )
    .expect("report");
    println!("[report] {}", path.display());
    println!("[report] {}", path2.display());
}
