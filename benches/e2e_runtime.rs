//! Real end-to-end runtime bench on the cluster (SimEngine by default,
//! PJRT tiny artifacts when built with `--features pjrt` + `make artifacts`):
//! prefill wall-time, decode per-token latency, the paper's tok/s speed
//! metric, and the coordinator-overhead share — the numbers the §Perf
//! iteration log in EXPERIMENTS.md tracks.

use apb::bench_harness::{default_bencher, Table};
use apb::config::{ApbOptions, AttnMethod};
use apb::coordinator::Cluster;
use apb::report;
use apb::util::json::{self, Json};
use apb::util::rng::Rng;
use apb::util::stats::fmt_duration;

fn main() {
    let cfg = apb::load_config_or_sim("tiny").expect("config");
    let cluster = Cluster::start(&cfg).expect("cluster");
    let mut rng = Rng::new(123);
    let doc: Vec<i32> = (0..cfg.apb.doc_len())
        .map(|_| rng.range(1, cfg.model.vocab_size as i64) as i32)
        .collect();
    let query: Vec<i32> = (0..cfg.apb.query_len)
        .map(|_| rng.range(1, cfg.model.vocab_size as i64) as i32)
        .collect();
    let opts = ApbOptions::default();

    let b = default_bencher();
    println!("== e2e_runtime ({} backend: {} hosts, doc {} tokens) ==",
             cfg.backend.name(), cfg.apb.n_hosts, cfg.apb.doc_len());

    // Prefill (includes cache clear so each iteration is a fresh request).
    let s_prefill = b.report("prefill (full APB, per request)", || {
        cluster.clear().unwrap();
        cluster.prefill(&doc, &query, &opts).unwrap();
    });

    // StarAttn prefill (no communication) for the comm-cost delta.
    let star_opts = ApbOptions { method: AttnMethod::StarAttn, ..opts };
    let s_star = b.report("prefill (no passing = Star-mode)", || {
        cluster.clear().unwrap();
        cluster.prefill(&doc, &query, &star_opts).unwrap();
    });

    // Decode.
    cluster.clear().unwrap();
    cluster.prefill(&doc, &query, &opts).unwrap();
    let n_new = 8;
    let s_gen = b.run(|| {
        // Query chunk + n_new greedy steps; cache resets via clear+prefill
        // are excluded by re-prefilling outside the timer? Prefill state
        // persists; generate() appends to host H's cache each run, so
        // clear+prefill inside keeps it bounded.
        cluster.clear().unwrap();
        cluster.prefill(&doc, &query, &opts).unwrap();
        cluster.generate(&query, n_new).unwrap();
    });
    let gen_only = (s_gen.mean - s_prefill.mean).max(0.0);
    let per_tok = gen_only / n_new as f64;
    println!("decode+query-chunk: {} total, ~{} per generated token",
             fmt_duration(gen_only), fmt_duration(per_tok));

    // Component shares from the host timers.
    cluster.clear().unwrap();
    let rep = cluster.prefill(&doc, &query, &opts).unwrap();
    let mut sum = apb::coordinator::PrefillTiming::default();
    for t in &rep.per_host {
        sum.add(t);
    }
    let coord = sum.topk_s + sum.comm_s + sum.cache_s;
    let share = coord / sum.total_s.max(1e-12);
    let mut table = Table::new("coordinator overhead (sum over hosts)",
                               &["component", "seconds", "share"]);
    for (name, v) in [("embed", sum.embed_s), ("layer_pre", sum.layer_pre_s),
                      ("topk", sum.topk_s), ("comm wait", sum.comm_s),
                      ("layer_post", sum.layer_post_s), ("cache", sum.cache_s)] {
        table.row(vec![name.into(), format!("{v:.4}"),
                       format!("{:.1}%", 100.0 * v / sum.total_s)]);
    }
    table.print();
    println!("coordinator (non-PJRT) share: {:.1}%", share * 100.0);

    let speed = (doc.len() + query.len() + n_new) as f64 / s_gen.mean;
    println!("paper speed metric: {:.0} tok/s (tiny model, CPU interpret)", speed);

    let path = report::write_report(
        "e2e_runtime",
        vec![("config", json::s(&cfg.name))],
        Json::Arr(vec![report::row(vec![
            ("prefill_mean_s", json::num(s_prefill.mean)),
            ("prefill_p50_s", json::num(s_prefill.p50)),
            ("star_prefill_s", json::num(s_star.mean)),
            ("decode_per_token_s", json::num(per_tok)),
            ("speed_tok_per_s", json::num(speed)),
            ("coordinator_share", json::num(share)),
        ])]),
    )
    .expect("report");
    println!("[report] {}", path.display());
}
