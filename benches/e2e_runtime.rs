//! Real end-to-end runtime bench on the cluster (SimEngine by default,
//! PJRT tiny artifacts when built with `--features pjrt` + `make artifacts`):
//! the scalar-vs-tiled kernel microbench, prefill wall-time, decode
//! per-token latency, the paper's tok/s speed metric, the
//! coordinator-overhead share, and the KV slab-arena counters — the numbers
//! committed to `BENCH_runtime.json` (regenerated and field-validated by
//! CI's threaded leg) and explained in `docs/serving-guide.md`.
//!
//! Timing exclusion rule: every timed section measures ONLY the operation
//! it names. State preparation (cache clears, the prefill that decode
//! steps extend) runs in `Bencher::run_with_setup`'s untimed setup phase
//! before each iteration, so the decode rows are decode steps only — never
//! a hidden re-prefill.

use apb::bench_harness::{default_bencher, Table};
use apb::config::{ApbOptions, AttnMethod, Config};
use apb::coordinator::Cluster;
use apb::report;
use apb::runtime::sim::{masked_attention_seg, masked_attention_seg_ref, resolve_sim_threads};
use apb::runtime::KvSeg;
use apb::util::json::{self, Json};
use apb::util::rng::Rng;
use apb::util::stats::fmt_duration;
use apb::util::tensor::Tensor;

fn rand_tensor(rng: &mut Rng, shape: Vec<usize>) -> Tensor {
    let n: usize = shape.iter().product();
    let data: Vec<f32> = (0..n).map(|_| (rng.f32() - 0.5) * 2.0).collect();
    Tensor::new(shape, data).expect("rand tensor")
}

fn tokens(rng: &mut Rng, n: usize, vocab: usize) -> Vec<i32> {
    (0..n).map(|_| rng.range(1, vocab as i64) as i32).collect()
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke" || a == "--quick");
    let b = default_bencher();
    if smoke {
        println!("[e2e_runtime] smoke mode");
    }

    // --- Kernel microbench: scalar reference vs tiled dispatch -------------
    // Segmented shapes chosen to look like the hot call sites (a prefill
    // chunk attending [anchor | passing | local], and a long decode tail).
    // Each shape first asserts bit-identity, then times both kernels; the
    // committed JSON records min-of-iters so CI can require the tiled
    // kernel to win on at least one shape without flaking on noise.
    struct Shape {
        name: &'static str,
        nq: usize,
        seg_rows: [usize; 2],
        h: usize,
        kh: usize,
        hd: usize,
    }
    let shapes = [
        Shape { name: "prefill-chunk", nq: 16, seg_rows: [96, 32], h: 8, kh: 4, hd: 32 },
        Shape { name: "long-tail", nq: 8, seg_rows: [384, 128], h: 8, kh: 2, hd: 64 },
    ];
    let mut kernel_rows = Vec::new();
    let mut kernel_table =
        Table::new("kernel: masked_attention_seg scalar vs tiled (min over iters)",
                   &["shape", "scalar", "tiled", "speedup"]);
    let mut any_tiled_win = false;
    for sp in &shapes {
        let mut rng = Rng::new(7);
        let nq = if smoke { sp.nq.min(8) } else { sp.nq };
        let q = rand_tensor(&mut rng, vec![nq, sp.h, sp.hd]);
        let kv: Vec<(Tensor, Tensor, usize)> = sp
            .seg_rows
            .iter()
            .map(|&r| {
                let rows = if smoke { r / 2 } else { r };
                (rand_tensor(&mut rng, vec![rows, sp.kh, sp.hd]),
                 rand_tensor(&mut rng, vec![rows, sp.kh, sp.hd]),
                 rows)
            })
            .collect();
        let segs: Vec<KvSeg<'_>> =
            kv.iter().map(|(k, v, len)| KvSeg { k, v, len: *len }).collect();
        let nk: usize = kv.iter().map(|s| s.2).sum();
        // Causal-style stair mask so tiles see partial visibility too.
        let visible = move |qi: usize, kj: usize| kj < nk - (nq - 1 - qi);
        let (o_ref, l_ref) = masked_attention_seg_ref(&q, &segs, visible);
        let (o_til, l_til) = masked_attention_seg(&q, &segs, visible);
        assert_eq!(o_ref.data, o_til.data, "{}: tiled out != scalar out", sp.name);
        assert_eq!(l_ref.data, l_til.data, "{}: tiled lse != scalar lse", sp.name);
        let s_ref = b.run(|| {
            std::hint::black_box(masked_attention_seg_ref(&q, &segs, visible));
        });
        let s_til = b.run(|| {
            std::hint::black_box(masked_attention_seg(&q, &segs, visible));
        });
        any_tiled_win |= s_til.min <= s_ref.min;
        kernel_table.row(vec![
            sp.name.into(),
            fmt_duration(s_ref.min),
            fmt_duration(s_til.min),
            format!("{:.2}x", s_ref.min / s_til.min.max(1e-12)),
        ]);
        kernel_rows.push(report::row(vec![
            ("shape", json::s(sp.name)),
            ("nq", json::num(nq as f64)),
            ("nk", json::num(nk as f64)),
            ("scalar_min_s", json::num(s_ref.min)),
            ("tiled_min_s", json::num(s_til.min)),
        ]));
    }
    kernel_table.print();
    assert!(any_tiled_win, "tiled kernel slower than scalar on every shape");

    // --- End-to-end: scalar-pinned cluster vs default (tiled + pool) -------
    let cfg = apb::load_config_or_sim("tiny").expect("config");
    let cfg_scalar = cfg.clone().with_sim_scalar(true);
    let cluster = Cluster::start(&cfg).expect("cluster");
    let scalar_cluster = Cluster::start(&cfg_scalar).expect("scalar cluster");
    let mut rng = Rng::new(123);
    let doc = tokens(&mut rng, cfg.apb.doc_len(), cfg.model.vocab_size);
    let query = tokens(&mut rng, cfg.apb.query_len, cfg.model.vocab_size);
    let opts = ApbOptions::default();
    let sim_threads = resolve_sim_threads(cfg.sim_threads, cfg.apb.n_hosts);
    println!("== e2e_runtime ({} backend: {} hosts, doc {} tokens, {} sim threads) ==",
             cfg.backend.name(), cfg.apb.n_hosts, cfg.apb.doc_len(), sim_threads);

    // Prefill: clear in setup, time the prefill alone.
    let s_prefill_scalar = b.run_with_setup(
        || scalar_cluster.clear().unwrap(),
        || {
            scalar_cluster.prefill(&doc, &query, &opts).unwrap();
        },
    );
    let s_prefill = b.run_with_setup(
        || cluster.clear().unwrap(),
        || {
            cluster.prefill(&doc, &query, &opts).unwrap();
        },
    );
    println!("prefill  scalar {}  tiled {}  ({:.2}x, min)",
             fmt_duration(s_prefill_scalar.min), fmt_duration(s_prefill.min),
             s_prefill_scalar.min / s_prefill.min.max(1e-12));

    // StarAttn prefill (no communication) for the comm-cost delta.
    let star_opts = ApbOptions { method: AttnMethod::StarAttn, ..opts };
    let s_star = b.run_with_setup(
        || cluster.clear().unwrap(),
        || {
            cluster.prefill(&doc, &query, &star_opts).unwrap();
        },
    );

    // Decode: setup re-prefills (untimed), the timed body is the query
    // chunk + n_new greedy steps — nothing else.
    let n_new = if smoke { 4 } else { 8 };
    let mut gen_scalar = None;
    let s_gen_scalar = b.run_with_setup(
        || {
            scalar_cluster.clear().unwrap();
            scalar_cluster.prefill(&doc, &query, &opts).unwrap();
        },
        || gen_scalar = Some(scalar_cluster.generate(&query, n_new).unwrap()),
    );
    let mut gen_tiled = None;
    let s_gen = b.run_with_setup(
        || {
            cluster.clear().unwrap();
            cluster.prefill(&doc, &query, &opts).unwrap();
        },
        || gen_tiled = Some(cluster.generate(&query, n_new).unwrap()),
    );
    let (gen_scalar, gen_tiled) = (gen_scalar.unwrap(), gen_tiled.unwrap());
    // The perf pass must be invisible in the numerics: same greedy tokens,
    // bit-identical query logits, scalar vs tiled+pooled.
    assert_eq!(gen_scalar.tokens, gen_tiled.tokens, "scalar/tiled tokens diverge");
    assert_eq!(gen_scalar.query_logits, gen_tiled.query_logits,
               "scalar/tiled query logits diverge");
    let per_tok_scalar = s_gen_scalar.min / n_new as f64;
    let per_tok = s_gen.min / n_new as f64;
    println!("decode   scalar ~{}  tiled ~{} per generated token (min)",
             fmt_duration(per_tok_scalar), fmt_duration(per_tok));

    // --- Slab arena: freeze/evict churn + steady-state decode --------------
    // A prefix-cache cluster cycling MORE distinct documents than the store
    // caps (max_resident) forces freeze -> evict -> freeze churn; after the
    // arena warms up, every re-armed slot slab is recycled. Then a decode
    // window on the same cluster must allocate zero slabs.
    let warm = Cluster::start(&cfg.clone().with_prefix_cache(true)).expect("warm cluster");
    let churn_rounds = cfg.apb.max_resident.max(1) * 2 + 2;
    for round in 0..churn_rounds {
        let sid = (round + 1) as u64;
        let d = tokens(&mut rng, cfg.apb.doc_len(), cfg.model.vocab_size);
        warm.prefill_session(sid, &d, &query, &opts).expect("churn prefill");
        warm.clear_session(sid).expect("churn clear");
    }
    let churn_stats = warm.pool_stats().expect("pool stats");
    let slab_allocs: u64 = churn_stats.iter().map(|s| s.slab_allocs).sum();
    let slab_reuses: u64 = churn_stats.iter().map(|s| s.slab_reuses).sum();
    let slabs_free: u64 = churn_stats.iter().map(|s| s.slabs_free as u64).sum();
    assert!(slab_reuses > 0,
            "churning {churn_rounds} docs past the prefix cap must recycle slabs");
    // Steady-state decode: query chunk + batched steps on a live session.
    warm.prefill_session(999, &doc, &query, &opts).expect("steady prefill");
    let before: u64 = warm.pool_stats().expect("stats").iter().map(|s| s.slab_allocs).sum();
    warm.decode_query_chunk(999, &query).expect("steady query chunk");
    for t in 0..n_new {
        warm.decode_step_batch(&[(999, (t + 2) as i32)]).expect("steady step");
    }
    let after: u64 = warm.pool_stats().expect("stats").iter().map(|s| s.slab_allocs).sum();
    let decode_slab_allocs_delta = after - before;
    assert_eq!(decode_slab_allocs_delta, 0, "decode steps must not allocate slabs");
    println!("slabs    allocs {slab_allocs}  reuses {slab_reuses}  free {slabs_free}  \
              decode-window alloc delta {decode_slab_allocs_delta}");

    // --- Coordinator overhead from the host timers -------------------------
    cluster.clear().unwrap();
    let rep = cluster.prefill(&doc, &query, &opts).unwrap();
    let mut sum = apb::coordinator::PrefillTiming::default();
    for t in &rep.per_host {
        sum.add(t);
    }
    let coord = sum.topk_s + sum.comm_s + sum.cache_s;
    let share = coord / sum.total_s.max(1e-12);
    let mut table = Table::new("coordinator overhead (sum over hosts)",
                               &["component", "seconds", "share"]);
    for (name, v) in [("embed", sum.embed_s), ("layer_pre", sum.layer_pre_s),
                      ("topk", sum.topk_s), ("comm wait", sum.comm_s),
                      ("layer_post", sum.layer_post_s), ("cache", sum.cache_s)] {
        table.row(vec![name.into(), format!("{v:.4}"),
                       format!("{:.1}%", 100.0 * v / sum.total_s)]);
    }
    table.print();
    println!("coordinator (non-PJRT) share: {:.1}%", share * 100.0);

    let speed = (doc.len() + query.len() + n_new) as f64 / (s_prefill.min + s_gen.min);
    println!("paper speed metric: {:.0} tok/s (tiny model, CPU interpret)", speed);

    // --- Machine-readable record (committed as BENCH_runtime.json) ---------
    // `schema_version` gates the CI validator: bump it when fields change.
    let bench = json::obj(vec![
        ("bench", json::s("e2e_runtime")),
        ("schema_version", json::num(1.0)),
        ("config", json::s(&cfg.name)),
        ("smoke", Json::Bool(smoke)),
        ("driver", json::s(cluster.driver().name())),
        ("sim_threads", json::num(sim_threads as f64)),
        ("kernel_shapes", Json::Arr(kernel_rows)),
        ("prefill_scalar_min_s", json::num(s_prefill_scalar.min)),
        ("prefill_tiled_min_s", json::num(s_prefill.min)),
        ("star_prefill_min_s", json::num(s_star.min)),
        ("decode_per_token_scalar_s", json::num(per_tok_scalar)),
        ("decode_per_token_tiled_s", json::num(per_tok)),
        ("n_new", json::num(n_new as f64)),
        ("slab_allocs", json::num(slab_allocs as f64)),
        ("slab_reuses", json::num(slab_reuses as f64)),
        ("slabs_free", json::num(slabs_free as f64)),
        ("decode_slab_allocs_delta", json::num(decode_slab_allocs_delta as f64)),
        ("coordinator_share", json::num(share)),
        ("speed_tok_per_s", json::num(speed)),
    ]);
    std::fs::write("BENCH_runtime.json", bench.pretty()).expect("BENCH_runtime.json");
    println!("[bench json] BENCH_runtime.json");

    let path = report::write_report(
        "e2e_runtime",
        vec![("config", json::s(&cfg.name)), ("smoke", Json::Bool(smoke))],
        Json::Arr(vec![report::row(vec![
            ("prefill_scalar_min_s", json::num(s_prefill_scalar.min)),
            ("prefill_tiled_min_s", json::num(s_prefill.min)),
            ("star_prefill_min_s", json::num(s_star.min)),
            ("decode_per_token_tiled_s", json::num(per_tok)),
            ("speed_tok_per_s", json::num(speed)),
            ("coordinator_share", json::num(share)),
        ])]),
    )
    .expect("report");
    println!("[report] {}", path.display());
}
