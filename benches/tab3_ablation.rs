//! Table 3 — component ablation on E.MC (anchor A, passing P, compressor C
//! = retaining heads R vs random Rd., query embedding Q), n=128K, l_b=32K,
//! l_a=4K, l_p=2K (§B.2.3).
//!
//! Oracle-derived scores for all 9 paper rows, PLUS a real-cluster section
//! measuring how each ablation changes the actual computation (logit
//! distance to the full-APB baseline + compressor retention recall).

use apb::bench_harness::Table;
use apb::config::{ApbOptions, AttnMethod};
use apb::coordinator::Cluster;
use apb::oracle::{expected_score, AccMethod, ApbQuality, EvalCtx};
use apb::report;
use apb::ruler::tasks::{infbench_tasks, ModelCol};
use apb::ruler::{gen_instance, TaskKind};
use apb::util::json::{self, Json};
use apb::util::rng::Rng;

/// The 9 rows of Table 3: (no, A, P, retaining, Q).
const ROWS: [(usize, bool, bool, bool, bool); 9] = [
    (0, true, true, true, true),
    (1, true, true, true, false),
    (2, true, true, false, true),
    (3, true, true, false, false),
    (4, true, false, false, true),
    (5, true, false, false, false),
    (6, false, true, true, false),
    (7, false, true, false, false),
    (8, false, false, false, false),
];

fn opts_for(row: (usize, bool, bool, bool, bool)) -> ApbOptions {
    ApbOptions {
        use_anchor: row.1,
        // The "P" ablation bit is the Apb-vs-StarAttn method choice.
        method: if row.2 {
            AttnMethod::Apb
        } else {
            AttnMethod::StarAttn
        },
        retaining_compressor: row.3,
        embed_query: row.4,
        // The measured section reads retention_recall per row.
        record_retained: true,
        ..Default::default()
    }
}

fn main() {
    // --- Oracle section (paper numbers' twin) ---------------------------
    let t = infbench_tasks().into_iter().find(|t| t.id == "E.MC").unwrap();
    // n=128K split over 4 hosts -> l_b = 32K (§B.2.3).
    let ctx = EvalCtx { n: 131072.0, hosts: 4.0, model: ModelCol::Llama,
                        samples: 50, seed: 3 };
    let (l_a, l_p, l_b) = (4096.0, 2048.0, 32768.0);
    let mut table = Table::new(
        "Table 3: ablation on E.MC (oracle)",
        &["No.", "A", "P", "C", "Q", "E.MC"],
    );
    let mut rows = Vec::new();
    let mut scores = Vec::new();
    for row in ROWS {
        let o = opts_for(row);
        let q = ApbQuality::from_options(&o, l_a, l_p, l_b);
        let s = expected_score(&t, AccMethod::Apb(q), &ctx);
        scores.push(s);
        table.row(vec![
            row.0.to_string(),
            if row.1 { "Y" } else { "x" }.into(),
            if row.2 { "Y" } else { "x" }.into(),
            if row.3 { "R" } else { "Rd." }.into(),
            if row.4 { "Y" } else { "x" }.into(),
            format!("{s:.2}"),
        ]);
        rows.push(report::row(vec![
            ("no", json::num(row.0 as f64)),
            ("anchor", Json::Bool(row.1)),
            ("passing", Json::Bool(row.2)),
            ("retaining", Json::Bool(row.3)),
            ("query", Json::Bool(row.4)),
            ("score", json::num(s)),
        ]));
    }
    table.print();

    // Paper orderings: row0 best; anchor removal catastrophic.
    assert!(scores[0] >= scores[1] && scores[1] >= scores[2]);
    assert!(scores[0] > scores[4] && scores[4] >= scores[5]);
    assert!(scores[5] > scores[6] + 10.0, "anchor removal must collapse");
    assert!(scores[6] >= scores[8]);

    // --- Real-cluster section (sim backend by default) ------------------
    {
        let cfg = apb::load_config_or_sim("tiny").expect("config");
        let cluster = Cluster::start(&cfg).expect("cluster");
        let mut rng = Rng::new(77);
        let inst = gen_instance(&cfg, TaskKind::MultiKeyNiah { keys: 3 }, &mut rng);
        let baseline = {
            cluster.clear().unwrap();
            cluster.prefill(&inst.doc, &inst.query, &ApbOptions::default()).unwrap();
            cluster.generate(&inst.query, 2).unwrap().query_logits
        };
        let mut mtable = Table::new(
            "Table 3 (measured, tiny cluster): ablation effect on computation",
            &["No.", "retention recall", "logit Linf vs full APB", "comm bytes"],
        );
        for row in ROWS {
            let o = opts_for(row);
            cluster.clear().unwrap();
            let rep = cluster.prefill(&inst.doc, &inst.query, &o).unwrap();
            let gen = cluster.generate(&inst.query, 2).unwrap();
            let linf = gen
                .query_logits
                .iter()
                .zip(&baseline)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            let recall = rep.retention_recall(&cfg, &inst.needle_positions);
            mtable.row(vec![
                row.0.to_string(),
                format!("{recall:.3}"),
                format!("{linf:.4}"),
                rep.comm_bytes.to_string(),
            ]);
            rows.push(report::row(vec![
                ("no", json::num(row.0 as f64)),
                ("measured_recall", json::num(recall)),
                ("logit_linf", json::num(linf as f64)),
                ("comm_bytes", json::num(rep.comm_bytes as f64)),
            ]));
        }
        mtable.print();
    }

    let path = report::write_report("tab3_ablation", vec![], Json::Arr(rows))
        .expect("report");
    println!("[report] {}", path.display());
}
