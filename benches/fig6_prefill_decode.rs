//! Figure 6 / Table 10 — prefill vs decoding wall-time at 128K: prefill
//! dominates (the motivation for optimizing prefill). Analytical on the
//! paper profile + real measurement on the tiny cluster.

use apb::attnsim::{estimate, Hyper, Method, A800, LLAMA31_8B};
use apb::bench_harness::Table;
use apb::config::ApbOptions;
use apb::coordinator::Cluster;
use apb::report;
use apb::util::json::{self, Json};

fn main() {
    let n = 131072.0;
    let n_out = 64.0;
    let mut table = Table::new(
        "Figure 6 / Table 10: prefill vs decoding time (ms), 128K, analytical",
        &["Method", "Prefill", "Prefill (ovl)", "Comm hidden", "Decoding",
          "Decode share"],
    );
    let mut rows = Vec::new();
    for method in Method::ALL {
        let h = if method.uses_sequence_parallelism() { 8.0 } else { 1.0 };
        let est = estimate(method, &LLAMA31_8B, n, h, &Hyper::e2e_128k(), &A800, n_out);
        let d = est.decode_per_token_s * n_out;
        table.row(vec![
            method.name().into(),
            format!("{:.1}", est.prefill_s * 1e3),
            // The measured-overlap win: per layer step the collective runs
            // under the attention compute (max(comm, compute) model).
            format!("{:.1}", est.prefill_overlapped_s * 1e3),
            format!("{:.2}", est.comm_hidden_s * 1e3),
            format!("{:.1}", d * 1e3),
            format!("{:.1}%", 100.0 * d / (d + est.prefill_s)),
        ]);
        rows.push(report::row(vec![
            ("method", json::s(method.name())),
            ("prefill_ms", json::num(est.prefill_s * 1e3)),
            ("prefill_overlapped_ms", json::num(est.prefill_overlapped_s * 1e3)),
            ("comm_hidden_ms", json::num(est.comm_hidden_s * 1e3)),
            ("overlap_fraction", json::num(est.overlap_fraction())),
            ("decode_ms", json::num(d * 1e3)),
        ]));
        // Figure 6's claim: prefill is the bottleneck for every method —
        // with or without the overlap win.
        assert!(est.prefill_s > d, "{}: prefill must dominate", method.name());
        assert!(est.prefill_overlapped_s > d,
                "{}: overlap cannot flip the bottleneck", method.name());
    }
    table.print();

    // Real measurement on the tiny cluster (sim backend by default).
    {
        let cfg = apb::load_config_or_sim("tiny").expect("config");
        let cluster = Cluster::start(&cfg).expect("cluster");
        let mut rng = apb::util::rng::Rng::new(9);
        let doc: Vec<i32> = (0..cfg.apb.doc_len())
            .map(|_| rng.range(1, cfg.model.vocab_size as i64) as i32)
            .collect();
        let query: Vec<i32> = (0..cfg.apb.query_len)
            .map(|_| rng.range(1, cfg.model.vocab_size as i64) as i32)
            .collect();
        cluster.prefill(&doc, &query, &ApbOptions::default()).expect("warm");
        cluster.clear().unwrap();
        let pre = cluster.prefill(&doc, &query, &ApbOptions::default()).expect("prefill");
        let gen = cluster.generate(&query, 8).expect("generate");
        println!("\nMeasured tiny cluster: prefill {:.1} ms, decode {:.1} ms \
                  ({} tokens, {:.1} ms/token incl. query chunk)",
                 pre.wall_seconds * 1e3, gen.wall_seconds * 1e3, gen.tokens.len(),
                 gen.wall_seconds * 1e3 / gen.tokens.len() as f64);
        rows.push(report::row(vec![
            ("method", json::s("APB-tiny-measured")),
            ("backend", json::s(cfg.backend.name())),
            ("prefill_ms", json::num(pre.wall_seconds * 1e3)),
            ("decode_ms", json::num(gen.wall_seconds * 1e3)),
        ]));
    }

    let path = report::write_report("fig6_tab10_prefill_decode", vec![],
                                    Json::Arr(rows)).expect("report");
    println!("[report] {}", path.display());
}
