//! Figure 3 — the speed/performance tradeoff scatter: average accuracy
//! (Tables 1/2) vs average speed (Tables 9/12) per method and model.
//! APB must sit top-right (best tradeoff).

use apb::attnsim::{estimate, speed_tok_per_s, Hyper, Method, ModelProfile, A800,
                   LLAMA31_8B, QWEN25_14B, YI_34B};
use apb::bench_harness::{AsciiPlot, Table};
use apb::oracle::{expected_score, AccMethod, ApbQuality, EvalCtx};
use apb::report;
use apb::ruler::tasks::{infbench_tasks, ruler_tasks, ModelCol};
use apb::util::json::{self, Json};

const N: f64 = 131072.0;
const HOSTS: f64 = 8.0;

fn acc_method(m: Method) -> Option<AccMethod> {
    match m {
        Method::FlashAttn | Method::Ulysses | Method::RingAttn => Some(AccMethod::Full),
        Method::MInference => Some(AccMethod::MInference),
        Method::StarAttn => Some(AccMethod::StarAttn),
        Method::Apb => Some(AccMethod::Apb(
            ApbQuality::paper_default(4096.0, 2048.0, 16384.0))),
    }
}

fn avg_speed(method: Method, model: &ModelProfile) -> Option<f64> {
    let h = if method.uses_sequence_parallelism() { HOSTS } else { 1.0 };
    let tasks: Vec<_> = infbench_tasks().into_iter().chain(ruler_tasks()).collect();
    let mut sum = 0.0;
    for t in &tasks {
        let est = estimate(method, model, N, h, &Hyper::e2e_128k(), &A800,
                           t.out_tokens as f64);
        sum += speed_tok_per_s(&est, N, t.out_tokens as f64)?;
    }
    Some(sum / tasks.len() as f64)
}

fn avg_acc(method: Method, model: ModelCol) -> f64 {
    let am = acc_method(method).unwrap();
    let ctx = EvalCtx { n: N, hosts: HOSTS, model, samples: 0, seed: 0 };
    let tasks: Vec<_> = infbench_tasks().into_iter().chain(ruler_tasks()).collect();
    tasks.iter().map(|t| expected_score(t, am, &ctx)).sum::<f64>() / tasks.len() as f64
}

fn main() {
    let models: [(&ModelProfile, ModelCol); 3] = [
        (&LLAMA31_8B, ModelCol::Llama),
        (&QWEN25_14B, ModelCol::Qwen),
        (&YI_34B, ModelCol::Yi),
    ];
    let mut rows = Vec::new();
    for (profile, col) in models {
        let mut table = Table::new(
            &format!("Figure 3: tradeoff — {}", profile.name),
            &["Method", "speed tok/s", "avg score"],
        );
        let mut plot = AsciiPlot::new(&format!("Figure 3 ({}): speed → vs score ↑",
                                               profile.name));
        for method in Method::ALL {
            let Some(speed) = avg_speed(method, profile) else {
                table.row(vec![method.name().into(), "OOM".into(), "-".into()]);
                continue;
            };
            let acc = avg_acc(method, col);
            table.row(vec![method.name().into(), format!("{speed:.0}"),
                           format!("{acc:.2}")]);
            plot.series(method.name(), vec![(speed, acc)]);
            rows.push(report::row(vec![
                ("model", json::s(profile.name)),
                ("method", json::s(method.name())),
                ("speed", json::num(speed)),
                ("score", json::num(acc)),
            ]));
        }
        table.print();
        plot.print();

        // Pareto check: APB dominates StarAttn on both axes.
        let apb = (avg_speed(Method::Apb, profile).unwrap(), avg_acc(Method::Apb, col));
        let star = (avg_speed(Method::StarAttn, profile).unwrap(),
                    avg_acc(Method::StarAttn, col));
        assert!(apb.0 > star.0 && apb.1 > star.1,
                "{}: APB must Pareto-dominate StarAttn", profile.name);
    }
    let path = report::write_report("fig3_tradeoff", vec![("n", json::num(N))],
                                    Json::Arr(rows)).expect("report");
    println!("[report] {}", path.display());
}
