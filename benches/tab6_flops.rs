//! Table 6 — FLOPs-per-forward closed forms for FULLATTN / STARATTN / APB,
//! evaluated on the paper's models, cross-checked against the instrumented
//! per-component counters (DESIGN.md invariant 7).

use apb::attnsim::flops::{apb_components, fullattn_components, starattn_components,
                          Hyper};
use apb::attnsim::{apb_flops, fullattn_flops, starattn_flops, ALL_MODELS};
use apb::bench_harness::Table;
use apb::report;
use apb::util::json::{self, Json};

fn main() {
    let n = 131072.0;
    let hosts = 8.0;
    let hy = Hyper::e2e_128k();
    let mut rows = Vec::new();
    let mut table = Table::new(
        "Table 6: FLOPs per forward @128K (PFLOPs), closed form vs instrumented",
        &["Model", "Method", "closed form", "instrumented", "rel diff"],
    );
    for m in &ALL_MODELS {
        let entries: [(&str, f64, f64); 3] = [
            ("FullAttn", fullattn_flops(m, n), fullattn_components(m, n).total()),
            ("StarAttn", starattn_flops(m, n, hosts),
             starattn_components(m, n, hosts).total() * hosts),
            ("APB", apb_flops(m, n, &hy),
             // Closed form aggregates all hosts; components give the last
             // (critical-path) host — scale by H as an upper-bound check.
             apb_components(m, n, &hy, 1024.0).total() * hosts),
        ];
        for (name, cf, inst) in entries {
            let rel = (cf - inst).abs() / cf;
            table.row(vec![
                m.name.into(),
                name.into(),
                format!("{:.2}", cf / 1e15),
                format!("{:.2}", inst / 1e15),
                format!("{:.1}%", rel * 100.0),
            ]);
            rows.push(report::row(vec![
                ("model", json::s(m.name)),
                ("method", json::s(name)),
                ("closed_pflops", json::num(cf / 1e15)),
                ("instrumented_pflops", json::num(inst / 1e15)),
            ]));
            assert!(rel < 0.35, "{} {name}: closed vs instrumented {rel}", m.name);
        }
        // Ordering at the paper settings.
        assert!(apb_flops(m, n, &hy) < starattn_flops(m, n, hosts));
        assert!(starattn_flops(m, n, hosts) < fullattn_flops(m, n));
    }
    table.print();

    let path = report::write_report("tab6_flops", vec![("n", json::num(n))],
                                    Json::Arr(rows)).expect("report");
    println!("[report] {}", path.display());
}
