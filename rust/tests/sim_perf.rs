//! Bit-identity gates of the SimEngine hot-path performance pass
//! (docs/ADR-005-sim-perf.md): the tiled/pooled kernels and the slab-backed
//! KV pool are pure performance changes, so every observable — logits,
//! LSEs, KV bytes, pool stats — must match the scalar reference EXACTLY
//! (f32 bit equality, not tolerance), for every `AttnMethod`, under both
//! drivers, across randomized shapes, segmentations and masks.
//!
//! Runs on the native SimEngine (non-skipping tier-1; prints `APB-RUN`).

use apb::config::{ApbOptions, AttnMethod, Config};
use apb::coordinator::{Cluster, Driver};
use apb::runtime::sim::{masked_attention_seg, masked_attention_seg_ref, resolve_sim_threads};
use apb::runtime::KvSeg;
use apb::util::rng::Rng;
use apb::util::tensor::Tensor;

fn rand_tensor(rng: &mut Rng, shape: Vec<usize>) -> Tensor {
    let n: usize = shape.iter().product();
    let data: Vec<f32> = (0..n).map(|_| (rng.f64() * 2.0 - 1.0) as f32).collect();
    Tensor::new(shape, data).expect("rand tensor")
}

/// Randomized sweep: the serially-tiled public kernel vs the retired scalar
/// loop, over random GQA shapes, 1–3 segments (some empty, some padded past
/// `len`), and random masks including fully-masked rows. Scratch builds up
/// across iterations on this one thread, so shape/mask interleaving also
/// exercises the thread-local scratch + nonce invalidation.
#[test]
fn prop_tiled_kernel_matches_scalar_reference() {
    println!("APB-RUN sim_perf backend=sim");
    let mut rng = Rng::new(0x5E6_0051);
    let gqa = [(4usize, 4usize), (4, 2), (8, 2), (6, 3), (4, 1), (1, 1)];
    for case in 0..60u64 {
        let (h, kh) = gqa[rng.below(gqa.len() as u64) as usize];
        let hd = [4usize, 8, 16, 32][rng.below(4) as usize];
        let nq = 1 + rng.below(9) as usize;
        let n_segs = 1 + rng.below(3) as usize;
        let kv: Vec<(Tensor, Tensor, usize)> = (0..n_segs)
            .map(|_| {
                let len = rng.below(80) as usize; // 0-len segments included
                let rows = len + rng.below(9) as usize; // padding past len
                (rand_tensor(&mut rng, vec![rows.max(1), kh, hd]),
                 rand_tensor(&mut rng, vec![rows.max(1), kh, hd]),
                 len)
            })
            .collect();
        let segs: Vec<KvSeg<'_>> =
            kv.iter().map(|(k, v, len)| KvSeg { k, v, len: *len }).collect();
        let nk: usize = kv.iter().map(|s| s.2).sum();
        let q = rand_tensor(&mut rng, vec![nq, h, hd]);
        // Random mask; roughly one row in four is fully masked (out must be
        // exactly 0 and lse exactly -inf on both paths).
        let mask: Vec<bool> = (0..nq)
            .map(|_| {
                if rng.below(4) == 0 {
                    vec![false; nk]
                } else {
                    (0..nk).map(|_| rng.below(3) > 0).collect()
                }
            })
            .collect::<Vec<Vec<bool>>>()
            .concat();
        let visible = |qi: usize, kj: usize| mask[qi * nk + kj];
        let (o_ref, l_ref) = masked_attention_seg_ref(&q, &segs, visible);
        let (o_til, l_til) = masked_attention_seg(&q, &segs, visible);
        assert_eq!(o_ref.shape, o_til.shape);
        assert_eq!(
            o_ref.data, o_til.data,
            "case {case}: tiled out != scalar (nq={nq} h={h} kh={kh} hd={hd} nk={nk})"
        );
        assert_eq!(
            l_ref.data, l_til.data,
            "case {case}: tiled lse != scalar (nq={nq} h={h} kh={kh} hd={hd} nk={nk})"
        );
    }
}

fn request(cfg: &Config, seed: u64) -> (Vec<i32>, Vec<i32>) {
    let mut rng = Rng::new(seed);
    let doc: Vec<i32> = (0..cfg.apb.doc_len())
        .map(|_| rng.range(1, cfg.model.vocab_size as i64) as i32)
        .collect();
    let query: Vec<i32> = (0..cfg.apb.query_len)
        .map(|_| rng.range(1, cfg.model.vocab_size as i64) as i32)
        .collect();
    (doc, query)
}

/// Two-session serving scenario on a fresh cluster; the batched decode step
/// carries BOTH sessions, so the pooled `decode_attn_batch` path runs with
/// heterogeneous per-session cache lengths.
fn scenario(cfg: &Config, driver: Driver) -> (Vec<f32>, Vec<apb::coordinator::PoolStats>) {
    let cluster = Cluster::start_with(cfg, driver).expect("cluster");
    let opts = ApbOptions { method: cfg.method, ..Default::default() };
    let (doc_a, query) = request(cfg, 0xA11CE);
    let (doc_b, _) = request(cfg, 0xB0B);
    cluster.prefill_session(1, &doc_a, &query, &opts).expect("prefill A");
    cluster.prefill_session(2, &doc_b, &query, &opts).expect("prefill B");
    let vocab = cfg.model.vocab_size;
    let mut trace = Vec::new();
    let mut toks = Vec::new();
    for sid in [1u64, 2] {
        let chunk = cluster.decode_query_chunk(sid, &query).expect("query chunk");
        toks.push(Tensor::argmax_row(&chunk.logits[chunk.logits.len() - vocab..]) as i32);
        trace.extend(chunk.logits);
    }
    for _ in 0..3 {
        let rep = cluster
            .decode_step_batch(&[(1, toks[0]), (2, toks[1])])
            .expect("batched step");
        for (i, (_, logits)) in rep.logits.iter().enumerate() {
            toks[i] = Tensor::argmax_row(logits) as i32;
            trace.extend(logits.iter().copied());
        }
    }
    (trace, cluster.pool_stats().expect("pool stats"))
}

/// The perf knobs (`sim_scalar`, `sim_threads`) must be invisible in every
/// observable, for every method, under both drivers: scalar reference,
/// tiled serial (1 thread) and tiled pooled (4 threads) produce the same
/// logits trace and the same per-host pool stats.
#[test]
fn prop_perf_knobs_are_invisible_for_all_methods_and_drivers() {
    println!("APB-RUN sim_perf_knobs backend=sim");
    for method in AttnMethod::ALL {
        for driver in [Driver::Sequential, Driver::Threaded] {
            let base = Config::sim_tiny().with_method(method);
            let oracle = scenario(&base.clone().with_sim_scalar(true), driver);
            assert!(oracle.0.iter().all(|x| x.is_finite()),
                    "{} {driver:?}: non-finite oracle logits", method.name());
            for threads in [1usize, 4] {
                let got = scenario(&base.clone().with_sim_threads(threads), driver);
                assert_eq!(got.0, oracle.0,
                           "{} {driver:?} threads={threads}: logits diverged \
                            from the scalar reference",
                           method.name());
                assert_eq!(got.1, oracle.1,
                           "{} {driver:?} threads={threads}: pool stats diverged",
                           method.name());
            }
        }
    }
}

/// Slab lifecycle through the whole cluster: churning more distinct
/// documents than the prefix store caps forces freeze → evict → recycle,
/// after which a fresh request served from RECYCLED (never re-zeroed) slabs
/// must match a cold cluster bit-for-bit — logits, KV bytes and prefix
/// accounting alike.
#[test]
fn slab_recycling_is_invisible_to_a_served_request() {
    println!("APB-RUN sim_perf_slabs backend=sim");
    let cfg = Config::sim_tiny().with_prefix_cache(true);
    let churned = Cluster::start(&cfg).expect("churned cluster");
    let opts = ApbOptions::default();
    let (_, query) = request(&cfg, 1);
    for round in 0..cfg.apb.max_resident * 2 + 2 {
        let (doc, _) = request(&cfg, 0x1000 + round as u64);
        let sid = (round + 1) as u64;
        churned.prefill_session(sid, &doc, &query, &opts).expect("churn prefill");
        churned.clear_session(sid).expect("churn clear");
    }
    let reuses: u64 = churned.pool_stats().expect("stats").iter()
        .map(|s| s.slab_reuses).sum();
    assert!(reuses > 0, "churn past the prefix cap must recycle slabs");
    // Reset the store (NOT the arena: `clear` parks every entry's slabs on
    // the free list and the lifetime counters survive), so the measured
    // request below freezes into recycled slabs and both clusters end up
    // with exactly one prefix entry to compare.
    churned.clear().expect("clear churned cluster");

    let fresh = Cluster::start(&cfg).expect("fresh cluster");
    let (doc, _) = request(&cfg, 0xF00D);
    let vocab = cfg.model.vocab_size;
    let mut traces = Vec::new();
    for cluster in [&churned, &fresh] {
        cluster.prefill_session(77, &doc, &query, &opts).expect("measured prefill");
        let chunk = cluster.decode_query_chunk(77, &query).expect("query chunk");
        let tok = Tensor::argmax_row(&chunk.logits[chunk.logits.len() - vocab..]) as i32;
        let step = cluster.decode_step_batch(&[(77, tok)]).expect("step");
        let mut trace = chunk.logits;
        trace.extend(step.logits[0].1.iter().copied());
        traces.push((trace,
                     cluster.pool_stats().expect("stats").iter()
                         .map(|s| (s.bytes_used, s.prefix_bytes, s.resident))
                         .collect::<Vec<_>>()));
    }
    let reuses_after: u64 = churned.pool_stats().expect("stats").iter()
        .map(|s| s.slab_reuses).sum();
    assert!(reuses_after > reuses,
            "the measured request must have frozen into recycled slabs");
    assert_eq!(traces[0].0, traces[1].0,
               "request served from recycled slabs diverged from a cold cluster");
    assert_eq!(traces[0].1, traces[1].1,
               "byte accounting diverged between recycled and cold pools");
}

#[test]
fn sim_thread_resolution_is_explicit_then_env_then_cores() {
    // An explicit config pin always wins; 0 defers (this test cannot assert
    // the env layer without racing other tests on the process environment,
    // so it only pins the arithmetic of the fallback).
    assert_eq!(resolve_sim_threads(3, 8), 3);
    assert_eq!(resolve_sim_threads(1, 1), 1);
    let auto = resolve_sim_threads(0, usize::MAX);
    assert_eq!(auto, 1, "huge host counts must clamp the pool to 1 thread");
    assert!(resolve_sim_threads(0, 1) >= 1);
}
