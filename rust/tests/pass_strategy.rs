//! Adaptive decode pass-strategy equivalence (`docs/ADR-007-adaptive-decode.md`).
//!
//! The hard invariant: pass-Q decode (qring rotation of attention
//! partials) is **bit-identical** to the pass-KV gather path — same
//! logits, same KV pool bytes, deterministic per-label comm bytes and
//! rounds — because both feed the same per-rank partials, in rank order,
//! through the same `merge_partials` fold. Property-tested here with the
//! in-tree RNG (proptest is unavailable offline) across all four
//! `AttnMethod`s and both cluster drivers, plus:
//!
//! * the qring volume per decode step is CONSTANT while the resident
//!   context grows (the scaling point of the rotation), and
//! * multi-turn `append_turn` counts as warm for the `Auto` chooser and
//!   is itself strategy-independent bit-for-bit.

use apb::cluster::Interconnect;
use apb::config::{ApbOptions, AttnMethod, Config, PassStrategy};
use apb::coordinator::{Cluster, Driver};
use apb::kvcache::SessionId;
use apb::util::rng::Rng;

const SID: SessionId = 1;

fn rand_tokens(rng: &mut Rng, n: usize, vocab: usize) -> Vec<i32> {
    (0..n).map(|_| rng.range(1, vocab as i64) as i32).collect()
}

/// Everything one decode pass produces that must be reproducible:
/// logits, greedy tokens, per-round label split, per-label meter rounds,
/// and the pool's resident bytes.
#[derive(Debug, Clone, PartialEq)]
struct Transcript {
    chunk_logits: Vec<f32>,
    step_logits: Vec<Vec<f32>>,
    tokens: Vec<i32>,
    /// (comm, att, qring) byte deltas: index 0 is the chunk pass, then
    /// one entry per decode step.
    bytes: Vec<(u64, u64, u64)>,
    /// (att, qring) meter-round deltas, same indexing.
    rounds: Vec<(u64, u64)>,
    strategies: Vec<PassStrategy>,
    pool_bytes: usize,
}

/// Prefill one session and run `n_steps` greedy decode steps under the
/// given fixed strategy, recording the full transcript.
fn run(
    driver: Driver,
    method: AttnMethod,
    strategy: PassStrategy,
    doc: &[i32],
    query: &[i32],
    n_steps: usize,
) -> Transcript {
    let cfg = Config::sim_tiny().with_pass_strategy(strategy);
    let cluster = Cluster::start_with(&cfg, driver).expect("cluster");
    let opts = ApbOptions { method, ..Default::default() };
    let prefill = cluster.prefill_session(SID, doc, query, &opts).expect("prefill");
    // The strategy is a decode-side knob: prefill comm must not see it.
    assert_eq!(
        prefill.comm_bytes > 0,
        method.passes_compressed_blocks() || method == AttnMethod::RingAttn,
        "{}: prefill comm is method-determined, not strategy-determined",
        method.name()
    );

    let meter = &cluster.fabric.meter;
    let label_rounds = || {
        (
            meter.rounds_for(Interconnect::ATT_LABEL),
            meter.rounds_for(Interconnect::QRING_LABEL),
        )
    };
    let mut bytes = Vec::new();
    let mut rounds = Vec::new();
    let mut strategies = Vec::new();

    let r0 = label_rounds();
    let chunk = cluster.decode_query_chunk(SID, query).expect("query chunk");
    let r1 = label_rounds();
    bytes.push((chunk.comm_bytes, chunk.att_bytes, chunk.qring_bytes));
    rounds.push((r1.0 - r0.0, r1.1 - r0.1));
    strategies.push(chunk.strategy);

    let vocab = cluster.cfg.model.vocab_size;
    let mut token =
        apb::util::tensor::Tensor::argmax_row(&chunk.logits[chunk.logits.len() - vocab..])
            as i32;
    let mut tokens = Vec::new();
    let mut step_logits = Vec::new();
    for _ in 0..n_steps {
        tokens.push(token);
        let r0 = label_rounds();
        let rep = cluster.decode_step_batch(&[(SID, token)]).expect("decode step");
        let r1 = label_rounds();
        bytes.push((rep.comm_bytes, rep.att_bytes, rep.qring_bytes));
        rounds.push((r1.0 - r0.0, r1.1 - r0.1));
        strategies.push(rep.strategy);
        token = apb::util::tensor::Tensor::argmax_row(&rep.logits[0].1) as i32;
        step_logits.push(rep.logits[0].1.clone());
    }

    let pool_bytes = cluster
        .pool_stats()
        .expect("pool stats")
        .iter()
        .map(|s| s.bytes_used)
        .sum();
    Transcript {
        chunk_logits: chunk.logits,
        step_logits,
        tokens,
        bytes,
        rounds,
        strategies,
        pool_bytes,
    }
}

#[test]
fn prop_pass_q_bit_identical_to_gather_for_all_methods_and_drivers() {
    let cfg = Config::sim_tiny();
    let (n, layers) = (cfg.apb.n_hosts, cfg.model.n_layers);
    // One metered partial: (out [rows, h, hd], lse [rows, h]) in f32.
    let partial_bytes =
        |rows: usize| (rows * (cfg.model.n_heads * cfg.model.head_dim() + cfg.model.n_heads) * 4) as u64;
    let mut rng = Rng::new(0x9AC7);
    for case in 0..3usize {
        let doc = rand_tokens(&mut rng, cfg.apb.doc_len(), cfg.model.vocab_size);
        let query = rand_tokens(&mut rng, cfg.apb.query_len, cfg.model.vocab_size);
        for method in AttnMethod::ALL {
            let mut per_driver = Vec::new();
            for driver in [Driver::Sequential, Driver::Threaded] {
                let kv = run(driver, method, PassStrategy::PassKv, &doc, &query, 3);
                let q = run(driver, method, PassStrategy::PassQ, &doc, &query, 3);
                let tag = format!("case {case} {} {}", method.name(), driver.name());

                // The invariant: logits, tokens and pool bytes are
                // bit-identical across strategies.
                assert_eq!(kv.chunk_logits, q.chunk_logits, "{tag}: chunk logits");
                assert_eq!(kv.step_logits, q.step_logits, "{tag}: step logits");
                assert_eq!(kv.tokens, q.tokens, "{tag}: greedy tokens");
                assert_eq!(kv.pool_bytes, q.pool_bytes, "{tag}: pool bytes");

                for (i, &(comm, att, qring)) in kv.bytes.iter().enumerate() {
                    let rows = if i == 0 { cfg.apb.query_len } else { 1 };
                    let (qcomm, qatt, qqring) = q.bytes[i];
                    // Decode rounds charge exactly one merge label.
                    assert_eq!(att + qring, comm, "{tag}: kv round {i} label split");
                    assert_eq!(qatt + qqring, qcomm, "{tag}: q round {i} label split");
                    assert_eq!(qring, 0, "{tag}: gather path must not touch qring");
                    if method.distributed_decode() {
                        assert_eq!(kv.strategies[i], PassStrategy::PassKv, "{tag}");
                        assert_eq!(q.strategies[i], PassStrategy::PassQ, "{tag}");
                        // Value-level: the gather posts one partial per
                        // rank per layer; the rotation posts the same
                        // partial unit n-1 times per rank per layer.
                        assert_eq!(att, (n * layers) as u64 * partial_bytes(rows),
                                   "{tag}: att bytes round {i}");
                        assert_eq!(qatt, 0, "{tag}: rotation must not touch att");
                        assert_eq!(qqring, (n - 1) as u64 * att,
                                   "{tag}: qring bytes round {i}");
                        assert_eq!(kv.rounds[i], ((n * layers) as u64, 0), "{tag}");
                        assert_eq!(q.rounds[i], (0, (n * (n - 1) * layers) as u64),
                                   "{tag}");
                    } else {
                        // Dense decodes on host 0: no merge collective at
                        // all, and the strategy degenerates to pass-KV.
                        assert_eq!((comm, qcomm), (0, 0), "{tag}: dense comm");
                        assert_eq!(kv.strategies[i], PassStrategy::PassKv, "{tag}");
                        assert_eq!(q.strategies[i], PassStrategy::PassKv, "{tag}");
                    }
                }
                per_driver.push((kv, q));
            }
            // Driver parity: the whole transcript (logits, bytes, rounds,
            // strategies, pool bytes) replays identically threaded vs
            // sequential.
            assert_eq!(per_driver[0], per_driver[1],
                       "case {case} {}: drivers diverged", method.name());
        }
    }
}

#[test]
fn qring_bytes_per_step_flat_while_context_grows() {
    // Each decode step appends one token to the resident context, so by
    // the last step the attended context is strictly longer than at the
    // first — the rotation's per-step volume must not care.
    let cfg = Config::sim_tiny().with_pass_strategy(PassStrategy::PassQ);
    let cluster = Cluster::start_with(&cfg, Driver::Sequential).expect("cluster");
    let mut rng = Rng::new(0xF1A7);
    let doc = rand_tokens(&mut rng, cfg.apb.doc_len(), cfg.model.vocab_size);
    let query = rand_tokens(&mut rng, cfg.apb.query_len, cfg.model.vocab_size);
    cluster.prefill_session(SID, &doc, &query, &ApbOptions::default()).expect("prefill");
    let chunk = cluster.decode_query_chunk(SID, &query).expect("chunk");
    assert!(chunk.qring_bytes > 0, "pass-Q chunk must ride the qring");

    let vocab = cluster.cfg.model.vocab_size;
    let mut token =
        apb::util::tensor::Tensor::argmax_row(&chunk.logits[chunk.logits.len() - vocab..])
            as i32;
    let mut per_step = Vec::new();
    for _ in 0..cfg.apb.max_new_tokens - 1 {
        let rep = cluster.decode_step_batch(&[(SID, token)]).expect("step");
        per_step.push(rep.qring_bytes);
        assert_eq!(rep.att_bytes, 0);
        token = apb::util::tensor::Tensor::argmax_row(&rep.logits[0].1) as i32;
    }
    assert!(per_step.len() >= 4, "need several steps to see the growth");
    assert!(per_step[0] > 0);
    assert!(
        per_step.iter().all(|&b| b == per_step[0]),
        "qring bytes must be flat in context length, got {per_step:?}"
    );
}

#[test]
fn append_turn_is_warm_for_auto_and_strategy_independent() {
    let mut rng = Rng::new(0x7B4E);
    let base = Config::sim_tiny();
    let doc = rand_tokens(&mut rng, base.apb.doc_len(), base.model.vocab_size);
    let query = rand_tokens(&mut rng, base.apb.query_len, base.model.vocab_size);
    let turn = rand_tokens(&mut rng, 3, base.model.vocab_size);

    // Under Auto: a cold session's chunk pays the gather, the follow-up
    // turn rides the qring, and every step after it stays warm.
    let cfg = Config::sim_tiny().with_pass_strategy(PassStrategy::Auto);
    let cluster = Cluster::start_with(&cfg, Driver::Sequential).expect("cluster");
    cluster.prefill_session(SID, &doc, &query, &ApbOptions::default()).expect("prefill");
    let chunk = cluster.decode_query_chunk(SID, &query).expect("chunk");
    assert_eq!(chunk.strategy, PassStrategy::PassKv, "cold session pays the gather");
    assert_eq!(chunk.qring_bytes, 0);
    let turn_rep = cluster.append_turn(SID, &turn).expect("turn");
    assert_eq!(turn_rep.strategy, PassStrategy::PassQ, "a follow-up turn is warm");
    assert!(turn_rep.qring_bytes > 0);
    assert_eq!(turn_rep.att_bytes, 0);
    assert!(turn_rep.logits.iter().all(|x| x.is_finite()));
    let vocab = cfg.model.vocab_size;
    let tok = apb::util::tensor::Tensor::argmax_row(
        &turn_rep.logits[turn_rep.logits.len() - vocab..],
    ) as i32;
    let step = cluster.decode_step_batch(&[(SID, tok)]).expect("step");
    assert_eq!(step.strategy, PassStrategy::PassQ, "turned session stays warm");

    // And the turn itself is bit-identical across fixed strategies.
    let mut turn_logits = Vec::new();
    for strategy in [PassStrategy::PassKv, PassStrategy::PassQ] {
        let cfg = Config::sim_tiny().with_pass_strategy(strategy);
        let cluster = Cluster::start_with(&cfg, Driver::Sequential).expect("cluster");
        cluster
            .prefill_session(SID, &doc, &query, &ApbOptions::default())
            .expect("prefill");
        cluster.decode_query_chunk(SID, &query).expect("chunk");
        let rep = cluster.append_turn(SID, &turn).expect("turn");
        assert_eq!(rep.strategy, strategy);
        turn_logits.push(rep.logits);
    }
    assert_eq!(turn_logits[0], turn_logits[1], "turn logits must be bit-identical");
}
