//! Cluster behaviour across the executable `AttnMethod` modes (APB /
//! StarAttn / RingAttn / Dense) and failure conditions, including the
//! exactness invariant: the exact methods must agree with the Dense oracle
//! within float tolerance, the approximate ones must not.
//!
//! Runs on the native SimEngine backend by default (no artifacts needed, so
//! these are non-skipping tier-1 tests); with `--features pjrt` and
//! `make artifacts` the same assertions run against the PJRT cluster.

use apb::cluster::Interconnect;
use apb::config::{ApbOptions, AttnMethod, Config};
use apb::coordinator::Cluster;
use apb::ruler::{gen_instance, TaskKind};
use apb::util::rng::Rng;

fn cluster() -> (apb::config::Config, Cluster) {
    let cfg = apb::load_config_or_sim("tiny").expect("config");
    println!("APB-RUN cluster_modes backend={}", cfg.backend.name());
    let c = Cluster::start(&cfg).expect("cluster start");
    (cfg, c)
}

#[test]
fn wrong_sized_inputs_are_rejected_not_fatal() {
    let (cfg, cluster) = cluster();
    let opts = ApbOptions::default();
    // Wrong doc length.
    assert!(cluster.prefill(&[1, 2, 3], &[0; 16], &opts).is_err());
    // Wrong query length.
    let doc = vec![1i32; cfg.apb.doc_len()];
    assert!(cluster.prefill(&doc, &[1, 2], &opts).is_err());
    // Cluster still serves correct requests afterwards.
    let query = vec![1i32; cfg.apb.query_len];
    cluster.prefill(&doc, &query, &opts).expect("recovers after bad input");
    let gen = cluster.generate(&query, 2).expect("generates");
    assert_eq!(gen.tokens.len(), 2);
}

#[test]
fn star_mode_moves_zero_bytes_and_differs() {
    let (cfg, cluster) = cluster();
    let mut rng = Rng::new(5);
    let inst = gen_instance(&cfg, TaskKind::SingleNiah, &mut rng);
    let apb_rep = cluster
        .prefill(&inst.doc, &inst.query, &ApbOptions::default())
        .unwrap();
    let apb_gen = cluster.generate(&inst.query, 2).unwrap();
    assert!(apb_rep.comm_bytes > 0);

    cluster.clear().unwrap();
    let star = ApbOptions { method: AttnMethod::StarAttn, ..Default::default() };
    let star_rep = cluster.prefill(&inst.doc, &inst.query, &star).unwrap();
    let star_gen = cluster.generate(&inst.query, 2).unwrap();
    assert_eq!(star_rep.comm_bytes, 0, "Star-mode must not communicate");
    let d: f32 = apb_gen
        .query_logits
        .iter()
        .zip(&star_gen.query_logits)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f32::max);
    assert!(d > 1e-6, "passing blocks must affect the computation");
}

#[test]
fn retention_recall_trained_beats_random() {
    // The measured heart of the R vs Rd. ablation: retaining heads (trained
    // on the PJRT path, query-similarity-wired on the sim path) must keep
    // planted needles at a much higher rate than the random selector's
    // l_p/l_b baseline.
    let (cfg, cluster) = cluster();
    let mut rng = Rng::new(17);
    let mut r_trained = 0.0;
    let mut r_random = 0.0;
    let mut used = 0usize;
    let samples = 6;
    for _ in 0..samples {
        let inst = gen_instance(&cfg, TaskKind::SingleNiah, &mut rng);
        // Host 0 carries no anchor, so its compressor sees no embedded
        // query and scores ~randomly by construction (same on the python
        // side); measure needles on hosts > 0, where the passing mechanism
        // actually applies (see PrefillReport::retention_recall docs).
        let positions: Vec<usize> = inst
            .needle_positions
            .iter()
            .copied()
            .filter(|&p| p >= cfg.apb.block_len)
            .collect();
        if positions.is_empty() {
            continue;
        }
        used += 1;
        // Retention-recall experiments opt in to the retained-index record.
        let recorded = ApbOptions { record_retained: true, ..Default::default() };
        cluster.clear().unwrap();
        let rep = cluster.prefill(&inst.doc, &inst.query, &recorded).unwrap();
        r_trained += rep.retention_recall(&cfg, &positions);
        cluster.clear().unwrap();
        let rep = cluster
            .prefill(&inst.doc, &inst.query,
                     &ApbOptions { retaining_compressor: false, ..recorded })
            .unwrap();
        r_random += rep.retention_recall(&cfg, &positions);
    }
    assert!(used >= 2, "too few needles landed beyond block 0 ({used})");
    r_trained /= used as f64;
    r_random /= used as f64;
    let frac = cfg.apb.passing_len as f64 / cfg.apb.block_len as f64;
    println!("trained {r_trained:.3} random {r_random:.3} over {used} samples \
              (l_p/l_b = {frac:.3})");
    // Random selector keeps ~l_p/l_b of anything (the selection is
    // coordinator-side and backend-independent, so this holds on both tiers).
    assert!((r_random - frac).abs() < 0.15, "random recall {r_random} vs {frac}");
    // Both a multiplicative and an absolute margin: the ratio guards the
    // trained/PJRT tier against regressions toward random, the absolute gap
    // guards against a tiny-random-recall sample making the ratio vacuous.
    assert!(r_trained > 1.5 * r_random && r_trained > r_random + 0.1,
            "retaining heads must beat random: {r_trained} vs {r_random}");
}

#[test]
fn rd_seed_changes_random_selection_deterministically() {
    let (cfg, cluster) = cluster();
    let mut rng = Rng::new(29);
    let inst = gen_instance(&cfg, TaskKind::SingleNiah, &mut rng);
    let run = |seed: u64| {
        cluster.clear().unwrap();
        let o = ApbOptions { retaining_compressor: false, rd_seed: seed,
                             record_retained: true, ..Default::default() };
        let rep = cluster.prefill(&inst.doc, &inst.query, &o).unwrap();
        rep.retained.clone()
    };
    let a = run(1);
    let b = run(1);
    let c = run(2);
    assert_eq!(a, b, "same rd_seed must reproduce the selection");
    assert_ne!(a, c, "different rd_seed must change the selection");
}

#[test]
fn retained_indices_are_opt_in() {
    // Serving requests must not drag O(layers × kv_heads × l_p) of retained
    // index sets through their lifetime unless a recall experiment asks.
    let (cfg, cluster) = cluster();
    let mut rng = Rng::new(23);
    let inst = gen_instance(&cfg, TaskKind::SingleNiah, &mut rng);
    let rep = cluster
        .prefill(&inst.doc, &inst.query, &ApbOptions::default())
        .unwrap();
    assert!(rep.retained.iter().all(|h| h.is_empty()),
            "retained must be empty without record_retained");
    assert_eq!(rep.retention_recall(&cfg, &[cfg.apb.block_len + 1]), 0.0);

    cluster.clear().unwrap();
    let rep = cluster
        .prefill(&inst.doc, &inst.query,
                 &ApbOptions { record_retained: true, ..Default::default() })
        .unwrap();
    for h in &rep.retained {
        assert_eq!(h.len(), cfg.model.n_layers);
        for layer in h {
            assert_eq!(layer.len(), cfg.model.n_kv_heads);
            for head in layer {
                assert_eq!(head.len(), cfg.apb.passing_len);
            }
        }
    }
}

/// One full request (prefill + query-chunk + 2 decode steps) on a fresh
/// cluster bound to `method`; returns the chunk logits plus the measured
/// per-label comm. The request is identical across methods (same seed,
/// same model weights via `Config::seed`), so logits are comparable.
fn run_method(method: AttnMethod) -> (Vec<f32>, u64, u64, u64) {
    let cfg = Config::sim_tiny().with_method(method);
    let cluster = Cluster::start(&cfg).expect("cluster start");
    let mut rng = Rng::new(77);
    let inst = gen_instance(&cfg, TaskKind::SingleNiah, &mut rng);
    let opts = ApbOptions { method, ..Default::default() };
    cluster.prefill(&inst.doc, &inst.query, &opts).expect("prefill");
    let gen = cluster.generate(&inst.query, 2).expect("generate");
    assert!(gen.query_logits.iter().all(|x| x.is_finite()),
            "{} produced non-finite logits", method.name());
    let m = &cluster.fabric.meter;
    (
        gen.query_logits,
        m.bytes_for(Interconnect::KV_LABEL),
        m.bytes_for(Interconnect::RING_LABEL),
        m.bytes_total(),
    )
}

fn linf(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

#[test]
fn ring_matches_dense_oracle_within_1e5() {
    // The tentpole exactness invariant: RingAttn (distributed, rotated KV
    // blocks + online-softmax merge) and Dense (everything on host 0) are
    // the same mathematical function; the cluster must reproduce that.
    println!("APB-RUN exact_methods backend=sim");
    let (dense, _, _, dense_total) = run_method(AttnMethod::Dense);
    let (ring, ring_kv, ring_ring, _) = run_method(AttnMethod::RingAttn);
    assert_eq!(dense_total, 0, "Dense must not communicate at all");
    assert_eq!(ring_kv, 0, "RingAttn never passes compressed blocks");
    assert!(ring_ring > 0, "RingAttn must rotate KV over the ring");
    let d = linf(&ring, &dense);
    assert!(d < 1e-5, "RingAttn vs Dense logits Linf {d} >= 1e-5");
}

#[test]
fn approximate_methods_differ_from_dense() {
    // The other half of `AttnMethod::exact_attention`: the anchor/passing
    // approximations must NOT match the oracle (if they did, the paper's
    // accuracy/compute trade-off would be vacuous on this cluster).
    let (dense, ..) = run_method(AttnMethod::Dense);
    for method in [AttnMethod::Apb, AttnMethod::StarAttn] {
        let (logits, ..) = run_method(method);
        let d = linf(&logits, &dense);
        assert!(!method.exact_attention());
        assert!(d > 1e-6, "{} unexpectedly matched the dense oracle", method.name());
    }
}

#[test]
fn ring_rotation_moves_full_kv_blocks() {
    // Measured comm volume: the ring rotates every host's full (K, V)
    // block to every other host — H-1 exchange rounds per layer, each
    // moving all H blocks once — while APB AllGathers only l_p compressed
    // rows per host per layer. Both are exactly predictable.
    let cfg = Config::sim_tiny().with_method(AttnMethod::RingAttn);
    let cluster = Cluster::start(&cfg).expect("cluster start");
    let mut rng = Rng::new(78);
    let inst = gen_instance(&cfg, TaskKind::SingleNiah, &mut rng);
    let ring_opts = ApbOptions { method: AttnMethod::RingAttn, ..Default::default() };
    cluster.prefill(&inst.doc, &inst.query, &ring_opts).unwrap();
    let (a, m) = (&cfg.apb, &cfg.model);
    let row_bytes = 2 * m.n_kv_heads * m.head_dim() * 4; // K and V, f32
    let total_rows = a.query_len + a.doc_len(); // [query | doc] split
    let want_ring = (m.n_layers * (a.n_hosts - 1) * total_rows * row_bytes) as u64;
    let meter = &cluster.fabric.meter;
    assert_eq!(meter.bytes_for(Interconnect::RING_LABEL), want_ring);
    assert_eq!(
        meter.rounds_for(Interconnect::RING_LABEL),
        (m.n_layers * a.n_hosts * (a.n_hosts - 1)) as u64,
        "every rank contributes to every exchange round"
    );
    assert_eq!(meter.bytes_for(Interconnect::KV_LABEL), 0);

    // APB's compressed passing on the same request, for the ratio claim.
    let apb_cluster = Cluster::start(&Config::sim_tiny()).expect("cluster start");
    apb_cluster.prefill(&inst.doc, &inst.query, &ApbOptions::default()).unwrap();
    let want_kv = (m.n_layers * a.n_hosts * 2 * a.passing_len * m.n_kv_heads
        * m.head_dim() * 4) as u64;
    let kv = apb_cluster.fabric.meter.bytes_for(Interconnect::KV_LABEL);
    assert_eq!(kv, want_kv);
    assert!(want_ring > kv,
            "ring must move more bytes than APB's compressed blocks \
             ({want_ring} vs {kv})");
}

#[test]
fn dense_request_needs_dense_sized_pool() {
    // A Dense request on a cluster whose pool was sized for the
    // distributed modes must be rejected cleanly — identically on every
    // host, before any collective — and the cluster must keep serving.
    let (cfg, cluster) = cluster();
    let mut rng = Rng::new(79);
    let inst = gen_instance(&cfg, TaskKind::SingleNiah, &mut rng);
    let dense = ApbOptions { method: AttnMethod::Dense, ..Default::default() };
    let err = cluster.prefill(&inst.doc, &inst.query, &dense).unwrap_err();
    assert!(format!("{err:#}").contains("KV rows"), "unexpected error: {err:#}");
    // RingAttn fits the standard pool (host 0 holds [query | block 0]).
    let ring = ApbOptions { method: AttnMethod::RingAttn, ..Default::default() };
    cluster.prefill(&inst.doc, &inst.query, &ring).expect("ring on standard pool");
    cluster.clear().unwrap();
    cluster
        .prefill(&inst.doc, &inst.query, &ApbOptions::default())
        .expect("APB still serves after the rejected request");
    let gen = cluster.generate(&inst.query, 2).expect("generate");
    assert_eq!(gen.tokens.len(), 2);
}

#[test]
fn generate_without_prefill_works_on_empty_caches() {
    // Degenerate but must not deadlock or crash: decode over empty caches
    // relies on the -inf LSE merge path.
    let (cfg, cluster) = cluster();
    cluster.clear().unwrap();
    let query = vec![1i32; cfg.apb.query_len];
    let gen = cluster.generate(&query, 1).expect("empty-cache decode");
    assert!(gen.query_logits.iter().all(|x| x.is_finite()));
}
