//! Shared-prefix KV reuse — the acceptance gate of the prefix-caching
//! tentpole (docs/ADR-003-prefix-caching.md): for EVERY `AttnMethod`, a
//! request whose digest hits the pool's prefix store must be
//! **bit-identical** to a cold prefill of the same request in
//!
//! * the query-chunk logits (exact f32 equality, not tolerance),
//! * the session's logical KV bytes and the per-host pool picture,
//! * the decode-path per-label CommMeter bytes AND rounds,
//!
//! while the warm prefill itself moves ZERO bytes (its entire document
//! pass is skipped) and reports `prefix_bytes_saved > 0`.
//!
//! Runs on the native SimEngine (non-skipping tier-1; prints `APB-RUN`).

use apb::cluster::Interconnect;
use apb::config::{ApbOptions, AttnMethod, Config};
use apb::coordinator::scheduler::{Request, Scheduler};
use apb::coordinator::{Cluster, PoolStats, SessionId};
use apb::util::rng::Rng;

const LABELS: [&str; 3] = [Interconnect::KV_LABEL, Interconnect::ATT_LABEL, Interconnect::RING_LABEL];

fn request(cfg: &Config, seed: u64) -> (Vec<i32>, Vec<i32>) {
    let mut rng = Rng::new(seed);
    let doc = (0..cfg.apb.doc_len())
        .map(|_| rng.range(1, cfg.model.vocab_size as i64) as i32)
        .collect();
    let query = (0..cfg.apb.query_len)
        .map(|_| rng.range(1, cfg.model.vocab_size as i64) as i32)
        .collect();
    (doc, query)
}

fn comm_snapshot(cluster: &Cluster) -> Vec<(u64, u64)> {
    let m = &cluster.fabric.meter;
    LABELS.iter().map(|l| (m.bytes_for(l), m.rounds_for(l))).collect()
}

fn comm_delta(before: &[(u64, u64)], after: &[(u64, u64)]) -> Vec<(u64, u64)> {
    before
        .iter()
        .zip(after)
        .map(|(b, a)| (a.0 - b.0, a.1 - b.1))
        .collect()
}

/// Everything the bit-identity invariant compares between a cold and a
/// warm run of the same request (the session is the only one resident
/// when the snapshot is taken).
#[derive(Debug, PartialEq)]
struct Fingerprint {
    /// Query-chunk logits — exact equality.
    logits: Vec<f32>,
    /// Per-label (bytes, rounds) the query-chunk decode contributed.
    decode_comm: Vec<(u64, u64)>,
    /// Per-host pool stats after prefill (private bytes + prefix store).
    pool_after_prefill: Vec<PoolStats>,
    /// Per-host logical KV rows... via bytes_used + prefix_bytes after the
    /// decode pass (shared entry counted once; one session resident).
    pool_after_decode: Vec<PoolStats>,
}

/// Prefill + query-chunk decode of `sid`, fingerprinting everything the
/// invariant compares. Returns (fingerprint, report).
fn run_once(
    cluster: &Cluster,
    sid: SessionId,
    doc: &[i32],
    query: &[i32],
    opts: &ApbOptions,
) -> (Fingerprint, apb::coordinator::PrefillReport, Vec<(u64, u64)>) {
    let before_prefill = comm_snapshot(cluster);
    let rep = cluster.prefill_session(sid, doc, query, opts).expect("prefill");
    let after_prefill = comm_snapshot(cluster);
    let pool_after_prefill = cluster.pool_stats().expect("pool stats");
    let chunk = cluster.decode_query_chunk(sid, query).expect("query chunk");
    let after_decode = comm_snapshot(cluster);
    let fp = Fingerprint {
        logits: chunk.logits,
        decode_comm: comm_delta(&after_prefill, &after_decode),
        pool_after_prefill,
        pool_after_decode: cluster.pool_stats().expect("pool stats"),
    };
    (fp, rep, comm_delta(&before_prefill, &after_prefill))
}

#[test]
fn prop_prefix_hit_is_bit_identical_for_all_methods() {
    println!("APB-RUN prefix_cache backend=sim");
    for method in AttnMethod::ALL {
        let cfg = Config::sim_tiny().with_method(method).with_prefix_cache(true);
        let (doc, query) = request(&cfg, 0x9E37 + method as u64);
        let opts = ApbOptions { method, ..Default::default() };

        // Reference: the same request on a cache-DISABLED cluster — proves
        // that merely enabling the cache never perturbs the cold path.
        let disabled = Cluster::start(&Config::sim_tiny().with_method(method))
            .expect("disabled cluster");
        let (fp_disabled, rep_disabled, _) = run_once(&disabled, 1, &doc, &query, &opts);
        assert!(!rep_disabled.prefix_hit);
        assert_eq!(rep_disabled.prefix_bytes_saved, 0);

        // Cold run on the enabled cluster: misses, freezes the prefix.
        let cluster = Cluster::start(&cfg).expect("cluster");
        let (fp_cold, rep_cold, _) = run_once(&cluster, 1, &doc, &query, &opts);
        assert!(!rep_cold.prefix_hit, "{}: first run must miss", method.name());
        assert_eq!(fp_cold.logits, fp_disabled.logits,
                   "{}: enabling the cache changed cold logits", method.name());
        assert_eq!(fp_cold.decode_comm, fp_disabled.decode_comm,
                   "{}: enabling the cache changed cold decode comm", method.name());
        let frozen: usize =
            fp_cold.pool_after_prefill.iter().map(|s| s.prefix_bytes).sum();
        assert!(frozen > 0, "{}: cold run must freeze a prefix entry", method.name());

        // Warm run: same request, fresh session — the store answers.
        cluster.clear_session(1).expect("clear cold session");
        let (fp_warm, rep_warm, warm_prefill_comm) =
            run_once(&cluster, 2, &doc, &query, &opts);
        assert!(rep_warm.prefix_hit, "{}: second run must hit", method.name());
        assert_eq!(rep_warm.comm_bytes, 0,
                   "{}: warm prefill must not communicate", method.name());
        assert!(warm_prefill_comm.iter().all(|&(b, r)| b == 0 && r == 0),
                "{}: warm prefill moved bytes: {warm_prefill_comm:?}", method.name());
        assert_eq!(rep_warm.prefix_bytes_saved, frozen as u64,
                   "{}: bytes saved must equal the frozen entry", method.name());
        assert!(rep_warm.prefix_bytes_saved > 0, "{}: must save bytes", method.name());

        // THE invariant: logits, decode comm (bytes AND rounds per label)
        // and the whole per-host pool picture are bit-identical to cold.
        assert_eq!(fp_warm, fp_cold,
                   "{}: prefix-hit run diverged from cold", method.name());

        // Retained indices survive the freeze/attach round trip too.
        let rec = ApbOptions { record_retained: true, ..opts };
        let rep_rec_cold = cluster.prefill_session(3, &doc, &query, &rec)
            .expect("recording cold prefill");
        cluster.clear_session(3).expect("clear");
        let rep_rec_warm = cluster.prefill_session(4, &doc, &query, &rec)
            .expect("recording warm prefill");
        assert!(!rep_rec_cold.prefix_hit && rep_rec_warm.prefix_hit,
                "{}: record_retained digests must key their own entry",
                method.name());
        assert_eq!(rep_rec_warm.retained, rep_rec_cold.retained,
                   "{}: warm retained record must match cold", method.name());
        cluster.clear_session(2).expect("clear");
        cluster.clear_session(4).expect("clear");
    }
}

#[test]
fn generation_after_hit_matches_cold_generation() {
    // Beyond the first chunk: full greedy decode over a warm session must
    // emit exactly the cold run's tokens (the private tail extends the
    // shared prefix copy-on-extend, and the segmented attention is
    // bit-identical to contiguous).
    println!("APB-RUN prefix_cache_generation backend=sim");
    let cfg = Config::sim_tiny().with_prefix_cache(true);
    let cluster = Cluster::start(&cfg).expect("cluster");
    let (doc, query) = request(&cfg, 0xBEEF);
    let opts = ApbOptions::default();
    let max_new = cfg.apb.max_new_tokens;

    cluster.prefill(&doc, &query, &opts).expect("cold prefill");
    let cold = cluster.generate(&query, max_new).expect("cold generate");
    // Same LEGACY session re-prefilled: realloc releases the ref, then the
    // digest hits and generation proceeds over the shared entry.
    let rep = cluster.prefill(&doc, &query, &opts).expect("warm prefill");
    assert!(rep.prefix_hit, "re-prefill of the same request must hit");
    let warm = cluster.generate(&query, max_new).expect("warm generate");
    assert_eq!(warm.tokens, cold.tokens, "warm decode diverged");
    assert_eq!(warm.query_logits, cold.query_logits, "warm chunk logits diverged");
}

#[test]
fn clear_session_releases_ref_without_dropping_shared_bytes() {
    println!("APB-RUN prefix_cache_refcount backend=sim");
    let cfg = Config::sim_tiny().with_prefix_cache(true);
    let cluster = Cluster::start(&cfg).expect("cluster");
    let (doc, query) = request(&cfg, 0xF00D);
    let opts = ApbOptions::default();

    cluster.prefill_session(1, &doc, &query, &opts).expect("cold prefill");
    let stats = cluster.pool_stats().expect("stats");
    let frozen: usize = stats.iter().map(|s| s.prefix_bytes).sum();
    assert!(frozen > 0);
    assert!(stats.iter().all(|s| s.prefix_entries == 1));

    // Clearing the only attached session drops its ref but NOT the entry.
    cluster.clear_session(1).expect("clear");
    let stats = cluster.pool_stats().expect("stats");
    assert!(stats.iter().all(|s| s.resident == 0));
    assert_eq!(stats.iter().map(|s| s.prefix_bytes).sum::<usize>(), frozen,
               "shared bytes must survive the rider's departure");
    assert!(stats.iter().all(|s| s.prefix_entries == 1));

    // ...so the next rider still hits warm.
    let rep = cluster.prefill_session(2, &doc, &query, &opts).expect("warm");
    assert!(rep.prefix_hit);

    // clear() (the full between-phases reset) drops the store too.
    cluster.clear().expect("clear all");
    let stats = cluster.pool_stats().expect("stats");
    assert!(stats.iter().all(|s| s.prefix_entries == 0 && s.prefix_bytes == 0));
    let rep = cluster.prefill_session(3, &doc, &query, &opts).expect("cold again");
    assert!(!rep.prefix_hit, "clear() must empty the prefix store");
}

#[test]
fn different_documents_and_methods_miss() {
    // A store warmed by one request must not answer a different document,
    // a different query, or the same content under another AttnMethod
    // (the method is part of the digest — a Dense-sized pool accepts all
    // four, so one cluster can check the cross-method miss directly).
    println!("APB-RUN prefix_cache_miss backend=sim");
    let cfg = Config::sim_tiny()
        .with_method(AttnMethod::Dense)
        .with_prefix_cache(true);
    let cluster = Cluster::start(&cfg).expect("cluster");
    let (doc, query) = request(&cfg, 0xAB);
    let apb = ApbOptions::default();

    let rep = cluster.prefill_session(1, &doc, &query, &apb).expect("cold");
    assert!(!rep.prefix_hit);

    // Different content: miss.
    let (doc2, _) = request(&cfg, 0xCD);
    let rep = cluster.prefill_session(2, &doc2, &query, &apb).expect("other doc");
    assert!(!rep.prefix_hit, "different document must miss");
    let mut query2 = query.clone();
    query2[0] = (query2[0] % 100) + 1;
    let rep = cluster.prefill_session(3, &doc, &query2, &apb).expect("other query");
    assert!(!rep.prefix_hit,
            "different query must miss (the anchor embeds the query, so \
             even the document KV is query-dependent)");

    // Same content, different method: the digest separates them.
    let star = ApbOptions { method: AttnMethod::StarAttn, ..apb };
    let rep = cluster.prefill_session(4, &doc, &query, &star).expect("star");
    assert!(!rep.prefix_hit, "same content under another method must miss");
    // And the original still hits.
    cluster.clear_session(1).expect("clear");
    let rep = cluster.prefill_session(5, &doc, &query, &apb).expect("warm");
    assert!(rep.prefix_hit);
}

#[test]
fn scheduler_reports_hits_and_hit_aware_ttft() {
    // Serving-side observability: same-corpus requests served sequentially
    // through the Scheduler must surface prefix_hits, prefix_bytes_saved
    // and the cold/warm TTFT split — with the warm request reaching its
    // first token faster than the cold miss (its admission is one attach
    // step instead of a document pass).
    println!("APB-RUN prefix_cache_serving backend=sim");
    let cfg = Config::sim_tiny().with_prefix_cache(true);
    let cluster = Cluster::start(&cfg).expect("cluster");
    let mut sched = Scheduler::new(&cluster, 8);
    let (doc, query) = request(&cfg, 0x5A5A);
    for id in 0..3u64 {
        sched.submit(Request {
            id,
            doc: doc.clone(),
            query: query.clone(),
            max_new: 2,
            opts: ApbOptions::default(),
            class: Default::default(),
        }).expect("submit");
        sched.run_all().expect("run");
    }
    assert!(!sched.completed[0].prefill.prefix_hit);
    assert!(sched.completed[1].prefill.prefix_hit);
    assert!(sched.completed[2].prefill.prefix_hit);
    // Hits decode the exact cold tokens.
    assert_eq!(sched.completed[1].tokens, sched.completed[0].tokens);
    assert_eq!(sched.completed[2].tokens, sched.completed[0].tokens);
    let m = sched.metrics();
    assert_eq!(m.prefix_hits, 2);
    assert!(m.prefix_bytes_saved > 0);
    let cold = m.ttft_cold.expect("one cold request");
    let warm = m.ttft_warm.expect("two warm requests");
    assert_eq!(cold.n, 1);
    assert_eq!(warm.n, 2);
    // Best warm sample vs the cold miss (robust to a one-off scheduler
    // hiccup on a loaded CI machine; the structural asserts above pin the
    // mechanism either way).
    assert!(warm.min < cold.min,
            "warm TTFT {:.3}ms must beat cold {:.3}ms — the hit skips the \
             whole document pass", warm.min * 1e3, cold.min * 1e3);
    // Every request still went through chunked admission (warm = 1 step).
    assert!(m.prefill_chunks.min >= 1.0);
}
