//! Suspend/resume bit-identity — the serving-invariant lockdown for the
//! preemption seam (docs/ADR-006-slo-scheduling.md): parking an in-flight
//! resumable prefill with `Cluster::prefill_suspend` and reviving it with
//! `Cluster::prefill_resume` must be unobservable in everything but wall
//! time. The query-chunk and decode logits, the per-label CommMeter bytes
//! AND rounds, and the per-host KV-pool bytes must be bit-identical to an
//! uninterrupted prefill — for every `AttnMethod`, under both drivers,
//! suspending at EVERY chunk boundary (quiescent and permit-captive alike),
//! and with a whole OTHER prefill interposed while parked.
//!
//! Runs on the native SimEngine (non-skipping tier-1; prints `APB-RUN`).

use apb::cluster::Interconnect;
use apb::config::{ApbOptions, AttnMethod, Config};
use apb::coordinator::{Cluster, Driver};
use apb::util::rng::Rng;
use apb::util::tensor::Tensor;

const LABELS: [&str; 3] =
    [Interconnect::KV_LABEL, Interconnect::ATT_LABEL, Interconnect::RING_LABEL];

fn request(cfg: &Config, seed: u64) -> (Vec<i32>, Vec<i32>) {
    let mut rng = Rng::new(seed);
    let doc: Vec<i32> = (0..cfg.apb.doc_len())
        .map(|_| rng.range(1, cfg.model.vocab_size as i64) as i32)
        .collect();
    let query: Vec<i32> = (0..cfg.apb.query_len)
        .map(|_| rng.range(1, cfg.model.vocab_size as i64) as i32)
        .collect();
    (doc, query)
}

/// Everything suspension must leave untouched. Wall-clock timing is
/// excluded on purpose — latency is the one thing parking MAY change.
#[derive(Debug, PartialEq)]
struct Fingerprint {
    chunk_logits: Vec<f32>,
    step_logits: Vec<f32>,
    /// (bytes, rounds) per meter label after the whole scenario.
    comm: Vec<(u64, u64)>,
    pool_bytes: Vec<usize>,
}

fn fingerprint(cluster: &Cluster, query: &[i32]) -> Fingerprint {
    let vocab = cluster.cfg.model.vocab_size;
    let chunk = cluster.decode_query_chunk(1, query).expect("query chunk");
    let tok = Tensor::argmax_row(&chunk.logits[chunk.logits.len() - vocab..]) as i32;
    let step = cluster.decode_step_batch(&[(1, tok)]).expect("decode step");
    let m = &cluster.fabric.meter;
    Fingerprint {
        chunk_logits: chunk.logits,
        step_logits: step.logits[0].1.clone(),
        comm: LABELS.iter().map(|l| (m.bytes_for(l), m.rounds_for(l))).collect(),
        pool_bytes: cluster
            .pool_stats()
            .expect("pool stats")
            .iter()
            .map(|s| s.bytes_used)
            .collect(),
    }
}

struct Outcome {
    fp: Fingerprint,
    n_steps: usize,
    /// Suspensions that landed on a fabric-quiescent boundary (permit
    /// released) vs. ones that held the permit captive mid-collective.
    quiet: usize,
    captive: usize,
}

/// One scenario on a fresh cluster: prefill session 1 with `chunk_tokens =
/// ct`, optionally suspending AND resuming at every single chunk boundary,
/// then decode (query chunk + one batched step). On captive boundaries the
/// scenario also proves the permit is really held: a rival `prefill_begin`
/// must be rejected without touching any host.
fn run(driver: Driver, method: AttnMethod, ct: usize, suspend_every: bool) -> Outcome {
    let cfg = Config::sim_tiny().with_method(method);
    let cluster = Cluster::start_with(&cfg, driver).expect("cluster");
    let (doc, query) = request(&cfg, 0x5EED);
    let opts = ApbOptions { method, chunk_tokens: Some(ct), ..Default::default() };
    let mut p = cluster.prefill_begin(1, &doc, &query, &opts).expect("begin");
    let n_steps = p.n_steps();
    let (mut quiet, mut captive) = (0usize, 0usize);
    loop {
        if suspend_every {
            let done = p.steps_done();
            let was_quiescent = p.fabric_quiescent();
            let s = cluster.prefill_suspend(p).expect("suspend");
            assert_eq!(s.sid(), 1);
            assert_eq!(s.steps_done(), done);
            assert_eq!(s.n_steps(), n_steps);
            assert_eq!(
                s.holds_permit(),
                !was_quiescent,
                "{} ct={ct} step {done}: the permit is released iff the \
                 boundary is fabric-quiescent",
                method.name()
            );
            if s.holds_permit() {
                captive += 1;
                // A captive permit keeps admission closed: the rival fails
                // at the permit claim, before any host command.
                let Err(err) = cluster.prefill_begin(9, &doc, &query, &opts) else {
                    panic!("captive permit must reject a rival prefill");
                };
                assert!(
                    format!("{err:#}").contains("already in flight"),
                    "captive-permit rejection must name the in-flight session"
                );
            } else {
                quiet += 1;
            }
            let Ok(revived) = cluster.prefill_resume(s) else {
                panic!("{} ct={ct} step {done}: resume must reclaim the slot",
                       method.name());
            };
            p = revived;
            assert_eq!(p.steps_done(), done, "resume must not lose progress");
        }
        if cluster.prefill_step(&mut p).expect("step").is_some() {
            break;
        }
    }
    Outcome { fp: fingerprint(&cluster, &query), n_steps, quiet, captive }
}

#[test]
fn suspend_resume_bit_identity_all_methods_both_drivers() {
    println!("APB-RUN suspend_resume backend=sim");
    for method in AttnMethod::ALL {
        for driver in [Driver::Sequential, Driver::Threaded] {
            for ct in [1usize, 5] {
                let base = run(driver, method, ct, false);
                assert!(base.fp.chunk_logits.iter().all(|x| x.is_finite()));
                let split = run(driver, method, ct, true);
                assert_eq!(
                    split.fp, base.fp,
                    "{} {:?} ct={ct}: suspending at every chunk boundary \
                     changed logits, comm or pool state",
                    method.name(), driver
                );
                // Every boundary was suspended exactly once.
                assert_eq!(split.quiet + split.captive, split.n_steps);
                // The fabric structure decides which boundaries hold the
                // permit: APB's compressed-block gather and Ring's rotations
                // stay open across steps; StarAttn passes nothing and Dense
                // never touches the fabric, so they park permit-free at
                // every boundary.
                match method {
                    AttnMethod::Apb | AttnMethod::RingAttn => {
                        assert!(split.captive > 0 && split.quiet > 0,
                                "{} ct={ct}: expected both boundary kinds",
                                method.name());
                    }
                    AttnMethod::StarAttn | AttnMethod::Dense => {
                        assert_eq!(split.captive, 0,
                                   "{} posts no fabric rounds", method.name());
                    }
                }
            }
        }
    }
}

/// A quiescent suspension releases the prefill permit, so a whole OTHER
/// session can admit — begin, run every chunk, finish, freeze KV — while
/// the first sits parked; resuming then yields the exact same logits, comm
/// totals and pool bytes as running the two prefills back to back. This is
/// the precise seam `Scheduler::maybe_preempt` swaps requests through.
fn interpose(driver: Driver, split: bool) -> Fingerprint {
    let cfg = Config::sim_tiny();
    let cluster = Cluster::start_with(&cfg, driver).expect("cluster");
    let (doc, query) = request(&cfg, 0xD0C);
    let (doc2, query2) = request(&cfg, 0x0DD);
    let opts = ApbOptions { chunk_tokens: Some(4), ..Default::default() };
    if split {
        let mut p = cluster.prefill_begin(1, &doc, &query, &opts).expect("begin");
        let target = p.n_steps() / 2;
        while p.steps_done() < target || !p.fabric_quiescent() {
            assert!(
                cluster.prefill_step(&mut p).expect("step").is_none(),
                "no quiescent boundary found past the midpoint"
            );
        }
        let s = cluster.prefill_suspend(p).expect("suspend");
        assert!(!s.holds_permit(), "quiescent suspend must release the permit");
        cluster.prefill_session(7, &doc2, &query2, &opts).expect("interposed");
        let Ok(mut p) = cluster.prefill_resume(s) else {
            panic!("slot must be free after the interposed prefill finished")
        };
        while cluster.prefill_step(&mut p).expect("step").is_none() {}
    } else {
        cluster.prefill_session(1, &doc, &query, &opts).expect("prefill 1");
        cluster.prefill_session(7, &doc2, &query2, &opts).expect("prefill 7");
    }
    // Fingerprint decodes session 1; session 7's logits are checked too so
    // the interposed prefill itself is value-verified, not just no-panic.
    let chunk7 = cluster.decode_query_chunk(7, &query2).expect("chunk 7");
    assert!(chunk7.logits.iter().all(|x| x.is_finite()));
    let mut fp = fingerprint(&cluster, &query);
    fp.chunk_logits.extend(chunk7.logits);
    fp
}

#[test]
fn quiescent_suspension_admits_an_interposed_prefill() {
    println!("APB-RUN suspend_interpose backend=sim");
    for driver in [Driver::Sequential, Driver::Threaded] {
        let base = interpose(driver, false);
        let split = interpose(driver, true);
        assert_eq!(split, base,
                   "{driver:?}: a prefill interposed through the parked seam \
                    diverged from back-to-back execution");
    }
}

#[test]
fn resume_backs_off_while_a_rival_holds_the_slot() {
    // The scheduler's re-park path: `prefill_resume` hands the token back
    // untouched when another prefill owns the one-at-a-time slot, and the
    // parked session still completes bit-identically afterwards.
    println!("APB-RUN suspend_backoff backend=sim");
    let cfg = Config::sim_tiny();
    let cluster = Cluster::start(&cfg).expect("cluster");
    let (doc, query) = request(&cfg, 0xFADE);
    let opts = ApbOptions { chunk_tokens: Some(8), ..Default::default() };
    let mut p = cluster.prefill_begin(1, &doc, &query, &opts).expect("begin");
    cluster.prefill_step(&mut p).expect("step");
    assert!(p.fabric_quiescent(), "APB's first pre op opens no round");
    let s = cluster.prefill_suspend(p).expect("suspend");
    assert!(!s.holds_permit());

    // A rival takes the slot; the parked token must bounce, intact.
    let mut rival = cluster.prefill_begin(2, &doc, &query, &opts).expect("rival");
    let s = match cluster.prefill_resume(s) {
        Ok(_) => panic!("resume must fail while session 2 holds the slot"),
        Err(s) => s,
    };
    assert_eq!((s.sid(), s.steps_done()), (1, 1), "bounced token untouched");

    while cluster.prefill_step(&mut rival).expect("rival step").is_none() {}
    let Ok(mut p) = cluster.prefill_resume(s) else {
        panic!("slot is free again once the rival finished")
    };
    while cluster.prefill_step(&mut p).expect("step").is_none() {}

    // Same (doc, query) in both sessions: the parked-then-resumed KV must
    // decode EXACTLY like the rival's uninterrupted one.
    let c1 = cluster.decode_query_chunk(1, &query).expect("chunk 1");
    let c2 = cluster.decode_query_chunk(2, &query).expect("chunk 2");
    assert_eq!(c1.logits, c2.logits,
               "interrupted and uninterrupted prefills of the same request \
                must be indistinguishable");
}

#[test]
fn suspend_rejects_a_finished_prefill() {
    println!("APB-RUN suspend_finished backend=sim");
    let cfg = Config::sim_tiny();
    let cluster = Cluster::start(&cfg).expect("cluster");
    let (doc, query) = request(&cfg, 0xF1ED);
    let opts = ApbOptions::default();
    let mut p = cluster.prefill_begin(1, &doc, &query, &opts).expect("begin");
    while cluster.prefill_step(&mut p).expect("step").is_none() {}
    let err = match cluster.prefill_suspend(p) {
        Ok(_) => panic!("a finished prefill must not be suspendable"),
        Err(e) => e,
    };
    assert!(format!("{err:#}").contains("nothing to suspend"),
            "finished prefill must be rejected with a diagnostic");
}
