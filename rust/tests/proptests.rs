//! Randomized property tests (proptest is unavailable offline; these use
//! the in-tree RNG with many seeded cases per property).

use apb::cluster::collectives::{Collective, CommMeter, RingExchange};
use apb::kvcache::{KvPool, SessionId};
use apb::util::json::Json;
use apb::util::rng::Rng;
use apb::util::stats::{percentile, summarize};
use apb::util::tensor::{merge_partials, top_lp_indices, Tensor};

const CASES: usize = 200;

fn rand_tensor(rng: &mut Rng, shape: Vec<usize>) -> Tensor {
    let n: usize = shape.iter().product();
    let data = (0..n).map(|_| rng.normal() as f32).collect();
    Tensor::new(shape, data).unwrap()
}

/// Dense softmax over explicit per-host key sets — the oracle for the
/// merge property.
fn dense_softmax(q_logits: &[Vec<f32>], values: &[Vec<f32>]) -> f32 {
    // Single (row, head, dim=1) problem: logits per key, scalar values.
    let all_logits: Vec<f32> = q_logits.iter().flatten().copied().collect();
    let all_vals: Vec<f32> = values.iter().flatten().copied().collect();
    let m = all_logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut denom = 0.0;
    let mut acc = 0.0;
    for (&l, &v) in all_logits.iter().zip(&all_vals) {
        let w = (l - m).exp();
        denom += w;
        acc += w * v;
    }
    acc / denom
}

#[test]
fn prop_merge_partials_equals_dense_softmax() {
    // For arbitrary host partitions of a key set, partial-softmax + LSE
    // merge must equal the dense softmax (DESIGN.md invariant 4).
    let mut rng = Rng::new(0xAB);
    for case in 0..CASES {
        let hosts = 1 + rng.below(6) as usize;
        let mut logits = Vec::new();
        let mut vals = Vec::new();
        let mut outs = Vec::new();
        let mut lses = Vec::new();
        for _ in 0..hosts {
            let k = 1 + rng.below(9) as usize;
            let l: Vec<f32> = (0..k).map(|_| (rng.normal() * 3.0) as f32).collect();
            let v: Vec<f32> = (0..k).map(|_| rng.normal() as f32).collect();
            // Per-host partial: softmax over its own keys + lse.
            let m = l.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let denom: f32 = l.iter().map(|x| (x - m).exp()).sum();
            let out: f32 = l
                .iter()
                .zip(&v)
                .map(|(x, y)| (x - m).exp() * y)
                .sum::<f32>()
                / denom;
            outs.push(Tensor::new(vec![1, 1, 1], vec![out]).unwrap());
            lses.push(Tensor::new(vec![1, 1], vec![m + denom.ln()]).unwrap());
            logits.push(l);
            vals.push(v);
        }
        let merged = merge_partials(&outs, &lses);
        let want = dense_softmax(&logits, &vals);
        assert!(
            (merged.data[0] - want).abs() < 1e-4,
            "case {case}: merged {} vs dense {want}",
            merged.data[0]
        );
    }
}

#[test]
fn prop_top_lp_matches_naive_selection() {
    let mut rng = Rng::new(0xCD);
    for _ in 0..CASES {
        let n = 1 + rng.below(64) as usize;
        let kh = 1 + rng.below(4) as usize;
        let l_p = 1 + rng.below(n as u64) as usize;
        let scores = rand_tensor(&mut rng, vec![n, kh]);
        let got = top_lp_indices(&scores, l_p);
        for j in 0..kh {
            // Naive: sort all indices by score desc, take l_p, sort asc.
            let mut idx: Vec<usize> = (0..n).collect();
            idx.sort_by(|&a, &b| {
                scores.at2(b, j).partial_cmp(&scores.at2(a, j)).unwrap()
                    .then(a.cmp(&b))
            });
            let mut want = idx[..l_p].to_vec();
            want.sort_unstable();
            assert_eq!(got[j], want);
        }
    }
}

#[test]
fn prop_tensor_concat_slice_roundtrip() {
    let mut rng = Rng::new(0xEF);
    for _ in 0..CASES {
        let rows_a = 1 + rng.below(10) as usize;
        let rows_b = 1 + rng.below(10) as usize;
        let cols = 1 + rng.below(8) as usize;
        let a = rand_tensor(&mut rng, vec![rows_a, cols]);
        let b = rand_tensor(&mut rng, vec![rows_b, cols]);
        let c = Tensor::concat_rows(&[&a, &b]);
        assert_eq!(c.slice_rows(0, rows_a), a);
        assert_eq!(c.slice_rows(rows_a, rows_a + rows_b), b);
        // Gather identity permutation reproduces the tensor.
        let idx: Vec<usize> = (0..c.shape[0]).collect();
        assert_eq!(c.gather_rows(&idx), c);
    }
}

#[test]
fn prop_json_roundtrip_arbitrary_values() {
    let mut rng = Rng::new(0x11);
    fn gen(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 0),
            2 => Json::Num((rng.normal() * 1e3).round()),
            3 => Json::Str(format!("s{}-\"esc\"\n{}", rng.below(100), rng.below(10))),
            4 => Json::Arr((0..rng.below(5)).map(|_| gen(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    for _ in 0..CASES {
        let v = gen(&mut rng, 3);
        let parsed = Json::parse(&v.dumps()).unwrap();
        assert_eq!(parsed, v);
        let pretty = Json::parse(&v.pretty()).unwrap();
        assert_eq!(pretty, v);
    }
}

#[test]
fn prop_percentiles_bounded_and_monotone() {
    let mut rng = Rng::new(0x22);
    for _ in 0..CASES {
        let n = 1 + rng.below(50) as usize;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let s = summarize(&xs);
        assert!(s.min <= s.p50 && s.p50 <= s.p90 && s.p90 <= s.p95
                && s.p95 <= s.p99 && s.p99 <= s.max);
        let mut sorted = xs.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for q in [0.0, 0.25, 0.5, 0.75, 1.0] {
            let p = percentile(&sorted, q);
            assert!(p >= s.min && p <= s.max);
        }
    }
}

#[test]
fn prop_collective_rank_order_under_random_scheduling() {
    // Heavier-weight variant of the fabric test: random host counts,
    // random per-round delays, many rounds; results must always arrive
    // complete and in rank order.
    let mut seed_rng = Rng::new(0x33);
    for _ in 0..8 {
        let n = 2 + seed_rng.below(5) as usize;
        let rounds = 10;
        let c = std::sync::Arc::new(Collective::new(
            n,
            std::sync::Arc::new(CommMeter::default()),
        ));
        let mut handles = Vec::new();
        for rank in 0..n {
            let c = std::sync::Arc::clone(&c);
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(rank as u64 * 7 + 1);
                for round in 0..rounds {
                    if rng.below(2) == 0 {
                        std::thread::yield_now();
                    }
                    let t = Tensor::new(vec![1], vec![(round * n + rank) as f32])
                        .unwrap();
                    let all = c.all_gather(rank, (t.clone(), t));
                    for (r, (o, _)) in all.iter().enumerate() {
                        assert_eq!(o.data[0] as usize, round * n + r);
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }
}

#[test]
fn prop_ring_all_pass_rotation_covers_every_pair_once() {
    // The RingAttn rotation invariant: forwarding the received block for
    // N-1 exchange rounds delivers every origin's block to every other
    // rank EXACTLY once, under arbitrary host counts and thread timing.
    // (One "round" here = the full N-1-step all-pass rotation, as one
    // prefill layer runs it.)
    let mut seed_rng = Rng::new(0x66);
    for case in 0..6usize {
        let n = 2 + seed_rng.below(5) as usize;
        let meter = std::sync::Arc::new(CommMeter::default());
        let ring = std::sync::Arc::new(RingExchange::labeled(
            n,
            "ring",
            std::sync::Arc::clone(&meter),
        ));
        let mut handles = Vec::new();
        for rank in 0..n {
            let ring = std::sync::Arc::clone(&ring);
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new((case * 31 + rank) as u64 + 5);
                // Payload carries its origin rank; receivers log every
                // (origin, receiver) delivery.
                let mut held = Tensor::new(vec![1], vec![rank as f32]).unwrap();
                let mut seen: Vec<(usize, usize)> = Vec::new();
                for _ in 1..n {
                    if rng.below(2) == 0 {
                        std::thread::yield_now();
                    }
                    held = ring.exchange(rank, held);
                    seen.push((held.data[0] as usize, rank));
                }
                seen
            }));
        }
        let mut all: Vec<(usize, usize)> = Vec::new();
        for h in handles {
            all.extend(h.join().unwrap());
        }
        all.sort_unstable();
        // Exactly the (src, dst) pairs with src != dst, each once.
        let mut want: Vec<(usize, usize)> = Vec::new();
        for src in 0..n {
            for dst in 0..n {
                if src != dst {
                    want.push((src, dst));
                }
            }
        }
        assert_eq!(all, want, "case {case} n={n}");
        // Each rank sends once per exchange step.
        assert_eq!(meter.rounds_for("ring"), (n * (n - 1)) as u64);
    }
}

#[test]
fn prop_comm_meter_label_totals_are_additive() {
    // bytes_total/rounds_total must equal the sum over labels for any
    // interleaving of contributions on the kv/att/ring labels — the
    // invariant the per-method comm tables rely on when splitting one
    // fabric meter into per-collective columns.
    const LABELS: [&str; 3] = ["kv", "att", "ring"];
    let mut rng = Rng::new(0x77);
    for _ in 0..40 {
        let meter = std::sync::Arc::new(CommMeter::default());
        let mut shadow = std::collections::BTreeMap::<&str, (u64, u64)>::new();
        for _ in 0..rng.below(60) {
            let label = LABELS[rng.below(3) as usize];
            let bytes = rng.below(1 << 16);
            meter.add(label, bytes);
            let e = shadow.entry(label).or_insert((0, 0));
            e.0 += bytes;
            e.1 += 1;
        }
        let sum_bytes: u64 = LABELS.iter().map(|l| meter.bytes_for(l)).sum();
        let sum_rounds: u64 = LABELS.iter().map(|l| meter.rounds_for(l)).sum();
        assert_eq!(meter.bytes_total(), sum_bytes);
        assert_eq!(meter.rounds_total(), sum_rounds);
        for (label, (b, r)) in shadow {
            assert_eq!(meter.bytes_for(label), b);
            assert_eq!(meter.rounds_for(label), r);
        }
        meter.reset();
        assert_eq!(meter.bytes_total(), 0);
        assert_eq!(meter.rounds_total(), 0);
    }
}

#[test]
fn prop_kv_pool_accounting_under_random_alloc_free() {
    // Serving invariants of the session-slot pool under arbitrary
    // alloc/append/free interleavings:
    //  * resident count never exceeds the slot count;
    //  * alloc succeeds iff a slot is free (or the session is resident);
    //  * bytes_used always equals the sum over resident sessions of their
    //    appended rows (model-checked against a shadow map);
    //  * a failed alloc (exhaustion) changes nothing.
    let (kh, hd) = (2usize, 4usize);
    let row_bytes = 2 * kh * hd * 4; // K and V, f32
    let mk_rows = |n: usize| {
        Tensor::new(vec![n, kh, hd], vec![0.5; n * kh * hd]).unwrap()
    };
    let mut rng = Rng::new(0x55);
    for _ in 0..40 {
        let slots = 1 + rng.below(4) as usize;
        let cache_max = 4 + rng.below(8) as usize;
        let mut pool = KvPool::new(slots, 1, cache_max, kh, hd);
        let mut shadow: std::collections::BTreeMap<SessionId, usize> =
            Default::default();
        for _ in 0..200 {
            let sid = rng.below(6);
            match rng.below(3) {
                0 => {
                    let was_resident = shadow.contains_key(&sid);
                    match pool.alloc(sid) {
                        Ok(_) => {
                            assert!(was_resident || shadow.len() < slots,
                                    "alloc must fail when full");
                            shadow.insert(sid, 0); // alloc resets the cache
                        }
                        Err(e) => {
                            assert!(!was_resident && shadow.len() == slots,
                                    "spurious exhaustion: {e:#}");
                        }
                    }
                }
                1 => {
                    if let Some(rows) = shadow.get_mut(&sid) {
                        let n = 1 + rng.below(3) as usize;
                        let r = mk_rows(n);
                        if *rows + n <= cache_max {
                            pool.get_mut(sid).unwrap().append(0, &r, &r).unwrap();
                            *rows += n;
                        } else {
                            assert!(pool.get_mut(sid).unwrap().append(0, &r, &r)
                                        .is_err());
                        }
                    } else {
                        assert!(pool.get_mut(sid).is_err());
                    }
                }
                _ => {
                    assert_eq!(pool.free(sid), shadow.remove(&sid).is_some());
                }
            }
            assert_eq!(pool.resident(), shadow.len());
            assert!(pool.resident() <= pool.n_slots());
            let want_bytes: usize = shadow.values().map(|r| r * row_bytes).sum();
            assert_eq!(pool.bytes_used(), want_bytes);
            let mut sids = pool.resident_sids();
            sids.sort_unstable();
            assert_eq!(sids, shadow.keys().copied().collect::<Vec<_>>());
        }
    }
}

#[test]
fn prop_rng_python_parity_random_scores() {
    // The rust random-selector scores must equal the python twin formula
    // for arbitrary (seed, layer, host, head, idx) tuples. The python side
    // pins the same splitmix64 vectors in test_retaining.py.
    use apb::util::rng::{random_score, splitmix64};
    let mut rng = Rng::new(0x44);
    for _ in 0..CASES {
        let seed = rng.below(1 << 20);
        let layer = rng.below(64);
        let host = rng.below(16);
        let head = rng.below(8);
        let idx = rng.below(4096);
        let key = (seed << 40) ^ (layer << 28) ^ (host << 16) ^ (head << 12) ^ idx;
        let want = splitmix64(key) as f64 / 2f64.powi(64);
        let got = random_score(seed, layer, host, head, idx) as f64;
        assert!((got - want).abs() < 1e-7);
    }
}
