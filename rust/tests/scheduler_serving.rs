//! Scheduler + serving-path integration: continuous batching over session
//! slots. Runs on the native SimEngine by default (non-skipping); uses
//! PJRT artifacts when present + enabled.

use apb::cluster::Interconnect;
use apb::config::{ApbOptions, AttnMethod};
use apb::coordinator::scheduler::{Request, Scheduler};
use apb::coordinator::{Cluster, SessionId};
use apb::ruler::{gen_instance, TaskKind};
use apb::util::rng::Rng;
use apb::util::tensor::Tensor;

fn cluster() -> (apb::config::Config, Cluster) {
    let cfg = apb::load_config_or_sim("tiny").expect("config");
    println!("APB-RUN scheduler_serving backend={}", cfg.backend.name());
    let c = Cluster::start(&cfg).expect("cluster start");
    (cfg, c)
}

fn request(cfg: &apb::config::Config, id: u64, rng: &mut Rng) -> Request {
    let inst = gen_instance(cfg, TaskKind::SingleNiah, rng);
    Request { id, doc: inst.doc, query: inst.query, max_new: 2,
              opts: ApbOptions::default(), class: Default::default() }
}

/// Residency-overlap assertions need >= `n` KV slots. Sim configs ship 4,
/// but a PJRT artifact manifest may pin `max_resident` to the paper's 1 —
/// those skip (announced, for the CI skip audit) rather than fail.
fn has_slots(cfg: &apb::config::Config, n: usize, test: &str) -> bool {
    if cfg.apb.max_resident < n {
        println!("APB-SKIP {test}: config '{}' has max_resident {} < {n}",
                 cfg.name, cfg.apb.max_resident);
        return false;
    }
    true
}

/// Greedy generation for one resident session through the session API —
/// the session-level twin of `Cluster::generate` (query-chunk pass, then
/// one batched step per token).
fn gen_session(cluster: &Cluster, sid: SessionId, query: &[i32], max_new: usize)
               -> Vec<i32> {
    let vocab = cluster.cfg.model.vocab_size;
    let chunk = cluster.decode_query_chunk(sid, query).expect("chunk");
    let mut token = Tensor::argmax_row(&chunk.logits[chunk.logits.len() - vocab..]) as i32;
    let mut tokens = Vec::with_capacity(max_new);
    for step in 0..max_new {
        tokens.push(token);
        if step + 1 == max_new {
            break;
        }
        let rep = cluster.decode_step_batch(&[(sid, token)]).expect("step");
        token = Tensor::argmax_row(&rep.logits[0].1) as i32;
    }
    tokens
}

#[test]
fn fifo_order_and_complete_metrics() {
    let (cfg, cluster) = cluster();
    let mut sched = Scheduler::new(&cluster, 16);
    let mut rng = Rng::new(1);
    // Enough decode budget that a session is still decoding while the next
    // request's chunked admission runs — the overlap the peak_resident
    // assertion below measures.
    let max_new = 4;
    for id in 0..3 {
        sched
            .submit(Request { max_new, ..request(&cfg, id, &mut rng) })
            .unwrap();
    }
    let done = sched.run_all().unwrap();
    assert_eq!(done, 3);
    assert_eq!(sched.queued(), 0);
    assert_eq!(sched.resident(), 0, "all sessions retired");
    assert!(sched.prefill_in_flight().is_none(), "no admission left behind");
    // FIFO completion order.
    let ids: Vec<u64> = sched.completed.iter().map(|r| r.id).collect();
    assert_eq!(ids, vec![0, 1, 2]);
    for r in &sched.completed {
        assert_eq!(r.tokens.len(), max_new);
        assert!(r.speed_tok_per_s > 0.0);
        assert!(r.e2e_s >= r.prefill.wall_seconds);
        assert!(r.ttft_s >= r.queue_wait_s, "TTFT includes queue wait");
        assert!(r.decode_comm_bytes > 0,
                "decode AllGather traffic must be metered per request");
        assert!(r.prefill_chunks >= 1,
                "every request is admitted through the chunk driver");
    }
    let m = sched.metrics();
    assert_eq!(m.n_requests, 3);
    assert_eq!(m.total_tokens, 3 * max_new);
    assert!(m.prefill.p50 > 0.0 && m.e2e.p99 >= m.e2e.p50);
    assert!(m.ttft.p50 > 0.0 && m.decode_comm_bytes > 0);
    assert!(m.prefill_chunks.min >= 1.0);
    if cfg.apb.max_resident >= 2 {
        assert!(m.peak_resident >= 2, "requests must share the cluster");
    }
}

#[test]
fn decode_ticks_proceed_between_prefill_chunks() {
    // THE stall-free acceptance test: while a newly admitted long request's
    // prefill is in flight, a resident session must emit one token on EVERY
    // scheduler tick — no stall longer than one chunk.
    let cfg = apb::load_config_or_sim("tiny").expect("config");
    println!("APB-RUN stall_free backend={}", cfg.backend.name());
    if !has_slots(&cfg, 2, "decode_ticks_proceed_between_prefill_chunks") {
        return;
    }
    let cluster = Cluster::start(&cfg).expect("cluster");
    let mut sched = Scheduler::new(&cluster, 8);
    let mut rng = Rng::new(61);

    // Request A: the largest decode budget the sim-tiny KV slot can hold
    // (cache_max reserves a `max_new_tokens` decode tail; the query-chunk
    // pass seeds token 1 without appending, so max_new_tokens + 1 rows
    // fit) — so A stays resident and decoding well into B's admission.
    let a_budget = cfg.apb.max_new_tokens + 1;
    let a = gen_instance(&cfg, TaskKind::SingleNiah, &mut rng);
    sched
        .submit(Request { id: 0, doc: a.doc, query: a.query, max_new: a_budget,
                          opts: ApbOptions::default(), class: Default::default() })
        .unwrap();
    // Drive until A is decoding (its own admission finished).
    while sched.prefill_in_flight().is_some() || sched.active_token_counts().is_empty() {
        assert!(sched.step().unwrap());
    }

    // Request B: small chunks -> its admission spans many ticks.
    let b = gen_instance(&cfg, TaskKind::SingleNiah, &mut rng);
    sched
        .submit(Request {
            id: 1,
            doc: b.doc,
            query: b.query,
            max_new: 2,
            opts: ApbOptions { chunk_tokens: Some(4), ..Default::default() },
            class: Default::default(),
        })
        .unwrap();

    let a_tokens = |s: &Scheduler<'_>| {
        s.active_token_counts().iter().find(|&&(id, _)| id == 0).map(|&(_, n)| n)
    };
    let mut asserted_ticks = 0;
    loop {
        let before = a_tokens(&sched);
        assert!(sched.step().unwrap());
        let inflight = sched.prefill_in_flight();
        if let (Some(nb), Some((rid, done, total))) = (before, inflight) {
            assert_eq!(rid, 1);
            assert!(done >= 1 && done <= total);
            if let Some(na) = a_tokens(&sched) {
                assert_eq!(na, nb + 1,
                           "resident session stalled during admission chunk \
                            {done}/{total}");
                asserted_ticks += 1;
            }
        }
        if inflight.is_none() {
            break;
        }
    }
    // A emits one token per tick from 2 up to its budget while B admits
    // (52 chunk steps at ct=4), so every tick of A's remaining lifetime is
    // asserted above.
    assert!(asserted_ticks >= 4,
            "B's chunked admission must interleave with A's decode over multiple \
             ticks (saw {asserted_ticks})");

    sched.run_all().unwrap();
    assert_eq!(sched.completed.len(), 2);
    let resp = |id: u64| sched.completed.iter().find(|r| r.id == id).unwrap();
    assert_eq!(resp(1).tokens.len(), 2);
    assert!(resp(1).prefill_chunks > resp(0).prefill_chunks,
            "smaller chunk_tokens must mean more admission steps ({} vs {})",
            resp(1).prefill_chunks, resp(0).prefill_chunks);
}

#[test]
fn mixed_method_traffic_is_grouped_per_decode_path() {
    // One request per AttnMethod, served concurrently: the scheduler must
    // split each decode tick into the distributed group (APB/Star/Ring —
    // one shared att AllGather batch) and the Dense group (host-0 local),
    // because Dense sessions never join collectives. A Dense-sized pool
    // accepts every method.
    let cfg = apb::load_config_or_sim("tiny").expect("config").with_method(AttnMethod::Dense);
    println!("APB-RUN mixed_methods backend={}", cfg.backend.name());
    let cluster = Cluster::start(&cfg).expect("cluster start");
    let mut sched = Scheduler::new(&cluster, 16);
    let mut rng = Rng::new(9);
    for (id, method) in AttnMethod::ALL.into_iter().enumerate() {
        let inst = gen_instance(&cfg, TaskKind::SingleNiah, &mut rng);
        sched
            .submit(Request {
                id: id as u64,
                doc: inst.doc,
                query: inst.query,
                max_new: 3,
                opts: ApbOptions { method, ..Default::default() },
                class: Default::default(),
            })
            .unwrap();
    }
    let done = sched.run_all().unwrap();
    assert_eq!(done, AttnMethod::ALL.len());
    for r in &sched.completed {
        assert_eq!(r.tokens.len(), 3);
        let method = AttnMethod::ALL[r.id as usize];
        if method.distributed_decode() {
            assert!(r.decode_comm_bytes > 0,
                    "{} decode must use the att AllGather", method.name());
        } else {
            assert_eq!(r.decode_comm_bytes, 0,
                       "Dense decode must not communicate");
        }
    }
}

#[test]
fn backpressure_rejects_beyond_capacity() {
    let (cfg, cluster) = cluster();
    let mut sched = Scheduler::new(&cluster, 2);
    let mut rng = Rng::new(2);
    sched.submit(request(&cfg, 0, &mut rng)).unwrap();
    sched.submit(request(&cfg, 1, &mut rng)).unwrap();
    let err = sched.submit(request(&cfg, 2, &mut rng));
    assert!(err.is_err(), "third submit must hit backpressure");
    assert!(format!("{:#}", err.unwrap_err()).contains("backpressure"));
    // Draining frees capacity again.
    assert!(sched.step().unwrap());
    sched.submit(request(&cfg, 3, &mut rng)).unwrap();
    sched.run_all().unwrap();
    let ids: Vec<u64> = sched.completed.iter().map(|r| r.id).collect();
    assert_eq!(ids, vec![0, 1, 3]);
}

#[test]
fn per_request_isolation() {
    // Identical requests produce identical tokens even when interleaved
    // with different ones — no KV-cache leakage between requests.
    let (cfg, cluster) = cluster();
    let mut rng = Rng::new(3);
    let a = request(&cfg, 0, &mut rng);
    let b = request(&cfg, 1, &mut rng);
    let mut sched = Scheduler::new(&cluster, 8);
    sched.submit(a.clone()).unwrap();
    sched.submit(b).unwrap();
    sched.submit(Request { id: 2, ..a.clone() }).unwrap();
    sched.run_all().unwrap();
    assert_eq!(sched.completed[0].tokens, sched.completed[2].tokens,
               "same request must decode identically regardless of history");
}

#[test]
fn overlapping_sessions_match_sequential() {
    // The session-slot acceptance test: with >=2 sessions resident on the
    // cluster at once (the second admitted and prefilled BEFORE the first
    // finished decoding) and their decode steps interleaved in shared
    // batched passes, every request's tokens must be bit-identical to the
    // same requests run one-at-a-time on a fresh cluster.
    let cfg = apb::load_config_or_sim("tiny").expect("config");
    println!("APB-RUN scheduler_serving backend={}", cfg.backend.name());
    if !has_slots(&cfg, 2, "overlapping_sessions_match_sequential") {
        return;
    }
    let max_new = 4;
    let mut rng = Rng::new(41);
    let reqs: Vec<Request> = (0..3)
        .map(|id| {
            let inst = gen_instance(&cfg, TaskKind::SingleNiah, &mut rng);
            Request { id, doc: inst.doc, query: inst.query, max_new,
                      opts: ApbOptions::default(), class: Default::default() }
        })
        .collect();

    // Reference: run-to-completion on a fresh cluster, one at a time.
    let sequential: Vec<Vec<i32>> = {
        let c = Cluster::start(&cfg).expect("reference cluster");
        reqs.iter()
            .map(|r| {
                c.clear().unwrap();
                c.prefill(&r.doc, &r.query, &r.opts).unwrap();
                c.generate(&r.query, r.max_new).unwrap().tokens
            })
            .collect()
    };

    // Continuous batching on a fresh cluster.
    let c = Cluster::start(&cfg).expect("serving cluster");
    let mut sched = Scheduler::new(&c, 8);
    for r in &reqs {
        sched.submit(r.clone()).unwrap();
    }
    let done = sched.run_all().unwrap();
    assert_eq!(done, reqs.len());
    assert!(sched.peak_resident >= 2,
            "continuous batching must hold >= 2 sessions resident, saw {}",
            sched.peak_resident);
    for r in &sched.completed {
        assert_eq!(r.tokens, sequential[r.id as usize],
                   "request {} diverged between interleaved and sequential", r.id);
    }
}

#[test]
fn batched_decode_is_one_backend_pass_per_layer() {
    // One continuous-batching step over S sessions must cost exactly ONE
    // stacked decode pass per layer — observable as n_hosts × n_layers
    // attention-AllGather contributions, independent of S (a per-session
    // loop would contribute S× that).
    let (cfg, cluster) = cluster();
    if !has_slots(&cfg, 2, "batched_decode_is_one_backend_pass_per_layer") {
        return;
    }
    let mut rng = Rng::new(43);
    let a = gen_instance(&cfg, TaskKind::SingleNiah, &mut rng);
    let b = gen_instance(&cfg, TaskKind::SingleNiah, &mut rng);
    cluster.prefill_session(1, &a.doc, &a.query, &ApbOptions::default()).unwrap();
    cluster.prefill_session(2, &b.doc, &b.query, &ApbOptions::default()).unwrap();
    let c1 = cluster.decode_query_chunk(1, &a.query).unwrap();
    let c2 = cluster.decode_query_chunk(2, &b.query).unwrap();
    assert!(c1.comm_bytes > 0, "chunk decode comm must be metered");
    let vocab = cfg.model.vocab_size;
    let t1 = Tensor::argmax_row(&c1.logits[c1.logits.len() - vocab..]) as i32;
    let t2 = Tensor::argmax_row(&c2.logits[c2.logits.len() - vocab..]) as i32;

    let per_step = (cfg.apb.n_hosts * cfg.model.n_layers) as u64;
    let r0 = cluster.fabric.meter.rounds_for(Interconnect::ATT_LABEL);
    let rep = cluster.decode_step_batch(&[(1, t1), (2, t2)]).unwrap();
    let dr = cluster.fabric.meter.rounds_for(Interconnect::ATT_LABEL) - r0;
    assert_eq!(dr, per_step,
               "2-session batched step took {dr} att rounds, expected {per_step}");
    assert_eq!(rep.logits.len(), 2);
    assert_eq!(rep.logits[0].0, 1);
    assert_eq!(rep.logits[1].0, 2);
    assert!(rep.comm_bytes > 0, "batched decode comm must be metered");

    // And a single-session step costs the same number of rounds: the batch
    // dimension rides the same collectives rather than multiplying them.
    let r1 = cluster.fabric.meter.rounds_for(Interconnect::ATT_LABEL);
    cluster.decode_step_batch(&[(1, t1)]).unwrap();
    assert_eq!(cluster.fabric.meter.rounds_for(Interconnect::ATT_LABEL) - r1, per_step);
}

#[test]
fn kv_pool_exhaustion_is_backpressure_not_corruption() {
    // Prefilling more sessions than the pool has slots must fail with a
    // backpressure error — and leave every resident session's KV intact
    // (identical tokens to an uncontended run).
    let (cfg, cluster) = cluster();
    let slots = cfg.apb.max_resident;
    let mut rng = Rng::new(47);
    let inst = gen_instance(&cfg, TaskKind::SingleNiah, &mut rng);
    let max_new = 3;

    // Uncontended reference on a fresh cluster.
    let want = {
        let c = Cluster::start(&cfg).expect("reference cluster");
        c.prefill(&inst.doc, &inst.query, &ApbOptions::default()).unwrap();
        c.generate(&inst.query, max_new).unwrap().tokens
    };

    for sid in 1..=slots as u64 {
        cluster
            .prefill_session(sid, &inst.doc, &inst.query, &ApbOptions::default())
            .unwrap();
    }
    let err = cluster
        .prefill_session(slots as u64 + 1, &inst.doc, &inst.query,
                         &ApbOptions::default())
        .unwrap_err();
    assert!(format!("{err:#}").contains("backpressure"),
            "exhaustion must surface as backpressure, got: {err:#}");

    // Every resident session still decodes exactly the reference tokens.
    for sid in 1..=slots as u64 {
        assert_eq!(gen_session(&cluster, sid, &inst.query, max_new), want,
                   "session {sid} corrupted by the rejected admission");
    }

    // Freeing a slot re-opens admission.
    cluster.clear_session(1).unwrap();
    cluster
        .prefill_session(slots as u64 + 1, &inst.doc, &inst.query,
                         &ApbOptions::default())
        .unwrap();
    assert_eq!(gen_session(&cluster, slots as u64 + 1, &inst.query, max_new), want);
}

#[test]
fn legacy_generate_reports_decode_comm() {
    // Satellite: decode-path AllGather traffic must not vanish from the
    // legacy GenReport either.
    let (cfg, cluster) = cluster();
    let mut rng = Rng::new(53);
    let inst = gen_instance(&cfg, TaskKind::SingleNiah, &mut rng);
    cluster.prefill(&inst.doc, &inst.query, &ApbOptions::default()).unwrap();
    let gen = cluster.generate(&inst.query, 3).unwrap();
    assert!(gen.comm_bytes > 0, "GenReport.comm_bytes must meter decode traffic");
    // Prefill comm (compressed KV) and decode comm (attention partials)
    // are metered under separate labels.
    assert!(cluster.fabric.meter.bytes_for(Interconnect::KV_LABEL) > 0);
    assert!(cluster.fabric.meter.bytes_for(Interconnect::ATT_LABEL) >= gen.comm_bytes);
}
