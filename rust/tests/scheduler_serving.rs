//! Scheduler + serving-path integration. Runs on the native SimEngine by
//! default (non-skipping); uses PJRT artifacts when present + enabled.

use apb::config::ApbOptions;
use apb::coordinator::scheduler::{Request, Scheduler};
use apb::coordinator::Cluster;
use apb::ruler::{gen_instance, TaskKind};
use apb::util::rng::Rng;

fn cluster() -> (apb::config::Config, Cluster) {
    let cfg = apb::load_config_or_sim("tiny").expect("config");
    println!("APB-RUN scheduler_serving backend={}", cfg.backend.name());
    let c = Cluster::start(&cfg).expect("cluster start");
    (cfg, c)
}

fn request(cfg: &apb::config::Config, id: u64, rng: &mut Rng) -> Request {
    let inst = gen_instance(cfg, TaskKind::SingleNiah, rng);
    Request { id, doc: inst.doc, query: inst.query, max_new: 2,
              opts: ApbOptions::default() }
}

#[test]
fn fifo_order_and_complete_metrics() {
    let (cfg, cluster) = cluster();
    let mut sched = Scheduler::new(&cluster, 16);
    let mut rng = Rng::new(1);
    for id in 0..3 {
        sched.submit(request(&cfg, id, &mut rng)).unwrap();
    }
    let done = sched.run_all().unwrap();
    assert_eq!(done, 3);
    assert_eq!(sched.queued(), 0);
    // FIFO completion order.
    let ids: Vec<u64> = sched.completed.iter().map(|r| r.id).collect();
    assert_eq!(ids, vec![0, 1, 2]);
    for r in &sched.completed {
        assert_eq!(r.tokens.len(), 2);
        assert!(r.speed_tok_per_s > 0.0);
        assert!(r.e2e_s >= r.prefill.wall_seconds);
    }
    let m = sched.metrics();
    assert_eq!(m.n_requests, 3);
    assert_eq!(m.total_tokens, 6);
    assert!(m.prefill.p50 > 0.0 && m.e2e.p99 >= m.e2e.p50);
}

#[test]
fn backpressure_rejects_beyond_capacity() {
    let (cfg, cluster) = cluster();
    let mut sched = Scheduler::new(&cluster, 2);
    let mut rng = Rng::new(2);
    sched.submit(request(&cfg, 0, &mut rng)).unwrap();
    sched.submit(request(&cfg, 1, &mut rng)).unwrap();
    let err = sched.submit(request(&cfg, 2, &mut rng));
    assert!(err.is_err(), "third submit must hit backpressure");
    assert!(format!("{:#}", err.unwrap_err()).contains("backpressure"));
    // Draining frees capacity again.
    assert!(sched.step().unwrap());
    sched.submit(request(&cfg, 3, &mut rng)).unwrap();
    sched.run_all().unwrap();
    let ids: Vec<u64> = sched.completed.iter().map(|r| r.id).collect();
    assert_eq!(ids, vec![0, 1, 3]);
}

#[test]
fn per_request_isolation() {
    // Identical requests produce identical tokens even when interleaved
    // with different ones — no KV-cache leakage between requests.
    let (cfg, cluster) = cluster();
    let mut rng = Rng::new(3);
    let a = request(&cfg, 0, &mut rng);
    let b = request(&cfg, 1, &mut rng);
    let mut sched = Scheduler::new(&cluster, 8);
    sched.submit(a.clone()).unwrap();
    sched.submit(b).unwrap();
    sched.submit(Request { id: 2, ..a.clone() }).unwrap();
    sched.run_all().unwrap();
    assert_eq!(sched.completed[0].tokens, sched.completed[2].tokens,
               "same request must decode identically regardless of history");
}
