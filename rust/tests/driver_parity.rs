//! Driver parity — the acceptance gate of the threaded-hosts redesign
//! (docs/ADR-004-threaded-hosts.md): a cluster under [`Driver::Threaded`]
//! (one OS thread per host, genuinely rendezvousing collectives) must be
//! **bit-identical** to the [`Driver::Sequential`] oracle (leader-owned
//! workers, deterministic rank-order microstepping) in
//!
//! * the query-chunk and per-step decode logits,
//! * the per-label CommMeter bytes AND rounds (the drivers may never add,
//!   drop or resize a collective),
//! * the per-host KV-pool slot bytes,
//!
//! for every `AttnMethod`, across chunk sizes, and through mid-prefill
//! cancellation. A wedged threaded rank cannot hang the suite: the fabric's
//! rendezvous timeout converts a stuck round into a structured error, so a
//! deadlock shows up as a test FAILURE, not a CI timeout.
//!
//! Runs on the native SimEngine (non-skipping tier-1; prints `APB-RUN`).

use apb::cluster::Interconnect;
use apb::config::{ApbOptions, AttnMethod, Config};
use apb::coordinator::{Cluster, Driver};
use apb::util::rng::Rng;
use apb::util::tensor::Tensor;

const LABELS: [&str; 3] =
    [Interconnect::KV_LABEL, Interconnect::ATT_LABEL, Interconnect::RING_LABEL];

fn request(cfg: &Config, seed: u64) -> (Vec<i32>, Vec<i32>) {
    let mut rng = Rng::new(seed);
    let doc: Vec<i32> = (0..cfg.apb.doc_len())
        .map(|_| rng.range(1, cfg.model.vocab_size as i64) as i32)
        .collect();
    let query: Vec<i32> = (0..cfg.apb.query_len)
        .map(|_| rng.range(1, cfg.model.vocab_size as i64) as i32)
        .collect();
    (doc, query)
}

/// Everything the parity property compares, captured from one fresh
/// cluster. Wall-clock timing is deliberately excluded — it is the one
/// thing the drivers are ALLOWED to differ on.
#[derive(Debug, PartialEq)]
struct Fingerprint {
    chunk_logits: Vec<f32>,
    step_logits: Vec<f32>,
    /// (bytes, rounds) per meter label after the whole scenario.
    comm: Vec<(u64, u64)>,
    pool_bytes: Vec<usize>,
}

/// One full scenario on a fresh cluster under `driver`: optionally begin a
/// prefill, drive `k` chunk steps and CANCEL it mid-flight (the fabric
/// must drain identically under both drivers), then prefill a fresh
/// session to completion and decode (query chunk + one batched step).
fn run(driver: Driver, method: AttnMethod, ct: usize, abort_after: Option<usize>)
       -> Fingerprint {
    let cfg = Config::sim_tiny().with_method(method);
    let cluster = Cluster::start_with(&cfg, driver).expect("cluster");
    let (doc, query) = request(&cfg, 0xAB1E);
    let opts = ApbOptions { method, chunk_tokens: Some(ct), ..Default::default() };
    if let Some(k) = abort_after {
        let mut p = cluster.prefill_begin(1, &doc, &query, &opts).expect("begin");
        for _ in 0..k.min(p.n_steps() - 1) {
            cluster.prefill_step(&mut p).expect("step");
        }
        cluster.clear_session(1).expect("cancel mid-prefill");
    }
    cluster.prefill_session(2, &doc, &query, &opts).expect("prefill");
    let chunk = cluster.decode_query_chunk(2, &query).expect("query chunk");
    let vocab = cfg.model.vocab_size;
    let tok = Tensor::argmax_row(&chunk.logits[chunk.logits.len() - vocab..]) as i32;
    let step = cluster.decode_step_batch(&[(2, tok)]).expect("decode step");
    let m = &cluster.fabric.meter;
    Fingerprint {
        chunk_logits: chunk.logits,
        step_logits: step.logits[0].1.clone(),
        comm: LABELS.iter().map(|l| (m.bytes_for(l), m.rounds_for(l))).collect(),
        pool_bytes: cluster
            .pool_stats()
            .expect("pool stats")
            .iter()
            .map(|s| s.bytes_used)
            .collect(),
    }
}

#[test]
fn prop_threaded_equals_sequential_for_all_methods() {
    println!("APB-RUN driver_parity backend=sim");
    let cfg = Config::sim_tiny();
    for method in AttnMethod::ALL {
        // Chunk sizes spanning single-token, mid-block and one-shot; with
        // and without a cancelled admission before the measured request.
        for ct in [1usize, 7, 10 * cfg.apb.doc_len()] {
            for abort_after in [None, Some(2)] {
                let seq = run(Driver::Sequential, method, ct, abort_after);
                assert!(seq.chunk_logits.iter().all(|x| x.is_finite()),
                        "{} ct={ct}: non-finite oracle logits", method.name());
                let thr = run(Driver::Threaded, method, ct, abort_after);
                assert_eq!(thr, seq,
                           "{} ct={ct} abort_after={abort_after:?}: threaded \
                            diverged from the sequential oracle",
                           method.name());
            }
        }
    }
}

/// One serving-shaped scenario: session 1 resident and decoding, session 2
/// admitted chunk-by-chunk with a seeded-random number of session-1 decode
/// ticks interleaved between chunk steps. Returns the full logits trace of
/// every tick plus the comm fingerprint — same seed, same interleaving,
/// so the drivers must match bit-for-bit.
fn interleaved(driver: Driver, seed: u64) -> (Vec<f32>, Vec<(u64, u64)>) {
    let cfg = Config::sim_tiny();
    let cluster = Cluster::start_with(&cfg, driver).expect("cluster");
    let (doc, query) = request(&cfg, seed);
    let opts = ApbOptions::default();
    cluster.prefill_session(1, &doc, &query, &opts).expect("prefill A");
    let chunk = cluster.decode_query_chunk(1, &query).expect("chunk A");
    let vocab = cfg.model.vocab_size;
    let mut tok = Tensor::argmax_row(&chunk.logits[chunk.logits.len() - vocab..]) as i32;
    let mut trace = chunk.logits;

    let mut rng = Rng::new(seed ^ 0x71C4);
    let mut p = cluster.prefill_begin(2, &doc, &query, &opts).expect("begin B");
    loop {
        let done = cluster.prefill_step(&mut p).expect("step B");
        for _ in 0..rng.below(3) {
            let rep = cluster.decode_step_batch(&[(1, tok)]).expect("tick A");
            tok = Tensor::argmax_row(&rep.logits[0].1) as i32;
            trace.extend(rep.logits[0].1.iter().copied());
        }
        if done.is_some() {
            break;
        }
    }
    let chunk_b = cluster.decode_query_chunk(2, &query).expect("chunk B");
    trace.extend(chunk_b.logits);
    let m = &cluster.fabric.meter;
    (trace, LABELS.iter().map(|l| (m.bytes_for(l), m.rounds_for(l))).collect())
}

#[test]
fn stress_concurrent_threaded_clusters_match_their_sequential_oracles() {
    // N worker threads, each owning TWO whole clusters (a sequential
    // oracle and a threaded run of the identical seeded interleaving) —
    // up to N × n_hosts host threads plus N leaders live at once, all
    // hammering mpsc channels and condvar rendezvous concurrently. Any
    // cross-cluster interference, lost wakeup or deadlock surfaces as a
    // divergence, a rendezvous-timeout error, or a join failure here.
    println!("APB-RUN driver_parity_stress backend=sim");
    let handles: Vec<_> = (0..4u64)
        .map(|i| {
            std::thread::Builder::new()
                .name(format!("parity-worker-{i}"))
                .spawn(move || {
                    let seq = interleaved(Driver::Sequential, 0xBEEF + i);
                    let thr = interleaved(Driver::Threaded, 0xBEEF + i);
                    assert_eq!(thr, seq, "worker {i}: threaded diverged");
                })
                .expect("spawn")
        })
        .collect();
    for h in handles {
        h.join().expect("stress worker panicked (deadlock/divergence)");
    }
}

#[test]
fn sequential_driver_reports_itself_and_env_default_is_threaded() {
    let cfg = Config::sim_tiny();
    let seq = Cluster::start_with(&cfg, Driver::Sequential).expect("sequential cluster");
    assert_eq!(seq.driver(), Driver::Sequential);
    assert_eq!(seq.n_hosts(), cfg.apb.n_hosts);
    // `Cluster::start` resolves APB_DRIVER; this test binary does not set
    // it, so the default must be the production (threaded) driver — unless
    // the CI matrix leg pinned it, in which case it must follow the pin.
    let want = match std::env::var("APB_DRIVER") {
        Ok(s) => Driver::parse(&s).expect("valid APB_DRIVER"),
        Err(_) => Driver::Threaded,
    };
    let env = Cluster::start(&cfg).expect("env cluster");
    assert_eq!(env.driver(), want);
}
