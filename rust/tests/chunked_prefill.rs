//! Chunk-split invariance — the acceptance gate of the resumable-prefill
//! refactor (docs/ADR-002-chunked-prefill.md): for EVERY `AttnMethod` and
//! ANY chunk partition of the document (chunk size 1, ragged sizes, larger
//! than the doc), chunked prefill must be **bit-identical** to one-shot
//! prefill in
//!
//! * the query-chunk logits,
//! * the per-label CommMeter bytes AND rounds (chunking may never add,
//!   drop or resize a collective),
//! * the per-host KV-pool slot bytes.
//!
//! Runs on the native SimEngine (non-skipping tier-1; prints `APB-RUN`).

use apb::cluster::Interconnect;
use apb::config::{ApbOptions, AttnMethod, Config};
use apb::coordinator::Cluster;
use apb::util::rng::Rng;

const LABELS: [&str; 3] = [Interconnect::KV_LABEL, Interconnect::ATT_LABEL, Interconnect::RING_LABEL];

/// Everything the invariance compares, captured from one fresh cluster.
#[derive(Debug, PartialEq)]
struct RunFingerprint {
    /// Query-chunk logits (compared EXACTLY — bit-identity, not tolerance).
    logits: Vec<f32>,
    /// (bytes, rounds) per meter label after prefill only.
    prefill_comm: Vec<(u64, u64)>,
    /// Per-host KV-pool bytes resident after prefill.
    pool_bytes: Vec<usize>,
    /// Leader-visible prefill comm total.
    report_comm: u64,
}

fn run(method: AttnMethod, doc: &[i32], query: &[i32], ct: usize) -> RunFingerprint {
    let cfg = Config::sim_tiny().with_method(method);
    let cluster = Cluster::start(&cfg).expect("cluster");
    let opts = ApbOptions { method, chunk_tokens: Some(ct), ..Default::default() };
    let rep = cluster.prefill_session(1, doc, query, &opts).expect("prefill");
    let m = &cluster.fabric.meter;
    let prefill_comm = LABELS.iter().map(|l| (m.bytes_for(l), m.rounds_for(l))).collect();
    let pool_bytes = cluster
        .pool_stats()
        .expect("pool stats")
        .iter()
        .map(|s| s.bytes_used)
        .collect();
    let chunk = cluster.decode_query_chunk(1, query).expect("query chunk");
    RunFingerprint {
        logits: chunk.logits,
        prefill_comm,
        pool_bytes,
        report_comm: rep.comm_bytes,
    }
}

#[test]
fn prop_chunk_partition_is_bit_identical_for_all_methods() {
    println!("APB-RUN chunked_prefill backend=sim");
    let cfg = Config::sim_tiny();
    let mut rng = Rng::new(0x5EED);
    let doc: Vec<i32> = (0..cfg.apb.doc_len())
        .map(|_| rng.range(1, cfg.model.vocab_size as i64) as i32)
        .collect();
    let query: Vec<i32> = (0..cfg.apb.query_len)
        .map(|_| rng.range(1, cfg.model.vocab_size as i64) as i32)
        .collect();

    for method in AttnMethod::ALL {
        // Reference: one chunk per phase (chunk larger than the whole doc).
        let oneshot = run(method, &doc, &query, 10 * cfg.apb.doc_len());
        assert!(oneshot.logits.iter().all(|x| x.is_finite()),
                "{}: non-finite reference logits", method.name());
        assert!(oneshot.pool_bytes.iter().sum::<usize>() > 0,
                "{}: prefill must leave KV resident", method.name());

        // Boundary partitions: single-token chunks, ragged, just past the
        // block boundary, beyond the doc — plus randomized sizes.
        let mut cts =
            vec![1usize, 5, cfg.apb.block_len, cfg.apb.block_len + 1, cfg.apb.doc_len() + 1];
        for _ in 0..2 {
            cts.push(1 + rng.below(2 * cfg.apb.block_len as u64) as usize);
        }
        for ct in cts {
            let got = run(method, &doc, &query, ct);
            assert_eq!(got, oneshot,
                       "{} chunk_tokens={ct}: chunked prefill diverged from one-shot",
                       method.name());
        }
    }
}

#[test]
fn comm_structure_is_chunk_invariant_per_method() {
    // Spot-check the absolute comm structure stays the method's own under
    // aggressive chunking: APB only moves `kv`, Ring only `ring`, Star and
    // Dense nothing — with the exact same round counts as one-shot.
    let cfg = Config::sim_tiny();
    let mut rng = Rng::new(0xC0DE);
    let doc: Vec<i32> = (0..cfg.apb.doc_len())
        .map(|_| rng.range(1, cfg.model.vocab_size as i64) as i32)
        .collect();
    let query: Vec<i32> = (0..cfg.apb.query_len)
        .map(|_| rng.range(1, cfg.model.vocab_size as i64) as i32)
        .collect();
    let (a, m) = (&cfg.apb, &cfg.model);

    let apb = run(AttnMethod::Apb, &doc, &query, 3);
    assert!(apb.prefill_comm[0].0 > 0, "APB must move compressed blocks");
    assert_eq!(apb.prefill_comm[0].1, (m.n_layers * a.n_hosts) as u64,
               "one kv AllGather per layer regardless of chunking");
    assert_eq!(apb.prefill_comm[2], (0, 0), "APB never rides the ring");

    let ring = run(AttnMethod::RingAttn, &doc, &query, 3);
    assert_eq!(ring.prefill_comm[0], (0, 0));
    assert_eq!(ring.prefill_comm[2].1,
               (m.n_layers * a.n_hosts * (a.n_hosts - 1)) as u64,
               "N-1 exchange rounds per layer regardless of chunking");

    for method in [AttnMethod::StarAttn, AttnMethod::Dense] {
        let fp = run(method, &doc, &query, 3);
        assert_eq!(fp.report_comm, 0, "{} prefill must not communicate", method.name());
    }
}

#[test]
fn prefill_in_flight_guards_decode_and_second_prefill() {
    println!("APB-RUN chunked_prefill_guards backend=sim");
    let cfg = Config::sim_tiny();
    let cluster = Cluster::start(&cfg).expect("cluster");
    let mut rng = Rng::new(0xFACE);
    let doc: Vec<i32> = (0..cfg.apb.doc_len())
        .map(|_| rng.range(1, cfg.model.vocab_size as i64) as i32)
        .collect();
    let query: Vec<i32> = (0..cfg.apb.query_len)
        .map(|_| rng.range(1, cfg.model.vocab_size as i64) as i32)
        .collect();
    let opts = ApbOptions::default();

    let mut progress = cluster.prefill_begin(1, &doc, &query, &opts).expect("begin");
    assert!(progress.n_steps() > 1, "sim-tiny default must be chunked");
    assert_eq!(progress.steps_done(), 0);

    // A second prefill may not start while this one is in flight (the ring
    // pipeline holds open fabric rounds between steps).
    let err = cluster.prefill_begin(2, &doc, &query, &opts).unwrap_err();
    assert!(format!("{err:#}").contains("already in flight"), "got: {err:#}");

    // Decoding the half-prefilled session is refused on every host.
    cluster.prefill_step(&mut progress).expect("step");
    assert_eq!(progress.steps_done(), 1);
    let err = cluster.decode_query_chunk(1, &query).unwrap_err();
    assert!(format!("{err:#}").contains("prefill in flight"), "got: {err:#}");

    // Driving to completion unblocks everything.
    let report = loop {
        if let Some(r) = cluster.prefill_step(&mut progress).expect("step") {
            break r;
        }
    };
    assert!(report.comm_bytes > 0, "APB prefill must have communicated");
    let chunk = cluster.decode_query_chunk(1, &query).expect("decode after prefill");
    assert!(chunk.logits.iter().all(|x| x.is_finite()));
    cluster.prefill_session(2, &doc, &query, &opts).expect("next prefill runs");
}

#[test]
fn clearing_the_inflight_session_cancels_its_prefill() {
    // Cancelling an admission by clearing its session must release the
    // one-prefill-at-a-time marker (not wedge the cluster) and leave the
    // cluster fully serviceable.
    let cfg = Config::sim_tiny();
    let cluster = Cluster::start(&cfg).expect("cluster");
    let mut rng = Rng::new(0xCAFE);
    let doc: Vec<i32> = (0..cfg.apb.doc_len())
        .map(|_| rng.range(1, cfg.model.vocab_size as i64) as i32)
        .collect();
    let query: Vec<i32> = (0..cfg.apb.query_len)
        .map(|_| rng.range(1, cfg.model.vocab_size as i64) as i32)
        .collect();
    let opts = ApbOptions::default();

    let mut p = cluster.prefill_begin(1, &doc, &query, &opts).expect("begin");
    cluster.prefill_step(&mut p).expect("one chunk");
    cluster.clear_session(1).expect("cancel the admission");

    // The stale handle is dead: hosts no longer hold a machine for it.
    let err = cluster.prefill_step(&mut p).unwrap_err();
    assert!(format!("{err:#}").contains("no prefill in flight"), "got: {err:#}");

    // And a fresh prefill proceeds — the marker was released.
    cluster.prefill_session(2, &doc, &query, &opts).expect("fresh prefill");
    let chunk = cluster.decode_query_chunk(2, &query).expect("decode");
    assert!(chunk.logits.iter().all(|x| x.is_finite()));
}

#[test]
fn cancelling_a_ring_prefill_mid_rotation_does_not_wedge_the_fabric() {
    // The hard cancellation case: a RingAttn machine killed between a
    // posted and a completed exchange. abort() must drain the posted round
    // on every host (non-blocking under leader lockstep), or the next ring
    // prefill's post would panic with "posted again before completing".
    let cfg = Config::sim_tiny(); // ring fits the standard pool
    let cluster = Cluster::start(&cfg).expect("cluster");
    let mut rng = Rng::new(0xD00D);
    let doc: Vec<i32> = (0..cfg.apb.doc_len())
        .map(|_| rng.range(1, cfg.model.vocab_size as i64) as i32)
        .collect();
    let query: Vec<i32> = (0..cfg.apb.query_len)
        .map(|_| rng.range(1, cfg.model.vocab_size as i64) as i32)
        .collect();
    let ring = ApbOptions { method: AttnMethod::RingAttn, ..Default::default() };

    // Drive past the layer's RingPost (plan per layer: Pre×C, RingPost,
    // ...) so a ring round is posted but not yet completed, then cancel.
    let n_chunks = (cfg.apb.query_len + cfg.apb.block_len).div_ceil(cfg.apb.chunk_tokens);
    let mut p = cluster.prefill_begin(1, &doc, &query, &ring).expect("begin");
    for _ in 0..n_chunks + 1 {
        assert!(cluster.prefill_step(&mut p).expect("step").is_none());
    }
    cluster.clear_session(1).expect("cancel mid-rotation");

    // The ring collective must be pristine: a full fresh ring prefill +
    // decode runs (it re-posts the very rounds a wedged fabric would
    // panic on).
    cluster.prefill_session(2, &doc, &query, &ring).expect("ring prefill after cancel");
    let chunk = cluster.decode_query_chunk(2, &query).expect("decode");
    assert!(chunk.logits.iter().all(|x| x.is_finite()));
}
