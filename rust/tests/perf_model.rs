//! Paper-shape regression suite over the analytical performance model:
//! every headline claim of the evaluation section, as an assertion.

use apb::attnsim::{apb_flops, estimate, fullattn_flops, speed_tok_per_s,
                   starattn_flops, Hyper, Method, A800, ALL_MODELS, LLAMA31_8B};

fn est(method: Method, n: f64, hosts: f64) -> apb::attnsim::Estimate {
    let h = if method.uses_sequence_parallelism() { hosts } else { 1.0 };
    estimate(method, &LLAMA31_8B, n, h, &Hyper::paper_schedule(n, hosts), &A800, 64.0)
}

#[test]
fn abstract_headline_speedups() {
    // "speedups of up to 9.2x, 4.2x, and 1.6x compared with FLASHATTN,
    // RINGATTN, and STARATTN" — take the max over the sweep; our model
    // must land in a band around each (shape, not absolutes).
    let lengths = [32768.0, 65536.0, 131072.0, 262144.0, 524288.0];
    let max_ratio = |base: Method| {
        lengths
            .iter()
            .filter_map(|&n| {
                let b = est(base, n, 8.0);
                let a = est(Method::Apb, n, 8.0);
                (!b.oom && !a.oom).then_some(b.prefill_s / a.prefill_s)
            })
            .fold(0.0f64, f64::max)
    };
    let vs_flash = max_ratio(Method::FlashAttn);
    let vs_ring = max_ratio(Method::RingAttn);
    let vs_star = max_ratio(Method::StarAttn);
    assert!((5.0..25.0).contains(&vs_flash), "vs FlashAttn {vs_flash}");
    assert!((1.8..8.0).contains(&vs_ring), "vs RingAttn {vs_ring}");
    assert!((1.2..3.0).contains(&vs_star), "vs StarAttn {vs_star}");
    // And the ordering of the three headline ratios matches the paper.
    assert!(vs_flash > vs_ring && vs_ring > vs_star);
}

#[test]
fn speed_crossover_star_vs_ulysses() {
    // §4.2: "StarAttn faster than RingAttn, though its improvement over
    // Ulysses remains limited" — Star beats Ring at every length, but
    // Star/Ulysses stay within a modest factor at 128K.
    for n in [131072.0, 262144.0, 524288.0] {
        assert!(est(Method::StarAttn, n, 8.0).prefill_s
                    < est(Method::RingAttn, n, 8.0).prefill_s,
                "Star < Ring at {n}");
    }
    let s = est(Method::StarAttn, 131072.0, 8.0).prefill_s;
    let u = est(Method::Ulysses, 131072.0, 8.0).prefill_s;
    assert!(u / s < 2.0, "Star's edge over Ulysses is limited: {}", u / s);
}

#[test]
fn sp_methods_3x_to_10x_over_flashattn() {
    // §4.2: Ring/Ulysses achieve 3–10x over FlashAttn.
    for n in [65536.0, 131072.0] {
        let flash = est(Method::FlashAttn, n, 8.0).prefill_s;
        for m in [Method::Ulysses, Method::RingAttn] {
            let r = flash / est(m, n, 8.0).prefill_s;
            assert!((2.5..14.0).contains(&r), "{} at {n}: {r}", m.name());
        }
    }
}

#[test]
fn apb_speed_advantage_grows_with_length() {
    // Figure 4(b) / Table 15, in the paper's own metric (tok/s): APB's
    // edge over StarAttn is humble at 32K (paper 1.22x) and pronounced at
    // 512K (paper 1.61x) — the ratio must grow monotonically in n.
    let ratio = |n: f64| {
        let a = speed_tok_per_s(&est(Method::Apb, n, 8.0), n, 64.0).unwrap();
        let s = speed_tok_per_s(&est(Method::StarAttn, n, 8.0), n, 64.0).unwrap();
        a / s
    };
    let r32 = ratio(32768.0);
    let r128 = ratio(131072.0);
    let r512 = ratio(524288.0);
    assert!(r512 > r128 && r128 > r32, "ratios {r32} {r128} {r512}");
    assert!((1.1..1.5).contains(&r32), "humble at 32K: {r32}");
    assert!((1.25..2.2).contains(&r512), "pronounced at 512K: {r512}");
}

#[test]
fn flops_orderings_hold_for_all_models() {
    for m in &ALL_MODELS {
        for n in [131072.0, 262144.0, 524288.0] {
            let hy = Hyper::paper_schedule(n, 8.0);
            assert!(apb_flops(m, n, &hy) < starattn_flops(m, n, 8.0), "{}", m.name);
            assert!(starattn_flops(m, n, 8.0) < fullattn_flops(m, n), "{}", m.name);
        }
    }
}

#[test]
fn speed_scales_down_with_model_size() {
    // Tables 9/12: Llama > Qwen > Yi columns for every method.
    let hy = Hyper::e2e_128k();
    for method in Method::ALL {
        let h = if method.uses_sequence_parallelism() { 8.0 } else { 1.0 };
        let mut speeds = Vec::new();
        for m in &ALL_MODELS {
            let e = estimate(method, m, 131072.0, h, &hy, &A800, 64.0);
            speeds.push(speed_tok_per_s(&e, 131072.0, 64.0));
        }
        if let (Some(l), Some(q)) = (speeds[0], speeds[1]) {
            assert!(l > q, "{}: Llama {l} !> Qwen {q}", method.name());
        }
        if let (Some(q), Some(y)) = (speeds[1], speeds[2]) {
            assert!(q > y, "{}: Qwen {q} !> Yi {y}", method.name());
        }
    }
}

#[test]
fn oom_grid_matches_table11_exactly() {
    // Full Table 11 OOM pattern (Llama-3.1-8B).
    let grid: [(Method, &[bool; 6]); 6] = [
        (Method::FlashAttn, &[false, false, false, true, true, true]),
        (Method::Ulysses, &[false, false, false, false, false, true]),
        (Method::RingAttn, &[false, false, false, false, false, true]),
        (Method::MInference, &[false, false, false, true, true, true]),
        (Method::StarAttn, &[false, false, false, false, false, true]),
        (Method::Apb, &[false, false, false, false, false, false]),
    ];
    let lengths = [32768.0, 65536.0, 131072.0, 262144.0, 524288.0, 1048576.0];
    for (method, want) in grid {
        for (&n, &w) in lengths.iter().zip(want) {
            assert_eq!(est(method, n, 8.0).oom, w, "{} at {}K", method.name(),
                       n as usize / 1024);
        }
    }
}

#[test]
fn decode_time_grows_with_context_but_stays_minor() {
    let d1 = est(Method::Apb, 65536.0, 8.0).decode_per_token_s;
    let d2 = est(Method::Apb, 524288.0, 8.0).decode_per_token_s;
    assert!(d2 > d1);
    // Figure 6: decode of 64 tokens is a small share of e2e at 128K.
    let e = est(Method::Apb, 131072.0, 8.0);
    assert!(e.decode_per_token_s * 64.0 < 0.5 * e.prefill_s);
}

#[test]
fn yi34b_fits_via_layer_split() {
    // §B.2.1: Yi-34B runs across two machines; its per-device memory must
    // fit at 128K for SP methods (the paper reports Yi speeds, not OOM).
    use apb::attnsim::YI_34B;
    let hy = Hyper::e2e_128k();
    for method in [Method::Ulysses, Method::RingAttn, Method::StarAttn, Method::Apb] {
        let e = estimate(method, &YI_34B, 131072.0, 8.0, &hy, &A800, 64.0);
        assert!(!e.oom, "{} must fit Yi-34B at 128K", method.name());
    }
}
