//! SimEngine numerics (non-skipping tier-1 tests):
//!
//! 1. the online-softmax merge of per-host partial attentions must equal a
//!    single-host softmax over the union of all keys within 1e-5 — the
//!    correctness core of Algorithm 3 line 10;
//! 2. top-l_p block selection must be deterministic under a fixed `Rng`
//!    seed — what makes the compressor's AllGather payloads reproducible.

use apb::runtime::sim::masked_attention;
use apb::util::rng::Rng;
use apb::util::tensor::{merge_partials, top_lp_indices, Tensor};

fn rand_tensor(rng: &mut Rng, shape: Vec<usize>) -> Tensor {
    let n: usize = shape.iter().product();
    Tensor::new(shape, (0..n).map(|_| rng.normal() as f32).collect()).unwrap()
}

#[test]
fn merge_across_hosts_equals_single_host_softmax() {
    println!("APB-RUN sim_numerics");
    let mut rng = Rng::new(0x51);
    for case in 0..25 {
        let (nq, h, kh, hd) = (3, 4, 2, 8);
        let hosts = 2 + (case % 3);
        let per_host = 5;
        let nk = hosts * per_host;
        let q = rand_tensor(&mut rng, vec![nq, h, hd]);
        let k = rand_tensor(&mut rng, vec![nk, kh, hd]);
        let v = rand_tensor(&mut rng, vec![nk, kh, hd]);

        // Single host: dense softmax over the whole key set.
        let (want, want_lse) = masked_attention(&q, &k, &v, |_, _| true);

        // Distributed: each host attends to its own key shard, then the
        // partials are merged with the online-softmax identity.
        let mut outs = Vec::new();
        let mut lses = Vec::new();
        for hst in 0..hosts {
            let ks = k.slice_rows(hst * per_host, (hst + 1) * per_host);
            let vs = v.slice_rows(hst * per_host, (hst + 1) * per_host);
            let (o, l) = masked_attention(&q, &ks, &vs, |_, _| true);
            outs.push(o);
            lses.push(l);
        }
        let merged = merge_partials(&outs, &lses);
        assert_eq!(merged.shape, want.shape);
        for (i, (a, b)) in merged.data.iter().zip(&want.data).enumerate() {
            assert!(
                (a - b).abs() < 1e-5,
                "case {case} elem {i}: merged {a} vs dense {b}"
            );
        }
        // The merged LSE identity: log-sum-exp over the union.
        let mut merged_lse = vec![f32::NEG_INFINITY; nq * h];
        for l in &lses {
            for (slot, &x) in merged_lse.iter_mut().zip(&l.data) {
                if x.is_finite() {
                    let m = slot.max(x);
                    *slot = m + ((*slot - m).exp() + (x - m).exp()).ln();
                }
            }
        }
        for (a, b) in merged_lse.iter().zip(&want_lse.data) {
            assert!((a - b).abs() < 1e-4, "lse {a} vs {b}");
        }
    }
}

#[test]
fn merge_with_empty_hosts_ignores_them() {
    let mut rng = Rng::new(0x52);
    let (nq, h, kh, hd) = (2, 2, 1, 4);
    let q = rand_tensor(&mut rng, vec![nq, h, hd]);
    let k = rand_tensor(&mut rng, vec![6, kh, hd]);
    let v = rand_tensor(&mut rng, vec![6, kh, hd]);
    let (want, _) = masked_attention(&q, &k, &v, |_, _| true);
    // Host 1 sees zero keys (all masked) -> out 0, lse -inf.
    let (o0, l0) = masked_attention(&q, &k, &v, |_, _| true);
    let (o1, l1) = masked_attention(&q, &k, &v, |_, _| false);
    let merged = merge_partials(&[o0, o1], &[l0, l1]);
    for (a, b) in merged.data.iter().zip(&want.data) {
        assert!((a - b).abs() < 1e-6, "empty host must not perturb the merge");
    }
}

#[test]
fn top_lp_selection_deterministic_under_fixed_seed() {
    // The same Rng seed must produce the same scores and therefore the same
    // per-head retained indices, run after run; a different seed must not.
    let gen_scores = |seed: u64| {
        let mut rng = Rng::new(seed);
        rand_tensor(&mut rng, vec![48, 4])
    };
    let a = top_lp_indices(&gen_scores(99), 8);
    let b = top_lp_indices(&gen_scores(99), 8);
    let c = top_lp_indices(&gen_scores(100), 8);
    assert_eq!(a, b, "fixed seed must reproduce the selection");
    assert_ne!(a, c, "different seed must change the selection");
    for head in &a {
        assert_eq!(head.len(), 8);
        for w in head.windows(2) {
            assert!(w[0] < w[1], "retained indices ascending (RoPE order)");
        }
    }
}

#[test]
fn sim_engine_stages_deterministic_across_instances() {
    use apb::config::Config;
    use apb::runtime::{create_backend, ExecBackend};

    let cfg = Config::sim_tiny();
    let a = create_backend(&cfg).unwrap();
    let b = create_backend(&cfg).unwrap();
    let tokens: Vec<i32> = (0..cfg.apb.n_tot() as i32).map(|i| i % 100).collect();
    let ha = a.embed(&tokens).unwrap();
    let hb = b.embed(&tokens).unwrap();
    assert_eq!(ha, hb);
    let (qa, ka, va, sa) = a.layer_pre(0, &ha, cfg.apb.query_len as i32).unwrap();
    let (qb, kb, vb, sb) = b.layer_pre(0, &hb, cfg.apb.query_len as i32).unwrap();
    assert_eq!(qa, qb);
    assert_eq!(ka, kb);
    assert_eq!(va, vb);
    assert_eq!(sa, sb);
    // And the scores feed a deterministic selection.
    assert_eq!(
        top_lp_indices(&sa, cfg.apb.passing_len),
        top_lp_indices(&sb, cfg.apb.passing_len)
    );
}
