//! Serving-invariant suite for SLO-aware preemptive scheduling
//! (docs/ADR-006-slo-scheduling.md): starvation-freedom under the
//! adversarial seeded trace, the TTFT-spans-suspension contract, the
//! admission queue's aging bound as a pure property test, and cross-driver
//! trace-replay determinism via [`ReplayFingerprint`].
//!
//! Runs on the native SimEngine (non-skipping tier-1; prints `APB-RUN`).

use apb::config::{ApbOptions, Config};
use apb::coordinator::scheduler::{AdmissionQueue, Class, Request, Scheduler};
use apb::coordinator::{Cluster, Driver};
use apb::util::rng::Rng;
use apb::workload::{generate, run_trace, TraceSpec};

fn tokens(cfg: &Config, seed: u64) -> (Vec<i32>, Vec<i32>) {
    let mut rng = Rng::new(seed);
    let doc: Vec<i32> = (0..cfg.apb.doc_len())
        .map(|_| rng.range(1, cfg.model.vocab_size as i64) as i32)
        .collect();
    let query: Vec<i32> = (0..cfg.apb.query_len)
        .map(|_| rng.range(1, cfg.model.vocab_size as i64) as i32)
        .collect();
    (doc, query)
}

/// The headline serving invariant: on the trace BUILT to starve FIFO
/// (block-scale Batch prefills front-loaded in every burst), no short
/// request's TTFT may exceed the starvation budget — aging must pull every
/// Interactive/Standard request past the head-of-line longs. Batch traffic
/// may blow the budget (its own backlog is self-inflicted), which is why
/// the `starved == 0` CI gate runs on the smoke trace, not this one.
#[test]
fn adversarial_trace_is_starvation_free() {
    println!("APB-RUN slo_adversarial backend=sim");
    let cfg = Config::sim_tiny();
    let cluster = Cluster::start(&cfg).expect("cluster");
    let spec = TraceSpec::by_name("adversarial").expect("named spec");
    let trace = generate(&cfg, &spec).expect("trace");
    assert!(trace.n_long() >= 1, "adversarial trace must carry a block-scale long");
    let mut sched = Scheduler::new(&cluster, 16);
    let done = run_trace(&mut sched, &trace).expect("trace run");
    assert_eq!(done, spec.n_requests, "every request must complete");

    let budget = sched.policy.starvation_budget_ticks;
    for r in &sched.completed {
        let a = trace.arrivals.iter().find(|a| a.req.id == r.id).expect("traced id");
        // Value-level completion: the full decode budget, token for token.
        assert_eq!(r.tokens.len(), a.req.max_new, "request {} short-changed", r.id);
        // TTFT can never undercut the admission work itself (cold path:
        // one scheduler tick drives at most one prefill chunk).
        assert!(
            r.ttft_ticks >= r.prefill_chunks as u64,
            "request {}: ttft {} < {} chunks",
            r.id, r.ttft_ticks, r.prefill_chunks
        );
        if a.req.opts.chunk_tokens.is_none() {
            assert!(
                r.ttft_ticks <= budget,
                "short request {} ({}) starved: ttft {} > budget {budget}",
                r.id, r.class.name(), r.ttft_ticks
            );
        }
        // The contrapositive, request by request: anything over budget is
        // Batch queueing behind Batch — never a policy-protected class.
        if r.ttft_ticks > budget {
            assert_eq!(r.class, Class::Batch, "request {} starved cross-class", r.id);
        }
    }
    // Priority is visible in completion order: the first retirement is a
    // protected-class short, the last is a Batch long.
    assert_ne!(sched.completed.first().expect("nonempty").class, Class::Batch);
    assert_eq!(sched.completed.last().expect("nonempty").class, Class::Batch);

    let m = sched.metrics();
    assert_eq!(m.n_requests, spec.n_requests);
    assert!(m.ttft_ticks.p50 <= m.ttft_ticks.p95 && m.ttft_ticks.p95 <= m.ttft_ticks.p99);
    assert_eq!(
        m.per_class.iter().map(|c| c.n_requests).sum::<usize>(),
        spec.n_requests,
        "per-class stats must partition the trace"
    );
    let of = |class: Class| m.per_class.iter().find(|c| c.class == class);
    let (interactive, batch) =
        (of(Class::Interactive).expect("interactive shorts"), of(Class::Batch).expect("longs"));
    // Class separation end to end: the WORST interactive TTFT beats the
    // BEST Batch one (a long's own prefill alone dwarfs a short's wait).
    assert!(
        interactive.ttft_ticks.max < batch.ttft_ticks.min,
        "class priority not visible: interactive max {} >= batch min {}",
        interactive.ttft_ticks.max, batch.ttft_ticks.min
    );
    for c in &m.per_class {
        assert!(c.slo_met <= c.n_requests);
        let frac = c.slo_met as f64 / c.n_requests as f64;
        assert!((c.slo_fraction - frac).abs() < 1e-12, "{}: goodput fraction", c.class.name());
    }
}

/// THE TTFT definition (rustdoc on `Response::ttft_s`): enqueue → first
/// query-chunk logit, spanning any preemption-parked gap. A long Batch
/// prefill is preempted by an Interactive arrival; its TTFT must cover its
/// own chunks PLUS the preemptor's entire admission — measuring from
/// resume would report at most the chunk count alone.
#[test]
fn ttft_spans_suspension_not_resume() {
    println!("APB-RUN slo_ttft_preempt backend=sim");
    let cfg = Config::sim_tiny();
    let cluster = Cluster::start(&cfg).expect("cluster");
    let (doc, query) = tokens(&cfg, 0x77F7);
    let mut sched = Scheduler::new(&cluster, 4);
    sched
        .submit(Request {
            id: 0,
            doc: doc.clone(),
            query: query.clone(),
            max_new: 2,
            opts: ApbOptions { chunk_tokens: Some(1), ..Default::default() },
            class: Class::Batch,
        })
        .expect("submit long");
    // Drive the long request into its block-scale prefill (2 chunks in).
    let mut spins = 0;
    while !matches!(sched.prefill_in_flight(), Some((0, steps, _)) if steps >= 2) {
        assert!(sched.step().expect("step"), "idled before the long admitted");
        spins += 1;
        assert!(spins < 8, "long request never reached its second chunk");
    }
    sched
        .submit(Request {
            id: 1,
            doc,
            query,
            max_new: 1,
            opts: ApbOptions::default(),
            class: Class::Interactive,
        })
        .expect("submit short");
    // Next tick: the strictly-more-urgent Interactive request parks the
    // Batch prefill at its (quiescent) chunk boundary and takes the seat.
    assert!(sched.step().expect("step"));
    assert_eq!(sched.parked_count(), 1, "the Batch prefill must park");
    match sched.prefill_in_flight() {
        Some((1, _, _)) => {}
        other => panic!("preemptor should hold the admission seat, got {other:?}"),
    }
    sched.run_all().expect("drain");

    assert_eq!(sched.completed[0].id, 1, "the preemptor finishes first");
    let long = sched.completed.iter().find(|r| r.id == 0).expect("long done");
    let short = sched.completed.iter().find(|r| r.id == 1).expect("short done");
    assert_eq!(long.preemptions, 1);
    assert_eq!(sched.preemptions_total, 1);
    assert_eq!(long.tokens.len(), 2);
    assert_eq!(short.tokens.len(), 1);
    // The span contract: the long's TTFT covers its own admission work AND
    // the whole parked gap (= the short's admission). A from-resume
    // measurement could never exceed its own chunk count plus its wait of
    // a few ticks — this bound rules that out structurally.
    assert!(
        long.ttft_ticks >= (long.prefill_chunks + short.prefill_chunks) as u64,
        "ttft {} does not span the suspension ({} own + {} preemptor chunks)",
        long.ttft_ticks, long.prefill_chunks, short.prefill_chunks
    );
    assert!(short.ttft_ticks < long.ttft_ticks);
}

/// Pure-queue property test (no cluster): under seeded adversarial
/// arrivals — up to two fresh requests per tick, classes chosen to bury
/// whoever is already waiting — no popped request has EVER waited more
/// than `Class::ALL.len() * aging + capacity` ticks. Once a request has
/// waited `ALL.len() * aging`, its effective priority strictly beats any
/// fresh arrival (see `effective_priority`), so only the <= capacity-1
/// requests already queued at that moment can still be served ahead of
/// it, one per tick.
#[test]
fn admission_is_starvation_free_under_adversarial_arrivals() {
    println!("APB-RUN slo_queue_aging backend=sim");
    let aging = 4u64;
    let cap = 8usize;
    let bound = Class::ALL.len() as u64 * aging + cap as u64;
    for seed in 0..16u64 {
        let mut rng = Rng::new(0xADC0 + seed);
        let mut q = AdmissionQueue::new(cap);
        let mut tick = 0u64;
        let (mut next_id, mut served) = (0u64, 0usize);
        while served < 200 {
            tick += 1;
            for _ in 0..rng.below(3) {
                let class = Class::ALL[rng.below(3) as usize];
                let req = Request {
                    id: next_id,
                    doc: vec![1; 4],
                    query: vec![1; 2],
                    max_new: 1,
                    opts: ApbOptions::default(),
                    class,
                };
                next_id += 1;
                // A full queue rejects (backpressure) — that's admission
                // control, not starvation; the bound covers accepted ones.
                let _ = q.submit(req, tick);
            }
            if let Some((req, _, enq_tick)) = q.pop_best(tick, aging) {
                served += 1;
                let waited = tick - enq_tick;
                assert!(
                    waited <= bound,
                    "seed {seed}: request {} ({}) waited {waited} > bound {bound}",
                    req.id, req.class.name()
                );
            }
        }
    }
}

/// Same seed, same trace, both drivers: the timing-free
/// [`ReplayFingerprint`] — tokens, tick latencies, comm bytes, preemption
/// tallies — must compare equal between `Driver::Sequential` and
/// `Driver::Threaded`, with and without the prefix store. This is the
/// determinism contract that makes `BENCH_serving.json` reproducible.
#[test]
fn seeded_traces_replay_identically_across_drivers() {
    println!("APB-RUN slo_replay backend=sim");
    for (name, prefix_cache) in [("smoke", false), ("smoke", true), ("bursty", false)] {
        let spec = TraceSpec::by_name(name).expect("named spec");
        let mut fps = Vec::new();
        for driver in [Driver::Sequential, Driver::Threaded] {
            let cfg = Config::sim_tiny().with_prefix_cache(prefix_cache);
            let cluster = Cluster::start_with(&cfg, driver).expect("cluster");
            let trace = generate(&cfg, &spec).expect("trace");
            let mut sched = Scheduler::new(&cluster, 16);
            let done = run_trace(&mut sched, &trace).expect("trace run");
            assert_eq!(done, spec.n_requests, "{name} {driver:?}: trace must drain");
            fps.push(sched.replay_fingerprint());
        }
        assert_eq!(
            fps[0], fps[1],
            "{name} prefix_cache={prefix_cache}: replay diverged across drivers"
        );
        assert_eq!(fps[0].n_requests, spec.n_requests);
        assert!(fps[0].total_tokens > 0);
        let hits = fps[0].per_request.iter().filter(|r| r.prefix_hit).count();
        if prefix_cache {
            // The smoke corpus replays one (doc, query) pair 3 times and
            // admissions are serialized by the prefill permit, so at least
            // the last replay attaches warm.
            assert!(hits >= 1, "{name}: shared corpus produced no warm admission");
        } else {
            assert_eq!(hits, 0, "{name}: prefix hits without the store enabled");
        }
    }
}
