//! End-to-end integration, two tiers:
//!
//! * **Sim tier (always runs, no artifacts):** the cluster executes the full
//!   Algorithm-2 prefill + Algorithm-3 decode on the native SimEngine and
//!   must be deterministic, finite and mode-sensitive. These are the
//!   non-skipping tier-1 tests CI gates on (they print `APB-RUN`).
//! * **Golden tier (PJRT builds with `make artifacts` only):** the rust
//!   cluster replays the AOT artifacts and must reproduce the golden
//!   outputs recorded by the python cluster simulation (aot.py::build_golden)
//!   — same tokens, same logits. Skips print an explicit `APB-SKIP` marker
//!   that CI greps for.

use apb::config::{ApbOptions, AttnMethod, Config};
use apb::coordinator::Cluster;
use apb::runtime::load_golden;

fn tiny_config() -> Option<apb::config::Config> {
    match apb::load_config("tiny") {
        Ok(c) => Some(c),
        Err(e) => {
            eprintln!("APB-SKIP golden_e2e: artifacts/tiny not usable ({e:#})");
            None
        }
    }
}

/// Shared ablation battery (both tiers): every component toggle must change
/// the computation without breaking it, and no-passing must not communicate.
fn assert_ablations_change_generation(cluster: &Cluster, doc: &[i32], query: &[i32]) {
    let variants = [
        ApbOptions { method: AttnMethod::StarAttn, ..Default::default() },
        ApbOptions { use_anchor: false, ..Default::default() },
        ApbOptions { retaining_compressor: false, ..Default::default() },
        ApbOptions { embed_query: false, ..Default::default() },
    ];
    let baseline = {
        cluster.clear().unwrap();
        cluster.prefill(doc, query, &ApbOptions::default()).unwrap();
        cluster.generate(query, 2).unwrap().query_logits
    };
    for (i, opts) in variants.iter().enumerate() {
        cluster.clear().unwrap();
        let rep = cluster.prefill(doc, query, opts).unwrap();
        if opts.method.passes_compressed_blocks() {
            assert!(rep.comm_bytes > 0, "variant {i} must pass compressed blocks");
        } else {
            assert_eq!(rep.comm_bytes, 0, "no-passing must not communicate");
        }
        let gen = cluster.generate(query, 2).unwrap();
        assert_eq!(gen.tokens.len(), 2, "variant {i} owes two greedy tokens");
        let vocab = cluster.cfg.model.vocab_size;
        assert!(
            gen.tokens.iter().all(|&t| t >= 0 && (t as usize) < vocab),
            "variant {i} emitted out-of-vocabulary tokens"
        );
        assert!(gen.query_logits.iter().all(|x| x.is_finite()),
                "variant {i} produced non-finite logits");
        let diff: f32 = gen
            .query_logits
            .iter()
            .zip(&baseline)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(diff > 1e-6, "variant {i} did not change the computation");
    }
}

// ---------------------------------------------------------------------------
// Sim tier — always runs
// ---------------------------------------------------------------------------

#[test]
fn sim_e2e_prefill_decode_deterministic() {
    let cfg = Config::sim_tiny();
    println!("APB-RUN sim_e2e backend={}", cfg.backend.name());
    let cluster = Cluster::start(&cfg).expect("sim cluster start");
    let mut rng = apb::util::rng::Rng::new(7);
    let doc: Vec<i32> = (0..cfg.apb.doc_len())
        .map(|_| rng.range(1, cfg.model.vocab_size as i64) as i32)
        .collect();
    let query: Vec<i32> = (0..cfg.apb.query_len)
        .map(|_| rng.range(1, cfg.model.vocab_size as i64) as i32)
        .collect();
    let opts = ApbOptions::default();

    let rep = cluster.prefill(&doc, &query, &opts).expect("prefill");
    assert!(rep.comm_bytes > 0, "prefill must move compressed blocks");
    assert_eq!(rep.per_host.len(), cfg.apb.n_hosts, "one timing row per host");
    for t in &rep.per_host {
        assert!(t.total_s > 0.0);
    }
    let n_new = cfg.apb.max_new_tokens;
    let g1 = cluster.generate(&query, n_new).expect("generate");
    assert_eq!(g1.tokens.len(), n_new);
    assert_eq!(g1.query_logits.len(), cfg.apb.query_len * cfg.model.vocab_size);
    assert!(g1.query_logits.iter().all(|x| x.is_finite()));
    assert!(
        g1.tokens.iter().all(|&t| t >= 0 && (t as usize) < cfg.model.vocab_size),
        "greedy tokens in vocabulary"
    );

    // Greedy-token determinism: a fresh prefill of the same request must
    // reproduce tokens AND logits bit-for-bit.
    cluster.clear().unwrap();
    cluster.prefill(&doc, &query, &opts).unwrap();
    let g2 = cluster.generate(&query, n_new).unwrap();
    assert_eq!(g1.tokens, g2.tokens, "greedy tokens must be deterministic");
    assert_eq!(g1.query_logits, g2.query_logits, "logits must be deterministic");
}

#[test]
fn sim_ablations_change_generation_but_stay_finite() {
    let cfg = Config::sim_tiny();
    let cluster = Cluster::start(&cfg).expect("sim cluster start");
    let mut rng = apb::util::rng::Rng::new(11);
    let doc: Vec<i32> = (0..cfg.apb.doc_len())
        .map(|_| rng.range(1, cfg.model.vocab_size as i64) as i32)
        .collect();
    let query: Vec<i32> = (0..cfg.apb.query_len)
        .map(|_| rng.range(1, cfg.model.vocab_size as i64) as i32)
        .collect();
    assert_ablations_change_generation(&cluster, &doc, &query);
}

#[test]
fn sim_cross_host_merge_consistency() {
    // Fresh-merge consistency across requests: the same document prefilled
    // with two *different* queries must produce (a) bit-identical results
    // when a request is repeated, and (b) different logits between the two
    // queries — i.e. the per-layer online-softmax merges are recomputed
    // per request with no state leaking across clears.
    let cfg = Config::sim_tiny();
    let cluster = Cluster::start(&cfg).expect("sim cluster start");
    let mut rng = apb::util::rng::Rng::new(13);
    let doc: Vec<i32> = (0..cfg.apb.doc_len())
        .map(|_| rng.range(1, cfg.model.vocab_size as i64) as i32)
        .collect();
    let q1: Vec<i32> = (0..cfg.apb.query_len)
        .map(|_| rng.range(1, cfg.model.vocab_size as i64) as i32)
        .collect();
    let q2: Vec<i32> = q1.iter().map(|&t| (t % 100) + 1).collect();
    assert_ne!(q1, q2);

    let run = |q: &[i32]| {
        cluster.clear().unwrap();
        cluster.prefill(&doc, q, &ApbOptions::default()).unwrap();
        cluster.generate(q, 3).unwrap()
    };
    let a1 = run(&q1);
    let a2 = run(&q1);
    assert_eq!(a1.tokens, a2.tokens);
    assert_eq!(a1.query_logits, a2.query_logits);

    let b1 = run(&q2);
    let b2 = run(&q2);
    assert_eq!(b1.tokens, b2.tokens);
    assert_eq!(b1.query_logits, b2.query_logits);

    let diff: f32 = a1
        .query_logits
        .iter()
        .zip(&b1.query_logits)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max);
    assert!(diff > 1e-6, "different queries must change the merged logits");
}

#[test]
fn sim_decode_comm_is_value_exact_per_label() {
    // Value-level decode comm audit (`docs/ADR-007-adaptive-decode.md`):
    // a full `generate` is one query-chunk pass plus `max_new - 1`
    // single-token steps, and each layer of each pass moves exactly one
    // (out, lse) partial per rank — on the `att` AllGather under pass-KV
    // (1 post per rank per layer), on the `qring` rotation under pass-Q
    // (n-1 posts per rank per layer, same partial unit). Asserted to the
    // byte and to the round, not just nonzero.
    use apb::cluster::Interconnect;
    use apb::config::PassStrategy;
    let base = Config::sim_tiny();
    let mut rng = apb::util::rng::Rng::new(17);
    let doc: Vec<i32> = (0..base.apb.doc_len())
        .map(|_| rng.range(1, base.model.vocab_size as i64) as i32)
        .collect();
    let query: Vec<i32> = (0..base.apb.query_len)
        .map(|_| rng.range(1, base.model.vocab_size as i64) as i32)
        .collect();
    let (n, layers) = (base.apb.n_hosts, base.model.n_layers);
    // One f32 (out, lse) partial row: [n_heads, head_dim] + [n_heads].
    let partial_row = (base.model.n_heads * base.model.head_dim() + base.model.n_heads) * 4;
    let n_new = base.apb.max_new_tokens;
    let decode_rows = base.apb.query_len + (n_new - 1);
    let exchanges = n_new; // 1 chunk + (n_new - 1) steps
    let gather_bytes = (n * layers * decode_rows * partial_row) as u64;

    let mut outcomes = Vec::new();
    for strategy in [PassStrategy::PassKv, PassStrategy::PassQ] {
        let cfg = Config::sim_tiny().with_pass_strategy(strategy);
        let cluster = Cluster::start(&cfg).expect("sim cluster start");
        cluster.prefill(&doc, &query, &ApbOptions::default()).expect("prefill");
        let m = &cluster.fabric.meter;
        let snap = || {
            (
                m.bytes_for(Interconnect::ATT_LABEL),
                m.rounds_for(Interconnect::ATT_LABEL),
                m.bytes_for(Interconnect::QRING_LABEL),
                m.rounds_for(Interconnect::QRING_LABEL),
            )
        };
        let before = snap();
        let gen = cluster.generate(&query, n_new).expect("generate");
        let after = snap();
        let att = (after.0 - before.0, after.1 - before.1);
        let qring = (after.2 - before.2, after.3 - before.3);
        match strategy {
            PassStrategy::PassKv => {
                assert_eq!(att, (gather_bytes, (exchanges * n * layers) as u64),
                           "pass-KV att (bytes, rounds)");
                assert_eq!(qring, (0, 0), "gather path must not touch qring");
            }
            PassStrategy::PassQ => {
                assert_eq!(
                    qring,
                    ((n - 1) as u64 * gather_bytes,
                     (exchanges * n * (n - 1) * layers) as u64),
                    "pass-Q qring (bytes, rounds)"
                );
                assert_eq!(att, (0, 0), "rotation must not touch att");
            }
            PassStrategy::Auto => unreachable!(),
        }
        outcomes.push((gen.tokens, gen.query_logits));
    }
    assert_eq!(outcomes[0], outcomes[1],
               "pass strategies must generate bit-identically");
}

// ---------------------------------------------------------------------------
// Golden tier — PJRT artifacts only
// ---------------------------------------------------------------------------

#[test]
fn golden_generation_matches_python() {
    let Some(cfg) = tiny_config() else { return };
    let (golden, n_new) = load_golden(&cfg)
        .expect("golden section")
        .expect("tiny config carries a golden blob");
    let doc = golden.i32s("doc_tokens").unwrap();
    let query = golden.i32s("query_tokens").unwrap();
    let want_gen = golden.i32s("generated").unwrap();
    let want_logits = golden.tensor("query_logits").unwrap();

    let cluster = Cluster::start(&cfg).expect("cluster start");
    let opts = ApbOptions::default();
    let report = cluster.prefill(&doc, &query, &opts).expect("prefill");
    assert!(report.comm_bytes > 0, "prefill must move compressed blocks");
    for t in &report.per_host {
        assert!(t.total_s > 0.0);
    }

    let gen = cluster.generate(&query, n_new).expect("generate");
    assert_eq!(gen.tokens, want_gen, "greedy tokens must match python");

    // Query-chunk logits: identical computation modulo HLO scheduling.
    assert_eq!(gen.query_logits.len(), want_logits.data.len());
    let mut max_abs = 0f32;
    let mut max_rel = 0f32;
    for (a, b) in gen.query_logits.iter().zip(&want_logits.data) {
        let d = (a - b).abs();
        max_abs = max_abs.max(d);
        max_rel = max_rel.max(d / b.abs().max(1.0));
    }
    assert!(
        max_abs < 2e-3 && max_rel < 2e-3,
        "logits diverged: max_abs={max_abs} max_rel={max_rel}"
    );
}

#[test]
fn prefill_is_deterministic_across_runs() {
    let Some(cfg) = tiny_config() else { return };
    let (golden, _) = load_golden(&cfg).unwrap().unwrap();
    let doc = golden.i32s("doc_tokens").unwrap();
    let query = golden.i32s("query_tokens").unwrap();

    let cluster = Cluster::start(&cfg).expect("cluster start");
    let opts = ApbOptions::default();
    cluster.prefill(&doc, &query, &opts).unwrap();
    let g1 = cluster.generate(&query, 3).unwrap();
    cluster.clear().unwrap();
    cluster.prefill(&doc, &query, &opts).unwrap();
    let g2 = cluster.generate(&query, 3).unwrap();
    assert_eq!(g1.tokens, g2.tokens);
    assert_eq!(g1.query_logits, g2.query_logits);
}

#[test]
fn ablations_change_generation_but_stay_finite() {
    let Some(cfg) = tiny_config() else { return };
    let (golden, _) = load_golden(&cfg).unwrap().unwrap();
    let doc = golden.i32s("doc_tokens").unwrap();
    let query = golden.i32s("query_tokens").unwrap();
    let cluster = Cluster::start(&cfg).expect("cluster start");
    assert_ablations_change_generation(&cluster, &doc, &query);
}
