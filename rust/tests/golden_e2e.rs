//! End-to-end integration: the rust cluster replays the AOT artifacts and
//! must reproduce the golden outputs recorded by the python cluster
//! simulation (aot.py::build_golden) — same tokens, same logits.
//!
//! Requires `make artifacts` (skips with a notice otherwise).

use apb::config::ApbOptions;
use apb::coordinator::Cluster;
use apb::runtime::load_golden;

fn tiny_config() -> Option<apb::config::Config> {
    match apb::load_config("tiny") {
        Ok(c) => Some(c),
        Err(e) => {
            eprintln!("SKIP golden_e2e: artifacts/tiny not built ({e:#})");
            None
        }
    }
}

#[test]
fn golden_generation_matches_python() {
    let Some(cfg) = tiny_config() else { return };
    let (golden, n_new) = load_golden(&cfg)
        .expect("golden section")
        .expect("tiny config carries a golden blob");
    let doc = golden.i32s("doc_tokens").unwrap();
    let query = golden.i32s("query_tokens").unwrap();
    let want_gen = golden.i32s("generated").unwrap();
    let want_logits = golden.tensor("query_logits").unwrap();

    let cluster = Cluster::start(&cfg).expect("cluster start");
    let opts = ApbOptions::default();
    let report = cluster.prefill(&doc, &query, &opts).expect("prefill");
    assert!(report.comm_bytes > 0, "prefill must move compressed blocks");
    for t in &report.per_host {
        assert!(t.total_s > 0.0);
    }

    let gen = cluster.generate(&query, n_new).expect("generate");
    assert_eq!(gen.tokens, want_gen, "greedy tokens must match python");

    // Query-chunk logits: identical computation modulo HLO scheduling.
    assert_eq!(gen.query_logits.len(), want_logits.data.len());
    let mut max_abs = 0f32;
    let mut max_rel = 0f32;
    for (a, b) in gen.query_logits.iter().zip(&want_logits.data) {
        let d = (a - b).abs();
        max_abs = max_abs.max(d);
        max_rel = max_rel.max(d / b.abs().max(1.0));
    }
    assert!(
        max_abs < 2e-3 && max_rel < 2e-3,
        "logits diverged: max_abs={max_abs} max_rel={max_rel}"
    );
}

#[test]
fn prefill_is_deterministic_across_runs() {
    let Some(cfg) = tiny_config() else { return };
    let (golden, _) = load_golden(&cfg).unwrap().unwrap();
    let doc = golden.i32s("doc_tokens").unwrap();
    let query = golden.i32s("query_tokens").unwrap();

    let cluster = Cluster::start(&cfg).expect("cluster start");
    let opts = ApbOptions::default();
    cluster.prefill(&doc, &query, &opts).unwrap();
    let g1 = cluster.generate(&query, 3).unwrap();
    cluster.clear().unwrap();
    cluster.prefill(&doc, &query, &opts).unwrap();
    let g2 = cluster.generate(&query, 3).unwrap();
    assert_eq!(g1.tokens, g2.tokens);
    assert_eq!(g1.query_logits, g2.query_logits);
}

#[test]
fn ablations_change_generation_but_stay_finite() {
    let Some(cfg) = tiny_config() else { return };
    let (golden, _) = load_golden(&cfg).unwrap().unwrap();
    let doc = golden.i32s("doc_tokens").unwrap();
    let query = golden.i32s("query_tokens").unwrap();
    let cluster = Cluster::start(&cfg).expect("cluster start");

    let variants = [
        ApbOptions { use_passing: false, ..Default::default() },
        ApbOptions { use_anchor: false, ..Default::default() },
        ApbOptions { retaining_compressor: false, ..Default::default() },
        ApbOptions { embed_query: false, ..Default::default() },
    ];
    let baseline = {
        cluster.clear().unwrap();
        cluster.prefill(&doc, &query, &ApbOptions::default()).unwrap();
        cluster.generate(&query, 2).unwrap().query_logits
    };
    for (i, opts) in variants.iter().enumerate() {
        cluster.clear().unwrap();
        let rep = cluster.prefill(&doc, &query, opts).unwrap();
        if !opts.use_passing {
            assert_eq!(rep.comm_bytes, 0, "no-passing must not communicate");
        }
        let gen = cluster.generate(&query, 2).unwrap();
        assert!(gen.query_logits.iter().all(|x| x.is_finite()),
                "variant {i} produced non-finite logits");
        let diff: f32 = gen
            .query_logits
            .iter()
            .zip(&baseline)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(diff > 1e-6, "variant {i} did not change the computation");
    }
}
