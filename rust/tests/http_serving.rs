//! Tier-1 conformance suite for the HTTP/1.1 front door
//! (`rust/src/http/`, `docs/ADR-008-http-front-door.md`).
//!
//! What it pins, end-to-end over real loopback sockets:
//!
//! * **Bit-identity**: the token stream out of `POST /v1/generate` equals
//!   a direct `Cluster` prefill+generate of the same request, for every
//!   `AttnMethod`, and the terminal `done` event's `tokens` array equals
//!   the streamed sequence (dense indices, own chunk per event line).
//! * **Multi-turn**: `keep: true` returns a session id whose follow-up
//!   `turn` streams match a direct `append_turn` + greedy decode mirror.
//! * **Backpressure**: a KV pool fully held by persistent sessions turns
//!   plain generates into `429` + `Retry-After`; `DELETE /v1/session/<id>`
//!   frees a slot and the identical request then succeeds.
//! * **Metrics**: `GET /v1/metrics` is valid JSON whose latency summaries
//!   satisfy p50 <= p95 <= p99, with per-host pool stats.
//! * **Concurrency**: parallel connections stream identical tokens under
//!   BOTH host drivers (sequential and threaded legs in one test, on top
//!   of whatever `APB_DRIVER` leg CI pinned for the rest of the suite).
//!
//! Runs on the native SimEngine (non-skipping tier-1; prints `APB-RUN`).

use std::thread;

use apb::config::{ApbOptions, AttnMethod, Config};
use apb::coordinator::{Cluster, Driver};
use apb::http::{HttpClient, HttpOptions, HttpResponse, Server};
use apb::util::json::{Json, JsonWriter};
use apb::util::rng::Rng;
use apb::util::tensor::Tensor;

/// Seeded (doc, query) of the config's exact geometry.
fn request_tokens(cfg: &Config, seed: u64) -> (Vec<i32>, Vec<i32>) {
    let mut rng = Rng::new(seed);
    let doc: Vec<i32> = (0..cfg.apb.doc_len())
        .map(|_| rng.range(1, cfg.model.vocab_size as i64) as i32)
        .collect();
    let query: Vec<i32> = (0..cfg.apb.query_len)
        .map(|_| rng.range(1, cfg.model.vocab_size as i64) as i32)
        .collect();
    (doc, query)
}

fn start_server(driver: Driver) -> Server {
    Server::start(Config::sim_tiny(), driver, HttpOptions::default()).expect("server start")
}

fn generate_body(doc: &[i32], query: &[i32], max_new: usize, method: &str) -> String {
    JsonWriter::obj()
        .tokens_field("doc", doc)
        .tokens_field("query", query)
        .num_field("max_new", max_new as f64)
        .str_field("method", method)
        .close()
}

/// A decoded `/v1/generate` stream, with the wire-contract assertions
/// (dense indices, done.tokens == streamed sequence, no error) applied.
struct Streamed {
    tokens: Vec<i32>,
    done: Json,
    /// HTTP chunks that carried at least one token event — >= 2 proves the
    /// response actually streamed rather than arriving as one buffer.
    token_chunks: usize,
}

fn decode_stream(resp: &HttpResponse) -> Streamed {
    assert_eq!(resp.status, 200, "generate failed: {}", resp.body_str());
    let mut tokens: Vec<i32> = Vec::new();
    let mut done: Option<Json> = None;
    let mut token_chunks = 0usize;
    for chunk in &resp.chunks {
        let text = std::str::from_utf8(chunk).expect("UTF-8 event chunk");
        let mut chunk_has_token = false;
        for line in text.lines().filter(|l| !l.trim().is_empty()) {
            let ev = Json::parse(line).expect("event line is JSON");
            let kind = ev
                .req("event")
                .expect("event field")
                .as_str()
                .expect("event is a string")
                .to_string();
            match kind.as_str() {
                "token" => {
                    assert_eq!(
                        ev.req("index").unwrap().as_usize(),
                        Some(tokens.len()),
                        "token indices must be dense and in order"
                    );
                    tokens.push(ev.req("token").unwrap().as_i64().expect("token i32") as i32);
                    chunk_has_token = true;
                }
                "done" => {
                    assert!(done.is_none(), "two done events in one stream");
                    done = Some(ev);
                }
                other => panic!("unknown event kind '{other}'"),
            }
        }
        if chunk_has_token {
            token_chunks += 1;
        }
    }
    let done = done.expect("stream must end in a done event");
    assert!(done.get("error").is_none(), "stream errored: {}", done.dumps());
    let echoed: Vec<i32> = done
        .req("tokens")
        .expect("done.tokens")
        .as_arr()
        .expect("tokens array")
        .iter()
        .map(|t| t.as_i64().expect("token i32") as i32)
        .collect();
    assert_eq!(echoed, tokens, "done.tokens must equal the streamed sequence");
    Streamed { tokens, done, token_chunks }
}

#[test]
fn streamed_generate_is_bit_identical_to_a_direct_cluster_for_all_methods() {
    let driver = Driver::from_env();
    println!("APB-RUN http_serving backend=sim driver={}", driver.name());
    let cfg = Config::sim_tiny();
    let server = start_server(driver);
    let addr = server.local_addr().to_string();
    // Independent direct cluster: same config seed => identical synthetic
    // weights, so it is a true oracle for the server's internal cluster.
    let oracle = Cluster::start_with(&cfg, driver).expect("oracle cluster");
    let mut client = HttpClient::connect(&addr).expect("connect");
    let max_new = 5;
    let methods = [
        (AttnMethod::Apb, "apb"),
        (AttnMethod::StarAttn, "star"),
        (AttnMethod::RingAttn, "ring"),
        (AttnMethod::Dense, "dense"),
    ];
    for (i, (method, name)) in methods.into_iter().enumerate() {
        let (doc, query) = request_tokens(&cfg, 0xD0C0 + i as u64);
        let resp = client
            .request("POST", "/v1/generate", Some(&generate_body(&doc, &query, max_new, name)))
            .expect("request");
        let got = decode_stream(&resp);
        assert!(
            got.token_chunks >= 2,
            "method {name}: response arrived in {} token chunk(s) — not streamed",
            got.token_chunks
        );
        assert_eq!(got.tokens.len(), max_new, "method {name}: token budget");
        oracle.clear().expect("clear oracle");
        let opts = ApbOptions { method, ..Default::default() };
        oracle.prefill(&doc, &query, &opts).expect("oracle prefill");
        let want = oracle.generate(&query, max_new).expect("oracle generate").tokens;
        assert_eq!(
            got.tokens, want,
            "method {name}: HTTP stream diverged from the direct cluster"
        );
    }
}

#[test]
fn keep_and_append_turn_streams_match_a_direct_session_mirror() {
    let driver = Driver::from_env();
    println!("APB-RUN http_serving_turns backend=sim driver={}", driver.name());
    let cfg = Config::sim_tiny();
    let server = start_server(driver);
    let addr = server.local_addr().to_string();
    let mut client = HttpClient::connect(&addr).expect("connect");
    let (doc, query) = request_tokens(&cfg, 0x7EE7);
    let max_new = 3;

    // keep: true => persistent session + streamed first turn.
    let body = JsonWriter::obj()
        .tokens_field("doc", &doc)
        .tokens_field("query", &query)
        .num_field("max_new", max_new as f64)
        .bool_field("keep", true)
        .close();
    let got = decode_stream(&client.request("POST", "/v1/generate", Some(&body)).expect("keep"));
    let sid = got.done.req("session").expect("session id").as_i64().expect("numeric") as u64;

    // Follow-up turn against the kept session.
    let (_, turn) = request_tokens(&cfg, 0x7EE8);
    let body2 = JsonWriter::obj()
        .num_field("session", sid as f64)
        .tokens_field("turn", &turn)
        .num_field("max_new", max_new as f64)
        .close();
    let got2 = decode_stream(&client.request("POST", "/v1/generate", Some(&body2)).expect("turn"));

    // Direct mirror: same ops on an independent cluster.
    let mirror = Cluster::start_with(&cfg, driver).expect("mirror cluster");
    let vocab = cfg.model.vocab_size;
    let opts = ApbOptions::default();
    mirror.prefill_session(1, &doc, &query, &opts).expect("prefill");
    let chunk = mirror.decode_query_chunk(1, &query).expect("query chunk");
    let mut tok = Tensor::argmax_row(&chunk.logits[chunk.logits.len() - vocab..]) as i32;
    let mut want = vec![tok];
    while want.len() < max_new {
        let rep = mirror.decode_step_batch(&[(1, tok)]).expect("step");
        tok = Tensor::argmax_row(&rep.logits[0].1) as i32;
        want.push(tok);
    }
    assert_eq!(got.tokens, want, "keep-generate diverged from the direct mirror");

    let chunk2 = mirror.append_turn(1, &turn).expect("append turn");
    let mut tok2 = Tensor::argmax_row(&chunk2.logits[chunk2.logits.len() - vocab..]) as i32;
    let mut want2 = vec![tok2];
    while want2.len() < max_new {
        let rep = mirror.decode_step_batch(&[(1, tok2)]).expect("step");
        tok2 = Tensor::argmax_row(&rep.logits[0].1) as i32;
        want2.push(tok2);
    }
    assert_eq!(got2.tokens, want2, "append-turn stream diverged from the direct mirror");

    // Clearing the session invalidates further turns.
    let resp = client
        .request("DELETE", &format!("/v1/session/{sid}"), None)
        .expect("clear");
    assert_eq!(resp.status, 200);
    let resp = client.request("POST", "/v1/generate", Some(&body2)).expect("stale turn");
    assert_eq!(resp.status, 404, "turn on a cleared session must 404");
}

#[test]
fn pool_exhaustion_returns_429_and_recovers_after_session_clear() {
    let driver = Driver::from_env();
    println!("APB-RUN http_serving_backpressure backend=sim driver={}", driver.name());
    let cfg = Config::sim_tiny();
    let server = start_server(driver);
    let addr = server.local_addr().to_string();
    let mut client = HttpClient::connect(&addr).expect("connect");

    // Park a persistent session in every KV slot.
    let mut kept = Vec::new();
    for i in 0..cfg.apb.max_resident {
        let (doc, query) = request_tokens(&cfg, 0xF111 + i as u64);
        let body = JsonWriter::obj()
            .tokens_field("doc", &doc)
            .tokens_field("query", &query)
            .num_field("max_new", 1.0)
            .bool_field("keep", true)
            .close();
        let got =
            decode_stream(&client.request("POST", "/v1/generate", Some(&body)).expect("keep"));
        kept.push(got.done.req("session").unwrap().as_i64().unwrap() as u64);
    }

    // A plain generate can now never admit: backpressure, not a 5xx.
    let (doc, query) = request_tokens(&cfg, 0xF200);
    let body = generate_body(&doc, &query, 2, "apb");
    let resp = client.request("POST", "/v1/generate", Some(&body)).expect("overload");
    assert_eq!(resp.status, 429, "full pool must map to 429: {}", resp.body_str());
    let retry: u64 = resp
        .header("retry-after")
        .expect("429 must carry Retry-After")
        .parse()
        .expect("Retry-After is seconds");
    assert!(retry >= 1);

    // Freeing one slot un-wedges the identical request.
    let resp = client
        .request("DELETE", &format!("/v1/session/{}", kept[0]), None)
        .expect("clear");
    assert_eq!(resp.status, 200);
    let got = decode_stream(&client.request("POST", "/v1/generate", Some(&body)).expect("retry"));
    assert_eq!(got.tokens.len(), 2);

    // Session-clear edges: double clear and unknown ids are 404s.
    let resp = client
        .request("DELETE", &format!("/v1/session/{}", kept[0]), None)
        .expect("double clear");
    assert_eq!(resp.status, 404, "double clear must 404");
    let resp = client.request("DELETE", "/v1/session/999999999", None).expect("unknown");
    assert_eq!(resp.status, 404);
}

#[test]
fn metrics_roundtrip_reports_ordered_percentiles_and_pool_stats() {
    let driver = Driver::from_env();
    println!("APB-RUN http_serving_metrics backend=sim driver={}", driver.name());
    let cfg = Config::sim_tiny();
    let server = start_server(driver);
    let addr = server.local_addr().to_string();
    let mut client = HttpClient::connect(&addr).expect("connect");
    let n = 3usize;
    for i in 0..n {
        let (doc, query) = request_tokens(&cfg, 0x3E7 + i as u64);
        let resp = client
            .request("POST", "/v1/generate", Some(&generate_body(&doc, &query, 3, "apb")))
            .expect("generate");
        decode_stream(&resp);
    }
    let resp = client.request("GET", "/v1/metrics", None).expect("metrics");
    assert_eq!(resp.status, 200);
    let m = Json::parse(&resp.body_str()).expect("metrics JSON parses");
    assert_eq!(m.req("schema_version").unwrap().as_i64(), Some(1));
    assert_eq!(m.req("driver").unwrap().as_str(), Some(driver.name()));
    assert!(m.req("n_requests").unwrap().as_usize().unwrap() >= n);
    assert!(m.req("served").unwrap().as_usize().unwrap() >= n);
    let pool = m.req("pool").unwrap().as_arr().expect("pool array");
    assert_eq!(pool.len(), cfg.apb.n_hosts, "one pool entry per host");
    for host in pool {
        assert!(host.req("bytes_used").unwrap().as_f64().is_some());
        assert!(host.req("slabs_free").unwrap().as_f64().is_some());
    }
    for summary in ["ttft_ticks", "ttft_ms", "tpot_ms"] {
        let s = m.req(summary).unwrap();
        let p50 = s.req("p50").unwrap().as_f64().unwrap();
        let p95 = s.req("p95").unwrap().as_f64().unwrap();
        let p99 = s.req("p99").unwrap().as_f64().unwrap();
        assert!(
            p50 <= p95 && p95 <= p99,
            "{summary} percentiles disordered: {p50}/{p95}/{p99}"
        );
    }
}

#[test]
fn concurrent_connections_stream_identical_tokens_on_both_drivers() {
    println!("APB-RUN http_serving_concurrent backend=sim");
    let cfg = Config::sim_tiny();
    let max_new = 4;
    let n_conns = 4usize;
    let reqs: Vec<(Vec<i32>, Vec<i32>)> =
        (0..n_conns).map(|i| request_tokens(&cfg, 0xCC00 + i as u64)).collect();
    // One sequential direct oracle serves as the reference for BOTH legs —
    // so this also proves the two drivers agree with each other over HTTP.
    let want: Vec<Vec<i32>> = {
        let oracle = Cluster::start_with(&cfg, Driver::Sequential).expect("oracle");
        reqs.iter()
            .map(|(doc, query)| {
                oracle.clear().expect("clear");
                oracle.prefill(doc, query, &ApbOptions::default()).expect("prefill");
                oracle.generate(query, max_new).expect("generate").tokens
            })
            .collect()
    };
    for driver in [Driver::Sequential, Driver::Threaded] {
        let server = start_server(driver);
        let addr = server.local_addr().to_string();
        let handles: Vec<_> = reqs
            .iter()
            .map(|(doc, query)| {
                let body = generate_body(doc, query, max_new, "apb");
                let addr = addr.clone();
                thread::spawn(move || {
                    let mut client = HttpClient::connect(&addr).expect("connect");
                    let resp =
                        client.request("POST", "/v1/generate", Some(&body)).expect("generate");
                    decode_stream(&resp).tokens
                })
            })
            .collect();
        for (i, h) in handles.into_iter().enumerate() {
            let got = h.join().expect("client thread");
            assert_eq!(
                got, want[i],
                "driver {}: concurrent connection {i} diverged from the oracle",
                driver.name()
            );
        }
    }
}

#[test]
fn malformed_requests_map_to_4xx_and_keep_the_connection_alive() {
    let driver = Driver::from_env();
    println!("APB-RUN http_serving_errors backend=sim driver={}", driver.name());
    let cfg = Config::sim_tiny();
    let server = start_server(driver);
    let addr = server.local_addr().to_string();
    let mut client = HttpClient::connect(&addr).expect("connect");
    let (doc, query) = request_tokens(&cfg, 0xE44);

    let resp = client.request("GET", "/v1/healthz", None).expect("health");
    assert_eq!(resp.status, 200);

    // Every rejection below is answered on the SAME keep-alive connection.
    let cases: [(String, u16); 7] = [
        ("this is not json".into(), 400),
        // wrong geometry
        (generate_body(&[1, 2, 3], &query, 2, "apb"), 400),
        // missing doc/query
        (JsonWriter::obj().num_field("max_new", 2.0).close(), 400),
        // unknown method
        (generate_body(&doc, &query, 2, "bogus"), 400),
        // turn without session / session without turn
        (
            JsonWriter::obj().tokens_field("turn", &[1, 2]).num_field("max_new", 1.0).close(),
            400,
        ),
        (JsonWriter::obj().num_field("session", 7.0).close(), 400),
        // turn against a session that never existed
        (
            JsonWriter::obj()
                .num_field("session", 123456.0)
                .tokens_field("turn", &[1, 2])
                .close(),
            404,
        ),
    ];
    for (body, want) in &cases {
        let resp = client.request("POST", "/v1/generate", Some(body)).expect("request");
        assert_eq!(resp.status, *want, "body {body:?} -> {}", resp.body_str());
    }
    let resp = client.request("GET", "/v1/generate", None).expect("wrong verb");
    assert_eq!(resp.status, 405);
    let resp = client.request("GET", "/v1/nope", None).expect("unknown route");
    assert_eq!(resp.status, 404);
    let resp = client.request("DELETE", "/v1/session/notanumber", None).expect("bad id");
    assert_eq!(resp.status, 404);

    // ...and the connection still serves a real generate afterwards.
    let resp = client
        .request("POST", "/v1/generate", Some(&generate_body(&doc, &query, 2, "apb")))
        .expect("valid generate");
    let got = decode_stream(&resp);
    assert_eq!(got.tokens.len(), 2);
}
