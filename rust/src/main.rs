//! `apb` — leader entrypoint / CLI.
//!
//! Subcommands:
//!   info                         artifact + config inventory
//!   run      [--config tiny]     one request end-to-end on the cluster
//!   serve    [--requests N]      scheduler-driven serving demo
//!   simulate [--lengths ...]     analytical prefill/speed estimates
//!   eval     [--suite ruler]     oracle accuracy table
//!   golden                       replay + verify the python golden run

use anyhow::{bail, Context, Result};

use apb::attnsim::{estimate, speed_tok_per_s, Hyper, Method, A800, LLAMA31_8B};
use apb::bench_harness::Table;
use apb::cluster::Interconnect;
use apb::config::{ApbOptions, AttnMethod, PassStrategy};
use apb::coordinator::scheduler::{Request, Scheduler};
use apb::coordinator::{Cluster, Driver};
use apb::http::{HttpClient, HttpOptions, HttpResponse, Server};
use apb::util::json::{self, Json, JsonWriter};
use apb::workload::{self, TraceSpec};
use apb::oracle::{expected_score, AccMethod, ApbQuality, EvalCtx};
use apb::ruler::tasks::{infbench_tasks, ruler_tasks, ModelCol};
use apb::ruler::{gen_instance, TaskKind};
use apb::util::cli::Args;
use apb::util::rng::Rng;

const USAGE: &str = "usage: apb <info|run|serve|simulate|eval|golden> [options]
  info                              list artifacts and config
  run      --config tiny --max-new 8 --method apb|star|ring|dense
           --driver threaded|sequential (host execution driver; default
           $APB_DRIVER or threaded)
           --pass-strategy kv|q|auto (decode merge transport: pass-KV att
           AllGather, pass-Q qring rotation, or the leader-side adaptive
           chooser — bit-identical either way; docs/ADR-007)
  serve    --config tiny --requests 4 --max-new 4 --method apb|star|ring|dense
           --driver threaded|sequential --pass-strategy kv|q|auto
           --chunk-tokens N (prefill chunk size; smaller = finer decode
           interleaving) --prefix-cache (shared-prefix KV reuse: requests
           over one corpus skip repeat prefills) --smoke (CI gate: assert
           stall-free serving; with --prefix-cache also warm < cold TTFT)
           --trace smoke|adversarial|poisson|bursty|soak (drive a seeded
           workload trace through the SLO scheduler: priority classes,
           aging, preemption; prints p50/p95/p99 TTFT/TPOT + per-class
           goodput and writes BENCH_serving.json)
           --trace-seed N (reseed the trace generator)
           --sweep 1,2,4 (with --trace: replay the trace CLOSED-LOOP at
           each multiprogramming level, print the latency/goodput curve
           instead of the open-loop run, and write BENCH_sweep.json)
           --http 127.0.0.1:8080 (serve over HTTP/1.1 instead of the
           in-process demo: POST /v1/generate streams NDJSON token
           events via chunked transfer-encoding, GET /v1/metrics,
           DELETE /v1/session/<id>; docs/serving-guide.md. With --smoke:
           run the self-contained CI drill — health check, 429 + retry
           under a pool filled by persistent sessions, closed-loop
           'smoke'-trace replay, metrics sanity — then exit)
           --http-conns N (connection cap for --http; default 64)
           --queue N (admission queue bound; default 64)
  simulate --lengths 32768,131072 --hosts 8
  eval     --suite ruler|infbench --n 131072 --hosts 8
  golden   --config tiny";

/// Resolve the attention method from `--method`. (The pre-`AttnMethod`
/// `--star-mode` alias is gone; spell it `--method star`.)
fn method_from(args: &Args) -> Result<AttnMethod> {
    match args.get("method") {
        Some(s) => AttnMethod::parse(s),
        None => Ok(AttnMethod::Apb),
    }
}

/// Print the per-label measured communication of one cluster run.
fn print_comm(cluster: &Cluster) {
    let m = &cluster.fabric.meter;
    println!(
        "comm: kv {} B / {} rounds | ring {} B / {} rounds | att {} B / {} rounds \
         | qring {} B / {} rounds",
        m.bytes_for(Interconnect::KV_LABEL),
        m.rounds_for(Interconnect::KV_LABEL),
        m.bytes_for(Interconnect::RING_LABEL),
        m.rounds_for(Interconnect::RING_LABEL),
        m.bytes_for(Interconnect::ATT_LABEL),
        m.rounds_for(Interconnect::ATT_LABEL),
        m.bytes_for(Interconnect::QRING_LABEL),
        m.rounds_for(Interconnect::QRING_LABEL),
    );
}

/// Resolve the decode pass strategy from `--pass-strategy`
/// (`docs/ADR-007-adaptive-decode.md`); the pass-KV gather is the default.
fn strategy_from(args: &Args) -> Result<PassStrategy> {
    match args.get("pass-strategy") {
        Some(s) => PassStrategy::parse(s),
        None => Ok(PassStrategy::PassKv),
    }
}

/// Resolve the host execution driver from `--driver`, falling back to the
/// `APB_DRIVER` environment default.
fn driver_from(args: &Args) -> Result<Driver> {
    match args.get("driver") {
        Some(s) => Driver::parse(s)
            .ok_or_else(|| anyhow::anyhow!(
                "--driver={s} is not a driver (expected sequential|threaded)")),
        None => Ok(Driver::from_env()),
    }
}

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1), &["smoke", "help", "prefix-cache"])?;
    if args.has("help") || args.positional.is_empty() {
        println!("{USAGE}");
        return Ok(());
    }
    match args.positional[0].as_str() {
        "info" => info(&args),
        "run" => run(&args),
        "serve" => serve(&args),
        "simulate" => simulate(&args),
        "eval" => eval(&args),
        "golden" => golden(&args),
        other => bail!("unknown subcommand '{other}'\n{USAGE}"),
    }
}

fn info(args: &Args) -> Result<()> {
    let cfg = apb::load_config_or_sim(&args.str_or("config", "tiny"))?;
    println!("config '{}' (backend: {})", cfg.name, cfg.backend.name());
    println!("  model: d={} L={} heads={}/{} ffn={} vocab={}",
             cfg.model.d_model, cfg.model.n_layers, cfg.model.n_heads,
             cfg.model.n_kv_heads, cfg.model.d_ff, cfg.model.vocab_size);
    println!("  apb:   H={} l_b={} l_a={} l_q={} l_p={} (pass_max={}, cache_max={}, \
              chunk_tokens={})",
             cfg.apb.n_hosts, cfg.apb.block_len, cfg.apb.anchor_len,
             cfg.apb.query_len, cfg.apb.passing_len, cfg.apb.pass_max(),
             cfg.apb.cache_max(), cfg.apb.chunk_tokens);
    match cfg.manifest.get("artifacts").and_then(|a| a.as_obj()) {
        Some(arts) => {
            println!("  artifacts ({}):", arts.len());
            for (name, meta) in arts {
                let ins = meta.req("inputs")?.as_arr().unwrap().len();
                let outs = meta.req("outputs")?.as_arr().unwrap().len();
                println!("    {name:<18} {ins:>2} inputs -> {outs} outputs");
            }
        }
        None => println!("  artifacts: none (native SimEngine, synthetic weights)"),
    }
    Ok(())
}

fn default_request(cfg: &apb::config::Config, seed: u64) -> (Vec<i32>, Vec<i32>) {
    let mut rng = Rng::new(seed);
    let inst = gen_instance(cfg, TaskKind::SingleNiah, &mut rng);
    (inst.doc, inst.query)
}

fn run(args: &Args) -> Result<()> {
    let method = method_from(args)?;
    let cfg = apb::load_config_or_sim(&args.str_or("config", "tiny"))?
        .with_method(method)
        .with_pass_strategy(strategy_from(args)?);
    let cluster = Cluster::start_with(&cfg, driver_from(args)?)?;
    let (doc, query) = default_request(&cfg, args.usize_or("seed", 1)? as u64);
    let opts = ApbOptions { method, ..Default::default() };
    let rep = cluster.prefill(&doc, &query, &opts)?;
    let gen = cluster.generate(&query, args.usize_or("max-new", 8)?)?;
    println!("method {} (exact attention: {}) | driver {}", method.name(),
             method.exact_attention(), cluster.driver().name());
    println!("tokens: {:?}", gen.tokens);
    println!("prefill {:.1} ms | decode {:.1} ms | prefill comm {} B",
             rep.wall_seconds * 1e3, gen.wall_seconds * 1e3, rep.comm_bytes);
    print_comm(&cluster);
    Ok(())
}

fn serve(args: &Args) -> Result<()> {
    let method = method_from(args)?;
    let prefix_cache = args.has("prefix-cache");
    let mut cfg = apb::load_config_or_sim(&args.str_or("config", "tiny"))?
        .with_method(method)
        .with_prefix_cache(prefix_cache)
        .with_pass_strategy(strategy_from(args)?);
    // Cluster-wide chunked-prefill granularity (per-request overrides ride
    // on ApbOptions::chunk_tokens).
    cfg.apb.chunk_tokens = args.usize_or("chunk-tokens", cfg.apb.chunk_tokens)?.max(1);
    if args.get("http").is_some() {
        return serve_http(args, cfg, driver_from(args)?);
    }
    let cluster = Cluster::start_with(&cfg, driver_from(args)?)?;
    if args.get("trace").is_some() {
        return serve_trace(args, &cfg, &cluster);
    }
    let mut sched = Scheduler::new(&cluster, args.usize_or("queue", 64)?);
    let n = args.usize_or("requests", 4)?;
    let max_new = args.usize_or("max-new", 4)?;
    let mut rng = Rng::new(3);
    if prefix_cache {
        // The multi-tenant shared-corpus pattern the cache exists for:
        // every request queries the SAME document (request 1 is the cold
        // miss that freezes the prefix; the rest hit). Served sequentially
        // so each warm TTFT is pure service time, not queue wait behind
        // the cold prefill.
        let inst = gen_instance(&cfg, TaskKind::SingleNiah, &mut rng);
        for id in 0..n {
            sched.submit(Request {
                id: id as u64,
                doc: inst.doc.clone(),
                query: inst.query.clone(),
                max_new,
                opts: ApbOptions { method, ..Default::default() },
                class: Default::default(),
            })?;
            sched.run_all()?;
        }
    } else {
        for id in 0..n {
            let inst = gen_instance(&cfg, TaskKind::SingleNiah, &mut rng);
            sched.submit(Request {
                id: id as u64,
                doc: inst.doc,
                query: inst.query,
                max_new,
                opts: ApbOptions { method, ..Default::default() },
                class: Default::default(),
            })?;
        }
        sched.run_all()?;
    }
    let m = sched.metrics();
    println!("served {} requests ({} sessions resident at peak) | prefill p50 \
              {:.1} ms over {:.0} chunk steps | ttft p50 {:.1} ms | tpot p50 \
              {:.2} ms | e2e p50 {:.1} ms | speed mean {:.0} tok/s",
             m.n_requests, m.peak_resident, m.prefill.p50 * 1e3,
             m.prefill_chunks.mean, m.ttft.p50 * 1e3, m.tpot.p50 * 1e3,
             m.e2e.p50 * 1e3, m.speed_tok_per_s.mean);
    if prefix_cache {
        let fmt = |s: Option<apb::util::stats::Summary>| match s {
            Some(s) => format!("{:.2} ms", s.p50 * 1e3),
            None => "-".into(),
        };
        println!("prefix cache: {} hits | {} KV bytes saved | ttft p50 cold {} \
                  / warm {}",
                 m.prefix_hits, m.prefix_bytes_saved, fmt(m.ttft_cold),
                 fmt(m.ttft_warm));
    }
    if args.has("smoke") {
        // CI gate for stall-free serving: every request completed, each was
        // admitted through the resumable chunk driver, and (when slots
        // allow) sessions actually overlapped on the cluster.
        anyhow::ensure!(m.n_requests == n, "smoke: {} of {n} requests completed",
                        m.n_requests);
        anyhow::ensure!(m.prefill_chunks.min >= 1.0,
                        "smoke: a request bypassed chunked admission");
        if !prefix_cache && n >= 2 && cfg.apb.max_resident >= 2 {
            anyhow::ensure!(m.peak_resident >= 2,
                            "smoke: expected >= 2 resident sessions, saw {}",
                            m.peak_resident);
        }
        if prefix_cache && n >= 2 {
            // The shared-corpus gate: every request after the first must
            // hit the prefix store, skip real KV bytes, and reach its
            // first token faster than the cold miss did (warm admission is
            // one attach step instead of a whole document pass).
            anyhow::ensure!(m.prefix_hits == n - 1,
                            "smoke: expected {} prefix hits, saw {}", n - 1,
                            m.prefix_hits);
            anyhow::ensure!(m.prefix_bytes_saved > 0,
                            "smoke: prefix hits must save KV bytes");
            // Warm TTFT must beat the cold miss. Wall-clock on a tiny
            // config can absorb a scheduler hiccup, so gate on the BEST
            // warm sample (an OS preemption would have to hit every warm
            // request to flake this) — the structural facts (hits, zero
            // comm, one-step admission) are asserted above regardless.
            let cold = m.ttft_cold.expect("one cold request").min;
            let warm = m.ttft_warm.expect("warm requests").min;
            anyhow::ensure!(warm < cold,
                            "smoke: best warm TTFT {:.3} ms !< cold TTFT {:.3} ms",
                            warm * 1e3, cold * 1e3);
        }
        println!("apb serve --smoke OK (chunk_tokens {}, prefix cache {}, driver {})",
                 cfg.apb.chunk_tokens, if prefix_cache { "on" } else { "off" },
                 cluster.driver().name());
    }
    Ok(())
}

/// `apb serve --http <addr>`: run the std-only HTTP/1.1 front door
/// (`rust/src/http/`, `docs/ADR-008-http-front-door.md`) on this config.
/// Without `--smoke` the server runs until the process is killed; with
/// `--smoke` it drills itself over loopback — health check, 429 +
/// Retry-After under a pool fully held by persistent sessions (then
/// recovery after `DELETE /v1/session/<id>`), a closed-loop replay of the
/// `smoke` trace over real connections, a metrics sanity pass — and exits.
fn serve_http(args: &Args, cfg: apb::config::Config, driver: Driver) -> Result<()> {
    let opts = HttpOptions {
        addr: args.str_or("http", "127.0.0.1:0"),
        max_conns: args.usize_or("http-conns", 64)?,
        max_queue: args.usize_or("queue", 64)?,
        ..HttpOptions::default()
    };
    let smoke = args.has("smoke");
    let mut server = Server::start(cfg.clone(), driver, opts)?;
    let addr = server.local_addr().to_string();
    println!("apb http front door on {addr} (config '{}', driver {})",
             cfg.name, driver.name());
    if !smoke {
        return server.join();
    }
    // Run the drill before shutdown either way, so a failed gate still
    // tears the server down instead of leaking threads into the test run.
    let outcome = http_smoke(&cfg, &addr);
    server.shutdown()?;
    outcome?;
    println!("apb serve --http --smoke OK (driver {})", driver.name());
    Ok(())
}

/// Extract the persistent `session` id from a completed keep-generate
/// stream (the terminal `done` event carries it).
fn done_session(resp: &HttpResponse) -> Result<u64> {
    let body = resp.body_str();
    let last = body
        .lines()
        .rev()
        .find(|l| !l.trim().is_empty())
        .context("empty generate stream")?;
    let ev = Json::parse(last)?;
    anyhow::ensure!(ev.req("event")?.as_str() == Some("done"),
                    "stream did not end in a done event: {last}");
    ev.req("session")?
        .as_i64()
        .map(|s| s as u64)
        .context("done event without a session id")
}

/// The `--http --smoke` gate body. Asserts the three observables CI
/// cares about: full completion of a closed-loop trace replay, at least
/// one response streamed across >= 2 HTTP chunks, and backpressure
/// observed as 429 + Retry-After (with recovery after a session clear).
fn http_smoke(cfg: &apb::config::Config, addr: &str) -> Result<()> {
    let mut client = HttpClient::connect(addr)?;
    let resp = client.request("GET", "/v1/healthz", None)?;
    anyhow::ensure!(resp.status == 200, "healthz returned {}", resp.status);

    // Undersize the pool from the outside: park a persistent session in
    // every KV slot, so the next plain generate cannot ever admit.
    let mut rng = Rng::new(41);
    let mut kept: Vec<u64> = Vec::new();
    for _ in 0..cfg.apb.max_resident {
        let inst = gen_instance(cfg, TaskKind::SingleNiah, &mut rng);
        let body = JsonWriter::obj()
            .tokens_field("doc", &inst.doc)
            .tokens_field("query", &inst.query)
            .num_field("max_new", 1.0)
            .bool_field("keep", true)
            .close();
        let resp = client.request("POST", "/v1/generate", Some(&body))?;
        anyhow::ensure!(resp.status == 200, "keep generate returned {}", resp.status);
        kept.push(done_session(&resp)?);
    }
    let inst = gen_instance(cfg, TaskKind::SingleNiah, &mut rng);
    let body = JsonWriter::obj()
        .tokens_field("doc", &inst.doc)
        .tokens_field("query", &inst.query)
        .num_field("max_new", 2.0)
        .close();
    let resp = client.request("POST", "/v1/generate", Some(&body))?;
    anyhow::ensure!(resp.status == 429, "full pool must 429, got {}", resp.status);
    anyhow::ensure!(resp.header("retry-after").is_some(), "429 without Retry-After");
    // Freeing one slot un-wedges the identical request.
    let resp = client.request("DELETE", &format!("/v1/session/{}", kept[0]), None)?;
    anyhow::ensure!(resp.status == 200, "clear session returned {}", resp.status);
    let resp = client.request("POST", "/v1/generate", Some(&body))?;
    anyhow::ensure!(resp.status == 200, "post-clear generate returned {}", resp.status);
    for sid in &kept[1..] {
        let resp = client.request("DELETE", &format!("/v1/session/{sid}"), None)?;
        anyhow::ensure!(resp.status == 200, "clear session {sid} returned {}", resp.status);
    }
    println!("[http smoke] backpressure: 429 + Retry-After on a full pool, \
              recovered after DELETE /v1/session");

    // Closed-loop replay of the seeded smoke trace over real connections.
    let spec = TraceSpec::by_name("smoke").expect("smoke is a named spec");
    let trace = workload::generate(cfg, &spec)?;
    let report = workload::http::drive_http_trace(addr, &trace, 2)?;
    anyhow::ensure!(
        report.completed == trace.arrivals.len(),
        "smoke: {} of {} HTTP requests completed cleanly (429 {}, errors {}, dropped {})",
        report.completed, trace.arrivals.len(), report.rejected_429, report.errors,
        report.dropped
    );
    anyhow::ensure!(report.mismatches == 0,
                    "smoke: {} streams disagreed with their done.tokens", report.mismatches);
    anyhow::ensure!(report.multi_chunk >= 1,
                    "smoke: no response streamed across >= 2 HTTP chunks");
    println!("[http smoke] trace replay: {} completed | {} tokens | {} multi-chunk \
              streams | {} 429s absorbed",
             report.completed, report.total_tokens, report.multi_chunk,
             report.rejected_429);

    // Metrics sanity: well-formed JSON, counters advanced, percentiles
    // ordered.
    let resp = client.request("GET", "/v1/metrics", None)?;
    anyhow::ensure!(resp.status == 200, "metrics returned {}", resp.status);
    let m = Json::parse(&resp.body_str())?;
    let n = m.req("n_requests")?.as_f64().context("n_requests")?;
    anyhow::ensure!(n >= trace.arrivals.len() as f64,
                    "metrics n_requests {n} < trace size {}", trace.arrivals.len());
    let rejected = m.req("rejected_429")?.as_f64().context("rejected_429")?;
    anyhow::ensure!(rejected >= 1.0, "the observed 429 was not counted");
    let tt = m.req("ttft_ticks")?;
    let p50 = tt.req("p50")?.as_f64().context("p50")?;
    let p95 = tt.req("p95")?.as_f64().context("p95")?;
    let p99 = tt.req("p99")?.as_f64().context("p99")?;
    anyhow::ensure!(p50 <= p95 && p95 <= p99,
                    "ttft percentiles disordered: {p50}/{p95}/{p99}");
    println!("[http smoke] metrics: n_requests {n:.0} | ttft ticks p50/p95/p99 \
              {p50:.0}/{p95:.0}/{p99:.0}");
    Ok(())
}

/// `apb serve --trace <spec>`: expand a named workload spec into a seeded
/// trace, drive it through the SLO scheduler on this cluster, report
/// percentile latency + per-class goodput, and write the schema-versioned
/// `BENCH_serving.json` record (the serving twin of `BENCH_runtime.json`;
/// regenerated + field-validated on CI's threaded leg).
fn serve_trace(args: &Args, cfg: &apb::config::Config, cluster: &Cluster) -> Result<()> {
    let name = args.str_or("trace", "smoke");
    let mut spec = TraceSpec::by_name(&name).ok_or_else(|| {
        anyhow::anyhow!(
            "--trace={name} is not a trace spec (expected one of {:?})",
            TraceSpec::NAMES
        )
    })?;
    if let Some(seed) = args.get("trace-seed") {
        spec.seed = seed.parse().map_err(|_| anyhow::anyhow!("--trace-seed={seed} not a u64"))?;
    }
    if args.get("requests").is_some() {
        spec.n_requests = args.usize_or("requests", spec.n_requests)?;
    }
    let trace = workload::generate(cfg, &spec)?;
    if args.get("sweep").is_some() {
        // Closed-loop latency/goodput sweep: replay the trace at each
        // multiprogramming level on a fresh scheduler (prefix-store
        // warmth persists across points, as across a real soak's phases).
        let levels = args.usize_list_or("sweep", &[1, 2, 4])?;
        let points = workload::sweep_closed_loop(
            cluster, args.usize_or("queue", 64)?, &trace, &levels,
        )?;
        let mut table = Table::new(
            &format!("closed-loop sweep, trace '{}' (seed {})", spec.name, spec.seed),
            &["level", "done", "ticks", "tokens", "goodput tok/tick",
              "ttft ticks p50", "p95", "slo frac"],
        );
        for p in &points {
            table.row(vec![
                p.concurrency.to_string(),
                p.completed.to_string(),
                p.final_tick.to_string(),
                p.total_tokens.to_string(),
                format!("{:.3}", p.goodput_tok_per_tick),
                format!("{:.0}", p.ttft_ticks_p50),
                format!("{:.0}", p.ttft_ticks_p95),
                format!("{:.2}", p.slo_fraction),
            ]);
        }
        table.print();
        // The sweep twin of BENCH_serving.json: the closed-loop
        // latency/goodput curve, schema-versioned for the CI validator.
        let rows: Vec<Json> = points
            .iter()
            .map(|p| {
                json::obj(vec![
                    ("concurrency", json::num(p.concurrency as f64)),
                    ("completed", json::num(p.completed as f64)),
                    ("final_tick", json::num(p.final_tick as f64)),
                    ("total_tokens", json::num(p.total_tokens as f64)),
                    ("goodput_tok_per_tick", json::num(p.goodput_tok_per_tick)),
                    ("ttft_ticks_p50", json::num(p.ttft_ticks_p50)),
                    ("ttft_ticks_p95", json::num(p.ttft_ticks_p95)),
                    ("slo_fraction", json::num(p.slo_fraction)),
                ])
            })
            .collect();
        let bench = json::obj(vec![
            ("bench", json::s("serving_sweep")),
            ("schema_version", json::num(1.0)),
            ("config", json::s(&cfg.name)),
            ("driver", json::s(cluster.driver().name())),
            ("smoke", Json::Bool(args.has("smoke"))),
            ("trace", json::s(spec.name)),
            ("trace_seed", json::num(spec.seed as f64)),
            ("n_arrivals", json::num(trace.arrivals.len() as f64)),
            ("levels", Json::Arr(levels.iter().map(|l| json::num(*l as f64)).collect())),
            ("points", Json::Arr(rows)),
        ]);
        std::fs::write("BENCH_sweep.json", bench.pretty())?;
        println!("[bench json] BENCH_sweep.json");
        if args.has("smoke") {
            for p in &points {
                anyhow::ensure!(p.completed == trace.arrivals.len(),
                                "smoke: level {} completed {} of {}",
                                p.concurrency, p.completed, trace.arrivals.len());
            }
            println!("apb serve --trace {} --sweep --smoke OK", spec.name);
        }
        return Ok(());
    }
    let mut sched = Scheduler::new(cluster, args.usize_or("queue", 64)?);
    let done = workload::run_trace(&mut sched, &trace)?;
    let m = sched.metrics();
    println!(
        "trace '{}' (seed {}): {} requests ({} block-scale) over {} ticks | driver {}",
        spec.name, spec.seed, done, trace.n_long(), sched.tick(), cluster.driver().name()
    );
    println!(
        "ttft ticks p50/p95/p99 {:.0}/{:.0}/{:.0} | ttft ms p50/p95/p99 \
         {:.1}/{:.1}/{:.1} | tpot ms p50/p95/p99 {:.2}/{:.2}/{:.2}",
        m.ttft_ticks.p50, m.ttft_ticks.p95, m.ttft_ticks.p99,
        m.ttft.p50 * 1e3, m.ttft.p95 * 1e3, m.ttft.p99 * 1e3,
        m.tpot.p50 * 1e3, m.tpot.p95 * 1e3, m.tpot.p99 * 1e3,
    );
    println!(
        "peak resident {} | preemptions {} | starved {} | prefix hits {}",
        m.peak_resident, m.preemptions_total, m.starved, m.prefix_hits
    );
    println!(
        "decode comm split (strategy {}): att {} B | qring {} B",
        cfg.pass_strategy.name(), m.decode_att_bytes, m.decode_qring_bytes
    );
    let mut class_rows: Vec<Json> = Vec::new();
    for c in &m.per_class {
        println!(
            "  class {:<11} n {:>2} | slo met {}/{} ({:.0}%) | goodput {} tok | \
             ttft ticks p50/p99 {:.0}/{:.0}",
            c.class.name(), c.n_requests, c.slo_met, c.n_requests,
            c.slo_fraction * 100.0, c.goodput_tokens, c.ttft_ticks.p50, c.ttft_ticks.p99
        );
        class_rows.push(json::obj(vec![
            ("class", json::s(c.class.name())),
            ("n_requests", json::num(c.n_requests as f64)),
            ("slo_met", json::num(c.slo_met as f64)),
            ("slo_fraction", json::num(c.slo_fraction)),
            ("goodput_tokens", json::num(c.goodput_tokens as f64)),
            ("ttft_ticks_p50", json::num(c.ttft_ticks.p50)),
            ("ttft_ticks_p95", json::num(c.ttft_ticks.p95)),
            ("ttft_ticks_p99", json::num(c.ttft_ticks.p99)),
        ]));
    }
    // `schema_version` gates the CI validator: bump it when fields change.
    let bench = json::obj(vec![
        ("bench", json::s("serving_trace")),
        ("schema_version", json::num(1.0)),
        ("config", json::s(&cfg.name)),
        ("driver", json::s(cluster.driver().name())),
        ("smoke", Json::Bool(args.has("smoke"))),
        ("trace", json::s(spec.name)),
        ("trace_seed", json::num(spec.seed as f64)),
        ("prefix_cache", Json::Bool(cfg.apb.prefix_cache)),
        ("n_requests", json::num(m.n_requests as f64)),
        ("n_long", json::num(trace.n_long() as f64)),
        ("final_tick", json::num(sched.tick() as f64)),
        ("total_tokens", json::num(m.total_tokens as f64)),
        ("peak_resident", json::num(m.peak_resident as f64)),
        ("preemptions", json::num(m.preemptions_total as f64)),
        ("starved", json::num(m.starved as f64)),
        ("prefix_hits", json::num(m.prefix_hits as f64)),
        ("prefix_bytes_saved", json::num(m.prefix_bytes_saved as f64)),
        ("pass_strategy", json::s(cfg.pass_strategy.name())),
        ("decode_att_bytes", json::num(m.decode_att_bytes as f64)),
        ("decode_qring_bytes", json::num(m.decode_qring_bytes as f64)),
        ("ttft_ticks_p50", json::num(m.ttft_ticks.p50)),
        ("ttft_ticks_p95", json::num(m.ttft_ticks.p95)),
        ("ttft_ticks_p99", json::num(m.ttft_ticks.p99)),
        ("ttft_ms_p50", json::num(m.ttft.p50 * 1e3)),
        ("ttft_ms_p95", json::num(m.ttft.p95 * 1e3)),
        ("ttft_ms_p99", json::num(m.ttft.p99 * 1e3)),
        ("tpot_ms_p50", json::num(m.tpot.p50 * 1e3)),
        ("tpot_ms_p95", json::num(m.tpot.p95 * 1e3)),
        ("tpot_ms_p99", json::num(m.tpot.p99 * 1e3)),
        ("per_class", Json::Arr(class_rows)),
    ]);
    std::fs::write("BENCH_serving.json", bench.pretty())?;
    println!("[bench json] BENCH_serving.json");
    if args.has("smoke") {
        // CI gate for SLO scheduling: the whole trace completes, nothing
        // starves (every short request reached its first token within the
        // policy budget even with a block-scale prefill in flight), and
        // every request went through chunked admission.
        // Follow-up turns make `arrivals` exceed `n_requests` on multi-turn
        // specs (`soak`): gate on the expanded trace, not the spec knob.
        anyhow::ensure!(done == trace.arrivals.len(),
                        "smoke: {done} of {} trace arrivals completed",
                        trace.arrivals.len());
        anyhow::ensure!(m.starved == 0, "smoke: {} requests starved", m.starved);
        anyhow::ensure!(m.prefill_chunks.min >= 1.0,
                        "smoke: a request bypassed chunked admission");
        anyhow::ensure!(trace.n_long() >= 1,
                        "smoke: trace generated no block-scale request");
        if cfg.apb.prefix_cache {
            anyhow::ensure!(m.prefix_hits >= 1,
                            "smoke: corpus-sharing trace produced no prefix hits");
        }
        println!("apb serve --trace {} --smoke OK (driver {})",
                 spec.name, cluster.driver().name());
    }
    Ok(())
}

fn simulate(args: &Args) -> Result<()> {
    let hosts = args.usize_or("hosts", 8)? as f64;
    let lengths = args.usize_list_or("lengths",
                                     &[32768, 131072, 524288, 1048576])?;
    let mut table = Table::new(
        "analytical estimates (Llama-3.1-8B, A800)",
        &["Method", "n", "prefill s", "speed tok/s", "mem GB"],
    );
    for method in Method::ALL {
        let h = if method.uses_sequence_parallelism() { hosts } else { 1.0 };
        for &n in &lengths {
            let n = n as f64;
            let est = estimate(method, &LLAMA31_8B, n, h,
                               &Hyper::paper_schedule(n, hosts), &A800, 64.0);
            table.row(vec![
                method.name().into(),
                format!("{}K", n as usize / 1024),
                if est.oom { "OOM".into() } else { format!("{:.2}", est.prefill_s) },
                match speed_tok_per_s(&est, n, 64.0) {
                    Some(s) => format!("{s:.0}"),
                    None => "-".into(),
                },
                format!("{:.0}", est.mem_bytes_peak / 1e9),
            ]);
        }
    }
    table.print();
    Ok(())
}

fn eval(args: &Args) -> Result<()> {
    let suite = args.str_or("suite", "ruler");
    let tasks = match suite.as_str() {
        "ruler" => ruler_tasks(),
        "infbench" => infbench_tasks(),
        other => bail!("unknown suite '{other}'"),
    };
    let n = args.usize_or("n", 131072)? as f64;
    let hosts = args.usize_or("hosts", 8)? as f64;
    let ctx = EvalCtx { n, hosts, model: ModelCol::Llama, samples: 0, seed: 0 };
    let hy = Hyper::paper_schedule(n, hosts);
    let methods = [
        ("FullAttn", AccMethod::Full),
        ("MInference", AccMethod::MInference),
        ("StarAttn", AccMethod::StarAttn),
        ("APB", AccMethod::Apb(ApbQuality::paper_default(hy.l_a, hy.l_p, n / hosts))),
    ];
    let mut headers = vec!["Method"];
    headers.extend(tasks.iter().map(|t| t.id));
    headers.push("Avg.");
    let mut table = Table::new(&format!("{suite} @ {}K, H={hosts}", n as usize / 1024),
                               &headers);
    for (name, m) in methods {
        let mut cells = vec![name.to_string()];
        let mut sum = 0.0;
        for t in &tasks {
            let s = expected_score(t, m, &ctx);
            sum += s;
            cells.push(format!("{s:.1}"));
        }
        cells.push(format!("{:.1}", sum / tasks.len() as f64));
        table.row(cells);
    }
    table.print();
    Ok(())
}

fn golden(args: &Args) -> Result<()> {
    let cfg = apb::load_config(&args.str_or("config", "tiny"))?;
    let Some((golden, n_new)) = apb::runtime::load_golden(&cfg)? else {
        bail!("config '{}' carries no golden blob", cfg.name);
    };
    let doc = golden.i32s("doc_tokens")?;
    let query = golden.i32s("query_tokens")?;
    let want = golden.i32s("generated")?;
    let cluster = Cluster::start(&cfg)?;
    cluster.prefill(&doc, &query, &ApbOptions::default())?;
    let gen = cluster.generate(&query, n_new)?;
    println!("rust:   {:?}", gen.tokens);
    println!("python: {want:?}");
    if gen.tokens == want {
        println!("golden replay OK — rust cluster == python pipeline");
        Ok(())
    } else {
        bail!("golden replay MISMATCH")
    }
}
