//! Reader for the manifest-described binary blobs the AOT pipeline emits
//! (`weights.bin`, `golden.bin`): little-endian f32/i32 arrays described by
//! `entries: [{name, dtype, shape, offset, size}]` in `manifest.json`.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::json::Json;
use super::tensor::Tensor;

#[derive(Debug, Clone)]
pub struct BlobEntry {
    pub name: String,
    pub dtype: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
}

#[derive(Debug)]
pub struct Blob {
    pub entries: Vec<BlobEntry>,
    bytes: Vec<u8>,
    index: BTreeMap<String, usize>,
}

impl Blob {
    /// Load a blob file given its manifest description
    /// (`{"file": ..., "entries": [...]}`) and the artifact directory.
    pub fn load(dir: &Path, desc: &Json) -> Result<Blob> {
        let file = desc
            .req("file")?
            .as_str()
            .context("blob 'file' not a string")?;
        let entries = parse_entries(desc.req("entries")?)?;
        let bytes = std::fs::read(dir.join(file))
            .with_context(|| format!("reading blob {file}"))?;
        let total: usize = entries.iter().map(|e| e.size).sum();
        if bytes.len() != total {
            bail!("blob {file}: {} bytes on disk, manifest says {total}", bytes.len());
        }
        let index = entries
            .iter()
            .enumerate()
            .map(|(i, e)| (e.name.clone(), i))
            .collect();
        Ok(Blob { entries, bytes, index })
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.entries.iter().map(|e| e.name.as_str())
    }

    fn entry(&self, name: &str) -> Result<&BlobEntry> {
        let i = *self
            .index
            .get(name)
            .with_context(|| format!("blob entry '{name}' not found"))?;
        Ok(&self.entries[i])
    }

    /// f32 tensor by name.
    pub fn tensor(&self, name: &str) -> Result<Tensor> {
        let e = self.entry(name)?;
        if e.dtype != "f32" {
            bail!("entry '{name}' has dtype {}, wanted f32", e.dtype);
        }
        let n: usize = e.shape.iter().product();
        let mut data = Vec::with_capacity(n);
        let raw = &self.bytes[e.offset..e.offset + e.size];
        for c in raw.chunks_exact(4) {
            data.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        Tensor::new(e.shape.clone(), data)
    }

    /// i32 vector by name (tokens, generated ids).
    pub fn i32s(&self, name: &str) -> Result<Vec<i32>> {
        let e = self.entry(name)?;
        if e.dtype != "i32" {
            bail!("entry '{name}' has dtype {}, wanted i32", e.dtype);
        }
        let raw = &self.bytes[e.offset..e.offset + e.size];
        Ok(raw
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

fn parse_entries(v: &Json) -> Result<Vec<BlobEntry>> {
    let arr = v.as_arr().context("blob entries not an array")?;
    let mut out = Vec::with_capacity(arr.len());
    let mut expected_offset = 0usize;
    for e in arr {
        let entry = BlobEntry {
            name: e.req("name")?.as_str().context("name")?.to_string(),
            dtype: e.req("dtype")?.as_str().context("dtype")?.to_string(),
            shape: e.req("shape")?.usize_vec().context("shape")?,
            offset: e.req("offset")?.as_usize().context("offset")?,
            size: e.req("size")?.as_usize().context("size")?,
        };
        if entry.offset != expected_offset {
            bail!("entry '{}' offset {} != running total {}", entry.name, entry.offset,
                  expected_offset);
        }
        let numel: usize = entry.shape.iter().product();
        if entry.size != numel * 4 {
            bail!("entry '{}' size {} != 4*numel {}", entry.name, entry.size, numel * 4);
        }
        expected_offset += entry.size;
        out.push(entry);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_blob(dir: &Path) -> Json {
        let mut f = std::fs::File::create(dir.join("t.bin")).unwrap();
        for v in [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0] {
            f.write_all(&v.to_le_bytes()).unwrap();
        }
        for v in [7i32, 8] {
            f.write_all(&v.to_le_bytes()).unwrap();
        }
        Json::parse(
            r#"{"file":"t.bin","entries":[
                {"name":"w","dtype":"f32","shape":[2,3],"offset":0,"size":24},
                {"name":"ids","dtype":"i32","shape":[2],"offset":24,"size":8}
            ]}"#,
        )
        .unwrap()
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join(format!("blob_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let desc = write_blob(&dir);
        let blob = Blob::load(&dir, &desc).unwrap();
        let w = blob.tensor("w").unwrap();
        assert_eq!(w.shape, vec![2, 3]);
        assert_eq!(w.data, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(blob.i32s("ids").unwrap(), vec![7, 8]);
        assert!(blob.tensor("ids").is_err()); // dtype mismatch
        assert!(blob.tensor("nope").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn size_mismatch_rejected() {
        let dir = std::env::temp_dir().join(format!("blob_test2_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let desc = write_blob(&dir);
        // Truncate the file.
        let bytes = std::fs::read(dir.join("t.bin")).unwrap();
        std::fs::write(dir.join("t.bin"), &bytes[..16]).unwrap();
        assert!(Blob::load(&dir, &desc).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
