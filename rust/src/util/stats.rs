//! Summary statistics for the bench harness and serving metrics:
//! mean / stddev / percentiles over latency samples.

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

pub fn summarize(samples: &[f64]) -> Summary {
    assert!(!samples.is_empty(), "summarize of empty sample set");
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
        / n.max(2).saturating_sub(1) as f64;
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min: sorted[0],
        p50: percentile(&sorted, 0.50),
        p90: percentile(&sorted, 0.90),
        p95: percentile(&sorted, 0.95),
        p99: percentile(&sorted, 0.99),
        max: sorted[n - 1],
    }
}

/// Linear-interpolated percentile over a pre-sorted slice.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Human-friendly duration formatting for reports.
pub fn fmt_duration(seconds: f64) -> String {
    if seconds < 1e-6 {
        format!("{:.1}ns", seconds * 1e9)
    } else if seconds < 1e-3 {
        format!("{:.2}µs", seconds * 1e6)
    } else if seconds < 1.0 {
        format!("{:.2}ms", seconds * 1e3)
    } else {
        format!("{:.2}s", seconds)
    }
}

/// Throughput formatting (tokens / second).
pub fn fmt_rate(per_second: f64) -> String {
    if per_second >= 1e6 {
        format!("{:.2}M/s", per_second / 1e6)
    } else if per_second >= 1e3 {
        format!("{:.1}K/s", per_second / 1e3)
    } else {
        format!("{:.1}/s", per_second)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_summary() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert!((percentile(&sorted, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(percentile(&sorted, 0.0), 0.0);
        assert_eq!(percentile(&sorted, 1.0), 10.0);
    }

    #[test]
    fn single_sample() {
        let s = summarize(&[7.0]);
        assert_eq!(s.p95, 7.0);
        assert_eq!(s.p99, 7.0);
        assert_eq!(s.std, 0.0);
    }

    #[test]
    fn percentiles_are_ordered() {
        let samples: Vec<f64> = (0..100).map(|i| (i * 37 % 100) as f64).collect();
        let s = summarize(&samples);
        assert!(s.min <= s.p50 && s.p50 <= s.p90 && s.p90 <= s.p95);
        assert!(s.p95 <= s.p99 && s.p99 <= s.max);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_duration(2.5e-9), "2.5ns");
        assert_eq!(fmt_duration(1.5e-4), "150.00µs");
        assert_eq!(fmt_duration(0.25), "250.00ms");
        assert_eq!(fmt_duration(3.2), "3.20s");
        assert_eq!(fmt_rate(1234.0), "1.2K/s");
        assert_eq!(fmt_rate(12.0), "12.0/s");
        assert_eq!(fmt_rate(2.5e6), "2.50M/s");
    }
}
