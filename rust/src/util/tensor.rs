//! Small dense f32 tensor used on the coordinator hot path.
//!
//! This is deliberately not a general NDArray — just the operations the
//! L3 coordinator needs between PJRT calls: row slicing/stitching for KV
//! blocks, top-k gathers for the compressor, argmax for greedy decoding,
//! and the online-softmax LSE merge. Heavy math stays inside the AOT'd
//! HLO executables.

use anyhow::{bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elems, got {}", shape, n, data.len());
        }
        Ok(Tensor { shape, data })
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Reinterpret the shape (same element count, same row-major data).
    pub fn reshape(mut self, shape: Vec<usize>) -> Tensor {
        let n: usize = shape.iter().product();
        assert_eq!(n, self.data.len(), "reshape {:?} -> {:?}", self.shape, shape);
        self.shape = shape;
        self
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Size of one "row" (all dims after the first).
    pub fn row_len(&self) -> usize {
        self.shape[1..].iter().product()
    }

    /// Rows `lo..hi` along axis 0 as a new tensor.
    pub fn slice_rows(&self, lo: usize, hi: usize) -> Tensor {
        assert!(lo <= hi && hi <= self.shape[0], "slice {lo}..{hi} of {:?}", self.shape);
        let rl = self.row_len();
        let mut shape = self.shape.clone();
        shape[0] = hi - lo;
        Tensor { shape, data: self.data[lo * rl..hi * rl].to_vec() }
    }

    /// Overwrite rows starting at `at` along axis 0.
    pub fn write_rows(&mut self, at: usize, src: &Tensor) {
        assert_eq!(self.shape[1..], src.shape[1..], "row shapes differ");
        let rl = self.row_len();
        let n = src.shape[0];
        assert!(at + n <= self.shape[0], "write {at}+{n} into {:?}", self.shape);
        self.data[at * rl..(at + n) * rl].copy_from_slice(&src.data);
    }

    /// Concatenate along axis 0. All inputs must share trailing dims.
    pub fn concat_rows(parts: &[&Tensor]) -> Tensor {
        assert!(!parts.is_empty());
        let trailing = &parts[0].shape[1..];
        let rows: usize = parts.iter().map(|p| p.shape[0]).sum();
        let mut shape = vec![rows];
        shape.extend_from_slice(trailing);
        let mut data = Vec::with_capacity(rows * parts[0].row_len());
        for p in parts {
            assert_eq!(&p.shape[1..], trailing);
            data.extend_from_slice(&p.data);
        }
        Tensor { shape, data }
    }

    /// Gather rows by index along axis 0.
    pub fn gather_rows(&self, idx: &[usize]) -> Tensor {
        let rl = self.row_len();
        let mut shape = self.shape.clone();
        shape[0] = idx.len();
        let mut data = Vec::with_capacity(idx.len() * rl);
        for &i in idx {
            assert!(i < self.shape[0]);
            data.extend_from_slice(&self.data[i * rl..(i + 1) * rl]);
        }
        Tensor { shape, data }
    }

    /// View element [i, j] of a rank-2 tensor.
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.rank(), 2);
        self.data[i * self.shape[1] + j]
    }

    /// Last row of a rank-2 tensor.
    pub fn last_row(&self) -> &[f32] {
        let rl = self.row_len();
        &self.data[self.data.len() - rl..]
    }

    pub fn argmax_row(row: &[f32]) -> usize {
        let mut best = 0;
        let mut best_v = f32::NEG_INFINITY;
        for (i, &v) in row.iter().enumerate() {
            if v > best_v {
                best_v = v;
                best = i;
            }
        }
        best
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

/// Per-kv-head top-k over compressor scores, returning ascending indices —
/// the coordinator half of the paper's Top-l_p selection (§3.4). `scores`
/// is [n, kh] row-major; returns `kh` vectors of `l_p` ascending indices.
pub fn top_lp_indices(scores: &Tensor, l_p: usize) -> Vec<Vec<usize>> {
    assert_eq!(scores.rank(), 2);
    let (n, kh) = (scores.shape[0], scores.shape[1]);
    let l_p = l_p.min(n);
    let mut out = Vec::with_capacity(kh);
    for j in 0..kh {
        let mut idx: Vec<usize> = (0..n).collect();
        // Stable ordering tie-break on index to match jax.lax.top_k
        // (which prefers lower indices on ties).
        idx.sort_by(|&a, &b| {
            scores.at2(b, j)
                .partial_cmp(&scores.at2(a, j))
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        let mut top: Vec<usize> = idx[..l_p].to_vec();
        top.sort_unstable();
        out.push(top);
    }
    out
}

/// Online-softmax merge of per-host partial attentions (Algorithm 3
/// line 10). outs[h]: [n, heads, hd]; lses[h]: [n, heads]. Exactness is
/// property-tested against dense softmax in both python and rust.
pub fn merge_partials(outs: &[Tensor], lses: &[Tensor]) -> Tensor {
    assert_eq!(outs.len(), lses.len());
    assert!(!outs.is_empty());
    let shape = outs[0].shape.clone();
    let (n, heads, hd) = (shape[0], shape[1], shape[2]);
    let mut merged = Tensor::zeros(shape);
    for i in 0..n {
        for h in 0..heads {
            let mut m = f32::NEG_INFINITY;
            for l in lses {
                m = m.max(l.at2(i, h));
            }
            let m_safe = if m.is_finite() { m } else { 0.0 };
            let mut denom = 0.0f32;
            let mut acc = vec![0.0f32; hd];
            for (o, l) in outs.iter().zip(lses) {
                let lse = l.at2(i, h);
                if !lse.is_finite() {
                    continue; // host saw zero keys
                }
                let w = (lse - m_safe).exp();
                denom += w;
                let base = (i * heads + h) * hd;
                for d in 0..hd {
                    acc[d] += w * o.data[base + d];
                }
            }
            let denom = if denom > 0.0 { denom } else { 1.0 };
            let base = (i * heads + h) * hd;
            for d in 0..hd {
                merged.data[base + d] = acc[d] / denom;
            }
        }
    }
    merged
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        Tensor::new(shape, data).unwrap()
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn slice_write_roundtrip() {
        let mut a = Tensor::zeros(vec![4, 2]);
        let b = t(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        a.write_rows(1, &b);
        assert_eq!(a.slice_rows(1, 3), b);
        assert_eq!(a.slice_rows(0, 1).data, vec![0.0, 0.0]);
    }

    #[test]
    fn reshape_preserves_data() {
        let a = t(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let b = a.clone().reshape(vec![3, 2]);
        assert_eq!(b.shape, vec![3, 2]);
        assert_eq!(b.data, a.data);
    }

    #[test]
    #[should_panic(expected = "reshape")]
    fn reshape_rejects_bad_numel() {
        let a = t(vec![2, 2], vec![0.0; 4]);
        let _ = a.reshape(vec![3, 2]);
    }

    #[test]
    fn concat_and_gather() {
        let a = t(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = t(vec![1, 2], vec![5.0, 6.0]);
        let c = Tensor::concat_rows(&[&a, &b]);
        assert_eq!(c.shape, vec![3, 2]);
        let g = c.gather_rows(&[2, 0]);
        assert_eq!(g.data, vec![5.0, 6.0, 1.0, 2.0]);
    }

    #[test]
    fn argmax() {
        assert_eq!(Tensor::argmax_row(&[0.1, 3.0, 2.0]), 1);
        assert_eq!(Tensor::argmax_row(&[-5.0, -2.0, -2.0]), 1); // first wins
    }

    #[test]
    fn top_lp_sorted_and_correct() {
        // scores [4, 2]: head 0 prefers rows 3,1; head 1 prefers rows 0,2.
        let s = t(vec![4, 2], vec![
            0.1, 9.0, //
            5.0, 0.2, //
            0.3, 7.0, //
            8.0, 0.4,
        ]);
        let top = top_lp_indices(&s, 2);
        assert_eq!(top[0], vec![1, 3]);
        assert_eq!(top[1], vec![0, 2]);
    }

    #[test]
    fn top_lp_tie_prefers_lower_index() {
        let s = t(vec![3, 1], vec![1.0, 1.0, 1.0]);
        assert_eq!(top_lp_indices(&s, 2)[0], vec![0, 1]);
    }

    #[test]
    fn merge_single_host_is_identity() {
        let o = t(vec![1, 2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let l = t(vec![1, 2], vec![0.5, -1.0]);
        let m = merge_partials(&[o.clone()], &[l]);
        assert_eq!(m, o);
    }

    #[test]
    fn merge_matches_dense_two_hosts() {
        // Two hosts, one key each: softmax over 2 logits.
        // host A: key score a, value va; host B: key score b, value vb.
        let (a, b) = (0.3f32, -0.7f32);
        let (va, vb) = (2.0f32, -1.0f32);
        let oa = t(vec![1, 1, 1], vec![va]);
        let ob = t(vec![1, 1, 1], vec![vb]);
        let la = t(vec![1, 1], vec![a]); // lse of single logit = logit
        let lb = t(vec![1, 1], vec![b]);
        let m = merge_partials(&[oa, ob], &[la, lb]);
        let (ea, eb) = (a.exp(), b.exp());
        let want = (ea * va + eb * vb) / (ea + eb);
        assert!((m.data[0] - want).abs() < 1e-6);
    }

    #[test]
    fn merge_ignores_empty_host() {
        let o1 = t(vec![1, 1, 2], vec![1.0, 2.0]);
        let l1 = t(vec![1, 1], vec![0.0]);
        let o2 = t(vec![1, 1, 2], vec![9.0, 9.0]);
        let l2 = t(vec![1, 1], vec![f32::NEG_INFINITY]);
        let m = merge_partials(&[o1.clone(), o2], &[l1, l2]);
        assert_eq!(m, o1);
    }
}
