//! Tiny CLI argument parser (no `clap` offline): `--flag`, `--key value`,
//! `--key=value`, positionals, typed getters with defaults.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
    pub bools: Vec<String>,
    known_bools: Vec<&'static str>,
}

impl Args {
    /// `known_bools` lists flags that take no value (e.g. `--verbose`).
    pub fn parse<I: IntoIterator<Item = String>>(
        argv: I,
        known_bools: &[&'static str],
    ) -> Result<Args> {
        let mut out = Args { known_bools: known_bools.to_vec(), ..Default::default() };
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if known_bools.contains(&body) {
                    out.bools.push(body.to_string());
                } else {
                    let v = it
                        .next()
                        .with_context(|| format!("--{body} expects a value"))?;
                    out.flags.insert(body.to_string(), v);
                }
            } else if a.starts_with('-') && a.len() > 1 {
                bail!("unknown short option '{a}' (use --long options)");
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn has(&self, name: &str) -> bool {
        self.bools.iter().any(|b| b == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name}={v} not a usize")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name}={v} not a float")),
        }
    }

    /// Comma-separated usize list, e.g. `--hosts 2,4,8`.
    pub fn usize_list_or(&self, name: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| p.trim().parse().with_context(|| format!("bad list item '{p}'")))
                .collect(),
        }
    }

    /// Sanity-check that every given flag is one the command understands.
    pub fn check_known(&self, known: &[&str]) -> Result<()> {
        for k in self.flags.keys() {
            if !known.contains(&k.as_str()) {
                bail!("unknown option --{k}; known: {}", known.join(", "));
            }
        }
        for b in &self.bools {
            if !self.known_bools.contains(&b.as_str()) {
                bail!("unknown flag --{b}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from), &["verbose", "json"]).unwrap()
    }

    #[test]
    fn mixed_forms() {
        let a = parse("serve --config tiny --hosts=4 --verbose pos1");
        assert_eq!(a.positional, vec!["serve", "pos1"]);
        assert_eq!(a.get("config"), Some("tiny"));
        assert_eq!(a.usize_or("hosts", 1).unwrap(), 4);
        assert!(a.has("verbose"));
        assert!(!a.has("json"));
    }

    #[test]
    fn lists_and_defaults() {
        let a = parse("x --hosts 2,4,8");
        assert_eq!(a.usize_list_or("hosts", &[1]).unwrap(), vec![2, 4, 8]);
        assert_eq!(a.usize_list_or("lens", &[32]).unwrap(), vec![32]);
        assert_eq!(a.f64_or("alpha", 0.5).unwrap(), 0.5);
    }

    #[test]
    fn errors() {
        assert!(Args::parse(["--key".to_string()].into_iter(), &[]).is_err());
        assert!(Args::parse(["-x".to_string()].into_iter(), &[]).is_err());
        let a = parse("x --bogus 1");
        assert!(a.check_known(&["config"]).is_err());
        assert!(parse("x --config tiny").check_known(&["config"]).is_ok());
    }
}
