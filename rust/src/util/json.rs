//! Minimal JSON parser + serializer.
//!
//! The offline build environment ships no `serde`/`serde_json`, so this is
//! the in-tree substrate used to read `artifacts/*/manifest.json` and to
//! emit machine-readable bench/report outputs. It implements the full JSON
//! grammar (RFC 8259) minus `\u` surrogate-pair edge cases beyond the BMP
//! (sufficient for our ASCII manifests), with precise error positions.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field lookup that errors with the missing path.
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key).ok_or_else(|| JsonError {
            pos: 0,
            msg: format!("missing field '{key}'"),
        })
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|f| {
            if f >= 0.0 && f.fract() == 0.0 {
                Some(f as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // -- serialization ------------------------------------------------------

    pub fn dumps(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors used by report emitters.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
    Json::Arr(items.into_iter().collect())
}

/// An i32 token slice as a JSON array (the `/v1/generate` wire shape).
pub fn i32_arr(xs: &[i32]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
}

/// Incremental single-allocation object writer — the streaming twin of
/// [`Json::dumps`]. The HTTP front door serializes one event line per
/// decoded token; building a `BTreeMap<String, Json>` per token would
/// allocate per key on the per-token hot path, so this writer appends
/// fields straight into one `String` (same escaping as the tree
/// serializer) and preserves insertion order. `Json::parse` reads its
/// output back verbatim (round-trip tested below).
pub struct JsonWriter {
    out: String,
    first: bool,
}

impl JsonWriter {
    /// Start an object: `{`.
    pub fn obj() -> JsonWriter {
        JsonWriter { out: String::from("{"), first: true }
    }

    fn key(&mut self, k: &str) {
        if !self.first {
            self.out.push(',');
        }
        self.first = false;
        write_escaped(&mut self.out, k);
        self.out.push(':');
    }

    pub fn num_field(mut self, k: &str, v: f64) -> JsonWriter {
        self.key(k);
        if v.fract() == 0.0 && v.abs() < 9e15 {
            self.out.push_str(&format!("{}", v as i64));
        } else {
            self.out.push_str(&format!("{v}"));
        }
        self
    }

    pub fn str_field(mut self, k: &str, v: &str) -> JsonWriter {
        self.key(k);
        write_escaped(&mut self.out, v);
        self
    }

    pub fn bool_field(mut self, k: &str, v: bool) -> JsonWriter {
        self.key(k);
        self.out.push_str(if v { "true" } else { "false" });
        self
    }

    /// A pre-serialized JSON value (nested object/array) under `k`.
    pub fn raw_field(mut self, k: &str, raw_json: &str) -> JsonWriter {
        self.key(k);
        self.out.push_str(raw_json);
        self
    }

    /// An i32 array field without intermediate `Json` nodes.
    pub fn tokens_field(mut self, k: &str, xs: &[i32]) -> JsonWriter {
        self.key(k);
        self.out.push('[');
        for (i, x) in xs.iter().enumerate() {
            if i > 0 {
                self.out.push(',');
            }
            self.out.push_str(&x.to_string());
        }
        self.out.push(']');
        self
    }

    /// Close the object: `}`.
    pub fn close(mut self) -> String {
        self.out.push('}');
        self.out
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| self.err("invalid utf8 in number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy a full UTF-8 sequence.
                    let rest = &self.b[self.i..];
                    let ch_len = utf8_len(rest[0]);
                    if rest.len() < ch_len {
                        return Err(self.err("truncated utf8"));
                    }
                    let st = std::str::from_utf8(&rest[..ch_len])
                        .map_err(|_| self.err("invalid utf8"))?;
                    out.push_str(st);
                    self.i += ch_len;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b >> 5 == 0b110 {
        2
    } else if b >> 4 == 0b1110 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":"x"}],"c":null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s",true,null],"obj":{"k":-3}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.dumps()).unwrap();
        assert_eq!(v, v2);
        let v3 = Json::parse(&v.pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("01a").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
        assert!(Json::parse("[1] x").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""café → ok""#).unwrap();
        assert_eq!(v.as_str(), Some("café → ok"));
        let s = Json::Str("tab\t\"q\"".into()).dumps();
        assert_eq!(Json::parse(&s).unwrap().as_str(), Some("tab\t\"q\""));
    }

    #[test]
    fn usize_vec_and_accessors() {
        let v = Json::parse(r#"{"shape":[3,4,5],"n":7}"#).unwrap();
        assert_eq!(v.get("shape").unwrap().usize_vec(), Some(vec![3, 4, 5]));
        assert_eq!(v.get("n").unwrap().as_usize(), Some(7));
        assert_eq!(v.get("missing"), None);
        assert!(v.req("missing").is_err());
    }

    #[test]
    fn json_writer_output_parses_back() {
        let inner = JsonWriter::obj().num_field("p50", 1.5).num_field("p99", 3.0).close();
        let line = JsonWriter::obj()
            .str_field("event", "done\n\"quoted\"")
            .num_field("index", 3.0)
            .num_field("big", 1e16)
            .bool_field("ok", true)
            .tokens_field("tokens", &[5, -1, 127])
            .raw_field("ttft", &inner)
            .close();
        let v = Json::parse(&line).unwrap();
        assert_eq!(v.req("event").unwrap().as_str(), Some("done\n\"quoted\""));
        assert_eq!(v.req("index").unwrap().as_usize(), Some(3));
        assert_eq!(v.req("big").unwrap().as_f64(), Some(1e16));
        assert_eq!(v.req("ok").unwrap().as_bool(), Some(true));
        assert_eq!(
            v.req("tokens").unwrap().as_arr().unwrap().iter().map(|t| t.as_i64().unwrap())
                .collect::<Vec<_>>(),
            vec![5, -1, 127]
        );
        assert_eq!(v.req("ttft").unwrap().req("p99").unwrap().as_f64(), Some(3.0));
        // Empty object is valid too.
        assert_eq!(Json::parse(&JsonWriter::obj().close()).unwrap(), Json::Obj(Default::default()));
        // And the writer agrees with the tree serializer on token arrays.
        assert_eq!(i32_arr(&[5, -1, 127]).dumps(), "[5,-1,127]");
    }
}
