//! In-tree substrates for the offline environment (no serde/clap/rand/
//! criterion in the registry): JSON, RNG, CLI parsing, binary blobs,
//! dense f32 tensors, summary statistics.

pub mod blob;
pub mod cli;
pub mod json;
pub mod rng;
pub mod stats;
pub mod tensor;
