//! Deterministic RNG substrate (no `rand` crate offline).
//!
//! `splitmix64` is bit-identical to `python/compile/model.py::splitmix64`
//! — the random-selector compressor ablation ("Rd." in paper Table 3) uses
//! it on both sides so golden files replay exactly.

/// SplitMix64 mixer. Pinned vectors tested against the python twin.
pub fn splitmix64(x: u64) -> u64 {
    let x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Pseudo-score for the random-selector compressor; must equal
/// `model.random_scores` element (seed/layer/host/head/index keyed).
pub fn random_score(seed: u64, layer: u64, host: u64, head: u64, idx: u64) -> f32 {
    let key = (seed << 40) ^ (layer << 28) ^ (host << 16) ^ (head << 12) ^ idx;
    (splitmix64(key) as f64 / 2f64.powi(64)) as f32
}

/// xoshiro256** — general-purpose deterministic RNG for workloads/tests.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // Seed the state through splitmix64 per the xoshiro reference.
        let mut x = seed;
        let mut s = [0u64; 4];
        for slot in &mut s {
            x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
            *slot = splitmix64(x);
        }
        Rng { s }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        // Modulo bias is negligible for n << 2^64 (largest n here ~ 2^32).
        self.next_u64() % n.max(1)
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as i64
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n) (k <= n), ascending.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx.sort_unstable();
        idx
    }

    /// Weighted choice over non-negative weights; returns an index.
    pub fn choice_weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        if total <= 0.0 {
            return self.below(weights.len() as u64) as usize;
        }
        let mut target = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            target -= w;
            if target <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix64_pinned_vectors_match_python() {
        // Same vectors asserted in python/tests/test_retaining.py.
        assert_eq!(splitmix64(0), 0xE220_A839_7B1D_CDAF);
        assert_eq!(splitmix64(1), 0x910A_2DEC_8902_5CC1);
        assert_eq!(splitmix64(0xDEAD_BEEF), 0x4ADF_B90F_68C9_EB9B);
    }

    #[test]
    fn rng_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.08, "var {var}");
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut r = Rng::new(3);
        for _ in 0..50 {
            let k = r.below(20) as usize;
            let s = r.sample_indices(20, k);
            assert_eq!(s.len(), k);
            for w in s.windows(2) {
                assert!(w[0] < w[1]);
            }
            assert!(s.iter().all(|&i| i < 20));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_choice_respects_weights() {
        let mut r = Rng::new(9);
        let w = [0.0, 0.0, 1.0, 0.0];
        for _ in 0..100 {
            assert_eq!(r.choice_weighted(&w), 2);
        }
    }
}
