//! Analytical wall-time model for all six methods — the calibrated twin of
//! the paper's measured speed results (Figures 1/3/4/5/6, Tables 9–15).
//!
//! Per method it produces the Figure 5 component breakdown for prefill,
//! a per-step decode time, and an OOM verdict from the memory model. The
//! model prices each component as max(compute, memory) roofline time on
//! one device plus α–β collective costs, using the instrumented FLOPs
//! counters from `flops.rs`.
//!
//! Four of these methods also run as *executable* cluster modes
//! (`config::AttnMethod` routed through `coordinator`), so their comm
//! volumes and exactness are measured, not just modelled —
//! `impl From<AttnMethod> for Method` is the bridge, and
//! [`Method::exact_attention`] must agree with
//! `AttnMethod::exact_attention` (tested below). See `docs/architecture.md`
//! ("Method matrix") for the modelled × executable inventory.

use super::flops::{self, ComponentFlops, Hyper};
use super::hardware::Hardware;
use super::memory;
use super::profiles::ModelProfile;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Method {
    FlashAttn,
    Ulysses,
    RingAttn,
    MInference,
    StarAttn,
    Apb,
}

impl Method {
    pub const ALL: [Method; 6] = [
        Method::FlashAttn,
        Method::Ulysses,
        Method::RingAttn,
        Method::MInference,
        Method::StarAttn,
        Method::Apb,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Method::FlashAttn => "FlashAttn",
            Method::Ulysses => "Ulysses",
            Method::RingAttn => "RingAttn",
            Method::MInference => "MInference",
            Method::StarAttn => "StarAttn",
            Method::Apb => "APB",
        }
    }

    pub fn uses_sequence_parallelism(&self) -> bool {
        matches!(self, Method::Ulysses | Method::RingAttn | Method::StarAttn | Method::Apb)
    }

    pub fn exact_attention(&self) -> bool {
        matches!(self, Method::FlashAttn | Method::Ulysses | Method::RingAttn)
    }
}

/// Map an executable cluster mode onto its analytic twin. `Dense` — the
/// whole sequence with plain causal attention on one device — is exactly
/// what the `FlashAttn` row of the tables models.
impl From<crate::config::AttnMethod> for Method {
    fn from(m: crate::config::AttnMethod) -> Method {
        use crate::config::AttnMethod as A;
        match m {
            A::Apb => Method::Apb,
            A::StarAttn => Method::StarAttn,
            A::RingAttn => Method::RingAttn,
            A::Dense => Method::FlashAttn,
        }
    }
}

/// MInference effective visible keys per query (head-pattern budget).
pub const MINFERENCE_EFFECTIVE_KEYS: f64 = 12288.0;

/// Figure 5 / Table 13 component breakdown (seconds, whole prefill on the
/// critical-path host).
#[derive(Debug, Clone, Copy, Default)]
pub struct Breakdown {
    pub qkv: f64,
    pub retaining: f64,
    pub comm: f64,
    pub attention: f64,
    pub o_proj: f64,
    pub ffn: f64,
    pub others: f64,
}

impl Breakdown {
    pub fn total(&self) -> f64 {
        self.qkv + self.retaining + self.comm + self.attention + self.o_proj + self.ffn
            + self.others
    }

    fn from_components(c: &ComponentFlops, hw: &Hardware, attn_bytes: f64) -> Breakdown {
        let core = Breakdown {
            qkv: hw.t_gemm(c.qkv),
            retaining: hw.t_gemm(c.retaining),
            comm: 0.0,
            attention: hw.t_attn(c.attention, attn_bytes),
            o_proj: hw.t_gemm(c.o_proj),
            ffn: hw.t_gemm(c.ffn),
            others: 0.0,
        };
        // "Others" (norms, rope, embedding, softmax epilogues) tracked as a
        // fixed fraction of the core time, calibrated on Table 13 (~4–7%).
        Breakdown { others: 0.05 * core.total(), ..core }
    }
}

/// Full prefill+decode estimate for one request.
#[derive(Debug, Clone, Copy)]
pub struct Estimate {
    pub prefill: Breakdown,
    /// Serial prefill time (comm + compute summed) — the calibrated number
    /// the Table 11/13 assertions anchor on.
    pub prefill_s: f64,
    /// Prefill time under the comm/compute-overlap model: per layer step
    /// the collective runs concurrently with the attention compute, so the
    /// step costs `max(comm, attention) + rest` instead of
    /// `comm + attention + rest` ("Context Parallelism for Scalable
    /// Million-Token Inference"; the executable twin is the split
    /// post/complete rotation in `coordinator::prefill`). Layer steps are
    /// uniform, so the per-step max aggregates to
    /// `total - min(comm, attention)`.
    pub prefill_overlapped_s: f64,
    /// Communication hidden behind compute under the overlap model:
    /// `min(comm, attention)`. For RingAttn this hides the *exposed*
    /// fraction its calibrated comm term already models (the 0.6 exposure
    /// factor), i.e. the overlap estimate is the optimistic bound on top of
    /// the calibration.
    pub comm_hidden_s: f64,
    /// Warm (prefix-cache hit) prefill time: the document KV already sits
    /// in the pool's shared-prefix store, so the whole per-layer document
    /// pass — compute AND collectives — is skipped
    /// (`docs/ADR-003-prefix-caching.md`). What remains is wiring the
    /// resident KV share into the session (one HBM stream over it) plus
    /// the LM-head epilogue. The executable twin is the one-step
    /// `PrefixAttach` machine; `fig1_prefill` emits this next to the
    /// measured warm walltime in `BENCH_prefill.json`.
    pub prefill_warm_s: f64,
    pub decode_per_token_s: f64,
    pub oom: bool,
    pub flops_total: f64,
    pub mem_bytes_peak: f64,
}

impl Estimate {
    /// Fraction of the modeled communication the overlap model hides
    /// behind compute (0 for methods that do not communicate; 1 when comm
    /// fits entirely under the attention of the same step). This is the
    /// "overlap win" `fig1_prefill`/`fig6_prefill_decode` report per
    /// method and `BENCH_prefill.json` records.
    pub fn overlap_fraction(&self) -> f64 {
        if self.prefill.comm <= 0.0 {
            return 0.0;
        }
        self.comm_hidden_s / self.prefill.comm
    }

    /// Modeled cold/warm prefill ratio — the multi-tenant shared-corpus
    /// win the prefix cache buys when a request's document digest hits.
    pub fn warm_speedup(&self) -> f64 {
        self.prefill_s / self.prefill_warm_s.max(f64::MIN_POSITIVE)
    }
}

/// Attention HBM traffic on one device: Q/K/V/O streamed once plus the KV
/// re-reads FlashAttention does per query tile (modelled as `reread` full
/// passes over the visible KV).
fn attn_bytes(m: &ModelProfile, seq_rows: f64, visible_avg: f64, hw: &Hardware) -> f64 {
    let hd = m.head_dim();
    let qo = 2.0 * seq_rows * m.heads * hd * hw.elem_bytes;
    let kv = 2.0 * visible_avg * m.kv_heads * hd * hw.elem_bytes;
    m.layers * (qo + 6.0 * kv)
}

/// Estimate one method at input length `n` with `hosts` devices.
pub fn estimate(method: Method, m: &ModelProfile, n: f64, hosts: f64, hy: &Hyper,
                hw: &Hardware, _n_out: f64) -> Estimate {
    let mem = memory::peak_bytes(method, m, n, hosts, hy, hw);
    let oom = mem > hw.mem_cap;
    let (mut bd, flops_total) = match method {
        Method::FlashAttn => {
            let c = flops::fullattn_components(m, n);
            let b = attn_bytes(m, n, n / 2.0, hw);
            (Breakdown::from_components(&c, hw, b), c.total())
        }
        Method::MInference => {
            let c0 = flops::fullattn_components(m, n);
            let vis = MINFERENCE_EFFECTIVE_KEYS.min(n / 2.0);
            let c = ComponentFlops {
                attention: m.layers * 4.0 * n * vis * m.d / m.heads * m.heads,
                ..c0
            };
            // Sparse attention is scatter/gather heavy: lower effective
            // bandwidth (0.35x) + per-layer pattern-build overhead.
            let b = attn_bytes(m, n, vis, hw) / 0.35;
            let mut bd = Breakdown::from_components(&c, hw, b);
            bd.others += m.layers * 2.5e-3; // pattern search/dispatch
            (bd, c.total())
        }
        Method::Ulysses => {
            let c = flops::sp_exact_components(m, n, hosts);
            let b = attn_bytes(m, n / hosts, n / 2.0, hw);
            let mut bd = Breakdown::from_components(&c, hw, b);
            // 4 AllToAll rounds on Q,K,V,O per layer: each moves the
            // per-host activation slab.
            let slab = n / hosts * m.d * hw.elem_bytes;
            bd.comm = m.layers * 4.0 * hw.t_coll(slab * (hosts - 1.0) / hosts);
            (bd, c.total())
        }
        Method::RingAttn => {
            let c = flops::sp_exact_components(m, n, hosts);
            let b = attn_bytes(m, n / hosts, n / 2.0, hw);
            let mut bd = Breakdown::from_components(&c, hw, b);
            // H-1 rounds of KV-block ring passes per layer; overlap with
            // compute is imperfect (paper: Ring slower than Ulysses), model
            // exposed fraction as 60% of the volume.
            let kv_blk = 2.0 * (n / hosts) * m.kv_heads * m.head_dim() * hw.elem_bytes;
            bd.comm = m.layers * (hosts - 1.0) * hw.t_coll(kv_blk) * 0.6;
            // Ring's attention can't start on later blocks early: add the
            // pipeline bubble as attention inflation.
            bd.attention *= 1.55;
            (bd, c.total())
        }
        Method::StarAttn => {
            let c = flops::starattn_components(m, n, hosts);
            let seq = 2.0 * n / hosts;
            let b = attn_bytes(m, seq, n / hosts * 1.5, hw);
            (Breakdown::from_components(&c, hw, b), c.total() * hosts)
        }
        Method::Apb => {
            let c = flops::apb_components(m, n, hy, 1024.0);
            let l_aq = hy.l_a + hy.l_q;
            let seq = n / hosts + l_aq;
            let vis = l_aq + (hosts - 1.0) * hy.l_p / 2.0 + n / hosts / 2.0;
            let b = attn_bytes(m, seq, vis, hw);
            let mut bd = Breakdown::from_components(&c, hw, b);
            // One AllGather of the compressed block per layer.
            let blk = 2.0 * hy.l_p * m.kv_heads * m.head_dim() * hw.elem_bytes;
            bd.comm = m.layers * hw.t_coll(blk * (hosts - 1.0));
            (bd, flops::apb_flops(m, n, hy))
        }
    };
    // LM head on the last position.
    bd.others += hw.t_gemm(2.0 * m.d * m.vocab);

    // Overlap model: each layer's collective can run under that layer's
    // attention compute, so the hidden volume is min(comm, attention)
    // (uniform layers ⇒ per-step max == total - min).
    let comm_hidden_s = bd.comm.min(bd.attention);
    // Warm (prefix-hit) prefill: skip the whole per-layer document pass;
    // pay one HBM stream over the host's resident KV share (the attach)
    // plus the LM-head epilogue. Single-device methods hold the full
    // sequence's KV; SP methods hold 1/hosts of it.
    let resident_tokens = if method.uses_sequence_parallelism() { n / hosts } else { n };
    let prefill_warm_s = hw.t_mem(resident_tokens * m.kv_bytes_per_token(hw.elem_bytes))
        + hw.t_gemm(2.0 * m.d * m.vocab);
    let decode = decode_per_token(method, m, n, hosts, hw);
    Estimate {
        prefill: bd,
        prefill_s: bd.total(),
        prefill_overlapped_s: bd.total() - comm_hidden_s,
        comm_hidden_s,
        prefill_warm_s,
        decode_per_token_s: decode,
        oom,
        flops_total,
        mem_bytes_peak: mem,
    }
}

/// Decode is memory-bound: stream weights + visible KV once per token.
/// SP methods split the KV across hosts and add a small gather.
pub fn decode_per_token(method: Method, m: &ModelProfile, n: f64, hosts: f64,
                        hw: &Hardware) -> f64 {
    let weight_bytes = m.params * hw.elem_bytes;
    let kv_tokens = match method {
        Method::MInference => n, // MInference keeps the dense cache
        _ => n,
    };
    let kv_bytes = kv_tokens * m.kv_bytes_per_token(hw.elem_bytes);
    if method.uses_sequence_parallelism() {
        // Weights are replicated (read fully), KV split across hosts;
        // plus one (out, lse) gather per layer.
        let t_mem = hw.t_mem(weight_bytes + kv_bytes / hosts);
        let gather = m.layers * hw.t_coll(m.heads * m.head_dim() * hw.elem_bytes);
        t_mem + gather
    } else {
        let factor = if method == Method::MInference { 2.2 } else { 1.0 };
        hw.t_mem(weight_bytes + kv_bytes) * factor
    }
}

/// Paper speed metric (§4.1): (input + output tokens) / total time.
pub fn speed_tok_per_s(est: &Estimate, n_in: f64, n_out: f64) -> Option<f64> {
    if est.oom {
        return None;
    }
    let total = est.prefill_s + est.decode_per_token_s * n_out;
    Some((n_in + n_out) / total)
}

// ---------------------------------------------------------------------------
// Adaptive decode/append comm model (docs/ADR-007-adaptive-decode.md).
//
// The executable cluster exposes two merge collectives for decode/append:
// pass-KV (re-gather the distributed KV the new rows must attend — volume
// grows with resident context) and pass-Q (rotate the new rows' (out, lse)
// attention partials around the qring — volume independent of context).
// The executable twin measures the qring volume exactly
// (`benches/fig_decode_scaling.rs`); this model prices both sides so the
// crossover and the million-token scaling story can be swept far past what
// the tiny sim config can hold.
// ---------------------------------------------------------------------------

/// Total bytes pass-KV moves to append `t_new` tokens onto a resident
/// context of `n_ctx` tokens sharded across `hosts` devices: the other
/// hosts' context KV shares are re-gathered so the new rows can attend
/// them, and the new rows' own KV is broadcast so every replica extends.
/// Grows linearly in `n_ctx` — the curve the modeled section of
/// `BENCH_decode.json` records. Summed over layers.
pub fn pass_kv_comm_bytes(m: &ModelProfile, n_ctx: f64, t_new: f64, hosts: f64,
                          hw: &Hardware) -> f64 {
    let kv_row = 2.0 * m.kv_heads * m.head_dim() * hw.elem_bytes;
    m.layers * (n_ctx * (hosts - 1.0) / hosts + t_new * (hosts - 1.0)) * kv_row
}

/// Total bytes pass-Q moves for the same append: `hosts - 1` qring
/// rotation rounds per layer, each carrying the `t_new` rows'
/// `(out [h, hd], lse [h])` partial — independent of `n_ctx`, which is the
/// whole point of the rotation.
pub fn pass_q_comm_bytes(m: &ModelProfile, t_new: f64, hosts: f64, hw: &Hardware) -> f64 {
    let partial_row = (m.heads * m.head_dim() + m.heads) * hw.elem_bytes;
    m.layers * (hosts - 1.0) * t_new * partial_row
}

/// α–β time for the pass-KV side: one gather collective per layer.
pub fn pass_kv_comm_time(m: &ModelProfile, n_ctx: f64, t_new: f64, hosts: f64,
                         hw: &Hardware) -> f64 {
    let total = pass_kv_comm_bytes(m, n_ctx, t_new, hosts, hw);
    m.layers * hw.t_coll(total / m.layers)
}

/// α–β time for the pass-Q side: `hosts - 1` rotation rounds per layer,
/// each paying the collective latency on its own (small) payload.
pub fn pass_q_comm_time(m: &ModelProfile, t_new: f64, hosts: f64, hw: &Hardware) -> f64 {
    if hosts < 2.0 {
        return 0.0;
    }
    let rounds = m.layers * (hosts - 1.0);
    let total = pass_q_comm_bytes(m, t_new, hosts, hw);
    rounds * hw.t_coll(total / rounds)
}

/// The modeled adaptive chooser: pick whichever strategy moves its volume
/// faster for this (context, append, topology) point. Mirrors the
/// executable `PassStrategy::Auto` resolution — the executable gate is
/// warmth (pass-Q needs a resident distributed cache), the modeled gate is
/// the comm-time crossover; `BENCH_decode.json`'s validator checks the
/// pick equals the per-point winner. Never returns `Auto`. Degenerate
/// topologies (one host) fall back to pass-KV, like
/// `config::PassStrategy::resolve`.
pub fn choose_pass_strategy(m: &ModelProfile, n_ctx: f64, t_new: f64, hosts: f64,
                            hw: &Hardware) -> crate::config::PassStrategy {
    use crate::config::PassStrategy;
    if hosts < 2.0 {
        return PassStrategy::PassKv;
    }
    let kv = pass_kv_comm_time(m, n_ctx, t_new, hosts, hw);
    let q = pass_q_comm_time(m, t_new, hosts, hw);
    if q <= kv {
        PassStrategy::PassQ
    } else {
        PassStrategy::PassKv
    }
}

/// One point of the decode/append scaling sweep.
#[derive(Debug, Clone, Copy)]
pub struct DecodePoint {
    pub n_ctx: f64,
    /// Modeled comm volume of the append under each strategy (bytes,
    /// summed over layers).
    pub pass_kv_bytes: f64,
    pub pass_q_bytes: f64,
    /// Modeled append step time: shared memory-bound base (weights + the
    /// host's KV shard streamed once) plus the strategy's comm time.
    pub pass_kv_s: f64,
    pub pass_q_s: f64,
    /// The adaptive pick and its time — always the per-point winner.
    pub auto: crate::config::PassStrategy,
    pub auto_s: f64,
}

/// Context lengths for the decode scaling sweep — from the modeled
/// crossover region up past the million-token mark the ROADMAP north star
/// calls for.
pub const DECODE_SWEEP_LENGTHS: [f64; 7] =
    [4096.0, 65536.0, 131072.0, 262144.0, 524288.0, 1048576.0, 2097152.0];

/// Sweep the decode/append model over `lengths`, pricing both strategies
/// and the adaptive pick at each point (`BENCH_decode.json`'s modeled
/// section; validated on CI's threaded leg).
pub fn decode_scaling_sweep(m: &ModelProfile, t_new: f64, hosts: f64, hw: &Hardware,
                            lengths: &[f64]) -> Vec<DecodePoint> {
    use crate::config::PassStrategy;
    lengths
        .iter()
        .map(|&n_ctx| {
            let base = hw.t_mem(
                m.params * hw.elem_bytes
                    + n_ctx * m.kv_bytes_per_token(hw.elem_bytes) / hosts,
            );
            let pass_kv_bytes = pass_kv_comm_bytes(m, n_ctx, t_new, hosts, hw);
            let pass_q_bytes = pass_q_comm_bytes(m, t_new, hosts, hw);
            let pass_kv_s = base + pass_kv_comm_time(m, n_ctx, t_new, hosts, hw);
            let pass_q_s = base + pass_q_comm_time(m, t_new, hosts, hw);
            let auto = choose_pass_strategy(m, n_ctx, t_new, hosts, hw);
            let auto_s = if auto == PassStrategy::PassQ { pass_q_s } else { pass_kv_s };
            DecodePoint { n_ctx, pass_kv_bytes, pass_q_bytes, pass_kv_s, pass_q_s, auto, auto_s }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attnsim::hardware::A800;
    use crate::attnsim::profiles::LLAMA31_8B;

    fn est(method: Method, n: f64) -> Estimate {
        let hy = Hyper::paper_schedule(n, 8.0);
        estimate(method, &LLAMA31_8B, n, 8.0, &hy, &A800, 64.0)
    }

    #[test]
    fn executable_methods_agree_with_analytic_exactness() {
        // The modelled Method and the executable AttnMethod must never
        // disagree about which modes are exact — otherwise the accuracy
        // tables would claim exactness the cluster doesn't deliver.
        use crate::config::AttnMethod;
        for m in AttnMethod::ALL {
            assert_eq!(
                m.exact_attention(),
                Method::from(m).exact_attention(),
                "exactness mismatch for {}",
                m.name()
            );
        }
        assert_eq!(Method::from(AttnMethod::Dense), Method::FlashAttn);
        assert_eq!(Method::from(AttnMethod::Apb), Method::Apb);
    }

    #[test]
    fn figure1_ordering_at_128k() {
        // Paper Table 11 @128K: APB 0.94s < Star 3.50 < Ulysses 3.95 <
        // Ring 6.34 < MInference 15.16 < FlashAttn 30.01.
        let t = |m| est(m, 131072.0).prefill_s;
        assert!(t(Method::Apb) < t(Method::StarAttn));
        assert!(t(Method::StarAttn) < t(Method::Ulysses));
        assert!(t(Method::Ulysses) < t(Method::RingAttn));
        assert!(t(Method::RingAttn) < t(Method::MInference));
        assert!(t(Method::MInference) < t(Method::FlashAttn));
    }

    #[test]
    fn headline_speedups_within_band() {
        // Paper headline: APB up to 9.2x vs FlashAttn, 4.2x vs Ring,
        // 1.6x vs Star. Check the 128K point sits in a sane band.
        let apb = est(Method::Apb, 131072.0).prefill_s;
        let flash = est(Method::FlashAttn, 131072.0).prefill_s;
        let ring = est(Method::RingAttn, 131072.0).prefill_s;
        let star = est(Method::StarAttn, 131072.0).prefill_s;
        let s_flash = flash / apb;
        let s_ring = ring / apb;
        let s_star = star / apb;
        assert!((4.0..40.0).contains(&s_flash), "flash speedup {s_flash}");
        assert!((2.0..12.0).contains(&s_ring), "ring speedup {s_ring}");
        assert!((1.15..4.0).contains(&s_star), "star speedup {s_star}");
    }

    #[test]
    fn oom_pattern_matches_table11() {
        // FlashAttn & MInference OOM at 256K; SP methods OOM at 1M except APB.
        assert!(!est(Method::FlashAttn, 131072.0).oom);
        assert!(est(Method::FlashAttn, 262144.0).oom);
        assert!(est(Method::MInference, 262144.0).oom);
        assert!(!est(Method::Ulysses, 524288.0).oom);
        assert!(est(Method::Ulysses, 1048576.0).oom);
        assert!(est(Method::RingAttn, 1048576.0).oom);
        assert!(est(Method::StarAttn, 1048576.0).oom);
        assert!(!est(Method::Apb, 1048576.0).oom, "APB must survive 1M");
    }

    #[test]
    fn apb_advantage_grows_with_length() {
        let ratio = |n: f64| {
            est(Method::StarAttn, n).prefill_s / est(Method::Apb, n).prefill_s
        };
        assert!(ratio(524288.0) > ratio(65536.0) * 0.95,
                "APB advantage should not shrink with length");
    }

    #[test]
    fn decode_negligible_vs_prefill_at_128k() {
        // Figure 6: prefill dominates.
        let e = est(Method::Apb, 131072.0);
        let decode_total = e.decode_per_token_s * 64.0;
        assert!(decode_total < e.prefill_s,
                "decode {decode_total} vs prefill {}", e.prefill_s);
    }

    #[test]
    fn overlap_model_bounds_and_method_structure() {
        for method in Method::ALL {
            let e = est(method, 131072.0);
            // Overlap can only help, and never more than the full comm.
            assert!(e.prefill_overlapped_s <= e.prefill_s, "{}", method.name());
            assert!(e.prefill_overlapped_s >= e.prefill_s - e.prefill.comm - 1e-12,
                    "{}", method.name());
            let f = e.overlap_fraction();
            assert!((0.0..=1.0).contains(&f), "{}: fraction {f}", method.name());
            assert!((e.comm_hidden_s - (e.prefill_s - e.prefill_overlapped_s)).abs()
                        < 1e-12);
        }
        // Methods without collectives hide nothing; APB's tiny compressed
        // pass hides entirely under its attention (Figure 5: 0.62ms comm
        // vs 34ms attention).
        assert_eq!(est(Method::FlashAttn, 131072.0).overlap_fraction(), 0.0);
        assert_eq!(est(Method::MInference, 131072.0).overlap_fraction(), 0.0);
        let apb = est(Method::Apb, 131072.0);
        assert!(apb.overlap_fraction() > 0.99,
                "APB comm must hide under attention, fraction {}",
                apb.overlap_fraction());
        assert!(apb.comm_hidden_s > 0.0);
        // Ring moves real volume: overlap must win something visible.
        assert!(est(Method::RingAttn, 131072.0).comm_hidden_s > 0.0);
    }

    #[test]
    fn warm_prefill_model_bounds_and_ordering() {
        // A prefix-cache hit skips the whole document pass: the modeled
        // warm time must be positive (the attach still streams the cached
        // KV) and far below even the overlapped cold time, for every
        // method and length.
        for method in Method::ALL {
            for n in [32768.0, 131072.0, 524288.0] {
                let e = est(method, n);
                assert!(e.prefill_warm_s > 0.0, "{} @{n}", method.name());
                assert!(e.prefill_warm_s < e.prefill_overlapped_s,
                        "{} @{n}: warm {} !< overlapped {}", method.name(),
                        e.prefill_warm_s, e.prefill_overlapped_s);
                assert!(e.warm_speedup() > 1.0, "{} @{n}", method.name());
            }
        }
        // SP methods split the resident KV across hosts, so their attach is
        // cheaper than the single-device methods' full-sequence stream.
        let e128 = |m| est(m, 131072.0).prefill_warm_s;
        assert!(e128(Method::Apb) < e128(Method::FlashAttn));
        // And the headline: APB's warm hit is at least an order of
        // magnitude under its own cold prefill at 128K.
        assert!(est(Method::Apb, 131072.0).warm_speedup() > 10.0);
    }

    #[test]
    fn speed_metric_none_on_oom() {
        let e = est(Method::FlashAttn, 1048576.0);
        assert!(e.oom);
        assert_eq!(speed_tok_per_s(&e, 1048576.0, 64.0), None);
    }

    #[test]
    fn pass_q_comm_flat_while_pass_kv_grows_to_a_million_tokens() {
        // The ISSUE acceptance: qring volume independent of context while
        // the pass-KV side grows linearly, swept past 1M tokens.
        let last = *DECODE_SWEEP_LENGTHS.last().unwrap();
        assert!(last >= 1_048_576.0, "sweep must reach the million-token mark");
        let pts = decode_scaling_sweep(&LLAMA31_8B, 1.0, 8.0, &A800,
                                       &DECODE_SWEEP_LENGTHS);
        for w in pts.windows(2) {
            assert!(w[1].pass_kv_bytes > w[0].pass_kv_bytes,
                    "pass-KV volume must grow with context");
            assert!(w[1].pass_kv_s > w[0].pass_kv_s,
                    "pass-KV step time must grow with context");
            assert!((w[1].pass_q_bytes - w[0].pass_q_bytes).abs() < 1e-9,
                    "pass-Q volume must not depend on context");
        }
        // At scale the rotation wins outright.
        let at_1m = pts.iter().find(|p| p.n_ctx == 1_048_576.0).unwrap();
        assert!(at_1m.pass_q_s < at_1m.pass_kv_s);
        assert_eq!(at_1m.auto, crate::config::PassStrategy::PassQ);
    }

    #[test]
    fn auto_pick_matches_per_point_winner() {
        for t_new in [1.0, 256.0, 4096.0] {
            let pts = decode_scaling_sweep(&LLAMA31_8B, t_new, 8.0, &A800,
                                           &DECODE_SWEEP_LENGTHS);
            for p in &pts {
                let min = p.pass_kv_s.min(p.pass_q_s);
                assert_eq!(p.auto_s, min, "auto must take the per-point minimum");
                let want = if p.pass_q_s <= p.pass_kv_s {
                    crate::config::PassStrategy::PassQ
                } else {
                    crate::config::PassStrategy::PassKv
                };
                assert_eq!(p.auto, want, "auto pick at n_ctx {}", p.n_ctx);
            }
        }
    }

    #[test]
    fn chooser_crossover_and_degenerate_topology() {
        use crate::config::PassStrategy;
        // A bulk append onto a tiny resident context moves more partial
        // volume around the ring than re-gathering the context costs:
        // the chooser must flip back to pass-KV on that side of the
        // crossover.
        assert_eq!(choose_pass_strategy(&LLAMA31_8B, 64.0, 4096.0, 8.0, &A800),
                   PassStrategy::PassKv);
        // Steady-state decode on a long resident context: pass-Q.
        assert_eq!(choose_pass_strategy(&LLAMA31_8B, 1_048_576.0, 1.0, 8.0, &A800),
                   PassStrategy::PassQ);
        // One host has no ring to rotate around.
        assert_eq!(choose_pass_strategy(&LLAMA31_8B, 1_048_576.0, 1.0, 1.0, &A800),
                   PassStrategy::PassKv);
    }

    #[test]
    fn apb_comm_small_vs_attention() {
        // Figure 5: APB's communication is tiny (0.62ms vs 34ms attention).
        let e = est(Method::Apb, 131072.0);
        assert!(e.prefill.comm < 0.2 * e.prefill.attention,
                "comm {} vs attention {}", e.prefill.comm, e.prefill.attention);
    }
}
