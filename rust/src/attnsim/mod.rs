//! Analytical performance model of the paper's testbed (S11/S12 in
//! DESIGN.md): Table 6 FLOPs formulas, an A800 hardware profile, a
//! component-level wall-time model for all six methods, and the memory/OOM
//! model. Every speed table and figure in the paper is regenerated from
//! this module (see benches/), while numerics correctness is established
//! by the real PJRT cluster in `coordinator`.

pub mod flops;
pub mod hardware;
pub mod memory;
pub mod profiles;
pub mod walltime;

pub use flops::{apb_flops, fullattn_flops, minference_flops, starattn_flops, Hyper};
pub use hardware::{Hardware, A800};
pub use profiles::{ModelProfile, ALL_MODELS, LLAMA31_8B, QWEN25_14B, YI_34B};
pub use walltime::{
    choose_pass_strategy, decode_scaling_sweep, estimate, pass_kv_comm_bytes,
    pass_q_comm_bytes, speed_tok_per_s, Breakdown, DecodePoint, Estimate, Method,
    DECODE_SWEEP_LENGTHS,
};
