//! Hardware profiles for the analytical wall-time model.
//!
//! The paper's testbed (Appendix B.3): 8× NVIDIA A800-80GB per node,
//! third-generation NVLink intra-node, HDR InfiniBand across nodes. We
//! model each device with peak dense throughput, HBM bandwidth and
//! capacity, plus an α–β (latency–bandwidth) interconnect model.
//!
//! Efficiency factors (MFU) are calibrated once against the paper's
//! measured per-component times (Table 13) and then held fixed for every
//! experiment — the model's job is to reproduce *orderings and ratios*,
//! not absolute milliseconds (DESIGN.md §2).

#[derive(Debug, Clone, Copy)]
pub struct Hardware {
    /// Peak dense bf16 FLOP/s of one device.
    pub flops_peak: f64,
    /// HBM bandwidth, bytes/s.
    pub mem_bw: f64,
    /// HBM capacity, bytes.
    pub mem_cap: f64,
    /// Intra-node collective bandwidth per device, bytes/s (NVLink).
    pub link_bw: f64,
    /// Collective base latency per round, seconds.
    pub link_latency: f64,
    /// Achieved fraction of peak for big GEMMs.
    pub mfu_gemm: f64,
    /// Achieved fraction of peak for FlashAttention-style kernels.
    pub mfu_attn: f64,
    /// Bytes per element of activations/KV (bf16).
    pub elem_bytes: f64,
}

/// A800-80G node (NVLink3 + HDR IB), the paper's testbed.
pub const A800: Hardware = Hardware {
    flops_peak: 312e12,
    mem_bw: 2.0e12,
    mem_cap: 80e9,
    link_bw: 200e9, // effective per-direction NVLink collective bandwidth
    link_latency: 20e-6,
    mfu_gemm: 0.62,
    mfu_attn: 0.55,
    elem_bytes: 2.0,
};

impl Hardware {
    /// Time for `flops` of GEMM work on one device.
    pub fn t_gemm(&self, flops: f64) -> f64 {
        flops / (self.flops_peak * self.mfu_gemm)
    }

    /// Time for `flops` of attention work on one device, with a memory-
    /// bandwidth floor of `bytes` moved (roofline max).
    pub fn t_attn(&self, flops: f64, bytes: f64) -> f64 {
        (flops / (self.flops_peak * self.mfu_attn)).max(bytes / self.mem_bw)
    }

    /// Memory-bound time for streaming `bytes` through HBM.
    pub fn t_mem(&self, bytes: f64) -> f64 {
        bytes / self.mem_bw
    }

    /// α–β model for one collective round moving `bytes` per device.
    pub fn t_coll(&self, bytes: f64) -> f64 {
        self.link_latency + bytes / self.link_bw
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_time_scales_linearly() {
        let t1 = A800.t_gemm(1e12);
        let t2 = A800.t_gemm(2e12);
        assert!((t2 / t1 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn attn_respects_memory_floor() {
        // Tiny FLOPs but huge bytes -> memory bound.
        let t = A800.t_attn(1.0, 2.0e12);
        assert!((t - 1.0).abs() < 1e-9);
        // Huge FLOPs, tiny bytes -> compute bound.
        let t = A800.t_attn(312e12 * A800.mfu_attn, 1.0);
        assert!((t - 1.0).abs() < 1e-6);
    }

    #[test]
    fn collective_has_latency_floor() {
        assert!(A800.t_coll(0.0) >= A800.link_latency);
        assert!(A800.t_coll(200e9) > 1.0);
    }
}
