//! Model profiles: the paper's evaluation models, expressed in the Table 6
//! notation (L layers, hidden d, GQA factor g, FFN intermediate I).

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ModelProfile {
    pub name: &'static str,
    pub layers: f64,      // L
    pub d: f64,           // hidden size
    pub heads: f64,
    pub kv_heads: f64,
    pub inter: f64,       // I (FFN intermediate)
    pub vocab: f64,
    pub params: f64,      // total parameter count (for memory + decode)
    /// Layer-split pipeline stages (paper §B.2.1: Yi-34B runs across two
    /// 8-GPU machines with layers evenly divided).
    pub stages: f64,
}

impl ModelProfile {
    /// GQA factor g = heads / kv_heads.
    pub fn g(&self) -> f64 {
        self.heads / self.kv_heads
    }

    pub fn head_dim(&self) -> f64 {
        self.d / self.heads
    }

    /// KV-cache bytes per token (both K and V, all layers), bf16.
    pub fn kv_bytes_per_token(&self, elem_bytes: f64) -> f64 {
        2.0 * self.layers * self.kv_heads * self.head_dim() * elem_bytes
    }
}

/// Llama-3.1-8B-instruct (also Llama-3-8B-1M for the length sweep).
pub const LLAMA31_8B: ModelProfile = ModelProfile {
    name: "Llama-3.1-8B",
    layers: 32.0,
    d: 4096.0,
    heads: 32.0,
    kv_heads: 8.0,
    inter: 14336.0,
    vocab: 128256.0,
    params: 8.03e9,
    stages: 1.0,
};

/// Qwen-2.5-14B-instruct.
pub const QWEN25_14B: ModelProfile = ModelProfile {
    name: "Qwen-2.5-14B",
    layers: 48.0,
    d: 5120.0,
    heads: 40.0,
    kv_heads: 8.0,
    inter: 13824.0,
    vocab: 152064.0,
    params: 14.7e9,
    stages: 1.0,
};

/// Yi-34B-200K (paper runs it layer-split across two 8-GPU machines; the
/// per-device model is therefore L/2 deep — we keep the full profile and
/// model the pipeline split in the wall-time layer).
pub const YI_34B: ModelProfile = ModelProfile {
    name: "Yi-34B-200K",
    layers: 60.0,
    d: 7168.0,
    heads: 56.0,
    kv_heads: 8.0,
    inter: 20480.0,
    vocab: 64000.0,
    params: 34.4e9,
    stages: 2.0,
};

pub const ALL_MODELS: [ModelProfile; 3] = [LLAMA31_8B, QWEN25_14B, YI_34B];

/// The tiny local config, for cross-checking the FLOPs model against the
/// instrumented real pipeline.
pub fn from_config(cfg: &crate::config::Config) -> ModelProfile {
    let m = &cfg.model;
    // Parameter count: embed + lm_head + per-layer (attn + ffn + norms).
    let d = m.d_model as f64;
    let per_layer = d * d * (1.0 + 1.0 / (m.gqa_groups() as f64)) * 2.0
        + 3.0 * d * m.d_ff as f64;
    let params = 2.0 * (m.vocab_size as f64) * d + (m.n_layers as f64) * per_layer;
    ModelProfile {
        name: "local",
        layers: m.n_layers as f64,
        d,
        heads: m.n_heads as f64,
        kv_heads: m.n_kv_heads as f64,
        inter: m.d_ff as f64,
        vocab: m.vocab_size as f64,
        params,
        stages: 1.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gqa_factors() {
        assert_eq!(LLAMA31_8B.g(), 4.0);
        assert_eq!(QWEN25_14B.g(), 5.0);
        assert_eq!(YI_34B.g(), 7.0);
        assert_eq!(LLAMA31_8B.head_dim(), 128.0);
    }

    #[test]
    fn kv_bytes_llama_128k_matches_back_of_envelope() {
        // 2 * 32 layers * 8 kv heads * 128 dim * 2 bytes = 131072 B/token;
        // at 128K tokens ~ 17.2 GB.
        let per_tok = LLAMA31_8B.kv_bytes_per_token(2.0);
        assert_eq!(per_tok, 131072.0);
        let total = per_tok * 131072.0;
        assert!((total / 1e9 - 17.18) < 0.1);
    }
}
