//! Device-memory model: weights + KV cache + activations + method-specific
//! working sets. Drives the OOM verdicts ("x" points in Figure 1 /
//! Table 11).
//!
//! Calibration anchor (Table 11, Llama-3.1-8B on A800-80G):
//!   FlashAttn / MInference: OK at 128K, OOM at 256K   (single device)
//!   Ulysses / RingAttn / StarAttn: OK at 512K, OOM at 1M   (8 devices)
//!   APB: OK at 1M.
//! The working-set constants below reproduce exactly that pattern and are
//! documented rather than tuned per-point.

use super::flops::Hyper;
use super::hardware::Hardware;
use super::profiles::ModelProfile;
use super::walltime::Method;

/// Peak bytes on the most-loaded device.
pub fn peak_bytes(method: Method, m: &ModelProfile, n: f64, hosts: f64, hy: &Hyper,
                  hw: &Hardware) -> f64 {
    // Layer-split pipeline stages divide weights AND per-token KV evenly.
    let weights = m.params * hw.elem_bytes / m.stages;
    let kv_tok = m.kv_bytes_per_token(hw.elem_bytes) / m.stages;
    // Activation working set per resident token (hidden + qkv + ffn
    // intermediates kept alive across the layer, plus optimizer-free
    // inference framework overhead). ~56 * d bytes/token empirically for
    // bf16 HF-style pipelines.
    let act_per_tok = 56.0 * m.d * hw.elem_bytes / 2.0;
    // CUDA context + framework + fragmentation floor.
    let floor = 6e9;

    match method {
        Method::FlashAttn | Method::MInference => {
            let kv = n * kv_tok;
            let act = n * act_per_tok;
            let extra = if method == Method::MInference {
                // Sparse-index metadata per layer.
                n * 64.0 * m.layers / 8.0
            } else {
                0.0
            };
            weights + kv + act + extra + floor
        }
        Method::Ulysses | Method::RingAttn => {
            // Per host: n/H tokens resident, but exact SP needs transient
            // full-sequence KV passes (ring buffers / alltoall slabs) that
            // scale with n: 2 in-flight KV blocks + head-sharded slabs.
            let resident = n / hosts;
            let kv = resident * kv_tok;
            let act = resident * act_per_tok;
            let transient = 2.0 * resident * kv_tok + n * 8.0 * hw.elem_bytes;
            weights + kv + act + transient + floor
        }
        Method::StarAttn => {
            // Anchor doubles the resident tokens per host.
            let resident = 2.0 * n / hosts;
            weights + resident * (kv_tok + act_per_tok) + floor
        }
        Method::Apb => {
            let l_aq = hy.l_a + hy.l_q;
            let resident = n / hosts + l_aq;
            let passing = (hosts - 1.0) * hy.l_p * kv_tok / m.layers; // one layer live
            weights + resident * (kv_tok + act_per_tok) + passing + floor
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attnsim::hardware::A800;
    use crate::attnsim::profiles::{LLAMA31_8B, YI_34B};

    fn peak(method: Method, n: f64) -> f64 {
        let hy = Hyper::paper_schedule(n, 8.0);
        peak_bytes(method, &LLAMA31_8B, n, 8.0, &hy, &A800)
    }

    #[test]
    fn monotone_in_length() {
        for m in Method::ALL {
            assert!(peak(m, 262144.0) > peak(m, 131072.0), "{}", m.name());
        }
    }

    #[test]
    fn apb_lighter_than_star() {
        // Smaller anchors + compressed passing blocks < full-size anchors.
        assert!(peak(Method::Apb, 524288.0) < peak(Method::StarAttn, 524288.0));
    }

    #[test]
    fn weights_dominate_small_n() {
        let p = peak(Method::FlashAttn, 1024.0);
        assert!(p > LLAMA31_8B.params * 2.0);
        assert!(p < 80e9);
    }

    #[test]
    fn yi34b_heavier_than_llama() {
        let hy = Hyper::paper_schedule(131072.0, 8.0);
        let a = peak_bytes(Method::Apb, &LLAMA31_8B, 131072.0, 8.0, &hy, &A800);
        let b = peak_bytes(Method::Apb, &YI_34B, 131072.0, 8.0, &hy, &A800);
        assert!(b > a);
    }
}
