//! FLOPs-per-forward formulas — paper Table 6, implemented verbatim, plus
//! an instrumented per-component counter the closed forms are
//! property-tested against (DESIGN.md invariant 7).
//!
//! Notation: L layers, n input length, d hidden, g GQA factor, I FFN
//! intermediate, H hosts, l_a anchor length, l_p passing length.

use super::profiles::ModelProfile;

/// APB sequence-layout hyperparameters for the analytical model
/// (paper Table 5 schedule by default).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Hyper {
    pub hosts: f64,  // H
    pub l_a: f64,
    pub l_p: f64,
    pub l_q: f64,
}

impl Hyper {
    /// Table 5: the hyperparameters used for the length sweep (§4.3).
    /// l_b = n/H; l_a = l_b/4 capped at 8K; l_p = l_b/8 capped at 8K.
    pub fn paper_schedule(n: f64, hosts: f64) -> Hyper {
        let l_b = n / hosts;
        let cap = 8192.0;
        Hyper {
            hosts,
            l_a: (l_b / 4.0).min(cap),
            l_p: (l_b / 8.0).min(cap),
            l_q: 128.0,
        }
    }

    /// End-to-end benchmark setting (§B.2.1): l_a = 4K, l_p = 2K, H = 8.
    pub fn e2e_128k() -> Hyper {
        Hyper { hosts: 8.0, l_a: 4096.0, l_p: 2048.0, l_q: 128.0 }
    }
}

/// Table 6 row 1 — FULLATTN (FlashAttn / RingAttn / Ulysses share this).
pub fn fullattn_flops(m: &ModelProfile, n: f64) -> f64 {
    let (l, d, g, i) = (m.layers, m.d, m.g(), m.inter);
    l * (4.0 * n * d * d + 4.0 / g * n * d * d + 2.0 * n * n * d + 6.0 * n * d * i)
}

/// Table 6 row 2 — STARATTN (anchor = block = n/H).
pub fn starattn_flops(m: &ModelProfile, n: f64, hosts: f64) -> f64 {
    let (l, d, g, i) = (m.layers, m.d, m.g(), m.inter);
    let h = hosts;
    l / h
        * ((8.0 * h - 4.0) * n * d * d
            + (8.0 * h - 6.0) / g * n * d * d
            + (8.0 * h - 6.0) / h * n * n * d
            + (12.0 * h - 6.0) * n * d * i)
}

/// Table 6 row 3 — APB.
pub fn apb_flops(m: &ModelProfile, n: f64, hy: &Hyper) -> f64 {
    let (l, d, g, i) = (m.layers, m.d, m.g(), m.inter);
    let h = hy.hosts;
    let term1 = 4.0
        * (1.0 + 1.0 / g + 0.5 * n / (h * d) + 1.5 * i / d)
        * (n / h)
        * d
        * d;
    let blk = n / h + hy.l_a;
    let term2 = 4.0 * (h - 1.0) * (1.0 + 1.0 / g + 0.5 * blk / d + 1.5 * i / d) * blk * d * d;
    let term3 = hy.l_p * h * (h - 1.0) * blk * d;
    l * (term1 + term2 + term3)
}

/// MINFERENCE: the paper excludes it from Table 6 ("depends on the head
/// configuration search"). We model its attention term with an effective
/// visible-key budget per query (the union of A-shape / vertical-slash /
/// block-sparse patterns), keeping projections and FFN dense.
pub fn minference_flops(m: &ModelProfile, n: f64, effective_keys: f64) -> f64 {
    let (l, d, g, i) = (m.layers, m.d, m.g(), m.inter);
    let vis = effective_keys.min(n / 2.0); // causal average bound
    l * (4.0 * n * d * d + 4.0 / g * n * d * d + 2.0 * n * vis * d + 6.0 * n * d * i)
}

// ---------------------------------------------------------------------------
// Instrumented per-component counter: sums what each host actually computes,
// used (a) to cross-check the closed forms and (b) by the wall-time model.
// ---------------------------------------------------------------------------

/// Per-component FLOPs on ONE host's critical path for one forward.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ComponentFlops {
    pub qkv: f64,
    pub retaining: f64,
    pub attention: f64,
    pub o_proj: f64,
    pub ffn: f64,
}

impl ComponentFlops {
    pub fn total(&self) -> f64 {
        self.qkv + self.retaining + self.attention + self.o_proj + self.ffn
    }
}

/// FULLATTN on a single device: causal attention over n.
pub fn fullattn_components(m: &ModelProfile, n: f64) -> ComponentFlops {
    let (l, d, g, i) = (m.layers, m.d, m.g(), m.inter);
    ComponentFlops {
        qkv: l * (2.0 + 2.0 / g) * n * d * d,
        retaining: 0.0,
        // Causal: sum_i 2*i*d ~ n^2 d (QK^T + PV each n^2/2 * 2 flops).
        attention: l * 2.0 * 0.5 * n * n * d * 2.0 / 2.0 * 2.0 / 2.0 + l * n * n * d,
        o_proj: l * 2.0 * n * d * d,
        ffn: l * 6.0 * n * d * i,
    }
}

/// Sequence-parallel exact attention (Ring/Ulysses): per-host sequence is
/// n/H but attention work is the full causal set divided by H.
pub fn sp_exact_components(m: &ModelProfile, n: f64, hosts: f64) -> ComponentFlops {
    let full = fullattn_components(m, n);
    ComponentFlops {
        qkv: full.qkv / hosts,
        retaining: 0.0,
        attention: full.attention / hosts,
        o_proj: full.o_proj / hosts,
        ffn: full.ffn / hosts,
    }
}

/// StarAttn: each host processes [anchor | block] with block-local +
/// anchor attention, no communication. anchor = block = n/H.
pub fn starattn_components(m: &ModelProfile, n: f64, hosts: f64) -> ComponentFlops {
    let (l, d, g, i) = (m.layers, m.d, m.g(), m.inter);
    let l_b = n / hosts;
    let l_anchor = l_b; // StarAttn uses anchor size == block size
    let seq = l_b + l_anchor; // per-host processed length
    // Attention: anchor rows causal over anchor (~anchor^2/2), block rows
    // see anchor fully + causal local (~anchor*l_b + l_b^2/2); 2 matmuls
    // (QK^T, PV) at 2 flops each -> factor 4.
    let pairs = 0.5 * l_anchor * l_anchor + l_anchor * l_b + 0.5 * l_b * l_b;
    ComponentFlops {
        qkv: l * (2.0 + 2.0 / g) * seq * d * d,
        retaining: 0.0,
        attention: l * 4.0 * pairs * d,
        o_proj: l * 2.0 * seq * d * d,
        ffn: l * 6.0 * seq * d * i,
    }
}

/// APB per-host components for the LAST host (the critical path: largest
/// passing block). `retaining_hidden` sizes the compressor MLP.
pub fn apb_components(m: &ModelProfile, n: f64, hy: &Hyper,
                      retaining_hidden: f64) -> ComponentFlops {
    let (l, d, g, i) = (m.layers, m.d, m.g(), m.inter);
    let h = hy.hosts;
    let l_b = n / h;
    let l_aq = hy.l_a + hy.l_q;
    let seq = l_b + l_aq;
    let pass = (h - 1.0) * hy.l_p; // last host's passing block
    let pairs = 0.5 * l_aq * l_aq          // anchor causal
        + l_b * (l_aq + pass)               // local rows -> anchor+passing
        + 0.5 * l_b * l_b;                  // local causal
    let hd = m.head_dim();
    let rh = l * l_b * m.kv_heads * (2.0 * 3.0 * hd * retaining_hidden
        + 2.0 * retaining_hidden);
    ComponentFlops {
        qkv: l * (2.0 + 2.0 / g) * seq * d * d,
        retaining: rh,
        attention: l * 4.0 * pairs * d,
        o_proj: l * 2.0 * seq * d * d,
        ffn: l * 6.0 * seq * d * i,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attnsim::profiles::LLAMA31_8B;

    const N128K: f64 = 131072.0;

    #[test]
    fn apb_below_star_below_full_at_paper_settings() {
        // DESIGN.md invariant 7 (refined): APB < Star for all n >= 32K.
        // Star < Full only once the quadratic attention term dominates the
        // anchor-doubled linear terms (the Figure 4(c) crossover) — at the
        // paper's settings that is n >= 128K.
        for exp in 15..=19 {
            let n = (1u64 << exp) as f64; // 32K..512K
            let hy = Hyper::paper_schedule(n, 8.0);
            let full = fullattn_flops(&LLAMA31_8B, n);
            let star = starattn_flops(&LLAMA31_8B, n, 8.0);
            let apb = apb_flops(&LLAMA31_8B, n, &hy);
            assert!(apb < star, "n={n}: apb {apb} !< star {star}");
            assert!(apb < full, "n={n}: apb {apb} !< full {full}");
            if n >= 131072.0 {
                assert!(star < full, "n={n}: star {star} !< full {full}");
            }
        }
        // And the short-length regime indeed inverts (Star pays for its
        // full-size anchors — the overhead §C calls out).
        let n = 32768.0;
        assert!(starattn_flops(&LLAMA31_8B, n, 8.0) > fullattn_flops(&LLAMA31_8B, n));
    }

    #[test]
    fn apb_compute_reduction_grows_with_length() {
        let r = |n: f64| {
            apb_flops(&LLAMA31_8B, n, &Hyper::paper_schedule(n, 8.0))
                / fullattn_flops(&LLAMA31_8B, n)
        };
        assert!(r(524288.0) < r(131072.0));
        assert!(r(131072.0) < r(32768.0));
        assert!(r(524288.0) < 0.5, "at 512K APB should be <50% of full");
    }

    #[test]
    fn closed_forms_match_instrumented_within_tolerance() {
        // FULLATTN closed form vs component sum: identical terms.
        let n = N128K;
        let cf = fullattn_flops(&LLAMA31_8B, n);
        let comp = fullattn_components(&LLAMA31_8B, n).total();
        let rel = (cf - comp).abs() / cf;
        assert!(rel < 0.02, "fullattn closed {cf} vs components {comp}");
    }

    #[test]
    fn star_components_track_closed_form_shape() {
        // The paper's Star closed form aggregates all hosts; per-host * H
        // should land within ~15% (their formula folds minor terms).
        let n = N128K;
        let h = 8.0;
        let per_host = starattn_components(&LLAMA31_8B, n, h).total();
        let agg = starattn_flops(&LLAMA31_8B, n, h);
        let rel = (per_host * h - agg).abs() / agg;
        assert!(rel < 0.15, "star rel diff {rel}");
    }

    #[test]
    fn minference_between_full_and_linear() {
        let n = N128K;
        let dense = fullattn_flops(&LLAMA31_8B, n);
        let sparse = minference_flops(&LLAMA31_8B, n, 8192.0);
        assert!(sparse < dense);
        // Still strictly more than a zero-attention lower bound.
        let zero = minference_flops(&LLAMA31_8B, n, 0.0);
        assert!(sparse > zero);
    }

    #[test]
    fn paper_schedule_matches_table5() {
        // Table 5: n=128K -> l_b=16K, l_a=4K, l_p=2K (H=8).
        let hy = Hyper::paper_schedule(131072.0, 8.0);
        assert_eq!(hy.l_a, 4096.0);
        assert_eq!(hy.l_p, 2048.0);
        // n=512K -> l_a=8K cap, l_p=8K cap.
        let hy = Hyper::paper_schedule(524288.0, 8.0);
        assert_eq!(hy.l_a, 8192.0);
        assert_eq!(hy.l_p, 8192.0);
    }
}
