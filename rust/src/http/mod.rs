//! Pure-Rust, std-only HTTP/1.1 front door for `apb serve --http`.
//!
//! No crates.io dependencies and no vendored HTTP stack: [`parser`]
//! reads and validates requests byte-at-a-time against hard limits,
//! [`response`] writes fixed-length and chunked responses (and decodes
//! chunked bodies), [`router`] maps `(method, path)` to endpoints, and
//! [`server`] runs the accept loop + engine thread that bridges
//! connections into the existing [`crate::coordinator`] scheduler and
//! cluster. [`client`] is the matching loopback client used by the
//! workload generator's HTTP mode, the CI smoke gate, and the tier-1
//! conformance suite.
//!
//! Design record: `docs/ADR-008-http-front-door.md`.

pub mod client;
pub mod parser;
pub mod response;
pub mod router;
pub mod server;

pub use client::{HttpClient, HttpResponse};
pub use parser::{HttpRequest, Limits, ParseError};
pub use response::{ChunkedReader, ChunkedWriter};
pub use router::Route;
pub use server::{HttpOptions, Server};
