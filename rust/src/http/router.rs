//! Route table for the `/v1` API — pure function of (method, path), so
//! dispatch is unit-testable without sockets or a cluster.
//!
//! | Method | Path                | Route                      |
//! |--------|---------------------|----------------------------|
//! | POST   | `/v1/generate`      | [`Route::Generate`]        |
//! | GET    | `/v1/metrics`       | [`Route::Metrics`]         |
//! | GET    | `/v1/healthz`       | [`Route::Health`]          |
//! | DELETE | `/v1/session/<id>`  | [`Route::ClearSession`]    |
//!
//! A known path with the wrong method is 405 (with the allowed method in
//! the error detail); an unknown path is 404.

/// One dispatched endpoint.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Route {
    Generate,
    Metrics,
    Health,
    /// Clear one persistent (kept) session by id, releasing its KV slot.
    ClearSession(u64),
}

/// Resolve `(method, path)` to a route, or `(status, detail)` — 404 for
/// unknown paths, 405 for a known path with the wrong method.
pub fn route(method: &str, path: &str) -> Result<Route, (u16, String)> {
    let allow = |m: &str, r: Route| {
        if method == m {
            Ok(r)
        } else {
            Err((405, format!("{path} allows {m} only")))
        }
    };
    match path {
        "/v1/generate" => allow("POST", Route::Generate),
        "/v1/metrics" => allow("GET", Route::Metrics),
        "/v1/healthz" => allow("GET", Route::Health),
        _ => {
            if let Some(id) = path.strip_prefix("/v1/session/") {
                if !id.is_empty() && id.bytes().all(|b| b.is_ascii_digit()) {
                    let id: u64 = id
                        .parse()
                        .map_err(|_| (404, format!("session id '{id}' out of range")))?;
                    return allow("DELETE", Route::ClearSession(id));
                }
            }
            Err((404, format!("no route for {path}")))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routes_resolve() {
        assert_eq!(route("POST", "/v1/generate"), Ok(Route::Generate));
        assert_eq!(route("GET", "/v1/metrics"), Ok(Route::Metrics));
        assert_eq!(route("GET", "/v1/healthz"), Ok(Route::Health));
        assert_eq!(route("DELETE", "/v1/session/42"), Ok(Route::ClearSession(42)));
    }

    #[test]
    fn wrong_method_is_405_unknown_path_404() {
        assert_eq!(route("GET", "/v1/generate").unwrap_err().0, 405);
        assert_eq!(route("POST", "/v1/metrics").unwrap_err().0, 405);
        assert_eq!(route("GET", "/v1/session/42").unwrap_err().0, 405);
        assert_eq!(route("GET", "/nope").unwrap_err().0, 404);
        assert_eq!(route("DELETE", "/v1/session/").unwrap_err().0, 404);
        assert_eq!(route("DELETE", "/v1/session/abc").unwrap_err().0, 404);
        // Out-of-range u64.
        assert_eq!(route("DELETE", "/v1/session/99999999999999999999").unwrap_err().0, 404);
    }
}
