//! Minimal std-only HTTP/1.1 client for loopback use: the workload
//! generator's closed-loop HTTP driver, the `--http --smoke` CI gate, and
//! `rust/tests/http_serving.rs` all speak through this. Keep-alive by
//! default (one connection, many requests), with chunk-boundary-preserving
//! streaming reads so tests can assert a response actually streamed.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use super::response::ChunkedReader;

/// One decoded response. `chunks` preserves the sender's chunk boundaries
/// for chunked responses (fixed-length bodies decode as a single chunk).
#[derive(Debug, Clone)]
pub struct HttpResponse {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub chunks: Vec<Vec<u8>>,
}

impl HttpResponse {
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().find(|(k, _)| *k == name).map(|(_, v)| v.as_str())
    }

    /// Body with chunk boundaries flattened away.
    pub fn body(&self) -> Vec<u8> {
        self.chunks.concat()
    }

    pub fn body_str(&self) -> String {
        String::from_utf8_lossy(&self.body()).into_owned()
    }
}

/// A keep-alive connection to the front door.
pub struct HttpClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl HttpClient {
    pub fn connect(addr: &str) -> Result<HttpClient> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        stream.set_nodelay(true).ok();
        // A stuck server must surface as an error, not a hung test/CI job.
        stream.set_read_timeout(Some(Duration::from_secs(120))).ok();
        let writer = stream.try_clone().context("clone stream")?;
        Ok(HttpClient { reader: BufReader::new(stream), writer })
    }

    /// Issue one request and read the complete response (chunk boundaries
    /// preserved). `body = Some(json)` sends `Content-Length` framing.
    pub fn request(&mut self, method: &str, path: &str, body: Option<&str>) -> Result<HttpResponse> {
        write!(self.writer, "{method} {path} HTTP/1.1\r\nHost: apb\r\n")?;
        match body {
            Some(b) => {
                write!(
                    self.writer,
                    "Content-Type: application/json\r\nContent-Length: {}\r\n\r\n",
                    b.len()
                )?;
                self.writer.write_all(b.as_bytes())?;
            }
            None => write!(self.writer, "\r\n")?,
        }
        self.writer.flush()?;
        self.read_response()
    }

    fn read_response(&mut self) -> Result<HttpResponse> {
        let status_line = self.read_line()?;
        let mut parts = status_line.splitn(3, ' ');
        let (Some(version), Some(code)) = (parts.next(), parts.next()) else {
            bail!("malformed status line '{status_line}'");
        };
        if !version.starts_with("HTTP/1.") {
            bail!("unexpected version in '{status_line}'");
        }
        let status: u16 = code.parse().with_context(|| format!("status in '{status_line}'"))?;
        let mut headers = Vec::new();
        loop {
            let line = self.read_line()?;
            if line.is_empty() {
                break;
            }
            let (k, v) = line.split_once(':').context("header line missing ':'")?;
            headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
        }
        let header = |name: &str| {
            headers
                .iter()
                .find(|(k, _)| k == name)
                .map(|(_, v)| v.clone())
        };
        let chunks = if header("transfer-encoding").map(|v| v.eq_ignore_ascii_case("chunked"))
            == Some(true)
        {
            let mut reader = ChunkedReader::new(64 * 1024 * 1024);
            let mut chunks = Vec::new();
            while let Some(c) =
                reader.next_chunk(&mut self.reader).map_err(|e| anyhow::anyhow!("{e}"))?
            {
                chunks.push(c);
            }
            chunks
        } else {
            let n: usize = header("content-length")
                .context("response without Content-Length or chunked framing")?
                .parse()
                .context("bad Content-Length")?;
            let mut body = vec![0u8; n];
            std::io::Read::read_exact(&mut self.reader, &mut body)?;
            vec![body]
        };
        Ok(HttpResponse { status, headers, chunks })
    }

    fn read_line(&mut self) -> Result<String> {
        let mut line = String::new();
        self.reader.read_line(&mut line).context("read line")?;
        while line.ends_with('\n') || line.ends_with('\r') {
            line.pop();
        }
        Ok(line)
    }
}
