//! HTTP/1.1 request parsing — hand-rolled, std-only, defensive.
//!
//! The front door faces arbitrary bytes, so the parser's contract is
//! stricter than "parse valid HTTP": every malformed input must map to a
//! definite 4xx status (never a panic, never an unbounded read, never a
//! read past the declared body), and every limit is explicit in
//! [`Limits`]. The robustness proptests at the bottom of this file feed
//! hundreds of seeded malformed inputs (truncated request lines,
//! oversized/duplicate/folded headers, bad Content-Length, pipelined
//! garbage) through [`read_request`] and assert the 400/413/431 mapping.
//!
//! Status mapping (`docs/ADR-008-http-front-door.md`):
//!   400 — syntactically malformed (bad request line, bad header, bad or
//!         conflicting Content-Length, truncated head/body, obs-fold)
//!   413 — declared body larger than [`Limits::max_body_bytes`]
//!   431 — header section larger than [`Limits::max_head_bytes`] or more
//!         than [`Limits::max_headers`] header fields
//!   408 — socket read timeout on an idle keep-alive connection before any
//!         byte arrived (the handler closes without writing a response)

use std::io::{BufRead, Read};

/// Parser resource bounds. Defaults are generous for the JSON bodies the
/// `/v1` API carries (a sim-tiny generate body is well under 4 KiB) while
/// keeping a hostile peer from ballooning a handler thread.
#[derive(Debug, Clone)]
pub struct Limits {
    /// Cap on the request line + header section, in bytes (431 beyond).
    pub max_head_bytes: usize,
    /// Cap on the number of header fields (431 beyond).
    pub max_headers: usize,
    /// Cap on the declared/decoded body size, in bytes (413 beyond).
    pub max_body_bytes: usize,
}

impl Default for Limits {
    fn default() -> Limits {
        Limits { max_head_bytes: 16 * 1024, max_headers: 64, max_body_bytes: 4 * 1024 * 1024 }
    }
}

/// A definite client-facing parse failure: HTTP status + reason detail.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    pub status: u16,
    pub msg: String,
}

impl ParseError {
    fn new(status: u16, msg: impl Into<String>) -> ParseError {
        ParseError { status, msg: msg.into() }
    }
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} {}", self.status, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// One parsed request. Header names are lowercased at parse time; values
/// keep their bytes (trimmed of optional whitespace) as UTF-8.
#[derive(Debug, Clone)]
pub struct HttpRequest {
    pub method: String,
    /// Request target as sent (path + optional `?query`).
    pub target: String,
    pub version: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpRequest {
    /// First header value for `name` (ASCII case-insensitive lookup; names
    /// are stored lowercased).
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().find(|(k, _)| *k == name).map(|(_, v)| v.as_str())
    }

    /// Target path with any `?query` suffix stripped.
    pub fn path(&self) -> &str {
        match self.target.find('?') {
            Some(i) => &self.target[..i],
            None => &self.target,
        }
    }

    /// Whether the client asked to close the connection after this
    /// exchange (HTTP/1.1 defaults to keep-alive; HTTP/1.0 to close).
    pub fn wants_close(&self) -> bool {
        match self.header("connection") {
            Some(v) => v.eq_ignore_ascii_case("close"),
            None => self.version == "HTTP/1.0",
        }
    }
}

fn is_token_char(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b"!#$%&'*+-.^_`|~".contains(&b)
}

/// Read the head (request line + headers) up to and including the blank
/// line. Returns `None` on clean EOF before any byte (peer closed an idle
/// keep-alive connection). Truncation mid-head is a 400; exceeding
/// `max_head_bytes` is a 431.
fn read_head<R: BufRead>(r: &mut R, limits: &Limits) -> Result<Option<Vec<u8>>, ParseError> {
    let mut head: Vec<u8> = Vec::with_capacity(256);
    let mut byte = [0u8; 1];
    loop {
        match r.read(&mut byte) {
            Ok(0) => {
                if head.is_empty() {
                    return Ok(None);
                }
                return Err(ParseError::new(400, "truncated request head"));
            }
            Ok(_) => head.push(byte[0]),
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            // Socket read timeout: an idle keep-alive connection that never
            // sent a byte closes quietly (408 is the handler's "no response
            // needed" signal); stalling mid-request is a plain 400.
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) && head.is_empty() =>
            {
                return Err(ParseError::new(408, "idle connection timed out"))
            }
            Err(e) => return Err(ParseError::new(400, format!("read error: {e}"))),
        }
        if head.len() > limits.max_head_bytes {
            return Err(ParseError::new(431, "request head too large"));
        }
        if head.ends_with(b"\r\n\r\n") || head.ends_with(b"\n\n") {
            return Ok(Some(head));
        }
    }
}

/// Split the head into lines, tolerating bare-LF line endings (the spec
/// requires CRLF; lenient reading here never loosens the token checks).
fn head_lines(head: &[u8]) -> Result<Vec<String>, ParseError> {
    let text = std::str::from_utf8(head)
        .map_err(|_| ParseError::new(400, "request head is not valid UTF-8"))?;
    Ok(text
        .split('\n')
        .map(|l| l.strip_suffix('\r').unwrap_or(l).to_string())
        .collect())
}

/// Parse the request line `METHOD SP TARGET SP VERSION`.
fn parse_request_line(line: &str) -> Result<(String, String, String), ParseError> {
    let mut parts = line.split(' ');
    let (method, target, version) = match (parts.next(), parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(t), Some(v), None) if !m.is_empty() && !t.is_empty() => {
            (m.to_string(), t.to_string(), v.to_string())
        }
        _ => return Err(ParseError::new(400, "malformed request line")),
    };
    if !method.bytes().all(is_token_char) {
        return Err(ParseError::new(400, "method is not a token"));
    }
    if version != "HTTP/1.1" && version != "HTTP/1.0" {
        return Err(ParseError::new(400, "unsupported HTTP version"));
    }
    if !target.starts_with('/') {
        return Err(ParseError::new(400, "request target must be origin-form"));
    }
    Ok((method, target, version))
}

/// Parse one `Name: value` header line. Rejects obs-fold continuations
/// (leading whitespace), empty names, and non-token name characters.
fn parse_header_line(line: &str) -> Result<(String, String), ParseError> {
    if line.starts_with(' ') || line.starts_with('\t') {
        return Err(ParseError::new(400, "obsolete header line folding"));
    }
    let (name, value) =
        line.split_once(':').ok_or_else(|| ParseError::new(400, "header line missing ':'"))?;
    if name.is_empty() || !name.bytes().all(is_token_char) {
        return Err(ParseError::new(400, "header name is not a token"));
    }
    Ok((name.to_ascii_lowercase(), value.trim().to_string()))
}

/// Resolve the body framing from the parsed headers. Exactly one of
/// Content-Length / `Transfer-Encoding: chunked` may govern; conflicting
/// or repeated declarations are request smuggling vectors and map to 400.
enum Framing {
    None,
    Length(usize),
    Chunked,
}

fn framing(headers: &[(String, String)], limits: &Limits) -> Result<Framing, ParseError> {
    let lengths: Vec<&str> =
        headers.iter().filter(|(k, _)| k == "content-length").map(|(_, v)| v.as_str()).collect();
    let encodings: Vec<&str> =
        headers.iter().filter(|(k, _)| k == "transfer-encoding").map(|(_, v)| v.as_str()).collect();
    if !encodings.is_empty() {
        if !lengths.is_empty() {
            return Err(ParseError::new(400, "both Content-Length and Transfer-Encoding"));
        }
        if encodings.len() > 1 || !encodings[0].eq_ignore_ascii_case("chunked") {
            return Err(ParseError::new(400, "unsupported Transfer-Encoding"));
        }
        return Ok(Framing::Chunked);
    }
    match lengths.as_slice() {
        [] => Ok(Framing::None),
        [one] => {
            if one.is_empty() || !one.bytes().all(|b| b.is_ascii_digit()) {
                return Err(ParseError::new(400, "Content-Length is not a number"));
            }
            let n: usize =
                one.parse().map_err(|_| ParseError::new(400, "Content-Length overflows"))?;
            if n > limits.max_body_bytes {
                return Err(ParseError::new(413, "declared body too large"));
            }
            Ok(Framing::Length(n))
        }
        _ => Err(ParseError::new(400, "duplicate Content-Length")),
    }
}

/// Read one full request from `r`. Returns `Ok(None)` on clean EOF before
/// any byte (idle keep-alive close). Reads EXACTLY the head plus the
/// declared body — never beyond it — so pipelined bytes stay buffered for
/// the next call (and pipelined garbage surfaces as that call's 400).
pub fn read_request<R: BufRead>(
    r: &mut R,
    limits: &Limits,
) -> Result<Option<HttpRequest>, ParseError> {
    let Some(head) = read_head(r, limits)? else {
        return Ok(None);
    };
    let lines = head_lines(&head)?;
    // `head` ends with a blank-line terminator, so `lines` ends with >= 2
    // empty strings ("…\r\n\r\n" splits into [..., "", ""]).
    let (method, target, version) =
        parse_request_line(lines.first().ok_or_else(|| ParseError::new(400, "empty head"))?)?;
    let mut headers = Vec::new();
    for line in &lines[1..] {
        if line.is_empty() {
            break;
        }
        headers.push(parse_header_line(line)?);
        if headers.len() > limits.max_headers {
            return Err(ParseError::new(431, "too many header fields"));
        }
    }
    let body = match framing(&headers, limits)? {
        Framing::None => Vec::new(),
        Framing::Length(n) => {
            let mut body = vec![0u8; n];
            r.read_exact(&mut body)
                .map_err(|_| ParseError::new(400, "body shorter than Content-Length"))?;
            body
        }
        Framing::Chunked => super::response::read_chunked(r, limits.max_body_bytes)?,
    };
    Ok(Some(HttpRequest { method, target, version, headers, body }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::io::Cursor;

    fn parse(input: &[u8]) -> Result<Option<HttpRequest>, ParseError> {
        read_request(&mut Cursor::new(input.to_vec()), &Limits::default())
    }

    /// Parse and also report how many input bytes were consumed — the
    /// "never read past the declared body" observable.
    fn parse_consumed(input: &[u8]) -> (Result<Option<HttpRequest>, ParseError>, usize) {
        let mut cur = Cursor::new(input.to_vec());
        let res = read_request(&mut cur, &Limits::default());
        (res, cur.position() as usize)
    }

    const VALID: &str = "POST /v1/generate HTTP/1.1\r\nHost: x\r\nContent-Length: 4\r\n\r\nabcd";

    #[test]
    fn parses_a_valid_post() {
        let req = parse(VALID.as_bytes()).unwrap().unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path(), "/v1/generate");
        assert_eq!(req.header("host"), Some("x"));
        assert_eq!(req.body, b"abcd");
        assert!(!req.wants_close());
        println!("APB-RUN http_parser_valid backend=none");
    }

    #[test]
    fn never_reads_past_the_declared_body() {
        // Pipelined trailing bytes must stay unconsumed for the next read.
        let mut input = VALID.as_bytes().to_vec();
        input.extend_from_slice(b"GARBAGE THAT IS NOT HTTP\r\n");
        let (res, consumed) = parse_consumed(&input);
        assert!(res.unwrap().is_some());
        assert_eq!(consumed, VALID.len(), "parser read past the declared body");
        // The pipelined garbage surfaces as the NEXT request's 400.
        let mut cur = Cursor::new(input[consumed..].to_vec());
        let next = read_request(&mut cur, &Limits::default());
        assert_eq!(next.unwrap_err().status, 400);
    }

    #[test]
    fn clean_eof_is_none_not_an_error() {
        assert!(parse(b"").unwrap().is_none());
    }

    #[test]
    fn query_strings_are_split_from_the_path() {
        let req = parse(b"GET /v1/metrics?pretty=1 HTTP/1.1\r\n\r\n").unwrap().unwrap();
        assert_eq!(req.path(), "/v1/metrics");
        assert_eq!(req.target, "/v1/metrics?pretty=1");
    }

    #[test]
    fn http10_defaults_to_close() {
        let req = parse(b"GET / HTTP/1.0\r\n\r\n").unwrap().unwrap();
        assert!(req.wants_close());
    }

    /// Build one seeded malformed input. Each category is deliberately
    /// *shaped like* real-world malformation rather than pure noise, so
    /// the proptest exercises every rejection path (the `which` fan-out
    /// below) across many seeds.
    fn malformed_input(rng: &mut Rng, which: u64) -> (Vec<u8>, &'static str) {
        match which {
            // Truncated request line / head: cut a valid request at a
            // random byte strictly inside the head.
            0 => {
                let cut = 1 + (rng.below(VALID.len() as u64 - 5) as usize);
                (VALID.as_bytes()[..cut].to_vec(), "truncated head")
            }
            // Oversized header section (431).
            1 => {
                let mut v = b"GET / HTTP/1.1\r\n".to_vec();
                let n = 17_000 + rng.below(4096) as usize;
                v.resize(v.len() + n, b'a');
                (v, "oversized head")
            }
            // Too many header fields (431).
            2 => {
                let mut v = b"GET / HTTP/1.1\r\n".to_vec();
                for i in 0..(65 + rng.below(64)) {
                    v.extend_from_slice(format!("H{i}: x\r\n").as_bytes());
                }
                v.extend_from_slice(b"\r\n");
                (v, "too many headers")
            }
            // Duplicate Content-Length (400).
            3 => {
                let (a, b) = (rng.below(64), rng.below(64));
                let s = format!(
                    "POST / HTTP/1.1\r\nContent-Length: {a}\r\nContent-Length: {b}\r\n\r\n"
                );
                (s.into_bytes(), "duplicate content-length")
            }
            // Obs-fold continuation header (400).
            4 => {
                (b"GET / HTTP/1.1\r\nA: b\r\n  folded\r\n\r\n".to_vec(), "obs-fold header")
            }
            // Bad Content-Length value (400).
            5 => {
                let junk = ["abc", "-1", "1e3", "0x10", "", "999999999999999999999999"]
                    [rng.below(6) as usize];
                let s = format!("POST / HTTP/1.1\r\nContent-Length: {junk}\r\n\r\n");
                (s.into_bytes(), "bad content-length")
            }
            // Declared body beyond the cap (413).
            6 => {
                let n = 4 * 1024 * 1024 + 1 + rng.below(1 << 20);
                let s = format!("POST / HTTP/1.1\r\nContent-Length: {n}\r\n\r\n");
                (s.into_bytes(), "oversized body")
            }
            // Body shorter than Content-Length (400).
            7 => {
                let n = 10 + rng.below(100);
                let s = format!("POST / HTTP/1.1\r\nContent-Length: {n}\r\n\r\nshort");
                (s.into_bytes(), "truncated body")
            }
            // Garbage request line (pipelined-noise shape): random bytes,
            // newline-terminated head.
            8 => {
                let mut v: Vec<u8> =
                    (0..(8 + rng.below(48))).map(|_| 33 + (rng.below(94) as u8)).collect();
                // Strip token chars being the WHOLE line accidentally
                // forming `M T V`: random printable junk essentially never
                // parses, but force a guaranteed violation: no spaces.
                v.retain(|b| *b != b' ');
                v.extend_from_slice(b"\r\n\r\n");
                (v, "garbage request line")
            }
            // Conflicting framing: CL + TE (400).
            9 => (
                b"POST / HTTP/1.1\r\nContent-Length: 4\r\nTransfer-Encoding: chunked\r\n\r\nabcd"
                    .to_vec(),
                "conflicting framing",
            ),
            // Bad version (400).
            10 => {
                let vsn = ["HTTP/2.0", "HTTP/1.2", "ICY", "http/1.1 extra"][rng.below(4) as usize];
                (format!("GET / {vsn}\r\n\r\n").into_bytes(), "bad version")
            }
            // Malformed chunked body: bogus chunk-size line (400).
            _ => (
                b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\nzz\r\nabcd\r\n0\r\n\r\n"
                    .to_vec(),
                "bad chunk size",
            ),
        }
    }

    /// The satellite gate: >= 256 seeded malformed inputs, every one maps
    /// to 400/413/431 — never a panic (a panic fails the test run), never
    /// an accepted parse, and never a read past the input.
    #[test]
    fn proptest_malformed_inputs_map_to_4xx() {
        let mut n_cases = 0;
        for seed in 0..32u64 {
            let mut rng = Rng::new(seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(7));
            for which in 0..12u64 {
                let (input, label) = malformed_input(&mut rng, which);
                let (res, consumed) = parse_consumed(&input);
                let err = match res {
                    Err(e) => e,
                    Ok(r) => panic!(
                        "seed {seed} case '{label}' parsed as {:?} instead of erroring",
                        r.map(|q| (q.method, q.target))
                    ),
                };
                assert!(
                    matches!(err.status, 400 | 413 | 431),
                    "seed {seed} case '{label}': status {} not in 400/413/431",
                    err.status
                );
                assert!(consumed <= input.len());
                n_cases += 1;
            }
        }
        assert!(n_cases >= 256, "only {n_cases} malformed cases exercised");
        println!("APB-RUN http_parser_proptest backend=none cases={n_cases}");
    }

    /// Random truncation points of a larger valid request: every prefix is
    /// either the full parse or a definite 400/413/431 — no other outcome.
    #[test]
    fn proptest_every_truncation_is_definite() {
        let full = "POST /v1/generate HTTP/1.1\r\nHost: h\r\nAccept: */*\r\n\
                    Content-Length: 11\r\n\r\nhello world";
        let bytes = full.as_bytes();
        for cut in 1..bytes.len() {
            match parse(&bytes[..cut]) {
                Ok(r) => panic!("truncation at {cut} parsed as {:?}", r.map(|q| q.target)),
                Err(e) => assert!(
                    matches!(e.status, 400 | 413 | 431),
                    "truncation at {cut}: status {}",
                    e.status
                ),
            }
        }
        // And the untruncated request parses.
        assert_eq!(parse(bytes).unwrap().unwrap().body, b"hello world");
    }
}
