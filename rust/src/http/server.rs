//! The HTTP front door: accept loop, per-connection handler threads, and
//! the single **engine thread** that owns the `Cluster` + `Scheduler`.
//!
//! Threading model (`docs/ADR-008-http-front-door.md`): the cluster
//! leader API is deliberately single-threaded (`RefCell` bookkeeping,
//! one command round in flight), so handler threads never touch it.
//! Instead each connection parses requests and sends [`EngineCmd`]s over
//! an mpsc channel; the engine loop interleaves four duties per
//! iteration, exactly like the scheduler's own tick discipline:
//!
//!   1. drain commands (submit scheduler requests, answer metrics /
//!      clear-session, start draining on shutdown);
//!   2. run at most one *persistent* ("keep": true) prefill inline when
//!      the one-prefill-at-a-time permit is free;
//!   3. one `Scheduler::step` (admission chunk + batched decode tick);
//!   4. one batched decode step across live multi-turn streams, then
//!      flush newly emitted tokens to every stream as chunked events.
//!
//! Backpressure maps to `429 Too Many Requests` + `Retry-After`
//! (admission queue full, KV pool exhausted — including "every slot held
//! by persistent sessions"), never to an internal error. Graceful
//! shutdown stops the accept loop, rejects new generates with 503, and
//! drains every in-flight stream to completion at quiescent boundaries
//! before the cluster is dropped.

use std::collections::{HashMap, HashSet, VecDeque};
use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::config::{ApbOptions, AttnMethod, Config, PassStrategy};
use crate::coordinator::scheduler::{is_backpressure, Class, Request, Scheduler};
use crate::coordinator::{Cluster, Driver, SessionId};
use crate::util::json::{self, Json, JsonWriter};
use crate::util::stats::Summary;
use crate::util::tensor::Tensor;

use super::parser::{read_request, HttpRequest, Limits};
use super::response::{write_error, write_simple, ChunkedWriter};
use super::router::{route, Route};

/// Front-door knobs (`apb serve --http <addr> [--http-conns N]`).
#[derive(Debug, Clone)]
pub struct HttpOptions {
    /// Bind address (`127.0.0.1:0` picks an ephemeral port).
    pub addr: String,
    /// Connection cap: accepts beyond this are answered 503 and closed
    /// immediately (one handler thread per live connection).
    pub max_conns: usize,
    /// Admission-queue bound handed to the scheduler (submits beyond it
    /// are 429s).
    pub max_queue: usize,
    /// Idle keep-alive read timeout per connection, seconds.
    pub read_timeout_s: u64,
    pub limits: Limits,
}

impl Default for HttpOptions {
    fn default() -> HttpOptions {
        HttpOptions {
            addr: "127.0.0.1:0".into(),
            max_conns: 64,
            max_queue: 64,
            read_timeout_s: 30,
            limits: Limits::default(),
        }
    }
}

/// Shared accept-side counters, folded into `GET /v1/metrics`.
#[derive(Default)]
struct Counters {
    open_conns: AtomicUsize,
    total_conns: AtomicU64,
    conn_rejected_503: AtomicU64,
}

/// One parsed `/v1/generate` body.
struct GenerateSpec {
    doc: Vec<i32>,
    query: Vec<i32>,
    max_new: usize,
    opts: ApbOptions,
    class: Class,
    /// Keep the session resident after the stream completes (returns a
    /// `session` id usable for follow-up turns).
    keep: bool,
    /// Follow-up turn against a kept session.
    session: Option<SessionId>,
    turn: Vec<i32>,
}

/// Engine → handler stream events. The engine pre-serializes every body
/// so handler threads only frame bytes.
enum Event {
    /// Terminal pre-stream rejection (4xx/5xx before any token).
    Reject { status: u16, detail: String, retry_after: bool },
    /// One NDJSON token-event line (sent as its own HTTP chunk).
    Chunk(String),
    /// Final NDJSON line; the stream ends after it.
    Done(String),
}

enum ClearOutcome {
    Cleared,
    NotFound,
    Busy,
}

enum EngineCmd {
    Generate(Box<GenerateSpec>, Sender<Event>),
    Metrics(Sender<String>),
    ClearSession(SessionId, Sender<ClearOutcome>),
    Shutdown(Sender<()>),
}

/// A live multi-turn decode stream (persistent-session generate or
/// follow-up turn), advanced one *batched* decode step per engine
/// iteration — multiple turn streams share one stacked pass, exactly like
/// the scheduler's decode tick.
struct TurnStream {
    sid: SessionId,
    tx: Sender<Event>,
    produced: Vec<i32>,
    max_new: usize,
    prev: i32,
}

/// Scheduler-request stream state: outbound channel + tokens already
/// flushed.
struct SchedStream {
    tx: Sender<Event>,
    sent: usize,
}

/// The running front door. Owns the engine + accept threads; dropping it
/// performs a best-effort graceful shutdown.
pub struct Server {
    local_addr: SocketAddr,
    engine_tx: Sender<EngineCmd>,
    engine_join: Option<thread::JoinHandle<()>>,
    accept_join: Option<thread::JoinHandle<()>>,
    stop: Arc<AtomicBool>,
    counters: Arc<Counters>,
    shut: bool,
}

impl Server {
    /// Bind `opts.addr`, start the engine (which builds the cluster under
    /// `driver`) and the accept loop. Fails fast if the bind or the
    /// cluster start fails.
    pub fn start(cfg: Config, driver: Driver, opts: HttpOptions) -> Result<Server> {
        let listener =
            TcpListener::bind(&opts.addr).with_context(|| format!("bind {}", opts.addr))?;
        let local_addr = listener.local_addr().context("local_addr")?;
        let (engine_tx, engine_rx) = mpsc::channel::<EngineCmd>();
        let (ready_tx, ready_rx) = mpsc::channel::<std::result::Result<(), String>>();
        let counters = Arc::new(Counters::default());
        let stop = Arc::new(AtomicBool::new(false));

        let engine_cfg = cfg;
        let engine_counters = Arc::clone(&counters);
        let engine_opts = opts.clone();
        let engine_join = thread::Builder::new()
            .name("apb-http-engine".into())
            .spawn(move || {
                engine_main(engine_cfg, driver, engine_opts, engine_rx, ready_tx, engine_counters)
            })
            .context("spawn engine thread")?;
        match ready_rx.recv() {
            Ok(Ok(())) => {}
            Ok(Err(msg)) => {
                let _ = engine_join.join();
                anyhow::bail!("cluster start failed: {msg}");
            }
            Err(_) => {
                let _ = engine_join.join();
                anyhow::bail!("engine thread died during startup");
            }
        }

        let accept_tx = engine_tx.clone();
        let accept_counters = Arc::clone(&counters);
        let accept_stop = Arc::clone(&stop);
        let accept_opts = opts;
        let accept_join = thread::Builder::new()
            .name("apb-http-accept".into())
            .spawn(move || accept_main(listener, accept_opts, accept_tx, accept_stop, accept_counters))
            .context("spawn accept thread")?;

        Ok(Server {
            local_addr,
            engine_tx,
            engine_join: Some(engine_join),
            accept_join: Some(accept_join),
            stop,
            counters,
            shut: false,
        })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Graceful shutdown: stop accepting, reject new generates with 503,
    /// drain every in-flight stream to completion, drop the cluster.
    pub fn shutdown(&mut self) -> Result<()> {
        if self.shut {
            return Ok(());
        }
        self.shut = true;
        self.stop.store(true, Ordering::SeqCst);
        // Unblock the accept loop with a throwaway connection.
        let _ = TcpStream::connect(self.local_addr);
        if let Some(j) = self.accept_join.take() {
            let _ = j.join();
        }
        let (ack_tx, ack_rx) = mpsc::channel();
        if self.engine_tx.send(EngineCmd::Shutdown(ack_tx)).is_ok() {
            let _ = ack_rx.recv_timeout(Duration::from_secs(120));
        }
        if let Some(j) = self.engine_join.take() {
            let _ = j.join();
        }
        // Give straggling handler threads (clients that haven't closed) a
        // moment to notice; they hold no cluster state either way.
        for _ in 0..200 {
            if self.counters.open_conns.load(Ordering::SeqCst) == 0 {
                break;
            }
            thread::sleep(Duration::from_millis(5));
        }
        Ok(())
    }

    /// Block until the accept loop exits (serve-forever mode; ^C kills
    /// the process, `shutdown` from another thread ends it gracefully).
    pub fn join(mut self) -> Result<()> {
        if let Some(j) = self.accept_join.take() {
            let _ = j.join();
        }
        self.shutdown()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.shutdown();
    }
}

// ---------------------------------------------------------------------------
// Accept loop + connection handlers
// ---------------------------------------------------------------------------

fn accept_main(
    listener: TcpListener,
    opts: HttpOptions,
    engine_tx: Sender<EngineCmd>,
    stop: Arc<AtomicBool>,
    counters: Arc<Counters>,
) {
    for stream in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let Ok(stream) = stream else { continue };
        counters.total_conns.fetch_add(1, Ordering::SeqCst);
        if counters.open_conns.load(Ordering::SeqCst) >= opts.max_conns {
            // Connection cap: shed load at the edge, before a thread or a
            // queue slot is committed.
            counters.conn_rejected_503.fetch_add(1, Ordering::SeqCst);
            let mut w = stream;
            let _ = write_error(&mut w, 503, "connection limit reached", Some(1));
            continue;
        }
        counters.open_conns.fetch_add(1, Ordering::SeqCst);
        let tx = engine_tx.clone();
        let conn_counters = Arc::clone(&counters);
        let conn_opts = opts.clone();
        let spawned = thread::Builder::new().name("apb-http-conn".into()).spawn(move || {
            handle_conn(stream, conn_opts, tx);
            conn_counters.open_conns.fetch_sub(1, Ordering::SeqCst);
        });
        if spawned.is_err() {
            counters.open_conns.fetch_sub(1, Ordering::SeqCst);
        }
    }
}

fn handle_conn(stream: TcpStream, opts: HttpOptions, engine_tx: Sender<EngineCmd>) {
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(Duration::from_secs(opts.read_timeout_s.max(1)))).ok();
    let Ok(reader_stream) = stream.try_clone() else { return };
    let mut reader = BufReader::new(reader_stream);
    let mut writer = stream;
    loop {
        let req = match read_request(&mut reader, &opts.limits) {
            Ok(None) => break, // clean keep-alive close
            Ok(Some(req)) => req,
            Err(e) => {
                // 408 (idle timeout) closes quietly; real parse errors get
                // their mapped status before the connection drops.
                if e.status != 408 {
                    let _ = write_error(&mut writer, e.status, &e.msg, None);
                }
                break;
            }
        };
        let close = req.wants_close();
        let ok = dispatch(&req, &mut writer, &engine_tx);
        if close || !ok {
            break;
        }
    }
}

/// Route + serve one request. Returns false when the connection should
/// close (stream write failure or engine gone).
fn dispatch(req: &HttpRequest, w: &mut TcpStream, engine_tx: &Sender<EngineCmd>) -> bool {
    let routed = match route(&req.method, req.path()) {
        Ok(r) => r,
        Err((status, detail)) => return write_error(w, status, &detail, None).is_ok(),
    };
    match routed {
        Route::Health => {
            let body = JsonWriter::obj().str_field("status", "ok").close();
            write_simple(w, 200, "application/json", body.as_bytes(), &[]).is_ok()
        }
        Route::Metrics => {
            let (tx, rx) = mpsc::channel();
            if engine_tx.send(EngineCmd::Metrics(tx)).is_err() {
                return write_error(w, 503, "engine stopped", None).is_ok();
            }
            match rx.recv_timeout(Duration::from_secs(60)) {
                Ok(body) => {
                    write_simple(w, 200, "application/json", body.as_bytes(), &[]).is_ok()
                }
                Err(_) => write_error(w, 500, "metrics timed out", None).is_ok(),
            }
        }
        Route::ClearSession(sid) => {
            let (tx, rx) = mpsc::channel();
            if engine_tx.send(EngineCmd::ClearSession(sid, tx)).is_err() {
                return write_error(w, 503, "engine stopped", None).is_ok();
            }
            match rx.recv_timeout(Duration::from_secs(60)) {
                Ok(ClearOutcome::Cleared) => {
                    let body = JsonWriter::obj().num_field("session", sid as f64)
                        .bool_field("cleared", true).close();
                    write_simple(w, 200, "application/json", body.as_bytes(), &[]).is_ok()
                }
                Ok(ClearOutcome::NotFound) => {
                    write_error(w, 404, &format!("no persistent session {sid}"), None).is_ok()
                }
                Ok(ClearOutcome::Busy) => {
                    write_error(w, 409, &format!("session {sid} has a stream in flight"), None)
                        .is_ok()
                }
                Err(_) => write_error(w, 500, "clear timed out", None).is_ok(),
            }
        }
        Route::Generate => {
            let body = String::from_utf8_lossy(&req.body);
            let (tx, rx) = mpsc::channel();
            // Body parsing happens on the engine thread? No: here, but the
            // spec needs the config. The engine validates geometry; the
            // handler only checks JSON shape via the engine's parser — we
            // ship the raw body and let the engine parse so the config
            // stays in one place.
            if engine_tx.send(EngineCmd::Generate(
                match parse_probe(&body) {
                    Ok(spec) => spec,
                    Err((status, detail)) => {
                        return write_error(w, status, &detail, None).is_ok()
                    }
                },
                tx,
            )).is_err() {
                return write_error(w, 503, "engine stopped", None).is_ok();
            }
            stream_events(w, &rx)
        }
    }
}

/// Handler-side pre-parse: JSON syntax + field extraction that needs no
/// config (geometry checks happen on the engine, which owns the config).
fn parse_probe(body: &str) -> std::result::Result<Box<GenerateSpec>, (u16, String)> {
    let v = Json::parse(body).map_err(|e| (400, format!("body is not JSON: {e}")))?;
    let get_usize = |k: &str| -> std::result::Result<Option<usize>, (u16, String)> {
        match v.get(k) {
            None | Some(Json::Null) => Ok(None),
            Some(j) => j.as_usize().map(Some).ok_or((400, format!("'{k}' must be a non-negative integer"))),
        }
    };
    let get_tokens = |k: &str| -> std::result::Result<Option<Vec<i32>>, (u16, String)> {
        match v.get(k) {
            None | Some(Json::Null) => Ok(None),
            Some(j) => {
                let arr = j.as_arr().ok_or((400, format!("'{k}' must be an array")))?;
                arr.iter()
                    .map(|t| {
                        t.as_i64()
                            .and_then(|x| i32::try_from(x).ok())
                            .ok_or((400, format!("'{k}' must hold i32 tokens")))
                    })
                    .collect::<std::result::Result<Vec<i32>, _>>()
                    .map(Some)
            }
        }
    };
    let get_str = |k: &str| -> std::result::Result<Option<&str>, (u16, String)> {
        match v.get(k) {
            None | Some(Json::Null) => Ok(None),
            Some(j) => j.as_str().map(Some).ok_or((400, format!("'{k}' must be a string"))),
        }
    };

    let mut opts = ApbOptions::default();
    if let Some(m) = get_str("method")? {
        opts.method = AttnMethod::parse(m).map_err(|e| (400, format!("{e:#}")))?;
    }
    if let Some(ct) = get_usize("chunk_tokens")? {
        opts.chunk_tokens = Some(ct);
    }
    if let Some(ps) = get_str("pass_strategy")? {
        opts.pass_strategy =
            Some(PassStrategy::parse(ps).map_err(|e| (400, format!("{e:#}")))?);
    }
    let class = match get_str("class")? {
        Some(c) => Class::parse(c).ok_or((400, format!("'{c}' is not a class")))?,
        None => Class::default(),
    };
    let keep = match v.get("keep") {
        None | Some(Json::Null) => false,
        Some(j) => j.as_bool().ok_or((400, "'keep' must be a bool".to_string()))?,
    };
    let session = match v.get("session") {
        None | Some(Json::Null) => None,
        Some(j) => Some(
            j.as_i64()
                .and_then(|x| u64::try_from(x).ok())
                .ok_or((400, "'session' must be a session id".to_string()))?,
        ),
    };
    let turn = get_tokens("turn")?.unwrap_or_default();
    if session.is_some() && turn.is_empty() {
        return Err((400, "'session' requires a non-empty 'turn' token array".into()));
    }
    if session.is_none() && !turn.is_empty() {
        return Err((400, "'turn' requires 'session'".into()));
    }
    let (doc, query) = if session.is_some() {
        (Vec::new(), Vec::new())
    } else {
        (
            get_tokens("doc")?.ok_or((400, "'doc' token array is required".to_string()))?,
            get_tokens("query")?.ok_or((400, "'query' token array is required".to_string()))?,
        )
    };
    let max_new = get_usize("max_new")?.unwrap_or(0); // 0 → engine default
    Ok(Box::new(GenerateSpec { doc, query, max_new, opts, class, keep, session, turn }))
}

/// Pump engine events onto the wire. The first event decides the shape:
/// a `Reject` is a plain status response; anything else opens a chunked
/// 200 and streams until `Done`.
fn stream_events(w: &mut TcpStream, rx: &Receiver<Event>) -> bool {
    let first = match rx.recv_timeout(Duration::from_secs(300)) {
        Ok(e) => e,
        Err(_) => return write_error(w, 500, "engine did not respond", None).is_ok(),
    };
    match first {
        Event::Reject { status, detail, retry_after } => {
            write_error(w, status, &detail, if retry_after { Some(1) } else { None }).is_ok()
        }
        first => {
            let Ok(mut cw) = ChunkedWriter::begin(&mut *w, 200, "application/x-ndjson", &[])
            else {
                return false;
            };
            let mut ev = first;
            loop {
                match ev {
                    Event::Chunk(line) => {
                        if cw.chunk(line.as_bytes()).is_err() {
                            return false;
                        }
                    }
                    Event::Done(line) => {
                        if cw.chunk(line.as_bytes()).is_err() {
                            return false;
                        }
                        return cw.finish().is_ok();
                    }
                    Event::Reject { .. } => return false, // engine never rejects mid-stream
                }
                ev = match rx.recv_timeout(Duration::from_secs(300)) {
                    Ok(e) => e,
                    Err(_) => return false,
                };
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Engine
// ---------------------------------------------------------------------------

/// Persistent session ids live far above the scheduler's (which start at
/// `LEGACY_SESSION + 1` and count up) so the two allocators never collide.
const PERSIST_SID_BASE: SessionId = 1_000_000;

fn reject(tx: &Sender<Event>, status: u16, detail: impl Into<String>, retry_after: bool) {
    let _ = tx.send(Event::Reject { status, detail: detail.into(), retry_after });
}

fn token_line(index: usize, token: i32) -> String {
    let mut line = JsonWriter::obj()
        .str_field("event", "token")
        .num_field("index", index as f64)
        .num_field("token", token as f64)
        .close();
    line.push('\n');
    line
}

fn argmax_token(row: &[f32]) -> i32 {
    Tensor::argmax_row(row) as i32
}

struct Engine<'a> {
    cfg: &'a Config,
    sched: Scheduler<'a>,
    cluster: &'a Cluster,
    capacity: usize,
    persist: HashSet<SessionId>,
    next_psid: SessionId,
    streams: HashMap<u64, SchedStream>,
    turns: Vec<TurnStream>,
    keep_q: VecDeque<(Box<GenerateSpec>, Sender<Event>)>,
    next_req_id: u64,
    completed_seen: usize,
    served: u64,
    rejected_429: u64,
    draining: bool,
    counters: Arc<Counters>,
}

fn engine_main(
    cfg: Config,
    driver: Driver,
    opts: HttpOptions,
    rx: Receiver<EngineCmd>,
    ready_tx: Sender<std::result::Result<(), String>>,
    counters: Arc<Counters>,
) {
    let cluster = match Cluster::start_with(&cfg, driver) {
        Ok(c) => c,
        Err(e) => {
            let _ = ready_tx.send(Err(format!("{e:#}")));
            return;
        }
    };
    let _ = ready_tx.send(Ok(()));
    let sched = Scheduler::new(&cluster, opts.max_queue);
    let mut eng = Engine {
        cfg: &cfg,
        capacity: cfg.apb.max_resident,
        sched,
        cluster: &cluster,
        persist: HashSet::new(),
        next_psid: PERSIST_SID_BASE,
        streams: HashMap::new(),
        turns: Vec::new(),
        keep_q: VecDeque::new(),
        next_req_id: 1,
        completed_seen: 0,
        served: 0,
        rejected_429: 0,
        draining: false,
        counters,
    };
    let mut drain_ack: Option<Sender<()>> = None;

    loop {
        // 1) Commands. Block (with a short poll) when no stream can make
        // progress anyway — keeps the engine cold between requests instead
        // of spinning the loop.
        let mut disconnected = false;
        if !eng.can_progress() && !eng.draining {
            match rx.recv_timeout(Duration::from_millis(50)) {
                Ok(cmd) => eng.handle(cmd, &mut drain_ack),
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => disconnected = true,
            }
        }
        loop {
            match rx.try_recv() {
                Ok(cmd) => eng.handle(cmd, &mut drain_ack),
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    disconnected = true;
                    break;
                }
            }
        }
        if disconnected && !eng.can_progress() {
            // Every Server/handler sender is gone and nothing left can
            // advance: there is no one to stream to.
            break;
        }

        // 2..5) One quiescent-boundary slice of work.
        eng.step();

        if eng.draining {
            if eng.idle() {
                if let Some(ack) = drain_ack.take() {
                    let _ = ack.send(());
                }
                break;
            }
            if !eng.can_progress() {
                // Queued work that can never admit (every KV slot is a
                // persistent session nobody will DELETE while draining):
                // fail the stragglers rather than hang shutdown.
                eng.fail_all_streams("server is draining");
                if let Some(ack) = drain_ack.take() {
                    let _ = ack.send(());
                }
                break;
            }
        }
    }
}

impl<'a> Engine<'a> {
    fn idle(&self) -> bool {
        self.sched.queued() == 0
            && self.sched.resident() == 0
            && self.streams.is_empty()
            && self.turns.is_empty()
            && self.keep_q.is_empty()
    }

    /// Whether a [`Engine::step`] slice could advance anything right now.
    /// False both when fully idle and when the only outstanding work is
    /// queued admissions that cannot seat (`max_resident` == 0 because
    /// every KV slot is persistent) — in either case the loop should
    /// block on the command channel instead of spinning.
    fn can_progress(&self) -> bool {
        !self.turns.is_empty()
            || !self.keep_q.is_empty()
            || (self.effective_capacity() >= 1
                && (self.sched.queued() > 0 || self.sched.resident() > 0))
            || self.sched.resident() > 0
    }

    /// Scheduler slots not reserved by persistent sessions (live or
    /// queued-to-prefill).
    fn effective_capacity(&self) -> usize {
        self.capacity.saturating_sub(self.persist.len() + self.keep_q.len())
    }

    fn handle(&mut self, cmd: EngineCmd, drain_ack: &mut Option<Sender<()>>) {
        match cmd {
            EngineCmd::Generate(spec, tx) => self.handle_generate(spec, tx),
            EngineCmd::Metrics(tx) => {
                let _ = tx.send(self.metrics_json());
            }
            EngineCmd::ClearSession(sid, tx) => {
                let outcome = if !self.persist.contains(&sid) {
                    ClearOutcome::NotFound
                } else if self.turns.iter().any(|t| t.sid == sid) {
                    ClearOutcome::Busy
                } else {
                    self.persist.remove(&sid);
                    match self.cluster.clear_session(sid) {
                        Ok(()) => ClearOutcome::Cleared,
                        Err(_) => ClearOutcome::Cleared, // slot freed engine-side regardless
                    }
                };
                let _ = tx.send(outcome);
            }
            EngineCmd::Shutdown(ack) => {
                self.draining = true;
                *drain_ack = Some(ack);
            }
        }
    }

    fn handle_generate(&mut self, mut spec: Box<GenerateSpec>, tx: Sender<Event>) {
        if self.draining {
            return reject(&tx, 503, "server is draining", false);
        }
        if spec.max_new == 0 {
            spec.max_new = self.cfg.apb.max_new_tokens.max(1);
        }
        if let Some(psid) = spec.session {
            return self.start_turn(&spec, psid, tx);
        }
        // Geometry validation (engine-side: it owns the config).
        if spec.doc.len() != self.cfg.apb.doc_len() {
            return reject(
                &tx,
                400,
                format!("doc length {} != configured {}", spec.doc.len(), self.cfg.apb.doc_len()),
                false,
            );
        }
        if spec.query.len() != self.cfg.apb.query_len {
            return reject(
                &tx,
                400,
                format!(
                    "query length {} != configured {}",
                    spec.query.len(),
                    self.cfg.apb.query_len
                ),
                false,
            );
        }
        if spec.keep {
            if self.persist.len() + self.keep_q.len() + self.sched.resident() >= self.capacity {
                self.rejected_429 += 1;
                return reject(&tx, 429, "kv pool exhausted (persistent sessions)", true);
            }
            self.keep_q.push_back((spec, tx));
            return;
        }
        if self.effective_capacity() == 0 {
            // Every KV slot is (or is about to be) held by a persistent
            // session: a queued request could never admit. Backpressure,
            // not an internal error.
            self.rejected_429 += 1;
            return reject(&tx, 429, "kv pool exhausted: backpressure", true);
        }
        let id = self.next_req_id;
        self.next_req_id += 1;
        let req = Request {
            id,
            doc: std::mem::take(&mut spec.doc),
            query: std::mem::take(&mut spec.query),
            max_new: spec.max_new,
            opts: spec.opts,
            class: spec.class,
        };
        match self.sched.submit(req) {
            Ok(()) => {
                self.served += 1;
                self.streams.insert(id, SchedStream { tx, sent: 0 });
            }
            Err(e) if is_backpressure(&e) => {
                self.rejected_429 += 1;
                reject(&tx, 429, format!("{e:#}"), true);
            }
            Err(e) => reject(&tx, 500, format!("{e:#}"), false),
        }
    }

    /// Start a follow-up turn against a kept session: one `append_turn`
    /// chunk pass yields the first token; the rest decode batched.
    fn start_turn(&mut self, spec: &GenerateSpec, psid: SessionId, tx: Sender<Event>) {
        if !self.persist.contains(&psid) {
            return reject(&tx, 404, format!("no persistent session {psid}"), false);
        }
        if self.turns.iter().any(|t| t.sid == psid) {
            return reject(&tx, 409, format!("session {psid} has a stream in flight"), false);
        }
        match self.cluster.append_turn(psid, &spec.turn) {
            Ok(chunk) => {
                let vocab = self.cfg.model.vocab_size;
                let token0 = argmax_token(&chunk.logits[chunk.logits.len() - vocab..]);
                self.served += 1;
                let _ = tx.send(Event::Chunk(token_line(0, token0)));
                self.finish_or_stream_turn(psid, tx, vec![token0], spec.max_new);
            }
            Err(e) if is_backpressure(&e) => {
                self.rejected_429 += 1;
                reject(&tx, 429, format!("{e:#}"), true);
            }
            Err(e) => reject(&tx, 500, format!("{e:#}"), false),
        }
    }

    /// Either the stream is complete (send `done`) or it joins the
    /// batched turn-decode rotation.
    fn finish_or_stream_turn(
        &mut self,
        sid: SessionId,
        tx: Sender<Event>,
        produced: Vec<i32>,
        max_new: usize,
    ) {
        if produced.len() >= max_new {
            let _ = tx.send(Event::Done(done_line_persistent(sid, &produced)));
        } else {
            let prev = *produced.last().expect("first token present");
            self.turns.push(TurnStream { sid, tx, produced, max_new, prev });
        }
    }

    /// One engine slice: at most one persistent prefill, one scheduler
    /// step, one batched turn-decode step, then flush new tokens.
    fn step(&mut self) {
        // Reserve scheduler headroom for persistent + queued-keep slots.
        self.sched.max_resident = self.effective_capacity();

        // (2) One persistent prefill, only while the one-prefill-at-a-time
        // permit is guaranteed free (no scheduler admission in flight) —
        // `prefill_session` runs begin→finish inline, i.e. at a fabric-
        // quiescent boundary, then releases the permit before the next
        // scheduler step.
        if !self.keep_q.is_empty() && self.sched.prefill_in_flight().is_none() {
            let (spec, tx) = self.keep_q.pop_front().expect("non-empty");
            self.run_keep_prefill(&spec, tx);
        }

        // (3) One scheduler step (admission chunk interleaved with the
        // batched decode tick). `max_resident == 0` means every slot is
        // persistent: queued work waits for a DELETE /v1/session.
        if self.sched.max_resident >= 1
            && (self.sched.queued() > 0 || self.sched.resident() > 0)
        {
            if let Err(e) = self.sched.step() {
                self.fail_all_streams(&format!("scheduler error: {e:#}"));
            }
        }

        // (4) One batched decode step across live turn streams.
        self.step_turns();

        // (5) Flush newly decoded scheduler tokens + completed responses.
        self.flush_sched_streams();
    }

    fn run_keep_prefill(&mut self, spec: &GenerateSpec, tx: Sender<Event>) {
        let psid = self.next_psid;
        self.next_psid += 1;
        let prefilled = self
            .cluster
            .prefill_session(psid, &spec.doc, &spec.query, &spec.opts)
            .and_then(|_| self.cluster.decode_query_chunk(psid, &spec.query));
        match prefilled {
            Ok(chunk) => {
                self.persist.insert(psid);
                let vocab = self.cfg.model.vocab_size;
                let token0 = argmax_token(&chunk.logits[chunk.logits.len() - vocab..]);
                self.served += 1;
                let _ = tx.send(Event::Chunk(token_line(0, token0)));
                self.finish_or_stream_turn(psid, tx, vec![token0], spec.max_new);
            }
            Err(e) => {
                let _ = self.cluster.clear_session(psid);
                if is_backpressure(&e) {
                    self.rejected_429 += 1;
                    reject(&tx, 429, format!("{e:#}"), true);
                } else {
                    reject(&tx, 500, format!("{e:#}"), false);
                }
            }
        }
    }

    fn step_turns(&mut self) {
        if self.turns.is_empty() {
            return;
        }
        let entries: Vec<(SessionId, i32)> = self.turns.iter().map(|t| (t.sid, t.prev)).collect();
        let rep = match self.cluster.decode_step_batch(&entries) {
            Ok(rep) => rep,
            Err(e) => {
                let msg = format!("decode error: {e:#}");
                for t in self.turns.drain(..) {
                    let _ = t.tx.send(Event::Done(error_done_line(&msg)));
                }
                return;
            }
        };
        let mut finished: Vec<usize> = Vec::new();
        for (i, (sid, row)) in rep.logits.iter().enumerate() {
            let t = &mut self.turns[i];
            debug_assert_eq!(t.sid, *sid, "batch rows come back in entry order");
            let token = argmax_token(row);
            t.produced.push(token);
            t.prev = token;
            let _ = t.tx.send(Event::Chunk(token_line(t.produced.len() - 1, token)));
            if t.produced.len() >= t.max_new {
                finished.push(i);
            }
        }
        for i in finished.into_iter().rev() {
            let t = self.turns.swap_remove(i);
            let _ = t.tx.send(Event::Done(done_line_persistent(t.sid, &t.produced)));
        }
    }

    fn flush_sched_streams(&mut self) {
        let mut flushes: Vec<(u64, Vec<i32>, usize)> = Vec::new();
        for (rid, toks) in self.sched.active_tokens() {
            if let Some(st) = self.streams.get(&rid) {
                if toks.len() > st.sent {
                    flushes.push((rid, toks[st.sent..].to_vec(), toks.len()));
                }
            }
        }
        for (rid, new_toks, total) in flushes {
            if let Some(st) = self.streams.get_mut(&rid) {
                for (k, tok) in new_toks.iter().enumerate() {
                    let _ = st.tx.send(Event::Chunk(token_line(st.sent + k, *tok)));
                }
                st.sent = total;
            }
        }
        let completed = &self.sched.completed;
        for resp in completed.iter().skip(self.completed_seen) {
            if let Some(st) = self.streams.remove(&resp.id) {
                for (k, tok) in resp.tokens.iter().enumerate().skip(st.sent) {
                    let _ = st.tx.send(Event::Chunk(token_line(k, *tok)));
                }
                let _ = st.tx.send(Event::Done(done_line_response(resp)));
            }
        }
        self.completed_seen = completed.len();
    }

    fn fail_all_streams(&mut self, msg: &str) {
        for (_, st) in self.streams.drain() {
            let _ = st.tx.send(Event::Done(error_done_line(msg)));
        }
        for t in self.turns.drain(..) {
            let _ = t.tx.send(Event::Done(error_done_line(msg)));
        }
    }

    /// The `GET /v1/metrics` body: ServingMetrics (when any request has
    /// completed) + per-host PoolStats + live engine/edge gauges.
    fn metrics_json(&self) -> String {
        let mut fields: Vec<(&str, Json)> = vec![
            ("schema_version", json::num(1.0)),
            ("config", json::s(&self.cfg.name)),
            ("driver", json::s(self.cluster.driver().name())),
            ("queued", json::num(self.sched.queued() as f64)),
            ("resident", json::num(self.sched.resident() as f64)),
            ("persistent_sessions", json::num(self.persist.len() as f64)),
            ("active_turn_streams", json::num(self.turns.len() as f64)),
            ("served", json::num(self.served as f64)),
            ("rejected_429", json::num(self.rejected_429 as f64)),
            (
                "open_connections",
                json::num(self.counters.open_conns.load(Ordering::SeqCst) as f64),
            ),
            (
                "total_connections",
                json::num(self.counters.total_conns.load(Ordering::SeqCst) as f64),
            ),
            (
                "connections_rejected_503",
                json::num(self.counters.conn_rejected_503.load(Ordering::SeqCst) as f64),
            ),
        ];
        match self.sched.metrics_opt() {
            Some(m) => {
                fields.push(("n_requests", json::num(m.n_requests as f64)));
                fields.push(("total_tokens", json::num(m.total_tokens as f64)));
                fields.push(("peak_resident", json::num(m.peak_resident as f64)));
                fields.push(("preemptions", json::num(m.preemptions_total as f64)));
                fields.push(("starved", json::num(m.starved as f64)));
                fields.push(("prefix_hits", json::num(m.prefix_hits as f64)));
                fields.push(("decode_att_bytes", json::num(m.decode_att_bytes as f64)));
                fields.push(("decode_qring_bytes", json::num(m.decode_qring_bytes as f64)));
                fields.push(("ttft_ms", summary_json(&m.ttft, 1e3)));
                fields.push(("ttft_ticks", summary_json(&m.ttft_ticks, 1.0)));
                fields.push(("tpot_ms", summary_json(&m.tpot, 1e3)));
                let classes: Vec<Json> = m
                    .per_class
                    .iter()
                    .map(|c| {
                        json::obj(vec![
                            ("class", json::s(c.class.name())),
                            ("n_requests", json::num(c.n_requests as f64)),
                            ("slo_met", json::num(c.slo_met as f64)),
                            ("slo_fraction", json::num(c.slo_fraction)),
                            ("goodput_tokens", json::num(c.goodput_tokens as f64)),
                        ])
                    })
                    .collect();
                fields.push(("per_class", Json::Arr(classes)));
            }
            None => fields.push(("n_requests", json::num(0.0))),
        }
        match self.cluster.pool_stats() {
            Ok(stats) => {
                let pool: Vec<Json> = stats
                    .iter()
                    .enumerate()
                    .map(|(host, p)| {
                        json::obj(vec![
                            ("host", json::num(host as f64)),
                            ("resident", json::num(p.resident as f64)),
                            ("bytes_used", json::num(p.bytes_used as f64)),
                            ("bytes_reserved", json::num(p.bytes_reserved as f64)),
                            ("prefix_entries", json::num(p.prefix_entries as f64)),
                            ("prefix_bytes", json::num(p.prefix_bytes as f64)),
                            ("slab_allocs", json::num(p.slab_allocs as f64)),
                            ("slab_reuses", json::num(p.slab_reuses as f64)),
                            ("slabs_free", json::num(p.slabs_free as f64)),
                        ])
                    })
                    .collect();
                fields.push(("pool", Json::Arr(pool)));
            }
            Err(e) => fields.push(("pool_error", json::s(&format!("{e:#}")))),
        }
        json::obj(fields).dumps()
    }
}

fn summary_json(s: &Summary, scale: f64) -> Json {
    json::obj(vec![
        ("n", json::num(s.n as f64)),
        ("mean", json::num(s.mean * scale)),
        ("min", json::num(s.min * scale)),
        ("p50", json::num(s.p50 * scale)),
        ("p90", json::num(s.p90 * scale)),
        ("p95", json::num(s.p95 * scale)),
        ("p99", json::num(s.p99 * scale)),
        ("max", json::num(s.max * scale)),
    ])
}

fn done_line_response(resp: &crate::coordinator::scheduler::Response) -> String {
    let mut line = JsonWriter::obj()
        .str_field("event", "done")
        .num_field("id", resp.id as f64)
        .tokens_field("tokens", &resp.tokens)
        .num_field("n_tokens", resp.tokens.len() as f64)
        .num_field("ttft_ticks", resp.ttft_ticks as f64)
        .num_field("queue_wait_ticks", resp.queue_wait_ticks as f64)
        .num_field("prefill_chunks", resp.prefill_chunks as f64)
        .num_field("preemptions", resp.preemptions as f64)
        .num_field("decode_att_bytes", resp.decode_att_bytes as f64)
        .num_field("decode_qring_bytes", resp.decode_qring_bytes as f64)
        .bool_field("prefix_hit", resp.prefill.prefix_hit)
        .raw_field("session", "null")
        .close();
    line.push('\n');
    line
}

fn done_line_persistent(sid: SessionId, tokens: &[i32]) -> String {
    let mut line = JsonWriter::obj()
        .str_field("event", "done")
        .tokens_field("tokens", tokens)
        .num_field("n_tokens", tokens.len() as f64)
        .num_field("session", sid as f64)
        .close();
    line.push('\n');
    line
}

fn error_done_line(msg: &str) -> String {
    let mut line = JsonWriter::obj()
        .str_field("event", "done")
        .str_field("error", msg)
        .close();
    line.push('\n');
    line
}
