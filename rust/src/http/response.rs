//! HTTP/1.1 response writing + chunked transfer-encoding, std-only.
//!
//! Two response shapes cover the whole `/v1` API:
//!
//! * [`write_simple`] — a fixed body with `Content-Length` (metrics,
//!   health, every error status);
//! * [`ChunkedWriter`] — `Transfer-Encoding: chunked` streaming for
//!   `/v1/generate`, flushing **one chunk per emitted token event** so a
//!   client sees tokens as the scheduler decodes them, not when the
//!   request retires (`docs/ADR-008-http-front-door.md` records why this
//!   beat SSE here).
//!
//! The matching [`ChunkedReader`]/[`read_chunked`] decoder serves both
//! the client half (`http::client`, workload HTTP driver) and chunked
//! *request* bodies in the parser. Writer and reader are round-tripped
//! over arbitrary token-chunk partitions by the proptest below.

use std::io::{self, BufRead, Write};

use super::parser::ParseError;

/// Canonical reason phrase for every status the front door emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        409 => "Conflict",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Response",
    }
}

/// Write a head: status line + headers + blank line.
fn write_head<W: Write>(w: &mut W, status: u16, headers: &[(&str, String)]) -> io::Result<()> {
    write!(w, "HTTP/1.1 {} {}\r\n", status, reason(status))?;
    for (k, v) in headers {
        write!(w, "{k}: {v}\r\n")?;
    }
    write!(w, "\r\n")
}

/// Write a complete fixed-length response (keep-alive friendly: the
/// explicit `Content-Length` lets the peer keep the connection open).
pub fn write_simple<W: Write>(
    w: &mut W,
    status: u16,
    content_type: &str,
    body: &[u8],
    extra: &[(&str, String)],
) -> io::Result<()> {
    let mut headers: Vec<(&str, String)> = vec![
        ("Content-Type", content_type.to_string()),
        ("Content-Length", body.len().to_string()),
    ];
    headers.extend(extra.iter().map(|(k, v)| (*k, v.clone())));
    write_head(w, status, &headers)?;
    w.write_all(body)?;
    w.flush()
}

/// JSON error body + status, shared by every error path so clients see
/// one shape: `{"error": "..."}` (+ `Retry-After` on 429).
pub fn write_error<W: Write>(
    w: &mut W,
    status: u16,
    detail: &str,
    retry_after_s: Option<u64>,
) -> io::Result<()> {
    let body = crate::util::json::JsonWriter::obj().str_field("error", detail).close();
    let extra: Vec<(&str, String)> = match retry_after_s {
        Some(s) => vec![("Retry-After", s.to_string())],
        None => Vec::new(),
    };
    write_simple(w, status, "application/json", body.as_bytes(), &extra)
}

/// Streaming response writer: `Transfer-Encoding: chunked`, one flush per
/// chunk. Call [`ChunkedWriter::finish`] to emit the terminal chunk; the
/// connection stays reusable afterwards.
pub struct ChunkedWriter<W: Write> {
    w: W,
    finished: bool,
}

impl<W: Write> ChunkedWriter<W> {
    /// Write the response head and switch to chunked framing.
    pub fn begin(
        mut w: W,
        status: u16,
        content_type: &str,
        extra: &[(&str, String)],
    ) -> io::Result<ChunkedWriter<W>> {
        let mut headers: Vec<(&str, String)> = vec![
            ("Content-Type", content_type.to_string()),
            ("Transfer-Encoding", "chunked".to_string()),
        ];
        headers.extend(extra.iter().map(|(k, v)| (*k, v.clone())));
        write_head(&mut w, status, &headers)?;
        w.flush()?;
        Ok(ChunkedWriter { w, finished: false })
    }

    /// Emit one chunk (empty data is skipped — a zero-length chunk would
    /// be the terminator) and flush it to the wire immediately.
    pub fn chunk(&mut self, data: &[u8]) -> io::Result<()> {
        debug_assert!(!self.finished, "chunk after finish");
        if data.is_empty() {
            return Ok(());
        }
        write!(self.w, "{:x}\r\n", data.len())?;
        self.w.write_all(data)?;
        write!(self.w, "\r\n")?;
        self.w.flush()
    }

    /// Emit the terminal `0\r\n\r\n` chunk.
    pub fn finish(mut self) -> io::Result<()> {
        self.finished = true;
        write!(self.w, "0\r\n\r\n")?;
        self.w.flush()
    }
}

/// Incremental chunked-transfer decoder. [`ChunkedReader::next_chunk`]
/// preserves the writer's chunk boundaries — the observable the workload
/// driver uses to prove a response actually *streamed* (≥ 2 chunks)
/// rather than arriving as one buffered blob.
pub struct ChunkedReader {
    /// Total decoded bytes so far, checked against the size cap.
    total: usize,
    max_total: usize,
    done: bool,
}

impl ChunkedReader {
    pub fn new(max_total: usize) -> ChunkedReader {
        ChunkedReader { total: 0, max_total, done: false }
    }

    /// Read one chunk; `Ok(None)` after the terminal chunk (trailers are
    /// consumed and discarded). Malformed framing is a 400, exceeding the
    /// size cap a 413.
    pub fn next_chunk<R: BufRead>(&mut self, r: &mut R) -> Result<Option<Vec<u8>>, ParseError> {
        if self.done {
            return Ok(None);
        }
        let size_line = read_line(r)?;
        // Chunk extensions (";ext=val") are tolerated and ignored.
        let size_hex = size_line.split(';').next().unwrap_or("").trim();
        if size_hex.is_empty() || !size_hex.bytes().all(|b| b.is_ascii_hexdigit()) {
            return Err(ParseError { status: 400, msg: "bad chunk size".into() });
        }
        let size = usize::from_str_radix(size_hex, 16)
            .map_err(|_| ParseError { status: 400, msg: "chunk size overflows".into() })?;
        if size == 0 {
            // Terminal chunk: consume (and discard) trailers up to the
            // blank line.
            loop {
                if read_line(r)?.is_empty() {
                    break;
                }
            }
            self.done = true;
            return Ok(None);
        }
        self.total = self.total.saturating_add(size);
        if self.total > self.max_total {
            return Err(ParseError { status: 413, msg: "chunked body too large".into() });
        }
        let mut data = vec![0u8; size];
        io::Read::read_exact(r, &mut data)
            .map_err(|_| ParseError { status: 400, msg: "truncated chunk data".into() })?;
        match read_line(r) {
            Ok(l) if l.is_empty() => Ok(Some(data)),
            _ => Err(ParseError { status: 400, msg: "chunk data missing CRLF".into() }),
        }
    }
}

/// Decode a whole chunked body to one buffer (request bodies, simple
/// client calls).
pub fn read_chunked<R: BufRead>(r: &mut R, max_total: usize) -> Result<Vec<u8>, ParseError> {
    let mut reader = ChunkedReader::new(max_total);
    let mut out = Vec::new();
    while let Some(chunk) = reader.next_chunk(r)? {
        out.extend_from_slice(&chunk);
    }
    Ok(out)
}

/// Read one CRLF (or bare-LF) terminated line of bounded length.
fn read_line<R: BufRead>(r: &mut R) -> Result<String, ParseError> {
    let mut line = Vec::with_capacity(16);
    let mut byte = [0u8; 1];
    loop {
        match io::Read::read(r, &mut byte) {
            Ok(0) => return Err(ParseError { status: 400, msg: "truncated chunk framing".into() }),
            Ok(_) => {}
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(ParseError { status: 400, msg: format!("read error: {e}") }),
        }
        if byte[0] == b'\n' {
            if line.last() == Some(&b'\r') {
                line.pop();
            }
            return String::from_utf8(line)
                .map_err(|_| ParseError { status: 400, msg: "non-UTF-8 chunk framing".into() });
        }
        line.push(byte[0]);
        if line.len() > 128 {
            return Err(ParseError { status: 400, msg: "chunk framing line too long".into() });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::io::Cursor;

    /// The satellite round-trip proptest: arbitrary token-chunk partitions
    /// of arbitrary payloads survive writer → reader with byte identity
    /// AND boundary identity.
    #[test]
    fn proptest_chunked_roundtrip_preserves_partitions() {
        for seed in 0..64u64 {
            let mut rng = Rng::new(0xC0FFEE ^ seed);
            // A payload partitioned like a token stream: many small chunks.
            let n_chunks = 1 + rng.below(24) as usize;
            let chunks: Vec<Vec<u8>> = (0..n_chunks)
                .map(|_| {
                    let len = 1 + rng.below(96) as usize;
                    (0..len).map(|_| rng.below(256) as u8).collect()
                })
                .collect();

            let mut wire = Vec::new();
            {
                let mut cw =
                    ChunkedWriter::begin(&mut wire, 200, "application/octet-stream", &[]).unwrap();
                for c in &chunks {
                    cw.chunk(c).unwrap();
                }
                cw.finish().unwrap();
            }
            // Skip the head: the reader starts at the first chunk-size line.
            let head_end = wire.windows(4).position(|w| w == b"\r\n\r\n").unwrap() + 4;
            let mut r = Cursor::new(wire[head_end..].to_vec());
            let mut reader = ChunkedReader::new(1 << 20);
            let mut back = Vec::new();
            while let Some(c) = reader.next_chunk(&mut r).unwrap() {
                back.push(c);
            }
            assert_eq!(back, chunks, "seed {seed}: partition not preserved");
            assert_eq!(r.position() as usize, wire.len() - head_end, "seed {seed}: trailing bytes");
        }
        println!("APB-RUN http_chunked_roundtrip backend=none seeds=64");
    }

    #[test]
    fn chunked_reader_enforces_size_cap() {
        let mut wire = Vec::new();
        let mut cw = ChunkedWriter::begin(&mut wire, 200, "x", &[]).unwrap();
        cw.chunk(&[7u8; 256]).unwrap();
        cw.finish().unwrap();
        let head_end = wire.windows(4).position(|w| w == b"\r\n\r\n").unwrap() + 4;
        let mut r = Cursor::new(wire[head_end..].to_vec());
        let err = ChunkedReader::new(64).next_chunk(&mut r).unwrap_err();
        assert_eq!(err.status, 413);
    }

    #[test]
    fn error_bodies_are_json_with_retry_after() {
        let mut wire = Vec::new();
        write_error(&mut wire, 429, "kv pool exhausted", Some(1)).unwrap();
        let text = String::from_utf8(wire).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"));
        assert!(text.contains("Retry-After: 1\r\n"));
        assert!(text.contains(r#"{"error":"kv pool exhausted"}"#));
    }
}
