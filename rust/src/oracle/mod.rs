//! Task-accuracy oracle (S14): a mechanism-level model of how each
//! attention method succeeds or fails on long-context tasks.
//!
//! The paper's accuracy deltas come from three mechanisms (§4.2, §4.4):
//!
//!  1. **Context fragmentation** — under StarAttn each block is encoded
//!     seeing only the anchor; dependencies that cross blocks are lost.
//!     APB recovers them with probability ≈ the compressor's recall of
//!     the relevant units (the passing block).
//!  2. **Denoising** — compressed passing blocks carry *less distractor
//!     mass* than raw context, so distractor-heavy retrieval (R.KV, MK2/3)
//!     can exceed FULLATTN — the paper's "cleaner passing blocks" effect.
//!  3. **Aggregation loss** — tasks that integrate the whole context
//!     (CWE/FWE/E.Sum) degrade under any pruning, proportional to the
//!     dropped context mass.
//!
//! FULLATTN scores are the paper's own measurements (calibration anchors
//! in ruler::tasks); every other number is derived. We claim ordering and
//! approximate deltas, not absolute cell values (DESIGN.md §2).

use crate::config::ApbOptions;
use crate::ruler::tasks::{ModelCol, TaskProfile};
use crate::util::rng::Rng;

/// Accuracy-relevant method description.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AccMethod {
    /// FlashAttn / RingAttn / Ulysses — identical computation.
    Full,
    MInference,
    StarAttn,
    Apb(ApbQuality),
}

impl AccMethod {
    /// Accuracy model for an executable request (`config::AttnMethod` +
    /// ablation toggles). The exact methods (RingAttn / Dense) compute full
    /// causal attention — the cluster proves their logits match the dense
    /// oracle — so they must score as [`AccMethod::Full`], NOT as an
    /// anchored approximation; the anchored methods (APB / StarAttn) map
    /// onto the APB mechanism model via [`ApbQuality::from_options`].
    pub fn for_options(opts: &ApbOptions, l_a: f64, l_p: f64, l_b: f64) -> AccMethod {
        if opts.method.exact_attention() {
            AccMethod::Full
        } else {
            AccMethod::Apb(ApbQuality::from_options(opts, l_a, l_p, l_b))
        }
    }
}

/// APB mechanism knobs derived from hyperparameters + ablation toggles.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ApbQuality {
    /// P(a query-relevant KV unit survives compression into B^C).
    pub recall: f64,
    /// Anchor block present?
    pub anchor: bool,
    /// Passing blocks present?
    pub passing: bool,
    /// Anchor-coverage saturation in [0,1] (grows with l_a).
    pub anchor_cov: f64,
}

/// Retaining-head recall model: trained + query-aware heads retrieve the
/// relevant units with high probability, saturating in l_p; the random
/// selector only keeps l_p/l_b. Calibrated against the measured recall of
/// our trained heads (aot build logs) and the paper's Table 3 ordering.
pub fn compressor_recall(retaining: bool, query_embedded: bool, l_p: f64, l_b: f64) -> f64 {
    let frac = (l_p / l_b).clamp(0.0, 1.0);
    if !retaining {
        return frac; // random selector
    }
    let ceiling = if query_embedded { 0.88 } else { 0.58 };
    // Saturates once l_p exceeds a few times the relevant-set size
    // (Figure 7: l_p >= 1K is already flat; Table 4: l_p = 0.5K at 32K
    // H=8 still performs).
    let sat = 1.0 - (-l_p / 200.0).exp();
    (ceiling * sat).max(frac)
}

/// Anchor coverage: how much of the "attention sink + document head"
/// context a given anchor length restores. Saturates fast (Figure 7).
pub fn anchor_coverage(l_a: f64) -> f64 {
    1.0 - (-l_a / 300.0).exp()
}

impl ApbQuality {
    /// Mechanism knobs for the ANCHORED methods (APB, or StarAttn as the
    /// `passing = false` ablation). An exact-method request does not fit
    /// this model — route those through [`AccMethod::for_options`], which
    /// maps them to [`AccMethod::Full`].
    pub fn from_options(opts: &ApbOptions, l_a: f64, l_p: f64, l_b: f64) -> ApbQuality {
        ApbQuality {
            recall: compressor_recall(opts.retaining_compressor, opts.embed_query, l_p,
                                      l_b),
            anchor: opts.use_anchor,
            passing: opts.method.passes_compressed_blocks(),
            anchor_cov: anchor_coverage(l_a),
        }
    }

    pub fn paper_default(l_a: f64, l_p: f64, l_b: f64) -> ApbQuality {
        ApbQuality::from_options(&ApbOptions::default(), l_a, l_p, l_b)
    }
}

/// Evaluation context: length and host count (fragmentation exposure).
#[derive(Debug, Clone, Copy)]
pub struct EvalCtx {
    pub n: f64,
    pub hosts: f64,
    pub model: ModelCol,
    pub samples: usize,
    pub seed: u64,
}

/// Fraction of cross-block dependencies that fragmentation destroys under
/// StarAttn: blocks only see the anchor, so on average (H-1)/H of the
/// preceding context is invisible while encoding.
fn fragmentation(hosts: f64) -> f64 {
    ((hosts - 1.0) / hosts).clamp(0.0, 1.0)
}

/// Distractor confusion for block-local encoding when blocks get small:
/// StarAttn's Table 4 degradation at 32K with many hosts.
fn short_block_penalty(l_b: f64) -> f64 {
    (1.0 - l_b / 8192.0).clamp(0.0, 1.0)
}

/// Expected score (0–100) of `method` on `task` under `ctx`.
pub fn expected_score(task: &TaskProfile, method: AccMethod, ctx: &EvalCtx) -> f64 {
    let base = task.base_at(ctx.model, ctx.n);
    let l_b = ctx.n / ctx.hosts;
    let frag = fragmentation(ctx.hosts);
    let score = match method {
        AccMethod::Full => base,
        AccMethod::MInference => {
            // Dense projections, sparse attention: mild retrieval loss,
            // larger aggregation loss; slight "focus" gain on scan-style
            // tasks (M.Find's pattern matches MInference's strengths).
            let agg_loss = 0.42 * task.aggregation;
            let cross_loss = 0.22 * task.cross_block;
            let focus_gain = 0.10 * task.distractor * (1.0 - task.cross_block);
            base * (1.0 - agg_loss - cross_loss) + focus_gain * (100.0 - base) * 0.5
        }
        AccMethod::StarAttn => {
            let dep_loss = task.cross_block * frag * 0.32
                + task.chain * frag * 0.12;
            let distr_conf = 0.20 * task.distractor * short_block_penalty(l_b);
            let agg_loss = 0.08 * task.aggregation * frag;
            base * (1.0 - dep_loss - distr_conf - agg_loss)
        }
        AccMethod::Apb(q) => {
            if !q.anchor {
                // No anchor: the attention sink + document head are
                // invisible; block encodings collapse (Table 3 rows 6–8).
                let residual = if q.passing { 0.12 * q.recall } else { 0.04 };
                return (task.chance + residual * base).clamp(0.0, 100.0);
            }
            let recall = if q.passing { q.recall } else { 0.0 };
            // Unrecovered cross-block dependencies.
            let dep_loss = task.cross_block * frag * (1.0 - recall) * 0.45
                * (2.0 - q.anchor_cov);
            // Multi-hop chains must survive compression at every hop.
            let chain_loss = task.chain * frag
                * (1.0 - recall * recall) * 0.30;
            // Aggregation: pruned context mass is gone either way.
            let agg_loss = 0.12 * task.aggregation * frag;
            // Denoising: retained units arrive without distractor mass.
            let denoise = 0.55 * task.distractor * recall * q.anchor_cov;
            let s = base * (1.0 - dep_loss - chain_loss - agg_loss)
                + denoise * (100.0 - base);
            s.min(100.0)
        }
    };
    score.clamp(task.chance, 100.0)
}

/// Sampled score: binomial noise at the benchmark's sample count, so
/// regenerated tables wobble like real evaluations do.
pub fn sampled_score(task: &TaskProfile, method: AccMethod, ctx: &EvalCtx) -> f64 {
    let p = expected_score(task, method, ctx) / 100.0;
    let mut rng = Rng::new(ctx.seed ^ hash_id(task.id) ^ method_tag(&method));
    let n = ctx.samples.max(1);
    let mut hits = 0usize;
    for _ in 0..n {
        if rng.f64() < p {
            hits += 1;
        }
    }
    100.0 * hits as f64 / n as f64
}

fn hash_id(id: &str) -> u64 {
    id.bytes().fold(1469598103934665603u64, |h, b| {
        (h ^ b as u64).wrapping_mul(1099511628211)
    })
}

fn method_tag(m: &AccMethod) -> u64 {
    match m {
        AccMethod::Full => 1,
        AccMethod::MInference => 2,
        AccMethod::StarAttn => 3,
        AccMethod::Apb(q) => {
            4 ^ ((q.recall * 1e6) as u64) << 8
                ^ ((q.anchor as u64) << 3)
                ^ ((q.passing as u64) << 4)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ruler::tasks::{infbench_tasks, ruler_tasks};

    fn ctx() -> EvalCtx {
        EvalCtx { n: 131072.0, hosts: 8.0, model: ModelCol::Llama,
                  samples: 100_000, seed: 7 }
    }

    fn apb() -> AccMethod {
        AccMethod::Apb(ApbQuality::paper_default(4096.0, 2048.0, 16384.0))
    }

    #[test]
    fn apb_beats_star_on_average_ruler() {
        let c = ctx();
        let tasks = ruler_tasks();
        let avg = |m: AccMethod| {
            tasks.iter().map(|t| expected_score(t, m, &c)).sum::<f64>()
                / tasks.len() as f64
        };
        let full = avg(AccMethod::Full);
        let star = avg(AccMethod::StarAttn);
        let minf = avg(AccMethod::MInference);
        let apb_avg = avg(apb());
        // Paper Table 2 (Llama): Full 82.2, APB 81.6, Star 76.8, MInf 73.0.
        assert!(apb_avg > star, "apb {apb_avg} vs star {star}");
        assert!(apb_avg > minf, "apb {apb_avg} vs minf {minf}");
        assert!((apb_avg - full).abs() < 6.0, "apb {apb_avg} vs full {full}");
        assert!(star < full);
    }

    #[test]
    fn apb_wins_big_on_distractor_retrieval() {
        // R.KV and MK3: the paper's headline accuracy wins (81.8 vs 51.0;
        // 89.0 vs 67.0). The denoising mechanism must push APB above Full.
        let c = ctx();
        for (suite, id) in [("infbench", "R.KV"), ("ruler", "MK3"), ("ruler", "MK2")] {
            let tasks = if suite == "ruler" { ruler_tasks() } else { infbench_tasks() };
            let t = tasks.iter().find(|t| t.id == id).unwrap();
            let full = expected_score(t, AccMethod::Full, &c);
            let apb_s = expected_score(t, apb(), &c);
            let star = expected_score(t, AccMethod::StarAttn, &c);
            assert!(apb_s > full, "{id}: apb {apb_s} !> full {full}");
            assert!(apb_s > star, "{id}: apb {apb_s} !> star {star}");
        }
    }

    #[test]
    fn apb_loses_slightly_on_chained_tracking() {
        // VT: compression drops intermediate hops (paper: 51.96 vs 60.98).
        let c = ctx();
        let tasks = ruler_tasks();
        let vt = tasks.iter().find(|t| t.id == "VT").unwrap();
        let full = expected_score(vt, AccMethod::Full, &c);
        let apb_s = expected_score(vt, apb(), &c);
        assert!(apb_s < full);
        assert!(apb_s > 0.5 * full, "loss should be moderate");
    }

    #[test]
    fn exact_methods_score_as_full_attention() {
        // The accuracy oracle must agree with the executable exactness
        // invariant: a RingAttn/Dense request computes full attention, so
        // it scores exactly as Full — never as an anchored approximation.
        use crate::config::AttnMethod;
        let c = ctx();
        let (l_a, l_p, l_b) = (4096.0, 2048.0, 16384.0);
        let t = infbench_tasks().into_iter().find(|t| t.id == "E.MC").unwrap();
        let full = expected_score(&t, AccMethod::Full, &c);
        for m in [AttnMethod::RingAttn, AttnMethod::Dense] {
            let opts = ApbOptions { method: m, ..Default::default() };
            let acc = AccMethod::for_options(&opts, l_a, l_p, l_b);
            assert_eq!(acc, AccMethod::Full);
            assert_eq!(expected_score(&t, acc, &c), full);
        }
        // Anchored methods keep the mechanism model (Star = no passing).
        let star_opts = ApbOptions { method: AttnMethod::StarAttn, ..Default::default() };
        let star = AccMethod::for_options(&star_opts, l_a, l_p, l_b);
        assert!(matches!(star, AccMethod::Apb(q) if !q.passing && q.anchor));
        let apb = AccMethod::for_options(&ApbOptions::default(), l_a, l_p, l_b);
        assert!(matches!(apb, AccMethod::Apb(q) if q.passing));
    }

    #[test]
    fn ablation_ordering_matches_table3() {
        // Table 3 on E.MC: full APB > no-query > random-C > no-passing >
        // no-anchor (collapse towards chance).
        let c = EvalCtx { hosts: 4.0, ..ctx() }; // l_b = 32K setting
        let t = infbench_tasks().into_iter().find(|t| t.id == "E.MC").unwrap();
        let (l_a, l_p, l_b) = (4096.0, 2048.0, 32768.0);
        let q = |o: ApbOptions| {
            AccMethod::Apb(ApbQuality::from_options(&o, l_a, l_p, l_b))
        };
        let s_full = expected_score(&t, q(ApbOptions::default()), &c);
        let s_noq = expected_score(
            &t, q(ApbOptions { embed_query: false, ..Default::default() }), &c);
        let s_rd = expected_score(
            &t, q(ApbOptions { retaining_compressor: false, ..Default::default() }),
            &c);
        let s_nop = expected_score(
            &t,
            q(ApbOptions {
                method: crate::config::AttnMethod::StarAttn,
                ..Default::default()
            }),
            &c,
        );
        let s_noa = expected_score(
            &t, q(ApbOptions { use_anchor: false, ..Default::default() }), &c);
        assert!(s_full > s_noq, "{s_full} !> {s_noq}");
        assert!(s_noq > s_rd, "{s_noq} !> {s_rd}");
        assert!(s_rd >= s_nop, "{s_rd} !>= {s_nop}");
        assert!(s_nop > s_noa, "{s_nop} !> {s_noa}");
        assert!(s_noa <= t.chance + 12.0, "no-anchor must collapse: {s_noa}");
    }

    #[test]
    fn star_degrades_with_hosts_at_short_length_apb_stable() {
        // Table 4 @32K: Star 94 -> 84 as H goes 2 -> 8; APB stays 92–94.
        let t = infbench_tasks().into_iter().find(|t| t.id == "E.MC").unwrap();
        let score = |m: AccMethod, hosts: f64| {
            let c = EvalCtx { n: 32768.0, hosts, ..ctx() };
            expected_score(&t, m, &c)
        };
        let star2 = score(AccMethod::StarAttn, 2.0);
        let star8 = score(AccMethod::StarAttn, 8.0);
        assert!(star8 < star2 - 2.0, "star {star2} -> {star8}");
        let q = ApbQuality::paper_default(1024.0, 512.0, 32768.0 / 8.0);
        let apb2 = score(AccMethod::Apb(q), 2.0);
        let apb8 = score(AccMethod::Apb(q), 8.0);
        // Paper's claim is *relative* stability: APB's degradation must be
        // clearly smaller than StarAttn's, and APB stays on top at H=8.
        let apb_drop = apb2 - apb8;
        let star_drop = star2 - star8;
        assert!(apb_drop < 0.75 * star_drop,
                "apb drop {apb_drop} vs star drop {star_drop}");
        assert!(apb8 > star8, "apb {apb8} !> star {star8}");
    }

    #[test]
    fn recall_model_properties() {
        // Trained >> random; query-embedding matters; saturates in l_p.
        let r_full = compressor_recall(true, true, 2048.0, 16384.0);
        let r_noq = compressor_recall(true, false, 2048.0, 16384.0);
        let r_rand = compressor_recall(false, true, 2048.0, 16384.0);
        assert!(r_full > r_noq && r_noq > r_rand);
        assert!((r_rand - 0.125).abs() < 1e-9);
        let r1 = compressor_recall(true, true, 1024.0, 16384.0);
        let r4 = compressor_recall(true, true, 4096.0, 16384.0);
        assert!(r4 - r1 < 0.12, "saturating: {r1} -> {r4} (Figure 7)");
    }

    #[test]
    fn sampled_score_concentrates_on_expected() {
        let c = ctx();
        let t = &ruler_tasks()[0];
        let e = expected_score(t, apb(), &c);
        let s = sampled_score(t, apb(), &c);
        assert!((s - e).abs() < 1.0, "sampled {s} vs expected {e}");
    }
}
