//! # APB — distributed long-context inference, reproduced in Rust+JAX+Pallas
//!
//! Reproduction of *"APB: Accelerating Distributed Long-Context Inference
//! by Passing Compressed Context Blocks across GPUs"* (ACL 2025) as a
//! three-layer stack:
//!
//! * **L1** (`python/compile/kernels/`): the APB modified-mask
//!   FlashAttention and retaining-head compressor as Pallas kernels
//!   (interpret=True), validated against pure-jnp oracles;
//! * **L2** (`python/compile/model.py`): a Llama-architecture model whose
//!   per-host stage functions are AOT-lowered to HLO text;
//! * **L3** (this crate): the distributed coordinator — per-layer prefill
//!   orchestration with compressed-block AllGather, distributed decode
//!   with online-softmax merge, KV-cache management, scheduling — plus the
//!   analytical performance model, synthetic benchmarks and the paper's
//!   table/figure harnesses.
//!
//! ## Execution backends
//!
//! The coordinator runs every per-host stage through the
//! [`runtime::ExecBackend`] trait; which implementation backs a cluster is
//! chosen by [`config::Config::backend`]:
//!
//! * **`SimEngine`** (default build): a pure-Rust engine that natively
//!   executes the tiny-model stages — embed → APB-masked attention →
//!   SwiGLU MLP → LM head — with deterministic synthetic weights derived
//!   from [`util::rng`]. `Cluster::start(&Config::sim_tiny())` runs the
//!   full Algorithm-2 prefill (top-l_p selection, AllGather of compressed
//!   blocks, passing-block assembly) and Algorithm-3 decode (per-host LSE +
//!   online-softmax merge) with **no Python, no XLA and no artifacts** —
//!   this is what CI and a clean checkout exercise.
//! * **PJRT** (`--features pjrt`, plus a vendored `xla` crate): compiles
//!   the HLO-text artifacts emitted once by `make artifacts`
//!   (python/compile/aot.py) and replays them against golden files.
//!   Python never runs on the request path either way.
//!
//! [`load_config`] loads an artifact config strictly (and therefore only
//! succeeds on `pjrt` builds); [`load_config_or_sim`] falls back to the
//! self-contained [`config::Config::sim_tiny`] so examples and benches run
//! everywhere.
//!
//! ## Attention methods
//!
//! The paper's comparison set runs as executable cluster modes behind
//! [`config::AttnMethod`] (`Apb`, `StarAttn`, `RingAttn`, `Dense`), routed
//! through the whole [`coordinator`] stack, so comm volumes and exactness
//! are *measured*, not just modelled by [`attnsim`]. See
//! `docs/architecture.md` for the method matrix and
//! `docs/ADR-001-attn-methods.md` for the rationale.
//!
//! See DESIGN.md for the system inventory and the per-experiment index.

pub mod attnsim;
pub mod bench_harness;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod http;
pub mod kvcache;
pub mod oracle;
pub mod report;
pub mod ruler;
pub mod runtime;
pub mod util;
pub mod workload;

use std::path::PathBuf;

/// Resolve the artifacts directory for a named config: `$APB_ARTIFACTS`
/// or `<repo-root>/artifacts`, then `/<name>`.
pub fn artifacts_dir(name: &str) -> PathBuf {
    let base = std::env::var("APB_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| {
            // Walk up from the executable/cwd to find `artifacts/`.
            let cwd = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
            let mut dir = cwd.as_path();
            loop {
                let cand = dir.join("artifacts");
                if cand.is_dir() {
                    return cand;
                }
                match dir.parent() {
                    Some(p) => dir = p,
                    None => return cwd.join("artifacts"),
                }
            }
        });
    base.join(name)
}

/// Load an artifact config by name (strict: requires `make artifacts` AND a
/// `pjrt` build, since artifact configs are bound to the PJRT backend).
pub fn load_config(name: &str) -> anyhow::Result<config::Config> {
    let dir = artifacts_dir(name);
    let cfg = config::Config::load(&dir)?;
    if cfg!(feature = "pjrt") {
        Ok(cfg)
    } else {
        anyhow::bail!(
            "artifacts at {} need the PJRT backend, but this build has no `pjrt` \
             feature; use load_config_or_sim(\"{name}\") for the native SimEngine",
            dir.display()
        )
    }
}

/// Load the artifact config when it is present and usable, otherwise fall
/// back to the self-contained SimEngine tiny config — the default path for
/// examples, benches and CI, which carry no artifacts.
///
/// The fallback only applies to the default config names (`tiny`, `sim`,
/// `sim-tiny`) and is announced on stderr, so "measured" outputs stay
/// attributable to the config that actually ran; an explicitly requested
/// unknown config stays a hard error instead of silently substituting a
/// different model/topology.
pub fn load_config_or_sim(name: &str) -> anyhow::Result<config::Config> {
    match load_config(name) {
        Ok(cfg) => Ok(cfg),
        Err(e) if matches!(name, "tiny" | "sim" | "sim-tiny") => {
            eprintln!(
                "[apb] artifacts for '{name}' unavailable ({e:#}); \
                 using the native sim-tiny config"
            );
            Ok(config::Config::sim_tiny())
        }
        Err(e) => Err(e),
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn load_config_or_sim_falls_back_for_default_names_only() {
        let cfg = crate::load_config_or_sim("tiny").expect("default name falls back");
        assert!(cfg.apb.n_hosts >= 2, "sim config must exercise passing");
        // Explicitly requested unknown configs stay hard errors.
        assert!(crate::load_config_or_sim("definitely-not-built").is_err());
    }
}
