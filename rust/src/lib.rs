//! # APB — distributed long-context inference, reproduced in Rust+JAX+Pallas
//!
//! Reproduction of *"APB: Accelerating Distributed Long-Context Inference
//! by Passing Compressed Context Blocks across GPUs"* (ACL 2025) as a
//! three-layer stack:
//!
//! * **L1** (`python/compile/kernels/`): the APB modified-mask
//!   FlashAttention and retaining-head compressor as Pallas kernels
//!   (interpret=True), validated against pure-jnp oracles;
//! * **L2** (`python/compile/model.py`): a Llama-architecture model whose
//!   per-host stage functions are AOT-lowered to HLO text;
//! * **L3** (this crate): the distributed coordinator — per-layer prefill
//!   orchestration with compressed-block AllGather, distributed decode
//!   with online-softmax merge, KV-cache management, scheduling — plus the
//!   analytical performance model, synthetic benchmarks and the paper's
//!   table/figure harnesses.
//!
//! Python never runs on the request path: `make artifacts` emits HLO text
//! + weights once, and this crate executes them via PJRT (`xla` crate).
//!
//! See DESIGN.md for the system inventory and the per-experiment index.

pub mod attnsim;
pub mod bench_harness;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod kvcache;
pub mod oracle;
pub mod report;
pub mod ruler;
pub mod runtime;
pub mod util;

use std::path::PathBuf;

/// Resolve the artifacts directory for a named config: `$APB_ARTIFACTS`
/// or `<repo-root>/artifacts`, then `/<name>`.
pub fn artifacts_dir(name: &str) -> PathBuf {
    let base = std::env::var("APB_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| {
            // Walk up from the executable/cwd to find `artifacts/`.
            let cwd = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
            let mut dir = cwd.as_path();
            loop {
                let cand = dir.join("artifacts");
                if cand.is_dir() {
                    return cand;
                }
                match dir.parent() {
                    Some(p) => dir = p,
                    None => return cwd.join("artifacts"),
                }
            }
        });
    base.join(name)
}

/// Load a config by name from the artifacts directory.
pub fn load_config(name: &str) -> anyhow::Result<config::Config> {
    config::Config::load(&artifacts_dir(name))
}
