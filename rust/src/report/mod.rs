//! Report emission: every bench prints its paper-style table/figure AND
//! appends a machine-readable JSON record under `target/apb-reports/`, so
//! the committed bench artifacts (`BENCH_prefill.json`,
//! `BENCH_runtime.json`, `BENCH_serving.json`, `BENCH_decode.json`) cite
//! stable, regenerable sources.

use std::io::Write;
use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::util::json::{self, Json};

pub fn reports_dir() -> PathBuf {
    let dir = std::env::var("APB_REPORTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("target/apb-reports"));
    let _ = std::fs::create_dir_all(&dir);
    dir
}

/// Write one experiment record: `{experiment, meta, rows}`.
pub fn write_report(experiment: &str, meta: Vec<(&str, Json)>, rows: Json) -> Result<PathBuf> {
    let path = reports_dir().join(format!("{experiment}.json"));
    let mut obj = vec![("experiment", json::s(experiment))];
    obj.extend(meta);
    obj.push(("rows", rows));
    let mut f = std::fs::File::create(&path)
        .with_context(|| format!("creating {}", path.display()))?;
    f.write_all(json::obj(obj).pretty().as_bytes())?;
    Ok(path)
}

/// Row helper: ordered (key, value) pairs.
pub fn row(pairs: Vec<(&str, Json)>) -> Json {
    json::obj(pairs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_roundtrip() {
        std::env::set_var("APB_REPORTS", std::env::temp_dir().join("apb-rep-test"));
        let path = write_report(
            "unit_test",
            vec![("n", json::num(128.0))],
            json::arr(vec![row(vec![("method", json::s("APB")),
                                    ("speed", json::num(9.2))])]),
        )
        .unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let v = Json::parse(&text).unwrap();
        assert_eq!(v.get("experiment").unwrap().as_str(), Some("unit_test"));
        assert_eq!(
            v.get("rows").unwrap().as_arr().unwrap()[0]
                .get("speed")
                .unwrap()
                .as_f64(),
            Some(9.2)
        );
        std::env::remove_var("APB_REPORTS");
    }
}
