//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Used by every `benches/*.rs` target (`cargo bench`, harness = false).
//! Provides warmup + timed iterations with summary statistics, and a
//! paper-style table renderer so each bench prints the rows of the table
//! or figure it regenerates.

use std::time::Instant;

use crate::util::stats::{summarize, Summary};

pub struct Bencher {
    pub warmup_iters: usize,
    pub iters: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { warmup_iters: 2, iters: 10 }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher { warmup_iters: 1, iters: 3 }
    }

    /// Time `f`, returning per-iteration seconds summary.
    pub fn run<F: FnMut()>(&self, mut f: F) -> Summary {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        summarize(&samples)
    }

    /// Time `f` with an untimed `setup` before EVERY iteration (warmup and
    /// timed alike). This is how a bench excludes state preparation from
    /// the measurement: e.g. a decode bench re-prefills in `setup` so the
    /// timed body is decode steps only.
    pub fn run_with_setup<S: FnMut(), F: FnMut()>(&self, mut setup: S, mut f: F) -> Summary {
        for _ in 0..self.warmup_iters {
            setup();
            f();
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            setup();
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        summarize(&samples)
    }

    pub fn report<F: FnMut()>(&self, name: &str, f: F) -> Summary {
        let s = self.run(f);
        println!(
            "{name:<44} mean {:>10} ±{:>9}  p50 {:>10}  (n={})",
            crate::util::stats::fmt_duration(s.mean),
            crate::util::stats::fmt_duration(s.std),
            crate::util::stats::fmt_duration(s.p50),
            s.n
        );
        s
    }
}

/// Honour `APB_BENCH_FAST=1` for CI-speed runs of the bench suite.
pub fn default_bencher() -> Bencher {
    if std::env::var("APB_BENCH_FAST").as_deref() == Ok("1") {
        Bencher::quick()
    } else {
        Bencher::default()
    }
}

/// Paper-style fixed-width table printer.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "table row arity");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        let line = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| {
                    if i == 0 {
                        format!("{:<w$}", c, w = widths[i])
                    } else {
                        format!("{:>w$}", c, w = widths[i])
                    }
                })
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&line(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// ASCII scatter/line plot for figure-style benches (speed vs length etc.).
pub struct AsciiPlot {
    pub title: String,
    pub width: usize,
    pub height: usize,
    series: Vec<(String, Vec<(f64, f64)>)>,
}

impl AsciiPlot {
    pub fn new(title: &str) -> Self {
        AsciiPlot { title: title.to_string(), width: 72, height: 20, series: Vec::new() }
    }

    pub fn series(&mut self, name: &str, points: Vec<(f64, f64)>) {
        self.series.push((name.to_string(), points));
    }

    pub fn render(&self) -> String {
        let marks = ['*', 'o', '+', 'x', '#', '@', '%', '&'];
        let all: Vec<(f64, f64)> = self.series.iter().flat_map(|s| s.1.clone()).collect();
        if all.is_empty() {
            return format!("== {} == (no data)\n", self.title);
        }
        let (mut x0, mut x1) = (f64::INFINITY, f64::NEG_INFINITY);
        let (mut y0, mut y1) = (f64::INFINITY, f64::NEG_INFINITY);
        for &(x, y) in &all {
            x0 = x0.min(x);
            x1 = x1.max(x);
            y0 = y0.min(y);
            y1 = y1.max(y);
        }
        if (x1 - x0).abs() < 1e-12 {
            x1 = x0 + 1.0;
        }
        if (y1 - y0).abs() < 1e-12 {
            y1 = y0 + 1.0;
        }
        let mut grid = vec![vec![' '; self.width]; self.height];
        for (si, (_, pts)) in self.series.iter().enumerate() {
            for &(x, y) in pts {
                let cx = ((x - x0) / (x1 - x0) * (self.width - 1) as f64).round() as usize;
                let cy = ((y - y0) / (y1 - y0) * (self.height - 1) as f64).round() as usize;
                grid[self.height - 1 - cy][cx] = marks[si % marks.len()];
            }
        }
        let mut out = format!("\n== {} ==\n", self.title);
        out.push_str(&format!("y: [{y0:.3e}, {y1:.3e}]\n"));
        for row in grid {
            out.push('|');
            out.extend(row);
            out.push('\n');
        }
        out.push('+');
        out.push_str(&"-".repeat(self.width));
        out.push('\n');
        out.push_str(&format!("x: [{x0:.3e}, {x1:.3e}]\n"));
        for (si, (name, _)) in self.series.iter().enumerate() {
            out.push_str(&format!("  {} {}\n", marks[si % marks.len()], name));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bencher_returns_sane_timings() {
        let b = Bencher { warmup_iters: 1, iters: 5 };
        let s = b.run(|| {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(s.n, 5);
        assert!(s.mean > 0.0 && s.mean < 1.0);
        assert!(s.min <= s.p50 && s.p50 <= s.max);
    }

    #[test]
    fn run_with_setup_runs_setup_before_every_iteration() {
        let b = Bencher { warmup_iters: 2, iters: 5 };
        let mut setups = 0usize;
        let mut bodies = 0usize;
        let s = b.run_with_setup(|| setups += 1, || bodies += 1);
        assert_eq!(s.n, 5);
        assert_eq!(setups, 7, "setup precedes warmup and timed iterations");
        assert_eq!(bodies, 7);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("T", &["method", "speed"]);
        t.row(vec!["APB".into(), "9.2x".into()]);
        t.row(vec!["StarAttn".into(), "1.6x".into()]);
        let r = t.render();
        assert!(r.contains("== T =="));
        assert!(r.contains("APB"));
        assert!(r.lines().count() >= 5);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn table_rejects_bad_row() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn plot_renders() {
        let mut p = AsciiPlot::new("speed");
        p.series("apb", vec![(1.0, 2.0), (2.0, 4.0)]);
        p.series("star", vec![(1.0, 1.0), (2.0, 2.0)]);
        let r = p.render();
        assert!(r.contains("speed"));
        assert!(r.contains('*') && r.contains('o'));
    }
}
