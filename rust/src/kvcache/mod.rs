//! Per-host KV-cache management.
//!
//! [`KvCache`] holds one padded [cache_max, kv_heads, head_dim] K and V
//! tensor per layer plus the valid length — what Algorithm 2 appends during
//! prefill (the local block only; anchor and passing KV are discarded) and
//! what Algorithm 3 reads and (on the last host) extends during decode.
//!
//! [`KvCache::append`] is deliberately incremental — the **chunk-append
//! API**: chunked prefill (`coordinator::prefill`) grows a session's KV a
//! few rows per `PrefillChunk` step, and the final contents are
//! byte-identical to a one-shot prefill's because appends are ordered and
//! the padded capacity is fixed up front. [`KvPool::stats`] exposes the
//! accounting the chunk-split invariance tests compare.
//!
//! [`KvPool`] turns that single implicit request into multi-request
//! residency: a fixed set of `KvCache` slots keyed by [`SessionId`], with
//! byte-accounted alloc/free and an explicit exhaustion error so slot
//! pressure surfaces as scheduler backpressure, never as corruption.
//!
//! Slot capacity depends on the cluster's attention method
//! (`config::ApbParams::cache_rows`): the distributed modes (APB /
//! StarAttn / RingAttn) hold at most a local block plus the decode tail
//! per session, while `AttnMethod::Dense` concentrates the whole
//! `[query | document]` sequence in host 0's slot. The host worker sizes
//! every pool from `Config::method` accordingly.

use anyhow::{bail, Result};

use crate::util::tensor::Tensor;

/// Identity of one serving session (request) resident on the cluster.
pub type SessionId = u64;

/// Point-in-time byte accounting of one host's pool — the observable the
/// chunk-split invariance proptest compares across chunk partitions, and
/// what `apb serve` ops dashboards read (`Cluster::pool_stats`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolStats {
    /// Sessions currently holding a slot.
    pub resident: usize,
    /// Bytes resident across occupied slots (valid KV rows only).
    pub bytes_used: usize,
    /// Bytes reserved by the whole pool (padded capacity of every slot).
    pub bytes_reserved: usize,
}

#[derive(Debug, Clone)]
pub struct LayerCache {
    pub k: Tensor,
    pub v: Tensor,
    pub len: usize,
}

#[derive(Debug)]
pub struct KvCache {
    pub layers: Vec<LayerCache>,
    pub cache_max: usize,
}

impl KvCache {
    pub fn new(n_layers: usize, cache_max: usize, kv_heads: usize, head_dim: usize) -> Self {
        let layers = (0..n_layers)
            .map(|_| LayerCache {
                k: Tensor::zeros(vec![cache_max, kv_heads, head_dim]),
                v: Tensor::zeros(vec![cache_max, kv_heads, head_dim]),
                len: 0,
            })
            .collect();
        KvCache { layers, cache_max }
    }

    pub fn len(&self, layer: usize) -> usize {
        self.layers[layer].len
    }

    pub fn is_empty(&self) -> bool {
        self.layers.iter().all(|l| l.len == 0)
    }

    /// Append `k`/`v` rows ([n, kh, hd]) to a layer. Errors on overflow —
    /// the scheduler's admission control must prevent this.
    pub fn append(&mut self, layer: usize, k: &Tensor, v: &Tensor) -> Result<()> {
        let lc = &mut self.layers[layer];
        let n = k.shape[0];
        if lc.len + n > self.cache_max {
            bail!(
                "kv cache overflow: layer {layer} len {} + {n} > cap {}",
                lc.len,
                self.cache_max
            );
        }
        lc.k.write_rows(lc.len, k);
        lc.v.write_rows(lc.len, v);
        lc.len += n;
        Ok(())
    }

    /// Reset all layers (request eviction).
    pub fn clear(&mut self) {
        for lc in &mut self.layers {
            lc.len = 0;
        }
    }

    /// Bytes currently resident (valid region only).
    pub fn bytes_used(&self) -> usize {
        self.layers
            .iter()
            .map(|l| 2 * l.len * l.k.row_len() * 4)
            .sum()
    }

    /// Bytes reserved (padded capacity).
    pub fn bytes_reserved(&self) -> usize {
        self.layers
            .iter()
            .map(|l| 2 * l.k.numel() * 4)
            .sum()
    }
}

struct Slot {
    sid: Option<SessionId>,
    cache: KvCache,
}

/// Fixed-capacity pool of per-session KV caches (one per residency slot).
///
/// Every host owns one pool sized `ApbParams::max_resident`; a session's
/// cache lives in its slot from prefill until `free`, so several requests
/// can hold KV on the cluster simultaneously (continuous batching).
pub struct KvPool {
    slots: Vec<Slot>,
}

impl KvPool {
    pub fn new(
        n_slots: usize,
        n_layers: usize,
        cache_max: usize,
        kv_heads: usize,
        head_dim: usize,
    ) -> Self {
        let slots = (0..n_slots.max(1))
            .map(|_| Slot {
                sid: None,
                cache: KvCache::new(n_layers, cache_max, kv_heads, head_dim),
            })
            .collect();
        KvPool { slots }
    }

    pub fn n_slots(&self) -> usize {
        self.slots.len()
    }

    /// Sessions currently holding a slot.
    pub fn resident(&self) -> usize {
        self.slots.iter().filter(|s| s.sid.is_some()).count()
    }

    pub fn resident_sids(&self) -> Vec<SessionId> {
        self.slots.iter().filter_map(|s| s.sid).collect()
    }

    pub fn contains(&self, sid: SessionId) -> bool {
        self.slots.iter().any(|s| s.sid == Some(sid))
    }

    /// Claim a slot for `sid`, returning its (cleared) cache. Re-allocating
    /// a resident session resets its cache in place (a fresh prefill of the
    /// same session id). Errors — without touching any resident cache —
    /// when every slot is occupied by another session.
    pub fn alloc(&mut self, sid: SessionId) -> Result<&mut KvCache> {
        if let Some(i) = self.slots.iter().position(|s| s.sid == Some(sid)) {
            self.slots[i].cache.clear();
            return Ok(&mut self.slots[i].cache);
        }
        let Some(i) = self.slots.iter().position(|s| s.sid.is_none()) else {
            bail!(
                "kv pool exhausted ({}/{} slots resident): backpressure — \
                 free a session before admitting another",
                self.slots.len(),
                self.slots.len()
            );
        };
        self.slots[i].sid = Some(sid);
        self.slots[i].cache.clear();
        Ok(&mut self.slots[i].cache)
    }

    pub fn get(&self, sid: SessionId) -> Result<&KvCache> {
        self.slots
            .iter()
            .find(|s| s.sid == Some(sid))
            .map(|s| &s.cache)
            .ok_or_else(|| anyhow::anyhow!("session {sid} not resident in kv pool"))
    }

    pub fn get_mut(&mut self, sid: SessionId) -> Result<&mut KvCache> {
        self.slots
            .iter_mut()
            .find(|s| s.sid == Some(sid))
            .map(|s| &mut s.cache)
            .ok_or_else(|| anyhow::anyhow!("session {sid} not resident in kv pool"))
    }

    /// Release `sid`'s slot (no-op when absent). Returns whether a slot was
    /// actually freed.
    pub fn free(&mut self, sid: SessionId) -> bool {
        match self.slots.iter_mut().find(|s| s.sid == Some(sid)) {
            Some(s) => {
                s.sid = None;
                s.cache.clear();
                true
            }
            None => false,
        }
    }

    pub fn clear_all(&mut self) {
        for s in &mut self.slots {
            s.sid = None;
            s.cache.clear();
        }
    }

    /// Bytes resident across occupied slots (valid regions only).
    pub fn bytes_used(&self) -> usize {
        self.slots
            .iter()
            .filter(|s| s.sid.is_some())
            .map(|s| s.cache.bytes_used())
            .sum()
    }

    /// Bytes reserved by the whole pool (padded capacity of every slot).
    pub fn bytes_reserved(&self) -> usize {
        self.slots.iter().map(|s| s.cache.bytes_reserved()).sum()
    }

    /// Snapshot of this pool's residency/byte accounting.
    pub fn stats(&self) -> PoolStats {
        PoolStats {
            resident: self.resident(),
            bytes_used: self.bytes_used(),
            bytes_reserved: self.bytes_reserved(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rows(n: usize, kh: usize, hd: usize, base: f32) -> Tensor {
        let data = (0..n * kh * hd).map(|i| base + i as f32).collect();
        Tensor::new(vec![n, kh, hd], data).unwrap()
    }

    #[test]
    fn append_and_read_back() {
        let mut c = KvCache::new(2, 8, 2, 4);
        assert!(c.is_empty());
        c.append(0, &rows(3, 2, 4, 0.0), &rows(3, 2, 4, 100.0)).unwrap();
        c.append(0, &rows(2, 2, 4, 50.0), &rows(2, 2, 4, 150.0)).unwrap();
        assert_eq!(c.len(0), 5);
        assert_eq!(c.len(1), 0);
        // First appended row intact.
        assert_eq!(c.layers[0].k.slice_rows(0, 3), rows(3, 2, 4, 0.0));
        assert_eq!(c.layers[0].k.slice_rows(3, 5), rows(2, 2, 4, 50.0));
        assert_eq!(c.layers[0].v.slice_rows(3, 5), rows(2, 2, 4, 150.0));
    }

    #[test]
    fn overflow_rejected() {
        let mut c = KvCache::new(1, 4, 1, 2);
        c.append(0, &rows(3, 1, 2, 0.0), &rows(3, 1, 2, 0.0)).unwrap();
        assert!(c.append(0, &rows(2, 1, 2, 0.0), &rows(2, 1, 2, 0.0)).is_err());
        // Failed append must not corrupt length.
        assert_eq!(c.len(0), 3);
    }

    #[test]
    fn clear_resets() {
        let mut c = KvCache::new(1, 4, 1, 2);
        c.append(0, &rows(2, 1, 2, 0.0), &rows(2, 1, 2, 0.0)).unwrap();
        assert!(c.bytes_used() > 0);
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.bytes_used(), 0);
        assert_eq!(c.bytes_reserved(), 2 * 4 * 1 * 2 * 4);
    }

    #[test]
    fn pool_alloc_get_free_roundtrip() {
        let mut p = KvPool::new(2, 1, 4, 1, 2);
        assert_eq!(p.n_slots(), 2);
        assert_eq!(p.resident(), 0);
        p.alloc(7).unwrap().append(0, &rows(2, 1, 2, 0.0), &rows(2, 1, 2, 9.0)).unwrap();
        p.alloc(8).unwrap();
        assert_eq!(p.resident(), 2);
        assert!(p.contains(7) && p.contains(8) && !p.contains(9));
        assert_eq!(p.get(7).unwrap().len(0), 2);
        assert_eq!(p.get(8).unwrap().len(0), 0);
        assert!(p.free(7));
        assert!(!p.free(7), "double free is a no-op");
        assert_eq!(p.resident(), 1);
        assert!(p.get(7).is_err());
    }

    #[test]
    fn pool_exhaustion_errors_without_corruption() {
        let mut p = KvPool::new(1, 1, 4, 1, 2);
        p.alloc(1).unwrap().append(0, &rows(3, 1, 2, 5.0), &rows(3, 1, 2, 6.0)).unwrap();
        let err = p.alloc(2).unwrap_err();
        assert!(format!("{err:#}").contains("backpressure"));
        // The resident session's cache is untouched by the failed alloc.
        assert_eq!(p.get(1).unwrap().len(0), 3);
        assert_eq!(p.get(1).unwrap().layers[0].k.slice_rows(0, 3), rows(3, 1, 2, 5.0));
    }

    #[test]
    fn pool_realloc_resets_in_place() {
        let mut p = KvPool::new(1, 1, 4, 1, 2);
        p.alloc(3).unwrap().append(0, &rows(2, 1, 2, 0.0), &rows(2, 1, 2, 0.0)).unwrap();
        assert_eq!(p.get(3).unwrap().len(0), 2);
        // Fresh prefill of the same session id starts from an empty cache.
        assert_eq!(p.alloc(3).unwrap().len(0), 0);
        assert_eq!(p.resident(), 1);
    }

    #[test]
    fn pool_stats_snapshot() {
        let mut p = KvPool::new(2, 1, 4, 1, 2);
        assert_eq!(p.stats(),
                   PoolStats { resident: 0, bytes_used: 0,
                               bytes_reserved: 2 * (2 * 4 * 1 * 2 * 4) });
        p.alloc(1).unwrap().append(0, &rows(2, 1, 2, 0.0), &rows(2, 1, 2, 0.0)).unwrap();
        let s = p.stats();
        assert_eq!(s.resident, 1);
        assert_eq!(s.bytes_used, p.bytes_used());
    }

    #[test]
    fn pool_byte_accounting() {
        let mut p = KvPool::new(2, 1, 4, 1, 2);
        assert_eq!(p.bytes_used(), 0);
        assert_eq!(p.bytes_reserved(), 2 * (2 * 4 * 1 * 2 * 4));
        p.alloc(1).unwrap().append(0, &rows(2, 1, 2, 0.0), &rows(2, 1, 2, 0.0)).unwrap();
        let one = p.bytes_used();
        assert_eq!(one, 2 * 2 * 2 * 4);
        p.alloc(2).unwrap().append(0, &rows(1, 1, 2, 0.0), &rows(1, 1, 2, 0.0)).unwrap();
        assert_eq!(p.bytes_used(), one + 2 * 2 * 4);
        p.clear_all();
        assert_eq!(p.bytes_used(), 0);
        assert_eq!(p.resident(), 0);
    }
}
