//! Per-host KV-cache manager.
//!
//! Holds one padded [cache_max, kv_heads, head_dim] K and V tensor per
//! layer plus the valid length — what Algorithm 2 appends during prefill
//! (the local block only; anchor and passing KV are discarded) and what
//! Algorithm 3 reads and (on the last host) extends during decode.

use anyhow::{bail, Result};

use crate::util::tensor::Tensor;

#[derive(Debug, Clone)]
pub struct LayerCache {
    pub k: Tensor,
    pub v: Tensor,
    pub len: usize,
}

#[derive(Debug)]
pub struct KvCache {
    pub layers: Vec<LayerCache>,
    pub cache_max: usize,
}

impl KvCache {
    pub fn new(n_layers: usize, cache_max: usize, kv_heads: usize, head_dim: usize) -> Self {
        let layers = (0..n_layers)
            .map(|_| LayerCache {
                k: Tensor::zeros(vec![cache_max, kv_heads, head_dim]),
                v: Tensor::zeros(vec![cache_max, kv_heads, head_dim]),
                len: 0,
            })
            .collect();
        KvCache { layers, cache_max }
    }

    pub fn len(&self, layer: usize) -> usize {
        self.layers[layer].len
    }

    pub fn is_empty(&self) -> bool {
        self.layers.iter().all(|l| l.len == 0)
    }

    /// Append `k`/`v` rows ([n, kh, hd]) to a layer. Errors on overflow —
    /// the scheduler's admission control must prevent this.
    pub fn append(&mut self, layer: usize, k: &Tensor, v: &Tensor) -> Result<()> {
        let lc = &mut self.layers[layer];
        let n = k.shape[0];
        if lc.len + n > self.cache_max {
            bail!(
                "kv cache overflow: layer {layer} len {} + {n} > cap {}",
                lc.len,
                self.cache_max
            );
        }
        lc.k.write_rows(lc.len, k);
        lc.v.write_rows(lc.len, v);
        lc.len += n;
        Ok(())
    }

    /// Reset all layers (request eviction).
    pub fn clear(&mut self) {
        for lc in &mut self.layers {
            lc.len = 0;
        }
    }

    /// Bytes currently resident (valid region only).
    pub fn bytes_used(&self) -> usize {
        self.layers
            .iter()
            .map(|l| 2 * l.len * l.k.row_len() * 4)
            .sum()
    }

    /// Bytes reserved (padded capacity).
    pub fn bytes_reserved(&self) -> usize {
        self.layers
            .iter()
            .map(|l| 2 * l.k.numel() * 4)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rows(n: usize, kh: usize, hd: usize, base: f32) -> Tensor {
        let data = (0..n * kh * hd).map(|i| base + i as f32).collect();
        Tensor::new(vec![n, kh, hd], data).unwrap()
    }

    #[test]
    fn append_and_read_back() {
        let mut c = KvCache::new(2, 8, 2, 4);
        assert!(c.is_empty());
        c.append(0, &rows(3, 2, 4, 0.0), &rows(3, 2, 4, 100.0)).unwrap();
        c.append(0, &rows(2, 2, 4, 50.0), &rows(2, 2, 4, 150.0)).unwrap();
        assert_eq!(c.len(0), 5);
        assert_eq!(c.len(1), 0);
        // First appended row intact.
        assert_eq!(c.layers[0].k.slice_rows(0, 3), rows(3, 2, 4, 0.0));
        assert_eq!(c.layers[0].k.slice_rows(3, 5), rows(2, 2, 4, 50.0));
        assert_eq!(c.layers[0].v.slice_rows(3, 5), rows(2, 2, 4, 150.0));
    }

    #[test]
    fn overflow_rejected() {
        let mut c = KvCache::new(1, 4, 1, 2);
        c.append(0, &rows(3, 1, 2, 0.0), &rows(3, 1, 2, 0.0)).unwrap();
        assert!(c.append(0, &rows(2, 1, 2, 0.0), &rows(2, 1, 2, 0.0)).is_err());
        // Failed append must not corrupt length.
        assert_eq!(c.len(0), 3);
    }

    #[test]
    fn clear_resets() {
        let mut c = KvCache::new(1, 4, 1, 2);
        c.append(0, &rows(2, 1, 2, 0.0), &rows(2, 1, 2, 0.0)).unwrap();
        assert!(c.bytes_used() > 0);
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.bytes_used(), 0);
        assert_eq!(c.bytes_reserved(), 2 * 4 * 1 * 2 * 4);
    }
}
