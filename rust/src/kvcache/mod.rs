//! Per-host KV-cache management.
//!
//! [`KvCache`] holds one padded [cache_max, kv_heads, head_dim] K and V
//! tensor per layer plus the valid length — what Algorithm 2 appends during
//! prefill (the local block only; anchor and passing KV are discarded) and
//! what Algorithm 3 reads and (on the last host) extends during decode.
//!
//! [`KvCache::append`] is deliberately incremental — the **chunk-append
//! API**: chunked prefill (`coordinator::prefill`) grows a session's KV a
//! few rows per `PrefillChunk` step, and the final contents are
//! byte-identical to a one-shot prefill's because appends are ordered and
//! the padded capacity is fixed up front. [`KvPool::stats`] exposes the
//! accounting the chunk-split invariance tests compare.
//!
//! [`KvPool`] turns that single implicit request into multi-request
//! residency: a fixed set of `KvCache` slots keyed by [`SessionId`], with
//! byte-accounted alloc/free and an explicit exhaustion error so slot
//! pressure surfaces as scheduler backpressure, never as corruption.
//!
//! Slot capacity depends on the cluster's attention method
//! (`config::ApbParams::cache_rows`): the distributed modes (APB /
//! StarAttn / RingAttn) hold at most a local block plus the decode tail
//! per session, while `AttnMethod::Dense` concentrates the whole
//! `[query | document]` sequence in host 0's slot. The host worker sizes
//! every pool from `Config::method` accordingly.
//!
//! # Shared-prefix KV reuse (`docs/ADR-003-prefix-caching.md`)
//!
//! The dominant multi-tenant pattern is many requests over one corpus.
//! When `config::ApbParams::prefix_cache` is on, each pool also owns a
//! **prefix store**: a cold prefill freezes the document KV it appended
//! into an immutable, refcounted [`SharedPrefix`] entry keyed by
//! [`prefix_digest`], and a later request with the same digest *attaches*
//! to that entry instead of recomputing — its [`KvCache`] becomes a
//! `[shared | private]` pair where the shared segment is the entry (read
//! via `Arc`, never copied or mutated) and the private tail receives the
//! query-chunk and decode rows copy-on-extend. Eviction is LRU over a
//! fixed entry cap ([`KvPool::set_prefix_cap`]); entries with live session
//! refs are never evicted. All store transitions (lookup, freeze, clear)
//! happen in leader lockstep with rank-symmetric keys, so every host makes
//! the same hit/miss decision — the plan-length check in
//! `coordinator::Cluster::prefill_begin` is the desync tripwire.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::config::{ApbOptions, Config};
use crate::runtime::{KvSeg, KvView};
use crate::util::tensor::Tensor;

/// Identity of one serving session (request) resident on the cluster.
pub type SessionId = u64;

/// Point-in-time byte accounting of one host's pool — the observable the
/// chunk-split invariance proptest compares across chunk partitions, and
/// what `apb serve` ops dashboards read (`Cluster::pool_stats`).
///
/// `bytes_used`/`bytes_reserved` count the slots' *private* tensors;
/// shared-prefix bytes are physical-once and reported separately in
/// `prefix_bytes` (an entry attached by five sessions is stored — and
/// counted — exactly once).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolStats {
    /// Sessions currently holding a slot.
    pub resident: usize,
    /// Bytes resident across occupied slots (valid private KV rows only).
    pub bytes_used: usize,
    /// Bytes reserved by the whole pool (padded capacity of every slot).
    pub bytes_reserved: usize,
    /// Entries currently held by the prefix store (0 when caching is off).
    pub prefix_entries: usize,
    /// Bytes of immutable shared-prefix KV the store holds, each entry
    /// counted once regardless of how many sessions are attached.
    pub prefix_bytes: usize,
    /// Lifetime slot-shaped KV slabs freshly allocated by the freeze path
    /// (arena misses). Flat once the arena is warm: steady-state serving
    /// freezes into recycled slabs and allocates nothing.
    pub slab_allocs: u64,
    /// Lifetime slabs recycled from the arena free list (arena hits).
    pub slab_reuses: u64,
    /// Slabs currently parked in the arena free list.
    pub slabs_free: usize,
}

/// One layer's K/V rows plus the valid length (`k`/`v` may be padded past
/// `len`, both in [`KvCache`] slots and in [`SharedPrefix`] entries — the
/// latter inherit the slot slabs they were frozen from).
#[derive(Debug, Clone)]
pub struct LayerCache {
    /// Key rows, `[rows, kv_heads, head_dim]`.
    pub k: Tensor,
    /// Value rows, same shape as `k`.
    pub v: Tensor,
    /// Valid row count (rows past it are padding).
    pub len: usize,
}

/// Rank-symmetric content digest keying the prefix store (FNV-1a over the
/// request content and everything that shapes the prefill output):
///
/// * the full document and query token ids — the query is part of the key
///   because APB embeds it in the anchor, so even the *document* KV is
///   query-dependent (see ADR-003 "Digest key design");
/// * the attention method and every ablation toggle of [`ApbOptions`]
///   (`use_anchor`, `retaining_compressor`, `embed_query`, `rd_seed`,
///   `record_retained` — the last so a recording request never attaches to
///   an entry frozen without retained indices);
/// * a config fingerprint (model dims, weight seed, APB layout lengths).
///
/// Deliberately **excluded**: `chunk_tokens` (any chunk partition is
/// bit-identical per ADR-002, so differently-chunked requests share
/// entries), `max_new`/`max_resident` (decode-side knobs), and
/// `prefix_cache` itself. Every input is available identically on the
/// leader and on every rank, so all hosts derive the same key.
pub fn prefix_digest(cfg: &Config, doc: &[i32], query: &[i32], opts: &ApbOptions) -> u64 {
    const OFFSET: u64 = 0xCBF2_9CE4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01B3;
    let mut h = OFFSET;
    let mut mix = |v: u64| {
        for b in v.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(PRIME);
        }
    };
    let m = &cfg.model;
    let a = &cfg.apb;
    for v in [
        cfg.seed,
        m.vocab_size as u64,
        m.n_layers as u64,
        m.d_model as u64,
        m.n_heads as u64,
        m.n_kv_heads as u64,
        m.d_ff as u64,
        m.retaining_hidden as u64,
        a.n_hosts as u64,
        a.block_len as u64,
        a.anchor_len as u64,
        a.query_len as u64,
        a.passing_len as u64,
        opts.method as u64,
        opts.use_anchor as u64,
        opts.retaining_compressor as u64,
        opts.embed_query as u64,
        opts.rd_seed,
        opts.record_retained as u64,
        doc.len() as u64,
        query.len() as u64,
    ] {
        mix(v);
    }
    for &t in doc {
        mix(t as u64);
    }
    for &t in query {
        mix(t as u64);
    }
    h
}

/// One immutable, refcounted shared KV prefix: exactly the per-layer rows a
/// cold prefill appended to its slot on THIS host, frozen at the final
/// prefill step and shared (via `Arc`) by every session whose request
/// matches the digest. Entries are never mutated after freezing — decode
/// rows land in each session's private tail ([`KvCache::append`]), so
/// attaching cannot perturb any other rider.
#[derive(Debug)]
pub struct SharedPrefix {
    /// Per-layer (k, v, len) rows in prefill append order — the padded
    /// slab tensors moved out of the freezing session's slot, valid to
    /// `len` (readers mask to it; padding rows are never read).
    layers: Vec<LayerCache>,
    /// The [`prefix_digest`] this entry was frozen under.
    digest: u64,
    /// KV bytes this entry holds on this host (0 is legal: a Dense prefill
    /// appends nothing on ranks > 0, and the empty entry keeps refcounts
    /// rank-symmetric).
    bytes: usize,
    /// The cold prefill's retained-index record (empty unless the request
    /// set `ApbOptions::record_retained`; recording requests only ever hit
    /// recording entries because the flag is part of the digest), served
    /// verbatim on warm hits so `PrefillReport.retained` stays
    /// bit-identical to a cold run.
    retained: Vec<Vec<Vec<u32>>>,
}

impl SharedPrefix {
    /// The digest key this entry answers to.
    pub fn digest(&self) -> u64 {
        self.digest
    }

    /// KV bytes this entry holds on this host (each entry counted once in
    /// [`PoolStats::prefix_bytes`] no matter how many sessions attach).
    pub fn bytes(&self) -> usize {
        self.bytes
    }

    /// Valid shared rows of one layer.
    pub fn len(&self, layer: usize) -> usize {
        self.layers[layer].len
    }

    /// True when no layer holds any row (a Dense entry on an idle rank).
    pub fn is_empty(&self) -> bool {
        self.layers.iter().all(|l| l.len == 0)
    }

    /// The cold prefill's retained-index record (see field docs).
    pub fn retained(&self) -> &Vec<Vec<Vec<u32>>> {
        &self.retained
    }
}

/// Per-session KV cache: a padded private tail plus an optional attached
/// [`SharedPrefix`] segment. The logical key sequence of layer `l` is
/// `[shared rows | private rows]`, exposed to backends as a
/// [`KvView`] by [`KvCache::view`]; `cache_max` bounds the COMBINED length.
#[derive(Debug)]
pub struct KvCache {
    /// The private tail (padded to `cache_max` rows per layer).
    pub layers: Vec<LayerCache>,
    /// Maximum combined (shared + private) rows per layer.
    pub cache_max: usize,
    /// Attached shared prefix, if this session rides a prefix-cache hit.
    shared: Option<Arc<SharedPrefix>>,
    /// Conversation-turn boundaries: the combined layer-0 length at the
    /// moment each turn began ([`KvCache::mark_turn`]). Partitions the
    /// private tail by turn for multi-turn append bookkeeping
    /// (`docs/ADR-007-adaptive-decode.md`) and is the seam a future
    /// copy-on-extend conversation branch would fork at.
    turn_marks: Vec<usize>,
}

impl KvCache {
    /// Build an empty cache of `n_layers` padded layers.
    pub fn new(n_layers: usize, cache_max: usize, kv_heads: usize, head_dim: usize) -> Self {
        let layers = (0..n_layers)
            .map(|_| LayerCache {
                k: Tensor::zeros(vec![cache_max, kv_heads, head_dim]),
                v: Tensor::zeros(vec![cache_max, kv_heads, head_dim]),
                len: 0,
            })
            .collect();
        KvCache { layers, cache_max, shared: None, turn_marks: Vec::new() }
    }

    /// Record a conversation-turn boundary at the current combined layer-0
    /// length — called BEFORE the new turn's first KV row lands, so mark
    /// `i` is where turn `i + 1`'s rows start.
    pub fn mark_turn(&mut self) {
        self.turn_marks.push(self.len(0));
    }

    /// Number of recorded turn boundaries (0 for a single-turn session).
    pub fn n_turns(&self) -> usize {
        self.turn_marks.len()
    }

    /// The recorded turn boundaries, in append order.
    pub fn turn_marks(&self) -> &[usize] {
        &self.turn_marks
    }

    /// Valid rows of the attached shared prefix at `layer` (0 when cold).
    pub fn shared_len(&self, layer: usize) -> usize {
        self.shared.as_ref().map_or(0, |s| s.len(layer))
    }

    /// Combined valid rows (shared prefix + private tail) at `layer`.
    pub fn len(&self, layer: usize) -> usize {
        self.shared_len(layer) + self.layers[layer].len
    }

    /// True when neither segment holds any row.
    pub fn is_empty(&self) -> bool {
        self.shared.is_none() && self.layers.iter().all(|l| l.len == 0)
    }

    /// The attached shared prefix, if any.
    pub fn shared(&self) -> Option<&Arc<SharedPrefix>> {
        self.shared.as_ref()
    }

    /// Attach an immutable shared prefix to this (empty) cache — the warm
    /// half of a prefix-cache hit. Fails if the cache already holds rows or
    /// a prefix, if the layer counts disagree, or if any layer's shared
    /// rows alone exceed `cache_max`. No decode-tail headroom is reserved
    /// here: entries frozen from this pool's own slots always leave the
    /// layout's tail room, and a later over-append still fails safely in
    /// [`KvCache::append`]'s combined-length check.
    pub fn attach_shared(&mut self, entry: Arc<SharedPrefix>) -> Result<()> {
        if self.shared.is_some() || self.layers.iter().any(|l| l.len > 0) {
            bail!("attach_shared on a non-empty cache");
        }
        if entry.layers.len() != self.layers.len() {
            bail!(
                "shared prefix has {} layers, cache has {}",
                entry.layers.len(),
                self.layers.len()
            );
        }
        if let Some(over) = entry.layers.iter().find(|l| l.len > self.cache_max) {
            bail!("shared prefix rows {} exceed slot capacity {}", over.len, self.cache_max);
        }
        self.shared = Some(entry);
        Ok(())
    }

    /// Append `k`/`v` rows ([n, kh, hd]) to a layer's private tail. Errors
    /// when the COMBINED (shared + private) length would overflow — the
    /// scheduler's admission control must prevent this.
    pub fn append(&mut self, layer: usize, k: &Tensor, v: &Tensor) -> Result<()> {
        let shared_len = self.shared_len(layer);
        let lc = &mut self.layers[layer];
        let n = k.shape[0];
        if shared_len + lc.len + n > self.cache_max {
            bail!(
                "kv cache overflow: layer {layer} len {} + {n} > cap {}",
                shared_len + lc.len,
                self.cache_max
            );
        }
        lc.k.write_rows(lc.len, k);
        lc.v.write_rows(lc.len, v);
        lc.len += n;
        Ok(())
    }

    /// Append row `row` of batched `k`/`v` (`[n, kh, hd]`) to a layer's
    /// private tail — the continuous-batching decode step's per-session
    /// append, copied straight from the batch tensor without materializing
    /// a one-row slice. Same combined-length rule as [`KvCache::append`].
    pub fn append_row(&mut self, layer: usize, k: &Tensor, v: &Tensor, row: usize) -> Result<()> {
        let shared_len = self.shared_len(layer);
        let lc = &mut self.layers[layer];
        if shared_len + lc.len + 1 > self.cache_max {
            bail!(
                "kv cache overflow: layer {layer} len {} + 1 > cap {}",
                shared_len + lc.len,
                self.cache_max
            );
        }
        let rl = lc.k.row_len();
        assert_eq!(k.row_len(), rl, "append_row: row shape mismatch");
        assert!(row < k.shape[0], "append_row: row {row} of {}", k.shape[0]);
        lc.k.data[lc.len * rl..(lc.len + 1) * rl]
            .copy_from_slice(&k.data[row * rl..(row + 1) * rl]);
        lc.v.data[lc.len * rl..(lc.len + 1) * rl]
            .copy_from_slice(&v.data[row * rl..(row + 1) * rl]);
        lc.len += 1;
        Ok(())
    }

    /// Borrowed `[shared | private]` view of one layer for decode.
    pub fn view(&self, layer: usize) -> KvView<'_> {
        let lc = &self.layers[layer];
        KvView {
            shared: self.shared.as_ref().map(|s| {
                let sl = &s.layers[layer];
                KvSeg { k: &sl.k, v: &sl.v, len: sl.len }
            }),
            tail: KvSeg { k: &lc.k, v: &lc.v, len: lc.len },
        }
    }

    /// Reset all layers and release any attached shared prefix (request
    /// eviction; the store's copy of the prefix survives).
    pub fn clear(&mut self) {
        self.shared = None;
        self.turn_marks.clear();
        for lc in &mut self.layers {
            lc.len = 0;
        }
    }

    /// Bytes currently resident in the PRIVATE tail (valid region only) —
    /// the physical footprint this session adds on top of any shared entry.
    pub fn bytes_used(&self) -> usize {
        self.layers
            .iter()
            .map(|l| 2 * l.len * l.k.row_len() * 4)
            .sum()
    }

    /// Bytes of the session's LOGICAL cache — private tail plus its view of
    /// the shared prefix. Equal to a cold session's `bytes_used` for the
    /// same request (the prefix-cache bit-identity observable).
    pub fn logical_bytes(&self) -> usize {
        self.bytes_used() + self.shared.as_ref().map_or(0, |s| s.bytes())
    }

    /// Bytes reserved (padded private capacity).
    pub fn bytes_reserved(&self) -> usize {
        self.layers
            .iter()
            .map(|l| 2 * l.k.numel() * 4)
            .sum()
    }
}

struct Slot {
    sid: Option<SessionId>,
    cache: KvCache,
}

/// Recycled slot-shaped KV slab tensors (`docs/ADR-005-sim-perf.md`).
///
/// [`KvPool::freeze_shared`] MOVES a slot's padded per-layer tensors into
/// the frozen [`SharedPrefix`] entry and re-arms the slot from this free
/// list; when the store later drops the last reference to an entry, its
/// tensors come back here. Steady-state freeze/evict churn therefore
/// allocates nothing — the counters below are the observable CI gates on.
///
/// Slabs are NOT zeroed on reuse: every reader masks to the valid `len`
/// rows, so stale padding is unreachable (the slab-vs-fresh bit-identity
/// proptest pins this). Entries dropped outside the pool's eviction points
/// (a session freed while holding the last ref to a never-stored entry)
/// are lost to the allocator — reclamation is best-effort by design.
struct SlabArena {
    /// Expected slab shape `[cache_max, kv_heads, head_dim]`; foreign
    /// shapes are refused at `put` (they could only arise from a future
    /// cross-pool migration, and a silently wrong slab shape would corrupt
    /// every later freeze).
    shape: Vec<usize>,
    free: Vec<Tensor>,
    allocs: u64,
    reuses: u64,
}

impl SlabArena {
    fn take(&mut self) -> Tensor {
        match self.free.pop() {
            Some(t) => {
                self.reuses += 1;
                t
            }
            None => {
                self.allocs += 1;
                Tensor::zeros(self.shape.clone())
            }
        }
    }

    fn put(&mut self, t: Tensor) {
        if t.shape == self.shape {
            self.free.push(t);
        }
    }
}

/// One prefix-store entry plus its LRU stamp.
struct PrefixSlot {
    entry: Arc<SharedPrefix>,
    last_used: u64,
}

/// Fixed-capacity pool of per-session KV caches (one per residency slot),
/// plus the host's shared-prefix store (see module docs).
///
/// Every host owns one pool sized `ApbParams::max_resident`; a session's
/// cache lives in its slot from prefill until `free`, so several requests
/// can hold KV on the cluster simultaneously (continuous batching).
pub struct KvPool {
    slots: Vec<Slot>,
    /// Shared-prefix store: digest-keyed entries, LRU-evicted at
    /// `prefix_cap` (0 = store disabled, the default).
    prefix: Vec<PrefixSlot>,
    prefix_cap: usize,
    /// Monotone LRU clock, bumped on every lookup hit and insert. Driven in
    /// leader lockstep, so identical on every rank.
    prefix_tick: u64,
    /// Lifetime hit counter (ops observability).
    prefix_hits: u64,
    /// Slab recycler backing [`KvPool::freeze_shared`].
    arena: SlabArena,
}

impl KvPool {
    /// Build a pool of `n_slots` session caches (prefix store disabled
    /// until [`KvPool::set_prefix_cap`]).
    pub fn new(
        n_slots: usize,
        n_layers: usize,
        cache_max: usize,
        kv_heads: usize,
        head_dim: usize,
    ) -> Self {
        let slots = (0..n_slots.max(1))
            .map(|_| Slot {
                sid: None,
                cache: KvCache::new(n_layers, cache_max, kv_heads, head_dim),
            })
            .collect();
        KvPool {
            slots,
            prefix: Vec::new(),
            prefix_cap: 0,
            prefix_tick: 0,
            prefix_hits: 0,
            arena: SlabArena {
                shape: vec![cache_max, kv_heads, head_dim],
                free: Vec::new(),
                allocs: 0,
                reuses: 0,
            },
        }
    }

    pub fn n_slots(&self) -> usize {
        self.slots.len()
    }

    /// Sessions currently holding a slot.
    pub fn resident(&self) -> usize {
        self.slots.iter().filter(|s| s.sid.is_some()).count()
    }

    pub fn resident_sids(&self) -> Vec<SessionId> {
        self.slots.iter().filter_map(|s| s.sid).collect()
    }

    pub fn contains(&self, sid: SessionId) -> bool {
        self.slots.iter().any(|s| s.sid == Some(sid))
    }

    /// Claim a slot for `sid`, returning its (cleared) cache. Re-allocating
    /// a resident session resets its cache in place (a fresh prefill of the
    /// same session id), releasing any shared-prefix ref it held. Errors —
    /// without touching any resident cache — when every slot is occupied by
    /// another session.
    ///
    /// # Examples
    ///
    /// ```
    /// use apb::kvcache::KvPool;
    ///
    /// // 2 slots x 1 layer, 4 rows x 1 kv-head x 2 dims each.
    /// let mut pool = KvPool::new(2, 1, 4, 1, 2);
    /// let cache = pool.alloc(7).expect("a slot is free");
    /// assert_eq!(cache.len(0), 0, "claimed slots start empty");
    /// pool.alloc(8).expect("second slot");
    /// let err = pool.alloc(9).unwrap_err();
    /// assert!(err.to_string().contains("backpressure"));
    /// ```
    pub fn alloc(&mut self, sid: SessionId) -> Result<&mut KvCache> {
        if let Some(i) = self.slots.iter().position(|s| s.sid == Some(sid)) {
            self.slots[i].cache.clear();
            return Ok(&mut self.slots[i].cache);
        }
        let Some(i) = self.slots.iter().position(|s| s.sid.is_none()) else {
            bail!(
                "kv pool exhausted ({}/{} slots resident): backpressure — \
                 free a session before admitting another",
                self.slots.len(),
                self.slots.len()
            );
        };
        self.slots[i].sid = Some(sid);
        self.slots[i].cache.clear();
        Ok(&mut self.slots[i].cache)
    }

    /// Shared view of a resident session's cache.
    pub fn get(&self, sid: SessionId) -> Result<&KvCache> {
        self.slots
            .iter()
            .find(|s| s.sid == Some(sid))
            .map(|s| &s.cache)
            .ok_or_else(|| anyhow::anyhow!("session {sid} not resident in kv pool"))
    }

    /// Mutable view of a resident session's cache.
    pub fn get_mut(&mut self, sid: SessionId) -> Result<&mut KvCache> {
        self.slots
            .iter_mut()
            .find(|s| s.sid == Some(sid))
            .map(|s| &mut s.cache)
            .ok_or_else(|| anyhow::anyhow!("session {sid} not resident in kv pool"))
    }

    /// Release `sid`'s slot (no-op when absent). Returns whether a slot was
    /// actually freed. A prefix-attached session only drops its `Arc` ref:
    /// the store's entry — and its bytes — survive for the next rider.
    ///
    /// # Examples
    ///
    /// ```
    /// use apb::kvcache::KvPool;
    ///
    /// let mut pool = KvPool::new(1, 1, 4, 1, 2);
    /// pool.alloc(7).unwrap();
    /// assert!(pool.free(7), "releases the slot");
    /// assert!(!pool.free(7), "double free is a no-op");
    /// ```
    pub fn free(&mut self, sid: SessionId) -> bool {
        match self.slots.iter_mut().find(|s| s.sid == Some(sid)) {
            Some(s) => {
                s.sid = None;
                s.cache.clear();
                true
            }
            None => false,
        }
    }

    /// Drop every session AND the prefix store (full reset between serving
    /// phases; `Cmd::Clear` on one session keeps the store warm instead).
    pub fn clear_all(&mut self) {
        // Slots first: dropping their shared refs makes the store the last
        // holder, so every entry's slabs can come back to the arena.
        for s in &mut self.slots {
            s.sid = None;
            s.cache.clear();
        }
        for p in std::mem::take(&mut self.prefix) {
            self.reclaim(p.entry);
        }
        self.prefix_tick = 0;
    }

    // -- prefix store --------------------------------------------------------

    /// Bound the prefix store to at most `cap` entries (0 disables it and
    /// drops any held entries). The cap is an ENTRY count — a rank-uniform
    /// quantity — rather than bytes, because per-rank entry sizes differ
    /// (a Dense prefill stores everything on rank 0 and nothing elsewhere)
    /// and eviction decisions must be identical on every host.
    pub fn set_prefix_cap(&mut self, cap: usize) {
        self.prefix_cap = cap;
        if cap == 0 {
            for p in std::mem::take(&mut self.prefix) {
                self.reclaim(p.entry);
            }
        }
    }

    /// Look up a digest, bumping its LRU stamp and the hit counter on
    /// success.
    pub fn prefix_lookup(&mut self, digest: u64) -> Option<Arc<SharedPrefix>> {
        let slot = self.prefix.iter_mut().find(|p| p.entry.digest == digest)?;
        self.prefix_tick += 1;
        slot.last_used = self.prefix_tick;
        self.prefix_hits += 1;
        Some(Arc::clone(&slot.entry))
    }

    /// Lifetime prefix-store hits on this host.
    pub fn prefix_hits(&self) -> u64 {
        self.prefix_hits
    }

    /// Entries currently held.
    pub fn prefix_entries(&self) -> usize {
        self.prefix.len()
    }

    /// Bytes of shared KV held by the store (each entry once).
    pub fn prefix_bytes(&self) -> usize {
        self.prefix.iter().map(|p| p.entry.bytes()).sum()
    }

    /// Insert an entry, LRU-evicting a ref-free entry if the store is at
    /// cap. Returns `false` — leaving the store untouched — when the store
    /// is disabled, already holds the digest, or is full of entries with
    /// live session refs (eviction of a live entry is REFUSED; the caller's
    /// session keeps its own `Arc` and simply isn't shareable).
    pub fn prefix_insert(&mut self, entry: Arc<SharedPrefix>) -> bool {
        if self.prefix_cap == 0 {
            return false;
        }
        if self.prefix.iter().any(|p| p.entry.digest == entry.digest) {
            return false;
        }
        if self.prefix.len() >= self.prefix_cap {
            // LRU candidate among entries only the store itself still
            // references (strong_count 1 = no attached session).
            let victim = self
                .prefix
                .iter()
                .enumerate()
                .filter(|(_, p)| Arc::strong_count(&p.entry) == 1)
                .min_by_key(|(_, p)| p.last_used)
                .map(|(i, _)| i);
            match victim {
                Some(i) => {
                    let evicted = self.prefix.remove(i);
                    self.reclaim(evicted.entry);
                }
                None => return false,
            }
        }
        self.prefix_tick += 1;
        self.prefix.push(PrefixSlot { entry, last_used: self.prefix_tick });
        true
    }

    /// Freeze a cold-prefilled session's private KV into a [`SharedPrefix`]
    /// entry: MOVE the slot's padded per-layer tensors into the entry
    /// wholesale (zero row copies — the entry keeps `len` to bound the
    /// valid region, exactly as the slot did), re-arm the slot with slabs
    /// from the arena free list, attach the new entry back onto the session
    /// (so the session itself decodes over `[shared | empty tail]`, the
    /// same path warm riders take), and offer it to the store under
    /// `digest`. Returns the entry; store insertion is best-effort (see
    /// [`KvPool::prefix_insert`]). Once the arena is warm, this whole
    /// operation allocates nothing.
    pub fn freeze_shared(
        &mut self,
        sid: SessionId,
        digest: u64,
        retained: Vec<Vec<Vec<u32>>>,
    ) -> Result<Arc<SharedPrefix>> {
        let Some(idx) = self.slots.iter().position(|s| s.sid == Some(sid)) else {
            bail!("session {sid} not resident in kv pool");
        };
        let cache = &mut self.slots[idx].cache;
        if cache.shared.is_some() {
            bail!("freeze_shared: session {sid} already rides a shared prefix");
        }
        let mut layers = Vec::with_capacity(cache.layers.len());
        for lc in &mut cache.layers {
            let k = std::mem::replace(&mut lc.k, self.arena.take());
            let v = std::mem::replace(&mut lc.v, self.arena.take());
            layers.push(LayerCache { k, v, len: lc.len });
            lc.len = 0;
        }
        // Bytes stay the VALID-region formula: the padding rows riding
        // along in the moved slabs are reserved capacity, not held KV.
        let bytes = layers.iter().map(|l| 2 * l.len * l.k.row_len() * 4).sum();
        let entry = Arc::new(SharedPrefix { layers, digest, bytes, retained });
        cache.shared = Some(Arc::clone(&entry));
        self.prefix_insert(Arc::clone(&entry));
        Ok(entry)
    }

    /// Return an entry's slab tensors to the arena if this `Arc` was the
    /// last reference. Best-effort: an entry still attached to a session
    /// (or cloned out by a caller) is simply left to the allocator.
    fn reclaim(&mut self, entry: Arc<SharedPrefix>) {
        if let Ok(e) = Arc::try_unwrap(entry) {
            for l in e.layers {
                self.arena.put(l.k);
                self.arena.put(l.v);
            }
        }
    }

    // -- accounting ----------------------------------------------------------

    /// Bytes resident across occupied slots (valid PRIVATE regions only).
    pub fn bytes_used(&self) -> usize {
        self.slots
            .iter()
            .filter(|s| s.sid.is_some())
            .map(|s| s.cache.bytes_used())
            .sum()
    }

    /// Bytes reserved by the whole pool (padded capacity of every slot).
    pub fn bytes_reserved(&self) -> usize {
        self.slots.iter().map(|s| s.cache.bytes_reserved()).sum()
    }

    /// Snapshot of this pool's residency/byte accounting.
    pub fn stats(&self) -> PoolStats {
        PoolStats {
            resident: self.resident(),
            bytes_used: self.bytes_used(),
            bytes_reserved: self.bytes_reserved(),
            prefix_entries: self.prefix_entries(),
            prefix_bytes: self.prefix_bytes(),
            slab_allocs: self.arena.allocs,
            slab_reuses: self.arena.reuses,
            slabs_free: self.arena.free.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ApbOptions, AttnMethod};

    fn rows(n: usize, kh: usize, hd: usize, base: f32) -> Tensor {
        let data = (0..n * kh * hd).map(|i| base + i as f32).collect();
        Tensor::new(vec![n, kh, hd], data).unwrap()
    }

    #[test]
    fn append_and_read_back() {
        let mut c = KvCache::new(2, 8, 2, 4);
        assert!(c.is_empty());
        c.append(0, &rows(3, 2, 4, 0.0), &rows(3, 2, 4, 100.0)).unwrap();
        c.append(0, &rows(2, 2, 4, 50.0), &rows(2, 2, 4, 150.0)).unwrap();
        assert_eq!(c.len(0), 5);
        assert_eq!(c.len(1), 0);
        // First appended row intact.
        assert_eq!(c.layers[0].k.slice_rows(0, 3), rows(3, 2, 4, 0.0));
        assert_eq!(c.layers[0].k.slice_rows(3, 5), rows(2, 2, 4, 50.0));
        assert_eq!(c.layers[0].v.slice_rows(3, 5), rows(2, 2, 4, 150.0));
    }

    #[test]
    fn overflow_rejected() {
        let mut c = KvCache::new(1, 4, 1, 2);
        c.append(0, &rows(3, 1, 2, 0.0), &rows(3, 1, 2, 0.0)).unwrap();
        assert!(c.append(0, &rows(2, 1, 2, 0.0), &rows(2, 1, 2, 0.0)).is_err());
        // Failed append must not corrupt length.
        assert_eq!(c.len(0), 3);
    }

    #[test]
    fn clear_resets() {
        let mut c = KvCache::new(1, 4, 1, 2);
        c.append(0, &rows(2, 1, 2, 0.0), &rows(2, 1, 2, 0.0)).unwrap();
        assert!(c.bytes_used() > 0);
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.bytes_used(), 0);
        assert_eq!(c.bytes_reserved(), 2 * 4 * 1 * 2 * 4);
    }

    #[test]
    fn turn_marks_partition_tail_and_clear_with_cache() {
        let mut c = KvCache::new(1, 8, 1, 2);
        assert_eq!(c.n_turns(), 0);
        c.append(0, &rows(3, 1, 2, 0.0), &rows(3, 1, 2, 0.0)).unwrap();
        // Mark BEFORE the turn's rows land: the mark is where they start.
        c.mark_turn();
        c.append(0, &rows(2, 1, 2, 0.0), &rows(2, 1, 2, 0.0)).unwrap();
        c.mark_turn();
        c.append(0, &rows(1, 1, 2, 0.0), &rows(1, 1, 2, 0.0)).unwrap();
        assert_eq!(c.turn_marks(), &[3, 5]);
        assert_eq!(c.n_turns(), 2);
        c.clear();
        assert_eq!(c.n_turns(), 0, "marks die with the cache rows");
    }

    #[test]
    fn pool_alloc_get_free_roundtrip() {
        let mut p = KvPool::new(2, 1, 4, 1, 2);
        assert_eq!(p.n_slots(), 2);
        assert_eq!(p.resident(), 0);
        p.alloc(7).unwrap().append(0, &rows(2, 1, 2, 0.0), &rows(2, 1, 2, 9.0)).unwrap();
        p.alloc(8).unwrap();
        assert_eq!(p.resident(), 2);
        assert!(p.contains(7) && p.contains(8) && !p.contains(9));
        assert_eq!(p.get(7).unwrap().len(0), 2);
        assert_eq!(p.get(8).unwrap().len(0), 0);
        assert!(p.free(7));
        assert!(!p.free(7), "double free is a no-op");
        assert_eq!(p.resident(), 1);
        assert!(p.get(7).is_err());
    }

    #[test]
    fn pool_exhaustion_errors_without_corruption() {
        let mut p = KvPool::new(1, 1, 4, 1, 2);
        p.alloc(1).unwrap().append(0, &rows(3, 1, 2, 5.0), &rows(3, 1, 2, 6.0)).unwrap();
        let err = p.alloc(2).unwrap_err();
        assert!(format!("{err:#}").contains("backpressure"));
        // The resident session's cache is untouched by the failed alloc.
        assert_eq!(p.get(1).unwrap().len(0), 3);
        assert_eq!(p.get(1).unwrap().layers[0].k.slice_rows(0, 3), rows(3, 1, 2, 5.0));
    }

    #[test]
    fn pool_realloc_resets_in_place() {
        let mut p = KvPool::new(1, 1, 4, 1, 2);
        p.alloc(3).unwrap().append(0, &rows(2, 1, 2, 0.0), &rows(2, 1, 2, 0.0)).unwrap();
        assert_eq!(p.get(3).unwrap().len(0), 2);
        // Fresh prefill of the same session id starts from an empty cache.
        assert_eq!(p.alloc(3).unwrap().len(0), 0);
        assert_eq!(p.resident(), 1);
    }

    #[test]
    fn pool_stats_snapshot() {
        let mut p = KvPool::new(2, 1, 4, 1, 2);
        assert_eq!(p.stats(),
                   PoolStats { resident: 0, bytes_used: 0,
                               bytes_reserved: 2 * (2 * 4 * 1 * 2 * 4),
                               prefix_entries: 0, prefix_bytes: 0,
                               slab_allocs: 0, slab_reuses: 0, slabs_free: 0 });
        p.alloc(1).unwrap().append(0, &rows(2, 1, 2, 0.0), &rows(2, 1, 2, 0.0)).unwrap();
        let s = p.stats();
        assert_eq!(s.resident, 1);
        assert_eq!(s.bytes_used, p.bytes_used());
    }

    #[test]
    fn pool_byte_accounting() {
        let mut p = KvPool::new(2, 1, 4, 1, 2);
        assert_eq!(p.bytes_used(), 0);
        assert_eq!(p.bytes_reserved(), 2 * (2 * 4 * 1 * 2 * 4));
        p.alloc(1).unwrap().append(0, &rows(2, 1, 2, 0.0), &rows(2, 1, 2, 0.0)).unwrap();
        let one = p.bytes_used();
        assert_eq!(one, 2 * 2 * 2 * 4);
        p.alloc(2).unwrap().append(0, &rows(1, 1, 2, 0.0), &rows(1, 1, 2, 0.0)).unwrap();
        assert_eq!(p.bytes_used(), one + 2 * 2 * 4);
        p.clear_all();
        assert_eq!(p.bytes_used(), 0);
        assert_eq!(p.resident(), 0);
    }

    // -- prefix store --------------------------------------------------------

    /// Prefill `n` rows into `sid`'s slot and freeze them under `digest`.
    fn freeze(p: &mut KvPool, sid: SessionId, digest: u64, n: usize) -> Arc<SharedPrefix> {
        p.alloc(sid).unwrap().append(0, &rows(n, 1, 2, sid as f32),
                                     &rows(n, 1, 2, sid as f32)).unwrap();
        p.freeze_shared(sid, digest, Vec::new()).unwrap()
    }

    #[test]
    fn freeze_moves_rows_into_shared_and_preserves_view() {
        let mut p = KvPool::new(2, 1, 6, 1, 2);
        p.set_prefix_cap(2);
        let k = rows(3, 1, 2, 5.0);
        let v = rows(3, 1, 2, 9.0);
        p.alloc(1).unwrap().append(0, &k, &v).unwrap();
        let entry = p.freeze_shared(1, 0xD1, Vec::new()).unwrap();
        assert_eq!(entry.bytes(), 2 * 3 * 2 * 4);
        assert_eq!(entry.len(0), 3);
        // The session's logical cache is unchanged: same rows, now shared.
        let c = p.get(1).unwrap();
        assert_eq!(c.len(0), 3);
        assert_eq!(c.bytes_used(), 0, "rows MOVED, not copied");
        assert_eq!(c.logical_bytes(), entry.bytes());
        let view = c.view(0);
        assert_eq!(view.len(), 3);
        let shared = view.shared.expect("shared segment attached");
        assert_eq!(shared.k.slice_rows(0, 3), k);
        assert_eq!(shared.v.slice_rows(0, 3), v);
        // Decode tail appends land in the private segment, copy-on-extend.
        p.get_mut(1).unwrap().append(0, &rows(1, 1, 2, 7.0), &rows(1, 1, 2, 7.0)).unwrap();
        let c = p.get(1).unwrap();
        assert_eq!(c.len(0), 4);
        assert_eq!(c.shared_len(0), 3);
        assert_eq!(c.view(0).tail.len, 1);
        // Combined capacity is enforced across segments: 3 shared + 3 > 6 - 1.
        assert!(p.get_mut(1).unwrap()
                 .append(0, &rows(3, 1, 2, 0.0), &rows(3, 1, 2, 0.0)).is_err());
        assert_eq!(p.stats().prefix_entries, 1);
        assert_eq!(p.stats().prefix_bytes, entry.bytes());
    }

    #[test]
    fn second_session_attaches_and_hits_count() {
        let mut p = KvPool::new(2, 1, 6, 1, 2);
        p.set_prefix_cap(2);
        freeze(&mut p, 1, 0xD1, 3);
        assert_eq!(p.prefix_hits(), 0);
        let entry = p.prefix_lookup(0xD1).expect("hit");
        assert_eq!(p.prefix_hits(), 1);
        assert!(p.prefix_lookup(0xD2).is_none(), "unknown digest misses");
        p.alloc(2).unwrap().attach_shared(entry).unwrap();
        let (a, b) = (p.get(1).unwrap(), p.get(2).unwrap());
        assert_eq!(a.len(0), b.len(0));
        // Physically one copy: both sessions' shared segments are the entry.
        assert_eq!(p.stats().prefix_bytes, a.logical_bytes());
        // Attaching to a non-empty cache is refused.
        let e2 = p.prefix_lookup(0xD1).unwrap();
        assert!(p.get_mut(2).unwrap().attach_shared(e2).is_err());
    }

    #[test]
    fn eviction_with_live_refs_is_refused() {
        let mut p = KvPool::new(2, 1, 6, 1, 2);
        p.set_prefix_cap(1);
        // Entry D1 stays attached to session 1 (live ref).
        freeze(&mut p, 1, 0xD1, 2);
        // Freezing session 2's rows wants a store slot, but the only
        // candidate has a live ref: insertion is refused, D1 survives...
        freeze(&mut p, 2, 0xD2, 3);
        assert_eq!(p.prefix_entries(), 1);
        assert!(p.prefix_lookup(0xD1).is_some());
        assert!(p.prefix_lookup(0xD2).is_none(), "D2 was not admitted");
        // ...and session 2 still rides its own (unshared) entry.
        assert_eq!(p.get(2).unwrap().len(0), 3);
        assert!(p.get(2).unwrap().shared().is_some());
    }

    #[test]
    fn lru_order_respected_under_pressure() {
        let mut p = KvPool::new(1, 1, 6, 1, 2);
        p.set_prefix_cap(2);
        // Freeze D1 and D2, releasing each session so the entries are
        // ref-free (evictable).
        freeze(&mut p, 1, 0xD1, 2);
        p.free(1);
        freeze(&mut p, 2, 0xD2, 2);
        p.free(2);
        assert_eq!(p.prefix_entries(), 2);
        // Touch D1: D2 becomes least-recently-used.
        assert!(p.prefix_lookup(0xD1).is_some());
        // Inserting D3 over the cap evicts D2, not D1.
        freeze(&mut p, 3, 0xD3, 2);
        p.free(3);
        assert_eq!(p.prefix_entries(), 2);
        assert!(p.prefix_lookup(0xD1).is_some(), "recently-used entry kept");
        assert!(p.prefix_lookup(0xD3).is_some(), "new entry admitted");
        assert!(p.prefix_lookup(0xD2).is_none(), "LRU entry evicted");
    }

    #[test]
    fn disabled_store_and_clear_all_drop_entries() {
        let mut p = KvPool::new(1, 1, 6, 1, 2);
        // Cap 0: freeze still works (session keeps its entry) but nothing
        // is stored.
        freeze(&mut p, 1, 0xD1, 2);
        assert_eq!(p.prefix_entries(), 0);
        assert!(p.get(1).unwrap().shared().is_some());
        p.free(1);
        // Enabled store survives per-session free but not clear_all.
        p.set_prefix_cap(2);
        freeze(&mut p, 1, 0xD2, 2);
        p.free(1);
        assert_eq!(p.prefix_entries(), 1);
        p.clear_all();
        assert_eq!(p.prefix_entries(), 0);
        assert_eq!(p.stats().prefix_bytes, 0);
    }

    #[test]
    fn append_row_matches_sliced_append() {
        // The batched-decode append path (no one-row temporaries) must be
        // byte-identical to slicing the batch row and appending it.
        let batch_k = rows(3, 2, 4, 10.0);
        let batch_v = rows(3, 2, 4, 90.0);
        let mut a = KvCache::new(1, 8, 2, 4);
        let mut b = KvCache::new(1, 8, 2, 4);
        for row in [2usize, 0, 1] {
            a.append_row(0, &batch_k, &batch_v, row).unwrap();
            b.append(0, &batch_k.slice_rows(row, row + 1),
                     &batch_v.slice_rows(row, row + 1)).unwrap();
        }
        assert_eq!(a.len(0), 3);
        assert_eq!(a.layers[0].k, b.layers[0].k);
        assert_eq!(a.layers[0].v, b.layers[0].v);
        assert_eq!(a.bytes_used(), b.bytes_used());
        // The combined-length check still guards the tail.
        let mut c = KvCache::new(1, 1, 2, 4);
        c.append_row(0, &batch_k, &batch_v, 0).unwrap();
        assert!(c.append_row(0, &batch_k, &batch_v, 1).is_err());
        assert_eq!(c.len(0), 1);
    }

    // -- slab arena ----------------------------------------------------------

    #[test]
    fn freeze_evict_churn_reuses_slabs_and_stops_allocating() {
        let mut p = KvPool::new(1, 2, 6, 1, 2);
        p.set_prefix_cap(1);
        // Cold start: the first freeze re-arms the slot with 2 fresh slabs
        // per layer (the arena has nothing to recycle yet), and the second
        // still allocates — its predecessor's slabs only return when the
        // eviction fires at insert time, AFTER the new freeze took slabs.
        freeze(&mut p, 1, 0xA1, 2);
        p.free(1);
        let s = p.stats();
        assert_eq!(s.slab_allocs, 4, "2 layers x (k, v) fresh slabs");
        assert_eq!(s.slab_reuses, 0);
        assert_eq!(s.slabs_free, 0, "entry still holds the moved slabs");
        freeze(&mut p, 1, 0xB0, 2);
        p.free(1);
        let s = p.stats();
        assert_eq!(s.slab_allocs, 8);
        assert_eq!(s.slabs_free, 4, "evicted 0xA1's slabs parked");
        // Steady state: two slab generations in flight, every further
        // freeze recycles and the allocation count stays flat forever.
        for round in 1..=4u64 {
            freeze(&mut p, 1, 0xB0 + round, 2);
            p.free(1);
        }
        let s = p.stats();
        assert_eq!(s.slab_allocs, 8, "steady-state churn allocates nothing");
        assert_eq!(s.slab_reuses, 4 * 4, "every later freeze recycled");
        assert_eq!(s.slabs_free, 4);
    }

    #[test]
    fn slab_reuse_is_invisible_to_readers() {
        let mut p = KvPool::new(1, 1, 6, 1, 2);
        p.set_prefix_cap(1);
        // Generation 1 pollutes a slab with 4 rows of distinctive values;
        // generation 2's insert evicts it, parking the polluted slabs;
        // generation 3's freeze re-arms the slot with them, un-zeroed.
        freeze(&mut p, 1, 0xA1, 4);
        p.free(1);
        freeze(&mut p, 2, 0xA2, 1);
        p.free(2);
        freeze(&mut p, 3, 0xA3, 2);
        assert!(p.stats().slab_reuses >= 2, "slot re-armed from the free list");
        // Session 3 now decodes into a recycled tail slab. Valid rows read
        // back exactly; rows past `len` (still holding generation-1 data)
        // are unreachable because every view masks to `len`.
        let k = rows(2, 1, 2, 77.0);
        let v = rows(2, 1, 2, 88.0);
        p.get_mut(3).unwrap().append(0, &k, &v).unwrap();
        let c = p.get(3).unwrap();
        let view = c.view(0);
        assert_eq!(view.tail.len, 2);
        assert_eq!(view.tail.k.slice_rows(0, 2), k);
        assert_eq!(view.tail.v.slice_rows(0, 2), v);
        assert_eq!(c.bytes_used(), 2 * 2 * 2 * 4, "byte accounting is len-based");
    }

    #[test]
    fn clear_all_and_cap_zero_return_slabs() {
        let mut p = KvPool::new(2, 1, 6, 1, 2);
        p.set_prefix_cap(2);
        freeze(&mut p, 1, 0xC1, 2);
        freeze(&mut p, 2, 0xC2, 2);
        assert_eq!(p.stats().slabs_free, 0, "entries hold their slabs");
        // clear_all drops the sessions FIRST, so both entries reclaim.
        p.clear_all();
        let s = p.stats();
        assert_eq!(s.prefix_entries, 0);
        assert_eq!(s.slabs_free, 4, "2 entries x (k, v) slabs returned");
        // Disabling the store reclaims held entries the same way (2 taken
        // by the freeze, then its entry's 2 returned on cap 0).
        p.set_prefix_cap(2);
        freeze(&mut p, 3, 0xC3, 2);
        p.free(3);
        p.set_prefix_cap(0);
        assert_eq!(p.stats().prefix_entries, 0);
        assert_eq!(p.stats().slabs_free, 4);
        // A live external ref blocks reclamation (best-effort contract).
        p.set_prefix_cap(2);
        let held = freeze(&mut p, 4, 0xC4, 2);
        p.free(4);
        let before = p.stats().slabs_free;
        p.clear_all();
        assert_eq!(p.stats().slabs_free, before,
                   "externally-held entry not reclaimed");
        drop(held);
    }

    #[test]
    fn digest_separates_methods_and_content_but_not_chunking() {
        let cfg = crate::config::Config::sim_tiny();
        let doc: Vec<i32> = (0..cfg.apb.doc_len() as i32).collect();
        let query = vec![1, 2, 3, 4];
        let d = |opts: &ApbOptions, doc: &[i32], query: &[i32]| {
            prefix_digest(&cfg, doc, query, opts)
        };
        let base = ApbOptions::default();
        // Same request, same digest (and deterministic).
        assert_eq!(d(&base, &doc, &query), d(&base, &doc, &query));
        // A digest "collision" across methods must MISS: the method is part
        // of the key, so all four methods key distinct entries.
        let digests: Vec<u64> = AttnMethod::ALL
            .iter()
            .map(|&method| d(&ApbOptions { method, ..base }, &doc, &query))
            .collect();
        for i in 0..digests.len() {
            for j in i + 1..digests.len() {
                assert_ne!(digests[i], digests[j],
                           "{} and {} must not share prefix entries",
                           AttnMethod::ALL[i].name(), AttnMethod::ALL[j].name());
            }
        }
        // Content changes change the key.
        let mut doc2 = doc.clone();
        doc2[17] ^= 1;
        assert_ne!(d(&base, &doc2, &query), d(&base, &doc, &query));
        assert_ne!(d(&base, &doc, &[9, 9, 9, 9]), d(&base, &doc, &query));
        // Ablation toggles and the retained-record flag change the key.
        for opts in [
            ApbOptions { use_anchor: false, ..base },
            ApbOptions { retaining_compressor: false, ..base },
            ApbOptions { embed_query: false, ..base },
            ApbOptions { rd_seed: base.rd_seed + 1, ..base },
            ApbOptions { record_retained: true, ..base },
        ] {
            assert_ne!(d(&opts, &doc, &query), d(&base, &doc, &query));
        }
        // Chunk granularity does NOT: any partition is bit-identical
        // (ADR-002), so differently-chunked requests share entries.
        let chunked = ApbOptions { chunk_tokens: Some(3), ..base };
        assert_eq!(d(&chunked, &doc, &query), d(&base, &doc, &query));
    }
}
