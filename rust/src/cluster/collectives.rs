//! Rendezvous collectives over threads (Mutex + Condvar), with payload
//! metering for the interconnect cost model.
//!
//! Two primitives back the executable cluster modes (see
//! `docs/architecture.md`, "Method matrix"):
//!
//! * [`Collective`] — N-rank AllGather (APB's compressed-block pass, label
//!   `kv`; the decode partial-attention merge, label `att`);
//! * [`RingExchange`] — neighbor send/recv (RingAttn's KV-block rotation,
//!   label `ring`): each rank sends to its successor and receives from its
//!   predecessor, so N-1 consecutive exchanges deliver every rank's
//!   original payload to every other rank exactly once (property-tested).
//!
//! Both primitives are built from split **`post` / `complete` halves** (the
//! NCCL-style async boundary): `post_tagged` contributes this rank's
//! payload without blocking and returns a [`Receipt`]; `complete` blocks
//! until the round has every rank's contribution and delivers the result.
//! The fused `all_gather_tagged` / `exchange_tagged` wrappers are
//! `post + complete` back to back. The chunked-prefill state machine
//! (`coordinator::prefill`) exploits the split to overlap communication
//! with compute: the RingAttn rotation posts the outgoing KV block, runs
//! the attention partials of the *previous* block, and only then completes
//! the receive — the executable twin of the `max(comm, compute)` overlap
//! model in `attnsim::walltime`.
//!
//! Correctness argument for `all_gather` (also property-tested): a round
//! completes only after all N ranks contribute; the completed result is
//! only replaced when all N ranks of the *next* round have contributed,
//! and a rank must `complete` round r before it may `post` round r+1 (the
//! `outstanding` flag) — so every rank reads an intact result.
//! `RingExchange` inherits the same argument with per-rank `Option` result
//! slots taken exactly once.

use std::collections::BTreeMap;
use std::sync::{Arc, Condvar, Mutex};

#[derive(Default, Clone, Copy)]
struct MeterSlot {
    bytes: u64,
    rounds: u64,
}

/// Bytes-on-the-wire meter, summed across all collectives of a fabric.
///
/// Contributions are recorded both in the fabric total and under the
/// contributing collective's label ("kv" for the prefill compressed-block
/// AllGather, "att" for the decode partial-attention AllGather), so the
/// prefill and decode communication volumes stay separable even though the
/// serving loop interleaves them.
#[derive(Default)]
pub struct CommMeter {
    total: Mutex<MeterSlot>,
    by_label: Mutex<BTreeMap<&'static str, MeterSlot>>,
}

impl CommMeter {
    pub fn add(&self, label: &'static str, bytes: u64) {
        {
            let mut t = self.total.lock().unwrap();
            t.bytes += bytes;
            t.rounds += 1;
        }
        let mut m = self.by_label.lock().unwrap();
        let slot = m.entry(label).or_default();
        slot.bytes += bytes;
        slot.rounds += 1;
    }

    pub fn bytes_total(&self) -> u64 {
        self.total.lock().unwrap().bytes
    }

    pub fn rounds_total(&self) -> u64 {
        self.total.lock().unwrap().rounds
    }

    pub fn bytes_for(&self, label: &str) -> u64 {
        self.by_label.lock().unwrap().get(label).copied().unwrap_or_default().bytes
    }

    /// Per-rank contribution count under a label: one batched decode step
    /// contributes `n_hosts * n_layers` "att" rounds regardless of how many
    /// sessions ride in the batch.
    pub fn rounds_for(&self, label: &str) -> u64 {
        self.by_label.lock().unwrap().get(label).copied().unwrap_or_default().rounds
    }

    pub fn reset(&self) {
        *self.total.lock().unwrap() = MeterSlot::default();
        self.by_label.lock().unwrap().clear();
    }
}

/// Payloads that can report their wire size for metering.
pub trait Meterable {
    fn wire_bytes(&self) -> u64;
}

impl Meterable for crate::util::tensor::Tensor {
    fn wire_bytes(&self) -> u64 {
        (self.data.len() * 4) as u64
    }
}

impl<A: Meterable, B: Meterable> Meterable for (A, B) {
    fn wire_bytes(&self) -> u64 {
        self.0.wire_bytes() + self.1.wire_bytes()
    }
}

impl<T: Meterable> Meterable for Vec<T> {
    fn wire_bytes(&self) -> u64 {
        self.iter().map(Meterable::wire_bytes).sum()
    }
}

/// Proof of a `post`: records the generation the round was posted under so
/// the matching `complete` knows when the round it joined has finished.
/// Receipts are collective-specific and single-use; holding one means the
/// rank has an outstanding round it must `complete` before posting again.
#[derive(Debug)]
#[must_use = "a posted round must be completed or the collective deadlocks"]
pub struct Receipt {
    gen: u64,
}

struct GatherState<T> {
    items: Vec<Option<T>>,
    count: usize,
    generation: u64,
    /// Session/round tag agreed by the round's first contributor; every
    /// other rank must present the same tag (serving-desync tripwire).
    tag: u64,
    /// Per-rank "posted but not yet completed" flags: a rank may have at
    /// most one round in flight, which is what keeps a completed result
    /// alive until every rank has read it (see module docs).
    outstanding: Vec<bool>,
    result: Vec<T>,
}

/// N-rank AllGather. Every rank contributes one `T` and receives all N
/// contributions in rank order.
pub struct Collective<T> {
    n: usize,
    label: &'static str,
    state: Mutex<GatherState<T>>,
    cv: Condvar,
    meter: Arc<CommMeter>,
}

impl<T: Clone + Meterable> Collective<T> {
    pub fn new(n: usize, meter: Arc<CommMeter>) -> Self {
        Self::labeled(n, "comm", meter)
    }

    pub fn labeled(n: usize, label: &'static str, meter: Arc<CommMeter>) -> Self {
        Collective {
            n,
            label,
            state: Mutex::new(GatherState {
                items: (0..n).map(|_| None).collect(),
                count: 0,
                generation: 0,
                tag: 0,
                outstanding: vec![false; n],
                result: Vec::new(),
            }),
            cv: Condvar::new(),
            meter,
        }
    }

    pub fn all_gather(&self, rank: usize, item: T) -> Vec<T> {
        self.all_gather_tagged(rank, 0, item)
    }

    /// AllGather with a per-round tag (the session id, or a digest of the
    /// decode batch). All ranks of a round must contribute the same tag —
    /// a mismatch means the hosts desynchronized across sessions, which
    /// would silently merge attention partials of *different* requests, so
    /// it is asserted rather than reported. Fused `post` + `complete`.
    pub fn all_gather_tagged(&self, rank: usize, tag: u64, item: T) -> Vec<T> {
        let receipt = self.post_tagged(rank, tag, item);
        self.complete(rank, receipt)
    }

    /// Non-blocking half: contribute this rank's payload to the open round
    /// (metering it as sent) and return a [`Receipt`] for [`Collective::complete`].
    /// Panics if this rank still has an uncompleted round outstanding — one
    /// round in flight per rank is the invariant the result-buffer safety
    /// argument rests on.
    pub fn post_tagged(&self, rank: usize, tag: u64, item: T) -> Receipt {
        assert!(rank < self.n, "rank {rank} out of {}", self.n);
        // Ring AllGather moves (N-1)/N of the total payload through each
        // link; meter the aggregate volume every rank sends once.
        self.meter.add(self.label, item.wire_bytes());
        let mut st = self.state.lock().unwrap();
        assert!(
            !st.outstanding[rank],
            "collective '{}': rank {rank} posted again before completing",
            self.label
        );
        let my_gen = st.generation;
        assert!(st.items[rank].is_none(), "rank {rank} double contribution");
        if st.count == 0 {
            st.tag = tag;
        } else {
            check_round_tag(self.label, st.tag, tag, rank);
        }
        st.items[rank] = Some(item);
        st.count += 1;
        st.outstanding[rank] = true;
        if st.count == self.n {
            // Round complete: snapshot result, clear contribution slots so
            // the next round can start immediately.
            st.result = st.items.iter_mut().map(|o| o.take().unwrap()).collect();
            st.count = 0;
            st.generation += 1;
            self.cv.notify_all();
        }
        Receipt { gen: my_gen }
    }

    /// Blocking half: wait until the posted round has all N contributions
    /// and return them in rank order.
    pub fn complete(&self, rank: usize, receipt: Receipt) -> Vec<T> {
        let mut st = self.state.lock().unwrap();
        debug_assert!(st.outstanding[rank], "complete without a post");
        while st.generation == receipt.gen {
            st = self.cv.wait(st).unwrap();
        }
        st.outstanding[rank] = false;
        st.result.clone()
    }

    /// Gather-to-root: only `root` receives the data (others get None).
    /// Implemented over all_gather for simplicity; volume metered the same
    /// since our cost model prices gather == all_gather lower bound.
    pub fn gather(&self, rank: usize, root: usize, item: T) -> Option<Vec<T>> {
        let all = self.all_gather(rank, item);
        (rank == root).then_some(all)
    }
}

struct RingState<T> {
    items: Vec<Option<T>>,
    count: usize,
    generation: u64,
    /// Round tag agreed by the first contributor (see `check_round_tag`).
    tag: u64,
    /// Per-rank "posted but not yet completed" flags (same invariant as
    /// [`GatherState::outstanding`]).
    outstanding: Vec<bool>,
    /// Per-rank delivery slots, taken exactly once per round.
    result: Vec<Option<T>>,
}

/// N-rank neighbor exchange: rank r sends one `T` to rank `(r+1) % N` and
/// receives the `T` sent by rank `(r-1+N) % N` — the NCCL send/recv pair of
/// Ring Attention's KV rotation, as one rendezvous. Repeating the exchange
/// N-1 times walks every payload all the way around the ring.
///
/// Unlike [`Collective::all_gather`] the received value is moved out (no
/// `Clone` bound): each rank owns exactly one incoming payload per round.
pub struct RingExchange<T> {
    n: usize,
    label: &'static str,
    state: Mutex<RingState<T>>,
    cv: Condvar,
    meter: Arc<CommMeter>,
}

impl<T: Meterable> RingExchange<T> {
    pub fn labeled(n: usize, label: &'static str, meter: Arc<CommMeter>) -> Self {
        RingExchange {
            n,
            label,
            state: Mutex::new(RingState {
                items: (0..n).map(|_| None).collect(),
                count: 0,
                generation: 0,
                tag: 0,
                outstanding: vec![false; n],
                result: (0..n).map(|_| None).collect(),
            }),
            cv: Condvar::new(),
            meter,
        }
    }

    pub fn exchange(&self, rank: usize, item: T) -> T {
        self.exchange_tagged(rank, 0, item)
    }

    /// Exchange with a per-round tag (session id): all ranks of a round
    /// must present the same tag — a mismatch means hosts desynchronized
    /// across sessions and would rotate KV blocks of *different* requests,
    /// so it panics (same tripwire as [`Collective::all_gather_tagged`]).
    /// Fused `post` + `complete`.
    pub fn exchange_tagged(&self, rank: usize, tag: u64, item: T) -> T {
        let receipt = self.post_tagged(rank, tag, item);
        self.complete(rank, receipt)
    }

    /// Non-blocking half: send this rank's payload towards its successor
    /// (metered) and return a [`Receipt`] for [`RingExchange::complete`].
    /// The chunked RingAttn prefill posts the outgoing block, computes the
    /// attention partials of the previously received block, and only then
    /// completes — communication/compute overlap at an explicit step
    /// boundary. Panics on a double post (one round in flight per rank).
    pub fn post_tagged(&self, rank: usize, tag: u64, item: T) -> Receipt {
        assert!(rank < self.n, "rank {rank} out of {}", self.n);
        // Each rank pushes its payload over one link per round.
        self.meter.add(self.label, item.wire_bytes());
        let mut st = self.state.lock().unwrap();
        assert!(
            !st.outstanding[rank],
            "ring '{}': rank {rank} posted again before completing",
            self.label
        );
        let my_gen = st.generation;
        assert!(st.items[rank].is_none(), "rank {rank} double contribution");
        if st.count == 0 {
            st.tag = tag;
        } else {
            check_round_tag(self.label, st.tag, tag, rank);
        }
        st.items[rank] = Some(item);
        st.count += 1;
        st.outstanding[rank] = true;
        if st.count == self.n {
            // Round complete: deliver each contribution to its successor.
            let n = self.n;
            let mut sent: Vec<Option<T>> = st.items.iter_mut().map(Option::take).collect();
            for (r, slot) in st.result.iter_mut().enumerate() {
                debug_assert!(slot.is_none(), "rank {r} never took its last delivery");
                *slot = sent[(r + n - 1) % n].take();
            }
            st.count = 0;
            st.generation += 1;
            self.cv.notify_all();
        }
        Receipt { gen: my_gen }
    }

    /// Blocking half: wait for the posted round to finish and take the
    /// payload delivered from this rank's predecessor (moved out — no
    /// `Clone` bound; each delivery is taken exactly once).
    pub fn complete(&self, rank: usize, receipt: Receipt) -> T {
        let mut st = self.state.lock().unwrap();
        debug_assert!(st.outstanding[rank], "complete without a post");
        while st.generation == receipt.gen {
            st = self.cv.wait(st).unwrap();
        }
        st.outstanding[rank] = false;
        st.result[rank].take().expect("ring delivery already taken")
    }
}

/// The per-round tag tripwire: a rank joining an open round must present
/// the tag the round was opened with. A mismatch means hosts desynchronized
/// across sessions — merging attention partials of *different* requests —
/// so it is a panic, not a recoverable error.
fn check_round_tag(label: &str, open_tag: u64, tag: u64, rank: usize) {
    assert_eq!(
        open_tag, tag,
        "collective '{label}' round tag mismatch: rank {rank} joined with \
         tag {tag} while the round in flight is {open_tag} (session desync)"
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tensor::Tensor;
    use std::thread;

    fn t(v: f32) -> Tensor {
        Tensor::new(vec![1], vec![v]).unwrap()
    }

    #[test]
    fn single_rank_allgather() {
        let c = Collective::new(1, Arc::new(CommMeter::default()));
        let r = c.all_gather(0, t(7.0));
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].data[0], 7.0);
    }

    #[test]
    fn meter_counts_bytes() {
        let m = Arc::new(CommMeter::default());
        let c = Collective::new(1, Arc::clone(&m));
        c.all_gather(0, t(1.0));
        assert_eq!(m.bytes_total(), 4);
        assert_eq!(m.rounds_total(), 1);
        m.reset();
        assert_eq!(m.bytes_total(), 0);
    }

    #[test]
    fn meter_separates_labels() {
        let m = Arc::new(CommMeter::default());
        let kv = Collective::labeled(1, "kv", Arc::clone(&m));
        let att = Collective::labeled(1, "att", Arc::clone(&m));
        kv.all_gather(0, t(1.0));
        kv.all_gather(0, t(2.0));
        att.all_gather(0, t(3.0));
        assert_eq!(m.bytes_for("kv"), 8);
        assert_eq!(m.rounds_for("kv"), 2);
        assert_eq!(m.bytes_for("att"), 4);
        assert_eq!(m.rounds_for("att"), 1);
        assert_eq!(m.bytes_total(), 12);
        assert_eq!(m.bytes_for("unknown"), 0);
        m.reset();
        assert_eq!(m.rounds_for("kv"), 0);
    }

    #[test]
    fn tagged_rounds_agree_across_ranks() {
        let n = 3;
        let c = Arc::new(Collective::new(n, Arc::new(CommMeter::default())));
        let mut handles = Vec::new();
        for rank in 0..n {
            let c = Arc::clone(&c);
            handles.push(thread::spawn(move || {
                // Successive rounds for different sessions: every rank
                // presents the matching tag and rounds complete normally.
                for sid in [7u64, 8, 7] {
                    let all = c.all_gather_tagged(rank, sid, t(rank as f32));
                    assert_eq!(all.len(), n);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn tag_check_accepts_match() {
        check_round_tag("att", 42, 42, 1);
    }

    #[test]
    #[should_panic(expected = "round tag mismatch")]
    fn tag_check_panics_on_mismatch() {
        check_round_tag("att", 7, 8, 1);
    }

    #[test]
    fn randomized_many_threads_many_rounds() {
        // Property test: arbitrary per-rank delays must never let rounds
        // interleave or deliver out-of-order results.
        let n = 5;
        let rounds = 40;
        let meter = Arc::new(CommMeter::default());
        let c = Arc::new(Collective::new(n, meter));
        let mut handles = Vec::new();
        for rank in 0..n {
            let c = Arc::clone(&c);
            handles.push(thread::spawn(move || {
                let mut rng = crate::util::rng::Rng::new(rank as u64 + 99);
                for round in 0..rounds {
                    if rng.below(3) == 0 {
                        std::thread::sleep(std::time::Duration::from_micros(
                            rng.below(200),
                        ));
                    }
                    let all = c.all_gather(rank, t((round * 100 + rank) as f32));
                    for (r, item) in all.iter().enumerate() {
                        assert_eq!(item.data[0] as usize, round * 100 + r);
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn ring_exchange_single_rank_returns_own_item() {
        let m = Arc::new(CommMeter::default());
        let r = RingExchange::labeled(1, "ring", Arc::clone(&m));
        let got = r.exchange(0, t(3.0));
        assert_eq!(got.data[0], 3.0);
        assert_eq!(m.bytes_for("ring"), 4);
    }

    #[test]
    fn ring_exchange_rotates_from_predecessor() {
        let n = 4;
        let m = Arc::new(CommMeter::default());
        let r = Arc::new(RingExchange::labeled(n, "ring", Arc::clone(&m)));
        let mut handles = Vec::new();
        for rank in 0..n {
            let r = Arc::clone(&r);
            handles.push(thread::spawn(move || {
                // Two rounds: payload forwarded onward each round, so after
                // round s a rank holds the item of origin (rank - s) mod n.
                let mut held = t(rank as f32);
                for s in 1..=2usize {
                    held = r.exchange_tagged(rank, 9, held);
                    let origin = (rank + n - s) % n;
                    assert_eq!(held.data[0] as usize, origin,
                               "rank {rank} step {s}");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // n ranks x 2 rounds, 4 bytes each.
        assert_eq!(m.bytes_for("ring"), (n * 2 * 4) as u64);
        assert_eq!(m.rounds_for("ring"), (n * 2) as u64);
    }

    #[test]
    fn split_post_complete_matches_fused_allgather() {
        let n = 3;
        let m = Arc::new(CommMeter::default());
        let c = Arc::new(Collective::labeled(n, "kv", Arc::clone(&m)));
        let mut handles = Vec::new();
        for rank in 0..n {
            let c = Arc::clone(&c);
            handles.push(thread::spawn(move || {
                // post → (compute window) → complete, twice; results must be
                // full rank-ordered rounds exactly like the fused call.
                for round in 0..2 {
                    let receipt = c.post_tagged(rank, 7, t((round * 10 + rank) as f32));
                    std::hint::black_box((0..500u64).sum::<u64>()); // "compute"
                    let all = c.complete(rank, receipt);
                    for (r, item) in all.iter().enumerate() {
                        assert_eq!(item.data[0] as usize, round * 10 + r);
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // Metered at post time: n ranks × 2 rounds × 4 bytes.
        assert_eq!(m.bytes_for("kv"), (n * 2 * 4) as u64);
    }

    #[test]
    fn split_ring_pipeline_overlaps_rounds() {
        // The chunked-prefill rotation pattern: post the held block, compute
        // on the previously received one, then complete — blocks still walk
        // the ring in origin order.
        let n = 4;
        let r = Arc::new(RingExchange::labeled(n, "ring", Arc::new(CommMeter::default())));
        let mut handles = Vec::new();
        for rank in 0..n {
            let r = Arc::clone(&r);
            handles.push(thread::spawn(move || {
                let mut held = t(rank as f32);
                for s in 1..n {
                    let receipt = r.post_tagged(rank, 3, held);
                    std::hint::black_box((0..500u64).sum::<u64>()); // "compute"
                    held = r.complete(rank, receipt);
                    let origin = (rank + n - s) % n;
                    assert_eq!(held.data[0] as usize, origin, "rank {rank} step {s}");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    #[should_panic(expected = "posted again before completing")]
    fn double_post_without_complete_panics() {
        let c = Collective::labeled(2, "att", Arc::new(CommMeter::default()));
        let r1 = c.post_tagged(0, 0, t(1.0));
        let _r2 = c.post_tagged(0, 0, t(2.0)); // must panic
        let _ = c.complete(0, r1);
    }

    #[test]
    fn gather_delivers_to_root_only() {
        let n = 3;
        let c = Arc::new(Collective::new(n, Arc::new(CommMeter::default())));
        let mut handles = Vec::new();
        for rank in 0..n {
            let c = Arc::clone(&c);
            handles.push(thread::spawn(move || {
                let got = c.gather(rank, 1, t(rank as f32));
                (rank, got.is_some())
            }));
        }
        for h in handles {
            let (rank, has) = h.join().unwrap();
            assert_eq!(has, rank == 1);
        }
    }
}
