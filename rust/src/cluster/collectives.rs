//! Rendezvous collectives over threads (Mutex + Condvar), with payload
//! metering for the interconnect cost model.
//!
//! Correctness argument for `all_gather` (also property-tested): a round
//! completes only after all N ranks contribute; the completed result is
//! only replaced when all N ranks of the *next* round have contributed,
//! and a rank cannot contribute to round r+1 before returning from round
//! r — so every rank reads an intact result.

use std::sync::{Arc, Condvar, Mutex};

/// Bytes-on-the-wire meter, summed across all collectives of a fabric.
#[derive(Default)]
pub struct CommMeter {
    bytes: Mutex<u64>,
    rounds: Mutex<u64>,
}

impl CommMeter {
    pub fn add(&self, bytes: u64) {
        *self.bytes.lock().unwrap() += bytes;
        *self.rounds.lock().unwrap() += 1;
    }

    pub fn bytes_total(&self) -> u64 {
        *self.bytes.lock().unwrap()
    }

    pub fn rounds_total(&self) -> u64 {
        *self.rounds.lock().unwrap()
    }

    pub fn reset(&self) {
        *self.bytes.lock().unwrap() = 0;
        *self.rounds.lock().unwrap() = 0;
    }
}

/// Payloads that can report their wire size for metering.
pub trait Meterable {
    fn wire_bytes(&self) -> u64;
}

impl Meterable for crate::util::tensor::Tensor {
    fn wire_bytes(&self) -> u64 {
        (self.data.len() * 4) as u64
    }
}

impl<A: Meterable, B: Meterable> Meterable for (A, B) {
    fn wire_bytes(&self) -> u64 {
        self.0.wire_bytes() + self.1.wire_bytes()
    }
}

impl<T: Meterable> Meterable for Vec<T> {
    fn wire_bytes(&self) -> u64 {
        self.iter().map(Meterable::wire_bytes).sum()
    }
}

struct GatherState<T> {
    items: Vec<Option<T>>,
    count: usize,
    generation: u64,
    result: Vec<T>,
}

/// N-rank AllGather. Every rank contributes one `T` and receives all N
/// contributions in rank order.
pub struct Collective<T> {
    n: usize,
    state: Mutex<GatherState<T>>,
    cv: Condvar,
    meter: Arc<CommMeter>,
}

impl<T: Clone + Meterable> Collective<T> {
    pub fn new(n: usize, meter: Arc<CommMeter>) -> Self {
        Collective {
            n,
            state: Mutex::new(GatherState {
                items: (0..n).map(|_| None).collect(),
                count: 0,
                generation: 0,
                result: Vec::new(),
            }),
            cv: Condvar::new(),
            meter,
        }
    }

    pub fn all_gather(&self, rank: usize, item: T) -> Vec<T> {
        assert!(rank < self.n, "rank {rank} out of {}", self.n);
        // Ring AllGather moves (N-1)/N of the total payload through each
        // link; meter the aggregate volume every rank sends once.
        self.meter.add(item.wire_bytes());
        let mut st = self.state.lock().unwrap();
        let my_gen = st.generation;
        assert!(st.items[rank].is_none(), "rank {rank} double contribution");
        st.items[rank] = Some(item);
        st.count += 1;
        if st.count == self.n {
            // Round complete: snapshot result, clear contribution slots so
            // the next round can start immediately.
            st.result = st.items.iter_mut().map(|o| o.take().unwrap()).collect();
            st.count = 0;
            st.generation += 1;
            self.cv.notify_all();
        } else {
            while st.generation == my_gen {
                st = self.cv.wait(st).unwrap();
            }
        }
        st.result.clone()
    }

    /// Gather-to-root: only `root` receives the data (others get None).
    /// Implemented over all_gather for simplicity; volume metered the same
    /// since our cost model prices gather == all_gather lower bound.
    pub fn gather(&self, rank: usize, root: usize, item: T) -> Option<Vec<T>> {
        let all = self.all_gather(rank, item);
        (rank == root).then_some(all)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tensor::Tensor;
    use std::thread;

    fn t(v: f32) -> Tensor {
        Tensor::new(vec![1], vec![v]).unwrap()
    }

    #[test]
    fn single_rank_allgather() {
        let c = Collective::new(1, Arc::new(CommMeter::default()));
        let r = c.all_gather(0, t(7.0));
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].data[0], 7.0);
    }

    #[test]
    fn meter_counts_bytes() {
        let m = Arc::new(CommMeter::default());
        let c = Collective::new(1, Arc::clone(&m));
        c.all_gather(0, t(1.0));
        assert_eq!(m.bytes_total(), 4);
        assert_eq!(m.rounds_total(), 1);
        m.reset();
        assert_eq!(m.bytes_total(), 0);
    }

    #[test]
    fn randomized_many_threads_many_rounds() {
        // Property test: arbitrary per-rank delays must never let rounds
        // interleave or deliver out-of-order results.
        let n = 5;
        let rounds = 40;
        let meter = Arc::new(CommMeter::default());
        let c = Arc::new(Collective::new(n, meter));
        let mut handles = Vec::new();
        for rank in 0..n {
            let c = Arc::clone(&c);
            handles.push(thread::spawn(move || {
                let mut rng = crate::util::rng::Rng::new(rank as u64 + 99);
                for round in 0..rounds {
                    if rng.below(3) == 0 {
                        std::thread::sleep(std::time::Duration::from_micros(
                            rng.below(200),
                        ));
                    }
                    let all = c.all_gather(rank, t((round * 100 + rank) as f32));
                    for (r, item) in all.iter().enumerate() {
                        assert_eq!(item.data[0] as usize, round * 100 + r);
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn gather_delivers_to_root_only() {
        let n = 3;
        let c = Arc::new(Collective::new(n, Arc::new(CommMeter::default())));
        let mut handles = Vec::new();
        for rank in 0..n {
            let c = Arc::clone(&c);
            handles.push(thread::spawn(move || {
                let got = c.gather(rank, 1, t(rank as f32));
                (rank, got.is_some())
            }));
        }
        for h in handles {
            let (rank, has) = h.join().unwrap();
            assert_eq!(has, rank == 1);
        }
    }
}
