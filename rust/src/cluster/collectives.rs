//! Rendezvous collectives over threads (Mutex + Condvar), with payload
//! metering for the interconnect cost model.
//!
//! Two primitives back the executable cluster modes (see
//! `docs/architecture.md`, "Method matrix"):
//!
//! * [`Collective`] — N-rank AllGather (APB's compressed-block pass, label
//!   `kv`; the decode partial-attention merge, label `att`);
//! * [`RingExchange`] — neighbor send/recv (RingAttn's KV-block rotation,
//!   label `ring`): each rank sends to its successor and receives from its
//!   predecessor, so N-1 consecutive exchanges deliver every rank's
//!   original payload to every other rank exactly once (property-tested).
//!
//! Both primitives are built from split **`post` / `complete` halves** (the
//! NCCL-style async boundary): `post_tagged` contributes this rank's
//! payload without blocking and returns a [`Receipt`]; `complete` blocks
//! until the round has every rank's contribution and delivers the result.
//! The fused `all_gather_tagged` / `exchange_tagged` wrappers are
//! `post + complete` back to back. The chunked-prefill state machine
//! (`coordinator::prefill`) exploits the split to overlap communication
//! with compute: post the outgoing payload, run attention on already-held
//! rows, and only then complete the receive.
//!
//! # Rendezvous failure, cancellation, and the wire model
//!
//! With hosts on real OS threads a wedged peer must not become a silent
//! deadlock, so `complete` waits with a per-collective **timeout** (default
//! 30 s, [`Collective::set_timeout`]) and converts expiry into a structured
//! [`ClusterError::RendezvousTimeout`] — the receipt stays live, and
//! [`Collective::cancel`] retracts the contribution (open round) or
//! discards the delivery (completed round) so the fabric drains and other
//! sessions keep running.
//!
//! Rendezvous on one machine takes nanoseconds, which leaves nothing for
//! compute to hide behind. The per-collective [`WireModel`] fixes that:
//! when a round completes, its delivery is stamped `ready_at = now +
//! delay(round_bytes)`, and `complete` does not return before `ready_at`.
//! [`Collective::complete_timed`] additionally reports the round's
//! [`RoundWindow`] — `window_s` (post → delivery ready), `exposed_s` (time
//! actually blocked in `complete`) and `hidden_s` (window − exposed, the
//! communication the caller's compute covered) — which is how
//! `benches/fig1_prefill` measures, rather than models, overlap.
//!
//! Correctness argument for `all_gather` (also property-tested): a round
//! completes only after all N ranks contribute; the completed result is
//! only replaced when all N ranks of the *next* round have contributed,
//! and a rank must `complete` round r before it may `post` round r+1 (the
//! `outstanding` flag) — so every rank reads an intact result.
//! `RingExchange` inherits the same argument with per-rank `Option` result
//! slots taken exactly once.

use std::collections::BTreeMap;
use std::fmt;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Default per-round rendezvous timeout: far above any sane round on one
/// machine, small enough that a wedged CI job fails with a diagnosis.
const DEFAULT_ROUND_TIMEOUT: Duration = Duration::from_secs(30);

/// Structured failure of a collective round — the typed alternative to a
/// deadlocked thread. Carries enough to diagnose *which* rendezvous on
/// *which* rank wedged.
#[derive(Debug, Clone, PartialEq)]
pub enum ClusterError {
    /// `complete` waited past the collective's round timeout: a peer rank
    /// never posted (crashed, aborted, or desynchronized). The receipt is
    /// still live — `cancel` it to drain the fabric.
    RendezvousTimeout {
        /// Meter label of the collective ("kv", "att", "ring", "qring").
        label: &'static str,
        /// The rank whose `complete` gave up.
        rank: usize,
        /// Tag of the round left open (session id / batch digest).
        tag: u64,
        /// How long the rank waited before giving up.
        waited_s: f64,
    },
}

impl fmt::Display for ClusterError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ClusterError::RendezvousTimeout { label, rank, tag, waited_s } => write!(
                f,
                "collective '{label}': rank {rank} timed out after {waited_s:.3}s \
                 waiting on round tag {tag} — a peer rank is wedged or dropped out"
            ),
        }
    }
}

impl std::error::Error for ClusterError {}

/// Timing of one completed round as seen by one rank, for measured (not
/// modeled) comm/compute overlap accounting.
#[derive(Debug, Default, Clone, Copy)]
pub struct RoundWindow {
    /// post → delivery-ready wall time: the full communication window.
    pub window_s: f64,
    /// Time this rank actually spent blocked inside `complete` (rendezvous
    /// wait plus any remaining wire delay).
    pub exposed_s: f64,
    /// `max(0, window − exposed)` — the part of the window the caller's
    /// own compute covered.
    pub hidden_s: f64,
}

/// How long a completed round's payload takes to traverse the wire.
///
/// `Instant` (the default) keeps rounds delivery-ready the moment the last
/// rank posts — bit-identical behavior and near-zero windows. `Modeled`
/// stamps each round with `latency + bytes/bandwidth`, giving compute a
/// real window to hide behind so measured overlap is meaningful on a
/// single machine. The model only delays delivery; payloads and meters are
/// untouched, so results stay bit-identical across wire models.
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub enum WireModel {
    /// Zero wire time: delivery is ready when the round completes.
    #[default]
    Instant,
    /// `latency_us + 8·bytes / (gbps·1e9)` seconds per round.
    Modeled { gbps: f64, latency_us: f64 },
}

impl WireModel {
    /// Wire traversal time for a round carrying `bytes`.
    pub fn delay(&self, bytes: u64) -> Duration {
        match *self {
            WireModel::Instant => Duration::ZERO,
            WireModel::Modeled { gbps, latency_us } => {
                let secs = latency_us * 1e-6 + (bytes as f64 * 8.0) / (gbps * 1e9);
                Duration::from_secs_f64(secs.max(0.0))
            }
        }
    }
}

#[derive(Default, Clone, Copy)]
struct MeterSlot {
    bytes: u64,
    rounds: u64,
}

/// Bytes-on-the-wire meter, summed across all collectives of a fabric.
///
/// Contributions are recorded both in the fabric total and under the
/// contributing collective's label ("kv" for the prefill compressed-block
/// AllGather, "att" for the decode partial-attention AllGather), so the
/// prefill and decode communication volumes stay separable even though the
/// serving loop interleaves them.
#[derive(Default)]
pub struct CommMeter {
    total: Mutex<MeterSlot>,
    by_label: Mutex<BTreeMap<&'static str, MeterSlot>>,
}

impl CommMeter {
    pub fn add(&self, label: &'static str, bytes: u64) {
        {
            let mut t = self.total.lock().unwrap();
            t.bytes += bytes;
            t.rounds += 1;
        }
        let mut m = self.by_label.lock().unwrap();
        let slot = m.entry(label).or_default();
        slot.bytes += bytes;
        slot.rounds += 1;
    }

    pub fn bytes_total(&self) -> u64 {
        self.total.lock().unwrap().bytes
    }

    pub fn rounds_total(&self) -> u64 {
        self.total.lock().unwrap().rounds
    }

    pub fn bytes_for(&self, label: &str) -> u64 {
        self.by_label.lock().unwrap().get(label).copied().unwrap_or_default().bytes
    }

    /// Per-rank contribution count under a label: one batched decode step
    /// contributes `n_hosts * n_layers` "att" rounds regardless of how many
    /// sessions ride in the batch.
    pub fn rounds_for(&self, label: &str) -> u64 {
        self.by_label.lock().unwrap().get(label).copied().unwrap_or_default().rounds
    }

    pub fn reset(&self) {
        *self.total.lock().unwrap() = MeterSlot::default();
        self.by_label.lock().unwrap().clear();
    }
}

/// Payloads that can report their wire size for metering.
pub trait Meterable {
    fn wire_bytes(&self) -> u64;
}

impl Meterable for crate::util::tensor::Tensor {
    fn wire_bytes(&self) -> u64 {
        (self.data.len() * 4) as u64
    }
}

impl<A: Meterable, B: Meterable> Meterable for (A, B) {
    fn wire_bytes(&self) -> u64 {
        self.0.wire_bytes() + self.1.wire_bytes()
    }
}

impl<T: Meterable> Meterable for Vec<T> {
    fn wire_bytes(&self) -> u64 {
        self.iter().map(Meterable::wire_bytes).sum()
    }
}

/// One collective primitive as the coordinator sees it: the common face of
/// [`Collective`] (AllGather, delivery `Vec<T>`) and [`RingExchange`]
/// (neighbor exchange, delivery `T`), so `PrefillMachine` and the decode
/// paths are generic over the collective instead of matching on concrete
/// types.
///
/// # Outstanding-receipt safety
///
/// The whole API leans on one invariant: **a rank has at most one round in
/// flight per collective** (`post_tagged` panics otherwise). That is what
/// makes the single delivery buffer sound — round g+1 cannot complete until
/// every rank has posted it, and no rank may post g+1 before completing (or
/// cancelling) g, so while any rank sits in `complete` for round g the
/// buffer still holds round g's delivery and `ready_at` still holds round
/// g's stamp; both are read and `outstanding` cleared under one lock. It is
/// also what makes a [`Receipt`] meaningful after a timeout: the failed
/// `complete` leaves `outstanding` set, so the receipt remains the unique
/// handle for the in-flight round until `cancel` consumes it. Dropping a
/// receipt without `complete`/`cancel` wedges the rank's slot — hence the
/// `#[must_use]` on [`Receipt`] and the hard assert on stale completes.
pub trait Fabric {
    /// What one rank contributes per round.
    type Payload: Meterable;
    /// What one rank receives per round.
    type Delivery;

    /// The meter label this collective records under.
    fn label(&self) -> &'static str;

    /// Non-blocking half: contribute this rank's payload (metered at post
    /// time) and return the [`Receipt`] for `complete`/`cancel`.
    fn post_tagged(&self, rank: usize, tag: u64, item: Self::Payload) -> Receipt;

    /// Blocking half: wait (bounded by the round timeout) for the posted
    /// round and deliver. On [`ClusterError::RendezvousTimeout`] the
    /// receipt stays live for `cancel`.
    fn complete(&self, rank: usize, receipt: &Receipt) -> Result<Self::Delivery, ClusterError>;

    /// `complete` plus the round's measured [`RoundWindow`].
    fn complete_timed(
        &self,
        rank: usize,
        receipt: &Receipt,
    ) -> Result<(Self::Delivery, RoundWindow), ClusterError>;

    /// Abandon an in-flight round: retract the contribution if the round is
    /// still open, discard the delivery if it already completed. Never
    /// blocks; consumes the receipt.
    fn cancel(&self, rank: usize, receipt: Receipt);

    /// Wire size of a payload (what the meter would record).
    fn bytes_of(&self, item: &Self::Payload) -> u64 {
        item.wire_bytes()
    }
}

/// `complete_timed` through any [`Fabric`], folding the round's window into
/// the caller's timing buckets: `exposed` → `comm_s` (time actually
/// blocked), plus the full `window_s` / `hidden_s` pair the measured
/// overlap fraction is computed from.
pub fn complete_accounted<F: Fabric>(
    fabric: &F,
    rank: usize,
    receipt: &Receipt,
    comm_s: &mut f64,
    window_s: &mut f64,
    hidden_s: &mut f64,
) -> Result<F::Delivery, ClusterError> {
    let (delivery, w) = fabric.complete_timed(rank, receipt)?;
    *comm_s += w.exposed_s;
    *window_s += w.window_s;
    *hidden_s += w.hidden_s;
    Ok(delivery)
}

/// Proof of a `post`: records the generation the round was posted under so
/// the matching `complete` knows when the round it joined has finished, and
/// the post instant the round's [`RoundWindow`] is measured from.
/// Receipts are collective-specific and single-use; holding one means the
/// rank has an outstanding round it must `complete` or `cancel` before
/// posting again.
#[derive(Debug)]
#[must_use = "a posted round must be completed or cancelled, or the collective wedges"]
pub struct Receipt {
    gen: u64,
    posted_at: Instant,
}

struct GatherState<T> {
    items: Vec<Option<T>>,
    count: usize,
    generation: u64,
    /// Session/round tag agreed by the round's first contributor; every
    /// other rank must present the same tag (serving-desync tripwire).
    tag: u64,
    /// Per-rank "posted but not yet completed" flags: a rank may have at
    /// most one round in flight, which is what keeps a completed result
    /// alive until every rank has read it (see [`Fabric`] docs).
    outstanding: Vec<bool>,
    /// Payload bytes contributed to the round in flight (for the wire
    /// model's delivery stamp; reset when the round completes).
    round_bytes: u64,
    /// When the last completed round's delivery clears the wire
    /// ([`WireModel::delay`] past the completing post).
    ready_at: Option<Instant>,
    result: Vec<T>,
}

/// N-rank AllGather. Every rank contributes one `T` and receives all N
/// contributions in rank order.
pub struct Collective<T> {
    n: usize,
    label: &'static str,
    state: Mutex<GatherState<T>>,
    cv: Condvar,
    meter: Arc<CommMeter>,
    wire: Mutex<WireModel>,
    timeout: Mutex<Duration>,
}

impl<T: Clone + Meterable> Collective<T> {
    pub fn new(n: usize, meter: Arc<CommMeter>) -> Self {
        Self::labeled(n, "comm", meter)
    }

    pub fn labeled(n: usize, label: &'static str, meter: Arc<CommMeter>) -> Self {
        Collective {
            n,
            label,
            state: Mutex::new(GatherState {
                items: (0..n).map(|_| None).collect(),
                count: 0,
                generation: 0,
                tag: 0,
                outstanding: vec![false; n],
                round_bytes: 0,
                ready_at: None,
                result: Vec::new(),
            }),
            cv: Condvar::new(),
            meter,
            wire: Mutex::new(WireModel::default()),
            timeout: Mutex::new(DEFAULT_ROUND_TIMEOUT),
        }
    }

    /// Swap the wire model used to stamp future rounds' delivery times.
    pub fn set_wire(&self, wire: WireModel) {
        *self.wire.lock().unwrap() = wire;
    }

    /// Set the per-round rendezvous timeout for future `complete` calls.
    pub fn set_timeout(&self, timeout: Duration) {
        *self.timeout.lock().unwrap() = timeout;
    }

    pub fn all_gather(&self, rank: usize, item: T) -> Vec<T> {
        self.all_gather_tagged(rank, 0, item)
    }

    /// AllGather with a per-round tag (the session id, or a digest of the
    /// decode batch). All ranks of a round must contribute the same tag —
    /// a mismatch means the hosts desynchronized across sessions, which
    /// would silently merge attention partials of *different* requests, so
    /// it is asserted rather than reported. Fused `post` + `complete`; a
    /// rendezvous timeout is a panic here (fused callers have no way to
    /// drain), use the split halves where recovery matters.
    pub fn all_gather_tagged(&self, rank: usize, tag: u64, item: T) -> Vec<T> {
        let receipt = self.post_tagged(rank, tag, item);
        match self.complete(rank, &receipt) {
            Ok(all) => all,
            Err(e) => panic!("{e}"),
        }
    }

    /// Non-blocking half: contribute this rank's payload to the open round
    /// (metering it as sent) and return a [`Receipt`] for [`Collective::complete`].
    /// Panics if this rank still has an uncompleted round outstanding — one
    /// round in flight per rank is the invariant the result-buffer safety
    /// argument rests on (see [`Fabric`]).
    pub fn post_tagged(&self, rank: usize, tag: u64, item: T) -> Receipt {
        assert!(rank < self.n, "rank {rank} out of {}", self.n);
        // Ring AllGather moves (N-1)/N of the total payload through each
        // link; meter the aggregate volume every rank sends once.
        let bytes = item.wire_bytes();
        self.meter.add(self.label, bytes);
        let posted_at = Instant::now();
        let mut st = self.state.lock().unwrap();
        assert!(
            !st.outstanding[rank],
            "collective '{}': rank {rank} posted again before completing",
            self.label
        );
        let my_gen = st.generation;
        assert!(st.items[rank].is_none(), "rank {rank} double contribution");
        if st.count == 0 {
            st.tag = tag;
        } else {
            check_round_tag(self.label, st.tag, tag, rank);
        }
        st.items[rank] = Some(item);
        st.count += 1;
        st.round_bytes += bytes;
        st.outstanding[rank] = true;
        if st.count == self.n {
            // Round complete: snapshot result, stamp its wire-ready time,
            // clear contribution slots so the next round can start.
            st.result = st.items.iter_mut().map(|o| o.take().unwrap()).collect();
            st.count = 0;
            st.generation += 1;
            let delay = self.wire.lock().unwrap().delay(st.round_bytes);
            st.ready_at = Some(Instant::now() + delay);
            st.round_bytes = 0;
            self.cv.notify_all();
        }
        Receipt { gen: my_gen, posted_at }
    }

    /// Blocking half: wait until the posted round has all N contributions
    /// (bounded by the round timeout) and return them in rank order. On
    /// [`ClusterError::RendezvousTimeout`] the receipt stays live — the
    /// caller must `cancel` it to drain the fabric.
    pub fn complete(&self, rank: usize, receipt: &Receipt) -> Result<Vec<T>, ClusterError> {
        self.complete_timed(rank, receipt).map(|(all, _)| all)
    }

    /// [`Collective::complete`] plus the round's measured [`RoundWindow`].
    pub fn complete_timed(
        &self,
        rank: usize,
        receipt: &Receipt,
    ) -> Result<(Vec<T>, RoundWindow), ClusterError> {
        let start = Instant::now();
        let timeout = *self.timeout.lock().unwrap();
        let mut st = self.state.lock().unwrap();
        assert!(
            st.outstanding[rank],
            "collective '{}': rank {rank} completing a stale receipt",
            self.label
        );
        while st.generation == receipt.gen {
            let waited = start.elapsed();
            if waited >= timeout {
                return Err(ClusterError::RendezvousTimeout {
                    label: self.label,
                    rank,
                    tag: st.tag,
                    waited_s: waited.as_secs_f64(),
                });
            }
            st = self.cv.wait_timeout(st, timeout - waited).unwrap().0;
        }
        // Read the delivery and its wire stamp and release the slot under
        // one lock (the outstanding invariant keeps both round-correct).
        st.outstanding[rank] = false;
        let ready_at = st.ready_at.expect("completed round carries a ready_at stamp");
        let result = st.result.clone();
        drop(st);
        sleep_until(ready_at);
        Ok((result, round_window(receipt, start, ready_at)))
    }

    /// Abandon this rank's in-flight round. If the round is still open the
    /// contribution is retracted (peers see an N-1 round that can complete
    /// once this slot is reposted by another session); if the round already
    /// completed the delivery is simply never read. Never blocks, so a
    /// leader can cancel all ranks of a dead session without deadlocking.
    pub fn cancel(&self, rank: usize, receipt: Receipt) {
        let mut st = self.state.lock().unwrap();
        assert!(
            st.outstanding[rank],
            "collective '{}': rank {rank} cancelling a stale receipt",
            self.label
        );
        if st.generation == receipt.gen {
            let item = st.items[rank].take().expect("open round holds this rank's payload");
            st.count -= 1;
            st.round_bytes = st.round_bytes.saturating_sub(item.wire_bytes());
        }
        st.outstanding[rank] = false;
    }

    /// Gather-to-root: only `root` receives the data (others get None).
    /// Implemented over all_gather for simplicity; volume metered the same
    /// since our cost model prices gather == all_gather lower bound.
    pub fn gather(&self, rank: usize, root: usize, item: T) -> Option<Vec<T>> {
        let all = self.all_gather(rank, item);
        (rank == root).then_some(all)
    }
}

impl<T: Clone + Meterable> Fabric for Collective<T> {
    type Payload = T;
    type Delivery = Vec<T>;

    fn label(&self) -> &'static str {
        self.label
    }

    fn post_tagged(&self, rank: usize, tag: u64, item: T) -> Receipt {
        Collective::post_tagged(self, rank, tag, item)
    }

    fn complete(&self, rank: usize, receipt: &Receipt) -> Result<Vec<T>, ClusterError> {
        Collective::complete(self, rank, receipt)
    }

    fn complete_timed(
        &self,
        rank: usize,
        receipt: &Receipt,
    ) -> Result<(Vec<T>, RoundWindow), ClusterError> {
        Collective::complete_timed(self, rank, receipt)
    }

    fn cancel(&self, rank: usize, receipt: Receipt) {
        Collective::cancel(self, rank, receipt)
    }
}

struct RingState<T> {
    items: Vec<Option<T>>,
    count: usize,
    generation: u64,
    /// Round tag agreed by the first contributor (see `check_round_tag`).
    tag: u64,
    /// Per-rank "posted but not yet completed" flags (same invariant as
    /// [`GatherState::outstanding`]).
    outstanding: Vec<bool>,
    /// Payload bytes of the round in flight (wire-model stamp input).
    round_bytes: u64,
    /// When the last completed round's deliveries clear the wire.
    ready_at: Option<Instant>,
    /// Per-rank delivery slots, taken exactly once per round.
    result: Vec<Option<T>>,
}

/// N-rank neighbor exchange: rank r sends one `T` to rank `(r+1) % N` and
/// receives the `T` sent by rank `(r-1+N) % N` — the NCCL send/recv pair of
/// Ring Attention's KV rotation, as one rendezvous. Repeating the exchange
/// N-1 times walks every payload all the way around the ring.
///
/// Unlike [`Collective::all_gather`] the received value is moved out (no
/// `Clone` bound): each rank owns exactly one incoming payload per round.
pub struct RingExchange<T> {
    n: usize,
    label: &'static str,
    state: Mutex<RingState<T>>,
    cv: Condvar,
    meter: Arc<CommMeter>,
    wire: Mutex<WireModel>,
    timeout: Mutex<Duration>,
}

impl<T: Meterable> RingExchange<T> {
    pub fn labeled(n: usize, label: &'static str, meter: Arc<CommMeter>) -> Self {
        RingExchange {
            n,
            label,
            state: Mutex::new(RingState {
                items: (0..n).map(|_| None).collect(),
                count: 0,
                generation: 0,
                tag: 0,
                outstanding: vec![false; n],
                round_bytes: 0,
                ready_at: None,
                result: (0..n).map(|_| None).collect(),
            }),
            cv: Condvar::new(),
            meter,
            wire: Mutex::new(WireModel::default()),
            timeout: Mutex::new(DEFAULT_ROUND_TIMEOUT),
        }
    }

    /// Swap the wire model used to stamp future rounds' delivery times.
    pub fn set_wire(&self, wire: WireModel) {
        *self.wire.lock().unwrap() = wire;
    }

    /// Set the per-round rendezvous timeout for future `complete` calls.
    pub fn set_timeout(&self, timeout: Duration) {
        *self.timeout.lock().unwrap() = timeout;
    }

    pub fn exchange(&self, rank: usize, item: T) -> T {
        self.exchange_tagged(rank, 0, item)
    }

    /// Exchange with a per-round tag (session id): all ranks of a round
    /// must present the same tag — a mismatch means hosts desynchronized
    /// across sessions and would rotate KV blocks of *different* requests,
    /// so it panics (same tripwire as [`Collective::all_gather_tagged`]).
    /// Fused `post` + `complete`; a rendezvous timeout panics here, use the
    /// split halves where recovery matters.
    pub fn exchange_tagged(&self, rank: usize, tag: u64, item: T) -> T {
        let receipt = self.post_tagged(rank, tag, item);
        match self.complete(rank, &receipt) {
            Ok(got) => got,
            Err(e) => panic!("{e}"),
        }
    }

    /// Non-blocking half: send this rank's payload towards its successor
    /// (metered) and return a [`Receipt`] for [`RingExchange::complete`].
    /// The chunked RingAttn prefill posts the outgoing block, computes the
    /// attention partials of the previously received block, and only then
    /// completes — communication/compute overlap at an explicit step
    /// boundary. Panics on a double post (one round in flight per rank).
    pub fn post_tagged(&self, rank: usize, tag: u64, item: T) -> Receipt {
        assert!(rank < self.n, "rank {rank} out of {}", self.n);
        // Each rank pushes its payload over one link per round.
        let bytes = item.wire_bytes();
        self.meter.add(self.label, bytes);
        let posted_at = Instant::now();
        let mut st = self.state.lock().unwrap();
        assert!(
            !st.outstanding[rank],
            "ring '{}': rank {rank} posted again before completing",
            self.label
        );
        let my_gen = st.generation;
        assert!(st.items[rank].is_none(), "rank {rank} double contribution");
        if st.count == 0 {
            st.tag = tag;
        } else {
            check_round_tag(self.label, st.tag, tag, rank);
        }
        st.items[rank] = Some(item);
        st.count += 1;
        st.round_bytes += bytes;
        st.outstanding[rank] = true;
        if st.count == self.n {
            // Round complete: deliver each contribution to its successor
            // and stamp the deliveries' wire-ready time.
            let n = self.n;
            let mut sent: Vec<Option<T>> = st.items.iter_mut().map(Option::take).collect();
            for (r, slot) in st.result.iter_mut().enumerate() {
                debug_assert!(slot.is_none(), "rank {r} never took its last delivery");
                *slot = sent[(r + n - 1) % n].take();
            }
            st.count = 0;
            st.generation += 1;
            let delay = self.wire.lock().unwrap().delay(st.round_bytes);
            st.ready_at = Some(Instant::now() + delay);
            st.round_bytes = 0;
            self.cv.notify_all();
        }
        Receipt { gen: my_gen, posted_at }
    }

    /// Blocking half: wait (bounded by the round timeout) for the posted
    /// round to finish and take the payload delivered from this rank's
    /// predecessor (moved out — no `Clone` bound; each delivery is taken
    /// exactly once). On [`ClusterError::RendezvousTimeout`] the receipt
    /// stays live — `cancel` it to drain the fabric.
    pub fn complete(&self, rank: usize, receipt: &Receipt) -> Result<T, ClusterError> {
        self.complete_timed(rank, receipt).map(|(got, _)| got)
    }

    /// [`RingExchange::complete`] plus the round's measured [`RoundWindow`].
    pub fn complete_timed(
        &self,
        rank: usize,
        receipt: &Receipt,
    ) -> Result<(T, RoundWindow), ClusterError> {
        let start = Instant::now();
        let timeout = *self.timeout.lock().unwrap();
        let mut st = self.state.lock().unwrap();
        assert!(
            st.outstanding[rank],
            "ring '{}': rank {rank} completing a stale receipt",
            self.label
        );
        while st.generation == receipt.gen {
            let waited = start.elapsed();
            if waited >= timeout {
                return Err(ClusterError::RendezvousTimeout {
                    label: self.label,
                    rank,
                    tag: st.tag,
                    waited_s: waited.as_secs_f64(),
                });
            }
            st = self.cv.wait_timeout(st, timeout - waited).unwrap().0;
        }
        st.outstanding[rank] = false;
        let ready_at = st.ready_at.expect("completed round carries a ready_at stamp");
        let got = st.result[rank].take().expect("ring delivery already taken");
        drop(st);
        sleep_until(ready_at);
        Ok((got, round_window(receipt, start, ready_at)))
    }

    /// Abandon this rank's in-flight round: retract the payload if the
    /// round is still open, discard the undelivered payload if the round
    /// already completed (so the next round's delivery slot is free).
    /// Never blocks.
    pub fn cancel(&self, rank: usize, receipt: Receipt) {
        let mut st = self.state.lock().unwrap();
        assert!(
            st.outstanding[rank],
            "ring '{}': rank {rank} cancelling a stale receipt",
            self.label
        );
        if st.generation == receipt.gen {
            let item = st.items[rank].take().expect("open round holds this rank's payload");
            st.count -= 1;
            st.round_bytes = st.round_bytes.saturating_sub(item.wire_bytes());
        } else {
            st.result[rank].take();
        }
        st.outstanding[rank] = false;
    }
}

impl<T: Meterable> Fabric for RingExchange<T> {
    type Payload = T;
    type Delivery = T;

    fn label(&self) -> &'static str {
        self.label
    }

    fn post_tagged(&self, rank: usize, tag: u64, item: T) -> Receipt {
        RingExchange::post_tagged(self, rank, tag, item)
    }

    fn complete(&self, rank: usize, receipt: &Receipt) -> Result<T, ClusterError> {
        RingExchange::complete(self, rank, receipt)
    }

    fn complete_timed(
        &self,
        rank: usize,
        receipt: &Receipt,
    ) -> Result<(T, RoundWindow), ClusterError> {
        RingExchange::complete_timed(self, rank, receipt)
    }

    fn cancel(&self, rank: usize, receipt: Receipt) {
        RingExchange::cancel(self, rank, receipt)
    }
}

/// Block until `ready_at` — the wire-model delivery delay as seen by one
/// completing rank (no lock held while sleeping).
fn sleep_until(ready_at: Instant) {
    let now = Instant::now();
    if ready_at > now {
        std::thread::sleep(ready_at - now);
    }
}

/// Assemble the measured [`RoundWindow`] for one completed round:
/// `window` spans post → wire-ready, `exposed` spans the `complete` call
/// itself (including any wire sleep), `hidden` is whatever compute between
/// post and complete covered.
fn round_window(receipt: &Receipt, complete_start: Instant, ready_at: Instant) -> RoundWindow {
    let window_s = ready_at.saturating_duration_since(receipt.posted_at).as_secs_f64();
    let exposed_s = complete_start.elapsed().as_secs_f64();
    RoundWindow { window_s, exposed_s, hidden_s: (window_s - exposed_s).max(0.0) }
}

/// The per-round tag tripwire: a rank joining an open round must present
/// the tag the round was opened with. A mismatch means hosts desynchronized
/// across sessions — merging attention partials of *different* requests —
/// so it is a panic, not a recoverable error.
fn check_round_tag(label: &str, open_tag: u64, tag: u64, rank: usize) {
    assert_eq!(
        open_tag, tag,
        "collective '{label}' round tag mismatch: rank {rank} joined with \
         tag {tag} while the round in flight is {open_tag} (session desync)"
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tensor::Tensor;
    use std::thread;

    fn t(v: f32) -> Tensor {
        Tensor::new(vec![1], vec![v]).unwrap()
    }

    #[test]
    fn single_rank_allgather() {
        let c = Collective::new(1, Arc::new(CommMeter::default()));
        let r = c.all_gather(0, t(7.0));
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].data[0], 7.0);
    }

    #[test]
    fn meter_counts_bytes() {
        let m = Arc::new(CommMeter::default());
        let c = Collective::new(1, Arc::clone(&m));
        c.all_gather(0, t(1.0));
        assert_eq!(m.bytes_total(), 4);
        assert_eq!(m.rounds_total(), 1);
        m.reset();
        assert_eq!(m.bytes_total(), 0);
    }

    #[test]
    fn meter_separates_labels() {
        let m = Arc::new(CommMeter::default());
        let kv = Collective::labeled(1, "kv", Arc::clone(&m));
        let att = Collective::labeled(1, "att", Arc::clone(&m));
        kv.all_gather(0, t(1.0));
        kv.all_gather(0, t(2.0));
        att.all_gather(0, t(3.0));
        assert_eq!(m.bytes_for("kv"), 8);
        assert_eq!(m.rounds_for("kv"), 2);
        assert_eq!(m.bytes_for("att"), 4);
        assert_eq!(m.rounds_for("att"), 1);
        assert_eq!(m.bytes_total(), 12);
        assert_eq!(m.bytes_for("unknown"), 0);
        m.reset();
        assert_eq!(m.rounds_for("kv"), 0);
    }

    #[test]
    fn tagged_rounds_agree_across_ranks() {
        let n = 3;
        let c = Arc::new(Collective::new(n, Arc::new(CommMeter::default())));
        let mut handles = Vec::new();
        for rank in 0..n {
            let c = Arc::clone(&c);
            handles.push(thread::spawn(move || {
                // Successive rounds for different sessions: every rank
                // presents the matching tag and rounds complete normally.
                for sid in [7u64, 8, 7] {
                    let all = c.all_gather_tagged(rank, sid, t(rank as f32));
                    assert_eq!(all.len(), n);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn tag_check_accepts_match() {
        check_round_tag("att", 42, 42, 1);
    }

    #[test]
    #[should_panic(expected = "round tag mismatch")]
    fn tag_check_panics_on_mismatch() {
        check_round_tag("att", 7, 8, 1);
    }

    #[test]
    fn randomized_many_threads_many_rounds() {
        // Property test: arbitrary per-rank delays must never let rounds
        // interleave or deliver out-of-order results.
        let n = 5;
        let rounds = 40;
        let meter = Arc::new(CommMeter::default());
        let c = Arc::new(Collective::new(n, meter));
        let mut handles = Vec::new();
        for rank in 0..n {
            let c = Arc::clone(&c);
            handles.push(thread::spawn(move || {
                let mut rng = crate::util::rng::Rng::new(rank as u64 + 99);
                for round in 0..rounds {
                    if rng.below(3) == 0 {
                        std::thread::sleep(std::time::Duration::from_micros(
                            rng.below(200),
                        ));
                    }
                    let all = c.all_gather(rank, t((round * 100 + rank) as f32));
                    for (r, item) in all.iter().enumerate() {
                        assert_eq!(item.data[0] as usize, round * 100 + r);
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn ring_exchange_single_rank_returns_own_item() {
        let m = Arc::new(CommMeter::default());
        let r = RingExchange::labeled(1, "ring", Arc::clone(&m));
        let got = r.exchange(0, t(3.0));
        assert_eq!(got.data[0], 3.0);
        assert_eq!(m.bytes_for("ring"), 4);
    }

    #[test]
    fn ring_exchange_rotates_from_predecessor() {
        let n = 4;
        let m = Arc::new(CommMeter::default());
        let r = Arc::new(RingExchange::labeled(n, "ring", Arc::clone(&m)));
        let mut handles = Vec::new();
        for rank in 0..n {
            let r = Arc::clone(&r);
            handles.push(thread::spawn(move || {
                // Two rounds: payload forwarded onward each round, so after
                // round s a rank holds the item of origin (rank - s) mod n.
                let mut held = t(rank as f32);
                for s in 1..=2usize {
                    held = r.exchange_tagged(rank, 9, held);
                    let origin = (rank + n - s) % n;
                    assert_eq!(held.data[0] as usize, origin,
                               "rank {rank} step {s}");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // n ranks x 2 rounds, 4 bytes each.
        assert_eq!(m.bytes_for("ring"), (n * 2 * 4) as u64);
        assert_eq!(m.rounds_for("ring"), (n * 2) as u64);
    }

    #[test]
    fn split_post_complete_matches_fused_allgather() {
        let n = 3;
        let m = Arc::new(CommMeter::default());
        let c = Arc::new(Collective::labeled(n, "kv", Arc::clone(&m)));
        let mut handles = Vec::new();
        for rank in 0..n {
            let c = Arc::clone(&c);
            handles.push(thread::spawn(move || {
                // post → (compute window) → complete, twice; results must be
                // full rank-ordered rounds exactly like the fused call.
                for round in 0..2 {
                    let receipt = c.post_tagged(rank, 7, t((round * 10 + rank) as f32));
                    std::hint::black_box((0..500u64).sum::<u64>()); // "compute"
                    let all = c.complete(rank, &receipt).unwrap();
                    for (r, item) in all.iter().enumerate() {
                        assert_eq!(item.data[0] as usize, round * 10 + r);
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // Metered at post time: n ranks × 2 rounds × 4 bytes.
        assert_eq!(m.bytes_for("kv"), (n * 2 * 4) as u64);
    }

    #[test]
    fn split_ring_pipeline_overlaps_rounds() {
        // The chunked-prefill rotation pattern: post the held block, compute
        // on the previously received one, then complete — blocks still walk
        // the ring in origin order.
        let n = 4;
        let r = Arc::new(RingExchange::labeled(n, "ring", Arc::new(CommMeter::default())));
        let mut handles = Vec::new();
        for rank in 0..n {
            let r = Arc::clone(&r);
            handles.push(thread::spawn(move || {
                let mut held = t(rank as f32);
                for s in 1..n {
                    let receipt = r.post_tagged(rank, 3, held);
                    std::hint::black_box((0..500u64).sum::<u64>()); // "compute"
                    held = r.complete(rank, &receipt).unwrap();
                    let origin = (rank + n - s) % n;
                    assert_eq!(held.data[0] as usize, origin, "rank {rank} step {s}");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    #[should_panic(expected = "posted again before completing")]
    fn double_post_without_complete_panics() {
        let c = Collective::labeled(2, "att", Arc::new(CommMeter::default()));
        let r1 = c.post_tagged(0, 0, t(1.0));
        let _r2 = c.post_tagged(0, 0, t(2.0)); // must panic
        let _ = c.complete(0, &r1);
    }

    #[test]
    fn gather_delivers_to_root_only() {
        let n = 3;
        let c = Arc::new(Collective::new(n, Arc::new(CommMeter::default())));
        let mut handles = Vec::new();
        for rank in 0..n {
            let c = Arc::clone(&c);
            handles.push(thread::spawn(move || {
                let got = c.gather(rank, 1, t(rank as f32));
                (rank, got.is_some())
            }));
        }
        for h in handles {
            let (rank, has) = h.join().unwrap();
            assert_eq!(has, rank == 1);
        }
    }

    #[test]
    fn rendezvous_timeout_is_structured_and_cancel_drains_the_fabric() {
        // One rank of a 2-rank collective posts; its peer never shows up.
        // complete must convert the wedge into a typed error (not deadlock),
        // cancel must retract the orphan contribution, and a fresh full
        // round must then succeed — other sessions survive a dead peer.
        println!("APB-RUN collectives_timeout backend=threads");
        let c = Arc::new(Collective::labeled(2, "att", Arc::new(CommMeter::default())));
        c.set_timeout(Duration::from_millis(30));
        let receipt = c.post_tagged(0, 9, t(1.0));
        let err = c.complete(0, &receipt).unwrap_err();
        match err {
            ClusterError::RendezvousTimeout { label, rank, tag, waited_s } => {
                assert_eq!(label, "att");
                assert_eq!(rank, 0);
                assert_eq!(tag, 9, "error names the round left open");
                assert!(waited_s >= 0.03, "waited at least the timeout: {waited_s}");
            }
        }
        // The timed-out receipt is still live; a second complete would wait
        // again, cancel retracts the contribution instead.
        c.cancel(0, receipt);

        // Fabric fully drained: a fresh round with both ranks completes.
        c.set_timeout(DEFAULT_ROUND_TIMEOUT);
        let c2 = Arc::clone(&c);
        let peer = thread::spawn(move || c2.all_gather_tagged(1, 11, t(20.0)));
        let all = c.all_gather_tagged(0, 11, t(10.0));
        assert_eq!(all[0].data[0], 10.0);
        assert_eq!(all[1].data[0], 20.0);
        peer.join().unwrap();
    }

    #[test]
    fn ring_cancel_after_completed_round_discards_delivery() {
        // Both ranks post (the round completes inside the second post);
        // rank 0 cancels instead of completing. Its delivery slot must be
        // discarded so the next round can deliver into it.
        let r = RingExchange::labeled(2, "ring", Arc::new(CommMeter::default()));
        let rc0 = r.post_tagged(0, 5, t(0.0));
        let rc1 = r.post_tagged(1, 5, t(1.0));
        r.cancel(0, rc0);
        assert_eq!(r.complete(1, &rc1).unwrap().data[0], 0.0);

        // The ring is pristine: a fresh round posts and delivers normally.
        let rc0 = r.post_tagged(0, 6, t(10.0));
        let rc1 = r.post_tagged(1, 6, t(11.0));
        assert_eq!(r.complete(0, &rc0).unwrap().data[0], 11.0);
        assert_eq!(r.complete(1, &rc1).unwrap().data[0], 10.0);
    }

    #[test]
    fn ring_timeout_then_cancel_keeps_peers_alive() {
        // The ring variant of the wedged-peer story: rank 0 posts alone,
        // times out with the structured error, cancels; a later full round
        // (both ranks) still rotates correctly.
        let r = Arc::new(RingExchange::labeled(2, "ring", Arc::new(CommMeter::default())));
        r.set_timeout(Duration::from_millis(20));
        let receipt = r.post_tagged(0, 3, t(7.0));
        let err = r.complete(0, &receipt).unwrap_err();
        assert!(matches!(err, ClusterError::RendezvousTimeout { label: "ring", rank: 0, .. }),
                "got: {err}");
        assert!(format!("{err}").contains("wedged"), "Display is diagnostic: {err}");
        r.cancel(0, receipt);

        r.set_timeout(DEFAULT_ROUND_TIMEOUT);
        let rc0 = r.post_tagged(0, 4, t(0.0));
        let rc1 = r.post_tagged(1, 4, t(1.0));
        assert_eq!(r.complete(0, &rc0).unwrap().data[0], 1.0);
        assert_eq!(r.complete(1, &rc1).unwrap().data[0], 0.0);
    }

    #[test]
    fn wire_model_stamps_windows_and_measures_hidden_time() {
        // Modeled wire: the round's window must cover at least the modeled
        // latency, and compute run between post and complete must show up
        // as hidden time.
        let c = Collective::labeled(1, "kv", Arc::new(CommMeter::default()));
        c.set_wire(WireModel::Modeled { gbps: 1.0, latency_us: 2000.0 });
        let before = Instant::now();
        let receipt = c.post_tagged(0, 1, t(1.0));
        thread::sleep(Duration::from_millis(1)); // compute inside the window
        let (all, w) = c.complete_timed(0, &receipt).unwrap();
        assert_eq!(all.len(), 1);
        assert!(w.window_s >= 0.002, "window covers the modeled latency: {}", w.window_s);
        assert!(w.hidden_s > 0.0, "the 1ms compute was hidden: {:?}", w);
        assert!(w.exposed_s >= 0.0 && w.hidden_s <= w.window_s + 1e-9);
        // complete really blocked until the wire cleared.
        assert!(before.elapsed() >= Duration::from_millis(2));
    }

    #[test]
    fn wire_model_delay_math() {
        assert_eq!(WireModel::Instant.delay(1 << 30), Duration::ZERO);
        // 1 GiB at 8 Gbps ≈ 1.07 s (+ negligible latency).
        let m = WireModel::Modeled { gbps: 8.0, latency_us: 0.0 };
        let d = m.delay(1 << 30).as_secs_f64();
        assert!((d - 1.073).abs() < 0.01, "got {d}");
        // Latency floors the delay even for empty payloads.
        let m = WireModel::Modeled { gbps: 8.0, latency_us: 500.0 };
        assert!(m.delay(0) >= Duration::from_micros(500));
    }

    #[test]
    fn collective_cancel_of_open_round_retracts_contribution() {
        // Generic-dispatch check doubling as the open-round cancel test:
        // drive a Collective through the Fabric trait object surface.
        fn post_then_cancel<F: Fabric>(f: &F, rank: usize, item: F::Payload) {
            let receipt = Fabric::post_tagged(f, rank, 1, item);
            Fabric::cancel(f, rank, receipt);
        }
        let c = Collective::labeled(2, "kv", Arc::new(CommMeter::default()));
        post_then_cancel(&c, 0, t(5.0));
        // The retraction left the round empty: a fresh 2-rank round (posted
        // single-threaded, completed after both posts) works.
        let rc0 = Collective::post_tagged(&c, 0, 2, t(1.0));
        let rc1 = Collective::post_tagged(&c, 1, 2, t(2.0));
        assert_eq!(c.complete(0, &rc0).unwrap().len(), 2);
        assert_eq!(c.complete(1, &rc1).unwrap().len(), 2);
    }

    #[test]
    fn complete_accounted_folds_windows_into_buckets() {
        let c = Collective::labeled(1, "kv", Arc::new(CommMeter::default()));
        c.set_wire(WireModel::Modeled { gbps: 1.0, latency_us: 1000.0 });
        let (mut comm, mut window, mut hidden) = (0.0, 0.0, 0.0);
        let receipt = Collective::post_tagged(&c, 0, 1, t(1.0));
        let all = complete_accounted(&c, 0, &receipt, &mut comm, &mut window, &mut hidden)
            .unwrap();
        assert_eq!(all.len(), 1);
        assert!(window >= 0.001 && comm > 0.0);
        assert!((window - (comm + hidden)).abs() < 1e-3, "buckets partition the window");
    }
}
