//! Multi-host cluster fabric: the stand-in for the paper's 8×A800 node.
//!
//! One OS thread per host; collectives implemented with Mutex+Condvar
//! rendezvous, mirroring NCCL semantics at the API level (§3.5 "we apply
//! an AllGather communication on the compressed KV cache across all the
//! hosts"). Payload volumes are metered per label so the interconnect cost
//! model (`attnsim::walltime`) can price each round and so the executable
//! cluster modes report *measured* communication:
//!
//! | label | collective | used by |
//! |---|---|---|
//! | `kv` | [`Collective`] AllGather of compressed (K_c, V_c) | APB prefill (Alg. 2 line "AllGather") |
//! | `att` | [`Collective`] AllGather of (out, lse) partials | decode merge (Alg. 3), pass-KV strategy |
//! | `ring` | [`RingExchange`] neighbor send/recv of full KV blocks | RingAttn prefill rotation |
//! | `qring` | [`RingExchange`] neighbor send/recv of (out, lse) partials | pass-Q decode rotation (ADR-007) |
//!
//! StarAttn charges no prefill label (its blocks never move) and Dense
//! charges nothing at all. The full method × label matrix lives in
//! `docs/architecture.md`.
//!
//! The two concrete primitives share the [`Fabric`] trait (post / complete
//! / cancel with structured [`ClusterError`] timeouts), so the coordinator
//! is generic over which collective a step rides; [`Interconnect`] is the
//! bundle of all four labeled instances handed to every host worker.

pub mod collectives;

pub use collectives::{
    complete_accounted, ClusterError, Collective, CommMeter, Fabric, Receipt, RingExchange,
    RoundWindow, WireModel,
};

use std::sync::Arc;
use std::time::Duration;

type TensorPair = (crate::util::tensor::Tensor, crate::util::tensor::Tensor);

/// Shared interconnect handed to every host worker: the four labeled
/// collectives plus their common byte meter.
pub struct Interconnect {
    pub n_hosts: usize,
    /// AllGather used during prefill for compressed (K_c, V_c) blocks.
    pub kv_gather: Collective<TensorPair>,
    /// AllGather used during decode for (partial out, lse) pairs (the
    /// pass-KV strategy).
    pub att_gather: Collective<TensorPair>,
    /// Neighbor send/recv used by RingAttn prefill to rotate (K, V) blocks.
    pub ring_pass: RingExchange<TensorPair>,
    /// Neighbor send/recv used by the pass-Q decode strategy to rotate
    /// (partial out, lse) pairs around the ring — `n_hosts - 1` rounds per
    /// layer per step, each round one context-length-independent payload
    /// (`docs/ADR-007-adaptive-decode.md`).
    pub q_ring: RingExchange<TensorPair>,
    /// Bytes-on-the-wire meter shared by all collectives.
    pub meter: Arc<CommMeter>,
}

impl Interconnect {
    pub fn new(n_hosts: usize) -> Arc<Interconnect> {
        let meter = Arc::new(CommMeter::default());
        Arc::new(Interconnect {
            n_hosts,
            kv_gather: Collective::labeled(n_hosts, Interconnect::KV_LABEL, Arc::clone(&meter)),
            att_gather: Collective::labeled(n_hosts, Interconnect::ATT_LABEL, Arc::clone(&meter)),
            ring_pass: RingExchange::labeled(n_hosts, Interconnect::RING_LABEL,
                                             Arc::clone(&meter)),
            q_ring: RingExchange::labeled(n_hosts, Interconnect::QRING_LABEL,
                                          Arc::clone(&meter)),
            meter,
        })
    }

    /// Apply one [`WireModel`] to all four collectives (see
    /// `benches/fig1_prefill`: a modeled wire gives compute a real window
    /// to hide behind so overlap can be *measured*).
    pub fn set_wire(&self, wire: WireModel) {
        self.kv_gather.set_wire(wire);
        self.att_gather.set_wire(wire);
        self.ring_pass.set_wire(wire);
        self.q_ring.set_wire(wire);
    }

    /// Apply one rendezvous timeout to all four collectives.
    pub fn set_round_timeout(&self, timeout: Duration) {
        self.kv_gather.set_timeout(timeout);
        self.att_gather.set_timeout(timeout);
        self.ring_pass.set_timeout(timeout);
        self.q_ring.set_timeout(timeout);
    }
}

impl Interconnect {
    /// Meter label of the prefill compressed-KV AllGather.
    pub const KV_LABEL: &'static str = "kv";
    /// Meter label of the decode partial-attention AllGather (pass-KV).
    pub const ATT_LABEL: &'static str = "att";
    /// Meter label of the RingAttn KV-block rotation.
    pub const RING_LABEL: &'static str = "ring";
    /// Meter label of the pass-Q decode partial rotation.
    pub const QRING_LABEL: &'static str = "qring";
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tensor::Tensor;
    use std::thread;

    #[test]
    fn fabric_allgather_kv_roundtrip() {
        let n = 4;
        let fabric = Interconnect::new(n);
        let mut handles = Vec::new();
        for rank in 0..n {
            let f = Arc::clone(&fabric);
            handles.push(thread::spawn(move || {
                let t = Tensor::new(vec![1, 1], vec![rank as f32]).unwrap();
                let all = f.kv_gather.all_gather(rank, (t.clone(), t));
                // Every host sees every rank's contribution in rank order.
                (0..n)
                    .map(|r| all[r].0.data[0] as usize)
                    .collect::<Vec<_>>()
            }));
        }
        for h in handles {
            assert_eq!(h.join().unwrap(), vec![0, 1, 2, 3]);
        }
        assert!(fabric.meter.bytes_total() > 0);
    }

    #[test]
    fn fabric_ring_pass_rotates_and_meters_separately() {
        let n = 3;
        let fabric = Interconnect::new(n);
        let mut handles = Vec::new();
        for rank in 0..n {
            let f = Arc::clone(&fabric);
            handles.push(thread::spawn(move || {
                let t = Tensor::new(vec![1], vec![rank as f32]).unwrap();
                let got = f.ring_pass.exchange(rank, (t.clone(), t));
                got.0.data[0] as usize
            }));
        }
        for (rank, h) in handles.into_iter().enumerate() {
            assert_eq!(h.join().unwrap(), (rank + n - 1) % n, "from predecessor");
        }
        assert_eq!(fabric.meter.bytes_for(Interconnect::RING_LABEL), (n * 2 * 4) as u64);
        assert_eq!(fabric.meter.bytes_for(Interconnect::KV_LABEL), 0);
    }

    #[test]
    fn fabric_repeated_rounds_do_not_cross() {
        let n = 3;
        let rounds = 25;
        let fabric = Interconnect::new(n);
        let mut handles = Vec::new();
        for rank in 0..n {
            let f = Arc::clone(&fabric);
            handles.push(thread::spawn(move || {
                for round in 0..rounds {
                    let t = Tensor::new(vec![1], vec![(round * 10 + rank) as f32]).unwrap();
                    let all = f.att_gather.all_gather(rank, (t.clone(), t));
                    for (r, (o, _)) in all.iter().enumerate() {
                        assert_eq!(o.data[0] as usize, round * 10 + r,
                                   "round {round} rank {rank} slot {r}");
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn interconnect_wire_and_timeout_apply_to_all_collectives() {
        // A wedged single-rank... not possible with n=1 (rounds complete at
        // post), so use n=2 and check the timeout took effect on each
        // collective by timing out with one lone poster.
        let fabric = Interconnect::new(2);
        fabric.set_round_timeout(Duration::from_millis(10));
        fabric.set_wire(WireModel::Instant);
        let t = || Tensor::new(vec![1], vec![1.0]).unwrap();

        let r = fabric.kv_gather.post_tagged(0, 1, (t(), t()));
        assert!(fabric.kv_gather.complete(0, &r).is_err());
        fabric.kv_gather.cancel(0, r);

        let r = fabric.att_gather.post_tagged(0, 1, (t(), t()));
        assert!(fabric.att_gather.complete(0, &r).is_err());
        fabric.att_gather.cancel(0, r);

        let r = fabric.ring_pass.post_tagged(0, 1, (t(), t()));
        assert!(fabric.ring_pass.complete(0, &r).is_err());
        fabric.ring_pass.cancel(0, r);

        let r = fabric.q_ring.post_tagged(0, 1, (t(), t()));
        assert!(fabric.q_ring.complete(0, &r).is_err());
        fabric.q_ring.cancel(0, r);
    }

    #[test]
    fn qring_meters_apart_from_att_and_ring() {
        // The pass-Q rotation must charge its own label: strategy choice is
        // observable purely from the meter split.
        let n = 3;
        let fabric = Interconnect::new(n);
        let mut handles = Vec::new();
        for rank in 0..n {
            let f = Arc::clone(&fabric);
            handles.push(thread::spawn(move || {
                let t = Tensor::new(vec![1], vec![rank as f32]).unwrap();
                let got = f.q_ring.exchange(rank, (t.clone(), t));
                got.0.data[0] as usize
            }));
        }
        for (rank, h) in handles.into_iter().enumerate() {
            assert_eq!(h.join().unwrap(), (rank + n - 1) % n, "from predecessor");
        }
        assert_eq!(fabric.meter.bytes_for(Interconnect::QRING_LABEL), (n * 2 * 4) as u64);
        assert_eq!(fabric.meter.bytes_for(Interconnect::ATT_LABEL), 0);
        assert_eq!(fabric.meter.bytes_for(Interconnect::RING_LABEL), 0);
        assert_eq!(fabric.meter.rounds_for(Interconnect::QRING_LABEL), n as u64,
                   "one metered contribution per rank per exchange");
    }
}
