//! Multi-host cluster fabric: the stand-in for the paper's 8×A800 node.
//!
//! One OS thread per host; collectives implemented with Mutex+Condvar
//! rendezvous, mirroring NCCL semantics at the API level (§3.5 "we apply
//! an AllGather communication on the compressed KV cache across all the
//! hosts"). Payload volumes are metered per label so the interconnect cost
//! model (`attnsim::walltime`) can price each round and so the executable
//! cluster modes report *measured* communication:
//!
//! | label | collective | used by |
//! |---|---|---|
//! | `kv` | [`Collective`] AllGather of compressed (K_c, V_c) | APB prefill (Alg. 2 line "AllGather") |
//! | `att` | [`Collective`] AllGather of (out, lse) partials | decode merge (Alg. 3), all distributed methods |
//! | `ring` | [`RingExchange`] neighbor send/recv of full KV blocks | RingAttn prefill rotation |
//!
//! StarAttn charges no prefill label (its blocks never move) and Dense
//! charges nothing at all. The full method × label matrix lives in
//! `docs/architecture.md`.

pub mod collectives;

pub use collectives::{Collective, CommMeter, RingExchange};

use std::sync::Arc;

type TensorPair = (crate::util::tensor::Tensor, crate::util::tensor::Tensor);

/// Shared fabric handed to every host worker.
pub struct Fabric {
    pub n_hosts: usize,
    /// AllGather used during prefill for compressed (K_c, V_c) blocks.
    pub kv_gather: Collective<TensorPair>,
    /// AllGather used during decode for (partial out, lse) pairs.
    pub att_gather: Collective<TensorPair>,
    /// Neighbor send/recv used by RingAttn prefill to rotate (K, V) blocks.
    pub ring_pass: RingExchange<TensorPair>,
    /// Bytes-on-the-wire meter shared by all collectives.
    pub meter: Arc<CommMeter>,
}

impl Fabric {
    pub fn new(n_hosts: usize) -> Arc<Fabric> {
        let meter = Arc::new(CommMeter::default());
        Arc::new(Fabric {
            n_hosts,
            kv_gather: Collective::labeled(n_hosts, Fabric::KV_LABEL, Arc::clone(&meter)),
            att_gather: Collective::labeled(n_hosts, Fabric::ATT_LABEL, Arc::clone(&meter)),
            ring_pass: RingExchange::labeled(n_hosts, Fabric::RING_LABEL,
                                             Arc::clone(&meter)),
            meter,
        })
    }
}

impl Fabric {
    /// Meter label of the prefill compressed-KV AllGather.
    pub const KV_LABEL: &'static str = "kv";
    /// Meter label of the decode partial-attention AllGather.
    pub const ATT_LABEL: &'static str = "att";
    /// Meter label of the RingAttn KV-block rotation.
    pub const RING_LABEL: &'static str = "ring";
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tensor::Tensor;
    use std::thread;

    #[test]
    fn fabric_allgather_kv_roundtrip() {
        let n = 4;
        let fabric = Fabric::new(n);
        let mut handles = Vec::new();
        for rank in 0..n {
            let f = Arc::clone(&fabric);
            handles.push(thread::spawn(move || {
                let t = Tensor::new(vec![1, 1], vec![rank as f32]).unwrap();
                let all = f.kv_gather.all_gather(rank, (t.clone(), t));
                // Every host sees every rank's contribution in rank order.
                (0..n)
                    .map(|r| all[r].0.data[0] as usize)
                    .collect::<Vec<_>>()
            }));
        }
        for h in handles {
            assert_eq!(h.join().unwrap(), vec![0, 1, 2, 3]);
        }
        assert!(fabric.meter.bytes_total() > 0);
    }

    #[test]
    fn fabric_ring_pass_rotates_and_meters_separately() {
        let n = 3;
        let fabric = Fabric::new(n);
        let mut handles = Vec::new();
        for rank in 0..n {
            let f = Arc::clone(&fabric);
            handles.push(thread::spawn(move || {
                let t = Tensor::new(vec![1], vec![rank as f32]).unwrap();
                let got = f.ring_pass.exchange(rank, (t.clone(), t));
                got.0.data[0] as usize
            }));
        }
        for (rank, h) in handles.into_iter().enumerate() {
            assert_eq!(h.join().unwrap(), (rank + n - 1) % n, "from predecessor");
        }
        assert_eq!(fabric.meter.bytes_for(Fabric::RING_LABEL), (n * 2 * 4) as u64);
        assert_eq!(fabric.meter.bytes_for(Fabric::KV_LABEL), 0);
    }

    #[test]
    fn fabric_repeated_rounds_do_not_cross() {
        let n = 3;
        let rounds = 25;
        let fabric = Fabric::new(n);
        let mut handles = Vec::new();
        for rank in 0..n {
            let f = Arc::clone(&fabric);
            handles.push(thread::spawn(move || {
                for round in 0..rounds {
                    let t = Tensor::new(vec![1], vec![(round * 10 + rank) as f32]).unwrap();
                    let all = f.att_gather.all_gather(rank, (t.clone(), t));
                    for (r, (o, _)) in all.iter().enumerate() {
                        assert_eq!(o.data[0] as usize, round * 10 + r,
                                   "round {round} rank {rank} slot {r}");
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }
}
