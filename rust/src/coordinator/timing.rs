//! Per-component wall-time accounting, the measured twin of the paper's
//! Figure 5 / Table 13 breakdown. Artifact granularity maps to the paper's
//! components as:
//!
//!   layer_pre   -> QKV projection + retaining-head calculation
//!   topk        -> compressor Top-l_p selection (coordinator-side)
//!   comm        -> AllGather wait (communication)
//!   layer_post  -> attention + O projection + FFN
//!   cache       -> KV-cache append ("others")
//!
//! `comm_s` is the *exposed* communication time (what the host actually
//! blocked on). The companion pair `comm_window_s` / `comm_hidden_s` tracks
//! the full post→delivery windows of the host's collective rounds and the
//! part of those windows its own compute covered — `hidden / window` is the
//! measured overlap fraction reported by `benches/fig1_prefill`. Both are
//! outside `accounted()` on purpose: the window overlaps the compute
//! buckets by construction.

#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PrefillTiming {
    pub embed_s: f64,
    pub layer_pre_s: f64,
    pub topk_s: f64,
    pub comm_s: f64,
    pub layer_post_s: f64,
    pub cache_s: f64,
    pub total_s: f64,
    /// Full post→delivery span of this host's collective rounds.
    pub comm_window_s: f64,
    /// Part of `comm_window_s` hidden behind this host's own compute.
    pub comm_hidden_s: f64,
}

impl PrefillTiming {
    pub fn accounted(&self) -> f64 {
        self.embed_s + self.layer_pre_s + self.topk_s + self.comm_s + self.layer_post_s
            + self.cache_s
    }

    pub fn other(&self) -> f64 {
        (self.total_s - self.accounted()).max(0.0)
    }

    pub fn add(&mut self, o: &PrefillTiming) {
        self.embed_s += o.embed_s;
        self.layer_pre_s += o.layer_pre_s;
        self.topk_s += o.topk_s;
        self.comm_s += o.comm_s;
        self.layer_post_s += o.layer_post_s;
        self.cache_s += o.cache_s;
        self.total_s += o.total_s;
        self.comm_window_s += o.comm_window_s;
        self.comm_hidden_s += o.comm_hidden_s;
    }
}

#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct DecodeTiming {
    pub pre_s: f64,
    pub attn_s: f64,
    pub comm_s: f64,
    pub merge_s: f64,
    pub post_s: f64,
    pub lm_head_s: f64,
    pub total_s: f64,
    /// Full post→delivery span of this host's decode gather rounds.
    pub comm_window_s: f64,
    /// Part of `comm_window_s` hidden behind this host's own compute.
    pub comm_hidden_s: f64,
}

impl DecodeTiming {
    pub fn add(&mut self, o: &DecodeTiming) {
        self.pre_s += o.pre_s;
        self.attn_s += o.attn_s;
        self.comm_s += o.comm_s;
        self.merge_s += o.merge_s;
        self.post_s += o.post_s;
        self.lm_head_s += o.lm_head_s;
        self.total_s += o.total_s;
        self.comm_window_s += o.comm_window_s;
        self.comm_hidden_s += o.comm_hidden_s;
    }
}

/// Tiny scope timer.
pub struct Stopwatch(std::time::Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch(std::time::Instant::now())
    }

    /// Seconds since start (or last lap), resetting the clock.
    pub fn lap(&mut self) -> f64 {
        let now = std::time::Instant::now();
        let dt = now.duration_since(self.0).as_secs_f64();
        self.0 = now;
        dt
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accounting() {
        let t = PrefillTiming {
            embed_s: 0.1,
            layer_pre_s: 0.2,
            topk_s: 0.05,
            comm_s: 0.1,
            layer_post_s: 0.3,
            cache_s: 0.05,
            total_s: 1.0,
            comm_window_s: 0.15,
            comm_hidden_s: 0.05,
        };
        // Window/hidden stay outside accounted(): they overlap the compute
        // buckets by construction.
        assert!((t.accounted() - 0.8).abs() < 1e-12);
        assert!((t.other() - 0.2).abs() < 1e-12);
        let mut sum = PrefillTiming::default();
        sum.add(&t);
        sum.add(&t);
        assert!((sum.total_s - 2.0).abs() < 1e-12);
        assert!((sum.comm_window_s - 0.3).abs() < 1e-12);
        assert!((sum.comm_hidden_s - 0.1).abs() < 1e-12);
    }

    #[test]
    fn stopwatch_laps_monotone() {
        let mut sw = Stopwatch::start();
        std::hint::black_box((0..10_000).sum::<u64>());
        let a = sw.lap();
        let b = sw.lap();
        assert!(a >= 0.0 && b >= 0.0);
    }
}
