//! L3 coordinator — the paper's system contribution.
//!
//! The `Cluster` owns one worker thread per host (each with its own PJRT
//! engine + KV cache) and drives the APB inference procedure:
//!
//!   prefill (Algorithm 2, per layer):
//!     layer_pre → top-l_p selection → AllGather(B^C) → passing-block
//!     assembly → layer_post → cache append
//!   decode (Algorithm 3, per layer):
//!     decode_pre → per-host decode_attn(+LSE) → Gather → online-softmax
//!     merge → decode_post; greedy next-token on the last host.
//!
//! The leader thread never touches tensors on the prefill path — it only
//! routes commands; all compute + collectives happen inside host workers,
//! exactly like the paper's one-process-per-GPU deployment.

pub mod host;
pub mod scheduler;
pub mod timing;

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::cluster::Fabric;
use crate::config::{ApbOptions, Config};
use crate::util::tensor::Tensor;

pub use timing::{DecodeTiming, PrefillTiming};

/// Commands from the leader to host workers.
#[derive(Clone)]
pub enum Cmd {
    /// Run the APB prefill over this host's token layout.
    Prefill { tokens: Arc<Vec<i32>>, opts: ApbOptions },
    /// Process the re-fed query chunk (decode path, n = l_q).
    QueryChunk { tokens: Arc<Vec<i32>> },
    /// Decode one token (broadcast of the previously sampled token).
    DecodeStep { token: i32, step: usize },
    /// Drop the request state (cache + hidden).
    Clear,
    Shutdown,
}

/// Worker responses to the leader.
pub enum Resp {
    PrefillDone {
        host: usize,
        timing: PrefillTiming,
        /// Per-layer, per-kv-head local-block indices the compressor
        /// retained (for retention-recall experiments; paper §3.4).
        retained: Vec<Vec<Vec<u32>>>,
    },
    /// Only the last host computes logits (all hosts hold identical hidden
    /// states after the merge, so one LM head suffices).
    StepDone { host: usize, logits: Option<Vec<f32>>, timing: DecodeTiming },
    Cleared { host: usize },
    Error { host: usize, msg: String },
}

struct HostHandle {
    cmd_tx: Sender<Cmd>,
    join: Option<std::thread::JoinHandle<()>>,
}

pub struct Cluster {
    pub cfg: Config,
    pub fabric: Arc<Fabric>,
    hosts: Vec<HostHandle>,
    resp_rx: Receiver<Resp>,
}

/// Leader-side report for one prefill.
#[derive(Debug, Clone)]
pub struct PrefillReport {
    pub per_host: Vec<PrefillTiming>,
    /// retained[host][layer][kv_head] -> local-block indices kept by the
    /// compressor (ascending).
    pub retained: Vec<Vec<Vec<Vec<u32>>>>,
    pub wall_seconds: f64,
    pub comm_bytes: u64,
}

impl PrefillReport {
    /// Recall of a set of *global document positions* in the compressor's
    /// retained set, averaged over layers and kv-heads — the measured twin
    /// of `oracle::compressor_recall`. Positions on host 0 are never
    /// passed (host 0 sends to nobody's past), so callers typically plant
    /// needles beyond block 0.
    pub fn retention_recall(&self, cfg: &Config, positions: &[usize]) -> f64 {
        let l_b = cfg.apb.block_len;
        let mut hits = 0usize;
        let mut total = 0usize;
        for &pos in positions {
            let host = pos / l_b;
            let local = (pos % l_b) as u32;
            if host >= self.retained.len() {
                continue;
            }
            for layer in &self.retained[host] {
                for head in layer {
                    total += 1;
                    if head.binary_search(&local).is_ok() {
                        hits += 1;
                    }
                }
            }
        }
        if total == 0 {
            return 0.0;
        }
        hits as f64 / total as f64
    }
}

/// Leader-side report for one generation.
#[derive(Debug, Clone)]
pub struct GenReport {
    pub tokens: Vec<i32>,
    pub query_logits: Vec<f32>,
    pub wall_seconds: f64,
    pub per_step_seconds: Vec<f64>,
}

/// Mirror of `model.host_tokens`: [anchor (l_aq) | local block] layout for
/// host `rank`. Host 0 carries no anchor (zero-filled, masked out).
pub fn host_tokens(cfg: &Config, doc: &[i32], query: &[i32], rank: usize,
                   opts: &ApbOptions) -> Vec<i32> {
    let a = &cfg.apb;
    let mut out = vec![0i32; a.n_tot()];
    if rank > 0 && opts.use_anchor {
        if opts.embed_query {
            out[..a.query_len].copy_from_slice(query);
        }
        out[a.query_len..a.l_aq()].copy_from_slice(&doc[..a.anchor_len]);
    }
    out[a.l_aq()..].copy_from_slice(&doc[rank * a.block_len..(rank + 1) * a.block_len]);
    out
}

/// n_anchor runtime scalar for a host (mirror of `model.n_anchor_for`).
pub fn n_anchor_for(cfg: &Config, rank: usize, opts: &ApbOptions) -> i32 {
    if rank > 0 && opts.use_anchor {
        cfg.apb.l_aq() as i32
    } else {
        0
    }
}

impl Cluster {
    /// Spawn one worker per host; each compiles the artifact set and
    /// uploads weights. Blocks until all engines are ready.
    pub fn start(cfg: &Config) -> Result<Cluster> {
        let fabric = Fabric::new(cfg.apb.n_hosts);
        let (resp_tx, resp_rx) = channel::<Resp>();
        let (ready_tx, ready_rx) = channel::<Result<usize>>();
        let mut hosts = Vec::with_capacity(cfg.apb.n_hosts);
        for rank in 0..cfg.apb.n_hosts {
            let (cmd_tx, cmd_rx) = channel::<Cmd>();
            let cfg2 = cfg.clone();
            let fabric2 = Arc::clone(&fabric);
            let resp_tx2 = resp_tx.clone();
            let ready_tx2 = ready_tx.clone();
            let join = std::thread::Builder::new()
                .name(format!("apb-host-{rank}"))
                .spawn(move || {
                    host::run_host(rank, cfg2, fabric2, cmd_rx, resp_tx2, ready_tx2)
                })
                .context("spawning host thread")?;
            hosts.push(HostHandle { cmd_tx, join: Some(join) });
        }
        drop(ready_tx);
        for _ in 0..cfg.apb.n_hosts {
            ready_rx
                .recv()
                .context("host died during startup")??;
        }
        Ok(Cluster { cfg: cfg.clone(), fabric, hosts, resp_rx })
    }

    fn broadcast(&self, cmd: Cmd) -> Result<()> {
        for h in &self.hosts {
            h.cmd_tx
                .send(cmd.clone())
                .map_err(|_| anyhow::anyhow!("host channel closed"))?;
        }
        Ok(())
    }

    fn collect<F: FnMut(Resp) -> Result<()>>(&self, n: usize, mut f: F) -> Result<()> {
        for _ in 0..n {
            match self.resp_rx.recv().context("cluster response channel closed")? {
                Resp::Error { host, msg } => bail!("host {host} failed: {msg}"),
                other => f(other)?,
            }
        }
        Ok(())
    }

    /// APB prefill of a document + query (Algorithm 1 lines 1–12).
    pub fn prefill(&self, doc: &[i32], query: &[i32], opts: &ApbOptions)
                   -> Result<PrefillReport> {
        let a = &self.cfg.apb;
        if doc.len() != a.doc_len() {
            bail!("doc length {} != configured {}", doc.len(), a.doc_len());
        }
        if query.len() != a.query_len {
            bail!("query length {} != configured {}", query.len(), a.query_len);
        }
        self.fabric.meter.reset();
        let t0 = std::time::Instant::now();
        for (rank, h) in self.hosts.iter().enumerate() {
            let tokens = Arc::new(host_tokens(&self.cfg, doc, query, rank, opts));
            h.cmd_tx
                .send(Cmd::Prefill { tokens, opts: *opts })
                .map_err(|_| anyhow::anyhow!("host {rank} channel closed"))?;
        }
        let mut per_host = vec![PrefillTiming::default(); self.hosts.len()];
        let mut retained = vec![Vec::new(); self.hosts.len()];
        self.collect(self.hosts.len(), |r| {
            if let Resp::PrefillDone { host, timing, retained: ret } = r {
                per_host[host] = timing;
                retained[host] = ret;
            }
            Ok(())
        })?;
        Ok(PrefillReport {
            per_host,
            retained,
            wall_seconds: t0.elapsed().as_secs_f64(),
            comm_bytes: self.fabric.meter.bytes_total(),
        })
    }

    /// Decode: re-feed the query chunk with exact distributed attention,
    /// then greedily generate `max_new` tokens (Algorithm 1 lines 13–25).
    pub fn generate(&self, query: &[i32], max_new: usize) -> Result<GenReport> {
        let t0 = std::time::Instant::now();
        let chunk = Arc::new(query.to_vec());
        self.broadcast(Cmd::QueryChunk { tokens: chunk })?;
        let mut logits: Option<Vec<f32>> = None;
        self.collect(self.hosts.len(), |r| {
            if let Resp::StepDone { logits: Some(l), .. } = r {
                logits = Some(l);
            }
            Ok(())
        })?;
        let query_logits = logits.context("no host produced query logits")?;
        let vocab = self.cfg.model.vocab_size;
        let last_row = &query_logits[query_logits.len() - vocab..];
        let mut token = Tensor::argmax_row(last_row) as i32;

        let mut tokens = Vec::with_capacity(max_new);
        let mut per_step = Vec::with_capacity(max_new);
        for step in 0..max_new {
            tokens.push(token);
            if step + 1 == max_new {
                break; // the last sampled token needs no further forward
            }
            let ts = std::time::Instant::now();
            self.broadcast(Cmd::DecodeStep { token, step })?;
            let mut step_logits: Option<Vec<f32>> = None;
            self.collect(self.hosts.len(), |r| {
                if let Resp::StepDone { logits: Some(l), .. } = r {
                    step_logits = Some(l);
                }
                Ok(())
            })?;
            per_step.push(ts.elapsed().as_secs_f64());
            let l = step_logits.context("no step logits")?;
            token = Tensor::argmax_row(&l) as i32;
        }
        Ok(GenReport {
            tokens,
            query_logits,
            wall_seconds: t0.elapsed().as_secs_f64(),
            per_step_seconds: per_step,
        })
    }

    /// Drop request state on every host (between requests).
    pub fn clear(&self) -> Result<()> {
        self.broadcast(Cmd::Clear)?;
        self.collect(self.hosts.len(), |_| Ok(()))
    }

    pub fn n_hosts(&self) -> usize {
        self.hosts.len()
    }
}

impl Drop for Cluster {
    fn drop(&mut self) {
        for h in &self.hosts {
            let _ = h.cmd_tx.send(Cmd::Shutdown);
        }
        for h in &mut self.hosts {
            if let Some(j) = h.join.take() {
                let _ = j.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_cfg() -> Config {
        // Hand-built sim config (no artifacts needed for token-layout tests).
        Config::sim(
            "fake",
            crate::config::ModelConfig {
                vocab_size: 64,
                n_layers: 2,
                d_model: 32,
                n_heads: 4,
                n_kv_heads: 2,
                d_ff: 64,
                rope_theta: 1e4,
                rms_eps: 1e-5,
                retaining_hidden: 16,
            },
            crate::config::ApbParams {
                n_hosts: 3,
                block_len: 8,
                anchor_len: 4,
                query_len: 2,
                passing_len: 2,
                max_new_tokens: 4,
            },
            0,
        )
    }

    #[test]
    fn host_tokens_layout() {
        let cfg = fake_cfg();
        let doc: Vec<i32> = (100..124).collect();
        let query = vec![7, 8];
        let opts = ApbOptions::default();
        let t0 = host_tokens(&cfg, &doc, &query, 0, &opts);
        assert_eq!(t0.len(), cfg.apb.n_tot());
        assert!(t0[..cfg.apb.l_aq()].iter().all(|&t| t == 0));
        assert_eq!(&t0[cfg.apb.l_aq()..], &doc[..8]);

        let t1 = host_tokens(&cfg, &doc, &query, 1, &opts);
        assert_eq!(&t1[..2], &[7, 8]);
        assert_eq!(&t1[2..6], &doc[..4]);
        assert_eq!(&t1[6..], &doc[8..16]);
        assert_eq!(n_anchor_for(&cfg, 0, &opts), 0);
        assert_eq!(n_anchor_for(&cfg, 1, &opts), 6);
    }

    #[test]
    fn host_tokens_ablations() {
        let cfg = fake_cfg();
        let doc: Vec<i32> = (100..124).collect();
        let query = vec![7, 8];
        let no_q = ApbOptions { embed_query: false, ..Default::default() };
        let t1 = host_tokens(&cfg, &doc, &query, 1, &no_q);
        assert_eq!(&t1[..2], &[0, 0]);
        assert_eq!(&t1[2..6], &doc[..4]);

        let no_a = ApbOptions { use_anchor: false, ..Default::default() };
        let t1 = host_tokens(&cfg, &doc, &query, 1, &no_a);
        assert!(t1[..cfg.apb.l_aq()].iter().all(|&t| t == 0));
        assert_eq!(n_anchor_for(&cfg, 1, &no_a), 0);
    }
}
