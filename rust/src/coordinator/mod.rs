//! L3 coordinator — the paper's system contribution.
//!
//! The `Cluster` drives one worker per host (each with its own execution
//! backend + KV pool) through the inference procedure of the request's
//! `config::AttnMethod` (the paper's comparison set as executable modes —
//! full matrix in `docs/architecture.md`, rationale in
//! `docs/ADR-001-attn-methods.md`):
//!
//!   APB / StarAttn prefill (Algorithm 2, per layer):
//!     layer_pre → top-l_p selection → AllGather(B^C) (APB only; StarAttn
//!     skips passing entirely) → passing-block assembly → layer_post →
//!     cache append
//!   RingAttn prefill (exact baseline, per layer):
//!     decode_pre at global positions → local causal `attn_partial` →
//!     N-1 ring exchanges of the full (K, V) block, one `attn_partial`
//!     per received block → online-softmax merge → decode_post →
//!     cache append
//!   Dense prefill (exactness anchor): the whole [query | document]
//!     sequence on host 0, plain causal attention, no communication.
//!   decode (Algorithm 3, per layer):
//!     decode_pre → per-host decode_attn(+LSE) → merge collective →
//!     online-softmax merge → decode_post; greedy next-token on the last
//!     host. The merge collective is strategy-selected per round
//!     (`docs/ADR-007-adaptive-decode.md`): **pass-KV** gathers every
//!     rank's (out, lse) partial in one `att` AllGather; **pass-Q**
//!     rotates the partials around the `qring` ring in `n_hosts - 1`
//!     context-length-independent rounds; **Auto** resolves per round from
//!     session warmth (prefix-store hits, multi-turn follow-ups) —
//!     leader-side, so the choice is rank-uniform by construction. Both
//!     strategies fold bit-identical partials in the identical rank order.
//!     Dense sessions instead decode entirely on host 0 (its cache holds
//!     every key) with no collective.
//!
//! **Drivers** (`docs/ADR-004-threaded-hosts.md`): every leader→host
//! command travels as one transport-shaped [`Envelope`] and both drivers
//! share one dispatch path ([`Cluster::dispatch`]). Under
//! [`Driver::Threaded`] each host runs [`host::run_host`] on its own OS
//! thread and collectives genuinely rendezvous (a wedged rank surfaces as
//! a structured `cluster::ClusterError` timeout, never a deadlock); under
//! [`Driver::Sequential`] the leader owns the workers directly and
//! round-robins decode microsteps in rank order — a deterministic oracle
//! the parity suite (`rust/tests/driver_parity.rs`) holds the threaded
//! driver bit-identical to. `Cluster::start` picks the driver from
//! `APB_DRIVER` (default threaded); `Cluster::start_with` pins it.
//!
//! Requests are first-class **sessions**: every envelope carries a
//! [`SessionId`], each host worker keeps one KV-pool slot plus position
//! bookkeeping per resident session, and a continuous-batching step decodes
//! all active sessions in ONE stacked backend pass per layer
//! (`Cmd::DecodeBatch`). The leader thread never touches tensors on the
//! prefill path — it only routes envelopes; all compute + collectives
//! happen inside host workers, exactly like the paper's
//! one-process-per-GPU deployment.
//!
//! Prefill is **chunked and resumable** (`Cmd::PrefillBegin` +
//! `Cmd::PrefillChunk`, driven through [`Cluster::prefill_begin`] /
//! [`Cluster::prefill_step`]): each host advances a per-session
//! `prefill::PrefillMachine` one bounded step per command, bit-identical
//! to one-shot prefill for any chunk size, so the scheduler can interleave
//! resident sessions' decode ticks between a long admission's chunks
//! instead of stalling them — see `docs/ADR-002-chunked-prefill.md`. The
//! one-prefill-at-a-time rule is enforced by an RAII [`PrefillPermit`].
//!
//! With `config::ApbParams::prefix_cache` on, prefill also rides
//! **shared-prefix KV reuse** (`docs/ADR-003-prefix-caching.md`): the
//! leader ships a rank-symmetric `kvcache::prefix_digest` with every
//! `Cmd::PrefillBegin`; a host whose prefix store holds the entry builds a
//! one-step warm machine that ATTACHES the session to the immutable
//! `kvcache::SharedPrefix` instead of recomputing (zero compute, zero
//! comm), while a cold run freezes its document KV into the store at the
//! final step. Hit/miss is asserted rank-uniform at begin (the
//! digest-desync tripwire), and a warm session's logits, KV bytes and
//! decode comm are bit-identical to a cold prefill of the same request
//! (`rust/tests/prefix_cache.rs`).

pub mod host;
mod prefill;
pub mod scheduler;
pub mod timing;

use std::cell::RefCell;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use std::collections::HashMap;

use crate::cluster::Interconnect;
use crate::config::{ApbOptions, AttnMethod, Config, PassStrategy};
use crate::util::tensor::Tensor;

pub use crate::kvcache::{PoolStats, SessionId};
pub use timing::{DecodeTiming, PrefillTiming};

/// Session id used by the legacy single-request helpers
/// ([`Cluster::prefill`] / [`Cluster::generate`]); scheduler-issued ids
/// start at 1 so they never collide.
pub const LEGACY_SESSION: SessionId = 0;

/// The transport unit between leader and hosts: which session the command
/// is about, the fabric round tag any collective it opens must use, and
/// the command body. Session-scoped commands ride `tag == sid`; a batched
/// decode rides the leader's [`batch_tag`] digest; cluster-scoped commands
/// (`PoolStats`, `ClearAll`, `Shutdown`) use `sid = tag = 0`.
#[derive(Clone)]
pub struct Envelope {
    pub sid: SessionId,
    pub tag: u64,
    pub body: Cmd,
}

/// Command bodies. Session addressing lives on the [`Envelope`], not here.
#[derive(Clone)]
pub enum Cmd {
    /// Claim the envelope session's KV-pool slot and build its resumable
    /// `prefill::PrefillMachine` over this host's token layout. Answered
    /// by `Resp::PrefillBegun` with the (rank-uniform) plan length.
    /// `digest` is the rank-symmetric prefix-cache key
    /// (`kvcache::prefix_digest`) when the cluster runs with
    /// `ApbParams::prefix_cache`, `None` otherwise: a digest-keyed begin
    /// takes the warm fast path when the host's prefix store holds the
    /// entry, and freezes its document KV into the store on a cold run.
    PrefillBegin {
        tokens: Arc<Vec<i32>>,
        opts: ApbOptions,
        digest: Option<u64>,
    },
    /// Advance the session's prefill machine by exactly one step.
    /// `chunk_idx` is the step index the leader believes it is driving —
    /// hosts verify it against their machine's progress (desync tripwire).
    /// The final step answers `Resp::PrefillDone`, earlier ones
    /// `Resp::PrefillStep`.
    PrefillChunk { chunk_idx: usize },
    /// Report this host's KV-pool accounting (`Resp::PoolStats`).
    PoolStats,
    /// Process the re-fed query chunk (decode path, n = l_q) — or, with
    /// `turn` set, a new conversation turn appended against the session's
    /// resident `[shared | private]` cache (the multi-turn incremental
    /// re-prefill; the host records the turn boundary in its KV cache).
    /// `strategy` is the leader-resolved decode pass strategy (never
    /// `Auto` — resolution must be rank-uniform, so it happens once,
    /// leader-side).
    QueryChunk { tokens: Arc<Vec<i32>>, strategy: PassStrategy, turn: bool },
    /// One continuous-batching decode step: one (session, previous token)
    /// entry per active session, executed as a single stacked backend pass
    /// per layer. The envelope's tag is the leader's [`batch_tag`] digest;
    /// `strategy` is leader-resolved like `QueryChunk`'s.
    DecodeBatch { entries: Arc<Vec<(SessionId, i32)>>, strategy: PassStrategy },
    /// Drop the envelope session's state (KV slot + positions).
    Clear,
    /// Drop every session (between serving phases / legacy callers).
    ClearAll,
    Shutdown,
}

/// Worker responses to the leader.
pub enum Resp {
    /// Prefill machine built; `steps` is the total number of
    /// `Cmd::PrefillChunk` steps the leader must drive (identical on every
    /// host — asserted by the leader). `prefix_hit` reports whether this
    /// host's prefix store answered the request's digest; the leader
    /// asserts it is rank-uniform (the digest-desync tripwire — a split
    /// verdict would run collectives on some ranks only and wedge the
    /// fabric).
    PrefillBegun { host: usize, sid: SessionId, steps: usize, prefix_hit: bool },
    /// One intermediate prefill step finished on this host. `quiescent`
    /// reports whether the host's machine now sits at a fabric-quiescent
    /// point (no posted-but-incomplete ring rotation or APB gather); the
    /// leader asserts it is rank-uniform and records it on
    /// [`PrefillProgress`] so a suspend at this boundary knows whether the
    /// one-prefill-at-a-time permit may be released.
    PrefillStep { host: usize, sid: SessionId, quiescent: bool },
    /// This host's KV-pool accounting snapshot.
    PoolStats { host: usize, stats: PoolStats },
    PrefillDone {
        host: usize,
        sid: SessionId,
        timing: PrefillTiming,
        /// Per-layer, per-kv-head local-block indices the compressor
        /// retained — recorded only when `ApbOptions::record_retained`
        /// (retention-recall experiments; paper §3.4), empty otherwise.
        /// On a prefix-cache hit this is the frozen entry's record, served
        /// verbatim (bit-identical to the cold run that froze it).
        retained: Vec<Vec<Vec<u32>>>,
        /// Whether this prefill attached to a shared prefix instead of
        /// computing (rank-uniform; see `Resp::PrefillBegun`).
        prefix_hit: bool,
        /// KV bytes this host did NOT recompute thanks to the hit (the
        /// shared entry's bytes on this rank; 0 on a cold prefill).
        prefix_bytes: u64,
    },
    /// Only the last host computes logits (all hosts hold identical hidden
    /// states after the merge, so one LM head suffices).
    StepDone { host: usize, sid: SessionId, logits: Option<Vec<f32>>, timing: DecodeTiming },
    /// Batched decode step: last host returns one logits row per entry, in
    /// entry order.
    BatchDone { host: usize, logits: Option<Vec<Vec<f32>>>, timing: DecodeTiming },
    Cleared { host: usize },
    Error { host: usize, msg: String },
}

/// Collective round tag for a decode batch: order-sensitive digest of the
/// session ids, so desynchronized batch composition across hosts trips the
/// fabric's tag assertion instead of silently merging the wrong partials.
/// Computed once by the leader and shipped on the [`Envelope`].
fn batch_tag(entries: &[(SessionId, i32)]) -> u64 {
    entries
        .iter()
        .fold(0x517C_C1B7_2722_0A95u64, |acc, (sid, _)| {
            acc.wrapping_mul(0x100_0000_01B3).wrapping_add(sid ^ 0x9E37_79B9_7F4A_7C15)
        })
}

/// Which execution driver a [`Cluster`] runs its hosts under.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Driver {
    /// The leader owns every `host::HostWorker` and advances decode jobs
    /// itself, round-robin in rank order. Single-threaded, deterministic,
    /// never blocks (every collective is posted by all ranks before any
    /// rank completes it — the microstep invariant). The test oracle.
    Sequential,
    /// One OS thread per host ([`host::run_host`]); collectives genuinely
    /// rendezvous and per-host wall clocks measure real overlap. The
    /// production driver and the default.
    Threaded,
}

impl Driver {
    /// Parse a driver name as accepted by `--driver` and `APB_DRIVER`.
    pub fn parse(s: &str) -> Option<Driver> {
        match s {
            "sequential" | "seq" => Some(Driver::Sequential),
            "threaded" | "thread" => Some(Driver::Threaded),
            _ => None,
        }
    }

    /// Driver choice from the `APB_DRIVER` environment variable
    /// (`sequential` | `threaded`), defaulting to [`Driver::Threaded`].
    /// Panics on an unrecognized value — a typo silently falling back to a
    /// different execution mode would invalidate a CI matrix leg.
    pub fn from_env() -> Driver {
        match std::env::var("APB_DRIVER") {
            Ok(s) => Driver::parse(&s).unwrap_or_else(|| {
                panic!("APB_DRIVER={s:?} is not a driver (expected sequential|threaded)")
            }),
            Err(_) => Driver::Threaded,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Driver::Sequential => "sequential",
            Driver::Threaded => "threaded",
        }
    }
}

struct HostHandle {
    cmd_tx: Sender<Envelope>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl HostHandle {
    /// The single leader→host entry point: enqueue one envelope.
    fn post(&self, env: Envelope) -> Result<()> {
        self.cmd_tx
            .send(env)
            .map_err(|_| anyhow::anyhow!("host channel closed"))
    }
}

/// How the leader reaches its hosts, per [`Driver`]. The sequential
/// workers sit in a `RefCell` (not a `Mutex`) on purpose: the leader is
/// the only caller, and a re-entrant dispatch is a bug worth a panic, not
/// a deadlock.
enum Link {
    Threaded {
        hosts: Vec<HostHandle>,
        resp_rx: Receiver<Resp>,
    },
    Sequential {
        workers: RefCell<Vec<host::HostWorker>>,
    },
}

/// Leader-side adaptive-chooser state for one session
/// (`docs/ADR-007-adaptive-decode.md`): whether its KV became resident
/// without a full document pass (prefix-store hit), how many conversation
/// turns it has appended, the attention method it was admitted under, and
/// any per-request strategy override from `ApbOptions::pass_strategy`.
#[derive(Debug, Clone, Copy)]
struct SessionMeta {
    prefix_hit: bool,
    turns: u32,
    method: AttnMethod,
    strategy: Option<PassStrategy>,
}

pub struct Cluster {
    pub cfg: Config,
    pub fabric: Arc<Interconnect>,
    driver: Driver,
    link: Link,
    /// Per-session [`SessionMeta`] feeding [`PassStrategy::Auto`]
    /// resolution. `RefCell` for the same reason as the sequential
    /// workers: the leader is the only caller.
    decode_meta: RefCell<HashMap<SessionId, SessionMeta>>,
    /// At most ONE prefill may be in flight per cluster: the ring machine
    /// keeps posted-but-incomplete fabric rounds across chunk steps, so a
    /// second interleaved prefill would join those rounds with a different
    /// session tag and trip the desync panic. [`PrefillPermit`] is the
    /// RAII claim on this slot; `clear_session`/`clear` release it
    /// directly (recovery path). Behind `Arc<Mutex>` so the permit can
    /// outlive any borrow of the cluster (it rides inside
    /// [`PrefillProgress`]).
    prefill_slot: Arc<Mutex<Option<SessionId>>>,
}

/// RAII claim on a cluster's one-prefill-in-flight slot, returned (inside
/// [`PrefillProgress`]) by [`Cluster::prefill_begin`] and consumed by the
/// final [`Cluster::prefill_step`].
///
/// Deliberately NOT released on `Drop`: after a failed step, ranks that
/// did not themselves error still hold machines (and, for ring, posted
/// fabric rounds), so the slot must stay held until
/// [`Cluster::clear_session`] cancels them — dropping the progress handle
/// must not quietly re-open admission.
pub struct PrefillPermit {
    slot: Arc<Mutex<Option<SessionId>>>,
    sid: SessionId,
}

impl PrefillPermit {
    fn claim(slot: &Arc<Mutex<Option<SessionId>>>, sid: SessionId) -> Result<PrefillPermit> {
        let mut guard = slot.lock().unwrap();
        if let Some(other) = *guard {
            bail!(
                "a prefill (session {other}) is already in flight on this \
                 cluster; drive it to completion (or clear that session) before \
                 beginning another — one resumable prefill at a time"
            );
        }
        *guard = Some(sid);
        Ok(PrefillPermit { slot: Arc::clone(slot), sid })
    }

    /// Release the slot — only if it still names this permit's session (a
    /// `clear_session` may already have re-opened it for someone else).
    fn finish(self) {
        let mut guard = self.slot.lock().unwrap();
        if *guard == Some(self.sid) {
            *guard = None;
        }
    }
}

/// Leader-side handle to one in-flight resumable prefill: how many chunk
/// steps remain, plus the accumulators (`wall_seconds` counts only time
/// spent inside `prefill_begin`/`prefill_step` calls — the interleaved
/// decode ticks of OTHER sessions are not charged to this request; the
/// comm delta per call is all this prefill's, because the leader drives
/// one command round at a time).
pub struct PrefillProgress {
    pub sid: SessionId,
    n_steps: usize,
    next: usize,
    wall_seconds: f64,
    comm_bytes: u64,
    per_host: Vec<PrefillTiming>,
    retained: Vec<Vec<Vec<Vec<u32>>>>,
    prefix_hit: bool,
    prefix_bytes_saved: u64,
    /// Whether every host's machine sits at a fabric-quiescent point (no
    /// posted-but-incomplete collective round). Rank-uniform by the
    /// lockstep invariant (asserted per step); `true` before the first
    /// step. Governs whether a suspend may release the prefill permit.
    quiescent: bool,
    /// The in-flight claim; taken and finished by the final step. Stays
    /// held across step errors (see [`PrefillPermit`]).
    permit: Option<PrefillPermit>,
}

impl PrefillProgress {
    /// Total `Cmd::PrefillChunk` steps this prefill takes.
    pub fn n_steps(&self) -> usize {
        self.n_steps
    }

    /// Steps already driven.
    pub fn steps_done(&self) -> usize {
        self.next
    }

    /// Whether this prefill attached to a cached shared prefix (warm) —
    /// known from `prefill_begin`, before any step is driven.
    pub fn prefix_hit(&self) -> bool {
        self.prefix_hit
    }

    /// Whether the machines currently hold no open fabric round (see the
    /// field doc). A [`Cluster::prefill_suspend`] at a quiescent boundary
    /// releases the one-prefill-at-a-time permit; a non-quiescent suspend
    /// parks the machines but keeps the permit held.
    pub fn fabric_quiescent(&self) -> bool {
        self.quiescent
    }
}

/// A parked in-flight prefill, produced by [`Cluster::prefill_suspend`]
/// and revived by [`Cluster::prefill_resume`]. The per-host
/// `PrefillMachine`s stay exactly where they are (keyed by session in each
/// host's machine map — parking involves NO host command), so resumption
/// is pure bookkeeping and the resumed run is bit-identical to an
/// uninterrupted one. The suspended session keeps its KV-pool slot and
/// therefore still counts toward residency.
///
/// If the suspend happened at a fabric-quiescent boundary the prefill
/// permit was released and other prefills may start (and finish) while
/// this one is parked. At a non-quiescent boundary the permit stays
/// captive in here — no other prefill can join the open collective
/// rounds — and is handed back verbatim on resume. Either way, dropping
/// the token without resuming leaks the session until
/// [`Cluster::clear_session`] reclaims it (which also drains any open
/// rounds and frees a captive permit's slot).
pub struct SuspendedPrefill {
    sid: SessionId,
    n_steps: usize,
    next: usize,
    wall_seconds: f64,
    comm_bytes: u64,
    per_host: Vec<PrefillTiming>,
    retained: Vec<Vec<Vec<Vec<u32>>>>,
    prefix_hit: bool,
    prefix_bytes_saved: u64,
    quiescent: bool,
    permit: Option<PrefillPermit>,
}

impl SuspendedPrefill {
    /// The parked session.
    pub fn sid(&self) -> SessionId {
        self.sid
    }

    /// Steps already driven before the suspend.
    pub fn steps_done(&self) -> usize {
        self.next
    }

    /// Total plan steps (unchanged by suspension).
    pub fn n_steps(&self) -> usize {
        self.n_steps
    }

    /// Whether the suspend landed on a fabric-quiescent boundary (permit
    /// released) or holds the permit captive.
    pub fn holds_permit(&self) -> bool {
        self.permit.is_some()
    }
}

/// Leader-side report for one prefill.
#[derive(Debug, Clone)]
pub struct PrefillReport {
    pub sid: SessionId,
    pub per_host: Vec<PrefillTiming>,
    /// retained[host][layer][kv_head] -> local-block indices kept by the
    /// compressor (ascending). Populated only when the request opted in
    /// via `ApbOptions::record_retained`; empty per-host vectors otherwise.
    pub retained: Vec<Vec<Vec<Vec<u32>>>>,
    pub wall_seconds: f64,
    pub comm_bytes: u64,
    /// Whether this request's prefill attached to a cached shared prefix
    /// (`docs/ADR-003-prefix-caching.md`) instead of recomputing. Always
    /// `false` when the cluster runs without `ApbParams::prefix_cache`.
    pub prefix_hit: bool,
    /// KV bytes the hit avoided recomputing, summed across hosts (0 cold).
    pub prefix_bytes_saved: u64,
}

impl PrefillReport {
    /// Recall of a set of *global document positions* in the compressor's
    /// retained set, averaged over layers and kv-heads — the measured twin
    /// of `oracle::compressor_recall`. Requires the prefill to have run
    /// with `ApbOptions::record_retained` (returns 0.0 otherwise).
    /// Positions on host 0 are never passed (host 0 sends to nobody's
    /// past), so callers typically plant needles beyond block 0.
    pub fn retention_recall(&self, cfg: &Config, positions: &[usize]) -> f64 {
        let l_b = cfg.apb.block_len;
        let mut hits = 0usize;
        let mut total = 0usize;
        for &pos in positions {
            let host = pos / l_b;
            let local = (pos % l_b) as u32;
            if host >= self.retained.len() {
                continue;
            }
            for layer in &self.retained[host] {
                for head in layer {
                    total += 1;
                    if head.binary_search(&local).is_ok() {
                        hits += 1;
                    }
                }
            }
        }
        if total == 0 {
            return 0.0;
        }
        hits as f64 / total as f64
    }
}

/// Leader-side report for one generation.
#[derive(Debug, Clone)]
pub struct GenReport {
    pub tokens: Vec<i32>,
    pub query_logits: Vec<f32>,
    pub wall_seconds: f64,
    pub per_step_seconds: Vec<f64>,
    /// Decode-path communication (query-chunk + per-step attention
    /// AllGathers), the decode twin of `PrefillReport::comm_bytes`.
    pub comm_bytes: u64,
}

/// Leader-side report for one session's query-chunk decode pass (or one
/// multi-turn append via [`Cluster::append_turn`]).
#[derive(Debug, Clone)]
pub struct ChunkReport {
    pub sid: SessionId,
    /// `[l_q, vocab]` logits rows (flattened) from the last host.
    pub logits: Vec<f32>,
    pub per_host: Vec<DecodeTiming>,
    pub wall_seconds: f64,
    pub comm_bytes: u64,
    /// The resolved pass strategy this round rode (never `Auto`).
    pub strategy: PassStrategy,
    /// This round's bytes on the pass-KV `att` AllGather.
    pub att_bytes: u64,
    /// This round's bytes on the pass-Q `qring` rotation.
    pub qring_bytes: u64,
}

/// Leader-side report for one continuous-batching decode step.
#[derive(Debug, Clone)]
pub struct StepBatchReport {
    /// One `[vocab]` logits row per submitted entry, in entry order.
    pub logits: Vec<(SessionId, Vec<f32>)>,
    pub per_host: Vec<DecodeTiming>,
    pub wall_seconds: f64,
    pub comm_bytes: u64,
    /// The resolved pass strategy this step rode (never `Auto`).
    pub strategy: PassStrategy,
    /// This step's bytes on the pass-KV `att` AllGather.
    pub att_bytes: u64,
    /// This step's bytes on the pass-Q `qring` rotation.
    pub qring_bytes: u64,
}

/// Token layout a host receives for one prefill, per attention method:
///
/// * `Apb` / `StarAttn` — the paper's `[anchor (l_aq) | local block]`
///   layout ([`host_tokens`]);
/// * `RingAttn` — the exact `[query | doc]` split: host 0 owns the query
///   prefix plus block 0, host r > 0 owns block r (global positions; no
///   anchor duplication);
/// * `Dense` — host 0 receives the entire `[query | doc]` sequence, every
///   other host receives nothing.
pub fn host_tokens_for(cfg: &Config, doc: &[i32], query: &[i32], rank: usize,
                       opts: &ApbOptions) -> Vec<i32> {
    let a = &cfg.apb;
    match opts.method {
        AttnMethod::Apb | AttnMethod::StarAttn => host_tokens(cfg, doc, query, rank, opts),
        AttnMethod::RingAttn => {
            if rank == 0 {
                let mut out = Vec::with_capacity(a.query_len + a.block_len);
                out.extend_from_slice(query);
                out.extend_from_slice(&doc[..a.block_len]);
                out
            } else {
                doc[rank * a.block_len..(rank + 1) * a.block_len].to_vec()
            }
        }
        AttnMethod::Dense => {
            if rank == 0 {
                let mut out = Vec::with_capacity(a.query_len + a.doc_len());
                out.extend_from_slice(query);
                out.extend_from_slice(doc);
                out
            } else {
                Vec::new()
            }
        }
    }
}

/// Mirror of `model.host_tokens`: [anchor (l_aq) | local block] layout for
/// host `rank`. Host 0 carries no anchor (zero-filled, masked out).
pub fn host_tokens(cfg: &Config, doc: &[i32], query: &[i32], rank: usize,
                   opts: &ApbOptions) -> Vec<i32> {
    let a = &cfg.apb;
    let mut out = vec![0i32; a.n_tot()];
    if rank > 0 && opts.use_anchor {
        if opts.embed_query {
            out[..a.query_len].copy_from_slice(query);
        }
        out[a.query_len..a.l_aq()].copy_from_slice(&doc[..a.anchor_len]);
    }
    out[a.l_aq()..].copy_from_slice(&doc[rank * a.block_len..(rank + 1) * a.block_len]);
    out
}

/// n_anchor runtime scalar for a host (mirror of `model.n_anchor_for`).
pub fn n_anchor_for(cfg: &Config, rank: usize, opts: &ApbOptions) -> i32 {
    if rank > 0 && opts.use_anchor {
        cfg.apb.l_aq() as i32
    } else {
        0
    }
}

impl Cluster {
    /// Start a cluster under the driver named by `APB_DRIVER`
    /// (default: threaded). See [`Cluster::start_with`].
    pub fn start(cfg: &Config) -> Result<Cluster> {
        Cluster::start_with(cfg, Driver::from_env())
    }

    /// Spawn (threaded) or construct in place (sequential) one worker per
    /// host; each compiles the artifact set and uploads weights. Blocks
    /// until all engines are ready.
    pub fn start_with(cfg: &Config, driver: Driver) -> Result<Cluster> {
        let fabric = Interconnect::new(cfg.apb.n_hosts);
        let link = match driver {
            Driver::Threaded => {
                let (resp_tx, resp_rx) = channel::<Resp>();
                let (ready_tx, ready_rx) = channel::<Result<usize>>();
                let mut hosts = Vec::with_capacity(cfg.apb.n_hosts);
                for rank in 0..cfg.apb.n_hosts {
                    let (cmd_tx, cmd_rx) = channel::<Envelope>();
                    let cfg2 = cfg.clone();
                    let fabric2 = Arc::clone(&fabric);
                    let resp_tx2 = resp_tx.clone();
                    let ready_tx2 = ready_tx.clone();
                    let join = std::thread::Builder::new()
                        .name(format!("apb-host-{rank}"))
                        .spawn(move || {
                            host::run_host(rank, cfg2, fabric2, cmd_rx, resp_tx2, ready_tx2)
                        })
                        .context("spawning host thread")?;
                    hosts.push(HostHandle { cmd_tx, join: Some(join) });
                }
                drop(ready_tx);
                for _ in 0..cfg.apb.n_hosts {
                    ready_rx.recv().context("host died during startup")??;
                }
                Link::Threaded { hosts, resp_rx }
            }
            Driver::Sequential => {
                let mut workers = Vec::with_capacity(cfg.apb.n_hosts);
                for rank in 0..cfg.apb.n_hosts {
                    workers.push(host::HostWorker::new(rank, cfg.clone(), Arc::clone(&fabric))?);
                }
                Link::Sequential { workers: RefCell::new(workers) }
            }
        };
        Ok(Cluster {
            cfg: cfg.clone(),
            fabric,
            driver,
            link,
            decode_meta: RefCell::new(HashMap::new()),
            prefill_slot: Arc::new(Mutex::new(None)),
        })
    }

    /// The driver this cluster runs under.
    pub fn driver(&self) -> Driver {
        self.driver
    }

    /// Release the in-flight prefill slot (unconditionally, or only if it
    /// names `sid`) — the recovery path `clear_session`/`clear` use; the
    /// happy path releases through [`PrefillPermit::finish`].
    fn release_prefill(&self, sid: Option<SessionId>) {
        let mut guard = self.prefill_slot.lock().unwrap();
        if sid.is_none() || *guard == sid {
            *guard = None;
        }
    }

    /// One envelope per host, identical bodies.
    fn fan_out(&self, sid: SessionId, tag: u64, body: Cmd) -> Vec<Envelope> {
        (0..self.cfg.apb.n_hosts)
            .map(|_| Envelope { sid, tag, body: body.clone() })
            .collect()
    }

    /// The ONE dispatch path both drivers share: deliver one envelope to
    /// every host, return every host's response (any order — responses
    /// carry their rank).
    ///
    /// Threaded: post all envelopes, then block for n responses (host
    /// threads rendezvous through the fabric among themselves; a wedged
    /// rank surfaces as that rank's timeout error response, so this recv
    /// is bounded too).
    ///
    /// Sequential: begin every envelope, then round-robin the opened
    /// decode jobs one microstep at a time in rank order. By the microstep
    /// invariant (every rank posts a collective round at the same step
    /// index and completes it at a strictly later index) no `job_step`
    /// ever blocks.
    fn dispatch(&self, envs: Vec<Envelope>) -> Result<Vec<Resp>> {
        debug_assert_eq!(envs.len(), self.cfg.apb.n_hosts);
        match &self.link {
            Link::Threaded { hosts, resp_rx } => {
                for (h, env) in hosts.iter().zip(envs) {
                    h.post(env)?;
                }
                let mut resps = Vec::with_capacity(hosts.len());
                for _ in 0..hosts.len() {
                    resps.push(
                        resp_rx.recv().context("cluster response channel closed")?,
                    );
                }
                Ok(resps)
            }
            Link::Sequential { workers } => {
                let mut workers = workers.borrow_mut();
                let n = workers.len();
                let mut resps: Vec<Option<Resp>> = (0..n).map(|_| None).collect();
                let mut jobs: Vec<Option<host::DecodeJob>> = (0..n).map(|_| None).collect();
                for (rank, env) in envs.into_iter().enumerate() {
                    match workers[rank].begin(env) {
                        host::Begun::Done(r) => resps[rank] = Some(r),
                        host::Begun::Job(j) => jobs[rank] = Some(j),
                    }
                }
                while jobs.iter().any(|j| j.is_some()) {
                    for rank in 0..n {
                        if let Some(job) = jobs[rank].as_mut() {
                            if let Some(r) = workers[rank].job_step(job) {
                                resps[rank] = Some(r);
                                jobs[rank] = None;
                            }
                        }
                    }
                }
                Ok(resps
                    .into_iter()
                    .map(|r| r.expect("every rank responds"))
                    .collect())
            }
        }
    }

    /// Dispatch + error folding: splits out `Resp::Error`s and fails with
    /// the joined messages AFTER the round fully drained (a partial drain
    /// would leave stale responses queued and desynchronize every later
    /// round on the threaded driver).
    fn transact(&self, envs: Vec<Envelope>) -> Result<Vec<Resp>> {
        let resps = self.dispatch(envs)?;
        let mut errors: Vec<String> = Vec::new();
        let mut ok = Vec::with_capacity(resps.len());
        for r in resps {
            match r {
                Resp::Error { host, msg } => errors.push(format!("host {host}: {msg}")),
                other => ok.push(other),
            }
        }
        if !errors.is_empty() {
            bail!("{}", errors.join("; "));
        }
        Ok(ok)
    }

    /// Start a resumable prefill of a document + query into session `sid`'s
    /// KV slot: every host claims the slot and builds its
    /// `prefill::PrefillMachine`; drive the returned [`PrefillProgress`]
    /// with [`Cluster::prefill_step`] until it yields the report. Fails
    /// with a backpressure error when every KV-pool slot is occupied, and
    /// when another prefill is already in flight (one at a time — the ring
    /// pipeline holds open fabric rounds across steps; see
    /// [`PrefillPermit`]).
    pub fn prefill_begin(
        &self,
        sid: SessionId,
        doc: &[i32],
        query: &[i32],
        opts: &ApbOptions,
    ) -> Result<PrefillProgress> {
        let a = &self.cfg.apb;
        if doc.len() != a.doc_len() {
            bail!("doc length {} != configured {}", doc.len(), a.doc_len());
        }
        if query.len() != a.query_len {
            bail!("query length {} != configured {}", query.len(), a.query_len);
        }
        let permit = PrefillPermit::claim(&self.prefill_slot, sid)?;
        match self.prefill_begin_inner(sid, doc, query, opts) {
            Ok(mut p) => {
                p.permit = Some(permit);
                // Seed the adaptive chooser: a prefix-store hit admits the
                // session warm (its KV is resident without a document
                // pass); turns accrue through `append_turn`.
                self.decode_meta.borrow_mut().insert(
                    sid,
                    SessionMeta {
                        prefix_hit: p.prefix_hit,
                        turns: 0,
                        method: opts.method,
                        strategy: opts.pass_strategy,
                    },
                );
                Ok(p)
            }
            Err(e) => {
                // No host holds a machine (begin either failed uniformly or
                // the error pre-empted the fan-out), so admission re-opens.
                permit.finish();
                Err(e)
            }
        }
    }

    /// Fallible body of [`Cluster::prefill_begin`]; the caller owns the
    /// permit.
    fn prefill_begin_inner(
        &self,
        sid: SessionId,
        doc: &[i32],
        query: &[i32],
        opts: &ApbOptions,
    ) -> Result<PrefillProgress> {
        let t0 = std::time::Instant::now();
        // Rank-symmetric prefix-cache key: computed once here from the FULL
        // request (hosts only see their per-rank token layouts) and shipped
        // with the begin, so every host looks up the same digest.
        let digest = self
            .cfg
            .apb
            .prefix_cache
            .then(|| crate::kvcache::prefix_digest(&self.cfg, doc, query, opts));
        let envs: Vec<Envelope> = (0..self.cfg.apb.n_hosts)
            .map(|rank| Envelope {
                sid,
                tag: sid,
                body: Cmd::PrefillBegin {
                    tokens: Arc::new(host_tokens_for(&self.cfg, doc, query, rank, opts)),
                    opts: *opts,
                    digest,
                },
            })
            .collect();
        let n_hosts = envs.len();
        let mut steps: Vec<usize> = Vec::with_capacity(n_hosts);
        let mut hits: Vec<bool> = Vec::with_capacity(n_hosts);
        for r in self.transact(envs)? {
            if let Resp::PrefillBegun { steps: s, sid: rsid, prefix_hit, .. } = r {
                debug_assert_eq!(rsid, sid);
                steps.push(s);
                hits.push(prefix_hit);
            }
        }
        // Digest-desync tripwire: hit/miss must be rank-uniform (the stores
        // evolve in leader lockstep, so a split verdict means a host's
        // store diverged — running collectives on a subset of ranks would
        // wedge the fabric).
        let prefix_hit = hits[0];
        if hits.iter().any(|&h| h != prefix_hit) {
            bail!(
                "prefix-cache digest desync for session {sid}: per-host \
                 hit verdicts {hits:?} are not rank-uniform"
            );
        }
        let n_steps = steps[0];
        if steps.iter().any(|&s| s != n_steps) {
            bail!("hosts disagree on the prefill plan length: {steps:?}");
        }
        Ok(PrefillProgress {
            sid,
            n_steps,
            next: 0,
            wall_seconds: t0.elapsed().as_secs_f64(),
            comm_bytes: 0,
            per_host: vec![PrefillTiming::default(); n_hosts],
            retained: vec![Vec::new(); n_hosts],
            prefix_hit,
            prefix_bytes_saved: 0,
            quiescent: true,
            permit: None,
        })
    }

    /// Drive one `Cmd::PrefillChunk` step on every host. Returns the
    /// finished [`PrefillReport`] after the final step, `None` before.
    /// Between calls the cluster is free for other work — this is the seam
    /// the stall-free scheduler interleaves decode ticks into.
    pub fn prefill_step(&self, p: &mut PrefillProgress) -> Result<Option<PrefillReport>> {
        if p.next >= p.n_steps {
            bail!("prefill for session {} already finished", p.sid);
        }
        let t0 = std::time::Instant::now();
        let bytes0 = self.fabric.meter.bytes_total();
        let last = p.next + 1 == p.n_steps;
        if let Err(e) = self.prefill_step_inner(p, last) {
            // Only the ranks that themselves errored dropped their
            // machines; surviving ranks may still hold machines (and, for
            // ring, posted rounds). The permit therefore STAYS held inside
            // `p`: recovery is `clear_session(sid)`, which aborts the
            // machines on every host (draining posted rounds) and releases
            // the slot — a fresh prefill before that clear would wedge the
            // fabric.
            return Err(e);
        }
        p.next += 1;
        p.wall_seconds += t0.elapsed().as_secs_f64();
        p.comm_bytes += self.fabric.meter.bytes_total() - bytes0;
        if !last {
            return Ok(None);
        }
        if let Some(permit) = p.permit.take() {
            permit.finish();
        }
        Ok(Some(PrefillReport {
            sid: p.sid,
            per_host: std::mem::take(&mut p.per_host),
            retained: std::mem::take(&mut p.retained),
            wall_seconds: p.wall_seconds,
            comm_bytes: p.comm_bytes,
            prefix_hit: p.prefix_hit,
            prefix_bytes_saved: p.prefix_bytes_saved,
        }))
    }

    /// Fallible body of [`Cluster::prefill_step`]: fan out one
    /// `PrefillChunk` and collect every host's step response (harvesting
    /// timing + retained indices on the final step).
    fn prefill_step_inner(&self, p: &mut PrefillProgress, last: bool) -> Result<()> {
        let envs = self.fan_out(p.sid, p.sid, Cmd::PrefillChunk { chunk_idx: p.next });
        let mut quiet: Vec<bool> = Vec::with_capacity(self.cfg.apb.n_hosts);
        for r in self.transact(envs)? {
            match r {
                Resp::PrefillStep { quiescent, .. } => {
                    debug_assert!(!last, "host finished early");
                    quiet.push(quiescent);
                }
                Resp::PrefillDone { host, timing, retained: ret, prefix_bytes, .. } => {
                    debug_assert!(last, "host finished late");
                    p.per_host[host] = timing;
                    p.retained[host] = ret;
                    p.prefix_bytes_saved += prefix_bytes;
                    quiet.push(true);
                }
                _ => {}
            }
        }
        // Quiescence-desync tripwire: fabric ops sit at identical plan
        // indices on every rank (lockstep invariant), so a split verdict
        // means a host's machine diverged from the shared plan.
        let quiescent = quiet[0];
        if quiet.iter().any(|&q| q != quiescent) {
            bail!(
                "prefill quiescence desync for session {}: per-host verdicts \
                 {quiet:?} are not rank-uniform",
                p.sid
            );
        }
        p.quiescent = quiescent;
        Ok(())
    }

    /// Park an in-flight prefill at the current chunk boundary WITHOUT
    /// aborting it: the per-host machines stay resident (no host command is
    /// sent — parking is leader-side bookkeeping only) and the returned
    /// [`SuspendedPrefill`] revives bit-identically via
    /// [`Cluster::prefill_resume`].
    ///
    /// At a fabric-quiescent boundary ([`PrefillProgress::fabric_quiescent`])
    /// the one-prefill-at-a-time permit is RELEASED, so other prefills can
    /// begin, run, and finish while this one is parked — this is the seam
    /// the SLO scheduler preempts through. At a non-quiescent boundary
    /// (mid ring rotation / mid APB gather) the permit stays captive
    /// inside the token: no other prefill can join the open collective
    /// rounds, so suspension is still safe at ANY chunk boundary — it just
    /// cannot re-open admission until resumed past the open round.
    ///
    /// Fails on a finished or errored progress handle (no permit to park).
    pub fn prefill_suspend(&self, mut p: PrefillProgress) -> Result<SuspendedPrefill> {
        if p.next >= p.n_steps {
            bail!("prefill for session {} already finished: nothing to suspend", p.sid);
        }
        let Some(permit) = p.permit.take() else {
            bail!(
                "prefill for session {} holds no permit (begin failed or a \
                 prior step errored); clear the session instead of suspending",
                p.sid
            );
        };
        let permit = if p.quiescent {
            permit.finish();
            None
        } else {
            Some(permit)
        };
        Ok(SuspendedPrefill {
            sid: p.sid,
            n_steps: p.n_steps,
            next: p.next,
            wall_seconds: p.wall_seconds,
            comm_bytes: p.comm_bytes,
            per_host: std::mem::take(&mut p.per_host),
            retained: std::mem::take(&mut p.retained),
            prefix_hit: p.prefix_hit,
            prefix_bytes_saved: p.prefix_bytes_saved,
            quiescent: p.quiescent,
            permit,
        })
    }

    /// Revive a suspended prefill: re-claim the one-prefill-at-a-time slot
    /// (or reuse the captive permit from a non-quiescent suspend) and hand
    /// back a [`PrefillProgress`] that continues exactly where the suspend
    /// left off. When another prefill currently holds the slot the token
    /// comes back untouched as `Err` so the caller can retry later —
    /// resumption never aborts or leaks the parked session.
    pub fn prefill_resume(
        &self,
        s: SuspendedPrefill,
    ) -> std::result::Result<PrefillProgress, SuspendedPrefill> {
        let mut s = s;
        let permit = match s.permit.take() {
            Some(p) => p,
            None => match PrefillPermit::claim(&self.prefill_slot, s.sid) {
                Ok(p) => p,
                Err(_) => return Err(s),
            },
        };
        Ok(PrefillProgress {
            sid: s.sid,
            n_steps: s.n_steps,
            next: s.next,
            wall_seconds: s.wall_seconds,
            comm_bytes: s.comm_bytes,
            per_host: s.per_host,
            retained: s.retained,
            prefix_hit: s.prefix_hit,
            prefix_bytes_saved: s.prefix_bytes_saved,
            quiescent: s.quiescent,
            permit: Some(permit),
        })
    }

    /// One-shot prefill (Algorithm 1 lines 1–12): begin, then drain every
    /// chunk step back to back. Bit-identical to any other chunk partition
    /// (see `docs/ADR-002-chunked-prefill.md`); the session stays resident
    /// until [`Cluster::clear_session`].
    pub fn prefill_session(
        &self,
        sid: SessionId,
        doc: &[i32],
        query: &[i32],
        opts: &ApbOptions,
    ) -> Result<PrefillReport> {
        let mut progress = self.prefill_begin(sid, doc, query, opts)?;
        loop {
            if let Some(report) = self.prefill_step(&mut progress)? {
                return Ok(report);
            }
        }
    }

    /// Per-host KV-pool accounting (indexed by rank) — the observable the
    /// chunk-split invariance tests compare and ops dashboards poll.
    pub fn pool_stats(&self) -> Result<Vec<PoolStats>> {
        let mut stats = vec![
            PoolStats {
                resident: 0,
                bytes_used: 0,
                bytes_reserved: 0,
                prefix_entries: 0,
                prefix_bytes: 0,
                slab_allocs: 0,
                slab_reuses: 0,
                slabs_free: 0,
            };
            self.cfg.apb.n_hosts
        ];
        for r in self.transact(self.fan_out(0, 0, Cmd::PoolStats))? {
            if let Resp::PoolStats { host, stats: s } = r {
                stats[host] = s;
            }
        }
        Ok(stats)
    }

    /// Resolve the pass strategy for one decode round over `sids`
    /// (`docs/ADR-007-adaptive-decode.md`). Precedence: a per-request
    /// override (`ApbOptions::pass_strategy`) applies when every session
    /// in the round carries the same one; otherwise the cluster default
    /// (`Config::pass_strategy`) governs. `Auto` resolves to pass-Q only
    /// when EVERY session in the round is warm — KV resident via a
    /// prefix-store hit or an earlier appended turn (`turn_append` marks
    /// the round itself as a follow-up over resident KV) — so a mixed
    /// round pays the gather and the choice stays batch-uniform. Never
    /// returns `Auto`: resolution is leader-side precisely so every rank
    /// rides the same collective.
    fn resolve_strategy(&self, sids: &[SessionId], turn_append: bool) -> PassStrategy {
        let meta = self.decode_meta.borrow();
        let mut warm = !sids.is_empty();
        let mut method = self.cfg.method;
        let mut overrides: Vec<Option<PassStrategy>> = Vec::with_capacity(sids.len());
        for sid in sids {
            match meta.get(sid) {
                Some(m) => {
                    warm &= m.prefix_hit || m.turns > 0;
                    method = m.method;
                    overrides.push(m.strategy);
                }
                None => {
                    warm = false;
                    overrides.push(None);
                }
            }
        }
        let warm = warm || turn_append;
        let chosen = match overrides.first() {
            Some(first) if overrides.iter().all(|o| o == first) => {
                first.unwrap_or(self.cfg.pass_strategy)
            }
            _ => self.cfg.pass_strategy,
        };
        chosen.resolve(warm, self.cfg.apb.n_hosts, method)
    }

    /// Re-feed a session's query chunk with exact distributed attention
    /// (Algorithm 1 lines 13–16), returning the chunk logits.
    pub fn decode_query_chunk(&self, sid: SessionId, query: &[i32]) -> Result<ChunkReport> {
        if query.len() != self.cfg.apb.query_len {
            bail!("query length {} != configured {}", query.len(), self.cfg.apb.query_len);
        }
        self.chunk_pass(sid, query, false)
    }

    /// Append a new conversation turn to a resident session: the turn's
    /// tokens re-prefill ONLY themselves, attending the resident
    /// `[shared | private]` cache exactly like the re-fed query chunk (one
    /// decode pass, self-causal on the last host), the host-side KV cache
    /// records the turn boundary, and the session counts as warm for the
    /// adaptive chooser from here on — a multi-turn follow-up is the
    /// canonical pass-Q round. Fails (on every host, as backpressure) when
    /// the turn would overflow the session's KV slot.
    pub fn append_turn(&self, sid: SessionId, tokens: &[i32]) -> Result<ChunkReport> {
        if tokens.is_empty() {
            bail!("append_turn of zero tokens");
        }
        let report = self.chunk_pass(sid, tokens, true)?;
        let mut meta = self.decode_meta.borrow_mut();
        meta.entry(sid)
            .or_insert(SessionMeta {
                prefix_hit: false,
                turns: 0,
                method: self.cfg.method,
                strategy: None,
            })
            .turns += 1;
        Ok(report)
    }

    /// Shared body of [`Cluster::decode_query_chunk`] /
    /// [`Cluster::append_turn`]: one strategy-resolved `Cmd::QueryChunk`
    /// round over every host.
    fn chunk_pass(&self, sid: SessionId, tokens: &[i32], turn: bool) -> Result<ChunkReport> {
        let strategy = self.resolve_strategy(&[sid], turn);
        let bytes0 = self.fabric.meter.bytes_total();
        let att0 = self.fabric.meter.bytes_for(Interconnect::ATT_LABEL);
        let qring0 = self.fabric.meter.bytes_for(Interconnect::QRING_LABEL);
        let t0 = std::time::Instant::now();
        let envs = self.fan_out(
            sid,
            sid,
            Cmd::QueryChunk { tokens: Arc::new(tokens.to_vec()), strategy, turn },
        );
        let mut logits: Option<Vec<f32>> = None;
        let mut per_host = vec![DecodeTiming::default(); self.cfg.apb.n_hosts];
        for r in self.transact(envs)? {
            if let Resp::StepDone { host, logits: l, timing, .. } = r {
                per_host[host] = timing;
                if let Some(l) = l {
                    logits = Some(l);
                }
            }
        }
        Ok(ChunkReport {
            sid,
            logits: logits.context("no host produced query logits")?,
            per_host,
            wall_seconds: t0.elapsed().as_secs_f64(),
            comm_bytes: self.fabric.meter.bytes_total() - bytes0,
            strategy,
            att_bytes: self.fabric.meter.bytes_for(Interconnect::ATT_LABEL) - att0,
            qring_bytes: self.fabric.meter.bytes_for(Interconnect::QRING_LABEL) - qring0,
        })
    }

    /// One continuous-batching decode step over the active sessions: each
    /// entry is (session, previously sampled token). All entries ride ONE
    /// stacked backend pass per layer on every host; logits come back per
    /// session in entry order.
    pub fn decode_step_batch(&self, entries: &[(SessionId, i32)]) -> Result<StepBatchReport> {
        if entries.is_empty() {
            bail!("decode_step_batch of zero sessions");
        }
        for (i, (sid, _)) in entries.iter().enumerate() {
            if entries[..i].iter().any(|(s, _)| s == sid) {
                bail!("session {sid} appears twice in one decode batch");
            }
        }
        let sids: Vec<SessionId> = entries.iter().map(|&(s, _)| s).collect();
        let strategy = self.resolve_strategy(&sids, false);
        let bytes0 = self.fabric.meter.bytes_total();
        let att0 = self.fabric.meter.bytes_for(Interconnect::ATT_LABEL);
        let qring0 = self.fabric.meter.bytes_for(Interconnect::QRING_LABEL);
        let t0 = std::time::Instant::now();
        let envs = self.fan_out(
            0,
            batch_tag(entries),
            Cmd::DecodeBatch { entries: Arc::new(entries.to_vec()), strategy },
        );
        let mut rows: Option<Vec<Vec<f32>>> = None;
        let mut per_host = vec![DecodeTiming::default(); self.cfg.apb.n_hosts];
        for r in self.transact(envs)? {
            if let Resp::BatchDone { host, logits, timing } = r {
                per_host[host] = timing;
                if let Some(l) = logits {
                    rows = Some(l);
                }
            }
        }
        let rows = rows.context("no host produced batch logits")?;
        if rows.len() != entries.len() {
            bail!("batch returned {} logit rows for {} entries", rows.len(), entries.len());
        }
        Ok(StepBatchReport {
            logits: entries.iter().map(|(s, _)| *s).zip(rows).collect(),
            per_host,
            wall_seconds: t0.elapsed().as_secs_f64(),
            comm_bytes: self.fabric.meter.bytes_total() - bytes0,
            strategy,
            att_bytes: self.fabric.meter.bytes_for(Interconnect::ATT_LABEL) - att0,
            qring_bytes: self.fabric.meter.bytes_for(Interconnect::QRING_LABEL) - qring0,
        })
    }

    /// Drop one session's state (KV slot + position bookkeeping + any
    /// in-flight prefill machine) on every host, freeing its residency
    /// slot. Clearing the session whose prefill is in flight cancels it
    /// cleanly: every host drains any posted-but-incomplete fabric round
    /// (see `PrefillMachine::abort`) and the one-prefill-at-a-time slot
    /// is released, so the cluster keeps serving.
    pub fn clear_session(&self, sid: SessionId) -> Result<()> {
        self.transact(self.fan_out(sid, sid, Cmd::Clear))?;
        self.decode_meta.borrow_mut().remove(&sid);
        self.release_prefill(Some(sid));
        Ok(())
    }

    /// Drop every session's state on every host, including any in-flight
    /// prefill machines (cancelled cleanly — posted fabric rounds are
    /// drained — and the in-flight slot is released).
    pub fn clear(&self) -> Result<()> {
        self.transact(self.fan_out(0, 0, Cmd::ClearAll))?;
        self.decode_meta.borrow_mut().clear();
        self.release_prefill(None);
        Ok(())
    }

    /// Legacy single-request prefill: runs as [`LEGACY_SESSION`], resetting
    /// that session's slot in place (the pre-session behaviour).
    pub fn prefill(&self, doc: &[i32], query: &[i32], opts: &ApbOptions)
                   -> Result<PrefillReport> {
        self.prefill_session(LEGACY_SESSION, doc, query, opts)
    }

    /// Decode: re-feed the query chunk with exact distributed attention,
    /// then greedily generate `max_new` tokens (Algorithm 1 lines 13–25)
    /// for the legacy session.
    pub fn generate(&self, query: &[i32], max_new: usize) -> Result<GenReport> {
        let t0 = std::time::Instant::now();
        let chunk = self.decode_query_chunk(LEGACY_SESSION, query)?;
        let mut comm_bytes = chunk.comm_bytes;
        let vocab = self.cfg.model.vocab_size;
        let last_row = &chunk.logits[chunk.logits.len() - vocab..];
        let mut token = Tensor::argmax_row(last_row) as i32;

        let mut tokens = Vec::with_capacity(max_new);
        let mut per_step = Vec::with_capacity(max_new);
        for step in 0..max_new {
            tokens.push(token);
            if step + 1 == max_new {
                break; // the last sampled token needs no further forward
            }
            let rep = self.decode_step_batch(&[(LEGACY_SESSION, token)])?;
            per_step.push(rep.wall_seconds);
            comm_bytes += rep.comm_bytes;
            token = Tensor::argmax_row(&rep.logits[0].1) as i32;
        }
        Ok(GenReport {
            tokens,
            query_logits: chunk.logits,
            wall_seconds: t0.elapsed().as_secs_f64(),
            per_step_seconds: per_step,
            comm_bytes,
        })
    }

    pub fn n_hosts(&self) -> usize {
        self.cfg.apb.n_hosts
    }
}

impl Drop for Cluster {
    fn drop(&mut self) {
        if let Link::Threaded { hosts, .. } = &mut self.link {
            for h in hosts.iter() {
                let _ = h.cmd_tx.send(Envelope { sid: 0, tag: 0, body: Cmd::Shutdown });
            }
            for h in hosts.iter_mut() {
                if let Some(j) = h.join.take() {
                    let _ = j.join();
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_cfg() -> Config {
        // Hand-built sim config (no artifacts needed for token-layout tests).
        Config::sim(
            "fake",
            crate::config::ModelConfig {
                vocab_size: 64,
                n_layers: 2,
                d_model: 32,
                n_heads: 4,
                n_kv_heads: 2,
                d_ff: 64,
                rope_theta: 1e4,
                rms_eps: 1e-5,
                retaining_hidden: 16,
            },
            crate::config::ApbParams {
                n_hosts: 3,
                block_len: 8,
                anchor_len: 4,
                query_len: 2,
                passing_len: 2,
                max_new_tokens: 4,
                max_resident: 2,
                chunk_tokens: 4,
                prefix_cache: false,
            },
            0,
        )
    }

    #[test]
    fn host_tokens_layout() {
        let cfg = fake_cfg();
        let doc: Vec<i32> = (100..124).collect();
        let query = vec![7, 8];
        let opts = ApbOptions::default();
        let t0 = host_tokens(&cfg, &doc, &query, 0, &opts);
        assert_eq!(t0.len(), cfg.apb.n_tot());
        assert!(t0[..cfg.apb.l_aq()].iter().all(|&t| t == 0));
        assert_eq!(&t0[cfg.apb.l_aq()..], &doc[..8]);

        let t1 = host_tokens(&cfg, &doc, &query, 1, &opts);
        assert_eq!(&t1[..2], &[7, 8]);
        assert_eq!(&t1[2..6], &doc[..4]);
        assert_eq!(&t1[6..], &doc[8..16]);
        assert_eq!(n_anchor_for(&cfg, 0, &opts), 0);
        assert_eq!(n_anchor_for(&cfg, 1, &opts), 6);
    }

    #[test]
    fn host_tokens_ablations() {
        let cfg = fake_cfg();
        let doc: Vec<i32> = (100..124).collect();
        let query = vec![7, 8];
        let no_q = ApbOptions { embed_query: false, ..Default::default() };
        let t1 = host_tokens(&cfg, &doc, &query, 1, &no_q);
        assert_eq!(&t1[..2], &[0, 0]);
        assert_eq!(&t1[2..6], &doc[..4]);

        let no_a = ApbOptions { use_anchor: false, ..Default::default() };
        let t1 = host_tokens(&cfg, &doc, &query, 1, &no_a);
        assert!(t1[..cfg.apb.l_aq()].iter().all(|&t| t == 0));
        assert_eq!(n_anchor_for(&cfg, 1, &no_a), 0);
    }

    #[test]
    fn host_tokens_for_exact_methods() {
        let cfg = fake_cfg(); // 3 hosts, l_b 8, l_a 4, l_q 2
        let doc: Vec<i32> = (100..124).collect();
        let query = vec![7, 8];
        let ring = ApbOptions { method: AttnMethod::RingAttn, ..Default::default() };
        // Ring host 0 owns [query | block 0] at global positions 0..l_q+l_b.
        let t0 = host_tokens_for(&cfg, &doc, &query, 0, &ring);
        assert_eq!(t0.len(), cfg.apb.query_len + cfg.apb.block_len);
        assert_eq!(&t0[..2], &[7, 8]);
        assert_eq!(&t0[2..], &doc[..8]);
        // Ring host r > 0 owns exactly its block, no anchor duplication.
        let t2 = host_tokens_for(&cfg, &doc, &query, 2, &ring);
        assert_eq!(&t2[..], &doc[16..24]);
        // Dense: everything on host 0, nothing elsewhere.
        let dense = ApbOptions { method: AttnMethod::Dense, ..Default::default() };
        let d0 = host_tokens_for(&cfg, &doc, &query, 0, &dense);
        assert_eq!(d0.len(), cfg.apb.query_len + cfg.apb.doc_len());
        assert_eq!(&d0[..2], &[7, 8]);
        assert_eq!(&d0[2..], &doc[..]);
        assert!(host_tokens_for(&cfg, &doc, &query, 1, &dense).is_empty());
        // APB/Star fall through to the paper's anchored layout.
        let apb = ApbOptions::default();
        assert_eq!(host_tokens_for(&cfg, &doc, &query, 1, &apb),
                   host_tokens(&cfg, &doc, &query, 1, &apb));
        let star = ApbOptions { method: AttnMethod::StarAttn, ..Default::default() };
        assert_eq!(host_tokens_for(&cfg, &doc, &query, 1, &star),
                   host_tokens(&cfg, &doc, &query, 1, &star));
    }

    #[test]
    fn batch_tag_is_order_sensitive_and_token_blind() {
        let a = batch_tag(&[(1, 5), (2, 9)]);
        let b = batch_tag(&[(2, 5), (1, 9)]);
        let c = batch_tag(&[(1, 0), (2, 0)]);
        assert_ne!(a, b, "session order must change the round tag");
        assert_eq!(a, c, "sampled tokens must not change the round tag");
        assert_ne!(batch_tag(&[(1, 0)]), batch_tag(&[(1, 0), (2, 0)]));
    }

    #[test]
    fn driver_parse_and_names() {
        assert_eq!(Driver::parse("sequential"), Some(Driver::Sequential));
        assert_eq!(Driver::parse("seq"), Some(Driver::Sequential));
        assert_eq!(Driver::parse("threaded"), Some(Driver::Threaded));
        assert_eq!(Driver::parse("thread"), Some(Driver::Threaded));
        assert_eq!(Driver::parse("parallel"), None);
        assert_eq!(Driver::Sequential.name(), "sequential");
        assert_eq!(Driver::Threaded.name(), "threaded");
    }

    #[test]
    fn auto_chooser_tracks_session_warmth() {
        let cfg = fake_cfg().with_pass_strategy(PassStrategy::Auto);
        let cluster = Cluster::start_with(&cfg, Driver::Sequential).expect("cluster");
        let meta = |hit, turns, strategy| SessionMeta {
            prefix_hit: hit,
            turns,
            method: AttnMethod::Apb,
            strategy,
        };
        // Unknown session: cold, Auto pays the gather.
        assert_eq!(cluster.resolve_strategy(&[1], false), PassStrategy::PassKv);
        // A turn append is by definition a follow-up over resident KV.
        assert_eq!(cluster.resolve_strategy(&[1], true), PassStrategy::PassQ);
        cluster.decode_meta.borrow_mut().insert(1, meta(true, 0, None));
        cluster.decode_meta.borrow_mut().insert(2, meta(false, 2, None));
        cluster.decode_meta.borrow_mut().insert(3, meta(false, 0, None));
        // Prefix-hit and multi-turn sessions are warm → pass-Q.
        assert_eq!(cluster.resolve_strategy(&[1], false), PassStrategy::PassQ);
        assert_eq!(cluster.resolve_strategy(&[2], false), PassStrategy::PassQ);
        assert_eq!(cluster.resolve_strategy(&[1, 2], false), PassStrategy::PassQ);
        // One cold session in the round pays the gather for everyone.
        assert_eq!(cluster.resolve_strategy(&[1, 3], false), PassStrategy::PassKv);
        // A uniform per-request override beats the cluster default...
        cluster.decode_meta.borrow_mut().insert(3, meta(false, 0, Some(PassStrategy::PassQ)));
        assert_eq!(cluster.resolve_strategy(&[3], false), PassStrategy::PassQ);
        // ...but a split override falls back to it (here: Auto over a
        // warm + cold pair → gather).
        cluster.decode_meta.borrow_mut().insert(1, meta(true, 0, Some(PassStrategy::PassKv)));
        assert_eq!(cluster.resolve_strategy(&[1, 3], false), PassStrategy::PassKv);
    }

    #[test]
    fn duplicate_sessions_in_one_batch_rejected() {
        let cfg = fake_cfg();
        let cluster = Cluster::start(&cfg).expect("cluster");
        let err = cluster.decode_step_batch(&[(1, 0), (2, 0), (1, 3)]).unwrap_err();
        assert!(format!("{err:#}").contains("twice"));
        assert!(cluster.decode_step_batch(&[]).is_err());
    }
}
