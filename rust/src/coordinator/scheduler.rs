//! Request scheduler: continuous batching over session slots, with
//! **stall-free chunked admission**.
//!
//! The pre-session scheduler drained a FIFO run-to-completion — one request
//! occupied all H hosts from prefill to last token, with a full cluster
//! clear in between. Serving heavy traffic (ROADMAP north star; cf. Medha
//! and "Context Parallelism for Scalable Million-Token Inference") needs
//! requests to be first-class instead: [`AdmissionQueue`] applies
//! backpressure at the door, the scheduler keeps up to
//! `ApbParams::max_resident` sessions' KV resident on the cluster at once,
//! and every decode tick advances ALL active sessions in one batched
//! backend pass per layer (`Cluster::decode_step_batch`).
//!
//! Admission is where head-of-line blocking used to live: a one-shot
//! prefill of a long request froze every resident session for its whole
//! duration. Each [`Scheduler::step`] now advances the admitting session's
//! resumable prefill by AT MOST ONE chunk (`Cluster::prefill_step`,
//! bounded by `chunk_tokens`) and *then* runs the batched decode tick, so
//! no resident session ever stalls longer than one chunk — Medha's "no
//! request left behind", executable. Per-request TTFT/TPOT (whose
//! definitions chunking does NOT change: TTFT is still enqueue → first
//! query-chunk logit) and the per-session `prefill_chunks` count land in
//! [`ServingMetrics`].
//!
//! When the cluster runs with `ApbParams::prefix_cache`, an admission
//! whose request matches a frozen shared prefix is warm: its entire
//! document pass collapses to one attach step, so the request reaches its
//! first token after one tick of admission work. [`ServingMetrics`]
//! reports `prefix_hits`, `prefix_bytes_saved` and the hit-aware
//! `ttft_cold` / `ttft_warm` split. (Admission CAPACITY is unchanged:
//! slots are counted per session, and a warm session still claims one —
//! prefix reuse saves compute, comm and physical KV bytes, not slots; see
//! ADR-003 "Rejected alternatives".)

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::config::ApbOptions;
use crate::util::stats::{summarize, Summary};

use super::{Cluster, PrefillProgress, PrefillReport, SessionId};

#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub doc: Vec<i32>,
    pub query: Vec<i32>,
    pub max_new: usize,
    pub opts: ApbOptions,
}

#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub queue_wait_s: f64,
    pub prefill: PrefillReport,
    pub gen_wall_s: f64,
    pub e2e_s: f64,
    /// Paper speed metric: (#input + #output) / (prefill + decode) time.
    pub speed_tok_per_s: f64,
    /// Time to first token: submission → first sampled token (includes
    /// queue wait, prefill and the query-chunk pass).
    pub ttft_s: f64,
    /// Time per output token: mean decode-step latency after the first
    /// token (0.0 for single-token requests).
    pub tpot_s: f64,
    /// Decode-path communication attributed to this request (query-chunk
    /// pass + its share of each batched step's AllGather traffic).
    pub decode_comm_bytes: u64,
    /// How many resumable-prefill steps (`Cmd::PrefillChunk`) admission
    /// drove for this request — the fairness knob's observable: more chunks
    /// = finer interleaving with resident sessions' decode ticks.
    pub prefill_chunks: usize,
}

/// Cluster-independent admission control: a bounded FIFO that rejects
/// (backpressure to the client) instead of growing without bound. Split
/// from the scheduler so the admission policy is unit-testable without a
/// live cluster.
pub struct AdmissionQueue {
    queue: VecDeque<(Request, Instant)>,
    pub max_queue: usize,
}

impl AdmissionQueue {
    pub fn new(max_queue: usize) -> Self {
        AdmissionQueue { queue: VecDeque::new(), max_queue }
    }

    /// Admission control: reject when the queue is full.
    pub fn submit(&mut self, req: Request) -> Result<()> {
        if self.queue.len() >= self.max_queue {
            bail!("queue full ({} requests): backpressure", self.max_queue);
        }
        self.queue.push_back((req, Instant::now()));
        Ok(())
    }

    pub fn pop(&mut self) -> Option<(Request, Instant)> {
        self.queue.pop_front()
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }
}

/// One admitted request holding a session slot on the cluster.
struct ActiveSession {
    sid: SessionId,
    /// Attention method the request was prefilled under — decides which
    /// decode group (distributed merge vs. Dense host-0) its ticks join.
    method: crate::config::AttnMethod,
    req_id: u64,
    enqueued: Instant,
    queue_wait_s: f64,
    prefill: PrefillReport,
    prefill_chunks: usize,
    max_new: usize,
    n_in: usize,
    tokens: Vec<i32>,
    ttft_s: f64,
    gen_started: Instant,
    step_seconds: Vec<f64>,
    decode_comm_bytes: u64,
}

impl ActiveSession {
    fn finished(&self) -> bool {
        self.tokens.len() >= self.max_new
    }
}

/// The one request whose resumable prefill admission is currently driving,
/// chunk by chunk. It already holds a KV-pool slot on every host (claimed
/// at `prefill_begin`), so it counts toward residency.
struct Admitting {
    req: Request,
    sid: SessionId,
    enqueued: Instant,
    /// Queue wait freezes when the request is popped for admission — the
    /// chunks that follow are service time, not queueing.
    queue_wait_s: f64,
    progress: PrefillProgress,
}

pub struct Scheduler<'a> {
    cluster: &'a Cluster,
    pub admission: AdmissionQueue,
    /// Residency bound: how many sessions may hold KV simultaneously
    /// (defaults to the config's `max_resident`, i.e. the KV-pool size —
    /// admitting more would be rejected by the hosts anyway). The
    /// admitting session's slot counts.
    pub max_resident: usize,
    active: Vec<ActiveSession>,
    admitting: Option<Admitting>,
    next_sid: SessionId,
    /// High-water mark of simultaneously resident sessions (decoding +
    /// admitting).
    pub peak_resident: usize,
    pub completed: Vec<Response>,
}

impl<'a> Scheduler<'a> {
    pub fn new(cluster: &'a Cluster, max_queue: usize) -> Self {
        Scheduler {
            cluster,
            admission: AdmissionQueue::new(max_queue),
            max_resident: cluster.cfg.apb.max_resident,
            active: Vec::new(),
            admitting: None,
            next_sid: super::LEGACY_SESSION + 1,
            peak_resident: 0,
            completed: Vec::new(),
        }
    }

    pub fn submit(&mut self, req: Request) -> Result<()> {
        self.admission.submit(req)
    }

    pub fn queued(&self) -> usize {
        self.admission.len()
    }

    /// Sessions currently resident on the cluster (decoding + the one being
    /// prefilled, which already holds its KV slot).
    pub fn resident(&self) -> usize {
        self.active.len() + usize::from(self.admitting.is_some())
    }

    /// The admission in flight, if any: (request id, chunk steps driven,
    /// total chunk steps). Test/ops observability for the stall-free
    /// guarantee.
    pub fn prefill_in_flight(&self) -> Option<(u64, usize, usize)> {
        self.admitting
            .as_ref()
            .map(|a| (a.req.id, a.progress.steps_done(), a.progress.n_steps()))
    }

    /// Tokens emitted so far per active (decoding) session, as
    /// (request id, count) pairs — lets tests assert decode progress
    /// BETWEEN an admission's prefill chunks.
    pub fn active_token_counts(&self) -> Vec<(u64, usize)> {
        self.active.iter().map(|s| (s.req_id, s.tokens.len())).collect()
    }

    /// Advance admission by AT MOST one prefill chunk: pop the next queued
    /// request into a free slot if no admission is in flight, then drive
    /// one `PrefillChunk` step. When the plan finishes, run the query-chunk
    /// pass (first token, TTFT) and move the session into the decode set.
    /// Everything here is bounded by one chunk of work — the stall-free
    /// invariant.
    fn admit_step(&mut self) -> Result<()> {
        if self.admitting.is_none() {
            // The admitting session claims a KV slot on every host, so it
            // must fit the residency bound alongside the decoding sessions.
            if self.active.len() + 1 > self.max_resident {
                return Ok(());
            }
            let Some((req, enqueued)) = self.admission.pop() else {
                return Ok(());
            };
            let sid = self.next_sid;
            self.next_sid += 1;
            let queue_wait_s = enqueued.elapsed().as_secs_f64();
            let progress =
                self.cluster.prefill_begin(sid, &req.doc, &req.query, &req.opts)?;
            self.admitting = Some(Admitting { req, sid, enqueued, queue_wait_s, progress });
            self.peak_resident = self.peak_resident.max(self.active.len() + 1);
        }
        let Some(a) = self.admitting.as_mut() else { return Ok(()) };
        let cluster = self.cluster;
        let Some(prefill) = cluster.prefill_step(&mut a.progress)? else {
            return Ok(()); // more chunks to go; decode ticks run in between
        };
        let Admitting { req, sid, enqueued, queue_wait_s, progress } =
            self.admitting.take().expect("admitting session vanished");
        let prefill_chunks = progress.n_steps();
        let gen_started = Instant::now();
        let chunk = cluster.decode_query_chunk(sid, &req.query)?;
        let vocab = cluster.cfg.model.vocab_size;
        let first = crate::util::tensor::Tensor::argmax_row(
            &chunk.logits[chunk.logits.len() - vocab..],
        ) as i32;
        // A zero-budget request still prefills + runs the chunk (the
        // pre-session scheduler did the same via generate(query, 0))
        // but emits no tokens; it retires on the next tick.
        let tokens = if req.max_new == 0 { Vec::new() } else { vec![first] };
        self.active.push(ActiveSession {
            sid,
            method: req.opts.method,
            req_id: req.id,
            enqueued,
            queue_wait_s,
            prefill,
            prefill_chunks,
            max_new: req.max_new,
            n_in: req.doc.len() + req.query.len(),
            tokens,
            // TTFT's definition is UNCHANGED by chunking: submission →
            // first query-chunk logit (it now naturally includes the decode
            // ticks interleaved between this request's prefill chunks).
            ttft_s: enqueued.elapsed().as_secs_f64(),
            gen_started,
            step_seconds: Vec::new(),
            decode_comm_bytes: chunk.comm_bytes,
        });
        Ok(())
    }

    /// One batched decode step across every active session that still owes
    /// tokens: each forwards its previously sampled token, all in one
    /// backend pass per layer. Sessions are grouped by decode path
    /// (distributed merge vs. Dense host-0 local) because Dense sessions
    /// never join the `att` collective — one sub-batch per non-empty group,
    /// in a fixed order so every host sees the same round sequence.
    fn decode_tick(&mut self) -> Result<()> {
        let group = |want_distributed: bool| -> Vec<(SessionId, i32)> {
            self.active
                .iter()
                .filter(|s| !s.finished() && s.method.distributed_decode() == want_distributed)
                .map(|s| (s.sid, *s.tokens.last().expect("chunk seeded one token")))
                .collect()
        };
        for entries in [group(true), group(false)] {
            self.decode_group(&entries)?;
        }
        Ok(())
    }

    /// Advance one decode group (possibly empty) by one batched step.
    fn decode_group(&mut self, entries: &[(SessionId, i32)]) -> Result<()> {
        if entries.is_empty() {
            return Ok(());
        }
        let rep = self.cluster.decode_step_batch(entries)?;
        // Exact attribution: spread the step's comm volume over the riders,
        // handing the division remainder to the first few so no bytes are
        // dropped from the per-request totals.
        let n = entries.len() as u64;
        let (share, rem) = (rep.comm_bytes / n, rep.comm_bytes % n);
        for (i, (sid, logits)) in rep.logits.iter().enumerate() {
            let s = self
                .active
                .iter_mut()
                .find(|s| s.sid == *sid)
                .expect("batch response for unknown session");
            s.tokens.push(crate::util::tensor::Tensor::argmax_row(logits) as i32);
            s.step_seconds.push(rep.wall_seconds);
            s.decode_comm_bytes += share + u64::from((i as u64) < rem);
        }
        Ok(())
    }

    /// Move finished sessions out of their slots, freeing host KV.
    fn retire(&mut self) -> Result<()> {
        let mut i = 0;
        while i < self.active.len() {
            if !self.active[i].finished() {
                i += 1;
                continue;
            }
            let s = self.active.remove(i);
            self.cluster.clear_session(s.sid)?;
            let gen_wall_s = s.gen_started.elapsed().as_secs_f64();
            let e2e_s = s.enqueued.elapsed().as_secs_f64() - s.queue_wait_s;
            let n_out = s.tokens.len();
            let speed = (s.n_in + n_out) as f64
                / (s.prefill.wall_seconds + gen_wall_s).max(f64::MIN_POSITIVE);
            let tpot_s = if s.step_seconds.is_empty() {
                0.0
            } else {
                s.step_seconds.iter().sum::<f64>() / s.step_seconds.len() as f64
            };
            self.completed.push(Response {
                id: s.req_id,
                tokens: s.tokens,
                queue_wait_s: s.queue_wait_s,
                prefill: s.prefill,
                gen_wall_s,
                e2e_s,
                speed_tok_per_s: speed,
                ttft_s: s.ttft_s,
                tpot_s,
                decode_comm_bytes: s.decode_comm_bytes,
                prefill_chunks: s.prefill_chunks,
            });
        }
        Ok(())
    }

    /// One scheduling tick: advance admission by AT MOST one prefill chunk,
    /// then advance every active session one token, then retire finished
    /// sessions — so a newly admitted long request can never freeze
    /// resident decoders for more than one chunk of work. Returns false
    /// when fully idle (nothing queued, nothing admitting, nothing
    /// resident).
    pub fn step(&mut self) -> Result<bool> {
        if self.max_resident == 0 {
            bail!("max_resident must be >= 1 (nothing could ever be admitted)");
        }
        if self.admission.is_empty() && self.active.is_empty() && self.admitting.is_none() {
            return Ok(false);
        }
        self.admit_step()?;
        self.decode_tick()?;
        self.retire()?;
        Ok(true)
    }

    /// Drain queue + active sessions; returns how many requests completed.
    pub fn run_all(&mut self) -> Result<usize> {
        let before = self.completed.len();
        while self.step()? {}
        Ok(self.completed.len() - before)
    }

    pub fn metrics(&self) -> ServingMetrics {
        let mut m = ServingMetrics::from_responses(&self.completed);
        m.peak_resident = self.peak_resident;
        m
    }
}

/// Aggregate serving metrics over completed requests.
#[derive(Debug, Clone)]
pub struct ServingMetrics {
    pub n_requests: usize,
    pub e2e: Summary,
    pub prefill: Summary,
    pub decode: Summary,
    pub queue_wait: Summary,
    pub speed_tok_per_s: Summary,
    pub ttft: Summary,
    pub tpot: Summary,
    /// Resumable-prefill steps driven per request: the chunked-admission
    /// fairness observable (1 step per layer phase minimum; grows as
    /// `chunk_tokens` shrinks).
    pub prefill_chunks: Summary,
    pub total_tokens: usize,
    pub decode_comm_bytes: u64,
    /// High-water mark of sessions resident at once (0 when built from
    /// bare responses).
    pub peak_resident: usize,
    /// Requests whose prefill attached to a cached shared prefix instead
    /// of recomputing (`docs/ADR-003-prefix-caching.md`); 0 unless the
    /// cluster runs with `ApbParams::prefix_cache`.
    pub prefix_hits: usize,
    /// KV bytes those hits avoided recomputing, summed across hosts and
    /// requests (`PrefillReport::prefix_bytes_saved`).
    pub prefix_bytes_saved: u64,
    /// Hit-aware TTFT split: latency summary over the cold (miss) requests
    /// only, `None` when no request missed. Warm admissions skip the whole
    /// document pass, so comparing these two summaries is the serving-side
    /// view of the prefix cache's win.
    pub ttft_cold: Option<Summary>,
    /// TTFT summary over the prefix-hit requests only, `None` without hits.
    pub ttft_warm: Option<Summary>,
}

impl ServingMetrics {
    pub fn from_responses(rs: &[Response]) -> ServingMetrics {
        assert!(!rs.is_empty(), "no completed responses");
        let col = |f: &dyn Fn(&Response) -> f64| -> Summary {
            summarize(&rs.iter().map(f).collect::<Vec<_>>())
        };
        let ttft_of = |want_hit: bool| -> Option<Summary> {
            let samples: Vec<f64> = rs
                .iter()
                .filter(|r| r.prefill.prefix_hit == want_hit)
                .map(|r| r.ttft_s)
                .collect();
            (!samples.is_empty()).then(|| summarize(&samples))
        };
        ServingMetrics {
            n_requests: rs.len(),
            e2e: col(&|r| r.e2e_s),
            prefill: col(&|r| r.prefill.wall_seconds),
            decode: col(&|r| r.gen_wall_s),
            queue_wait: col(&|r| r.queue_wait_s),
            speed_tok_per_s: col(&|r| r.speed_tok_per_s),
            ttft: col(&|r| r.ttft_s),
            tpot: col(&|r| r.tpot_s),
            prefill_chunks: col(&|r| r.prefill_chunks as f64),
            total_tokens: rs.iter().map(|r| r.tokens.len()).sum(),
            decode_comm_bytes: rs.iter().map(|r| r.decode_comm_bytes).sum(),
            peak_resident: 0,
            prefix_hits: rs.iter().filter(|r| r.prefill.prefix_hit).count(),
            prefix_bytes_saved: rs.iter().map(|r| r.prefill.prefix_bytes_saved).sum(),
            ttft_cold: ttft_of(false),
            ttft_warm: ttft_of(true),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> Request {
        Request {
            id,
            doc: vec![0; 8],
            query: vec![0; 2],
            max_new: 1,
            opts: ApbOptions::default(),
        }
    }

    #[test]
    fn backpressure_bounds_queue() {
        // Admission control without a cluster: the queue rejects beyond its
        // bound and frees capacity as requests are popped for admission.
        let mut q = AdmissionQueue::new(3);
        let mut rejected = 0;
        for i in 0..10 {
            match q.submit(req(i)) {
                Ok(()) => {}
                Err(e) => {
                    assert!(format!("{e:#}").contains("backpressure"));
                    rejected += 1;
                }
            }
        }
        assert_eq!(q.len(), 3);
        assert_eq!(rejected, 7);
        // FIFO pop order, and popping reopens admission.
        let (first, _) = q.pop().unwrap();
        assert_eq!(first.id, 0);
        q.submit(req(10)).unwrap();
        assert!(q.submit(req(11)).is_err());
        let ids: Vec<u64> = std::iter::from_fn(|| q.pop().map(|(r, _)| r.id)).collect();
        assert_eq!(ids, vec![1, 2, 10]);
        assert!(q.is_empty());
    }
}
