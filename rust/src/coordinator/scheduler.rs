//! Request scheduler: continuous batching over session slots, with
//! **stall-free chunked admission** and **SLO-aware preemptive
//! scheduling**.
//!
//! The pre-session scheduler drained a FIFO run-to-completion — one request
//! occupied all H hosts from prefill to last token, with a full cluster
//! clear in between. Serving heavy traffic (ROADMAP north star; cf. Medha
//! and "Context Parallelism for Scalable Million-Token Inference") needs
//! requests to be first-class instead: [`AdmissionQueue`] applies
//! backpressure at the door, the scheduler keeps up to
//! `ApbParams::max_resident` sessions' KV resident on the cluster at once,
//! and every decode tick advances ALL active sessions in one batched
//! backend pass per layer (`Cluster::decode_step_batch`).
//!
//! Admission is where head-of-line blocking used to live: a one-shot
//! prefill of a long request froze every resident session for its whole
//! duration. Each [`Scheduler::step`] advances the admitting session's
//! resumable prefill by AT MOST ONE chunk (`Cluster::prefill_step`,
//! bounded by `chunk_tokens`) and *then* runs the batched decode tick, so
//! no resident session ever stalls longer than one chunk — Medha's "no
//! request left behind", executable.
//!
//! Chunking bounds how long resident *decoders* wait, but FIFO admission
//! still lets one block-scale prefill head-of-line-block every *queued*
//! request behind it. [`SchedPolicy`] closes that gap:
//!
//! * **Priority classes** ([`Class`]) with per-class TTFT SLOs — the queue
//!   pops by [`effective_priority`], not arrival order.
//! * **Aging** — a request's effective priority improves linearly with
//!   every tick it waits, so class is a head start, never a trump card:
//!   after `aging_ticks` ticks of waiting a request outranks a fresh
//!   arrival one class above it (starvation-free admission).
//! * **Preemption** — when a strictly more urgent request is queued and
//!   the in-flight admission sits at a fabric-quiescent chunk boundary,
//!   the scheduler parks it ([`Cluster::prefill_suspend`]) without
//!   aborting: the per-host machines stay resident, the prefill permit is
//!   released, the urgent request admits, and the parked prefill resumes
//!   later bit-identically. Aging makes preemption self-limiting: once a
//!   request has waited `2 * aging_ticks` its effective priority is at
//!   least as urgent as ANY fresh arrival, so the strict-inequality
//!   preemption rule can never fire against it again.
//!
//! All policy decisions are made in scheduler **ticks** (one per
//! [`Scheduler::step`]), never wall clock, so a seeded trace replays
//! identically under `Driver::Sequential` and `Driver::Threaded`
//! ([`ReplayFingerprint`]). Per-request TTFT/TPOT land in
//! [`ServingMetrics`] with p50/p95/p99 spreads and per-class goodput.
//!
//! When the cluster runs with `ApbParams::prefix_cache`, an admission
//! whose request matches a frozen shared prefix is warm: its entire
//! document pass collapses to one attach step, so the request reaches its
//! first token after one tick of admission work. [`ServingMetrics`]
//! reports `prefix_hits`, `prefix_bytes_saved` and the hit-aware
//! `ttft_cold` / `ttft_warm` split. (Admission CAPACITY is unchanged:
//! slots are counted per session, and a warm session still claims one —
//! prefix reuse saves compute, comm and physical KV bytes, not slots; see
//! ADR-003 "Rejected alternatives".)

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::config::ApbOptions;
use crate::util::stats::{summarize, Summary};

use super::{Cluster, PrefillProgress, PrefillReport, SessionId, SuspendedPrefill};

/// Priority class of a request — the head start it gets at admission.
/// Lower [`Class::index`] admits sooner at equal waiting time; aging
/// ([`SchedPolicy::aging_ticks`]) converts waiting into priority so no
/// class can starve another (see [`effective_priority`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub enum Class {
    /// Latency-sensitive traffic (chat turns, short lookups).
    Interactive,
    /// The default for unclassified requests — exactly the old FIFO
    /// behavior when every request carries it.
    #[default]
    Standard,
    /// Throughput traffic that tolerates queueing (block-scale prefills,
    /// offline summarization).
    Batch,
}

impl Class {
    /// Every class, in priority order (most urgent first).
    pub const ALL: [Class; 3] = [Class::Interactive, Class::Standard, Class::Batch];

    /// Priority rank: 0 = most urgent. The multiplier in
    /// [`effective_priority`].
    pub fn index(self) -> usize {
        match self {
            Class::Interactive => 0,
            Class::Standard => 1,
            Class::Batch => 2,
        }
    }

    /// Stable lowercase name (CLI, reports, `BENCH_serving.json`).
    pub fn name(self) -> &'static str {
        match self {
            Class::Interactive => "interactive",
            Class::Standard => "standard",
            Class::Batch => "batch",
        }
    }

    /// Parse a class name as accepted by trace specs and the CLI.
    pub fn parse(s: &str) -> Option<Class> {
        match s {
            "interactive" => Some(Class::Interactive),
            "standard" => Some(Class::Standard),
            "batch" => Some(Class::Batch),
            _ => None,
        }
    }
}

/// Scheduling policy: per-class TTFT SLOs plus the aging and preemption
/// knobs. The default is back-compatible: all-`Standard` traffic under the
/// default policy degenerates to exact FIFO with zero preemptions (equal
/// class ⇒ effective priority orders by arrival; the strict-inequality
/// preemption rule never fires against the earliest arrival).
#[derive(Debug, Clone)]
pub struct SchedPolicy {
    /// Ticks of waiting worth one priority class: a request that has
    /// waited `aging_ticks` outranks a fresh arrival one class above it.
    /// Must be >= 1 (0 would erase classes entirely — use all-Standard
    /// traffic for that).
    pub aging_ticks: u64,
    /// Whether a strictly more urgent queued request may park the
    /// in-flight admission at a fabric-quiescent chunk boundary.
    pub preempt: bool,
    /// Per-class TTFT SLO in scheduler ticks, indexed by [`Class::index`].
    /// Goodput in [`ServingMetrics`] counts requests whose `ttft_ticks`
    /// meets their class SLO.
    pub slo_ttft_ticks: [u64; 3],
    /// The starvation tripwire: a completed request whose `ttft_ticks`
    /// exceeds this counts as starved in [`ServingMetrics::starved`]. The
    /// serving-invariant suite pins this to 0 on the smoke trace.
    pub starvation_budget_ticks: u64,
}

impl Default for SchedPolicy {
    fn default() -> Self {
        SchedPolicy {
            aging_ticks: 32,
            preempt: true,
            slo_ttft_ticks: [64, 256, 4096],
            starvation_budget_ticks: 1024,
        }
    }
}

/// Effective priority of a request that has waited `waited_ticks`:
/// `class.index() * aging_ticks - waited_ticks`. **Lower is more
/// urgent.** Class is a head start of `aging_ticks` per level; waiting
/// erodes it one tick at a time. Two properties the invariant tests lean
/// on:
///
/// * within one class this is exactly FIFO (longer wait ⇒ lower value);
/// * any request that has waited `Class::ALL.len() * aging_ticks` ticks
///   has a value ≤ the best any fresh arrival can present, so neither
///   admission selection nor the strict-inequality preemption rule can
///   pass it over — admission is starvation-free.
pub fn effective_priority(class: Class, waited_ticks: u64, aging_ticks: u64) -> i64 {
    class.index() as i64 * aging_ticks as i64 - waited_ticks as i64
}

#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub doc: Vec<i32>,
    pub query: Vec<i32>,
    pub max_new: usize,
    pub opts: ApbOptions,
    /// Priority class ([`Class::Standard`] preserves pre-policy behavior).
    pub class: Class,
}

/// Whether an error is a *backpressure* rejection (admission queue full,
/// KV pool exhausted, turn overflowing its KV slot) rather than a fault.
/// Both rejection sites spell it out in their message (see
/// [`AdmissionQueue::submit`] and `KvPool`'s exhaustion error, which
/// doc-tests the marker); the HTTP front door maps exactly these to
/// `429 Too Many Requests` + `Retry-After` and everything else to 500.
pub fn is_backpressure(err: &anyhow::Error) -> bool {
    format!("{err:#}").contains("backpressure")
}

#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub class: Class,
    pub tokens: Vec<i32>,
    pub queue_wait_s: f64,
    pub prefill: PrefillReport,
    pub gen_wall_s: f64,
    pub e2e_s: f64,
    /// Paper speed metric: (#input + #output) / (prefill + decode) time.
    pub speed_tok_per_s: f64,
    /// Time to first token — THE definition, used by every TTFT field in
    /// this crate (`ttft_s`, [`Response::ttft_ticks`], the
    /// [`ServingMetrics`] summaries and the `BENCH_serving.json` record):
    /// **enqueue → first query-chunk logit**. The span covers queue wait
    /// (frozen when the request is popped for admission, reported
    /// separately as `queue_wait_s`), every resumable-prefill chunk, the
    /// decode ticks of OTHER sessions interleaved between those chunks,
    /// AND any time the prefill spent suspended by a preemption — a
    /// preempted-then-resumed request's TTFT still measures from enqueue,
    /// never from resume (asserted by
    /// `ttft_spans_suspension_not_resume` in `rust/tests/slo_scheduling.rs`).
    pub ttft_s: f64,
    /// TTFT in scheduler ticks, same definition as [`Response::ttft_s`]:
    /// submit tick → the tick whose admission work produced the first
    /// query-chunk logit. Tick-based, so deterministic across drivers.
    pub ttft_ticks: u64,
    /// End-to-end service ticks: submit tick → retire tick, minus the
    /// ticks spent queued (the tick twin of `e2e_s`, which also excludes
    /// queue wait).
    pub e2e_ticks: u64,
    /// Ticks spent in the admission queue before being popped.
    pub queue_wait_ticks: u64,
    /// How many times this request's in-flight prefill was parked by the
    /// preemption policy (0 under FIFO-equivalent traffic).
    pub preemptions: usize,
    /// Time per output token: mean decode-step latency after the first
    /// token (0.0 for single-token requests).
    pub tpot_s: f64,
    /// Decode-path communication attributed to this request (query-chunk
    /// pass + its share of each batched step's merge traffic).
    pub decode_comm_bytes: u64,
    /// The pass-KV slice of `decode_comm_bytes`: bytes this request's
    /// rounds moved over the `att` AllGather
    /// (`docs/ADR-007-adaptive-decode.md`).
    pub decode_att_bytes: u64,
    /// The pass-Q slice of `decode_comm_bytes`: bytes over the `qring`
    /// rotation — per round independent of context length.
    pub decode_qring_bytes: u64,
    /// How many resumable-prefill steps (`Cmd::PrefillChunk`) admission
    /// drove for this request — the fairness knob's observable: more chunks
    /// = finer interleaving with resident sessions' decode ticks.
    pub prefill_chunks: usize,
}

/// One queued request, stamped with its submission tick (for aging) and a
/// submission sequence number (FIFO tie-break at equal priority).
struct Queued {
    req: Request,
    at: Instant,
    enq_tick: u64,
    seq: u64,
}

/// Cluster-independent admission control: a bounded queue that rejects
/// (backpressure to the client) instead of growing without bound, and pops
/// by [`effective_priority`] rather than arrival order. Split from the
/// scheduler so the admission policy is unit-testable without a live
/// cluster. With single-class traffic `pop_best` IS FIFO (aging orders by
/// arrival; ties broken by submission sequence).
pub struct AdmissionQueue {
    queue: VecDeque<Queued>,
    next_seq: u64,
    pub max_queue: usize,
}

impl AdmissionQueue {
    pub fn new(max_queue: usize) -> Self {
        AdmissionQueue { queue: VecDeque::new(), next_seq: 0, max_queue }
    }

    /// Admission control: reject when the queue is full. `now_tick` stamps
    /// the request for aging.
    pub fn submit(&mut self, req: Request, now_tick: u64) -> Result<()> {
        if self.queue.len() >= self.max_queue {
            bail!("queue full ({} requests): backpressure", self.max_queue);
        }
        let seq = self.next_seq;
        self.next_seq += 1;
        self.queue.push_back(Queued { req, at: Instant::now(), enq_tick: now_tick, seq });
        Ok(())
    }

    /// The best (lowest) effective priority any queued request presents at
    /// `now_tick`, or `None` on an empty queue. The preemption rule
    /// compares this against the in-flight admission.
    pub fn peek_best_eff(&self, now_tick: u64, aging_ticks: u64) -> Option<i64> {
        self.queue
            .iter()
            .map(|q| effective_priority(q.req.class, now_tick.saturating_sub(q.enq_tick), aging_ticks))
            .min()
    }

    /// Pop the most urgent request: lowest [`effective_priority`], ties
    /// broken by submission order. Returns the request plus its enqueue
    /// wall-instant and tick.
    pub fn pop_best(&mut self, now_tick: u64, aging_ticks: u64) -> Option<(Request, Instant, u64)> {
        let best = self
            .queue
            .iter()
            .enumerate()
            .min_by_key(|(_, q)| {
                (
                    effective_priority(
                        q.req.class,
                        now_tick.saturating_sub(q.enq_tick),
                        aging_ticks,
                    ),
                    q.seq,
                )
            })
            .map(|(i, _)| i)?;
        let q = self.queue.remove(best).expect("index from enumerate");
        Some((q.req, q.at, q.enq_tick))
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }
}

/// One admitted request holding a session slot on the cluster.
struct ActiveSession {
    sid: SessionId,
    /// Attention method the request was prefilled under — decides which
    /// decode group (distributed merge vs. Dense host-0) its ticks join.
    method: crate::config::AttnMethod,
    req_id: u64,
    class: Class,
    enqueued: Instant,
    enq_tick: u64,
    queue_wait_s: f64,
    queue_wait_ticks: u64,
    prefill: PrefillReport,
    prefill_chunks: usize,
    preemptions: usize,
    max_new: usize,
    n_in: usize,
    tokens: Vec<i32>,
    ttft_s: f64,
    ttft_ticks: u64,
    gen_started: Instant,
    step_seconds: Vec<f64>,
    decode_comm_bytes: u64,
    decode_att_bytes: u64,
    decode_qring_bytes: u64,
}

impl ActiveSession {
    fn finished(&self) -> bool {
        self.tokens.len() >= self.max_new
    }
}

/// The one request whose resumable prefill admission is currently driving,
/// chunk by chunk. It already holds a KV-pool slot on every host (claimed
/// at `prefill_begin`), so it counts toward residency.
struct Admitting {
    req: Request,
    sid: SessionId,
    enqueued: Instant,
    enq_tick: u64,
    /// Queue wait freezes when the request is popped for admission — the
    /// chunks that follow are service time, not queueing. Suspension time
    /// after a preemption is service time too (it still counts toward
    /// TTFT, which measures from enqueue).
    queue_wait_s: f64,
    queue_wait_ticks: u64,
    preemptions: usize,
    progress: PrefillProgress,
}

/// A preempted admission, parked mid-prefill. Holds its KV slot (counts
/// toward residency) and its [`SuspendedPrefill`] token; competes for
/// re-admission through the same [`effective_priority`] as the queue,
/// aged from its ORIGINAL submission tick.
struct Parked {
    req: Request,
    sid: SessionId,
    enqueued: Instant,
    enq_tick: u64,
    queue_wait_s: f64,
    queue_wait_ticks: u64,
    preemptions: usize,
    suspended: SuspendedPrefill,
}

pub struct Scheduler<'a> {
    cluster: &'a Cluster,
    pub admission: AdmissionQueue,
    /// The scheduling policy (classes, SLOs, aging, preemption). The
    /// default degenerates to FIFO under single-class traffic.
    pub policy: SchedPolicy,
    /// Residency bound: how many sessions may hold KV simultaneously
    /// (defaults to the config's `max_resident`, i.e. the KV-pool size —
    /// admitting more would be rejected by the hosts anyway). The
    /// admitting session's slot counts, and so does every parked
    /// (suspended) session: preemption trades latency, not memory.
    pub max_resident: usize,
    active: Vec<ActiveSession>,
    admitting: Option<Admitting>,
    /// Preempted admissions, parked mid-prefill (KV still resident).
    parked: Vec<Parked>,
    next_sid: SessionId,
    /// The scheduler clock: one tick per [`Scheduler::step`] call. Every
    /// policy decision (aging, SLOs, preemption) reads this — never wall
    /// time — so seeded traces replay identically across drivers.
    tick: u64,
    /// High-water mark of simultaneously resident sessions (decoding +
    /// admitting + parked).
    pub peak_resident: usize,
    /// Total preemptions performed (suspend events), across all requests.
    pub preemptions_total: usize,
    pub completed: Vec<Response>,
}

impl<'a> Scheduler<'a> {
    pub fn new(cluster: &'a Cluster, max_queue: usize) -> Self {
        Self::with_policy(cluster, max_queue, SchedPolicy::default())
    }

    /// A scheduler with an explicit [`SchedPolicy`].
    pub fn with_policy(cluster: &'a Cluster, max_queue: usize, policy: SchedPolicy) -> Self {
        assert!(policy.aging_ticks >= 1, "aging_ticks must be >= 1");
        Scheduler {
            cluster,
            admission: AdmissionQueue::new(max_queue),
            policy,
            max_resident: cluster.cfg.apb.max_resident,
            active: Vec::new(),
            admitting: None,
            parked: Vec::new(),
            next_sid: super::LEGACY_SESSION + 1,
            tick: 0,
            peak_resident: 0,
            preemptions_total: 0,
            completed: Vec::new(),
        }
    }

    pub fn submit(&mut self, req: Request) -> Result<()> {
        let tick = self.tick;
        self.admission.submit(req, tick)
    }

    pub fn queued(&self) -> usize {
        self.admission.len()
    }

    /// The scheduler clock (ticks elapsed = [`Scheduler::step`] calls).
    pub fn tick(&self) -> u64 {
        self.tick
    }

    /// Jump the scheduler clock forward to `tick` (no-op when already
    /// past). Trace replay uses this to model idle gaps between arrivals
    /// without burning a `step` per empty tick; aging and SLO accounting
    /// see the jump.
    pub fn advance_to(&mut self, tick: u64) {
        self.tick = self.tick.max(tick);
    }

    /// Sessions currently resident on the cluster: decoding + the one
    /// being prefilled + parked preempted admissions (all hold KV slots).
    pub fn resident(&self) -> usize {
        self.active.len() + usize::from(self.admitting.is_some()) + self.parked.len()
    }

    /// Preempted admissions currently parked mid-prefill.
    pub fn parked_count(&self) -> usize {
        self.parked.len()
    }

    /// The admission in flight, if any: (request id, chunk steps driven,
    /// total chunk steps). Test/ops observability for the stall-free
    /// guarantee.
    pub fn prefill_in_flight(&self) -> Option<(u64, usize, usize)> {
        self.admitting
            .as_ref()
            .map(|a| (a.req.id, a.progress.steps_done(), a.progress.n_steps()))
    }

    /// Tokens emitted so far per active (decoding) session, as
    /// (request id, count) pairs — lets tests assert decode progress
    /// BETWEEN an admission's prefill chunks.
    pub fn active_token_counts(&self) -> Vec<(u64, usize)> {
        self.active.iter().map(|s| (s.req_id, s.tokens.len())).collect()
    }

    /// Tokens emitted so far per active (decoding) session, as
    /// (request id, tokens) pairs. The HTTP front door's streaming loop
    /// reads this after every [`Scheduler::step`] and flushes the suffix
    /// beyond its per-request cursor as chunked-transfer token events —
    /// the scheduler itself stays streaming-agnostic.
    pub fn active_tokens(&self) -> Vec<(u64, &[i32])> {
        self.active.iter().map(|s| (s.req_id, s.tokens.as_slice())).collect()
    }

    /// [`Scheduler::metrics`] without the non-empty precondition: `None`
    /// until a first request completes. Ops surfaces (`GET /v1/metrics`)
    /// poll before, during, and after traffic, so "no data yet" has to be
    /// a value, not a panic.
    pub fn metrics_opt(&self) -> Option<ServingMetrics> {
        if self.completed.is_empty() {
            None
        } else {
            Some(self.metrics())
        }
    }

    /// Effective priority of the in-flight admission at the current tick.
    fn admitting_eff(&self) -> Option<i64> {
        self.admitting.as_ref().map(|a| {
            effective_priority(
                a.req.class,
                self.tick.saturating_sub(a.enq_tick),
                self.policy.aging_ticks,
            )
        })
    }

    /// Index of the most urgent parked admission, with its priority.
    fn best_parked(&self) -> Option<(usize, i64)> {
        self.parked
            .iter()
            .enumerate()
            .map(|(i, p)| {
                (
                    effective_priority(
                        p.req.class,
                        self.tick.saturating_sub(p.enq_tick),
                        self.policy.aging_ticks,
                    ),
                    i,
                )
            })
            .min()
            .map(|(eff, i)| (i, eff))
    }

    /// Preemption check: when a STRICTLY more urgent request is queued and
    /// the in-flight admission sits at a fabric-quiescent chunk boundary,
    /// park it (releasing the prefill permit) so the urgent request can
    /// admit this very tick. Aging makes this self-limiting: after one
    /// preemption the parked request and its preemptor age at the same
    /// rate, so their priority gap is constant and the parked one can
    /// never be preempted by the same rival again; after waiting
    /// `Class::ALL.len() * aging_ticks` its priority beats every possible
    /// fresh arrival, so the strict rule goes permanently quiet for it.
    fn maybe_preempt(&mut self) -> Result<()> {
        if !self.policy.preempt {
            return Ok(());
        }
        let Some(admit_eff) = self.admitting_eff() else { return Ok(()) };
        let Some(best_eff) = self.admission.peek_best_eff(self.tick, self.policy.aging_ticks)
        else {
            return Ok(());
        };
        if best_eff >= admit_eff {
            return Ok(());
        }
        // Only preempt at a fabric-quiescent boundary: a non-quiescent
        // suspend keeps the prefill permit captive, so the preemptor could
        // not begin its own prefill anyway — parking would add latency and
        // free nothing.
        if !self.admitting.as_ref().expect("checked above").progress.fabric_quiescent() {
            return Ok(());
        }
        // The preemptor needs a KV slot of its own next to the parked
        // session's (suspension keeps KV resident); without room the swap
        // would just stall admission entirely.
        if self.active.len() + self.parked.len() + 2 > self.max_resident {
            return Ok(());
        }
        let a = self.admitting.take().expect("checked above");
        let suspended = self.cluster.prefill_suspend(a.progress)?;
        self.preemptions_total += 1;
        self.parked.push(Parked {
            req: a.req,
            sid: a.sid,
            enqueued: a.enqueued,
            enq_tick: a.enq_tick,
            queue_wait_s: a.queue_wait_s,
            queue_wait_ticks: a.queue_wait_ticks,
            preemptions: a.preemptions + 1,
            suspended,
        });
        Ok(())
    }

    /// Fill the admission seat when empty: resume the most urgent parked
    /// admission or begin the most urgent queued request, whichever
    /// presents the lower effective priority (ties prefer the parked one —
    /// it was submitted no later, holds KV already, and may hold a captive
    /// permit that blocks fresh prefills).
    fn seat_next(&mut self) -> Result<()> {
        if self.admitting.is_some() {
            return Ok(());
        }
        let parked_best = self.best_parked();
        // A non-quiescent suspend keeps the prefill permit captive: no new
        // prefill can begin until that one resumes, so it overrides the
        // priority comparison.
        let captive = self.parked.iter().position(|p| p.suspended.holds_permit());
        let queued_best = self.admission.peek_best_eff(self.tick, self.policy.aging_ticks);
        let resume_idx = match (captive, parked_best, queued_best) {
            (Some(i), _, _) => Some(i),
            (None, Some((i, pe)), Some(qe)) if pe <= qe => Some(i),
            (None, Some((i, _)), None) => Some(i),
            _ => None,
        };
        if let Some(i) = resume_idx {
            let Parked {
                req,
                sid,
                enqueued,
                enq_tick,
                queue_wait_s,
                queue_wait_ticks,
                preemptions,
                suspended,
            } = self.parked.remove(i);
            match self.cluster.prefill_resume(suspended) {
                Ok(progress) => {
                    self.admitting = Some(Admitting {
                        req,
                        sid,
                        enqueued,
                        enq_tick,
                        queue_wait_s,
                        queue_wait_ticks,
                        preemptions,
                        progress,
                    });
                }
                Err(suspended) => {
                    // The prefill slot is held elsewhere (legacy caller
                    // outside the scheduler). Re-park and retry next tick.
                    self.parked.push(Parked {
                        req,
                        sid,
                        enqueued,
                        enq_tick,
                        queue_wait_s,
                        queue_wait_ticks,
                        preemptions,
                        suspended,
                    });
                }
            }
            return Ok(());
        }
        // The admitting session claims a KV slot on every host, so it must
        // fit the residency bound alongside decoders and parked sessions.
        if self.active.len() + self.parked.len() + 1 > self.max_resident {
            return Ok(());
        }
        let Some((req, enqueued, enq_tick)) =
            self.admission.pop_best(self.tick, self.policy.aging_ticks)
        else {
            return Ok(());
        };
        let sid = self.next_sid;
        self.next_sid += 1;
        let queue_wait_s = enqueued.elapsed().as_secs_f64();
        let queue_wait_ticks = self.tick.saturating_sub(enq_tick);
        let progress = self.cluster.prefill_begin(sid, &req.doc, &req.query, &req.opts)?;
        self.admitting = Some(Admitting {
            req,
            sid,
            enqueued,
            enq_tick,
            queue_wait_s,
            queue_wait_ticks,
            preemptions: 0,
            progress,
        });
        Ok(())
    }

    /// Advance admission by AT MOST one prefill chunk: apply the
    /// preemption rule, seat the most urgent waiting request if the seat
    /// is free, then drive one `PrefillChunk` step. When the plan
    /// finishes, run the query-chunk pass (first token, TTFT) and move the
    /// session into the decode set. Everything here is bounded by one
    /// chunk of work — the stall-free invariant.
    fn admit_step(&mut self) -> Result<()> {
        self.maybe_preempt()?;
        self.seat_next()?;
        self.peak_resident = self.peak_resident.max(self.resident());
        let Some(a) = self.admitting.as_mut() else { return Ok(()) };
        let cluster = self.cluster;
        let Some(prefill) = cluster.prefill_step(&mut a.progress)? else {
            return Ok(()); // more chunks to go; decode ticks run in between
        };
        let a = self.admitting.take().expect("admitting session vanished");
        let Admitting {
            req,
            sid,
            enqueued,
            enq_tick,
            queue_wait_s,
            queue_wait_ticks,
            preemptions,
            progress,
        } = a;
        let prefill_chunks = progress.n_steps();
        let gen_started = Instant::now();
        let chunk = cluster.decode_query_chunk(sid, &req.query)?;
        let vocab = cluster.cfg.model.vocab_size;
        let first = crate::util::tensor::Tensor::argmax_row(
            &chunk.logits[chunk.logits.len() - vocab..],
        ) as i32;
        // A zero-budget request still prefills + runs the chunk (the
        // pre-session scheduler did the same via generate(query, 0))
        // but emits no tokens; it retires on the next tick.
        let tokens = if req.max_new == 0 { Vec::new() } else { vec![first] };
        self.active.push(ActiveSession {
            sid,
            method: req.opts.method,
            req_id: req.id,
            class: req.class,
            enqueued,
            enq_tick,
            queue_wait_s,
            queue_wait_ticks,
            prefill,
            prefill_chunks,
            preemptions,
            max_new: req.max_new,
            n_in: req.doc.len() + req.query.len(),
            tokens,
            // TTFT per THE definition (see `Response::ttft_s`): measured
            // from enqueue, so it spans queue wait, every chunk, the
            // interleaved decode ticks AND any preemption-parked span.
            ttft_s: enqueued.elapsed().as_secs_f64(),
            ttft_ticks: self.tick.saturating_sub(enq_tick),
            gen_started,
            step_seconds: Vec::new(),
            decode_comm_bytes: chunk.comm_bytes,
            decode_att_bytes: chunk.att_bytes,
            decode_qring_bytes: chunk.qring_bytes,
        });
        Ok(())
    }

    /// One batched decode step across every active session that still owes
    /// tokens: each forwards its previously sampled token, all in one
    /// backend pass per layer. Sessions are grouped by decode path
    /// (distributed merge vs. Dense host-0 local) because Dense sessions
    /// never join the `att` collective — one sub-batch per non-empty group,
    /// in a fixed order so every host sees the same round sequence.
    fn decode_tick(&mut self) -> Result<()> {
        let group = |want_distributed: bool| -> Vec<(SessionId, i32)> {
            self.active
                .iter()
                .filter(|s| !s.finished() && s.method.distributed_decode() == want_distributed)
                .map(|s| (s.sid, *s.tokens.last().expect("chunk seeded one token")))
                .collect()
        };
        for entries in [group(true), group(false)] {
            self.decode_group(&entries)?;
        }
        Ok(())
    }

    /// Advance one decode group (possibly empty) by one batched step.
    fn decode_group(&mut self, entries: &[(SessionId, i32)]) -> Result<()> {
        if entries.is_empty() {
            return Ok(());
        }
        let rep = self.cluster.decode_step_batch(entries)?;
        // Exact attribution: spread the step's comm volume over the riders,
        // handing the division remainder to the first few so no bytes are
        // dropped from the per-request totals (same rule per label).
        let n = entries.len() as u64;
        let spread = |total: u64, i: usize| total / n + u64::from((i as u64) < total % n);
        for (i, (sid, logits)) in rep.logits.iter().enumerate() {
            let s = self
                .active
                .iter_mut()
                .find(|s| s.sid == *sid)
                .expect("batch response for unknown session");
            s.tokens.push(crate::util::tensor::Tensor::argmax_row(logits) as i32);
            s.step_seconds.push(rep.wall_seconds);
            s.decode_comm_bytes += spread(rep.comm_bytes, i);
            s.decode_att_bytes += spread(rep.att_bytes, i);
            s.decode_qring_bytes += spread(rep.qring_bytes, i);
        }
        Ok(())
    }

    /// Move finished sessions out of their slots, freeing host KV.
    fn retire(&mut self) -> Result<()> {
        let mut i = 0;
        while i < self.active.len() {
            if !self.active[i].finished() {
                i += 1;
                continue;
            }
            let s = self.active.remove(i);
            self.cluster.clear_session(s.sid)?;
            let gen_wall_s = s.gen_started.elapsed().as_secs_f64();
            let e2e_s = s.enqueued.elapsed().as_secs_f64() - s.queue_wait_s;
            let e2e_ticks = self
                .tick
                .saturating_sub(s.enq_tick)
                .saturating_sub(s.queue_wait_ticks);
            let n_out = s.tokens.len();
            let speed = (s.n_in + n_out) as f64
                / (s.prefill.wall_seconds + gen_wall_s).max(f64::MIN_POSITIVE);
            let tpot_s = if s.step_seconds.is_empty() {
                0.0
            } else {
                s.step_seconds.iter().sum::<f64>() / s.step_seconds.len() as f64
            };
            self.completed.push(Response {
                id: s.req_id,
                class: s.class,
                tokens: s.tokens,
                queue_wait_s: s.queue_wait_s,
                prefill: s.prefill,
                gen_wall_s,
                e2e_s,
                speed_tok_per_s: speed,
                ttft_s: s.ttft_s,
                ttft_ticks: s.ttft_ticks,
                e2e_ticks,
                queue_wait_ticks: s.queue_wait_ticks,
                preemptions: s.preemptions,
                tpot_s,
                decode_comm_bytes: s.decode_comm_bytes,
                decode_att_bytes: s.decode_att_bytes,
                decode_qring_bytes: s.decode_qring_bytes,
                prefill_chunks: s.prefill_chunks,
            });
        }
        Ok(())
    }

    /// One scheduling tick: advance the clock, apply preemption/seating,
    /// advance admission by AT MOST one prefill chunk, then advance every
    /// active session one token, then retire finished sessions — so a
    /// newly admitted long request can never freeze resident decoders for
    /// more than one chunk of work. Returns false when fully idle (nothing
    /// queued, nothing admitting, nothing parked, nothing resident).
    pub fn step(&mut self) -> Result<bool> {
        if self.max_resident == 0 {
            bail!("max_resident must be >= 1 (nothing could ever be admitted)");
        }
        if self.admission.is_empty()
            && self.active.is_empty()
            && self.admitting.is_none()
            && self.parked.is_empty()
        {
            return Ok(false);
        }
        self.tick += 1;
        self.admit_step()?;
        self.decode_tick()?;
        self.retire()?;
        Ok(true)
    }

    /// Drain queue + active sessions; returns how many requests completed.
    pub fn run_all(&mut self) -> Result<usize> {
        let before = self.completed.len();
        while self.step()? {}
        Ok(self.completed.len() - before)
    }

    pub fn metrics(&self) -> ServingMetrics {
        let mut m = ServingMetrics::with_policy(&self.completed, &self.policy);
        m.peak_resident = self.peak_resident;
        m.preemptions_total = self.preemptions_total;
        m
    }

    /// Timing-free digest of a finished run for cross-driver replay
    /// equality: everything here is deterministic given the same trace —
    /// token values, tick-based latencies, modeled comm bytes, policy
    /// tallies — while wall-clock fields (`*_s`) are excluded. Shared by
    /// `rust/tests/driver_parity.rs` and `rust/tests/slo_scheduling.rs`.
    pub fn replay_fingerprint(&self) -> ReplayFingerprint {
        let mut per_request: Vec<RequestFingerprint> = self
            .completed
            .iter()
            .map(|r| RequestFingerprint {
                id: r.id,
                class: r.class,
                tokens: r.tokens.clone(),
                prefill_comm_bytes: r.prefill.comm_bytes,
                prefill_chunks: r.prefill_chunks,
                prefix_hit: r.prefill.prefix_hit,
                ttft_ticks: r.ttft_ticks,
                e2e_ticks: r.e2e_ticks,
                queue_wait_ticks: r.queue_wait_ticks,
                preemptions: r.preemptions,
                decode_comm_bytes: r.decode_comm_bytes,
                decode_att_bytes: r.decode_att_bytes,
                decode_qring_bytes: r.decode_qring_bytes,
            })
            .collect();
        per_request.sort_by_key(|r| r.id);
        ReplayFingerprint {
            n_requests: self.completed.len(),
            total_tokens: self.completed.iter().map(|r| r.tokens.len()).sum(),
            final_tick: self.tick,
            peak_resident: self.peak_resident,
            preemptions_total: self.preemptions_total,
            per_request,
        }
    }
}

/// Per-completed-request digest inside [`ReplayFingerprint`] — only
/// driver-deterministic fields (tokens, ticks, modeled comm bytes), no
/// wall clock.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RequestFingerprint {
    pub id: u64,
    pub class: Class,
    pub tokens: Vec<i32>,
    pub prefill_comm_bytes: u64,
    pub prefill_chunks: usize,
    pub prefix_hit: bool,
    pub ttft_ticks: u64,
    pub e2e_ticks: u64,
    pub queue_wait_ticks: u64,
    pub preemptions: usize,
    pub decode_comm_bytes: u64,
    pub decode_att_bytes: u64,
    pub decode_qring_bytes: u64,
}

/// Normalized, timing-free run digest (see
/// [`Scheduler::replay_fingerprint`]): two runs of the same seeded trace
/// must compare equal under BOTH drivers.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ReplayFingerprint {
    pub n_requests: usize,
    pub total_tokens: usize,
    pub final_tick: u64,
    pub peak_resident: usize,
    pub preemptions_total: usize,
    pub per_request: Vec<RequestFingerprint>,
}

/// Per-class slice of [`ServingMetrics`]: latency spread and goodput for
/// one [`Class`] (absent classes are skipped, not zero-filled).
#[derive(Debug, Clone)]
pub struct ClassStats {
    pub class: Class,
    pub n_requests: usize,
    /// TTFT in scheduler ticks over this class's completed requests.
    pub ttft_ticks: Summary,
    /// Requests whose `ttft_ticks` met the class TTFT SLO
    /// ([`SchedPolicy::slo_ttft_ticks`]).
    pub slo_met: usize,
    /// Output tokens produced by SLO-meeting requests — "goodput" counts
    /// only work delivered within the latency contract.
    pub goodput_tokens: usize,
    /// Fraction of this class's requests that met their SLO.
    pub slo_fraction: f64,
}

/// Aggregate serving metrics over completed requests.
#[derive(Debug, Clone)]
pub struct ServingMetrics {
    pub n_requests: usize,
    pub e2e: Summary,
    pub prefill: Summary,
    pub decode: Summary,
    pub queue_wait: Summary,
    pub speed_tok_per_s: Summary,
    /// TTFT (seconds) — definition on [`Response::ttft_s`].
    pub ttft: Summary,
    /// TTFT in scheduler ticks — the deterministic twin of `ttft`.
    pub ttft_ticks: Summary,
    pub tpot: Summary,
    /// Resumable-prefill steps driven per request: the chunked-admission
    /// fairness observable (1 step per layer phase minimum; grows as
    /// `chunk_tokens` shrinks).
    pub prefill_chunks: Summary,
    pub total_tokens: usize,
    pub decode_comm_bytes: u64,
    /// Decode comm split by strategy label (ADR-007): bytes moved by the
    /// pass-KV `att` AllGather vs the pass-Q `qring` rotation. They sum to
    /// `decode_comm_bytes` (decode merges ride exactly one of the two).
    pub decode_att_bytes: u64,
    pub decode_qring_bytes: u64,
    /// High-water mark of sessions resident at once (0 when built from
    /// bare responses).
    pub peak_resident: usize,
    /// Per-class latency + goodput, in [`Class::ALL`] order, classes with
    /// no completed requests omitted.
    pub per_class: Vec<ClassStats>,
    /// Completed requests whose `ttft_ticks` blew the policy's
    /// starvation budget — the serving-invariant suite and the CI smoke
    /// trace pin this to 0.
    pub starved: usize,
    /// Total preemption (suspend) events across the run.
    pub preemptions_total: usize,
    /// Requests whose prefill attached to a cached shared prefix instead
    /// of recomputing (`docs/ADR-003-prefix-caching.md`); 0 unless the
    /// cluster runs with `ApbParams::prefix_cache`.
    pub prefix_hits: usize,
    /// KV bytes those hits avoided recomputing, summed across hosts and
    /// requests (`PrefillReport::prefix_bytes_saved`).
    pub prefix_bytes_saved: u64,
    /// Hit-aware TTFT split: latency summary over the cold (miss) requests
    /// only, `None` when no request missed. Warm admissions skip the whole
    /// document pass, so comparing these two summaries is the serving-side
    /// view of the prefix cache's win.
    pub ttft_cold: Option<Summary>,
    /// TTFT summary over the prefix-hit requests only, `None` without hits.
    pub ttft_warm: Option<Summary>,
}

impl ServingMetrics {
    /// Metrics under the default [`SchedPolicy`] (per-class SLOs at their
    /// default budgets).
    pub fn from_responses(rs: &[Response]) -> ServingMetrics {
        Self::with_policy(rs, &SchedPolicy::default())
    }

    /// Metrics with SLO/goodput accounting under an explicit policy.
    pub fn with_policy(rs: &[Response], policy: &SchedPolicy) -> ServingMetrics {
        assert!(!rs.is_empty(), "no completed responses");
        let col = |f: &dyn Fn(&Response) -> f64| -> Summary {
            summarize(&rs.iter().map(f).collect::<Vec<_>>())
        };
        let ttft_of = |want_hit: bool| -> Option<Summary> {
            let samples: Vec<f64> = rs
                .iter()
                .filter(|r| r.prefill.prefix_hit == want_hit)
                .map(|r| r.ttft_s)
                .collect();
            (!samples.is_empty()).then(|| summarize(&samples))
        };
        let per_class = Class::ALL
            .iter()
            .filter_map(|&class| {
                let of: Vec<&Response> = rs.iter().filter(|r| r.class == class).collect();
                if of.is_empty() {
                    return None;
                }
                let slo = policy.slo_ttft_ticks[class.index()];
                let met: Vec<&&Response> =
                    of.iter().filter(|r| r.ttft_ticks <= slo).collect();
                Some(ClassStats {
                    class,
                    n_requests: of.len(),
                    ttft_ticks: summarize(
                        &of.iter().map(|r| r.ttft_ticks as f64).collect::<Vec<_>>(),
                    ),
                    slo_met: met.len(),
                    goodput_tokens: met.iter().map(|r| r.tokens.len()).sum(),
                    slo_fraction: met.len() as f64 / of.len() as f64,
                })
            })
            .collect();
        ServingMetrics {
            n_requests: rs.len(),
            e2e: col(&|r| r.e2e_s),
            prefill: col(&|r| r.prefill.wall_seconds),
            decode: col(&|r| r.gen_wall_s),
            queue_wait: col(&|r| r.queue_wait_s),
            speed_tok_per_s: col(&|r| r.speed_tok_per_s),
            ttft: col(&|r| r.ttft_s),
            ttft_ticks: col(&|r| r.ttft_ticks as f64),
            tpot: col(&|r| r.tpot_s),
            prefill_chunks: col(&|r| r.prefill_chunks as f64),
            total_tokens: rs.iter().map(|r| r.tokens.len()).sum(),
            decode_comm_bytes: rs.iter().map(|r| r.decode_comm_bytes).sum(),
            decode_att_bytes: rs.iter().map(|r| r.decode_att_bytes).sum(),
            decode_qring_bytes: rs.iter().map(|r| r.decode_qring_bytes).sum(),
            peak_resident: 0,
            per_class,
            starved: rs
                .iter()
                .filter(|r| r.ttft_ticks > policy.starvation_budget_ticks)
                .count(),
            preemptions_total: rs.iter().map(|r| r.preemptions).sum(),
            prefix_hits: rs.iter().filter(|r| r.prefill.prefix_hit).count(),
            prefix_bytes_saved: rs.iter().map(|r| r.prefill.prefix_bytes_saved).sum(),
            ttft_cold: ttft_of(false),
            ttft_warm: ttft_of(true),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> Request {
        classed(id, Class::Standard)
    }

    fn classed(id: u64, class: Class) -> Request {
        Request {
            id,
            doc: vec![0; 8],
            query: vec![0; 2],
            max_new: 1,
            opts: ApbOptions::default(),
            class,
        }
    }

    #[test]
    fn backpressure_bounds_queue() {
        // Admission control without a cluster: the queue rejects beyond its
        // bound and frees capacity as requests are popped for admission.
        let mut q = AdmissionQueue::new(3);
        let mut rejected = 0;
        for i in 0..10 {
            match q.submit(req(i), 0) {
                Ok(()) => {}
                Err(e) => {
                    assert!(format!("{e:#}").contains("backpressure"));
                    rejected += 1;
                }
            }
        }
        assert_eq!(q.len(), 3);
        assert_eq!(rejected, 7);
        // Single-class pop_best IS FIFO, and popping reopens admission.
        let aging = SchedPolicy::default().aging_ticks;
        let (first, _, _) = q.pop_best(0, aging).unwrap();
        assert_eq!(first.id, 0);
        q.submit(req(10), 0).unwrap();
        assert!(q.submit(req(11), 0).is_err());
        let ids: Vec<u64> =
            std::iter::from_fn(|| q.pop_best(0, aging).map(|(r, _, _)| r.id)).collect();
        assert_eq!(ids, vec![1, 2, 10]);
        assert!(q.is_empty());
    }

    #[test]
    fn classes_order_admission_and_aging_promotes() {
        let aging = 8;
        let mut q = AdmissionQueue::new(16);
        q.submit(classed(0, Class::Batch), 0).unwrap();
        q.submit(classed(1, Class::Interactive), 5).unwrap();
        // At tick 5: batch has waited 5 (eff 2*8-5=11), fresh interactive
        // eff 0 — interactive admits first despite arriving later.
        assert_eq!(q.peek_best_eff(5, aging), Some(0));
        let (r, _, _) = q.pop_best(5, aging).unwrap();
        assert_eq!(r.id, 1);
        // Much later the aged batch request beats a fresh interactive: at
        // tick 0+2*aging its eff is 0, strictly below any later arrival.
        q.submit(classed(2, Class::Interactive), 17).unwrap();
        let (r, _, _) = q.pop_best(17, aging).unwrap();
        assert_eq!(r.id, 0, "aged batch request outranks fresh interactive");
    }

    #[test]
    fn effective_priority_is_fifo_within_class_and_bounded() {
        let aging = 32;
        for class in Class::ALL {
            // Within one class: strictly FIFO (earlier ⇒ lower value).
            assert!(
                effective_priority(class, 10, aging) < effective_priority(class, 3, aging)
            );
            // Starvation bound: after ALL.len()*aging ticks of waiting, no
            // fresh arrival of any class presents a lower value.
            let aged = effective_priority(class, Class::ALL.len() as u64 * aging, aging);
            for rival in Class::ALL {
                assert!(aged <= effective_priority(rival, 0, aging));
            }
        }
    }

    #[test]
    fn default_policy_is_fifo_compatible() {
        // All-Standard traffic under the default policy: pop order is
        // exactly arrival order regardless of probe tick.
        let mut q = AdmissionQueue::new(8);
        for i in 0..5 {
            q.submit(req(i), i * 3).unwrap();
        }
        let aging = SchedPolicy::default().aging_ticks;
        let ids: Vec<u64> =
            std::iter::from_fn(|| q.pop_best(100, aging).map(|(r, _, _)| r.id)).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
    }
}
