//! Request scheduler: FIFO admission queue with backpressure on top of the
//! cluster. APB is a prefill-throughput system, so scheduling is
//! run-to-completion per request (the paper's serving setting: one long
//! query occupies all H hosts); the scheduler's job is admission control,
//! queue-wait accounting, and aggregate serving metrics.

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::config::ApbOptions;
use crate::util::stats::{summarize, Summary};

use super::{Cluster, PrefillReport};

#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub doc: Vec<i32>,
    pub query: Vec<i32>,
    pub max_new: usize,
    pub opts: ApbOptions,
}

#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub queue_wait_s: f64,
    pub prefill: PrefillReport,
    pub gen_wall_s: f64,
    pub e2e_s: f64,
    /// Paper speed metric: (#input + #output) / (prefill + decode) time.
    pub speed_tok_per_s: f64,
}

pub struct Scheduler<'a> {
    cluster: &'a Cluster,
    queue: VecDeque<(Request, Instant)>,
    pub max_queue: usize,
    pub completed: Vec<Response>,
}

impl<'a> Scheduler<'a> {
    pub fn new(cluster: &'a Cluster, max_queue: usize) -> Self {
        Scheduler { cluster, queue: VecDeque::new(), max_queue, completed: Vec::new() }
    }

    /// Admission control: reject when the queue is full (backpressure to
    /// the client instead of unbounded memory growth).
    pub fn submit(&mut self, req: Request) -> Result<()> {
        if self.queue.len() >= self.max_queue {
            bail!("queue full ({} requests): backpressure", self.max_queue);
        }
        self.queue.push_back((req, Instant::now()));
        Ok(())
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// Process one queued request to completion. Returns false when idle.
    pub fn step(&mut self) -> Result<bool> {
        let Some((req, enq)) = self.queue.pop_front() else {
            return Ok(false);
        };
        let queue_wait_s = enq.elapsed().as_secs_f64();
        let t0 = Instant::now();
        self.cluster.clear()?;
        let prefill = self.cluster.prefill(&req.doc, &req.query, &req.opts)?;
        let gen = self.cluster.generate(&req.query, req.max_new)?;
        let e2e_s = t0.elapsed().as_secs_f64();
        let n_in = req.doc.len() + req.query.len();
        let n_out = gen.tokens.len();
        let speed = (n_in + n_out) as f64 / (prefill.wall_seconds + gen.wall_seconds);
        self.completed.push(Response {
            id: req.id,
            tokens: gen.tokens.clone(),
            queue_wait_s,
            prefill,
            gen_wall_s: gen.wall_seconds,
            e2e_s,
            speed_tok_per_s: speed,
        });
        let _ = gen; // GenReport consumed above
        Ok(true)
    }

    /// Drain the queue.
    pub fn run_all(&mut self) -> Result<usize> {
        let mut n = 0;
        while self.step()? {
            n += 1;
        }
        Ok(n)
    }

    pub fn metrics(&self) -> ServingMetrics {
        ServingMetrics::from_responses(&self.completed)
    }
}

/// Aggregate serving metrics over completed requests.
#[derive(Debug, Clone)]
pub struct ServingMetrics {
    pub n_requests: usize,
    pub e2e: Summary,
    pub prefill: Summary,
    pub decode: Summary,
    pub queue_wait: Summary,
    pub speed_tok_per_s: Summary,
    pub total_tokens: usize,
}

impl ServingMetrics {
    pub fn from_responses(rs: &[Response]) -> ServingMetrics {
        assert!(!rs.is_empty(), "no completed responses");
        let col = |f: &dyn Fn(&Response) -> f64| -> Summary {
            summarize(&rs.iter().map(f).collect::<Vec<_>>())
        };
        ServingMetrics {
            n_requests: rs.len(),
            e2e: col(&|r| r.e2e_s),
            prefill: col(&|r| r.prefill.wall_seconds),
            decode: col(&|r| r.gen_wall_s),
            queue_wait: col(&|r| r.queue_wait_s),
            speed_tok_per_s: col(&|r| r.speed_tok_per_s),
            total_tokens: rs.iter().map(|r| r.tokens.len()).sum(),
        }
    }
}


#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> Request {
        Request {
            id,
            doc: vec![0; 8],
            query: vec![0; 2],
            max_new: 1,
            opts: ApbOptions::default(),
        }
    }

    #[test]
    fn backpressure_bounds_queue() {
        // Scheduler logic is cluster-independent for admission control;
        // build it with a dangling reference via a tiny helper struct is
        // not possible, so we test through the public API in the
        // integration suite. Here: pure queue-bound check via submit().
        // (Cluster-dependent paths are covered in rust/tests/.)
        let cluster: Option<Cluster> = None;
        assert!(cluster.is_none());
        // Queue-bound property replicated on a plain VecDeque:
        let mut q: VecDeque<Request> = VecDeque::new();
        let max = 3;
        let mut rejected = 0;
        for i in 0..10 {
            if q.len() >= max {
                rejected += 1;
            } else {
                q.push_back(req(i));
            }
        }
        assert_eq!(q.len(), 3);
        assert_eq!(rejected, 7);
    }
}
