//! Resumable per-(chunk, layer) prefill state machines — the tentpole of
//! stall-free serving (`docs/ADR-002-chunked-prefill.md`).
//!
//! A [`PrefillMachine`] holds one session's in-flight prefill on one host.
//! The leader drives it with `Cmd::PrefillChunk { chunk_idx }` envelopes, one
//! bounded step at a time, so the scheduler can interleave resident
//! sessions' decode ticks between steps (Medha-style "no request left
//! behind"). Every machine advances through a *precomputed plan* whose
//! length and collective placement are identical on every rank — hosts
//! stay in lockstep on the fabric without any extra coordination.
//!
//! **The hard invariant is bit-identity**: for ANY `chunk_tokens`, the
//! machine produces exactly the same logits, KV-cache bytes and per-label
//! comm meter totals as the one-shot prefill it replaced (property-tested
//! in `rust/tests/chunked_prefill.rs`). It holds because
//!
//! * every backend stage underneath (RMSNorm, projection, RoPE, masked
//!   attention, FFN, the score MLP, the online-softmax merge) is
//!   **row-wise**, so slicing rows into chunks re-computes the same values;
//! * the **collective sequence is untouched** — chunking never adds,
//!   drops, reorders or resizes a fabric round.
//!
//! That second point dictates the shape of each machine:
//!
//! * **APB / StarAttn** are *layer-major*: the top-l_p selection needs the
//!   whole block's scores and the passing AllGather happens once per
//!   layer, so a layer runs `Pre×C → Select(+post) → Append×C →
//!   Assemble(complete) → Post×C` and only then moves on. (Chunk-major
//!   chunking would need per-chunk gathers — different comm.) The gather
//!   rides the split [`post`/`complete`](crate::cluster::collectives)
//!   halves with the C cache-append steps scheduled *inside* the window,
//!   so the compressed-block pass is genuinely hidden behind local work —
//!   the measured counterpart of the paper's Figure 1 overlap claim.
//! * **RingAttn** is layer-major too (the rotation moves *full* KV blocks),
//!   but the N-1 exchange rounds are software-pipelined through the split
//!   [`post`/`complete`](crate::cluster::collectives) halves: each round's
//!   block is posted *before* the previous block's attention partials are
//!   computed, overlapping communication with compute — the executable
//!   twin of the `max(comm, compute)` model in `attnsim::walltime`.
//! * **Dense** has no collectives and plain causal attention, so it gets
//!   the classic *chunk-major* chunked prefill: each step runs one chunk of
//!   rows through every layer against the session's running KV cache.
//!
//! One prefill may be in flight per cluster at a time (the ring pipeline
//! holds posted-but-incomplete fabric rounds across steps); the leader
//! enforces this in [`super::Cluster::prefill_begin`].
//!
//! A **prefix-cache hit** (`docs/ADR-003-prefix-caching.md`) degenerates
//! the whole plan to a single [`Op::PrefixAttach`] step: the session was
//! attached to the immutable `kvcache::SharedPrefix` at `PrefillBegin`, so
//! the machine fast-forwards every matched chunk — no compute, no
//! collective — and its `Done` serves the entry's frozen retained record.
//! The warm plan length (1) is rank-uniform exactly like the cold plans,
//! which is what lets the leader's plan-length check double as the
//! digest-desync tripwire.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::cluster::{complete_accounted, Interconnect, Receipt};
use crate::config::{ApbOptions, ApbParams, AttnMethod, Config};
use crate::kvcache::{KvCache, SessionId, SharedPrefix};
use crate::runtime::ExecBackend;
use crate::util::rng::random_score;
use crate::util::tensor::{merge_partials, top_lp_indices, Tensor};

use super::timing::{PrefillTiming, Stopwatch};

/// Everything a machine step may touch on its host, borrowed for the
/// duration of one `Cmd::PrefillChunk`.
pub(crate) struct StepCtx<'a> {
    pub rank: usize,
    pub cfg: &'a Config,
    pub fabric: &'a Interconnect,
    pub backend: &'a dyn ExecBackend,
    /// The session's KV-pool slot (claimed at `PrefillBegin`).
    pub cache: &'a mut KvCache,
}

/// What one step produced.
pub(crate) enum StepOutcome {
    /// More steps remain.
    Progress,
    /// Plan exhausted: accumulated timing + retained indices (the payload
    /// of `Resp::PrefillDone`).
    Done(PrefillTiming, Vec<Vec<Vec<u32>>>),
}

/// Global positions of host `rank`'s rows under the exact-method layout
/// `[query | doc]` (RingAttn): host 0 owns the query prefix + block 0
/// starting at position 0, host r > 0 owns block r starting at
/// `l_q + r·l_b`. Must mirror `super::host_tokens_for`.
pub(crate) fn ring_positions(a: &ApbParams, rank: usize) -> Vec<i32> {
    let (start, len) = if rank == 0 {
        (0usize, a.query_len + a.block_len)
    } else {
        (a.query_len + rank * a.block_len, a.block_len)
    };
    (start as i32..(start + len) as i32).collect()
}

/// Split `rows` into `n_chunks` ranges of (up to) `ct` rows each. `n_chunks`
/// is derived from the LARGEST per-host row count so every rank's plan has
/// the same length; ranks with fewer rows get trailing empty ranges.
fn chunk_ranges(rows: usize, ct: usize, n_chunks: usize) -> Vec<(usize, usize)> {
    (0..n_chunks)
        .map(|c| ((c * ct).min(rows), ((c + 1) * ct).min(rows)))
        .collect()
}

/// Per-kv-head gather of compressed KV rows: k/v are the local slices
/// `[l_b, kh, hd]`; `idx[j]` lists ascending positions for head j (§3.4).
fn gather_compressed(k: &Tensor, v: &Tensor, idx: &[Vec<usize>]) -> (Tensor, Tensor) {
    let (kh, hd) = (k.shape[1], k.shape[2]);
    let l_p = idx[0].len();
    let mut kc = Tensor::zeros(vec![l_p, kh, hd]);
    let mut vc = Tensor::zeros(vec![l_p, kh, hd]);
    for j in 0..kh {
        for (t, &i) in idx[j].iter().enumerate() {
            let src = (i * kh + j) * hd;
            let dst = (t * kh + j) * hd;
            kc.data[dst..dst + hd].copy_from_slice(&k.data[src..src + hd]);
            vc.data[dst..dst + hd].copy_from_slice(&v.data[src..src + hd]);
        }
    }
    (kc, vc)
}

// ---------------------------------------------------------------------------
// Plans
// ---------------------------------------------------------------------------

/// One bounded unit of prefill work. Ops touching the fabric (`ApbSelect`,
/// `ApbAssemble`, `RingPost`, `RingForward`, `RingComplete`) sit at the
/// same plan indices on every rank — that is the lockstep invariant.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Op {
    // --- APB / StarAttn (layer-major) ---------------------------------
    /// C == 1 fast path: the classic full-layout `layer_pre` (also the only
    /// pre op PJRT artifacts support).
    ApbPreFull { li: usize },
    /// Chunked pre: anchor rows (at c == 0) + one local chunk through
    /// projection/RoPE/scores.
    ApbPre { li: usize, c: usize },
    /// Top-l_p select (+ retained record) and, for APB, *posting* the
    /// per-layer AllGather of compressed blocks (completed by
    /// `ApbAssemble`; StarAttn posts nothing).
    ApbSelect { li: usize },
    /// Append one chunk's LOCAL rows to the session's KV slot — scheduled
    /// inside the gather window so the pass hides behind cache work.
    ApbAppend { li: usize, c: usize },
    /// Complete the compressed-block gather and assemble the passing
    /// blocks of ranks < mine.
    ApbAssemble { li: usize },
    /// Modified-mask attention + FFN for one chunk.
    ApbPost { li: usize, c: usize },
    // --- RingAttn (layer-major, pipelined rotation) --------------------
    RingPre { li: usize, c: usize },
    /// Post this host's own (K, V) block into exchange round 1.
    RingPost { li: usize },
    /// Complete the previous exchange and immediately post the received
    /// block onward (the forwarding step of the rotation pipeline).
    RingForward { li: usize },
    /// Complete the final exchange of the layer.
    RingComplete { li: usize },
    /// Attention partial of block `s` (0 = own block) for one chunk of
    /// query rows — for s >= 1 this runs while the NEXT exchange is in
    /// flight (comm/compute overlap).
    RingPartial { li: usize, s: usize, c: usize },
    /// Online-softmax merge + decode_post for one chunk of rows.
    RingTail { li: usize, c: usize },
    /// Append this host's own block KV to the session slot.
    RingAppend { li: usize },
    // --- Dense (chunk-major) -------------------------------------------
    /// One chunk of `[query | doc]` rows through EVERY layer against the
    /// running KV cache (host 0 only; other ranks no-op in lockstep).
    DenseChunk { c: usize },
    // --- Prefix-cache hit (any method) ---------------------------------
    /// Warm fast path: the session already attached to a `SharedPrefix`
    /// at `PrefillBegin`; this single step retires the plan with the
    /// entry's frozen timing-free outcome. No compute, no collective.
    PrefixAttach,
}

fn apb_plan(n_layers: usize, n_chunks: usize) -> Vec<Op> {
    let mut plan = Vec::with_capacity(n_layers * (3 * n_chunks + 2));
    for li in 0..n_layers {
        if n_chunks == 1 {
            plan.push(Op::ApbPreFull { li });
        } else {
            plan.extend((0..n_chunks).map(|c| Op::ApbPre { li, c }));
        }
        plan.push(Op::ApbSelect { li });
        // The cache appends sit between post and complete on purpose: they
        // are the local work the gather window hides behind.
        plan.extend((0..n_chunks).map(|c| Op::ApbAppend { li, c }));
        plan.push(Op::ApbAssemble { li });
        plan.extend((0..n_chunks).map(|c| Op::ApbPost { li, c }));
    }
    plan
}

fn ring_plan(n_layers: usize, n_hosts: usize, n_chunks: usize) -> Vec<Op> {
    let mut plan = Vec::new();
    for li in 0..n_layers {
        plan.extend((0..n_chunks).map(|c| Op::RingPre { li, c }));
        if n_hosts > 1 {
            plan.push(Op::RingPost { li });
        }
        plan.extend((0..n_chunks).map(|c| Op::RingPartial { li, s: 0, c }));
        for s in 1..n_hosts.saturating_sub(1) {
            plan.push(Op::RingForward { li });
            plan.extend((0..n_chunks).map(|c| Op::RingPartial { li, s, c }));
        }
        if n_hosts > 1 {
            plan.push(Op::RingComplete { li });
            plan.extend((0..n_chunks).map(|c| Op::RingPartial { li, s: n_hosts - 1, c }));
        }
        plan.extend((0..n_chunks).map(|c| Op::RingTail { li, c }));
        plan.push(Op::RingAppend { li });
    }
    plan
}

fn dense_plan(n_chunks: usize) -> Vec<Op> {
    (0..n_chunks).map(|c| Op::DenseChunk { c }).collect()
}

// ---------------------------------------------------------------------------
// The machine
// ---------------------------------------------------------------------------

/// One session's resumable prefill on one host: a precomputed [`Op`] plan
/// plus the per-layer carry state the ops thread across step boundaries
/// (layer-input hidden, the layer's q/k/v and scores, ring partial
/// accumulators, outstanding collective receipts, the running KV in the
/// pool slot).
pub(crate) struct PrefillMachine {
    sid: SessionId,
    opts: ApbOptions,
    plan: Vec<Op>,
    next: usize,
    tm: PrefillTiming,
    retained: Vec<Vec<Vec<u32>>>,
    /// Chunk row ranges. APB: over the local block. Ring: over this host's
    /// `[query? | block]` rows. Dense: over host 0's whole sequence.
    chunks: Vec<(usize, usize)>,
    /// Layer-input hidden states, updated in place as post/tail chunks
    /// complete. APB: `[n_tot, d]`. Ring: `[rows, d]`. Dense: unused (the
    /// chunk-major walk embeds per chunk).
    hidden: Tensor,
    /// Dense keeps the raw tokens (embedded chunk by chunk).
    tokens: Vec<i32>,
    /// Current layer's projected q/k/v (assembled chunk by chunk) and
    /// compressor scores (APB).
    q: Tensor,
    k: Tensor,
    v: Tensor,
    scores: Tensor,
    /// APB: assembled passing blocks of the current layer.
    k_pass: Tensor,
    v_pass: Tensor,
    pass_len: i32,
    n_anchor: i32,
    pos_offset: i32,
    /// Ring: global positions of this host's rows.
    positions: Vec<i32>,
    /// Ring: every origin's position vector, precomputed once (the partial
    /// ops consume one per received block, every chunk of every layer).
    origin_positions: Vec<Vec<i32>>,
    /// Ring: accumulated attention partials of the current layer, in the
    /// same order the monolithic loop pushed them (own block first, then
    /// each received block with origin < rank).
    outs: Vec<Tensor>,
    lses: Vec<Tensor>,
    /// Ring: the block received by the last completed exchange.
    held: Option<(Tensor, Tensor)>,
    /// Ring: receipt of the posted-but-not-yet-completed exchange round.
    pending_ring: Option<Receipt>,
    /// APB: receipt of the posted-but-not-yet-completed compressed-block
    /// gather (in flight between `ApbSelect` and `ApbAssemble`).
    pending_gather: Option<Receipt>,
    /// Prefix-cache key this request was begun under (`None` when the
    /// cluster runs without `ApbParams::prefix_cache`). A cold machine
    /// with a digest freezes its document KV into the store at the final
    /// step (`host::HostWorker::prefill_chunk`).
    digest: Option<u64>,
    /// The shared entry a warm machine attached to (`None` on cold runs).
    warm: Option<Arc<SharedPrefix>>,
}

impl PrefillMachine {
    /// Build the machine for `sid` and return it with its plan length
    /// (identical on every rank for a given request). Embeds the host's
    /// rows up front for the layer-major methods; Dense embeds per chunk.
    pub(crate) fn new(
        rank: usize,
        cfg: &Config,
        sid: SessionId,
        tokens: &[i32],
        opts: &ApbOptions,
        backend: &dyn ExecBackend,
        digest: Option<u64>,
    ) -> Result<(PrefillMachine, usize)> {
        let (a, m) = (&cfg.apb, &cfg.model);
        let ct = a.chunk_tokens_for(opts);
        let t0 = std::time::Instant::now();
        let mut sw = Stopwatch::start();
        let mut tm = PrefillTiming::default();

        let (plan, chunks, hidden, positions, kept_tokens) = match opts.method {
            AttnMethod::Apb | AttnMethod::StarAttn => {
                if tokens.len() != a.n_tot() {
                    bail!("apb prefill: host {rank} wants {} rows, got {}",
                          a.n_tot(), tokens.len());
                }
                let n_chunks = a.block_len.div_ceil(ct);
                let chunks = chunk_ranges(a.block_len, ct, n_chunks);
                let hidden = backend.embed(tokens)?;
                tm.embed_s += sw.lap();
                (apb_plan(m.n_layers, n_chunks), chunks, hidden, Vec::new(), Vec::new())
            }
            AttnMethod::RingAttn => {
                let positions = ring_positions(a, rank);
                if tokens.len() != positions.len() {
                    bail!("ring prefill: host {rank} wants {} rows, got {}",
                          positions.len(), tokens.len());
                }
                // Host 0 owns the most rows; its count fixes the (rank-
                // uniform) chunk count, trailing ranges on other ranks are
                // empty.
                let max_rows = a.query_len + a.block_len;
                let n_chunks = max_rows.div_ceil(ct);
                let chunks = chunk_ranges(positions.len(), ct, n_chunks);
                let hidden = backend.embed(tokens)?;
                tm.embed_s += sw.lap();
                (ring_plan(m.n_layers, a.n_hosts, n_chunks), chunks, hidden, positions,
                 Vec::new())
            }
            AttnMethod::Dense => {
                let rows = a.query_len + a.doc_len();
                if rank == 0 && tokens.len() != rows {
                    bail!("dense prefill: host 0 wants {rows} rows, got {}", tokens.len());
                }
                let n_chunks = rows.div_ceil(ct);
                let chunks = chunk_ranges(if rank == 0 { rows } else { 0 }, ct, n_chunks);
                (dense_plan(n_chunks), chunks, Tensor::zeros(vec![0, 0]), Vec::new(),
                 tokens.to_vec())
            }
        };
        let _ = sw.lap();
        tm.total_s += t0.elapsed().as_secs_f64();

        let machine = PrefillMachine {
            sid,
            opts: *opts,
            plan,
            next: 0,
            tm,
            retained: Vec::new(),
            chunks,
            hidden,
            tokens: kept_tokens,
            q: Tensor::zeros(vec![0, 0]),
            k: Tensor::zeros(vec![0, 0]),
            v: Tensor::zeros(vec![0, 0]),
            scores: Tensor::zeros(vec![0, 0]),
            k_pass: Tensor::zeros(vec![0, 0]),
            v_pass: Tensor::zeros(vec![0, 0]),
            pass_len: 0,
            n_anchor: super::n_anchor_for(cfg, rank, opts),
            pos_offset: (a.query_len + rank * a.block_len) as i32,
            origin_positions: if positions.is_empty() {
                Vec::new()
            } else {
                (0..a.n_hosts).map(|r| ring_positions(a, r)).collect()
            },
            positions,
            outs: Vec::new(),
            lses: Vec::new(),
            held: None,
            pending_ring: None,
            pending_gather: None,
            digest,
            warm: None,
        };
        let steps = machine.plan.len();
        Ok((machine, steps))
    }

    /// Build the warm (prefix-hit) machine: a one-step [`Op::PrefixAttach`]
    /// plan over the `SharedPrefix` entry the session attached to at
    /// `PrefillBegin`. Rank-uniform by construction — every host either
    /// holds the digest's entry or none does (tripwired by the leader).
    pub(crate) fn new_warm(
        sid: SessionId,
        opts: &ApbOptions,
        digest: u64,
        entry: Arc<SharedPrefix>,
    ) -> (PrefillMachine, usize) {
        let machine = PrefillMachine {
            sid,
            opts: *opts,
            plan: vec![Op::PrefixAttach],
            next: 0,
            tm: PrefillTiming::default(),
            // Served verbatim from the cold run that froze the entry
            // (nonempty only under `record_retained`, which is part of the
            // digest — so recording requests only hit recording entries).
            retained: entry.retained().clone(),
            chunks: Vec::new(),
            hidden: Tensor::zeros(vec![0, 0]),
            tokens: Vec::new(),
            q: Tensor::zeros(vec![0, 0]),
            k: Tensor::zeros(vec![0, 0]),
            v: Tensor::zeros(vec![0, 0]),
            scores: Tensor::zeros(vec![0, 0]),
            k_pass: Tensor::zeros(vec![0, 0]),
            v_pass: Tensor::zeros(vec![0, 0]),
            pass_len: 0,
            n_anchor: 0,
            pos_offset: 0,
            origin_positions: Vec::new(),
            positions: Vec::new(),
            outs: Vec::new(),
            lses: Vec::new(),
            held: None,
            pending_ring: None,
            pending_gather: None,
            digest: Some(digest),
            warm: Some(entry),
        };
        (machine, 1)
    }

    /// The prefix-cache key this machine was begun under, if any.
    pub(crate) fn digest(&self) -> Option<u64> {
        self.digest
    }

    /// The shared entry a warm machine rides (`None` on cold runs).
    pub(crate) fn warm_entry(&self) -> Option<&Arc<SharedPrefix>> {
        self.warm.as_ref()
    }

    /// True when this rank holds no posted-but-incomplete fabric round
    /// (neither a ring rotation nor an APB compressed-block gather is in
    /// flight). At a quiescent boundary the machine can be parked
    /// indefinitely — and the one-prefill-at-a-time permit released — with
    /// no peer able to observe the pause, because every collective this
    /// machine will ever touch again starts from a fresh post. The plan
    /// builders make quiescence rank-uniform: fabric ops sit at identical
    /// plan indices on every rank (lockstep invariant), so either all
    /// ranks report quiescent at a boundary or none do.
    pub(crate) fn fabric_quiescent(&self) -> bool {
        self.pending_ring.is_none() && self.pending_gather.is_none()
    }

    /// Cancel the machine, draining any posted-but-incomplete fabric round
    /// (the ring rotation and/or the APB compressed-block gather) via
    /// [`cancel`](crate::cluster::collectives::Fabric::cancel). Never
    /// blocks: if the round already completed (the common case under the
    /// leader's lockstep, where every rank posted during the same step)
    /// the delivery is discarded; if the round is genuinely still open
    /// (a peer died mid-round) the contribution is retracted — either way
    /// the collective's per-rank state is pristine for the next session.
    /// Every rank runs this from the same `Cmd::Clear`/`ClearAll`.
    pub(crate) fn abort(mut self, rank: usize, fabric: &Interconnect) {
        if let Some(receipt) = self.pending_ring.take() {
            fabric.ring_pass.cancel(rank, receipt);
        }
        if let Some(receipt) = self.pending_gather.take() {
            fabric.kv_gather.cancel(rank, receipt);
        }
    }

    /// Advance by exactly one plan op. `chunk_idx` must equal the number of
    /// steps already taken — a mismatch means the leader and this host
    /// disagree about the machine's progress (desync tripwire).
    pub(crate) fn step(&mut self, ctx: &mut StepCtx<'_>, chunk_idx: usize)
                       -> Result<StepOutcome> {
        if chunk_idx != self.next {
            bail!(
                "prefill chunk desync for session {}: leader drives step {chunk_idx}, \
                 host {} expects {}",
                self.sid, ctx.rank, self.next
            );
        }
        let Some(&op) = self.plan.get(self.next) else {
            bail!("prefill for session {} already finished", self.sid);
        };
        let t0 = std::time::Instant::now();
        match op {
            Op::ApbPreFull { li } => self.apb_pre_full(ctx, li)?,
            Op::ApbPre { li, c } => self.apb_pre(ctx, li, c)?,
            Op::ApbSelect { li } => self.apb_select(ctx, li)?,
            Op::ApbAppend { li, c } => self.apb_append(ctx, li, c)?,
            Op::ApbAssemble { li } => self.apb_assemble(ctx, li)?,
            Op::ApbPost { li, c } => self.apb_post(ctx, li, c)?,
            Op::RingPre { li, c } => self.ring_pre(ctx, li, c)?,
            Op::RingPost { li } => self.ring_post(ctx, li)?,
            Op::RingForward { li } => self.ring_forward(ctx, li)?,
            Op::RingComplete { li } => self.ring_complete(ctx, li)?,
            Op::RingPartial { li, s, c } => self.ring_partial(ctx, li, s, c)?,
            Op::RingTail { li, c } => self.ring_tail(ctx, li, c)?,
            Op::RingAppend { li } => self.ring_append(ctx, li)?,
            Op::DenseChunk { c } => self.dense_chunk(ctx, c)?,
            // Warm fast path: the attach already happened at PrefillBegin;
            // the step only exists so the begin/step driver (and the
            // scheduler's one-chunk-per-tick admission) stays uniform.
            Op::PrefixAttach => {}
        }
        self.tm.total_s += t0.elapsed().as_secs_f64();
        self.next += 1;
        if self.next == self.plan.len() {
            Ok(StepOutcome::Done(self.tm, std::mem::take(&mut self.retained)))
        } else {
            Ok(StepOutcome::Progress)
        }
    }

    // -- APB / StarAttn ------------------------------------------------------

    fn apb_pre_full(&mut self, ctx: &mut StepCtx<'_>, li: usize) -> Result<()> {
        let mut sw = Stopwatch::start();
        let (q, k, v, scores) = ctx.backend.layer_pre(li, &self.hidden, self.pos_offset)?;
        (self.q, self.k, self.v, self.scores) = (q, k, v, scores);
        self.tm.layer_pre_s += sw.lap();
        Ok(())
    }

    fn apb_pre(&mut self, ctx: &mut StepCtx<'_>, li: usize, c: usize) -> Result<()> {
        let (a, m) = (&ctx.cfg.apb, &ctx.cfg.model);
        let mut sw = Stopwatch::start();
        let (c0, c1) = self.chunks[c];
        if c == 0 {
            // Fresh per-layer scratch + the anchor rows' projections (the
            // anchor is layer state shared by every chunk).
            self.q = Tensor::zeros(vec![a.n_tot(), m.n_heads, m.head_dim()]);
            self.k = Tensor::zeros(vec![a.n_tot(), m.n_kv_heads, m.head_dim()]);
            self.v = Tensor::zeros(vec![a.n_tot(), m.n_kv_heads, m.head_dim()]);
            self.scores = Tensor::zeros(vec![a.block_len, m.n_kv_heads]);
            let anchor_pos: Vec<i32> = (0..a.l_aq() as i32).collect();
            let (qa, ka, va) = ctx.backend.decode_pre(
                li, &self.hidden.slice_rows(0, a.l_aq()), &anchor_pos)?;
            self.q.write_rows(0, &qa);
            self.k.write_rows(0, &ka);
            self.v.write_rows(0, &va);
        }
        let anchor = self.hidden.slice_rows(0, a.l_aq());
        let rows = self.hidden.slice_rows(a.l_aq() + c0, a.l_aq() + c1);
        let pos: Vec<i32> = (c0 as i32..c1 as i32).map(|i| self.pos_offset + i).collect();
        let (qc, kc, vc, sc) = ctx.backend.layer_pre_chunk(li, &anchor, &rows, &pos)?;
        self.q.write_rows(a.l_aq() + c0, &qc);
        self.k.write_rows(a.l_aq() + c0, &kc);
        self.v.write_rows(a.l_aq() + c0, &vc);
        self.scores.write_rows(c0, &sc);
        self.tm.layer_pre_s += sw.lap();
        Ok(())
    }

    fn apb_select(&mut self, ctx: &mut StepCtx<'_>, li: usize) -> Result<()> {
        let (a, m) = (&ctx.cfg.apb, &ctx.cfg.model);
        let mut sw = Stopwatch::start();
        let n_tot = a.n_tot();
        let k_local = self.k.slice_rows(a.l_aq(), n_tot);
        let v_local = self.v.slice_rows(a.l_aq(), n_tot);
        // Top-l_p selection (coordinator side, §3.4).
        let scores_used = if self.opts.retaining_compressor {
            self.scores.clone()
        } else {
            let mut rd = Tensor::zeros(vec![a.block_len, m.n_kv_heads]);
            for i in 0..a.block_len {
                for j in 0..m.n_kv_heads {
                    rd.data[i * m.n_kv_heads + j] = random_score(
                        self.opts.rd_seed, li as u64, ctx.rank as u64, j as u64, i as u64,
                    );
                }
            }
            rd
        };
        let idx = top_lp_indices(&scores_used, a.passing_len);
        if self.opts.record_retained {
            self.retained.push(
                idx.iter()
                    .map(|head| head.iter().map(|&i| i as u32).collect())
                    .collect(),
            );
        }
        let (k_c, v_c) = gather_compressed(&k_local, &v_local, &idx);
        self.tm.topk_s += sw.lap();

        // Post the AllGather of compressed blocks (§3.5), session-tagged —
        // completed by `ApbAssemble` after the appends, so the pass rides
        // under local work (the measured-overlap window). StarAttn skips
        // passing entirely: zero prefill communication.
        let passing = self.opts.method.passes_compressed_blocks();
        self.pass_len = if passing { (ctx.rank * a.passing_len) as i32 } else { 0 };
        if passing {
            self.pending_gather =
                Some(ctx.fabric.kv_gather.post_tagged(ctx.rank, self.sid, (k_c, v_c)));
        }
        self.tm.comm_s += sw.lap();
        Ok(())
    }

    fn apb_append(&mut self, ctx: &mut StepCtx<'_>, li: usize, c: usize) -> Result<()> {
        let a = &ctx.cfg.apb;
        let mut sw = Stopwatch::start();
        // Cache append of this chunk's LOCAL rows only (anchor discarded).
        // Runs between the gather's post and complete: attention reads the
        // per-layer k/v scratch, never the pool, so appending early is
        // bit-identical — same slices, same chunk order, same pool bytes.
        let (c0, c1) = self.chunks[c];
        ctx.cache.append(
            li,
            &self.k.slice_rows(a.l_aq() + c0, a.l_aq() + c1),
            &self.v.slice_rows(a.l_aq() + c0, a.l_aq() + c1),
        )?;
        self.tm.cache_s += sw.lap();
        Ok(())
    }

    fn apb_assemble(&mut self, ctx: &mut StepCtx<'_>, _li: usize) -> Result<()> {
        let (a, m) = (&ctx.cfg.apb, &ctx.cfg.model);
        // Complete the gather (StarAttn never posted one). On a rendezvous
        // timeout the receipt is kept so `abort` can still drain the round.
        let blocks: Vec<(Tensor, Tensor)> = match self.pending_gather.take() {
            Some(receipt) => match complete_accounted(
                &ctx.fabric.kv_gather,
                ctx.rank,
                &receipt,
                &mut self.tm.comm_s,
                &mut self.tm.comm_window_s,
                &mut self.tm.comm_hidden_s,
            ) {
                Ok(all) => all,
                Err(e) => {
                    self.pending_gather = Some(receipt);
                    return Err(e.into());
                }
            },
            None => Vec::new(),
        };

        // Passing-block assembly: ranks < mine, rank order.
        let mut sw = Stopwatch::start();
        self.k_pass = Tensor::zeros(vec![a.pass_max(), m.n_kv_heads, m.head_dim()]);
        self.v_pass = self.k_pass.clone();
        for r in 0..ctx.rank.min(blocks.len()) {
            self.k_pass.write_rows(r * a.passing_len, &blocks[r].0);
            self.v_pass.write_rows(r * a.passing_len, &blocks[r].1);
        }
        self.tm.layer_post_s += sw.lap();
        Ok(())
    }

    fn apb_post(&mut self, ctx: &mut StepCtx<'_>, li: usize, c: usize) -> Result<()> {
        let a = &ctx.cfg.apb;
        let mut sw = Stopwatch::start();
        let (c0, c1) = self.chunks[c];
        // Chunk 0 carries the anchor rows (they attend + feed forward too).
        let (row0, row1) = if c == 0 { (0, a.l_aq() + c1) } else {
            (a.l_aq() + c0, a.l_aq() + c1)
        };
        let h_rows = self.hidden.slice_rows(row0, row1);
        let q_rows = self.q.slice_rows(row0, row1);
        let new_rows = ctx.backend.layer_post_rows(
            li, &h_rows, &q_rows, row0, &self.k, &self.v, &self.k_pass, &self.v_pass,
            self.pass_len, self.n_anchor,
        )?;
        self.hidden.write_rows(row0, &new_rows);
        self.tm.layer_post_s += sw.lap();
        Ok(())
    }

    // -- RingAttn ------------------------------------------------------------

    fn ring_pre(&mut self, ctx: &mut StepCtx<'_>, li: usize, c: usize) -> Result<()> {
        let m = &ctx.cfg.model;
        let mut sw = Stopwatch::start();
        let rows = self.positions.len();
        if c == 0 {
            self.q = Tensor::zeros(vec![rows, m.n_heads, m.head_dim()]);
            self.k = Tensor::zeros(vec![rows, m.n_kv_heads, m.head_dim()]);
            self.v = Tensor::zeros(vec![rows, m.n_kv_heads, m.head_dim()]);
            self.outs.clear();
            self.lses.clear();
        }
        let (c0, c1) = self.chunks[c];
        if c0 < c1 {
            // QKV + RoPE at the rows' true global positions (no anchors, no
            // retaining heads — this is the exact baseline).
            let (q, k, v) = ctx.backend.decode_pre(
                li, &self.hidden.slice_rows(c0, c1), &self.positions[c0..c1])?;
            self.q.write_rows(c0, &q);
            self.k.write_rows(c0, &k);
            self.v.write_rows(c0, &v);
        }
        self.tm.layer_pre_s += sw.lap();
        Ok(())
    }

    fn ring_post(&mut self, ctx: &mut StepCtx<'_>, _li: usize) -> Result<()> {
        let mut sw = Stopwatch::start();
        // Send the own block into round 1; partials of the own block run
        // while the exchange is in flight.
        let receipt = ctx.fabric.ring_pass.post_tagged(
            ctx.rank, self.sid, (self.k.clone(), self.v.clone()));
        self.pending_ring = Some(receipt);
        self.tm.comm_s += sw.lap();
        Ok(())
    }

    /// Complete the pending ring round, folding its exposed/window/hidden
    /// times into the machine's buckets. On a rendezvous timeout the
    /// receipt goes back into `pending_ring` so a later `abort` can still
    /// drain the round.
    fn complete_ring(&mut self, ctx: &mut StepCtx<'_>) -> Result<(Tensor, Tensor)> {
        let receipt = self.pending_ring.take().expect("ring step without a posted round");
        match complete_accounted(
            &ctx.fabric.ring_pass,
            ctx.rank,
            &receipt,
            &mut self.tm.comm_s,
            &mut self.tm.comm_window_s,
            &mut self.tm.comm_hidden_s,
        ) {
            Ok(block) => Ok(block),
            Err(e) => {
                self.pending_ring = Some(receipt);
                Err(e.into())
            }
        }
    }

    fn ring_forward(&mut self, ctx: &mut StepCtx<'_>, _li: usize) -> Result<()> {
        let block = self.complete_ring(ctx)?;
        let mut sw = Stopwatch::start();
        // Forward the received block onward, keep a copy to attend to while
        // the next exchange is in flight.
        let receipt = ctx.fabric.ring_pass.post_tagged(
            ctx.rank, self.sid, (block.0.clone(), block.1.clone()));
        self.pending_ring = Some(receipt);
        self.held = Some(block);
        self.tm.comm_s += sw.lap();
        Ok(())
    }

    fn ring_complete(&mut self, ctx: &mut StepCtx<'_>, _li: usize) -> Result<()> {
        let block = self.complete_ring(ctx)?;
        self.held = Some(block);
        Ok(())
    }

    fn ring_partial(&mut self, ctx: &mut StepCtx<'_>, _li: usize, s: usize, c: usize)
                    -> Result<()> {
        let a = &ctx.cfg.apb;
        let m = &ctx.cfg.model;
        let mut sw = Stopwatch::start();
        let origin = (ctx.rank + a.n_hosts - s) % a.n_hosts;
        // Blocks from later hosts are entirely in this host's future — skip
        // the (fully masked) attention; the block was still forwarded so
        // every rank runs the same number of exchange rounds.
        if s > 0 && origin >= ctx.rank {
            return Ok(());
        }
        if c == 0 {
            let rows = self.positions.len();
            self.outs.push(Tensor::zeros(vec![rows, m.n_heads, m.head_dim()]));
            self.lses.push(Tensor::zeros(vec![rows, m.n_heads]));
        }
        let (c0, c1) = self.chunks[c];
        if c0 < c1 {
            let (k_blk, v_blk, k_pos): (_, _, &[i32]) = if s == 0 {
                (&self.k, &self.v, &self.positions[..])
            } else {
                let held = self.held.as_ref().expect("ring partial without a held block");
                (&held.0, &held.1, &self.origin_positions[origin][..])
            };
            let (o, l) = ctx.backend.attn_partial(
                &self.q.slice_rows(c0, c1), k_blk, v_blk,
                &self.positions[c0..c1], k_pos,
            )?;
            let slot = self.outs.len() - 1;
            self.outs[slot].write_rows(c0, &o);
            self.lses[slot].write_rows(c0, &l);
        }
        self.tm.layer_post_s += sw.lap();
        Ok(())
    }

    fn ring_tail(&mut self, ctx: &mut StepCtx<'_>, li: usize, c: usize) -> Result<()> {
        let mut sw = Stopwatch::start();
        let (c0, c1) = self.chunks[c];
        if c0 < c1 {
            // Merge this chunk's rows across all accumulated partials with
            // the online-softmax identity, then O-proj + FFN.
            let outs: Vec<Tensor> =
                self.outs.iter().map(|o| o.slice_rows(c0, c1)).collect();
            let lses: Vec<Tensor> =
                self.lses.iter().map(|l| l.slice_rows(c0, c1)).collect();
            let att = merge_partials(&outs, &lses);
            let new_rows = ctx.backend.decode_post(
                li, &self.hidden.slice_rows(c0, c1), &att)?;
            self.hidden.write_rows(c0, &new_rows);
        }
        self.tm.layer_post_s += sw.lap();
        Ok(())
    }

    fn ring_append(&mut self, ctx: &mut StepCtx<'_>, li: usize) -> Result<()> {
        let mut sw = Stopwatch::start();
        // Cache this host's own rows (computed locally before the rotation;
        // the block still held after N-1 exchanges originated at the
        // successor rank and is simply dropped).
        self.held = None;
        ctx.cache.append(li, &self.k, &self.v)?;
        self.tm.cache_s += sw.lap();
        Ok(())
    }

    // -- Dense ---------------------------------------------------------------

    fn dense_chunk(&mut self, ctx: &mut StepCtx<'_>, c: usize) -> Result<()> {
        if ctx.rank != 0 {
            return Ok(()); // lockstep no-op: the whole sequence lives on host 0
        }
        let m = &ctx.cfg.model;
        let mut sw = Stopwatch::start();
        let (c0, c1) = self.chunks[c];
        if c0 == c1 {
            return Ok(());
        }
        let mut hidden = ctx.backend.embed(&self.tokens[c0..c1])?;
        self.tm.embed_s += sw.lap();
        let pos_chunk: Vec<i32> = (c0 as i32..c1 as i32).collect();
        for li in 0..m.n_layers {
            let (q, k, v) = ctx.backend.decode_pre(li, &hidden, &pos_chunk)?;
            self.tm.layer_pre_s += sw.lap();
            // Plain causal attention of the chunk against everything before
            // it (the running KV — carry state of the chunk-major walk)
            // plus itself. One partial IS the full softmax: every row sees
            // at least itself, so no merge is needed.
            let lc = &ctx.cache.layers[li];
            let k_vis = Tensor::concat_rows(&[&lc.k.slice_rows(0, lc.len), &k]);
            let v_vis = Tensor::concat_rows(&[&lc.v.slice_rows(0, lc.len), &v]);
            let pos_vis: Vec<i32> = (0..c1 as i32).collect();
            let (att, _lse) =
                ctx.backend.attn_partial(&q, &k_vis, &v_vis, &pos_chunk, &pos_vis)?;
            hidden = ctx.backend.decode_post(li, &hidden, &att)?;
            self.tm.layer_post_s += sw.lap();
            ctx.cache.append(li, &k, &v)?;
            self.tm.cache_s += sw.lap();
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plans_are_rank_uniform_and_place_collectives_identically() {
        // The lockstep invariant: for every method and chunk count, each
        // rank derives the same plan (length AND op sequence) from the
        // config alone.
        for n_chunks in [1usize, 2, 5] {
            // Per layer: (1 | C) pre + select + C appends + assemble +
            // C posts = 3C + 2 (the C == 1 fast path folds pre into one op).
            let apb = apb_plan(3, n_chunks);
            assert_eq!(apb.len(), 3 * (3 * n_chunks + 2));
            for n_hosts in [1usize, 2, 4] {
                let ring = ring_plan(2, n_hosts, n_chunks);
                // Per layer: C pre + N collective-touching ops (1 post,
                // N-2 forwards, 1 complete; none when N == 1) + N*C
                // partial ops + C tails + 1 append.
                let coll = if n_hosts > 1 { n_hosts } else { 0 };
                let per_layer =
                    n_chunks + coll + n_hosts * n_chunks + n_chunks + 1;
                assert_eq!(ring.len(), 2 * per_layer, "ring N={n_hosts} C={n_chunks}");
            }
            assert_eq!(dense_plan(n_chunks).len(), n_chunks);
        }
    }

    #[test]
    fn chunk_ranges_partition_and_pad() {
        // Even split.
        assert_eq!(chunk_ranges(8, 4, 2), vec![(0, 4), (4, 8)]);
        // Ragged tail.
        assert_eq!(chunk_ranges(7, 3, 3), vec![(0, 3), (3, 6), (6, 7)]);
        // Rank with fewer rows than the global chunk count: empty tails.
        assert_eq!(chunk_ranges(3, 3, 3), vec![(0, 3), (3, 3), (3, 3)]);
        // Chunk larger than the row count: one real chunk.
        assert_eq!(chunk_ranges(5, 100, 1), vec![(0, 5)]);
        // Every range is contiguous and covers the rows exactly once.
        let rs = chunk_ranges(11, 2, 6);
        let mut at = 0;
        for (lo, hi) in rs {
            assert_eq!(lo, at.min(11));
            at = hi;
        }
        assert_eq!(at, 11);
    }

    #[test]
    fn ring_positions_match_layout() {
        let a = ApbParams {
            n_hosts: 3,
            block_len: 8,
            anchor_len: 4,
            query_len: 2,
            passing_len: 2,
            max_new_tokens: 4,
            max_resident: 2,
            chunk_tokens: 4,
            prefix_cache: false,
        };
        assert_eq!(ring_positions(&a, 0), (0..10).collect::<Vec<i32>>());
        assert_eq!(ring_positions(&a, 1), (10..18).collect::<Vec<i32>>());
        assert_eq!(ring_positions(&a, 2), (18..26).collect::<Vec<i32>>());
    }
}
