//! Host worker: one simulated GPU. Owns an execution backend (SimEngine or
//! PJRT, per `Config::backend`), a KV pool with one slot per resident
//! session, and per-session position bookkeeping; executes the per-layer
//! stages of the session's `AttnMethod` (Algorithm 2 prefill + Algorithm 3
//! decode for APB/StarAttn, the ring rotation for RingAttn, single-host
//! causal for Dense) and participates in fabric collectives.

use std::collections::HashMap;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::cluster::Fabric;
use crate::config::{ApbOptions, ApbParams, AttnMethod, Config};
use crate::kvcache::{KvPool, SessionId};
use crate::runtime::{create_backend, ExecBackend, KvView};
use crate::util::rng::random_score;
use crate::util::tensor::{merge_partials, top_lp_indices, Tensor};

use super::timing::{DecodeTiming, PrefillTiming, Stopwatch};
use super::{Cmd, Resp};

pub fn run_host(
    rank: usize,
    cfg: Config,
    fabric: Arc<Fabric>,
    cmd_rx: Receiver<Cmd>,
    resp_tx: Sender<Resp>,
    ready_tx: Sender<Result<usize>>,
) {
    match HostWorker::new(rank, cfg, fabric) {
        Ok(mut w) => {
            let _ = ready_tx.send(Ok(rank));
            w.serve(cmd_rx, resp_tx);
        }
        Err(e) => {
            let _ = ready_tx.send(Err(e));
        }
    }
}

/// Per-session decode bookkeeping owned by the worker: the global position
/// of the next token row this session will decode (set to
/// `query_len + doc_len` by prefill — the first re-fed query-chunk row —
/// and advanced by every decode pass), plus the attention method the
/// session was prefilled under, which routes its decode passes (Dense
/// sessions decode entirely on host 0; everything else runs the
/// distributed Algorithm-3 merge). Registered on EVERY host, including the
/// idle ranks of a Dense session, so decode commands can be routed without
/// the leader re-sending options.
struct SessionState {
    next_pos: i32,
    method: AttnMethod,
}

/// Global positions of host `rank`'s rows under the exact-method layout
/// `[query | doc]` (RingAttn): host 0 owns the query prefix + block 0
/// starting at position 0, host r > 0 owns block r starting at
/// `l_q + r·l_b`. Must mirror `super::host_tokens_for`.
fn ring_positions(a: &ApbParams, rank: usize) -> Vec<i32> {
    let (start, len) = if rank == 0 {
        (0usize, a.query_len + a.block_len)
    } else {
        (a.query_len + rank * a.block_len, a.block_len)
    };
    (start as i32..(start + len) as i32).collect()
}

/// Collective round tag for a decode batch: order-sensitive digest of the
/// session ids, so desynchronized batch composition across hosts trips the
/// fabric's tag assertion instead of silently merging the wrong partials.
fn batch_tag(entries: &[(SessionId, i32)]) -> u64 {
    entries
        .iter()
        .fold(0x517C_C1B7_2722_0A95u64, |acc, (sid, _)| {
            acc.wrapping_mul(0x100_0000_01B3).wrapping_add(sid ^ 0x9E37_79B9_7F4A_7C15)
        })
}

struct HostWorker {
    rank: usize,
    cfg: Config,
    fabric: Arc<Fabric>,
    backend: Box<dyn ExecBackend>,
    pool: KvPool,
    sessions: HashMap<SessionId, SessionState>,
}

impl HostWorker {
    fn new(rank: usize, cfg: Config, fabric: Arc<Fabric>) -> Result<Self> {
        let backend = create_backend(&cfg)
            .with_context(|| format!("host {rank}: creating {} backend", cfg.backend.name()))?;
        // Slot capacity follows the cluster's method: distributed modes
        // hold at most a local block + decode tail per session, Dense
        // concentrates the whole sequence on host 0 (every host's pool is
        // sized alike — rank-0-only sizing would save little sim memory and
        // complicate the symmetric capacity check).
        let pool = KvPool::new(
            cfg.apb.max_resident,
            cfg.model.n_layers,
            cfg.apb.cache_rows(cfg.method),
            cfg.model.n_kv_heads,
            cfg.model.head_dim(),
        );
        Ok(HostWorker { rank, cfg, fabric, backend, pool, sessions: HashMap::new() })
    }

    fn serve(&mut self, cmd_rx: Receiver<Cmd>, resp_tx: Sender<Resp>) {
        while let Ok(cmd) = cmd_rx.recv() {
            let resp = match cmd {
                Cmd::Shutdown => break,
                Cmd::Clear { sid } => {
                    self.pool.free(sid);
                    self.sessions.remove(&sid);
                    Resp::Cleared { host: self.rank }
                }
                Cmd::ClearAll => {
                    self.pool.clear_all();
                    self.sessions.clear();
                    Resp::Cleared { host: self.rank }
                }
                Cmd::Prefill { sid, tokens, opts } => {
                    match self.prefill(sid, &tokens, &opts) {
                        Ok((timing, retained)) => {
                            Resp::PrefillDone { host: self.rank, sid, timing, retained }
                        }
                        Err(e) => Resp::Error { host: self.rank, msg: format!("{e:#}") },
                    }
                }
                Cmd::QueryChunk { sid, tokens } => match self.decode_pass(sid, &tokens) {
                    Ok((logits, timing)) => {
                        Resp::StepDone { host: self.rank, sid, logits, timing }
                    }
                    Err(e) => Resp::Error { host: self.rank, msg: format!("{e:#}") },
                },
                Cmd::DecodeBatch { entries } => match self.decode_batch(&entries) {
                    Ok((logits, timing)) => {
                        Resp::BatchDone { host: self.rank, logits, timing }
                    }
                    Err(e) => Resp::Error { host: self.rank, msg: format!("{e:#}") },
                },
            };
            if resp_tx.send(resp).is_err() {
                break; // leader gone
            }
        }
    }

    /// Position of the first re-fed query-chunk row (end of the global
    /// [query | document] prefix every session's prefill covers).
    fn decode_pos0(&self) -> i32 {
        (self.cfg.apb.query_len + self.cfg.apb.doc_len()) as i32
    }

    /// Session lookup for decode, creating state on demand: a session that
    /// never prefilled (degenerate empty-cache decode) gets a fresh slot,
    /// the cluster-default method (`Config::method`) and the post-prefill
    /// position. Returns the session's method for decode routing.
    fn ensure_session(&mut self, sid: SessionId) -> Result<AttnMethod> {
        if let Some(s) = self.sessions.get(&sid) {
            return Ok(s.method);
        }
        let method = self.cfg.method;
        self.claim_slot(sid, method)?;
        Ok(method)
    }

    /// Per-kv-head gather of compressed KV rows: k/v are the local slices
    /// [l_b, kh, hd]; idx[j] lists ascending positions for head j.
    fn gather_compressed(
        &self,
        k: &Tensor,
        v: &Tensor,
        idx: &[Vec<usize>],
    ) -> (Tensor, Tensor) {
        let (kh, hd) = (k.shape[1], k.shape[2]);
        let l_p = idx[0].len();
        let mut kc = Tensor::zeros(vec![l_p, kh, hd]);
        let mut vc = Tensor::zeros(vec![l_p, kh, hd]);
        for j in 0..kh {
            for (t, &i) in idx[j].iter().enumerate() {
                let src = (i * kh + j) * hd;
                let dst = (t * kh + j) * hd;
                kc.data[dst..dst + hd].copy_from_slice(&k.data[src..src + hd]);
                vc.data[dst..dst + hd].copy_from_slice(&v.data[src..src + hd]);
            }
        }
        (kc, vc)
    }

    /// Prefill dispatch on the request's [`AttnMethod`]: the anchored
    /// Algorithm-2 path for APB/StarAttn, the ring rotation for RingAttn,
    /// single-host causal for Dense. In every mode the KV slot is claimed
    /// (or reset) BEFORE any collective, so pool exhaustion fails
    /// identically on every host — backpressure, never a deadlocked
    /// half-round. Returns timing + the per-layer/per-head retained
    /// indices (empty unless `opts.record_retained`; always empty for the
    /// exact methods, which have no compressor).
    fn prefill(
        &mut self,
        sid: SessionId,
        tokens: &[i32],
        opts: &ApbOptions,
    ) -> Result<(PrefillTiming, Vec<Vec<Vec<u32>>>)> {
        match opts.method {
            AttnMethod::Apb | AttnMethod::StarAttn => self.prefill_apb(sid, tokens, opts),
            AttnMethod::RingAttn => {
                self.prefill_ring(sid, tokens).map(|tm| (tm, Vec::new()))
            }
            AttnMethod::Dense => self.prefill_dense(sid, tokens).map(|tm| (tm, Vec::new())),
        }
    }

    /// Capacity check for a per-request method against the pool this
    /// cluster was sized for. Deliberately computed from the config alone
    /// (the pool's slot size IS `cache_rows(cfg.method)`), so every rank —
    /// including the idle ranks of a Dense prefill — reaches the same
    /// verdict before touching any state or collective.
    fn check_method_fits(&self, method: AttnMethod) -> Result<()> {
        let needed = self.cfg.apb.cache_rows(method);
        let have = self.cfg.apb.cache_rows(self.cfg.method);
        if needed > have {
            bail!(
                "method {} needs {needed} KV rows per slot but the pool was sized \
                 for {have} (cluster method {}); start the cluster from \
                 Config::with_method",
                method.name(),
                self.cfg.method.name()
            );
        }
        Ok(())
    }

    /// Claim (or reset) `sid`'s pool slot and register its session state,
    /// erroring — before any collective, identically on every host — when
    /// the pool was not sized for `method`.
    fn claim_slot(&mut self, sid: SessionId, method: AttnMethod) -> Result<()> {
        self.check_method_fits(method)?;
        self.pool.alloc(sid)?;
        self.sessions.insert(sid, SessionState { next_pos: self.decode_pos0(), method });
        Ok(())
    }

    /// Algorithm 2 — APB prefill over this host's [anchor | local] layout
    /// into session `sid`'s pool slot (StarAttn = same path with the
    /// passing step skipped: zero prefill communication).
    fn prefill_apb(
        &mut self,
        sid: SessionId,
        tokens: &[i32],
        opts: &ApbOptions,
    ) -> Result<(PrefillTiming, Vec<Vec<Vec<u32>>>)> {
        self.claim_slot(sid, opts.method)?;
        let cfg = &self.cfg;
        let (a, m) = (&cfg.apb, &cfg.model);
        let backend = self.backend.as_ref();
        let mut tm = PrefillTiming::default();
        let mut retained: Vec<Vec<Vec<u32>>> = Vec::new();
        let mut sw = Stopwatch::start();
        let total0 = std::time::Instant::now();

        let mut hidden = backend.embed(tokens)?;
        tm.embed_s += sw.lap();

        let pos_offset = (a.query_len + self.rank * a.block_len) as i32;
        let n_anchor = super::n_anchor_for(cfg, self.rank, opts);
        let passing = opts.method.passes_compressed_blocks();
        let pass_len: i32 = if passing {
            (self.rank * a.passing_len) as i32
        } else {
            0
        };

        for li in 0..m.n_layers {
            // --- layer_pre: QKV + RoPE + retaining scores ----------------
            let (q, k, v, scores) = backend.layer_pre(li, &hidden, pos_offset)?;
            tm.layer_pre_s += sw.lap();

            // --- Top-l_p selection (coordinator side, §3.4) ---------------
            let k_local = k.slice_rows(a.l_aq(), a.n_tot());
            let v_local = v.slice_rows(a.l_aq(), a.n_tot());
            let scores_used = if opts.retaining_compressor {
                scores
            } else {
                let mut rd = Tensor::zeros(vec![a.block_len, m.n_kv_heads]);
                for i in 0..a.block_len {
                    for j in 0..m.n_kv_heads {
                        rd.data[i * m.n_kv_heads + j] = random_score(
                            opts.rd_seed, li as u64, self.rank as u64, j as u64, i as u64,
                        );
                    }
                }
                rd
            };
            let idx = top_lp_indices(&scores_used, a.passing_len);
            if opts.record_retained {
                retained.push(
                    idx.iter()
                        .map(|head| head.iter().map(|&i| i as u32).collect())
                        .collect(),
                );
            }
            let (k_c, v_c) = self.gather_compressed(&k_local, &v_local, &idx);
            tm.topk_s += sw.lap();

            // --- AllGather of compressed blocks (§3.5), session-tagged ----
            let blocks: Vec<(Tensor, Tensor)> = if passing {
                self.fabric.kv_gather.all_gather_tagged(self.rank, sid, (k_c, v_c))
            } else {
                Vec::new()
            };
            tm.comm_s += sw.lap();

            // --- Passing-block assembly: ranks < mine, rank order ---------
            let mut k_pass =
                Tensor::zeros(vec![a.pass_max(), m.n_kv_heads, m.head_dim()]);
            let mut v_pass = k_pass.clone();
            for r in 0..self.rank.min(blocks.len()) {
                k_pass.write_rows(r * a.passing_len, &blocks[r].0);
                v_pass.write_rows(r * a.passing_len, &blocks[r].1);
            }

            // --- layer_post: APB attention + FFN (§3.6) -------------------
            hidden = backend.layer_post(
                li, &hidden, &q, &k, &v, &k_pass, &v_pass, pass_len, n_anchor,
            )?;
            tm.layer_post_s += sw.lap();

            // --- cache append: local block KV only (anchor discarded) -----
            self.pool.get_mut(sid)?.append(li, &k_local, &v_local)?;
            tm.cache_s += sw.lap();
        }
        tm.total_s = total0.elapsed().as_secs_f64();
        Ok((tm, retained))
    }

    /// RingAttn prefill (Ring Attention / Context Parallelism): this host's
    /// rows of the exact `[query | doc]` layout are processed with plain
    /// causal attention against ALL hosts' KV, obtained by rotating full
    /// (K, V) blocks around the ring (`Fabric::ring_pass`, `ring` meter
    /// label) — N-1 exchange rounds per layer, partials merged with the
    /// online-softmax identity. Exact: must match [`AttnMethod::Dense`]
    /// within float tolerance (tested in `cluster_modes`).
    fn prefill_ring(&mut self, sid: SessionId, tokens: &[i32]) -> Result<PrefillTiming> {
        self.claim_slot(sid, AttnMethod::RingAttn)?;
        let cfg = &self.cfg;
        let (a, m) = (&cfg.apb, &cfg.model);
        let positions = ring_positions(a, self.rank);
        if tokens.len() != positions.len() {
            bail!("ring prefill: host {} wants {} rows, got {}", self.rank,
                  positions.len(), tokens.len());
        }
        let n_hosts = a.n_hosts;
        let backend = self.backend.as_ref();
        let mut tm = PrefillTiming::default();
        let mut sw = Stopwatch::start();
        let total0 = std::time::Instant::now();

        let mut hidden = backend.embed(tokens)?;
        tm.embed_s += sw.lap();

        for li in 0..m.n_layers {
            // QKV + RoPE at the rows' true global positions (no anchors,
            // no retaining heads — this is the exact baseline).
            let (q, k, v) = backend.decode_pre(li, &hidden, &positions)?;
            tm.layer_pre_s += sw.lap();

            // Local causal partial, then one partial per block received off
            // the ring. Blocks from later hosts are entirely in this host's
            // future — skip the (fully masked) attention but still forward
            // them so every rank runs the same number of exchange rounds.
            let mut outs: Vec<Tensor> = Vec::with_capacity(n_hosts);
            let mut lses: Vec<Tensor> = Vec::with_capacity(n_hosts);
            let (o, l) = backend.attn_partial(&q, &k, &v, &positions, &positions)?;
            outs.push(o);
            lses.push(l);
            tm.layer_post_s += sw.lap();

            let mut block = (k.clone(), v.clone());
            for step in 1..n_hosts {
                block = self.fabric.ring_pass.exchange_tagged(self.rank, sid, block);
                tm.comm_s += sw.lap();
                let origin = (self.rank + n_hosts - step) % n_hosts;
                if origin < self.rank {
                    let k_pos = ring_positions(a, origin);
                    let (o, l) =
                        backend.attn_partial(&q, &block.0, &block.1, &positions, &k_pos)?;
                    outs.push(o);
                    lses.push(l);
                }
                tm.layer_post_s += sw.lap();
            }
            let att = merge_partials(&outs, &lses);
            hidden = backend.decode_post(li, &hidden, &att)?;
            tm.layer_post_s += sw.lap();

            // Cache this host's own rows (computed locally before the
            // rotation; the block still held after N-1 exchanges originated
            // at the successor rank and is simply dropped).
            self.pool.get_mut(sid)?.append(li, &k, &v)?;
            tm.cache_s += sw.lap();
        }
        tm.total_s = total0.elapsed().as_secs_f64();
        Ok(tm)
    }

    /// Dense prefill — the exactness anchor: host 0 runs the entire
    /// `[query | doc]` sequence through plain causal attention
    /// (`attn_partial` over its own rows) with zero communication; every
    /// other host claims the session's (empty, already-preallocated) slot
    /// and registers it, so session AND pool maps stay identical across
    /// ranks — both the capacity and the slot-exhaustion verdicts are
    /// reached symmetrically, and a rejected Dense request leaves NO rank
    /// with session state.
    fn prefill_dense(&mut self, sid: SessionId, tokens: &[i32]) -> Result<PrefillTiming> {
        let mut tm = PrefillTiming::default();
        self.claim_slot(sid, AttnMethod::Dense)?;
        if self.rank != 0 {
            return Ok(tm);
        }
        let cfg = &self.cfg;
        let (a, m) = (&cfg.apb, &cfg.model);
        let n = a.query_len + a.doc_len();
        if tokens.len() != n {
            bail!("dense prefill: host 0 wants {n} rows, got {}", tokens.len());
        }
        let positions: Vec<i32> = (0..n as i32).collect();
        let backend = self.backend.as_ref();
        let mut sw = Stopwatch::start();
        let total0 = std::time::Instant::now();

        let mut hidden = backend.embed(tokens)?;
        tm.embed_s += sw.lap();
        for li in 0..m.n_layers {
            let (q, k, v) = backend.decode_pre(li, &hidden, &positions)?;
            tm.layer_pre_s += sw.lap();
            // Full causal attention in one partial (every row sees itself,
            // so no merge is needed: a single partial IS the softmax).
            let (att, _lse) = backend.attn_partial(&q, &k, &v, &positions, &positions)?;
            hidden = backend.decode_post(li, &hidden, &att)?;
            tm.layer_post_s += sw.lap();
            self.pool.get_mut(sid)?.append(li, &k, &v)?;
            tm.cache_s += sw.lap();
        }
        tm.total_s = total0.elapsed().as_secs_f64();
        Ok(tm)
    }

    /// Algorithm 3 — one decode pass over a single session's chunk (the
    /// re-fed query). Distributed methods return logits on the last host;
    /// Dense sessions are forwarded to [`HostWorker::decode_pass_dense`].
    fn decode_pass(
        &mut self,
        sid: SessionId,
        tokens: &[i32],
    ) -> Result<(Option<Vec<f32>>, DecodeTiming)> {
        let method = self.ensure_session(sid)?;
        if !method.distributed_decode() {
            return self.decode_pass_dense(sid, tokens);
        }
        let n = tokens.len();
        let pos0 = self.sessions[&sid].next_pos;
        let positions: Vec<i32> = (0..n as i32).map(|i| pos0 + i).collect();
        let cfg = &self.cfg;
        let (a, m) = (&cfg.apb, &cfg.model);
        let backend = self.backend.as_ref();
        let last = self.rank == a.n_hosts - 1;
        let mut tm = DecodeTiming::default();
        let mut sw = Stopwatch::start();
        let total0 = std::time::Instant::now();

        let mut hidden = backend.embed(tokens)?;
        tm.pre_s += sw.lap();

        for li in 0..m.n_layers {
            // decode_pre: project + rope the chunk.
            let (q, k, v) = backend.decode_pre(li, &hidden, &positions)?;
            tm.pre_s += sw.lap();

            // Last host appends the chunk's KV before attending (line 7).
            let self_causal = if last {
                self.pool.get_mut(sid)?.append(li, &k, &v)?;
                true
            } else {
                false
            };
            let lc = &self.pool.get(sid)?.layers[li];
            let (out, lse) = backend.decode_attn(&q, &lc.k, &lc.v, lc.len, self_causal)?;
            tm.attn_s += sw.lap();

            // Gather all hosts' partials (line 9), session-tagged ...
            let all = self.fabric.att_gather.all_gather_tagged(self.rank, sid, (out, lse));
            tm.comm_s += sw.lap();

            // ... and merge with the online-softmax identity (line 10).
            let outs_v: Vec<Tensor> = all.iter().map(|(o, _)| o.clone()).collect();
            let lses_v: Vec<Tensor> = all.iter().map(|(_, l)| l.clone()).collect();
            let att = merge_partials(&outs_v, &lses_v);
            tm.merge_s += sw.lap();

            // decode_post: O-proj + FFN, replicated (identical on all hosts).
            hidden = backend.decode_post(li, &hidden, &att)?;
            tm.post_s += sw.lap();
        }
        self.sessions.get_mut(&sid).unwrap().next_pos += n as i32;

        let logits = if last {
            let l = backend.lm_head(&hidden)?;
            tm.lm_head_s += sw.lap();
            Some(l.data)
        } else {
            None
        };
        tm.total_s = total0.elapsed().as_secs_f64();
        Ok((logits, tm))
    }

    /// Dense decode: host 0's cache holds every key, so the chunk attends
    /// it self-causally in one pass — no collective, no merge, logits on
    /// host 0. Idle ranks only advance the session's position bookkeeping
    /// (kept in lockstep so a later method switch cannot desync positions).
    fn decode_pass_dense(
        &mut self,
        sid: SessionId,
        tokens: &[i32],
    ) -> Result<(Option<Vec<f32>>, DecodeTiming)> {
        let n = tokens.len();
        let mut tm = DecodeTiming::default();
        if self.rank != 0 {
            self.sessions.get_mut(&sid).unwrap().next_pos += n as i32;
            return Ok((None, tm));
        }
        let pos0 = self.sessions[&sid].next_pos;
        let positions: Vec<i32> = (0..n as i32).map(|i| pos0 + i).collect();
        let n_layers = self.cfg.model.n_layers;
        let backend = self.backend.as_ref();
        let mut sw = Stopwatch::start();
        let total0 = std::time::Instant::now();

        let mut hidden = backend.embed(tokens)?;
        tm.pre_s += sw.lap();
        for li in 0..n_layers {
            let (q, k, v) = backend.decode_pre(li, &hidden, &positions)?;
            tm.pre_s += sw.lap();
            // Append first, then attend self-causally (row i of the chunk
            // sees the prior cache plus chunk rows 0..=i) — the same rule
            // as the distributed last host's local partial.
            self.pool.get_mut(sid)?.append(li, &k, &v)?;
            let lc = &self.pool.get(sid)?.layers[li];
            let (att, _lse) = backend.decode_attn(&q, &lc.k, &lc.v, lc.len, true)?;
            tm.attn_s += sw.lap();
            hidden = backend.decode_post(li, &hidden, &att)?;
            tm.post_s += sw.lap();
        }
        self.sessions.get_mut(&sid).unwrap().next_pos += n as i32;
        let logits = backend.lm_head(&hidden)?;
        tm.lm_head_s += sw.lap();
        tm.total_s = total0.elapsed().as_secs_f64();
        Ok((Some(logits.data), tm))
    }

    /// Dense twin of [`HostWorker::decode_batch`]: all rows on host 0, one
    /// stacked pass per layer against the sessions' own caches, still zero
    /// communication.
    fn decode_batch_dense(
        &mut self,
        entries: &[(SessionId, i32)],
    ) -> Result<(Option<Vec<Vec<f32>>>, DecodeTiming)> {
        let mut tm = DecodeTiming::default();
        if self.rank != 0 {
            for &(sid, _) in entries {
                self.sessions.get_mut(&sid).unwrap().next_pos += 1;
            }
            return Ok((None, tm));
        }
        let tokens: Vec<i32> = entries.iter().map(|&(_, t)| t).collect();
        let positions: Vec<i32> =
            entries.iter().map(|&(sid, _)| self.sessions[&sid].next_pos).collect();
        let (n_layers, vocab) = (self.cfg.model.n_layers, self.cfg.model.vocab_size);
        let backend = self.backend.as_ref();
        let mut sw = Stopwatch::start();
        let total0 = std::time::Instant::now();

        let mut hidden = backend.embed(&tokens)?;
        tm.pre_s += sw.lap();
        for li in 0..n_layers {
            let (q, k, v) = backend.decode_pre(li, &hidden, &positions)?;
            tm.pre_s += sw.lap();
            for (i, &(sid, _)) in entries.iter().enumerate() {
                self.pool.get_mut(sid)?.append(
                    li,
                    &k.slice_rows(i, i + 1),
                    &v.slice_rows(i, i + 1),
                )?;
            }
            let views: Vec<KvView<'_>> = entries
                .iter()
                .map(|&(sid, _)| {
                    let lc = &self.pool.get(sid)?.layers[li];
                    Ok(KvView { k: &lc.k, v: &lc.v, len: lc.len })
                })
                .collect::<Result<_>>()?;
            let (att, _lse) = backend.decode_attn_batch(&q, &views)?;
            tm.attn_s += sw.lap();
            hidden = backend.decode_post(li, &hidden, &att)?;
            tm.post_s += sw.lap();
        }
        for &(sid, _) in entries {
            self.sessions.get_mut(&sid).unwrap().next_pos += 1;
        }
        let l = backend.lm_head(&hidden)?;
        tm.lm_head_s += sw.lap();
        tm.total_s = total0.elapsed().as_secs_f64();
        let rows = (0..entries.len())
            .map(|i| l.data[i * vocab..(i + 1) * vocab].to_vec())
            .collect();
        Ok((Some(rows), tm))
    }

    /// Continuous-batching decode step: one single-token row PER SESSION,
    /// stacked into ONE backend pass per layer (decode_pre with per-row
    /// positions + decode_attn_batch against per-row caches + one merge +
    /// one decode_post), so the per-step cost grows sublinearly in the
    /// number of active sessions. Row order — and therefore collective
    /// payload layout — is the leader's entry order on every host.
    fn decode_batch(
        &mut self,
        entries: &[(SessionId, i32)],
    ) -> Result<(Option<Vec<Vec<f32>>>, DecodeTiming)> {
        // Strict residency: decoding a cleared (or never-admitted) session
        // is a scheduler bug; silently resurrecting an empty cache would
        // turn it into plausible-but-wrong tokens. Checked before any
        // collective (session maps are identical on every host).
        for &(sid, _) in entries {
            if !self.sessions.contains_key(&sid) {
                anyhow::bail!("session {sid} not resident: cannot decode-batch");
            }
        }
        // Decode routing must be uniform across the batch: Dense sessions
        // never join collectives, so mixing them with distributed sessions
        // would desync the att_gather rounds. The scheduler groups by
        // decode path; this is the tripwire (identical on every host,
        // checked before any collective).
        let distributed = self.sessions[&entries[0].0].method.distributed_decode();
        for &(sid, _) in entries {
            if self.sessions[&sid].method.distributed_decode() != distributed {
                anyhow::bail!(
                    "decode batch mixes Dense and distributed sessions \
                     (session {sid} disagrees with session {})",
                    entries[0].0
                );
            }
        }
        if !distributed {
            return self.decode_batch_dense(entries);
        }
        let tag = batch_tag(entries);
        let tokens: Vec<i32> = entries.iter().map(|&(_, t)| t).collect();
        let positions: Vec<i32> =
            entries.iter().map(|&(sid, _)| self.sessions[&sid].next_pos).collect();
        let cfg = &self.cfg;
        let (a, m) = (&cfg.apb, &cfg.model);
        let backend = self.backend.as_ref();
        let last = self.rank == a.n_hosts - 1;
        let mut tm = DecodeTiming::default();
        let mut sw = Stopwatch::start();
        let total0 = std::time::Instant::now();

        let mut hidden = backend.embed(&tokens)?;
        tm.pre_s += sw.lap();

        for li in 0..m.n_layers {
            let (q, k, v) = backend.decode_pre(li, &hidden, &positions)?;
            tm.pre_s += sw.lap();

            // Last host appends each session's new row to ITS cache before
            // attending; each row then sees exactly its own cache's valid
            // prefix (the n=1 self-causal rule).
            if last {
                for (i, &(sid, _)) in entries.iter().enumerate() {
                    self.pool.get_mut(sid)?.append(
                        li,
                        &k.slice_rows(i, i + 1),
                        &v.slice_rows(i, i + 1),
                    )?;
                }
            }
            let views: Vec<KvView<'_>> = entries
                .iter()
                .map(|&(sid, _)| {
                    let lc = &self.pool.get(sid)?.layers[li];
                    Ok(KvView { k: &lc.k, v: &lc.v, len: lc.len })
                })
                .collect::<Result<_>>()?;
            let (out, lse) = backend.decode_attn_batch(&q, &views)?;
            tm.attn_s += sw.lap();

            // One batch-tagged AllGather round per layer for ALL sessions.
            let all = self.fabric.att_gather.all_gather_tagged(self.rank, tag, (out, lse));
            tm.comm_s += sw.lap();

            let outs_v: Vec<Tensor> = all.iter().map(|(o, _)| o.clone()).collect();
            let lses_v: Vec<Tensor> = all.iter().map(|(_, l)| l.clone()).collect();
            let att = merge_partials(&outs_v, &lses_v);
            tm.merge_s += sw.lap();

            hidden = backend.decode_post(li, &hidden, &att)?;
            tm.post_s += sw.lap();
        }
        for &(sid, _) in entries {
            self.sessions.get_mut(&sid).unwrap().next_pos += 1;
        }

        let logits = if last {
            let l = backend.lm_head(&hidden)?;
            tm.lm_head_s += sw.lap();
            let vocab = m.vocab_size;
            Some(
                (0..entries.len())
                    .map(|i| l.data[i * vocab..(i + 1) * vocab].to_vec())
                    .collect(),
            )
        } else {
            None
        };
        tm.total_s = total0.elapsed().as_secs_f64();
        Ok((logits, tm))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_tag_is_order_sensitive_and_token_blind() {
        let a = batch_tag(&[(1, 5), (2, 9)]);
        let b = batch_tag(&[(2, 5), (1, 9)]);
        let c = batch_tag(&[(1, 0), (2, 0)]);
        assert_ne!(a, b, "session order must change the round tag");
        assert_eq!(a, c, "sampled tokens must not change the round tag");
        assert_ne!(batch_tag(&[(1, 0)]), batch_tag(&[(1, 0), (2, 0)]));
    }
}
