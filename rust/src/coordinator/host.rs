//! Host worker: one simulated GPU. Owns an execution backend (SimEngine or
//! PJRT, per `Config::backend`) + KV cache, executes the per-layer APB
//! stages, and participates in fabric collectives.

use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::cluster::Fabric;
use crate::config::{ApbOptions, Config};
use crate::kvcache::KvCache;
use crate::runtime::{create_backend, ExecBackend};
use crate::util::rng::random_score;
use crate::util::tensor::{merge_partials, top_lp_indices, Tensor};

use super::timing::{DecodeTiming, PrefillTiming, Stopwatch};
use super::{Cmd, Resp};

pub fn run_host(
    rank: usize,
    cfg: Config,
    fabric: Arc<Fabric>,
    cmd_rx: Receiver<Cmd>,
    resp_tx: Sender<Resp>,
    ready_tx: Sender<Result<usize>>,
) {
    match HostWorker::new(rank, cfg, fabric) {
        Ok(mut w) => {
            let _ = ready_tx.send(Ok(rank));
            w.serve(cmd_rx, resp_tx);
        }
        Err(e) => {
            let _ = ready_tx.send(Err(e));
        }
    }
}

struct HostWorker {
    rank: usize,
    cfg: Config,
    fabric: Arc<Fabric>,
    backend: Box<dyn ExecBackend>,
    cache: KvCache,
}

impl HostWorker {
    fn new(rank: usize, cfg: Config, fabric: Arc<Fabric>) -> Result<Self> {
        let backend = create_backend(&cfg)
            .with_context(|| format!("host {rank}: creating {} backend", cfg.backend.name()))?;
        let cache = KvCache::new(
            cfg.model.n_layers,
            cfg.apb.cache_max(),
            cfg.model.n_kv_heads,
            cfg.model.head_dim(),
        );
        Ok(HostWorker { rank, cfg, fabric, backend, cache })
    }

    fn serve(&mut self, cmd_rx: Receiver<Cmd>, resp_tx: Sender<Resp>) {
        while let Ok(cmd) = cmd_rx.recv() {
            let resp = match cmd {
                Cmd::Shutdown => break,
                Cmd::Clear => {
                    self.cache.clear();
                    Resp::Cleared { host: self.rank }
                }
                Cmd::Prefill { tokens, opts } => match self.prefill(&tokens, &opts) {
                    Ok((timing, retained)) => {
                        Resp::PrefillDone { host: self.rank, timing, retained }
                    }
                    Err(e) => Resp::Error { host: self.rank, msg: format!("{e:#}") },
                },
                Cmd::QueryChunk { tokens } => {
                    let pos0 = (self.cfg.apb.query_len + self.cfg.apb.doc_len()) as i32;
                    match self.decode_pass(&tokens, pos0) {
                        Ok((logits, timing)) => {
                            Resp::StepDone { host: self.rank, logits, timing }
                        }
                        Err(e) => Resp::Error { host: self.rank, msg: format!("{e:#}") },
                    }
                }
                Cmd::DecodeStep { token, step } => {
                    let a = &self.cfg.apb;
                    let pos0 = (a.query_len + a.doc_len() + a.query_len + step) as i32;
                    match self.decode_pass(&[token], pos0) {
                        Ok((logits, timing)) => {
                            Resp::StepDone { host: self.rank, logits, timing }
                        }
                        Err(e) => Resp::Error { host: self.rank, msg: format!("{e:#}") },
                    }
                }
            };
            if resp_tx.send(resp).is_err() {
                break; // leader gone
            }
        }
    }

    /// Per-kv-head gather of compressed KV rows: k/v are the local slices
    /// [l_b, kh, hd]; idx[j] lists ascending positions for head j.
    fn gather_compressed(
        &self,
        k: &Tensor,
        v: &Tensor,
        idx: &[Vec<usize>],
    ) -> (Tensor, Tensor) {
        let (kh, hd) = (k.shape[1], k.shape[2]);
        let l_p = idx[0].len();
        let mut kc = Tensor::zeros(vec![l_p, kh, hd]);
        let mut vc = Tensor::zeros(vec![l_p, kh, hd]);
        for j in 0..kh {
            for (t, &i) in idx[j].iter().enumerate() {
                let src = (i * kh + j) * hd;
                let dst = (t * kh + j) * hd;
                kc.data[dst..dst + hd].copy_from_slice(&k.data[src..src + hd]);
                vc.data[dst..dst + hd].copy_from_slice(&v.data[src..src + hd]);
            }
        }
        (kc, vc)
    }

    /// Algorithm 2 — APB prefill over this host's [anchor | local] layout.
    /// Returns timing + the per-layer/per-head retained indices.
    fn prefill(
        &mut self,
        tokens: &[i32],
        opts: &ApbOptions,
    ) -> Result<(PrefillTiming, Vec<Vec<Vec<u32>>>)> {
        let cfg = &self.cfg;
        let (a, m) = (&cfg.apb, &cfg.model);
        let backend = self.backend.as_ref();
        self.cache.clear();
        let mut tm = PrefillTiming::default();
        let mut retained: Vec<Vec<Vec<u32>>> = Vec::with_capacity(m.n_layers);
        let mut sw = Stopwatch::start();
        let total0 = std::time::Instant::now();

        let mut hidden = backend.embed(tokens)?;
        tm.embed_s += sw.lap();

        let pos_offset = (a.query_len + self.rank * a.block_len) as i32;
        let n_anchor = super::n_anchor_for(cfg, self.rank, opts);
        let pass_len: i32 = if opts.use_passing {
            (self.rank * a.passing_len) as i32
        } else {
            0
        };

        for li in 0..m.n_layers {
            // --- layer_pre: QKV + RoPE + retaining scores ----------------
            let (q, k, v, scores) = backend.layer_pre(li, &hidden, pos_offset)?;
            tm.layer_pre_s += sw.lap();

            // --- Top-l_p selection (coordinator side, §3.4) ---------------
            let k_local = k.slice_rows(a.l_aq(), a.n_tot());
            let v_local = v.slice_rows(a.l_aq(), a.n_tot());
            let scores_used = if opts.retaining_compressor {
                scores
            } else {
                let mut rd = Tensor::zeros(vec![a.block_len, m.n_kv_heads]);
                for i in 0..a.block_len {
                    for j in 0..m.n_kv_heads {
                        rd.data[i * m.n_kv_heads + j] = random_score(
                            opts.rd_seed, li as u64, self.rank as u64, j as u64, i as u64,
                        );
                    }
                }
                rd
            };
            let idx = top_lp_indices(&scores_used, a.passing_len);
            retained.push(
                idx.iter()
                    .map(|head| head.iter().map(|&i| i as u32).collect())
                    .collect(),
            );
            let (k_c, v_c) = self.gather_compressed(&k_local, &v_local, &idx);
            tm.topk_s += sw.lap();

            // --- AllGather of compressed blocks (§3.5) --------------------
            let blocks: Vec<(Tensor, Tensor)> = if opts.use_passing {
                self.fabric.kv_gather.all_gather(self.rank, (k_c, v_c))
            } else {
                Vec::new()
            };
            tm.comm_s += sw.lap();

            // --- Passing-block assembly: ranks < mine, rank order ---------
            let mut k_pass =
                Tensor::zeros(vec![a.pass_max(), m.n_kv_heads, m.head_dim()]);
            let mut v_pass = k_pass.clone();
            for r in 0..self.rank.min(blocks.len()) {
                k_pass.write_rows(r * a.passing_len, &blocks[r].0);
                v_pass.write_rows(r * a.passing_len, &blocks[r].1);
            }

            // --- layer_post: APB attention + FFN (§3.6) -------------------
            hidden = backend.layer_post(
                li, &hidden, &q, &k, &v, &k_pass, &v_pass, pass_len, n_anchor,
            )?;
            tm.layer_post_s += sw.lap();

            // --- cache append: local block KV only (anchor discarded) -----
            self.cache.append(li, &k_local, &v_local)?;
            tm.cache_s += sw.lap();
        }
        tm.total_s = total0.elapsed().as_secs_f64();
        Ok((tm, retained))
    }

    /// Algorithm 3 — one decode pass (query chunk or single token).
    /// Returns logits on the last host only.
    fn decode_pass(
        &mut self,
        tokens: &[i32],
        pos0: i32,
    ) -> Result<(Option<Vec<f32>>, DecodeTiming)> {
        let cfg = &self.cfg;
        let (a, m) = (&cfg.apb, &cfg.model);
        let backend = self.backend.as_ref();
        let last = self.rank == a.n_hosts - 1;
        let mut tm = DecodeTiming::default();
        let mut sw = Stopwatch::start();
        let total0 = std::time::Instant::now();

        let mut hidden = backend.embed(tokens)?;
        tm.pre_s += sw.lap();

        for li in 0..m.n_layers {
            // decode_pre: project + rope the chunk.
            let (q, k, v) = backend.decode_pre(li, &hidden, pos0)?;
            tm.pre_s += sw.lap();

            // Last host appends the chunk's KV before attending (line 7).
            let self_causal = if last {
                self.cache.append(li, &k, &v)?;
                true
            } else {
                false
            };
            let lc = &self.cache.layers[li];
            let (out, lse) = backend.decode_attn(&q, &lc.k, &lc.v, lc.len, self_causal)?;
            tm.attn_s += sw.lap();

            // Gather all hosts' partials (line 9) ...
            let all = self.fabric.att_gather.all_gather(self.rank, (out, lse));
            tm.comm_s += sw.lap();

            // ... and merge with the online-softmax identity (line 10).
            let outs_v: Vec<Tensor> = all.iter().map(|(o, _)| o.clone()).collect();
            let lses_v: Vec<Tensor> = all.iter().map(|(_, l)| l.clone()).collect();
            let att = merge_partials(&outs_v, &lses_v);
            tm.merge_s += sw.lap();

            // decode_post: O-proj + FFN, replicated (identical on all hosts).
            hidden = backend.decode_post(li, &hidden, &att)?;
            tm.post_s += sw.lap();
        }

        let logits = if last {
            let l = backend.lm_head(&hidden)?;
            tm.lm_head_s += sw.lap();
            Some(l.data)
        } else {
            None
        };
        tm.total_s = total0.elapsed().as_secs_f64();
        Ok((logits, tm))
    }
}
