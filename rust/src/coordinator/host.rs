//! Host worker: one simulated GPU. Owns an execution backend (SimEngine or
//! PJRT, per `Config::backend`), a KV pool with one slot per resident
//! session, and per-session position bookkeeping; executes the per-layer
//! stages of the session's `AttnMethod` (Algorithm 2 prefill + Algorithm 3
//! decode for APB/StarAttn, the ring rotation for RingAttn, single-host
//! causal for Dense) and participates in fabric collectives.
//!
//! The worker is **driver-agnostic** (`docs/ADR-004-threaded-hosts.md`):
//! every [`Envelope`] is accepted by [`HostWorker::begin`], which either
//! finishes immediately ([`Begun::Done`]) or returns a resumable
//! [`DecodeJob`] whose [`HostWorker::job_step`] advances one bounded
//! microstep — at most one fabric `post` or one `complete` per call, never
//! both. Under the threaded driver each host's [`run_host`] loop spins the
//! job to completion on its own OS thread (blocking on real rendezvous);
//! under the sequential oracle the leader round-robins `job_step` across
//! ranks in rank order, which by the microstep invariant never blocks:
//! every rank posts a round at the same step index and completes it at a
//! strictly later one.
//!
//! Prefill is **resumable**: `Cmd::PrefillBegin` claims the KV slot and
//! builds a `PrefillMachine`; each `Cmd::PrefillChunk` advances it one
//! bounded step (the scheduler interleaves decode ticks in between), and
//! the final step retires the machine and reports timing — see
//! `coordinator::prefill` and `docs/ADR-002-chunked-prefill.md`.
//!
//! With `ApbParams::prefix_cache` on, a digest-keyed `PrefillBegin` whose
//! entry is resident in the pool's prefix store skips the document pass
//! entirely (warm attach, one-step machine), and a cold completion freezes
//! its document KV into the store; decode then runs over `[shared |
//! private]` KV views either way — see `docs/ADR-003-prefix-caching.md`.

use std::collections::HashMap;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::cluster::{complete_accounted, Interconnect, Receipt};
use crate::config::{ApbOptions, AttnMethod, Config, PassStrategy};
use crate::kvcache::{KvPool, SessionId};
use crate::runtime::{create_backend, ExecBackend, KvView};
use crate::util::tensor::{merge_partials, Tensor};

use super::prefill::{PrefillMachine, StepCtx, StepOutcome};
use super::timing::{DecodeTiming, PrefillTiming, Stopwatch};
use super::{Cmd, Envelope, Resp};

/// Threaded-driver entry point: construct the worker, signal readiness,
/// then serve envelopes until `Cmd::Shutdown` or a hung-up channel.
pub fn run_host(
    rank: usize,
    cfg: Config,
    fabric: Arc<Interconnect>,
    cmd_rx: Receiver<Envelope>,
    resp_tx: Sender<Resp>,
    ready_tx: Sender<Result<usize>>,
) {
    match HostWorker::new(rank, cfg, fabric) {
        Ok(mut w) => {
            let _ = ready_tx.send(Ok(rank));
            w.serve(cmd_rx, resp_tx);
        }
        Err(e) => {
            let _ = ready_tx.send(Err(e));
        }
    }
}

/// Per-session decode bookkeeping owned by the worker: the global position
/// of the next token row this session will decode (set to
/// `query_len + doc_len` by prefill — the first re-fed query-chunk row —
/// and advanced by every decode pass), plus the attention method the
/// session was prefilled under, which routes its decode passes (Dense
/// sessions decode entirely on host 0; everything else runs the
/// distributed Algorithm-3 merge). Registered on EVERY host, including the
/// idle ranks of a Dense session, so decode commands can be routed without
/// the leader re-sending options.
struct SessionState {
    next_pos: i32,
    method: AttnMethod,
}

/// Payload of `Resp::PrefillDone`: accumulated prefill timing, the
/// per-layer/per-kv-head retained index sets (empty unless the request set
/// `ApbOptions::record_retained`), whether the prefill rode a prefix-cache
/// hit, and the KV bytes that hit avoided recomputing on this host.
type PrefillOutcome = (PrefillTiming, Vec<Vec<Vec<u32>>>, bool, u64);

/// What a distributed decode job is stepping over.
enum JobKind {
    /// One session's multi-row chunk (the re-fed query).
    Chunk { sid: SessionId, n_rows: usize },
    /// Continuous-batching step: one single-token row per session, leader
    /// entry order fixed across hosts.
    Batch { entries: Vec<(SessionId, i32)> },
}

/// A resumable distributed decode pass (Algorithm 3): per-layer carry
/// state between [`HostWorker::job_step`] microsteps. `awaiting` holds the
/// receipt of the layer's posted-but-incomplete fabric round — its
/// presence IS the job's phase bit (post half done, complete half
/// pending). Which collective that round rides depends on `strategy`
/// (`docs/ADR-007-adaptive-decode.md`):
///
/// * **pass-KV** — one `att` AllGather of (out, lse) partials per layer,
///   merged the moment it completes (the original Algorithm-3 path);
/// * **pass-Q** — `n_hosts - 1` `qring` neighbor rounds per layer,
///   store-and-forward: each round delivers (and then forwards) one
///   origin's partial, `parts` banks them by origin rank, and the merge
///   runs only after the rotation delivered every origin — in rank order,
///   so `merge_partials` sees bit-identical inputs to the gather path.
pub(crate) struct DecodeJob {
    kind: JobKind,
    /// Fabric round tag (session id for chunks, the leader's batch digest
    /// for batches — shipped in the [`Envelope`]).
    tag: u64,
    /// Resolved decode pass strategy — never [`PassStrategy::Auto`] here;
    /// the leader resolves Auto before dispatch so every rank agrees.
    strategy: PassStrategy,
    hidden: Tensor,
    positions: Vec<i32>,
    /// Next layer to run (== n_layers when only the finish step remains).
    li: usize,
    awaiting: Option<Receipt>,
    /// Pass-Q rotation round within the current layer: 0 outside a
    /// rotation, r after posting round r (rounds run 1..=n_hosts-1).
    qround: usize,
    /// Pass-Q partial bank, indexed by origin rank; `parts[self.rank]` is
    /// this rank's own partial, banked at layer start.
    parts: Vec<Option<(Tensor, Tensor)>>,
    /// Pass-Q forwarding buffer: the partial received last round, to be
    /// posted onward on its own microstep (never in the same call as the
    /// complete — the one-fabric-op-per-microstep invariant).
    carry: Option<(Tensor, Tensor)>,
    tm: DecodeTiming,
    t0: std::time::Instant,
}

/// Outcome of [`HostWorker::begin`]: either the envelope finished in one
/// call, or it opened a [`DecodeJob`] the driver must step to completion.
pub(crate) enum Begun {
    Done(Resp),
    Job(DecodeJob),
}

pub(crate) struct HostWorker {
    rank: usize,
    cfg: Config,
    fabric: Arc<Interconnect>,
    backend: Box<dyn ExecBackend>,
    pool: KvPool,
    sessions: HashMap<SessionId, SessionState>,
    /// In-flight resumable prefills, one machine per session being
    /// prefilled (`Cmd::PrefillBegin` creates it, the final
    /// `Cmd::PrefillChunk` retires it). A session with a live machine has a
    /// partially filled KV slot and must not decode yet (tripwired below).
    machines: HashMap<SessionId, PrefillMachine>,
}

impl HostWorker {
    pub(crate) fn new(rank: usize, cfg: Config, fabric: Arc<Interconnect>) -> Result<Self> {
        let backend = create_backend(&cfg)
            .with_context(|| format!("host {rank}: creating {} backend", cfg.backend.name()))?;
        // Slot capacity follows the cluster's method: distributed modes
        // hold at most a local block + decode tail per session, Dense
        // concentrates the whole sequence on host 0 (every host's pool is
        // sized alike — rank-0-only sizing would save little sim memory and
        // complicate the symmetric capacity check).
        let mut pool = KvPool::new(
            cfg.apb.max_resident,
            cfg.model.n_layers,
            cfg.apb.cache_rows(cfg.method),
            cfg.model.n_kv_heads,
            cfg.model.head_dim(),
        );
        // Shared-prefix store: one slot-equivalent per residency slot. The
        // cap is an ENTRY count (rank-uniform) so LRU eviction decides
        // identically on every host — per-rank entry BYTES differ (Dense
        // stores everything on rank 0, nothing elsewhere).
        if cfg.apb.prefix_cache {
            pool.set_prefix_cap(cfg.apb.max_resident.max(1));
        }
        Ok(HostWorker {
            rank,
            cfg,
            fabric,
            backend,
            pool,
            sessions: HashMap::new(),
            machines: HashMap::new(),
        })
    }

    /// Threaded serve loop: one envelope in, one response out. A job spins
    /// inline — this thread owns the host, so blocking in `complete` is
    /// exactly the real-cluster behavior (bounded by the round timeout).
    fn serve(&mut self, cmd_rx: Receiver<Envelope>, resp_tx: Sender<Resp>) {
        while let Ok(env) = cmd_rx.recv() {
            if matches!(env.body, Cmd::Shutdown) {
                break;
            }
            let resp = match self.begin(env) {
                Begun::Done(resp) => resp,
                Begun::Job(mut job) => loop {
                    if let Some(resp) = self.job_step(&mut job) {
                        break resp;
                    }
                },
            };
            if resp_tx.send(resp).is_err() {
                break; // leader gone
            }
        }
    }

    /// Accept one envelope. All validation and every immediate (collective-
    /// free) command finishes here; distributed decodes return a
    /// [`DecodeJob`] for the driver to step. Errors are folded into
    /// `Resp::Error` — `begin` itself is infallible so both drivers share
    /// one dispatch surface.
    pub(crate) fn begin(&mut self, env: Envelope) -> Begun {
        let Envelope { sid, tag, body } = env;
        let resp = match body {
            // The threaded serve loop intercepts Shutdown before begin and
            // the sequential driver never dispatches it.
            Cmd::Shutdown => unreachable!("Shutdown is intercepted by the serve loop"),
            Cmd::Clear => {
                self.pool.free(sid);
                self.sessions.remove(&sid);
                // An in-flight machine is cancelled, not just dropped:
                // abort() drains any posted fabric round so the collectives
                // stay clean for the next session.
                if let Some(m) = self.machines.remove(&sid) {
                    m.abort(self.rank, &self.fabric);
                }
                Resp::Cleared { host: self.rank }
            }
            Cmd::ClearAll => {
                self.pool.clear_all();
                self.sessions.clear();
                for (_, m) in self.machines.drain() {
                    m.abort(self.rank, &self.fabric);
                }
                Resp::Cleared { host: self.rank }
            }
            Cmd::PrefillBegin { tokens, opts, digest } => {
                match self.prefill_begin(sid, &tokens, &opts, digest) {
                    Ok((steps, prefix_hit)) => {
                        Resp::PrefillBegun { host: self.rank, sid, steps, prefix_hit }
                    }
                    Err(e) => Resp::Error { host: self.rank, msg: format!("{e:#}") },
                }
            }
            Cmd::PrefillChunk { chunk_idx } => match self.prefill_chunk(sid, chunk_idx) {
                Ok(None) => {
                    // Report whether this rank's machine sits at a fabric
                    // quiescent point: the leader needs rank-uniform
                    // quiescence to decide if the prefill may be suspended
                    // (permit released) at this chunk boundary.
                    let quiescent = self
                        .machines
                        .get(&sid)
                        .map(|m| m.fabric_quiescent())
                        .unwrap_or(true);
                    Resp::PrefillStep { host: self.rank, sid, quiescent }
                }
                Ok(Some((timing, retained, prefix_hit, prefix_bytes))) => Resp::PrefillDone {
                    host: self.rank,
                    sid,
                    timing,
                    retained,
                    prefix_hit,
                    prefix_bytes,
                },
                Err(e) => Resp::Error { host: self.rank, msg: format!("{e:#}") },
            },
            Cmd::PoolStats => Resp::PoolStats { host: self.rank, stats: self.pool.stats() },
            Cmd::QueryChunk { tokens, strategy, turn } => {
                match self.decode_begin(sid, tag, &tokens, strategy, turn) {
                    Ok(begun) => return begun,
                    Err(e) => Resp::Error { host: self.rank, msg: format!("{e:#}") },
                }
            }
            Cmd::DecodeBatch { entries, strategy } => {
                match self.decode_batch_begin(tag, entries.to_vec(), strategy) {
                    Ok(begun) => return begun,
                    Err(e) => Resp::Error { host: self.rank, msg: format!("{e:#}") },
                }
            }
        };
        Begun::Done(resp)
    }

    /// Advance a decode job by one microstep (at most one fabric post OR
    /// one complete). `Some(resp)` when the job retired. Errors fold into
    /// `Resp::Error` like `begin`.
    pub(crate) fn job_step(&mut self, job: &mut DecodeJob) -> Option<Resp> {
        match self.job_step_inner(job) {
            Ok(done) => done,
            Err(e) => Some(Resp::Error { host: self.rank, msg: format!("{e:#}") }),
        }
    }

    fn job_step_inner(&mut self, job: &mut DecodeJob) -> Result<Option<Resp>> {
        // Complete half: a fabric round was posted by the previous
        // microstep; finish it on the strategy's collective. Pass-KV
        // merges immediately (all partials arrive at once); pass-Q merges
        // only once the rotation has delivered every origin's partial.
        if let Some(receipt) = job.awaiting.take() {
            if job.strategy == PassStrategy::PassQ {
                self.complete_qring_round(job, receipt)?;
            } else {
                self.complete_att_gather(job, receipt)?;
            }
            return Ok(None);
        }
        if job.li == self.cfg.model.n_layers {
            return self.job_finish(job).map(Some);
        }
        // Mid-rotation post half (pass-Q only): forward the partial
        // received last round to the successor. Posting gets its own
        // microstep so the lockstep invariant holds — every rank posts
        // round r at the same step index and completes it strictly later.
        if let Some(fwd) = job.carry.take() {
            job.qround += 1;
            job.awaiting = Some(self.fabric.q_ring.post_tagged(self.rank, job.tag, fwd));
            return Ok(None);
        }
        // Post half of layer `li`: project, append (last host), attend the
        // local partial, post the strategy's opening round. The complete
        // half runs next microstep — after every rank posted, by the
        // lockstep invariant.
        let li = job.li;
        let last = self.rank == self.cfg.apb.n_hosts - 1;
        let mut sw = Stopwatch::start();
        let (q, k, v) = self.backend.decode_pre(li, &job.hidden, &job.positions)?;
        job.tm.pre_s += sw.lap();
        let (out, lse) = match &job.kind {
            JobKind::Chunk { sid, .. } => {
                // Last host appends the chunk's KV before attending (Alg. 3
                // line 7); its rows then see themselves self-causally.
                let self_causal = if last {
                    self.pool.get_mut(*sid)?.append(li, &k, &v)?;
                    true
                } else {
                    false
                };
                // [shared | private] view: a prefix-hit session attends its
                // shared document rows plus its own tail, bit-identical to
                // a contiguous cold cache (one segmented kernel).
                let cache = self.pool.get(*sid)?;
                let view = cache.view(li);
                self.backend.decode_attn_view(&q, &view, self_causal)?
            }
            JobKind::Batch { entries } => {
                // Last host appends each session's new row to ITS cache
                // before attending; each row then sees exactly its own
                // cache's valid prefix (the n=1 self-causal rule).
                if last {
                    for (i, &(sid, _)) in entries.iter().enumerate() {
                        self.pool.get_mut(sid)?.append_row(li, &k, &v, i)?;
                    }
                }
                let views: Vec<KvView<'_>> = entries
                    .iter()
                    .map(|&(sid, _)| Ok(self.pool.get(sid)?.view(li)))
                    .collect::<Result<_>>()?;
                self.backend.decode_attn_batch(&q, &views)?
            }
        };
        job.tm.attn_s += sw.lap();
        match job.strategy {
            PassStrategy::PassQ => {
                // Open the rotation: bank this rank's own partial at its
                // origin slot and send a copy to the successor as round 1.
                let n = self.cfg.apb.n_hosts;
                job.parts.clear();
                job.parts.resize_with(n, || None);
                job.parts[self.rank] = Some((out.clone(), lse.clone()));
                job.qround = 1;
                job.awaiting =
                    Some(self.fabric.q_ring.post_tagged(self.rank, job.tag, (out, lse)));
            }
            _ => {
                // Gather all hosts' partials (line 9), round-tagged.
                job.awaiting =
                    Some(self.fabric.att_gather.post_tagged(self.rank, job.tag, (out, lse)));
            }
        }
        Ok(None)
    }

    /// Complete half of the pass-KV path: finish the layer's `att`
    /// AllGather, merge every rank's partial (delivered in rank order),
    /// run `decode_post`, advance to the next layer.
    fn complete_att_gather(&mut self, job: &mut DecodeJob, receipt: Receipt) -> Result<()> {
        let all = match complete_accounted(
            &self.fabric.att_gather,
            self.rank,
            &receipt,
            &mut job.tm.comm_s,
            &mut job.tm.comm_window_s,
            &mut job.tm.comm_hidden_s,
        ) {
            Ok(all) => all,
            Err(e) => {
                // Decode jobs have no resume path — drain the round so
                // the fabric survives this job's death.
                self.fabric.att_gather.cancel(self.rank, receipt);
                return Err(e.into());
            }
        };
        let mut sw = Stopwatch::start();
        let outs_v: Vec<Tensor> = all.iter().map(|(o, _)| o.clone()).collect();
        let lses_v: Vec<Tensor> = all.iter().map(|(_, l)| l.clone()).collect();
        let att = merge_partials(&outs_v, &lses_v);
        job.tm.merge_s += sw.lap();
        job.hidden = self.backend.decode_post(job.li, &job.hidden, &att)?;
        job.tm.post_s += sw.lap();
        job.li += 1;
        Ok(())
    }

    /// Complete one pass-Q rotation round. Store-and-forward: the pair
    /// delivered at round r is the partial of origin rank
    /// `(rank + n - r) % n` (each round every rank forwards what it
    /// received the round before, so partials travel the ring unmodified).
    /// Until the final round the item is also kept as `carry` for the next
    /// post microstep; after round `n - 1` every origin's partial is
    /// banked and the merge runs in rank order — the same slice order the
    /// gather path's AllGather delivers, so `merge_partials` folds
    /// bit-identical inputs in the identical FP op order.
    fn complete_qring_round(&mut self, job: &mut DecodeJob, receipt: Receipt) -> Result<()> {
        let n = self.cfg.apb.n_hosts;
        let got = match complete_accounted(
            &self.fabric.q_ring,
            self.rank,
            &receipt,
            &mut job.tm.comm_s,
            &mut job.tm.comm_window_s,
            &mut job.tm.comm_hidden_s,
        ) {
            Ok(got) => got,
            Err(e) => {
                self.fabric.q_ring.cancel(self.rank, receipt);
                return Err(e.into());
            }
        };
        let origin = (self.rank + n - job.qround) % n;
        if job.qround + 1 < n {
            // Still rotating: this partial moves on next microstep.
            job.carry = Some((got.0.clone(), got.1.clone()));
            job.parts[origin] = Some(got);
            return Ok(());
        }
        job.parts[origin] = Some(got);
        let mut sw = Stopwatch::start();
        let (outs_v, lses_v): (Vec<Tensor>, Vec<Tensor>) = job
            .parts
            .iter_mut()
            .map(|p| p.take().expect("rotation delivered every origin's partial"))
            .unzip();
        let att = merge_partials(&outs_v, &lses_v);
        job.tm.merge_s += sw.lap();
        job.hidden = self.backend.decode_post(job.li, &job.hidden, &att)?;
        job.tm.post_s += sw.lap();
        job.li += 1;
        job.qround = 0;
        Ok(())
    }

    /// Retire a finished decode job: advance position bookkeeping, produce
    /// logits on the last host, stamp the total.
    fn job_finish(&mut self, job: &mut DecodeJob) -> Result<Resp> {
        let last = self.rank == self.cfg.apb.n_hosts - 1;
        let mut sw = Stopwatch::start();
        let resp = match &job.kind {
            JobKind::Chunk { sid, n_rows } => {
                self.sessions.get_mut(sid).unwrap().next_pos += *n_rows as i32;
                let logits = if last {
                    let l = self.backend.lm_head(&job.hidden)?;
                    job.tm.lm_head_s += sw.lap();
                    Some(l.data)
                } else {
                    None
                };
                job.tm.total_s = job.t0.elapsed().as_secs_f64();
                Resp::StepDone { host: self.rank, sid: *sid, logits, timing: job.tm }
            }
            JobKind::Batch { entries } => {
                for &(sid, _) in entries.iter() {
                    self.sessions.get_mut(&sid).unwrap().next_pos += 1;
                }
                let logits = if last {
                    let l = self.backend.lm_head(&job.hidden)?;
                    job.tm.lm_head_s += sw.lap();
                    let vocab = self.cfg.model.vocab_size;
                    Some(
                        (0..entries.len())
                            .map(|i| l.data[i * vocab..(i + 1) * vocab].to_vec())
                            .collect(),
                    )
                } else {
                    None
                };
                job.tm.total_s = job.t0.elapsed().as_secs_f64();
                Resp::BatchDone { host: self.rank, logits, timing: job.tm }
            }
        };
        Ok(resp)
    }

    /// Position of the first re-fed query-chunk row (end of the global
    /// [query | document] prefix every session's prefill covers).
    fn decode_pos0(&self) -> i32 {
        (self.cfg.apb.query_len + self.cfg.apb.doc_len()) as i32
    }

    /// Session lookup for decode, creating state on demand: a session that
    /// never prefilled (degenerate empty-cache decode) gets a fresh slot,
    /// the cluster-default method (`Config::method`) and the post-prefill
    /// position. Returns the session's method for decode routing.
    fn ensure_session(&mut self, sid: SessionId) -> Result<AttnMethod> {
        if let Some(s) = self.sessions.get(&sid) {
            return Ok(s.method);
        }
        let method = self.cfg.method;
        self.claim_slot(sid, method)?;
        Ok(method)
    }

    /// Start a resumable prefill: claim (or reset) the session's KV slot —
    /// BEFORE building any machine state, so pool exhaustion fails
    /// identically on every host as backpressure, never a deadlocked
    /// half-round — then construct the method's [`PrefillMachine`] and
    /// return its plan length plus the prefix-cache hit verdict (both
    /// rank-uniform by construction; the leader asserts it).
    ///
    /// A digest whose entry is resident in the prefix store takes the warm
    /// fast path: the session attaches to the immutable `SharedPrefix`
    /// right here and the machine degenerates to one `PrefixAttach` step —
    /// the per-layer document pass is skipped entirely.
    fn prefill_begin(
        &mut self,
        sid: SessionId,
        tokens: &[i32],
        opts: &ApbOptions,
        digest: Option<u64>,
    ) -> Result<(usize, bool)> {
        self.claim_slot(sid, opts.method)?;
        if let Some(d) = digest {
            if let Some(entry) = self.pool.prefix_lookup(d) {
                self.pool.get_mut(sid)?.attach_shared(Arc::clone(&entry))?;
                let (machine, steps) = PrefillMachine::new_warm(sid, opts, d, entry);
                self.machines.insert(sid, machine);
                return Ok((steps, true));
            }
        }
        let (machine, steps) = PrefillMachine::new(
            self.rank, &self.cfg, sid, tokens, opts, self.backend.as_ref(), digest,
        )?;
        self.machines.insert(sid, machine);
        Ok((steps, false))
    }

    /// Advance session `sid`'s prefill machine by one step. Returns the
    /// accumulated timing + retained indices when the plan is exhausted
    /// (the machine is retired), `None` while steps remain. A step error
    /// cancels THIS host's machine (draining any posted fabric round);
    /// other hosts may still hold theirs, so the session cannot be resumed
    /// — only cleared (the leader keeps its in-flight permit held until
    /// then).
    fn prefill_chunk(
        &mut self,
        sid: SessionId,
        chunk_idx: usize,
    ) -> Result<Option<PrefillOutcome>> {
        let Some(machine) = self.machines.get_mut(&sid) else {
            bail!("session {sid} has no prefill in flight");
        };
        let cache = self.pool.get_mut(sid)?;
        let mut ctx = StepCtx {
            rank: self.rank,
            cfg: &self.cfg,
            fabric: &*self.fabric,
            backend: self.backend.as_ref(),
            cache,
        };
        match machine.step(&mut ctx, chunk_idx) {
            Ok(StepOutcome::Progress) => Ok(None),
            Ok(StepOutcome::Done(timing, retained)) => {
                let machine = self.machines.remove(&sid).expect("machine vanished");
                // Prefix-cache bookkeeping at retirement: a warm machine
                // reports the bytes its hit avoided recomputing; a cold
                // digest-keyed machine FREEZES its document KV into the
                // store (moving the slot's rows into an immutable shared
                // entry the session itself now rides — so cold and warm
                // sessions decode through the identical [shared | private]
                // path).
                let (hit, bytes) = if let Some(entry) = machine.warm_entry() {
                    (true, entry.bytes() as u64)
                } else if let Some(d) = machine.digest() {
                    self.pool.freeze_shared(sid, d, retained.clone())?;
                    (false, 0)
                } else {
                    (false, 0)
                };
                Ok(Some((timing, retained, hit, bytes)))
            }
            Err(e) => {
                // Same cancellation as Cmd::Clear: drain any posted fabric
                // round before discarding the machine.
                if let Some(m) = self.machines.remove(&sid) {
                    m.abort(self.rank, &self.fabric);
                }
                Err(e)
            }
        }
    }

    /// Capacity check for a per-request method against the pool this
    /// cluster was sized for. Deliberately computed from the config alone
    /// (the pool's slot size IS `cache_rows(cfg.method)`), so every rank —
    /// including the idle ranks of a Dense prefill — reaches the same
    /// verdict before touching any state or collective.
    fn check_method_fits(&self, method: AttnMethod) -> Result<()> {
        let needed = self.cfg.apb.cache_rows(method);
        let have = self.cfg.apb.cache_rows(self.cfg.method);
        if needed > have {
            bail!(
                "method {} needs {needed} KV rows per slot but the pool was sized \
                 for {have} (cluster method {}); start the cluster from \
                 Config::with_method",
                method.name(),
                self.cfg.method.name()
            );
        }
        Ok(())
    }

    /// Claim (or reset) `sid`'s pool slot and register its session state,
    /// erroring — before any collective, identically on every host — when
    /// the pool was not sized for `method`.
    fn claim_slot(&mut self, sid: SessionId, method: AttnMethod) -> Result<()> {
        self.check_method_fits(method)?;
        self.pool.alloc(sid)?;
        self.sessions.insert(sid, SessionState { next_pos: self.decode_pos0(), method });
        Ok(())
    }

    /// Tripwire + degenerate-topology guard for a decode command's pass
    /// strategy: `Auto` must never reach a host (the leader resolves it so
    /// every rank agrees — a per-rank resolution could split the fabric),
    /// and a fixed `PassQ` on a non-distributed method or a single-host
    /// cluster degrades to the collective-free gather path.
    fn resolve_strategy(&self, strategy: PassStrategy, method: AttnMethod)
                        -> Result<PassStrategy> {
        if strategy == PassStrategy::Auto {
            bail!("pass strategy Auto reached host {} unresolved (leader bug)", self.rank);
        }
        Ok(strategy.resolve(false, self.cfg.apb.n_hosts, method))
    }

    /// Open one decode pass over a single session's chunk (the re-fed
    /// query, or — with `turn` set — a new conversation turn appended
    /// against the resident `[shared | private]` cache). Dense sessions
    /// finish immediately (no collective); the distributed methods return
    /// a [`DecodeJob`] riding the resolved `strategy`'s collective. All
    /// tripwires run here, before any fabric round, identically on every
    /// host.
    fn decode_begin(
        &mut self,
        sid: SessionId,
        tag: u64,
        tokens: &[i32],
        strategy: PassStrategy,
        turn: bool,
    ) -> Result<Begun> {
        // A session mid-prefill has a partially filled KV slot; decoding it
        // would produce plausible-but-wrong logits. Checked before any
        // collective (machine maps are identical on every host).
        if self.machines.contains_key(&sid) {
            bail!("session {sid} has a prefill in flight: cannot decode yet");
        }
        let method = self.ensure_session(sid)?;
        let strategy = self.resolve_strategy(strategy, method)?;
        if turn {
            // New conversation turn: record the boundary before any of the
            // turn's KV lands, so the marks partition the private tail by
            // turn (`docs/ADR-007-adaptive-decode.md`).
            self.pool.get_mut(sid)?.mark_turn();
        }
        if !method.distributed_decode() {
            let (logits, timing) = self.decode_pass_dense(sid, tokens)?;
            return Ok(Begun::Done(Resp::StepDone { host: self.rank, sid, logits, timing }));
        }
        let pos0 = self.sessions[&sid].next_pos;
        let positions: Vec<i32> = (0..tokens.len() as i32).map(|i| pos0 + i).collect();
        let t0 = std::time::Instant::now();
        let mut tm = DecodeTiming::default();
        let mut sw = Stopwatch::start();
        let hidden = self.backend.embed(tokens)?;
        tm.pre_s += sw.lap();
        Ok(Begun::Job(DecodeJob {
            kind: JobKind::Chunk { sid, n_rows: tokens.len() },
            tag,
            strategy,
            hidden,
            positions,
            li: 0,
            awaiting: None,
            qround: 0,
            parts: Vec::new(),
            carry: None,
            tm,
            t0,
        }))
    }

    /// Open a continuous-batching decode step: one single-token row PER
    /// SESSION, stacked into ONE backend pass per layer (decode_pre with
    /// per-row positions + decode_attn_batch against per-row caches + one
    /// merge + one decode_post), so the per-step cost grows sublinearly in
    /// the number of active sessions. Row order — and therefore collective
    /// payload layout — is the leader's entry order on every host. The
    /// round tag is the leader's batch digest, shipped in the envelope.
    fn decode_batch_begin(
        &mut self,
        tag: u64,
        entries: Vec<(SessionId, i32)>,
        strategy: PassStrategy,
    ) -> Result<Begun> {
        // Strict residency: decoding a cleared (or never-admitted) session
        // is a scheduler bug; silently resurrecting an empty cache would
        // turn it into plausible-but-wrong tokens. Checked before any
        // collective (session maps are identical on every host).
        for &(sid, _) in &entries {
            if !self.sessions.contains_key(&sid) {
                bail!("session {sid} not resident: cannot decode-batch");
            }
            if self.machines.contains_key(&sid) {
                bail!("session {sid} has a prefill in flight: cannot decode-batch");
            }
        }
        // Decode routing must be uniform across the batch: Dense sessions
        // never join collectives, so mixing them with distributed sessions
        // would desync the att_gather rounds. The scheduler groups by
        // decode path; this is the tripwire (identical on every host,
        // checked before any collective).
        let distributed = self.sessions[&entries[0].0].method.distributed_decode();
        for &(sid, _) in &entries {
            if self.sessions[&sid].method.distributed_decode() != distributed {
                bail!(
                    "decode batch mixes Dense and distributed sessions \
                     (session {sid} disagrees with session {})",
                    entries[0].0
                );
            }
        }
        let strategy =
            self.resolve_strategy(strategy, self.sessions[&entries[0].0].method)?;
        if !distributed {
            let (logits, timing) = self.decode_batch_dense(&entries)?;
            return Ok(Begun::Done(Resp::BatchDone { host: self.rank, logits, timing }));
        }
        let tokens: Vec<i32> = entries.iter().map(|&(_, t)| t).collect();
        let positions: Vec<i32> =
            entries.iter().map(|&(sid, _)| self.sessions[&sid].next_pos).collect();
        let t0 = std::time::Instant::now();
        let mut tm = DecodeTiming::default();
        let mut sw = Stopwatch::start();
        let hidden = self.backend.embed(&tokens)?;
        tm.pre_s += sw.lap();
        Ok(Begun::Job(DecodeJob {
            kind: JobKind::Batch { entries },
            tag,
            strategy,
            hidden,
            positions,
            li: 0,
            awaiting: None,
            qround: 0,
            parts: Vec::new(),
            carry: None,
            tm,
            t0,
        }))
    }

    /// Dense decode: host 0's cache holds every key, so the chunk attends
    /// it self-causally in one pass — no collective, no merge, logits on
    /// host 0. Idle ranks only advance the session's position bookkeeping
    /// (kept in lockstep so a later method switch cannot desync positions).
    fn decode_pass_dense(
        &mut self,
        sid: SessionId,
        tokens: &[i32],
    ) -> Result<(Option<Vec<f32>>, DecodeTiming)> {
        let n = tokens.len();
        let mut tm = DecodeTiming::default();
        if self.rank != 0 {
            self.sessions.get_mut(&sid).unwrap().next_pos += n as i32;
            return Ok((None, tm));
        }
        let pos0 = self.sessions[&sid].next_pos;
        let positions: Vec<i32> = (0..n as i32).map(|i| pos0 + i).collect();
        let n_layers = self.cfg.model.n_layers;
        let backend = self.backend.as_ref();
        let mut sw = Stopwatch::start();
        let total0 = std::time::Instant::now();

        let mut hidden = backend.embed(tokens)?;
        tm.pre_s += sw.lap();
        for li in 0..n_layers {
            let (q, k, v) = backend.decode_pre(li, &hidden, &positions)?;
            tm.pre_s += sw.lap();
            // Append first, then attend self-causally (row i of the chunk
            // sees the prior cache plus chunk rows 0..=i) — the same rule
            // as the distributed last host's local partial.
            self.pool.get_mut(sid)?.append(li, &k, &v)?;
            let cache = self.pool.get(sid)?;
            let view = cache.view(li);
            let (att, _lse) = backend.decode_attn_view(&q, &view, true)?;
            tm.attn_s += sw.lap();
            hidden = backend.decode_post(li, &hidden, &att)?;
            tm.post_s += sw.lap();
        }
        self.sessions.get_mut(&sid).unwrap().next_pos += n as i32;
        let logits = backend.lm_head(&hidden)?;
        tm.lm_head_s += sw.lap();
        tm.total_s = total0.elapsed().as_secs_f64();
        Ok((Some(logits.data), tm))
    }

    /// Dense twin of the batched decode job: all rows on host 0, one
    /// stacked pass per layer against the sessions' own caches, still zero
    /// communication.
    fn decode_batch_dense(
        &mut self,
        entries: &[(SessionId, i32)],
    ) -> Result<(Option<Vec<Vec<f32>>>, DecodeTiming)> {
        let mut tm = DecodeTiming::default();
        if self.rank != 0 {
            for &(sid, _) in entries {
                self.sessions.get_mut(&sid).unwrap().next_pos += 1;
            }
            return Ok((None, tm));
        }
        let tokens: Vec<i32> = entries.iter().map(|&(_, t)| t).collect();
        let positions: Vec<i32> =
            entries.iter().map(|&(sid, _)| self.sessions[&sid].next_pos).collect();
        let (n_layers, vocab) = (self.cfg.model.n_layers, self.cfg.model.vocab_size);
        let backend = self.backend.as_ref();
        let mut sw = Stopwatch::start();
        let total0 = std::time::Instant::now();

        let mut hidden = backend.embed(&tokens)?;
        tm.pre_s += sw.lap();
        for li in 0..n_layers {
            let (q, k, v) = backend.decode_pre(li, &hidden, &positions)?;
            tm.pre_s += sw.lap();
            for (i, &(sid, _)) in entries.iter().enumerate() {
                self.pool.get_mut(sid)?.append_row(li, &k, &v, i)?;
            }
            let views: Vec<KvView<'_>> = entries
                .iter()
                .map(|&(sid, _)| Ok(self.pool.get(sid)?.view(li)))
                .collect::<Result<_>>()?;
            let (att, _lse) = backend.decode_attn_batch(&q, &views)?;
            tm.attn_s += sw.lap();
            hidden = backend.decode_post(li, &hidden, &att)?;
            tm.post_s += sw.lap();
        }
        for &(sid, _) in entries {
            self.sessions.get_mut(&sid).unwrap().next_pos += 1;
        }
        let l = backend.lm_head(&hidden)?;
        tm.lm_head_s += sw.lap();
        tm.total_s = total0.elapsed().as_secs_f64();
        let rows = (0..entries.len())
            .map(|i| l.data[i * vocab..(i + 1) * vocab].to_vec())
            .collect();
        Ok((Some(rows), tm))
    }
}
