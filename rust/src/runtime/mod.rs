//! Execution backends for the per-host stage functions.
//!
//! The coordinator hot path (`coordinator::host`) is written against the
//! [`ExecBackend`] trait — one typed method per stage of Algorithm 2
//! (prefill) and Algorithm 3 (decode). Two implementations exist:
//!
//! * [`SimEngine`] (`runtime::sim`, always built): a pure-Rust engine that
//!   natively executes the tiny-model stages (embed → APB-masked attention →
//!   SwiGLU MLP → LM head) with deterministic synthetic weights derived from
//!   `util::rng`. No Python, no XLA, no artifacts — this is what CI runs.
//! * `PjrtEngine` (`runtime::pjrt`, behind the `pjrt` cargo feature): the
//!   original PJRT runtime that compiles HLO-text artifacts emitted by
//!   `python/compile/aot.py` and replays them bit-for-bit against golden
//!   files. Requires the `xla` crate (not vendored in the offline image).
//!
//! [`create_backend`] picks the implementation from `Config::backend`.

pub mod pool;
pub mod sim;

#[cfg(feature = "pjrt")]
pub mod pjrt;

use anyhow::{Context, Result};

use crate::config::{BackendKind, Config};
use crate::util::blob::Blob;
use crate::util::json::Json;
use crate::util::tensor::Tensor;

pub use sim::SimEngine;

#[cfg(feature = "pjrt")]
pub use pjrt::{Artifact, Engine, HostArg, IoSpec};

/// One contiguous KV segment: `k`/`v` tensors (possibly padded) whose first
/// `len` rows are valid.
#[derive(Clone, Copy)]
pub struct KvSeg<'a> {
    pub k: &'a Tensor,
    pub v: &'a Tensor,
    pub len: usize,
}

/// Borrowed view of one session's per-layer KV cache for decode: an
/// optional immutable **shared-prefix** segment (present when the session
/// rides a prefix-cache hit — `kvcache::SharedPrefix`,
/// `docs/ADR-003-prefix-caching.md`) followed by the session's **private
/// tail** (query chunk + decoded tokens, appended in place into the
/// slot's slab-backed capacity — `docs/ADR-005-sim-perf.md`). The
/// logical cache is the in-order concatenation `[shared | tail]`; backends
/// attend it through [`ExecBackend::decode_attn_view`] /
/// [`ExecBackend::decode_attn_batch`] without materializing the
/// concatenation.
#[derive(Clone, Copy)]
pub struct KvView<'a> {
    /// Immutable shared-prefix rows (absent on cold sessions — the common
    /// case, and the only case the pre-prefix-cache code paths produced).
    pub shared: Option<KvSeg<'a>>,
    /// The session's private, append-only tail.
    pub tail: KvSeg<'a>,
}

impl<'a> KvView<'a> {
    /// Total valid rows across both segments.
    pub fn len(&self) -> usize {
        self.shared.map_or(0, |s| s.len) + self.tail.len
    }

    /// True when no segment holds any valid row.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The view's segments in key order (`[shared | tail]`), for kernels
    /// that walk the logical concatenation. Returns a stack-held
    /// [`SegList`] (derefs to `&[KvSeg]`) — the decode hot path calls this
    /// per row per layer per step, so it must not heap-allocate.
    pub fn segs(&self) -> SegList<'a> {
        match self.shared {
            Some(s) => SegList { segs: [s, self.tail], n: 2 },
            None => SegList { segs: [self.tail, self.tail], n: 1 },
        }
    }
}

/// At most two [`KvSeg`]s on the stack (`[shared | tail]` or `[tail]`),
/// dereferencing to the valid slice. The unused slot of a one-segment list
/// repeats the tail (`KvSeg` is `Copy`) and is never exposed.
pub struct SegList<'a> {
    segs: [KvSeg<'a>; 2],
    n: usize,
}

impl<'a> std::ops::Deref for SegList<'a> {
    type Target = [KvSeg<'a>];

    fn deref(&self) -> &[KvSeg<'a>] {
        &self.segs[..self.n]
    }
}

/// Per-host execution backend: the typed stage functions of the APB model.
///
/// All tensors are host-side dense f32 (`util::tensor::Tensor`); backends
/// that stage device buffers (PJRT) do so internally. Shapes follow
/// `python/compile/model.py`:
///
/// * `hidden`: `[n, d_model]`
/// * `q`: `[n, n_heads, head_dim]`, `k`/`v`: `[n, n_kv_heads, head_dim]`
/// * `scores`: `[block_len, n_kv_heads]` compressor scores (local rows only)
/// * `lse`: `[n, n_heads]` log-sum-exp of the partial attention
///
/// Backends are constructed and used entirely inside one host-worker thread
/// (PJRT state is deliberately thread-local), so no `Send` bound is imposed.
pub trait ExecBackend {
    /// Which backend this is (for logs and reports).
    fn kind(&self) -> BackendKind;

    /// Token embedding: `tokens [n] -> hidden [n, d]`.
    fn embed(&self, tokens: &[i32]) -> Result<Tensor>;

    /// Prefill stage 1 (Algorithm 2): QKV projection + RoPE + retaining-head
    /// scores over the local block. `hidden` rows are `[anchor | local]`;
    /// `pos_offset` is the global position of the first local token.
    /// Returns `(q, k, v, scores)`.
    fn layer_pre(
        &self,
        layer: usize,
        hidden: &Tensor,
        pos_offset: i32,
    ) -> Result<(Tensor, Tensor, Tensor, Tensor)>;

    /// Prefill stage 2 (Algorithm 2): APB modified-mask attention over
    /// `[anchor | passing | local]` keys, then O-proj + residual + FFN.
    /// `k_pass`/`v_pass` are `[pass_max, kh, hd]` with valid prefix
    /// `pass_len`; `n_anchor` is 0 on host 0 and `l_aq` elsewhere.
    #[allow(clippy::too_many_arguments)]
    fn layer_post(
        &self,
        layer: usize,
        hidden: &Tensor,
        q: &Tensor,
        k: &Tensor,
        v: &Tensor,
        k_pass: &Tensor,
        v_pass: &Tensor,
        pass_len: i32,
        n_anchor: i32,
    ) -> Result<Tensor>;

    /// Chunked twin of [`ExecBackend::layer_pre`] for the resumable prefill
    /// state machine (`coordinator::prefill`): QKV projection + RoPE +
    /// retaining-head scores for ONE chunk of local-block rows.
    ///
    /// * `hidden_anchor`: the `[l_aq, d]` anchor rows (query slot + anchor
    ///   head) at this layer's input — the compressor's query-similarity
    ///   features read the embedded-query rows out of it;
    /// * `hidden_chunk`: the `[n, d]` local rows of this chunk;
    /// * `pos_chunk`: the global position of each chunk row.
    ///
    /// Returns `(q, k, v, scores)` for the chunk rows only. Because every
    /// stage underneath (RMSNorm, projection, RoPE, the score MLP) is
    /// row-wise, chunked calls are bit-identical to the full-layout
    /// `layer_pre` — the invariant `rust/tests/chunked_prefill.rs` enforces.
    ///
    /// The default implementation refuses: a backend must opt in (SimEngine
    /// computes it natively; the PJRT artifact set predates chunked prefill,
    /// so PJRT clusters must keep `chunk_tokens >= block_len`, where the
    /// machine takes the one-chunk fast path through the classic
    /// `layer_pre`).
    fn layer_pre_chunk(
        &self,
        layer: usize,
        hidden_anchor: &Tensor,
        hidden_chunk: &Tensor,
        pos_chunk: &[i32],
    ) -> Result<(Tensor, Tensor, Tensor, Tensor)> {
        let _ = (layer, hidden_anchor, hidden_chunk, pos_chunk);
        anyhow::bail!(
            "this backend has no chunked prefill stage (layer_pre_chunk); \
             use chunk_tokens >= block_len so prefill runs one chunk per phase"
        )
    }

    /// Chunked twin of [`ExecBackend::layer_post`]: APB modified-mask
    /// attention + O-proj/FFN for the layout rows starting at absolute row
    /// `row0` (`hidden_rows`/`q_rows` carry only those rows; `k`/`v` are the
    /// full `[anchor | local]` keys of the layer). The mask is evaluated at
    /// the absolute row index `row0 + i`, so a chunked pass sees exactly the
    /// keys the monolithic pass shows that row.
    ///
    /// Default: delegates to [`ExecBackend::layer_post`] when the chunk IS
    /// the full layout (`row0 == 0`, same row count as `k`) — the one-chunk
    /// fast path every backend already supports — and refuses otherwise.
    #[allow(clippy::too_many_arguments)]
    fn layer_post_rows(
        &self,
        layer: usize,
        hidden_rows: &Tensor,
        q_rows: &Tensor,
        row0: usize,
        k: &Tensor,
        v: &Tensor,
        k_pass: &Tensor,
        v_pass: &Tensor,
        pass_len: i32,
        n_anchor: i32,
    ) -> Result<Tensor> {
        if row0 == 0 && hidden_rows.shape[0] == k.shape[0] {
            return self.layer_post(
                layer, hidden_rows, q_rows, k, v, k_pass, v_pass, pass_len, n_anchor,
            );
        }
        anyhow::bail!(
            "this backend has no row-offset prefill attention (layer_post_rows); \
             use chunk_tokens >= block_len so prefill runs one chunk per phase"
        )
    }

    /// Decode stage 1 (Algorithm 3): project + RoPE the new-token chunk at
    /// per-row positions `pos` (`pos.len() == hidden rows`). A single
    /// session's chunk passes consecutive positions; a continuous-batching
    /// step stacks one row per active session, each at its own position.
    /// Returns `(q, k, v)`.
    fn decode_pre(
        &self,
        layer: usize,
        hidden: &Tensor,
        pos: &[i32],
    ) -> Result<(Tensor, Tensor, Tensor)>;

    /// Decode stage 2: per-host partial attention of the chunk against the
    /// padded local KV cache, returning `(out, lse)` for the online-softmax
    /// merge. If `self_causal`, the chunk's own KV has been appended and row
    /// `i` sees `j < cache_len - (n-1-i)`; otherwise `j < cache_len`.
    ///
    /// The `(out, lse)` pair is the decode attention *partial*: the unit
    /// both merge collectives move. Pass-KV AllGathers one partial per
    /// rank; pass-Q rotates the same partials around the `qring`
    /// (`docs/ADR-007-adaptive-decode.md`). Either way the coordinator
    /// folds them with `util::tensor::merge_partials` in rank order, so a
    /// backend must produce partials whose value does NOT depend on which
    /// collective carries them — that is the bit-identity invariant
    /// `rust/tests/pass_strategy.rs` pins across strategies.
    fn decode_attn(
        &self,
        q: &Tensor,
        k_cache: &Tensor,
        v_cache: &Tensor,
        cache_len: usize,
        self_causal: bool,
    ) -> Result<(Tensor, Tensor)>;

    /// Decode attention over a `[shared | private]` [`KvView`] — the seam
    /// the prefix cache rides (`docs/ADR-003-prefix-caching.md`). Semantics
    /// match [`ExecBackend::decode_attn`] over the view's logical
    /// concatenation: every shared row is strictly in the chunk's past
    /// (always visible); the self-causal rule applies to the combined
    /// valid length.
    ///
    /// The default implementation delegates to `decode_attn` when the view
    /// has no shared segment — so cold sessions take the exact pre-existing
    /// backend path (bit-for-bit, PJRT included) — and otherwise runs the
    /// host-side segmented kernel `sim::masked_attention_seg`, which walks
    /// the segments in key order with the same accumulation order as a
    /// contiguous cache (for `SimEngine` that IS the native kernel; for
    /// PJRT it is the host-side fallback, same pattern as `attn_partial`).
    fn decode_attn_view(
        &self,
        q: &Tensor,
        view: &KvView<'_>,
        self_causal: bool,
    ) -> Result<(Tensor, Tensor)> {
        if view.shared.is_none() {
            return self.decode_attn(q, view.tail.k, view.tail.v, view.tail.len,
                                    self_causal);
        }
        let n = q.shape[0];
        let total = view.len();
        Ok(sim::masked_attention_seg(q, &view.segs(), |qi, kj| {
            let visible = if self_causal {
                total.saturating_sub(n - 1 - qi)
            } else {
                total
            };
            kj < visible
        }))
    }

    /// Batched decode attention: one backend pass serving all active
    /// sessions of a continuous-batching step. `q` is `[B, h, hd]` with one
    /// row per session; row `i` attends its own session's [`KvView`] (all
    /// valid rows visible — the row's own KV, if any, has already been
    /// appended by the caller). Returns stacked
    /// `(out [B, h, hd], lse [B, h])` — per-session partials that merge
    /// across ranks exactly like [`ExecBackend::decode_attn`]'s, under
    /// either pass strategy.
    ///
    /// The default implementation slices per row through
    /// [`ExecBackend::decode_attn_view`]; backends that can fuse the batch
    /// (SimEngine) override it.
    fn decode_attn_batch(
        &self,
        q: &Tensor,
        caches: &[KvView<'_>],
    ) -> Result<(Tensor, Tensor)> {
        let b = q.shape[0];
        anyhow::ensure!(caches.len() == b, "decode_attn_batch: {} rows, {} caches",
                        b, caches.len());
        let mut outs = Vec::with_capacity(b);
        let mut lses = Vec::with_capacity(b);
        for (i, c) in caches.iter().enumerate() {
            let (o, l) = self.decode_attn_view(&q.slice_rows(i, i + 1), c, false)?;
            outs.push(o);
            lses.push(l);
        }
        let out_refs: Vec<&Tensor> = outs.iter().collect();
        let lse_refs: Vec<&Tensor> = lses.iter().collect();
        Ok((Tensor::concat_rows(&out_refs), Tensor::concat_rows(&lse_refs)))
    }

    /// Position-causal partial attention for the exact baseline modes
    /// (`AttnMethod::RingAttn` rotated blocks, `AttnMethod::Dense` single
    /// host): query row `i` (global position `q_pos[i]`) attends key `j`
    /// iff `k_pos[j] <= q_pos[i]`. Returns `(out [n, h, hd], lse [n, h])`,
    /// merge-able across blocks with `util::tensor::merge_partials` — the
    /// online-softmax identity makes the merged result exactly dense causal
    /// attention over the union of key blocks. Rows with no visible key
    /// follow the zero-output / `-inf`-LSE convention.
    ///
    /// The default implementation computes dense masked attention on the
    /// host via `sim::masked_attention` — for `SimEngine` that IS the
    /// native kernel, and for PJRT (whose AOT artifact set predates the
    /// ring path) it acts as the host-side fallback. Ring merging therefore
    /// lives at this trait boundary rather than in the coordinator: a
    /// backend with a fused ring kernel overrides this one method without
    /// touching the rotation logic.
    fn attn_partial(
        &self,
        q: &Tensor,
        k: &Tensor,
        v: &Tensor,
        q_pos: &[i32],
        k_pos: &[i32],
    ) -> Result<(Tensor, Tensor)> {
        anyhow::ensure!(q.shape[0] == q_pos.len(),
                        "attn_partial: {} q rows, {} positions", q.shape[0], q_pos.len());
        anyhow::ensure!(k.shape[0] == k_pos.len(),
                        "attn_partial: {} k rows, {} positions", k.shape[0], k_pos.len());
        Ok(sim::masked_attention(q, k, v, |qi, kj| k_pos[kj] <= q_pos[qi]))
    }

    /// Decode stage 3: merged attention -> O-proj + residual + FFN.
    fn decode_post(&self, layer: usize, hidden: &Tensor, att: &Tensor) -> Result<Tensor>;

    /// Final norm + LM head: `hidden [n, d] -> logits [n, vocab]`.
    fn lm_head(&self, hidden: &Tensor) -> Result<Tensor>;
}

/// Instantiate the backend a config asks for. `Sim` always works; `Pjrt`
/// needs the `pjrt` cargo feature (and artifacts on disk).
pub fn create_backend(cfg: &Config) -> Result<Box<dyn ExecBackend>> {
    match cfg.backend {
        BackendKind::Sim => Ok(Box::new(SimEngine::new(cfg)?)),
        BackendKind::Pjrt => load_pjrt(cfg),
    }
}

#[cfg(feature = "pjrt")]
fn load_pjrt(cfg: &Config) -> Result<Box<dyn ExecBackend>> {
    Ok(Box::new(pjrt::Engine::load(cfg)?))
}

#[cfg(not(feature = "pjrt"))]
fn load_pjrt(cfg: &Config) -> Result<Box<dyn ExecBackend>> {
    anyhow::bail!(
        "config '{}' requests the PJRT backend, but this build has no `pjrt` \
         feature; rebuild with `--features pjrt` (plus a vendored `xla` crate) \
         or use a Sim config (Config::sim_tiny / load_config_or_sim)",
        cfg.name
    )
}

/// Load the golden blob recorded by aot.py (tiny config only). Sim configs
/// carry no manifest and return `Ok(None)`.
pub fn load_golden(cfg: &Config) -> Result<Option<(Blob, usize)>> {
    match cfg.manifest.get("golden") {
        None | Some(Json::Null) => Ok(None),
        Some(g) => {
            let n_new = g.req("n_new")?.as_usize().context("golden n_new")?;
            Ok(Some((Blob::load(&cfg.dir, g)?, n_new)))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_backend_always_constructs() {
        let cfg = Config::sim_tiny();
        let b = create_backend(&cfg).expect("sim backend");
        assert_eq!(b.kind(), BackendKind::Sim);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_backend_gated_off_by_default() {
        let mut cfg = Config::sim_tiny();
        cfg.backend = BackendKind::Pjrt;
        let err = create_backend(&cfg).unwrap_err();
        assert!(format!("{err:#}").contains("pjrt"));
    }

    #[test]
    fn sim_config_has_no_golden() {
        let cfg = Config::sim_tiny();
        assert!(load_golden(&cfg).unwrap().is_none());
    }
}
