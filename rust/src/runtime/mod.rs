//! PJRT runtime: load HLO-text artifacts produced by `python/compile/aot.py`,
//! compile them on the CPU PJRT client, and execute them from the
//! coordinator hot path.
//!
//! Two deliberate performance choices (EXPERIMENTS.md §Perf):
//!  * model weights are uploaded to device buffers ONCE per engine and
//!    executables run through `execute_b`, so the per-call cost is only the
//!    activation transfers;
//!  * one `Engine` per simulated host — mirroring the paper's one-process-
//!    per-GPU topology and keeping PJRT state thread-local.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};
use xla::{ElementType, HloModuleProto, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable,
          XlaComputation};

use crate::config::Config;
use crate::util::blob::Blob;
use crate::util::json::Json;
use crate::util::tensor::Tensor;

/// Input/output declaration recorded by the AOT manifest.
#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub dtype: String,
    pub shape: Vec<usize>,
}

pub struct Artifact {
    pub name: String,
    pub exe: PjRtLoadedExecutable,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

/// A per-host PJRT engine holding the compiled executables and the
/// device-resident weight buffers.
pub struct Engine {
    pub client: PjRtClient,
    artifacts: BTreeMap<String, Artifact>,
    weights: BTreeMap<String, PjRtBuffer>,
}

fn parse_iospec(v: &Json, default_name: &str) -> Result<IoSpec> {
    Ok(IoSpec {
        name: v
            .get("name")
            .and_then(|n| n.as_str())
            .unwrap_or(default_name)
            .to_string(),
        dtype: v.req("dtype")?.as_str().context("dtype")?.to_string(),
        shape: v.req("shape")?.usize_vec().context("shape")?,
    })
}

impl Engine {
    /// Compile the named artifacts (or all from the manifest when `names`
    /// is empty) and upload all weights.
    pub fn load(cfg: &Config, names: &[&str]) -> Result<Engine> {
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        let manifest_arts = cfg
            .manifest
            .req("artifacts")?
            .as_obj()
            .context("manifest artifacts not an object")?;
        let mut artifacts = BTreeMap::new();
        for (name, meta) in manifest_arts {
            if !names.is_empty() && !names.contains(&name.as_str()) {
                continue;
            }
            let file = meta.req("file")?.as_str().context("artifact file")?;
            let path = cfg.dir.join(file);
            let proto = HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow::anyhow!("parsing {}: {e:?}", path.display()))?;
            let comp = XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compiling {name}: {e:?}"))?;
            let inputs = meta
                .req("inputs")?
                .as_arr()
                .context("inputs")?
                .iter()
                .map(|v| parse_iospec(v, "?"))
                .collect::<Result<Vec<_>>>()?;
            let outputs = meta
                .req("outputs")?
                .as_arr()
                .context("outputs")?
                .iter()
                .enumerate()
                .map(|(i, v)| parse_iospec(v, &format!("out{i}")))
                .collect::<Result<Vec<_>>>()?;
            artifacts.insert(
                name.clone(),
                Artifact { name: name.clone(), exe, inputs, outputs },
            );
        }
        if artifacts.is_empty() {
            bail!("no artifacts loaded from {}", cfg.dir.display());
        }

        // Upload weights once.
        let blob = Blob::load(&cfg.dir, cfg.manifest.req("weights")?)?;
        let mut weights = BTreeMap::new();
        for name in blob.names().map(str::to_string).collect::<Vec<_>>() {
            let t = blob.tensor(&name)?;
            let buf = client
                .buffer_from_host_buffer::<f32>(&t.data, &t.shape, None)
                .map_err(|e| anyhow::anyhow!("uploading weight {name}: {e:?}"))?;
            weights.insert(name, buf);
        }
        Ok(Engine { client, artifacts, weights })
    }

    pub fn artifact_names(&self) -> Vec<&str> {
        self.artifacts.keys().map(|s| s.as_str()).collect()
    }

    pub fn artifact(&self, name: &str) -> Result<&Artifact> {
        self.artifacts
            .get(name)
            .with_context(|| format!("artifact '{name}' not loaded"))
    }

    pub fn weight(&self, name: &str) -> Result<&PjRtBuffer> {
        self.weights
            .get(name)
            .with_context(|| format!("weight '{name}' not found"))
    }

    /// Per-layer weight lookup (`layers.{i}.{short}`).
    pub fn layer_weight(&self, layer: usize, short: &str) -> Result<&PjRtBuffer> {
        self.weight(&format!("layers.{layer}.{short}"))
    }

    pub fn upload_f32(&self, t: &Tensor) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer::<f32>(&t.data, &t.shape, None)
            .map_err(|e| anyhow::anyhow!("upload f32 {:?}: {e:?}", t.shape))
    }

    pub fn upload_i32(&self, v: &[i32], shape: &[usize]) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer::<i32>(v, shape, None)
            .map_err(|e| anyhow::anyhow!("upload i32 {shape:?}: {e:?}"))
    }

    pub fn scalar_i32(&self, v: i32) -> Result<PjRtBuffer> {
        self.upload_i32(&[v], &[])
    }

    /// Execute an artifact with pre-staged buffers; outputs decoded to
    /// host-side f32 tensors using the manifest shapes.
    pub fn exec(&self, name: &str, args: &[&PjRtBuffer]) -> Result<Vec<Tensor>> {
        let art = self.artifact(name)?;
        if args.len() != art.inputs.len() {
            bail!(
                "artifact '{name}' wants {} inputs, got {}",
                art.inputs.len(),
                args.len()
            );
        }
        let outs = art
            .exe
            .execute_b(args)
            .map_err(|e| anyhow::anyhow!("executing {name}: {e:?}"))?;
        let tuple = outs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching {name} result: {e:?}"))?;
        // aot.py lowers with return_tuple=True: single tuple literal.
        let parts: Vec<Literal> = tuple
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untupling {name}: {e:?}"))?;
        if parts.len() != art.outputs.len() {
            bail!(
                "artifact '{name}': manifest says {} outputs, tuple has {}",
                art.outputs.len(),
                parts.len()
            );
        }
        let mut tensors = Vec::with_capacity(parts.len());
        for (lit, spec) in parts.into_iter().zip(&art.outputs) {
            let lit = match lit.ty() {
                Ok(ElementType::F32) => lit,
                _ => lit
                    .convert(ElementType::F32.primitive_type())
                    .map_err(|e| anyhow::anyhow!("converting {name} output: {e:?}"))?,
            };
            let data = lit
                .to_vec::<f32>()
                .map_err(|e| anyhow::anyhow!("reading {name} output: {e:?}"))?;
            tensors.push(Tensor::new(spec.shape.clone(), data)?);
        }
        Ok(tensors)
    }

    /// Convenience: execute with host-side values (tests / cold paths; the
    /// hot path stages buffers itself and reuses weight buffers).
    pub fn exec_t(&self, name: &str, args: &[HostArg]) -> Result<Vec<Tensor>> {
        let staged: Vec<PjRtBuffer> = args
            .iter()
            .map(|a| match a {
                HostArg::F32(t) => self.upload_f32(t),
                HostArg::I32s(v, shape) => self.upload_i32(v, shape),
                HostArg::ScalarI32(v) => self.scalar_i32(*v),
            })
            .collect::<Result<Vec<_>>>()?;
        let refs: Vec<&PjRtBuffer> = staged.iter().collect();
        self.exec(name, &refs)
    }
}

/// Host-side argument for `exec_t` cold paths.
pub enum HostArg {
    F32(Tensor),
    I32s(Vec<i32>, Vec<usize>),
    ScalarI32(i32),
}

/// Load the golden blob recorded by aot.py (tiny config only).
pub fn load_golden(cfg: &Config) -> Result<Option<(Blob, usize)>> {
    match cfg.manifest.get("golden") {
        None | Some(Json::Null) => Ok(None),
        Some(g) => {
            let n_new = g.req("n_new")?.as_usize().context("golden n_new")?;
            Ok(Some((Blob::load(&cfg.dir, g)?, n_new)))
        }
    }
}
